"""Document-partitioned search two ways (paper §3's scale-out path):

1. FLEET-LEVEL: one Lambda function per partition, scatter-gather through
   the FaaS runtime (latency = max over partitions + merge).
2. MESH-LEVEL: the same partitioning as a single shard_map program over a
   device mesh — each device owns a partition, global top-k via
   all-gather-merge. On this CPU container the mesh is 1×1..2×2 logical
   (set XLA_FLAGS=--xla_force_host_platform_device_count=4 to see 4 real
   partitions); on the production mesh it is 16×16.

Both must agree with the exact BM25 oracle.

    PYTHONPATH=src python examples/partitioned_search.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kvstore import KVStore
from repro.core.object_store import ObjectStore
from repro.core.partition import ScatterGather
from repro.core.runtime import FaaSRuntime, RuntimeConfig
from repro.data.corpus import synth_corpus, synth_queries
from repro.search.bm25 import encode_queries
from repro.search.distributed import (build_partitioned_state,
                                      make_dist_search_fn, partition_corpus)
from repro.search.oracle import OracleSearcher
from repro.search.searcher import SearchConfig, make_search_handler
from repro.search.service import index_corpus

N_PARTS = 4
docs = synth_corpus(2_000, vocab=3_000, seed=0)
queries = synth_queries(docs, 5, seed=1)
oracle = OracleSearcher(docs)

# -- 1. fleet-level scatter-gather ------------------------------------------------
# Distributed-IR subtlety: every partition must score with GLOBAL
# idf/avgdl (compute_global_stats) or the merged ranking diverges from a
# single-index build — the part of §3 that is NOT "just" engineering.
from repro.index.builder import compute_global_stats

print(f"== fleet-level: {N_PARTS} Lambda functions, scatter-gather ==")
gstats = compute_global_stats(docs)
parts, per = partition_corpus(docs, N_PARTS)
store, doc_store = ObjectStore(), KVStore()
runtime = FaaSRuntime(RuntimeConfig())
fns = []
for p, pdocs in enumerate(parts):
    catalog = index_corpus(pdocs, store, doc_store, asset=f"index-p{p}",
                           global_stats=gstats)
    runtime.register(f"search-p{p}", make_search_handler(
        catalog, doc_store, f"index-p{p}", SearchConfig(k=10)))
    fns.append(f"search-p{p}")
sg = ScatterGather(runtime, fns)

for q in queries:
    hits, lat, _ = sg.search({"q": q, "k": 10, "fetch_docs": False}, 10)
    # fleet hits carry partition-local ids; globalize via partition offset
    got = [h.partition * per + h.doc_id for h in hits]
    want = [d for d, _ in oracle.search(q, k=10)]
    ok = got[:3] == want[:3]
    print(f"  '{q[:28]:30s}' lat={lat * 1e3:7.1f} ms top3 "
          f"{'==' if ok else '!='} oracle")

# -- 2. mesh-level shard_map ---------------------------------------------------------
n_dev = len(jax.devices())
shape = {1: (1, 1), 2: (2, 1), 4: (2, 2), 8: (4, 2)}.get(n_dev, (1, 1))
n_mesh_parts = shape[0] * shape[1]        # one partition per device
print(f"\n== mesh-level: shard_map over {shape} device mesh "
      f"({n_mesh_parts} partitions) ==")
state, cfg, vocab = build_partitioned_state(docs, n_mesh_parts,
                                            {"k": 10, "max_blocks": 64})
mesh = jax.make_mesh(shape, ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
# partition axis (N_PARTS) shards over however many devices exist;
# XLA places 4/n_dev partitions per device.
fn = make_dist_search_fn(cfg, ("data", "model"))
tids, qtf = encode_queries(vocab, queries, max_terms=cfg.max_terms)
with jax.set_mesh(mesh):
    scores, ids = jax.jit(fn)(
        jax.tree_util.tree_map(jnp.asarray, state), tids, qtf)

for qi, q in enumerate(queries):
    want = [d for d, _ in oracle.search(q, k=10)]
    got = [int(i) for v, i in zip(scores[qi], ids[qi]) if v > 0]
    ok = got[:3] == want[:3]
    print(f"  '{q[:28]:30s}' top3 {'==' if ok else '!='} oracle "
          f"({[round(float(v), 2) for v in scores[qi][:3]]})")

print("\nboth realizations implement the same math: per-partition BM25 + "
      "k-survivor merge — paper §3, 'mostly a matter of software "
      "engineering'.")
