"""Document-partitioned search two ways (paper §3's scale-out path):

1. FLEET-LEVEL: ``build_partitioned_search_app`` — one Lambda function +
   one published segment per partition (packed with GLOBAL idf/avgdl by
   the one true packer, ``IndexWriter``), ``/search`` routed through the
   Gateway → ScatterGather → merge. All partitions fan out at the same
   arrival instant, so latency is max-over-partitions; a list of queries
   micro-batches as ONE invocation per partition (Q>1 through the same
   vmapped scoring fn).
2. MESH-LEVEL: the same partitioning as a single shard_map program over a
   device mesh — each device owns a partition and runs the same scoring
   core (``bm25.score_dense``), global top-k via all-gather-merge. On this
   CPU container the mesh is 1×1 (set
   XLA_FLAGS=--xla_force_host_platform_device_count=4 to see 4 real
   partitions); on the production mesh it is 16×16.

Both must agree with the exact BM25 oracle — and with each other, because
scoring and packing each have exactly one implementation.

    PYTHONPATH=src python examples/partitioned_search.py
"""

import jax
import jax.numpy as jnp

from repro.core.partition import FleetSpec, ReplicationSpec
from repro.data.corpus import synth_corpus, synth_queries
from repro.parallel import compat
from repro.search.bm25 import encode_queries
from repro.search.distributed import build_partitioned_state, make_dist_search_fn
from repro.search.oracle import OracleSearcher
from repro.search.service import build_partitioned_search_app

N_PARTS = 4
docs = synth_corpus(2_000, vocab=3_000, seed=0)
queries = synth_queries(docs, 5, seed=1)
oracle = OracleSearcher(docs)

# -- 1. fleet-level scatter-gather ------------------------------------------------
print(f"== fleet-level: {N_PARTS} Lambda functions, scatter-gather ==")
app = build_partitioned_search_app(docs, FleetSpec(n_parts=N_PARTS))

for q in queries:
    r = app.query(q, k=10)
    got = r.body["ids"]                      # already globalized by the app
    want = [d for d, _ in oracle.search(q, k=10)]
    ok = got[:3] == want[:3]
    cold = sum(p["cold"] for p in r.body["partitions"])
    print(f"  '{q[:28]:30s}' lat={r.latency_s * 1e3:7.1f} ms top3 "
          f"{'==' if ok else '!='} oracle  ({cold}/{N_PARTS} cold)")

# micro-batch: all 5 queries in ONE invocation per partition
r = app.query(queries, k=10, t_arrival=app.runtime.clock + 1)
n_ok = sum(res["ids"][:3] == [d for d, _ in oracle.search(q, k=3)]
           for q, res in zip(queries, r.body["results"]))
print(f"  batch Q={len(queries)}: {len(r.body['partitions'])} invocations, "
      f"lat={r.latency_s * 1e3:.1f} ms, {n_ok}/{len(queries)} top3 == oracle")
print(f"  fleet={app.runtime.fleet_size}, warm={app.runtime.warm_fraction():.0%}, "
      f"cost=${app.runtime.ledger.total_dollars:.6f}")

# -- 1b. replicated partitions + hedged scatter legs ------------------------------
# Each segment is served by TWO independent instance pools; when a primary
# projects a cold start (we kill its instance), the scatter leg fires a
# backup on the replica at the same arrival instant and the warm pool wins —
# the tail flattens, the ledger shows the hedging tax, results stay
# bit-identical (same PackedIndex behind every replica).
print(f"\n== replicated: {N_PARTS} partitions x 2 replicas, hedged legs ==")
from repro.core.partition import HedgePolicy  # noqa: E402

happ = build_partitioned_search_app(docs, FleetSpec(
    n_parts=N_PARTS, replication=ReplicationSpec(replicas=2, hedge=HedgePolicy())))
happ.warm()
for q in queries:                                 # warm traffic → policy history
    happ.query(q, k=10, t_arrival=happ.runtime.clock + 0.05, fetch_docs=False)
for q in queries:
    happ.runtime.kill_instance(fn=happ.fn_names[0])   # partition 0 goes cold
    r = happ.query(q, k=10, t_arrival=happ.runtime.clock + 0.05,
                   fetch_docs=False)
    hedged = [p["fn"] for p in r.body["partitions"] if p["hedged"]]
    ok = r.body["ids"][:3] == [d for d, _ in oracle.search(q, k=10)][:3]
    print(f"  '{q[:28]:30s}' lat={r.latency_s * 1e3:7.1f} ms top3 "
          f"{'==' if ok else '!='} oracle  hedged={hedged or '-'}")
led = happ.runtime.ledger
print(f"  hedge tax: ${led.hedge_dollars:.8f} of ${led.total_dollars:.6f} "
      f"({led.hedge_invocations} backup legs)")

# -- 2. mesh-level shard_map ---------------------------------------------------------
n_dev = len(jax.devices())
shape = {1: (1, 1), 2: (2, 1), 4: (2, 2), 8: (4, 2)}.get(n_dev, (1, 1))
n_mesh_parts = shape[0] * shape[1]        # one partition per device
print(f"\n== mesh-level: shard_map over {shape} device mesh "
      f"({n_mesh_parts} partitions) ==")
state, cfg, vocab = build_partitioned_state(docs, n_mesh_parts,
                                            {"k": 10, "max_blocks": 64})
mesh = compat.make_mesh(shape, ("data", "model"))
fn = make_dist_search_fn(cfg, ("data", "model"), mesh=mesh)
tids, qtf = encode_queries(vocab, queries, max_terms=cfg.max_terms,
                           idf=state["idf"])
with compat.use_mesh(mesh):
    scores, ids = jax.jit(fn)(
        jax.tree_util.tree_map(jnp.asarray, state), tids, qtf)

for qi, q in enumerate(queries):
    want = [d for d, _ in oracle.search(q, k=10)]
    got = [int(i) for v, i in zip(scores[qi], ids[qi]) if v > 0]
    ok = got[:3] == want[:3]
    print(f"  '{q[:28]:30s}' top3 {'==' if ok else '!='} oracle "
          f"({[round(float(v), 2) for v in scores[qi][:3]]})")

print("\nboth realizations run the SAME scoring core (bm25.score_dense) over "
      "the SAME packing (IndexWriter): per-partition BM25 + k-survivor merge "
      "— paper §3, now actually 'a matter of software engineering'.")
