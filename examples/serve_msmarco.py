"""End-to-end serving driver (the paper's kind of system): an MS-MARCO-like
passage corpus served through the serverless stack under a batched query
load, with the paper's measurements reported at the end — latency split
cold/warm, <300 ms check, queries-per-dollar, fungibility, and the §3
operations: batch reindex with zero-downtime switch-over, instance failure,
and straggler hedging.

    PYTHONPATH=src python examples/serve_msmarco.py [--docs 50000]
"""

import argparse
import time

import numpy as np

from repro.core.cost import fungibility_check, paper_headline_cost
from repro.core.refresh import refresh_fleet
from repro.core.runtime import RuntimeConfig
from repro.data.corpus import synth_corpus, synth_queries
from repro.index.builder import IndexWriter, write_segment
from repro.search.searcher import SearchConfig
from repro.search.service import build_search_app

ap = argparse.ArgumentParser()
ap.add_argument("--docs", type=int, default=30_000)
ap.add_argument("--queries", type=int, default=400)
ap.add_argument("--qps", type=float, default=25.0)
args = ap.parse_args()

print(f"building corpus + index ({args.docs} docs)...")
docs = synth_corpus(args.docs, vocab=max(4000, args.docs // 2), seed=0)
queries = synth_queries(docs, args.queries, seed=1)
app = build_search_app(
    docs,
    runtime_config=RuntimeConfig(memory_bytes=2 << 30, hedge_after_s=0.5),
    search_config=SearchConfig(k=10),
)

print(f"replaying {len(queries)} queries at {args.qps} QPS "
      "(Poisson arrivals)...")
rng = np.random.default_rng(7)
t = 0.0
wall0 = time.perf_counter()
for q in queries:
    t += float(rng.exponential(1.0 / args.qps))
    r = app.query(q, k=10, t_arrival=t)
    assert r.ok
wall = time.perf_counter() - wall0

recs = app.runtime.records
warm = sorted(r.latency_s for r in recs if not r.cold)
cold = sorted(r.latency_s for r in recs if r.cold)
led = app.runtime.ledger

print("\n=== paper §2 scorecard (simulated end-to-end latencies) ===")
print(f"warm queries: {len(warm)}  p50 {np.median(warm)*1e3:7.1f} ms  "
      f"p99 {np.quantile(warm, .99)*1e3:7.1f} ms   (paper budget < 300 ms)")
if cold:
    print(f"cold queries: {len(cold)}  p50 {np.median(cold)*1e3:7.1f} ms  "
          "(container boot + index hydration)")
print(f"under 300 ms (warm): {100 * np.mean(np.asarray(warm) < .3):.0f}%")
print(f"fleet peak size: {app.runtime.fleet_size} instances; "
      f"hedged: {sum(r.hedged for r in recs)}")
print(f"cost: ${led.total_dollars:.6f} for {led.invocations} queries → "
      f"{led.queries_per_dollar():,.0f} q/$  "
      f"(paper headline {paper_headline_cost():,.0f})")
a, b = fungibility_check(10, 10_000, 100, 1_000)
print(f"fungibility: 10 QPS×10,000 s = ${a:.2f} ≡ 100 QPS×1,000 s = ${b:.2f}")

print("\n=== paper §3 operations drill ===")
# batch reindex: add docs, publish v2 alongside v1, atomic switch + refresh
extra = synth_corpus(1000, vocab=max(4000, args.docs // 2), seed=99)
w = IndexWriter()
w.add_many(docs + [(f"new-{i}", t_) for i, (_, t_) in enumerate(extra)])
app.catalog.publish(app.asset, "v2", write_segment(w.pack()))
n = refresh_fleet(app.runtime, app.asset)
r = app.query(queries[0], t_arrival=app.runtime.clock + 1)
print(f"reindex → v2 published, {n} warm instances refreshed, "
      f"first query on v2: {'ok' if r.ok else 'FAIL'} "
      f"(version {r.body['version']})")

# failure injection: kill an instance; next query cold-starts a new one
app.runtime.kill_instance()
r = app.query(queries[1], t_arrival=app.runtime.clock + 0.01)
print(f"instance killed → next query "
      f"{'cold-started new instance' if r.record.cold else 'served warm'}, "
      f"latency {r.latency_s * 1e3:.1f} ms")
print(f"\n(real wall time: {wall:.1f}s for the replay)")
