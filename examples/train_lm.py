"""Train a ~100M-parameter LM for a few hundred steps on CPU, with the
production substrate: sharded jit step, checkpointing to the object store,
failure injection mid-run, and automatic restart recovery.

This is the conventional-training half of the framework; its checkpoints
land in the same ObjectStore the serving fleet hydrates from (paper §3's
batch-rebuild → refresh bridge).

    # quick CPU drill (~3 min; ~100M model, 30 steps + failure recovery):
    PYTHONPATH=src python examples/train_lm.py
    # the full few-hundred-step run (~1 h on this 1-core host; minutes on
    # a real accelerator):
    PYTHONPATH=src python examples/train_lm.py --steps 300 --batch 16 --seq 256
"""

import argparse
import sys

from repro.launch import train as train_mod

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=30)
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--seq", type=int, default=64)
ap.add_argument("--arch", default="stablelm-3b")
ap.add_argument("--fail-at", type=int, nargs="*", default=[18])
args = ap.parse_args()

sys.argv = [
    "train", "--arch", args.arch, "--preset", "100m",
    "--steps", str(args.steps), "--batch", str(args.batch),
    "--seq", str(args.seq), "--ckpt-every", "50",
    "--metrics-out", "/tmp/train_lm_metrics.json",
]
if args.fail_at:
    sys.argv += ["--fail-at"] + [str(x) for x in args.fail_at]

raise SystemExit(train_mod.main())
