"""Quickstart: build an index, publish it to the (simulated) object store,
and serve interactive queries through the serverless stack — Figure 1 of the
paper in ~40 lines of user code.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.data.corpus import synth_corpus, synth_queries
from repro.search.service import build_search_app

# 1. A document collection (synthetic stand-in for MS MARCO passages).
docs = synth_corpus(5_000, vocab=8_000, seed=0)
print(f"corpus: {len(docs)} docs, e.g. {docs[0][1][:60]}...")

# 2. One call wires the whole serverless application:
#    IndexWriter → packed segments → ObjectStore (S3)
#    raw docs → KVStore (DynamoDB)
#    stateless BM25 evaluator → FaaSRuntime (Lambda) ← Gateway (API GW)
app = build_search_app(docs)

# 3. Search. The first query lands on a COLD instance (hydrates the index
#    from the store); repeats are WARM (in-memory, paper §2).
for i, q in enumerate(synth_queries(docs, 5, seed=1)):
    r = app.query(q, k=3, t_arrival=app.runtime.clock + 1.0)
    hits = ", ".join(f"{d}:{s:.2f}" for d, s in
                     zip(r.body["ids"], r.body["scores"]))
    kind = "cold" if r.record.cold else "warm"
    print(f"q{i} [{kind} {r.latency_s * 1e3:7.1f} ms] "
          f"'{q[:30]}...' → {hits}")

# 4. The economics (paper §2): per-invocation GB·s billing.
led = app.runtime.ledger
print(f"\ninvocations: {led.invocations}, "
      f"compute cost: ${led.compute_dollars:.6f}, "
      f"queries/$: {led.queries_per_dollar():,.0f} "
      "(paper headline: 100,000)")
