"""Model zoo: transformer variants (decode == forward), MoE dispatch vs
dropless oracle, GNN invariances, recsys forwards, embedding lookup."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.common import init_params
from repro.models.moe import MoEConfig, moe_defs, moe_ffn, moe_ffn_dense_oracle
from repro.models.transformer import (LMConfig, MLAConfig, lm_decode,
                                      lm_forward, lm_loss, lm_param_defs,
                                      lm_prefill)

KEY = jax.random.PRNGKey(0)


def _lm_cfgs():
    return {
        "dense-gqa": LMConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                              n_kv_heads=2, d_ff=128, vocab=256,
                              dtype=jnp.float32),
        "swa-ring": LMConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                             n_kv_heads=2, d_ff=128, vocab=256, window=8,
                             dtype=jnp.float32),
        "gelu-partial-rope": LMConfig(name="t", n_layers=2, d_model=64,
                                      n_heads=4, n_kv_heads=4, d_ff=128,
                                      vocab=256, ffn_act="gelu", rope_pct=0.25,
                                      dtype=jnp.float32),
        "moe": LMConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                        n_kv_heads=4, d_ff=128, vocab=256, dtype=jnp.float32,
                        moe=MoEConfig(n_experts=8, top_k=2, d_model=64,
                                      d_ff=32, capacity_factor=4.0)),
        "mla-moe": LMConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                            n_kv_heads=4, d_ff=128, vocab=256,
                            dtype=jnp.float32,
                            mla=MLAConfig(q_lora=32, kv_lora=16, rope_dim=8,
                                          nope_dim=16, v_dim=16),
                            moe=MoEConfig(n_experts=8, top_k=2, d_model=64,
                                          d_ff=32, n_shared=1,
                                          capacity_factor=4.0)),
    }


@pytest.mark.parametrize("name", list(_lm_cfgs()))
def test_lm_decode_matches_forward(name):
    """Prefill + N decode steps reproduce the full-forward logits."""
    cfg = _lm_cfgs()[name]
    params = init_params(lm_param_defs(cfg), KEY)
    B, S, EXTRA = 2, 12, 3
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + EXTRA), 0,
                              cfg.vocab)
    logits_full, _ = lm_forward(params, toks, cfg)
    pl_logits, cache = lm_prefill(params, toks[:, :S], cfg, max_len=S + EXTRA)
    np.testing.assert_allclose(np.asarray(pl_logits),
                               np.asarray(logits_full[:, S - 1]),
                               rtol=2e-2, atol=2e-2)
    for t in range(EXTRA):
        step_logits, cache = lm_decode(params, cache, toks[:, S + t:S + t + 1],
                                       jnp.int32(S + t), cfg)
        np.testing.assert_allclose(np.asarray(step_logits),
                                   np.asarray(logits_full[:, S + t]),
                                   rtol=5e-2, atol=5e-2)


def test_lm_loss_decreases_with_training():
    cfg = _lm_cfgs()["dense-gqa"]
    from repro.train.optim import OptConfig
    from repro.train.steps import init_train_state, make_train_step
    from repro.data.lm import LMDataConfig, LMTokenStream
    params = init_params(lm_param_defs(cfg), KEY)
    state = init_train_state(params)
    step = jax.jit(make_train_step(lambda p, b: lm_loss(p, b, cfg),
                                   OptConfig(lr=3e-3, warmup_steps=5,
                                             total_steps=60)))
    data = LMTokenStream(LMDataConfig(vocab=cfg.vocab, batch=8, seq=32))
    losses = []
    for i in range(60):
        state, m = step(state, data.batch(i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.2


def test_swa_masks_beyond_window():
    """A token > window steps back must not influence the current logits."""
    cfg = _lm_cfgs()["swa-ring"]   # window=8
    params = init_params(lm_param_defs(cfg), KEY)
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 24), 0, cfg.vocab)
    # flipping token 0 must not change logits at position 20 (>2×window away
    # — with 2 layers the receptive field is 2·(window−1))
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab)
    l1, _ = lm_forward(params, toks, cfg)
    l2, _ = lm_forward(params, toks2, cfg)
    np.testing.assert_allclose(np.asarray(l1[0, 20:]), np.asarray(l2[0, 20:]),
                               rtol=1e-5, atol=1e-5)
    assert not np.allclose(np.asarray(l1[0, 1]), np.asarray(l2[0, 1]))


def test_moe_capacity_dispatch_matches_oracle():
    cfg = MoEConfig(n_experts=8, top_k=2, d_model=32, d_ff=16, n_shared=1,
                    capacity_factor=8.0)
    params = init_params(moe_defs(cfg, jnp.float32), KEY)
    x = jax.random.normal(jax.random.PRNGKey(3), (64, 32))
    y, aux = moe_ffn(params, x, cfg)
    y_ref = moe_ffn_dense_oracle(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-5,
                               atol=2e-5)
    assert float(aux) > 0.5          # aux ≈ 1 for near-balanced routing


def test_moe_drops_overflow_tokens():
    cfg = MoEConfig(n_experts=4, top_k=1, d_model=16, d_ff=8,
                    capacity_factor=0.25)
    params = init_params(moe_defs(cfg, jnp.float32), KEY)
    x = jax.random.normal(jax.random.PRNGKey(4), (32, 16))
    y, _ = moe_ffn(params, x, cfg)
    y_ref = moe_ffn_dense_oracle(params, x, cfg)
    # capacity-dropped tokens give zero output rows; oracle doesn't
    dropped = np.all(np.asarray(y) == 0, axis=-1)
    assert dropped.any()
    kept = ~dropped
    np.testing.assert_allclose(np.asarray(y)[kept], np.asarray(y_ref)[kept],
                               rtol=2e-5, atol=2e-5)


def test_gnn_permutation_equivariance():
    """Relabeling nodes permutes outputs correspondingly."""
    from repro.models.gnn import GNNConfig, gnn_forward, gnn_param_defs
    cfg = GNNConfig(name="t", d_feat=6, d_out=4, n_layers=2, d_hidden=16)
    params = init_params(gnn_param_defs(cfg), KEY)
    N, E = 12, 30
    rng = np.random.default_rng(0)
    feat = rng.normal(size=(N, 6)).astype(np.float32)
    src = rng.integers(0, N, E).astype(np.int32)
    dst = rng.integers(0, N, E).astype(np.int32)
    out = gnn_forward(params, {"feat": feat, "src": src, "dst": dst}, cfg)
    perm = rng.permutation(N)
    inv = np.argsort(perm)
    out_p = gnn_forward(params, {"feat": feat[perm],
                                 "src": inv[src].astype(np.int32),
                                 "dst": inv[dst].astype(np.int32)}, cfg)
    np.testing.assert_allclose(np.asarray(out)[perm], np.asarray(out_p),
                               rtol=2e-4, atol=2e-4)


def test_neighbor_sampler_subgraph_valid():
    from repro.data.graphs import NeighborSampler, padded_sizes, synth_graph
    g = synth_graph(500, avg_degree=8, d_feat=5, seed=1)
    sampler = NeighborSampler(g, fanout=(3, 2))
    seeds = np.arange(16)
    sub = sampler.sample(seeds, step=0)
    N_pad, E_pad = padded_sizes(16, (3, 2))
    assert sub["feat"].shape == (N_pad, 5)
    assert sub["src"].shape == (E_pad,)
    real = sub["src"] < N_pad
    # every real edge's dst is a previously-visited node (sampling invariant)
    assert (sub["dst"][real] < sub["n_real_nodes"]).all()
    assert sub["node_mask"].sum() == 16


def test_sharded_lookup_matches_take():
    from repro.models.embedding import sharded_lookup_shardmap
    from repro.parallel import compat
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    table = jax.random.normal(KEY, (64, 8))
    idx = jax.random.randint(jax.random.PRNGKey(5), (16,), 0, 64)
    with compat.use_mesh(mesh):
        got = sharded_lookup_shardmap(mesh, table, idx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(table)[idx],
                               rtol=1e-6)


def test_bert4rec_sampled_loss_close_to_full_when_neg_covers_vocab():
    """With negatives = whole vocab, sampled CE ≈ full-softmax CE."""
    from repro.models.recsys import (RecsysConfig, masked_item_loss,
                                     masked_item_loss_sampled,
                                     recsys_param_defs)
    cfg = RecsysConfig(name="t", kind="bert4rec", embed_dim=8, seq_len=6,
                       n_blocks=1, n_heads=2, n_items=30)
    params = init_params(recsys_param_defs(cfg), KEY)
    B = 4
    rng = np.random.default_rng(0)
    seq = rng.integers(0, 30, (B, 6)).astype(np.int32)
    mask_pos = np.tile(np.array([1, 4], np.int32), (B, 1))
    labels = np.take_along_axis(seq, mask_pos, 1)
    masked = seq.copy()
    np.put_along_axis(masked, mask_pos, 31, 1)
    # full-vocab "labels grid" for the dense oracle
    full_labels = np.full((B, 6), -1, np.int32)
    np.put_along_axis(full_labels, mask_pos, labels, 1)
    l_full, _ = masked_item_loss(params, {"seq": masked,
                                          "labels": full_labels}, cfg)
    neg = np.arange(30, dtype=np.int32)
    l_samp, _ = masked_item_loss_sampled(
        params, {"seq": masked, "mask_pos": mask_pos, "labels": labels,
                 "neg_ids": neg}, cfg)
    # sampled set = vocab ∪ {gold} (gold double-counted) → small gap only
    assert abs(float(l_full) - float(l_samp)) < 0.1


def test_recsys_training_learns():
    from repro.data.recsys_data import CTRStream
    from repro.models.recsys import RecsysConfig, recsys_loss, recsys_param_defs
    from repro.train.optim import OptConfig
    from repro.train.steps import init_train_state, make_train_step
    cfg = RecsysConfig(name="t", kind="fm", n_sparse=6, embed_dim=8,
                       rows_per_field=64)
    params = init_params(recsys_param_defs(cfg), KEY)
    state = init_train_state(params)
    step = jax.jit(make_train_step(lambda p, b: recsys_loss(p, b, cfg),
                                   OptConfig(lr=0.05, warmup_steps=5,
                                             total_steps=80,
                                             weight_decay=0.0)))
    data = CTRStream(n_sparse=6, rows_per_field=64, batch=256)
    losses = []
    for i in range(80):
        state, m = step(state, data.batch_at(i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.02
