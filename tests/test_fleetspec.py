"""FleetSpec — the one typed fleet surface — and the rollover prewarm.

Covers the API-redesign contract: spec validation at construction, the
legacy-kwarg deprecation shim (same fleet, same results, one warning),
mixing both surfaces is an error, lazy hydration is the fleet default,
and the prewarm ping moved from full backfill to term-frequency-ranked
partial hydration without changing a single post-rollover bit.
"""

import dataclasses
import warnings

import numpy as np
import pytest

from repro.core.partition import (FleetSpec, GatewaySpec, HedgePolicy,
                                  IndexSpec, ReplicationSpec, VectorSpec)
from repro.core.runtime import RuntimeConfig
from repro.data.corpus import synth_corpus, synth_queries
from repro.search.searcher import SearchConfig
from repro.search.service import build_partitioned_search_app

CFG = SearchConfig(sim_exec_s=0.002, sim_write_s=0.02)


# -- validation at construction -------------------------------------------------


def test_spec_validates_fields():
    with pytest.raises(ValueError):
        FleetSpec(n_parts=0)
    with pytest.raises(ValueError):
        ReplicationSpec(replicas=0)
    with pytest.raises(ValueError):
        GatewaySpec(routing="clever")
    with pytest.raises(ValueError):
        VectorSpec(dim=0)
    with pytest.raises(ValueError):
        VectorSpec(dtype="float64")
    with pytest.raises(ValueError):
        FleetSpec(n_parts=3, index=IndexSpec(partition_weights=[1.0, 2.0]))
    with pytest.raises(ValueError):
        FleetSpec(n_parts=2, index=IndexSpec(partition_weights=[1.0, -1.0]))


def test_hedge_float_shorthand_resolves_to_policy():
    spec = ReplicationSpec(replicas=2, hedge=0.25)
    assert isinstance(spec.hedge, HedgePolicy)
    assert spec.hedge.after_s == 0.25


# -- the deprecation shim -------------------------------------------------------


def test_legacy_kwargs_warn_and_build_the_same_fleet():
    docs = synth_corpus(80, vocab=150, seed=0)
    q = synth_queries(docs, 1, seed=1)[0]
    spec_app = build_partitioned_search_app(docs, FleetSpec(
        n_parts=2, replication=ReplicationSpec(replicas=2,
                                               hedge=HedgePolicy()),
        runtime_config=RuntimeConfig(), search_config=CFG))
    with pytest.warns(DeprecationWarning):
        legacy_app = build_partitioned_search_app(
            docs, n_parts=2, replicas=2, hedge=HedgePolicy(),
            runtime_config=RuntimeConfig(), search_config=CFG)
    r1 = spec_app.query(q, k=10, fetch_docs=False)
    r2 = legacy_app.query(q, k=10, fetch_docs=False)
    assert r1.body["ext_ids"] == r2.body["ext_ids"]
    assert list(r1.body["scores"]) == list(r2.body["scores"])


def test_bare_int_positional_is_legacy_n_parts():
    docs = synth_corpus(40, vocab=100, seed=2)
    with warnings.catch_warnings():
        warnings.simplefilter("error")      # an int spec is NOT deprecated
        app = build_partitioned_search_app(docs, 3, search_config=CFG)
    assert app.n_parts == 3


def test_mixing_spec_and_legacy_kwargs_is_an_error():
    docs = synth_corpus(40, vocab=100, seed=3)
    with pytest.raises(TypeError):
        build_partitioned_search_app(docs, FleetSpec(n_parts=2), replicas=2)


# -- lazy hydration is the fleet default ----------------------------------------


def test_fleet_defaults_to_lazy_hydration():
    docs = synth_corpus(60, vocab=150, seed=4)
    app = build_partitioned_search_app(docs, FleetSpec(
        n_parts=2, runtime_config=RuntimeConfig(), search_config=CFG))
    q = synth_queries(docs, 1, seed=5)[0]
    r = app.query(q, k=10, fetch_docs=False)
    assert r.ok
    # the lazy cold path bills backfill (the off-critical-path upgrade) —
    # an eager fleet never touches that ledger line
    assert app.runtime.ledger.backfill_gb_seconds > 0
    eager = build_partitioned_search_app(docs, FleetSpec(
        n_parts=2, runtime_config=RuntimeConfig(),
        search_config=dataclasses.replace(CFG, lazy_hydration=False)))
    r2 = eager.query(q, k=10, fetch_docs=False)
    assert eager.runtime.ledger.backfill_gb_seconds == 0
    # and lazy vs eager results are bit-identical
    assert r.body["ext_ids"] == r2.body["ext_ids"]
    assert ([np.float32(s).view(np.uint32) for s in r.body["scores"]]
            == [np.float32(s).view(np.uint32) for s in r2.body["scores"]])


# -- rollover prewarm: ranked partial hydration, not full backfill ---------------


def _churn_and_commit(app, docs, t_gap=0.01):
    app.add_documents(docs, t_arrival=app.runtime.clock + t_gap)
    app.delete_documents([d for d, _ in app.indexer.live_corpus()[::37]],
                         t_arrival=app.runtime.clock + t_gap)
    r = app.commit(t_arrival=app.runtime.clock + t_gap)
    assert r.ok, r.body
    return r


def test_prewarm_reads_fewer_bytes_than_full_backfill_ping():
    """The rollover ping on a lazy fleet hydrates the superindex plus the
    TOP-DOCUMENT-FREQUENCY terms' blocks (and the dense tier's live rows)
    instead of streaming whole segments — strictly fewer object-store GET
    bytes than the eager fleet's full re-hydration ping, while every
    post-rollover response stays bit-identical between the two fleets."""
    docs = synth_corpus(240, vocab=400, seed=6)
    queries = synth_queries(docs, 6, seed=7)

    def build(lazy):
        cfg = CFG if lazy else dataclasses.replace(CFG,
                                                   lazy_hydration=False)
        app = build_partitioned_search_app(docs[:200], FleetSpec(
            n_parts=2, index=IndexSpec(vector=VectorSpec(dim=16)),
            runtime_config=RuntimeConfig(), search_config=cfg))
        app.warm()
        for q in queries:               # steady state before the commit
            app.query(q, k=10, t_arrival=app.runtime.clock + 0.05,
                      fetch_docs=False)
        return app

    lazy_app, eager_app = build(True), build(False)
    ping_bytes = {}
    for tag, app in (("lazy", lazy_app), ("eager", eager_app)):
        before = app.store.stats.bytes_out
        _churn_and_commit(app, docs[200:])
        ping_bytes[tag] = app.store.stats.bytes_out - before
    assert ping_bytes["lazy"] < ping_bytes["eager"], ping_bytes

    # bit-identical post-rollover serving, both tiers
    for q in queries:
        for mode in ("sparse", "dense", "hybrid"):
            rl = lazy_app.query(q, k=10, mode=mode,
                                t_arrival=lazy_app.runtime.clock + 0.05,
                                fetch_docs=False)
            re_ = eager_app.query(q, k=10, mode=mode,
                                  t_arrival=eager_app.runtime.clock + 0.05,
                                  fetch_docs=False)
            assert rl.body["ext_ids"] == re_.body["ext_ids"], (q, mode)
            assert ([np.float32(s).view(np.uint32)
                     for s in rl.body["scores"]]
                    == [np.float32(s).view(np.uint32)
                        for s in re_.body["scores"]]), (q, mode)


def test_prewarm_ping_keeps_rollover_queries_off_the_hydration_path():
    """After a commit's prewarm pings, the first query against the new
    generation finds its terms already hydrated when they rank in the
    prewarmed top-df set — the rollover window's whole point."""
    docs = synth_corpus(160, vocab=200, seed=8)
    app = build_partitioned_search_app(docs[:140], FleetSpec(
        n_parts=2, runtime_config=RuntimeConfig(), search_config=CFG))
    app.warm()
    queries = synth_queries(docs, 4, seed=9)
    for q in queries:
        app.query(q, k=10, t_arrival=app.runtime.clock + 0.05,
                  fetch_docs=False)
    _churn_and_commit(app, docs[140:])
    # rollover queries: no cold record, and results match a fresh oracle
    from repro.search.oracle import OracleSearcher
    corpus = app.indexer.live_corpus()
    oracle = OracleSearcher(corpus)
    n0 = len(app.runtime.records)
    for q in queries:
        r = app.query(q, k=10, t_arrival=app.runtime.clock + 0.05,
                      fetch_docs=False)
        want = [oracle.doc_ids[i] for i, _ in oracle.search(q, k=10)]
        assert r.body["ext_ids"] == want
    assert not any(rec.cold for rec in app.runtime.records[n0:])
