"""Overload survival: bounded retries with backoff, typed exhaustion, and
the scatter's degraded-merge path.

The contract under test:

* ``RetryPolicy`` — attempts are bounded, backoff is exponential on the
  VIRTUAL clock, jitter draws only from the runtime's seeded RNG (and only
  when a backoff exists, so the zero-backoff default preserves the legacy
  failure-injection draw sequence bit-for-bit).
* ``RetriesExhausted`` — a typed error carrying (fn, attempts); the gateway
  maps it to 503 (retryable capacity exhaustion), not the generic 502.
* ``degraded_ok`` — a scatter leg whose retries ran out either fails the
  whole request loudly (default) or is merged around as an EMPTY partition
  result, with the degraded partitions recorded for introspection.
"""

import pytest

from repro.core.gateway import Gateway
from repro.core.partition import FleetSpec, ReplicationSpec
from repro.core.runtime import (FaaSRuntime, RetriesExhausted, RetryPolicy,
                                RuntimeConfig, RuntimeError_)
from repro.data.corpus import synth_corpus, synth_queries
from repro.search.searcher import SearchConfig
from repro.search.service import build_partitioned_search_app

K = 10


class _ScriptedRng:
    def __init__(self, draws):
        self.draws = list(draws)

    def random(self):
        return self.draws.pop(0)


class _NoDrawRng:
    def random(self):
        raise AssertionError("jitter must not draw when backoff is zero")


# -- RetryPolicy: the schedule itself -----------------------------------------


def test_retry_policy_validation():
    for bad in (dict(max_attempts=0), dict(base_backoff_s=-1.0),
                dict(max_backoff_s=-0.1), dict(multiplier=0.5),
                dict(jitter=1.5)):
        with pytest.raises(ValueError):
            RetryPolicy(**bad)


def test_retry_policy_backoff_schedule_and_cap():
    pol = RetryPolicy(max_attempts=4, base_backoff_s=0.1, multiplier=2.0,
                      max_backoff_s=0.35, jitter=0.0)
    rng = _NoDrawRng()           # jitter=0: never draws
    assert pol.backoff_s(1, rng) == pytest.approx(0.1)
    assert pol.backoff_s(2, rng) == pytest.approx(0.2)
    assert pol.backoff_s(3, rng) == pytest.approx(0.35)   # capped


def test_zero_backoff_never_draws_jitter():
    # the legacy-compat contract: the default policy must not perturb the
    # seeded failure-injection RNG stream, even with jitter configured
    assert RetryPolicy(jitter=0.5).backoff_s(1, _NoDrawRng()) == 0.0


def test_legacy_max_retries_maps_onto_policy():
    assert RuntimeConfig(max_retries=4).retry_policy().max_attempts == 5
    explicit = RetryPolicy(max_attempts=2)
    assert RuntimeConfig(max_retries=9,
                         retry=explicit).retry_policy() is explicit


def test_retries_exhaust_typed_and_backoff_on_virtual_clock():
    rt = FaaSRuntime(RuntimeConfig(
        failure_rate=1.0, seed=1,
        retry=RetryPolicy(max_attempts=3, base_backoff_s=0.1,
                          multiplier=2.0, max_backoff_s=0.15, jitter=0.0)))
    rt.register("f", lambda cache, p: (p, 0.001))
    with pytest.raises(RetriesExhausted) as ei:
        rt.invoke("f", {}, t_arrival=0.0)
    assert ei.value.fn == "f" and ei.value.attempts == 3
    assert isinstance(ei.value, RuntimeError_)     # legacy handlers still catch
    # two backoffs elapsed on the virtual clock: 0.1 then min(0.2, 0.15)
    assert rt.clock == pytest.approx(0.25)
    # dead attempts billed nothing
    assert rt.ledger.invocations == 0


def test_jittered_backoff_reproducible_per_seed():
    def run(seed):
        rt = FaaSRuntime(RuntimeConfig(
            failure_rate=1.0, seed=seed,
            retry=RetryPolicy(max_attempts=4, base_backoff_s=0.1,
                              jitter=0.5)))
        rt.register("f", lambda cache, p: (p, 0.001))
        with pytest.raises(RetriesExhausted):
            rt.invoke("f", {}, t_arrival=0.0)
        return rt.clock

    assert run(7) == run(7)              # same seed, same schedule
    assert run(7) != run(8)              # jitter actually drew


def test_gateway_maps_exhaustion_to_503():
    rt = FaaSRuntime(RuntimeConfig(failure_rate=1.0, max_retries=1, seed=3))
    rt.register("f", lambda cache, p: (p, 0.001))
    gw = Gateway(rt)
    gw.route("GET", "/x", "f")
    r = gw.request("GET", "/x", {}, t_arrival=0.0)
    assert r.status == 503 and "died" in r.body["error"]


# -- degraded_ok: partial-failure merges vs loud errors -----------------------


def _build(corpus, degraded_ok):
    return build_partitioned_search_app(corpus, FleetSpec(
        n_parts=2,
        replication=ReplicationSpec(replicas=1, degraded_ok=degraded_ok),
        search_config=SearchConfig(sim_exec_s=0.002, sim_write_s=0.02)))


@pytest.fixture(scope="module")
def corpus():
    return synth_corpus(120, vocab=300, seed=61)


def test_degraded_ok_merges_surviving_partitions(corpus):
    app = _build(corpus, degraded_ok=True)
    app.warm()
    q = synth_queries(corpus, 1, seed=63)[0]
    # partition 0's leg exhausts its 3 attempts; partition 1 survives
    app.runtime.config.failure_rate = 0.5
    app.runtime._rng = _ScriptedRng([0.1, 0.1, 0.1, 0.9])
    r = app.query(q, k=K, t_arrival=app.runtime.clock + 0.05,
                  fetch_docs=False)
    app.runtime.config.failure_rate = 0.0
    assert r.ok
    assert app.scatter.last_degraded == [0]
    # every hit comes from the surviving partition
    p1_ids = {ext for ext, _ in app.indexer.parts[1].live_docs()}
    assert r.body["ext_ids"] and set(r.body["ext_ids"]) <= p1_ids


def test_degraded_default_fails_loud_with_503(corpus):
    app = _build(corpus, degraded_ok=False)
    app.warm()
    q = synth_queries(corpus, 1, seed=63)[0]
    app.runtime.config.failure_rate = 0.5
    app.runtime._rng = _ScriptedRng([0.1, 0.1, 0.1])
    r = app.query(q, k=K, t_arrival=app.runtime.clock + 0.05,
                  fetch_docs=False)
    app.runtime.config.failure_rate = 0.0
    assert r.status == 503 and "died" in r.body["error"]


def test_all_legs_dead_errors_even_when_degraded_ok(corpus):
    app = _build(corpus, degraded_ok=True)
    app.warm()
    q = synth_queries(corpus, 1, seed=63)[0]
    app.runtime.config.failure_rate = 1.0
    r = app.query(q, k=K, t_arrival=app.runtime.clock + 0.05,
                  fetch_docs=False)
    app.runtime.config.failure_rate = 0.0
    assert r.status == 503


def test_batched_route_maps_exhaustion_to_503_each(corpus):
    app = _build(corpus, degraded_ok=False)
    app.warm()
    q = synth_queries(corpus, 1, seed=63)[0]
    app.runtime.config.failure_rate = 1.0
    h = app.submit(q, k=K, t_arrival=app.runtime.clock + 30.0,
                   fetch_docs=False)
    app.runtime.config.failure_rate = 0.0
    assert h.done() and h.response.status == 503
