"""Near-real-time indexing: delta segments, tombstones, merges, and
zero-downtime generation rollover — the version-consistency harness.

The load-bearing invariants:

* PARITY — any interleaving of add/delete/commit/merge must rank exactly
  like a from-scratch rebuild of the final live corpus (the delta path can
  never drift from the one-segment path). Guaranteed by construction:
  segments store stat-independent postings, idf/avgdl apply at query time
  from the generation manifest's incrementally-maintained live stats.
* CONSISTENCY — no single query ever merges hits from two different index
  generations, across partitions, hedged replica legs, or freshly-scaled
  pools, even when a rollover (or an instance kill) lands mid-scatter.
* ATOMICITY — concurrent generation publishes surface as PublishConflict;
  gc never deletes the serving generation or a segment it references.
"""

import random

import pytest

from repro.core.object_store import ObjectStore
from repro.core.refresh import (AssetCatalog, GenerationManifest,
                                PublishConflict, generation_version)
from repro.core.runtime import RuntimeConfig
from repro.data.corpus import synth_corpus, synth_queries
from repro.index.builder import (IndexWriter, MergePolicy, combine_segments,
                                 compute_global_stats, extend_vocab,
                                 global_vocab, update_stats)
from repro.index.tokenizer import tokenize
from repro.search.oracle import OracleSearcher
from repro.search.searcher import SearchConfig, Searcher
from repro.search.service import build_partitioned_search_app


CFG = SearchConfig(sim_exec_s=0.002, sim_write_s=0.02)


def build_app(docs, n_parts=2, **kw):
    kw.setdefault("runtime_config", RuntimeConfig())
    kw.setdefault("search_config", CFG)
    return build_partitioned_search_app(docs, n_parts=n_parts, **kw)


def oracle_top(corpus, q, k=10):
    oracle = OracleSearcher(corpus)
    return [oracle.doc_ids[i] for i, _ in oracle.search(q, k=k)]


def assert_fleet_matches_oracle(app, queries, k=10):
    """The fleet's merged top-k must equal a from-scratch oracle rebuild of
    the LIVE corpus, in the fleet's own (partition, internal-id) order."""
    corpus = app.indexer.live_corpus()
    for q in queries:
        r = app.query(q, k=k, t_arrival=app.runtime.clock + 0.05,
                      fetch_docs=False)
        assert r.ok, r.body
        assert r.body["ext_ids"] == oracle_top(corpus, q, k), q
        assert len(app.scatter.last_versions) == 1


# -- builder level: the delta segment itself ------------------------------------


def test_delta_plus_combine_equals_rebuild():
    docs = synth_corpus(240, vocab=400, seed=0)
    base_docs, new_docs = docs[:180], docs[180:]
    deleted = {docs[3][0], docs[100][0], docs[200][0]}

    stats = compute_global_stats(base_docs)
    vocab = global_vocab(stats)
    w = IndexWriter(global_stats=stats, vocab=vocab)
    w.add_many(base_docs)
    base = w.pack()

    vocab2 = extend_vocab(vocab, (t for _, txt in new_docs
                                  for t in tokenize(txt)))
    delta = IndexWriter.delta(new_docs, stats, vocab=vocab2)
    assert delta.meta.n_docs == len(new_docs)

    live_stats = dict(stats, df=dict(stats["df"]))
    by_id = dict(docs)
    for _, t in new_docs:
        update_stats(live_stats, t, sign=1)
    for e in deleted:
        update_stats(live_stats, by_id[e], sign=-1)

    dead_pos = [i for i, (e, _) in enumerate(base_docs + new_docs)
                if e in deleted]                 # tombstones = internal positions
    combined = combine_segments([base, delta], vocab=vocab2,
                                stats=live_stats, tombstones=dead_pos)
    live = [(e, t) for e, t in docs if e not in deleted]
    ref = compute_global_stats(live)
    assert live_stats["n_docs"] == ref["n_docs"]
    assert live_stats["avgdl"] == pytest.approx(ref["avgdl"])
    assert live_stats["df"] == ref["df"]

    s_delta = Searcher(combined, CFG)
    wr = IndexWriter(global_stats=ref, vocab=global_vocab(ref))
    wr.add_many(live)
    s_rebuild = Searcher(wr.pack(), CFG)
    for q in synth_queries(docs, 25, seed=2):
        e1 = [combined.meta.doc_ids[i] for i, _ in s_delta.search_one(q)]
        e2 = [s_rebuild.packed.meta.doc_ids[i]
              for i, _ in s_rebuild.search_one(q)]
        assert e1 == e2 == oracle_top(live, q), q
        assert not set(e1) & deleted


def test_extend_vocab_is_append_only():
    v = {"b": 0, "a": 1}
    v2 = extend_vocab(v, ["c", "a", "aa"])
    assert v2["b"] == 0 and v2["a"] == 1          # existing ids never move
    assert sorted(v2) == ["a", "aa", "b", "c"]
    assert v2["aa"] == 2 and v2["c"] == 3         # new ids appended, sorted
    assert extend_vocab(v2, ["a"]) == v2


def test_merge_policy_tiers():
    pol = MergePolicy(max_deltas=2, ratio=0.5, tombstone_ratio=0.2)
    assert not pol.should_merge(100, 0, 0, 0)           # nothing to do
    assert not pol.should_merge(100, 30, 1, 5)          # small tier, few dead
    assert pol.should_merge(100, 30, 3, 0)              # too many deltas
    assert pol.should_merge(100, 60, 1, 0)              # tier outgrew ratio
    assert pol.should_merge(100, 0, 0, 30)              # tombstone debt
    assert pol.should_merge(0, 1, 1, 0)                 # empty base: any delta


# -- property: random interleavings vs full rebuild ------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_random_interleaving_parity(seed):
    """Seeded random add/delete/commit/merge interleavings: after every
    commit the fleet must rank exactly like a rebuild of the live corpus."""
    rng = random.Random(seed)
    docs = synth_corpus(160, vocab=300, seed=seed)
    init, pool = docs[:90], list(docs[90:])
    # a tight merge policy so interleavings actually exercise compaction
    app = build_app(init, n_parts=2,
                    merge_policy=MergePolicy(max_deltas=2, ratio=0.4,
                                             tombstone_ratio=0.15))
    queries = synth_queries(docs, 10, seed=seed + 50)
    assert_fleet_matches_oracle(app, queries)

    for _ in range(4):
        n_ops = rng.randint(1, 3)
        for _ in range(n_ops):
            if pool and rng.random() < 0.6:
                take = rng.randint(1, min(12, len(pool)))
                batch, pool[:take] = pool[:take], []
                r = app.add_documents(batch)
                assert r.ok, r.body
            else:
                live = app.indexer.live_corpus()
                victims = rng.sample([e for e, _ in live],
                                     k=min(3, len(live)))
                r = app.delete_documents(victims)
                assert r.ok, r.body
        r = app.commit()
        assert r.ok, r.body
        assert_fleet_matches_oracle(app, queries)

    merges = sum(len(c["merged"]) for c in app.indexer.commits)
    assert merges >= 1, "interleaving never exercised merge compaction"
    # incremental stats never drifted from a from-scratch recount
    ref = compute_global_stats(app.indexer.live_corpus())
    assert app.indexer.stats["n_docs"] == ref["n_docs"]
    assert app.indexer.stats["avgdl"] == pytest.approx(ref["avgdl"])
    assert app.indexer.stats["df"] == ref["df"]


def test_delete_only_commit_and_update_semantics():
    docs = synth_corpus(80, vocab=200, seed=3)
    app = build_app(docs[:60], n_parts=2)
    # delete-only commit: tombstones published, no writer invocation
    victim = docs[0][0]
    app.delete_documents([victim])
    r = app.commit()
    assert r.ok and r.body["writers"] == 0 and r.body["deleted"] == 1
    corpus = app.indexer.live_corpus()
    assert victim not in [e for e, _ in corpus]
    assert_fleet_matches_oracle(app, synth_queries(docs, 6, seed=9))
    # duplicate add refused (update = delete + add + commit)
    with pytest.raises(ValueError):
        app.indexer.stage_add([(docs[1][0], "dup")])
    # deleting a never-committed pending add just unstages it
    app.add_documents(docs[60:62])
    app.delete_documents([docs[60][0]])
    r = app.commit()
    assert r.ok and r.body["indexed"] == 1 and r.body["deleted"] == 0
    assert docs[61][0] in [e for e, _ in app.indexer.live_corpus()]
    assert docs[60][0] not in [e for e, _ in app.indexer.live_corpus()]
    # deleting an unknown id is a no-op, not an error
    r = app.delete_documents(["nope"])
    assert r.ok and r.body["pending_deletes"] == 0
    # a half-bad add batch stages NOTHING (atomic validation)
    with pytest.raises(ValueError):
        app.indexer.stage_add([("brand-new", "x"), (docs[2][0], "dup")])
    assert "brand-new" not in app.indexer._pending_ids
    assert app.commit().body["committed"] is False


def test_update_flow_delete_add_commit():
    """The documented update recipe — delete + add + commit, in ONE batch —
    must work, and repeated updates of the same id must survive landing in
    the partition that tombstoned an older copy (tombstones are internal
    positions, so an old tombstone can never kill the re-added doc)."""
    docs = synth_corpus(60, vocab=150, seed=10)
    app = build_app(docs, n_parts=2)
    queries = synth_queries(docs, 6, seed=19)
    target = docs[2][0]
    for i in range(4):                  # round-robin lands both partitions
        text = f"mede bu dubo variant{i} bu mede"
        app.delete_documents([target])
        app.add_documents([(target, text)])
        r = app.commit()
        assert r.ok, r.body
        live = dict(app.indexer.live_corpus())
        assert live[target] == text     # new copy live, old copies dead
        assert_fleet_matches_oracle(app, queries + ["mede bu"])


# -- fault injection: version consistency under rollover + kills ------------------


def test_rollover_mid_scatter_never_tears_a_query():
    """Force a commit+rollover to land between two scatter legs of one
    query (and kill an instance for good measure): the query must still
    merge hits from ONE generation — the one pinned at dispatch — and the
    next query moves to the new generation."""
    docs = synth_corpus(120, vocab=250, seed=4)
    app = build_app(docs[:100], n_parts=3)
    q = synth_queries(docs, 1, seed=11)[0]
    app.query(q, fetch_docs=False)                      # hydrate gen 1
    gen_before = app.indexer.gen

    app.add_documents(docs[100:])                       # staged, uncommitted
    state = {"armed": True}
    orig_invoke = app.runtime.invoke

    def invoke(fn, payload, **kw):
        result = orig_invoke(fn, payload, **kw)
        if state["armed"] and fn.startswith("search-"):
            state["armed"] = False                      # re-entrancy guard
            app.runtime.kill_instance(fn=app.fn_names[1])
            r = app.commit()                            # rollover mid-scatter
            assert r.ok and r.body["gen"] == gen_before + 1
        return result

    app.runtime.invoke = invoke
    r = app.query(q, k=10, fetch_docs=False)
    assert r.ok
    # every leg answered from the generation pinned BEFORE the rollover
    assert app.scatter.last_versions == [generation_version(gen_before)]
    assert r.body["generation"] == gen_before
    # ...and the very next query serves the new generation, fleet-wide
    r2 = app.query(q, k=10, t_arrival=app.runtime.clock + 0.05,
                   fetch_docs=False)
    assert app.scatter.last_versions == [generation_version(gen_before + 1)]
    assert r2.body["generation"] == gen_before + 1
    assert_fleet_matches_oracle(app, [q])


def test_hedged_legs_share_the_pinned_generation():
    docs = synth_corpus(100, vocab=200, seed=5)
    app = build_app(docs[:80], n_parts=2, replicas=2, hedge=0.01)
    app.warm()
    queries = synth_queries(docs, 6, seed=13)
    for q in queries:                                   # build warm history
        app.query(q, fetch_docs=False,
                  t_arrival=app.runtime.clock + 0.05)
    app.add_documents(docs[80:])
    assert app.commit().ok
    # cold-inject the primary so the hedge actually fires post-rollover
    app.runtime.kill_instance(fn=app.fn_names[0])
    r = app.query(queries[0], fetch_docs=False,
                  t_arrival=app.runtime.clock + 0.05)
    assert r.ok
    assert len(app.scatter.last_versions) == 1          # backup leg included
    assert_fleet_matches_oracle(app, queries)


def test_scale_up_registers_replica_on_current_generation():
    docs = synth_corpus(100, vocab=200, seed=6)
    app = build_app(docs[:80], n_parts=2, autoscale=True)
    app.query(synth_queries(docs, 1, seed=14)[0], fetch_docs=False)
    app.add_documents(docs[80:])
    assert app.commit().ok
    current = app.indexer.gen
    ctl = app.controller
    ctl._scale_up(0, ctl.groups[0], app.runtime.clock + 1.0, "test")
    assert len(app.scatter.groups[0]) == 2
    # the fresh replica's prewarmed pool serves the CURRENT generation;
    # a query touching it must stay single-generation
    for q in synth_queries(docs, 4, seed=15):
        r = app.query(q, k=10, t_arrival=app.runtime.clock + 0.05,
                      fetch_docs=False)
        assert r.ok
        assert app.scatter.last_versions == [generation_version(current)]
    assert_fleet_matches_oracle(app, synth_queries(docs, 4, seed=16))


# -- publish atomicity + gc -------------------------------------------------------


def test_publish_generation_conflict_lost_update():
    """Two writers both base gen 2 on gen 1: the second publish must
    surface PublishConflict, not silently overwrite the winner."""
    store = ObjectStore()
    cat = AssetCatalog(store)
    m1 = GenerationManifest(gen=1, base="g1-base", deltas=[], tombstones=[],
                            stats={"n_docs": 1, "avgdl": 1.0, "df": {}},
                            vocab={})
    cat.publish_generation("idx", m1)
    winner = GenerationManifest(gen=2, base="g1-base", deltas=["g2-a"],
                                tombstones=[], stats=m1.stats, vocab={})
    loser = GenerationManifest(gen=2, base="g1-base", deltas=["g2-b"],
                               tombstones=[], stats=m1.stats, vocab={})
    cat.publish_generation("idx", winner)
    with pytest.raises(PublishConflict):
        cat.publish_generation("idx", loser)
    # the winner's manifest is intact and the loser left no phantom files
    assert cat.current_generation("idx").deltas == ["g2-a"]
    assert cat.read_generation("idx").gen == 2


def test_publish_generation_conflict_torn_race():
    """A manifest swap racing between our read and our conditional put is
    caught by the etag CAS — the torn-publish case."""
    store = ObjectStore()
    cat = AssetCatalog(store)
    m1 = GenerationManifest(gen=1, base="b", deltas=[], tombstones=[],
                            stats={"n_docs": 1, "avgdl": 1.0, "df": {}},
                            vocab={})
    cat.publish_generation("idx", m1)
    real_head = store.head

    def racing_head(key):
        meta = real_head(key)
        if key.endswith("MANIFEST"):
            # another writer flips the manifest AFTER our read
            store.put(key, b'{"current": "gen-000001"}')
        return meta

    store.head = racing_head
    m2 = GenerationManifest(gen=2, base="b", deltas=["d"], tombstones=[],
                            stats=m1.stats, vocab={})
    with pytest.raises(PublishConflict):
        cat.publish_generation("idx", m2)
    store.head = real_head
    # loser cleaned up: gen-000002 left no files behind
    assert not store.list(cat.version_prefix("idx", "gen-000002"))


def test_publish_generation_same_gen_race_spares_winner():
    """Two writers racing the SAME generation number: the loser's cleanup
    must never delete the winner's published files (the generation file is
    create-once, so the loser conflicts before touching anything)."""
    store = ObjectStore()
    cat = AssetCatalog(store)
    m1 = GenerationManifest(gen=1, base="b", deltas=[], tombstones=[],
                            stats={"n_docs": 1, "avgdl": 1.0, "df": {}},
                            vocab={})
    cat.publish_generation("idx", m1)
    winner = GenerationManifest(gen=2, base="b", deltas=["g2-winner"],
                                tombstones=[], stats=m1.stats, vocab={})
    loser = GenerationManifest(gen=2, base="b", deltas=["g2-loser"],
                               tombstones=[], stats=m1.stats, vocab={})
    # interleave: the loser passed the stale-base check (it read gen 1)
    # before the winner's flip landed — simulate by publishing the winner
    # from inside the loser's manifest read
    real_head = store.head

    def racing_head(key):
        meta = real_head(key)
        if key.endswith("MANIFEST"):
            store.head = real_head          # winner publishes, un-raced
            cat.publish_generation("idx", winner)
            store.head = racing_head
        return meta

    store.head = racing_head
    with pytest.raises(PublishConflict):
        cat.publish_generation("idx", loser)
    store.head = real_head
    # the WINNER's generation survives, fully readable, serving its deltas
    assert cat.current_version("idx") == generation_version(2)
    assert cat.read_generation("idx").deltas == ["g2-winner"]


def test_publish_segment_is_create_once():
    """Segments are immutable: re-publishing an existing id conflicts
    instead of silently overwriting bytes a manifest may already serve."""
    from repro.core.directory import RamDirectory
    store = ObjectStore()
    cat = AssetCatalog(store)
    cat.publish_segment("idx", "g000001-base", RamDirectory({"f": b"A"}))
    with pytest.raises(PublishConflict):
        cat.publish_segment("idx", "g000001-base", RamDirectory({"f": b"B"}))
    d = cat.open_segment("idx", "g000001-base")
    assert d.open_input("f").read_all() == b"A"   # original bytes intact


def test_gc_reclaims_merged_away_segments_keeps_serving():
    docs = synth_corpus(90, vocab=200, seed=7)
    app = build_app(docs[:60], n_parts=2,
                    merge_policy=MergePolicy(max_deltas=0))  # merge every commit
    app.add_documents(docs[60:75])
    assert app.commit().ok                               # gen 2: merge
    app.add_documents(docs[75:])
    assert app.commit().ok                               # gen 3: merge again
    cat, store = app.catalog, app.store
    for st in app.indexer.parts:
        asset = st.asset
        # serving + previous generations survive (rollback / pinned queries)
        versions = cat.versions(asset)
        assert cat.current_version(asset) in versions
        assert len(versions) == 2
        # every surviving generation's segments are readable...
        for v in versions:
            for seg in cat.read_generation(asset, v).segments:
                assert store.list(cat.segment_prefix(asset, seg)), (v, seg)
        # ...and the gen-1 base, referenced by nothing alive, is reclaimed
        assert not store.list(cat.segment_prefix(asset, "g000001-base"))
    assert_fleet_matches_oracle(app, synth_queries(docs, 6, seed=17))


def test_failed_commit_rolls_back_and_retries():
    """A commit whose publish conflicts PERSISTENTLY (every in-commit
    rebase-retry loses another race) must exhaust its bounded attempts,
    restore the writer's state — staged batch included — and surface the
    conflict; a later retry must publish a strictly NEWER generation than
    anything the partial flips left behind, instead of wedging on the
    stale-base check. (A TRANSIENT conflict no longer reaches the caller:
    the commit's own retry loop rebases and heals it —
    test_two_writer_race_converges_to_serialized_oracle.)"""
    docs = synth_corpus(90, vocab=200, seed=9)
    app = build_app(docs[:70], n_parts=2)
    ix = app.indexer
    queries = synth_queries(docs, 5, seed=18)
    app.add_documents(docs[70:])
    app.delete_documents([docs[1][0]])
    before = (dict(ix.stats, df=dict(ix.stats["df"])), dict(ix.vocab),
              [list(st.seg_docs) for st in ix.parts])

    # partition 1's CAS loses EVERY attempt: its manifest keeps moving
    # under us (the in-commit retries leave partition 0 further and
    # further ahead — exactly the partial-flip debris the heal must clear)
    real = ix.catalog.publish_generation
    calls = {"n": 0}
    p1_asset = ix.parts[1].asset

    def failing(name, manifest):
        calls["n"] += 1
        if name == p1_asset:
            raise PublishConflict("racing writer won")
        return real(name, manifest)

    ix.catalog.publish_generation = failing
    r = app.commit()
    assert r.status == 502 and "racing writer" in r.body["error"]
    assert calls["n"] >= 4            # bounded attempts actually retried
    ix.catalog.publish_generation = real
    # full rollback: gen, stats, vocab, tiers, and the staged batch
    assert ix.gen == 1
    assert ix.stats == before[0] and ix.vocab == before[1]
    assert [list(st.seg_docs) for st in ix.parts] == before[2]
    assert len(ix.pending_adds) == 20 and len(ix.pending_deletes) == 1
    # queries keep serving the old generation, consistently
    assert_fleet_matches_oracle(app, queries)
    # retry heals past the partial flips: partition 0 is several
    # generations ahead, so the retry publishes one newer still
    heal_gen = ix._published_gen() + 1
    assert heal_gen > 2
    r = app.commit()
    assert r.ok and r.body["gen"] == heal_gen
    assert all(ix.catalog.current_version(st.asset)
               == generation_version(heal_gen) for st in ix.parts)
    assert_fleet_matches_oracle(app, queries)


def test_rollover_prewarms_every_idle_instance():
    """A pool grown to N instances by concurrent traffic must have ALL N
    prewarmed by a commit's rollover — otherwise the un-pinged instances
    hydrate the new generation in-band on their next query, the exact p99
    spike the prewarm exists to prevent."""
    docs = synth_corpus(80, vocab=200, seed=11)
    app = build_app(docs[:60], n_parts=1)
    q1, q2 = synth_queries(docs, 2, seed=20)
    # two queries at ONE arrival instant grow the pool to 2 instances
    t0 = app.runtime.clock + 0.1
    app.query(q1, fetch_docs=False, t_arrival=t0)
    app.query(q2, fetch_docs=False, t_arrival=t0)
    fn = app.fn_names[0]
    assert sum(i.fn == fn for i in app.runtime._instances) == 2
    app.add_documents(docs[60:])
    r = app.commit(t_arrival=app.runtime.clock + 0.1)
    assert r.ok and r.body["pings"] == 2          # one per idle instance
    # concurrent post-rollover queries: BOTH instances serve warm
    t1 = app.runtime.clock + 0.1
    for q in (q1, q2):
        res = app.query(q, fetch_docs=False, t_arrival=t1)
        assert all(not p["cold"] and p["hydrate_s"] == 0
                   for p in res.body["partitions"]), res.body["partitions"]


def test_commit_survives_runtime_straggler_hedge():
    """FaaSRuntime.hedge_after_s re-executes handlers mid-invocation; a
    writer invocation that trips it publishes TWICE. Unique segment ids
    make the re-execution harmless (the loser's segment is an orphan for
    gc), instead of a PublishConflict that wedges every commit."""
    docs = synth_corpus(80, vocab=200, seed=13)
    # writer exec (~0.02 s modeled + per-doc) trips a 1 ms hedge threshold
    app = build_app(docs[:50], n_parts=2,
                    runtime_config=RuntimeConfig(hedge_after_s=0.001))
    queries = synth_queries(docs, 5, seed=21)
    app.add_documents(docs[50:65])
    r = app.commit()
    assert r.ok, r.body
    assert any(rec.write and rec.hedged for rec in app.runtime.records)
    assert_fleet_matches_oracle(app, queries)
    app.add_documents(docs[65:])
    assert app.commit().ok                  # and the next commit too
    assert_fleet_matches_oracle(app, queries)


def test_delete_removes_raw_document_content():
    """An index tombstone alone is cosmetic — the KV record must go too
    (data deletion is the usual reason to delete), except when the same
    commit re-adds the id (update: new content survives)."""
    docs = synth_corpus(60, vocab=150, seed=12)
    app = build_app(docs[:50], n_parts=2)
    gone, updated = docs[0][0], docs[1][0]
    assert gone in app.doc_store and updated in app.doc_store
    app.delete_documents([gone, updated])
    app.add_documents([(updated, "replacement text body")] + docs[50:])
    # staged only — content still fetchable until the commit lands
    assert gone in app.doc_store
    assert app.commit().ok
    assert gone not in app.doc_store              # content really deleted
    assert app.doc_store.get(updated)["contents"] == "replacement text body"


def test_commit_bills_the_write_line():
    docs = synth_corpus(80, vocab=200, seed=8)
    app = build_app(docs[:60], n_parts=2)
    led = app.runtime.ledger
    assert led.write_invocations == 0                    # bootstrap is offline
    app.add_documents(docs[60:])
    assert app.commit().ok
    assert led.write_invocations == 2                    # one per partition
    assert led.write_dollars > 0
    att = led.attribution()
    assert att["write"] == pytest.approx(led.write_dollars)
    assert sum(att.values()) == pytest.approx(led.compute_dollars)
    # writer invocations are tagged on the record log too
    writes = [r for r in app.runtime.records if r.write]
    assert len(writes) == 2 and all(r.fn.startswith("indexer-") for r in writes)


# -- concurrent multi-writer commits ------------------------------------------


PING = {"q": "", "k": 1, "fetch_docs": False}


def _serialized_twin(base_docs, batches, n_parts=2):
    """One writer committing the batches sequentially — the serialized
    oracle a raced pair of writers must converge to bit-for-bit."""
    app = build_app(base_docs, n_parts=n_parts)
    for adds, dels in batches:
        if dels:
            app.delete_documents(dels)
        if adds:
            app.add_documents(adds)
        r = app.commit()
        assert r.ok, r.body
    return app


def test_two_writer_race_converges_to_serialized_oracle():
    """Seeded sweep: two forked writers stage against the SAME generation
    and commit back to back. The loser must rebase on the winner — adopting
    its documents, live stats/vocab, and round-robin cursor — so the final
    index is bit-identical (placement, stats, merged top-k scores) to one
    writer committing the two batches serially. Without the rebase the
    loser's commit would silently publish a generation missing the
    winner's documents."""
    for seed in (0, 1, 2):
        rng = random.Random(seed)
        docs = synth_corpus(90, vocab=200, seed=40 + seed)
        base, extra = docs[:60], docs[60:]
        cut = rng.randrange(5, len(extra) - 5)
        batch_a, batch_b = extra[:cut], extra[cut:]
        del_a = [base[rng.randrange(len(base))][0]]
        del_b = [base[rng.randrange(len(base))][0]]  # may equal del_a

        racing = build_app(base, n_parts=2)
        a = racing.indexer
        b = a.fork(1)
        # both writers stage BEFORE either commits — the race
        a.stage_delete(del_a)
        a.stage_add(batch_a)
        b.stage_delete(del_b)
        b.stage_add(batch_b)
        ra, _ = a.commit(racing.fn_groups, ping_payload=PING)
        rb, _ = b.commit(racing.fn_groups, ping_payload=PING)
        assert rb["rebased"] == 1 and rb["gen"] == ra["gen"] + 1
        # the app pins queries to ITS writer's generation — the loser's
        # publish is foreign to A until A adopts it
        assert a.sync() is True
        assert a.gen == rb["gen"] and a.live_corpus() == b.live_corpus()

        serial = _serialized_twin(base, [(batch_a, del_a), (batch_b, del_b)])
        six = serial.indexer
        # logical state converged exactly: stats, vocab, placement, cursor
        assert b.stats == six.stats
        assert b.vocab == six.vocab
        assert b._rr == six._rr
        assert b.live_corpus() == six.live_corpus()
        # merged top-k bit-identical to the serialized twin AND the oracle
        queries = synth_queries(docs, 6, seed=70 + seed)
        for q in queries:
            r1 = racing.query(q, k=10, t_arrival=racing.runtime.clock + 0.05,
                              fetch_docs=False)
            r2 = serial.query(q, k=10, t_arrival=serial.runtime.clock + 0.05,
                              fetch_docs=False)
            assert r1.ok and r2.ok
            assert r1.body["ext_ids"] == r2.body["ext_ids"]
            assert r1.body["scores"] == r2.body["scores"]
        assert_fleet_matches_oracle(racing, queries)


def test_publish_conflict_loser_rebases_and_orphans_are_collected():
    """TRUE concurrency: the loser sampled the catalog BEFORE the winner's
    flip landed, so its first attempt targets the winner's generation and
    loses the create-once race — after its delta segments already
    uploaded. The in-commit retry must rebase and republish, and the
    failed attempt's uploads must be unreferenced orphans the
    reference-based gc reclaims."""
    docs = synth_corpus(80, vocab=200, seed=44)
    app = build_app(docs[:60], n_parts=2)
    a = app.indexer
    b = a.fork(1)
    a.stage_add(docs[60:70])
    b.stage_add(docs[70:])
    ra, _ = a.commit(app.fn_groups, ping_payload=PING)

    # freeze B's view of the catalog at the pre-flip instant for ONE
    # commit-loop iteration (what a truly concurrent reader would have seen)
    real_fg, real_pg = b._foreign_gen, b._published_gen
    stale = {"armed": True}

    def stale_fg():
        return None if stale["armed"] else real_fg()

    def stale_pg():
        if stale["armed"]:
            stale["armed"] = False
            return ra["gen"] - 1
        return real_pg()

    b._foreign_gen = stale_fg
    b._published_gen = stale_pg
    published = []
    real_pub = b.catalog.publish_segment

    def recording_pub(name, seg, files):
        published.append((name, seg))
        return real_pub(name, seg, files)

    b.catalog.publish_segment = recording_pub
    rb, _ = b.commit(app.fn_groups, ping_payload=PING)
    b.catalog.publish_segment = real_pub

    assert rb["publish_conflicts"] == 1 and rb["rebased"] == 1
    assert rb["gen"] == ra["gen"] + 1
    # attempt 1 uploaded delta segments AT THE WINNER'S generation before
    # the state segment's create-once check surfaced the conflict
    orphans = [(name, seg) for name, seg in published
               if seg.startswith(f"g{ra['gen']:06d}") and "w1-" in seg]
    assert orphans
    # ...and every one of them is gone: unreferenced by any surviving
    # manifest, swept by the reference-based gc the commit already ran
    for name, seg in orphans:
        assert app.store.list(app.catalog.segment_prefix(name, seg)) == []
    assert a.sync() is True
    queries = synth_queries(docs, 5, seed=46)
    assert_fleet_matches_oracle(app, queries)


def test_sync_adopts_foreign_publish():
    """A stale writer can adopt a racing writer's published state outside
    of a commit; a second sync is a no-op."""
    docs = synth_corpus(70, vocab=200, seed=47)
    app = build_app(docs[:60], n_parts=2)
    a = app.indexer
    b = a.fork(1)
    app.add_documents(docs[60:])
    app.commit()
    assert b.gen == 1
    assert b.sync() is True
    assert b.gen == a.gen
    assert b.live_corpus() == a.live_corpus()
    assert b._rr == a._rr
    assert b.sync() is False


def test_rebase_conflict_on_same_id_is_loud_and_restores(
):
    """Both writers staging an ADD of the same ext id is a real conflict
    (updates = delete + add): the loser's commit must fail loudly with the
    checkpoint restored and the batch still staged, never publish a
    silent duplicate."""
    docs = synth_corpus(70, vocab=200, seed=48)
    app = build_app(docs[:60], n_parts=2)
    a = app.indexer
    b = a.fork(1)
    dup = docs[60]
    a.stage_add([dup])
    b.stage_add([dup, docs[61]])
    a.commit(app.fn_groups, ping_payload=PING)
    with pytest.raises(ValueError, match="rebase conflict"):
        b.commit(app.fn_groups, ping_payload=PING)
    # rollback: still staged, view unchanged, index unharmed
    assert b.gen == 1 and len(b.pending_adds) == 2
    queries = synth_queries(docs, 4, seed=49)
    assert_fleet_matches_oracle(app, queries)
