"""Serverless core: object store, directory cache, hydration, runtime,
gateway, cost model, refresh — the paper's architecture invariants."""


import pytest

from repro.core.cache import HydrationCache
from repro.core.cost import (CostLedger, Invocation,
                             fungibility_check, paper_headline_cost)
from repro.core.directory import RamDirectory, StoreDirectory
from repro.core.gateway import Gateway
from repro.core.object_store import (NoSuchKey, ObjectStore,
                                     ObjectStoreError, PreconditionFailed)
from repro.core.refresh import AssetCatalog, PublishConflict, refresh_fleet
from repro.core.runtime import FaaSRuntime, RuntimeConfig


# -- object store -------------------------------------------------------------


def test_store_put_get_etag_and_range():
    s = ObjectStore()
    m1 = s.put("a/b", b"hello world")
    assert s.get("a/b") == b"hello world"
    assert s.get("a/b", start=6, length=5) == b"world"
    m2 = s.put("a/b", b"hello world")        # same content, same etag
    assert m1.etag == m2.etag
    with pytest.raises(NoSuchKey):
        s.get("missing")


def test_store_conditional_put():
    s = ObjectStore()
    meta = s.put("k", b"v1")
    s.put("k", b"v2", if_etag=meta.etag)     # CAS with correct etag
    with pytest.raises(PreconditionFailed):
        s.put("k", b"v3", if_etag=meta.etag)  # stale etag rejected
    with pytest.raises(PreconditionFailed):
        s.put("new", b"x", if_etag="nonempty")  # create-if-absent semantics
    s.put("new", b"x", if_etag="")


def test_store_list_and_network_accounting():
    s = ObjectStore()
    for i in range(5):
        s.put(f"p/{i}", bytes(100))
    assert len(s.list("p/")) == 5
    before = s.stats.sim_seconds
    s.get("p/0")
    assert s.stats.sim_seconds > before       # reads cost simulated time


def test_multipart_visibility():
    s = ObjectStore()
    up = s.multipart("big")
    up.write(b"aaa")
    up.write(b"bbb")
    assert "big" not in s                     # invisible until complete
    up.complete()
    assert s.get("big") == b"aaabbb"


# -- directory + block cache -----------------------------------------------------


def test_store_directory_block_cache():
    s = ObjectStore()
    s.put("idx/f.bin", bytes(range(256)) * 1024)       # 256 KiB
    d = StoreDirectory(s, "idx", block_size=64 << 10)
    inp = d.open_input("f.bin")
    assert inp.length() == 256 * 1024
    inp.seek(100)
    first = inp.read_bytes(16)
    gets_after_first = s.stats.gets
    inp.seek(100)
    assert inp.read_bytes(16) == first                 # warm: served from cache
    assert s.stats.gets == gets_after_first
    assert d.hits >= 1 and d.misses >= 1
    d.drop_cache()
    inp.seek(100)
    inp.read_bytes(16)
    assert s.stats.gets > gets_after_first             # cold again


def test_directory_slice_and_reads():
    d = RamDirectory({"x": b"0123456789abcdef"})
    inp = d.open_input("x")
    sl = inp.slice(4, 8)
    assert sl.read_bytes(4) == b"4567"
    assert sl.length() == 8


# -- hydration cache ----------------------------------------------------------------


def test_hydration_cache_warm_cold_and_eviction():
    import numpy as np
    cache = HydrationCache(capacity_bytes=1000)
    calls = []

    def hyd(tag, nbytes):
        def f():
            calls.append(tag)
            return np.zeros(nbytes, np.uint8), 0.5
        return f

    a = cache.get_or_hydrate("A", "v1", hyd("A", 400))
    assert cache.stats.misses == 1 and cache.stats.hydrate_seconds == 0.5
    a2 = cache.get_or_hydrate("A", "v1", hyd("A", 400))
    assert a2 is a and cache.stats.hits == 1 and calls == ["A"]
    cache.get_or_hydrate("B", "v1", hyd("B", 400))
    cache.get_or_hydrate("C", "v1", hyd("C", 400))     # evicts LRU (A)
    assert cache.stats.evictions >= 1
    assert ("A", "v1") not in cache
    # version bump = new key (the §3 refresh path)
    cache.get_or_hydrate("B", "v2", hyd("B2", 100))
    assert ("B", "v2") in cache


# -- cost model -----------------------------------------------------------------------


def test_paper_headline_100k_queries_per_dollar():
    assert abs(paper_headline_cost() - 100_000) < 100   # 2GB × 300ms


def test_fungibility_paper_example():
    a, b = fungibility_check(10, 10_000, 100, 1_000)
    assert a == pytest.approx(b)


def test_ledger_billing_quantum():
    led = CostLedger()
    led.charge(Invocation(memory_bytes=2 << 30, duration_s=0.0003))
    # sub-millisecond bills at the 1 ms quantum
    assert led.gb_seconds == pytest.approx(2 * 0.001)


# -- FaaS runtime ----------------------------------------------------------------------


def _echo_handler(cache, payload):
    cache.get_or_hydrate("state", "v1", lambda: ({"ready": True}, 0.2))
    return {"echo": payload}, 0.01


def test_runtime_cold_then_warm():
    rt = FaaSRuntime(RuntimeConfig())
    rt.register("f", _echo_handler)
    _, r1 = rt.invoke("f", 1)
    assert r1.cold and r1.hydrate_s == pytest.approx(0.2)
    _, r2 = rt.invoke("f", 2, t_arrival=rt.clock + 1)
    assert not r2.cold and r2.hydrate_s == 0
    assert r2.latency_s < r1.latency_s


def test_runtime_scales_with_concurrency():
    rt = FaaSRuntime(RuntimeConfig())
    rt.register("f", _echo_handler)
    for _ in range(8):
        rt.invoke("f", 0, t_arrival=0.0)      # simultaneous arrivals
    assert rt.fleet_size == 8                 # one container per in-flight req


def test_runtime_retry_on_instance_death():
    rt = FaaSRuntime(RuntimeConfig(failure_rate=1.0, max_retries=2, seed=1))
    rt.register("f", _echo_handler)
    with pytest.raises(Exception):
        rt.invoke("f", 0)
    rt2 = FaaSRuntime(RuntimeConfig(failure_rate=0.5, max_retries=5, seed=3))
    rt2.register("f", _echo_handler)
    out, rec = rt2.invoke("f", 42)
    assert out["echo"] == 42                  # eventually succeeds


def test_runtime_hedging_cuts_tail():
    slow_first = {"n": 0}

    def handler(cache, payload):
        slow_first["n"] += 1
        return payload, (5.0 if slow_first["n"] == 1 else 0.01)

    rt = FaaSRuntime(RuntimeConfig(hedge_after_s=0.1))
    rt.register("f", handler)
    _, rec = rt.invoke("f", 0)
    assert rec.hedged
    assert rec.latency_s < 5.0                # backup won


def test_kill_instance_failover():
    rt = FaaSRuntime(RuntimeConfig())
    rt.register("f", _echo_handler)
    rt.invoke("f", 0)
    assert rt.kill_instance()
    out, rec = rt.invoke("f", 1, t_arrival=rt.clock + 1)
    assert out["echo"] == 1 and rec.cold      # fresh container re-hydrated


# -- gateway ----------------------------------------------------------------------------


def test_gateway_routes_and_404():
    rt = FaaSRuntime()
    rt.register("f", _echo_handler)
    gw = Gateway(rt)
    gw.route("GET", "/search", "f")
    r = gw.request("GET", "/search", {"q": "x"})
    assert r.ok and r.body["echo"] == {"q": "x"}
    assert gw.request("GET", "/nope").status == 404


# -- versioned publish / refresh ----------------------------------------------------------


def test_publish_switchover_and_conflict():
    s = ObjectStore()
    cat = AssetCatalog(s)
    d1 = RamDirectory({"f": b"v1-data"})
    cat.publish("index", "v1", d1)
    assert cat.current_version("index") == "v1"
    d2 = RamDirectory({"f": b"v2-data"})
    cat.publish("index", "v2", d2)
    assert cat.current_version("index") == "v2"
    # old version still readable (rollback safety)
    _, dir1 = cat.open("index", "v1")
    assert dir1.open_input("f").read_all() == b"v1-data"
    assert set(cat.versions("index")) == {"v1", "v2"}


def test_publish_conflict_on_interleaved_manifest_swap():
    """A second publisher swapping the manifest between our etag read and
    our conditional put must surface as PublishConflict (paper §3: 'new
    indexes placed alongside the old' — never a torn pointer)."""
    s = ObjectStore()
    cat = AssetCatalog(s)
    cat.publish("index", "v1", RamDirectory({"f": b"1"}))
    real_head = s.head

    def racing_head(key):
        meta = real_head(key)
        if key.endswith("MANIFEST"):
            s.put(key, b'{"current": "v2"}')      # the interleaved writer
        return meta

    s.head = racing_head
    with pytest.raises(PublishConflict):
        cat.publish("index", "v3", RamDirectory({"f": b"3"}))
    s.head = real_head
    # the interleaved writer's flip survives; v3's data files exist but are
    # unreferenced (next gc's problem), and v1 stays readable
    assert cat.current_version("index") == "v2"
    _, d1 = cat.open("index", "v1")
    assert d1.open_input("f").read_all() == b"1"


def test_gc_keeps_serving_version_and_rollback():
    s = ObjectStore()
    cat = AssetCatalog(s)
    for i in (1, 2, 3, 4):
        cat.publish("index", f"v{i}", RamDirectory({"f": b"x" * i}))
    assert cat.current_version("index") == "v4"
    doomed = cat.gc("index", keep=2)
    assert doomed == ["v1", "v2"]
    assert set(cat.versions("index")) == {"v3", "v4"}       # serving + rollback
    for v in ("v1", "v2"):
        assert not s.list(cat.version_prefix("index", v))   # files really gone
    _, d = cat.open("index")
    assert d.open_input("f").read_all() == b"xxxx"
    # keep=1 may prune the rollback version but NEVER the serving one,
    # even after further publishes move the pointer
    cat.publish("index", "v5", RamDirectory({"f": b"y"}))
    assert cat.gc("index", keep=1) == ["v3", "v4"]
    assert cat.versions("index") == ["v5"]
    assert cat.current_version("index") == "v5"


def test_refresh_fleet_invalidates_warm_instances():
    s = ObjectStore()
    cat = AssetCatalog(s)
    cat.publish("index", "v1", RamDirectory({"f": b"v1"}))

    def handler(cache, payload):
        v = cat.current_version("index")
        data = cache.get_or_hydrate(
            "index", v,
            lambda: (cat.open("index", v)[1].open_input("f").read_all(), 0.1))
        return data.decode(), 0.01

    rt = FaaSRuntime()
    rt.register("f", handler)
    out, _ = rt.invoke("f", None)
    assert out == "v1"
    cat.publish("index", "v2", RamDirectory({"f": b"v2"}))
    refresh_fleet(rt, "index")
    out, rec = rt.invoke("f", None, t_arrival=rt.clock + 0.5)
    assert out == "v2" and rec.hydrate_s > 0   # re-hydrated new version


# -- range-read semantics (the lazy-hydration substrate) ----------------------


def test_store_range_read_semantics():
    """The bounds contract partial hydration leans on: zero-length ranges
    are legal (empty, still a billed GET), open-ended and over-long ranges
    clamp to EOF, and a start outside [0, size] fails loudly."""
    s = ObjectStore()
    s.put("k", b"0123456789")
    assert s.get("k", start=0, length=0) == b""
    assert s.get("k", start=10) == b""            # start == size: legal, empty
    assert s.get("k", start=4) == b"456789"
    assert s.get("k", start=8, length=100) == b"89"
    with pytest.raises(ObjectStoreError):
        s.get("k", start=-1)
    with pytest.raises(ObjectStoreError):
        s.get("k", start=11)                      # strictly past EOF
    with pytest.raises(NoSuchKey):
        s.get("missing", start=0, length=1)


def test_range_reads_bill_exactly_the_bytes_moved():
    """A ranged GET must move (and bill) ONLY the requested bytes — the
    whole-file-then-slice shortcut would make `bytes_out` and the modeled
    `read_cost_s` lie about what lazy hydration saves."""
    s = ObjectStore()
    s.put("big", bytes(1_000_000))
    g0, b0, t0 = s.stats.gets, s.stats.bytes_out, s.stats.sim_seconds
    chunk = s.get("big", start=123_456, length=100)
    assert len(chunk) == 100
    assert s.stats.gets - g0 == 1
    assert s.stats.bytes_out - b0 == 100
    assert s.stats.sim_seconds - t0 == pytest.approx(
        s.network.read_cost_s(100))


def test_backends_agree_on_ranges(tmp_path):
    """MemoryBackend (slice) and FilesystemBackend (seek) must return the
    same bytes for every range shape — the store's accounting assumes the
    backends are interchangeable."""
    from repro.core.object_store import FilesystemBackend, MemoryBackend
    data = bytes(range(256)) * 17
    mem, fs = MemoryBackend(), FilesystemBackend(str(tmp_path))
    mem.put("x/y", data)
    fs.put("x/y", data)
    for start, length in [(0, None), (0, 0), (0, len(data)), (5, 10),
                          (100, None), (len(data) - 1, 5), (len(data), 0),
                          (4096, 1)]:
        assert mem.get("x/y", start, length) == fs.get("x/y", start, length), \
            (start, length)


def test_etag_stable_across_range_reads():
    """Range reads are reads: the object's version identity (etag/size)
    must not drift however the object is sliced."""
    s = ObjectStore()
    m = s.put("k", b"abcdefghij")
    for start, length in [(0, 3), (3, None), (1, 100), (0, 0)]:
        s.get("k", start=start, length=length)
    after = s.head("k")
    assert after.etag == m.etag and after.size == m.size
