"""Lazy hydration: the cold-start demolition layer, inside-out.

* layout — superindex/payload serialization round-trips; the eager segment
  files are untouched (pre-existing readers stay bit-identical).
* partial views — only queried terms' blocks move; masked blocks stay
  non-live; incremental hydration never re-reads; extent coalescing obeys
  the network model's first-byte break-even.
* billing — the first query pays header + query-term ranges as hydration
  (critical path), backfill bills on its own ledger line and never touches
  query latency; the cache's byte accounting grows partial → full.
* policy re-derivation — HedgePolicy.from_cold_profile and the
  autoscaler's cold_overhead_s floor track the measured cold profile.
"""

import numpy as np
import pytest

from repro.core.cache import HydrationCache
from repro.core.kvstore import KVStore
from repro.core.object_store import ObjectStore
from repro.core.refresh import AssetCatalog
from repro.core.runtime import FaaSRuntime, RuntimeConfig
from repro.data.corpus import synth_corpus, synth_queries
from repro.index.builder import (PAYLOAD_FILE, SUPERINDEX_FILE, IndexWriter,
                                 pack_payload, pack_superindex,
                                 payload_row_bytes, read_segment,
                                 unpack_payload_rows, unpack_superindex,
                                 write_segment)
from repro.index.hydration import (LazyIndex, SuperIndexMissing,
                                   coalesce_extents, open_partial_segment)
from repro.index.tokenizer import tokenize
from repro.search.searcher import (SearchConfig, hydrate_searcher,
                                   lazy_hydrate_searcher, make_search_handler)

K = 10


@pytest.fixture(scope="module")
def corpus():
    return synth_corpus(400, vocab=600, seed=31)


@pytest.fixture(scope="module")
def queries(corpus):
    return synth_queries(corpus, 10, seed=33)


@pytest.fixture(scope="module")
def packed(corpus):
    w = IndexWriter()
    w.add_many(corpus)
    return w.pack()


def _publish(packed, name="idx", version="v1"):
    store = ObjectStore()
    cat = AssetCatalog(store)
    cat.publish(name, version, write_segment(packed))
    return store, cat


# -- layout -------------------------------------------------------------------


def test_superindex_roundtrip(packed):
    meta, vocab, (off, bmax, dlen, idf), fields = unpack_superindex(
        pack_superindex(packed))
    assert fields is None  # v1 pack → no fields header
    assert meta.n_docs == packed.meta.n_docs
    assert meta.n_blocks == packed.meta.n_blocks
    assert vocab == packed.vocab
    assert np.array_equal(off, np.asarray(packed.term_offsets))
    assert np.array_equal(bmax, np.asarray(packed.block_max))
    assert np.array_equal(dlen, np.asarray(packed.doc_len))
    assert np.array_equal(idf, np.asarray(packed.idf))
    with pytest.raises(ValueError):
        unpack_superindex(b"NOPE" + b"\x00" * 16)


def test_payload_roundtrip(packed):
    blob = pack_payload(packed)
    B = packed.meta.block
    assert len(blob) == packed.meta.n_blocks * payload_row_bytes(B)
    docs, tf = unpack_payload_rows(blob, B)
    assert np.array_equal(docs, np.asarray(packed.block_docs))
    assert np.array_equal(tf, np.asarray(packed.block_tf))
    # a row-aligned slice decodes exactly those rows
    row = payload_row_bytes(B)
    d2, t2 = unpack_payload_rows(blob[3 * row:7 * row], B)
    assert np.array_equal(d2, np.asarray(packed.block_docs)[3:7])
    assert np.array_equal(t2, np.asarray(packed.block_tf)[3:7])


def test_eager_segment_files_unchanged(packed):
    """The lazy layout is ADDITIVE: read_segment's files and bytes are what
    they were before PR 7, so eager hydration cost stays bit-identical."""
    d = write_segment(packed)
    names = set(d.list())
    assert {SUPERINDEX_FILE, PAYLOAD_FILE} <= names
    rs = read_segment(d)
    assert np.array_equal(np.asarray(rs.block_docs),
                          np.asarray(packed.block_docs))
    assert np.array_equal(np.asarray(rs.term_offsets),
                          np.asarray(packed.term_offsets))


def test_coalesce_extents_break_even():
    assert coalesce_extents([], 10) == []
    assert coalesce_extents([(0, 4), (20, 30)], 10) == [(0, 4), (20, 30)]
    assert coalesce_extents([(20, 30), (0, 4)], 16) == [(0, 30)]
    assert coalesce_extents([(0, 4), (2, 9), (9, 12)], 0) == [(0, 12)]
    assert coalesce_extents([(5, 5), (0, 3)], 0) == [(0, 3)]  # empty dropped


# -- partial views ------------------------------------------------------------


def test_partial_segment_hydrates_only_queried_terms(packed, queries):
    store, cat = _publish(packed)
    seg = open_partial_segment(cat.open("idx", "v1")[1])
    assert not seg.full
    tids = [packed.vocab[t] for t in tokenize(queries[0])
            if t in packed.vocab]
    before = seg.bytes_read
    assert seg.hydrate_terms(tids)
    moved = seg.bytes_read - before
    off = np.asarray(packed.term_offsets)
    want_rows = sum(int(off[t + 1] - off[t]) for t in set(tids))
    # at least the terms' rows moved; coalescing may pull gap rows too,
    # but never the whole payload
    assert moved >= want_rows * payload_row_bytes(packed.meta.block)
    assert moved < len(pack_payload(packed))
    for t in tids:
        assert seg._rows_live[off[t]:off[t + 1]].all()
    # re-hydrating the same terms is free
    assert not seg.hydrate_terms(tids)
    assert seg.bytes_read == moved + before


def test_partial_view_masks_absent_terms(packed):
    _, cat = _publish(packed)
    seg = open_partial_segment(cat.open("idx", "v1")[1])
    view = seg.to_packed()
    dead = ~seg._rows_live
    assert (np.asarray(view.block_docs)[dead] == packed.meta.n_docs).all()
    assert (np.asarray(view.block_tf)[dead] == 0).all()
    # header arrays are the TRUE full tables from the superindex
    assert np.array_equal(np.asarray(view.block_max),
                          np.asarray(packed.block_max))
    assert np.array_equal(np.asarray(view.idf), np.asarray(packed.idf))


def test_backfill_reaches_full_bit_identical(packed, queries):
    _, cat = _publish(packed)
    seg = open_partial_segment(cat.open("idx", "v1")[1])
    seg.hydrate_terms([packed.vocab[t] for t in tokenize(queries[0])
                       if t in packed.vocab])
    assert seg.backfill()
    assert seg.full
    assert np.array_equal(seg.block_docs, np.asarray(packed.block_docs))
    assert np.array_equal(seg.block_tf, np.asarray(packed.block_tf))
    assert not seg.backfill()          # idempotent once full


def test_missing_superindex_raises(packed):
    store, cat = _publish(packed)
    _, directory = cat.open("idx", "v1")
    store.delete(directory.prefix + SUPERINDEX_FILE)
    with pytest.raises(SuperIndexMissing):
        open_partial_segment(cat.open("idx", "v1")[1])


def test_lazy_cold_get_count_is_constant(packed, queries):
    """The cold-start win is GET-count, not just bytes: first-byte latency
    dominates, so the partial path must issue a small constant number of
    range GETs (superindex + coalesced payload spans), not one per term or
    per 1MiB block."""
    store, cat = _publish(packed)
    cfg = SearchConfig(sim_exec_s=0.002)
    g0 = store.stats.gets
    entry, _ = lazy_hydrate_searcher(cat, "idx", cfg, "v1")
    entry.ensure_queries(list(queries))
    lazy_gets = store.stats.gets - g0
    assert lazy_gets <= 4, lazy_gets


# -- billing ------------------------------------------------------------------


def test_lazy_cold_hydration_beats_full(packed, queries):
    _, cat = _publish(packed)
    cfg = SearchConfig(sim_exec_s=0.002)
    _, full_s = hydrate_searcher(cat, "idx", cfg, "v1")
    entry, header_s = lazy_hydrate_searcher(cat, "idx", cfg, "v1")
    _, term_s = entry.ensure_queries([queries[0]])
    assert header_s + term_s < full_s / 3


def test_handler_bills_backfill_off_critical_path(packed, corpus, queries):
    _, cat = _publish(packed)
    cfg = SearchConfig(sim_exec_s=0.002, lazy_hydration=True)
    rt = FaaSRuntime(RuntimeConfig())
    rt.register("s", make_search_handler(cat, KVStore(), "idx", cfg))
    _, rec = rt.invoke("s", {"q": queries[0], "fetch_docs": False})
    assert rec.cold and rec.hydrate_s > 0 and rec.backfill_s > 0
    # latency excludes backfill EXACTLY: provision + hydrate + exec only
    assert rec.latency_s == pytest.approx(
        rt.config.provision_s + rec.hydrate_s + rec.exec_s, abs=1e-12)
    assert rt.ledger.backfill_invocations == 1
    assert rt.ledger.backfill_gb_seconds > 0
    att = rt.ledger.attribution()
    assert att["backfill"] > 0
    assert sum(att.values()) == pytest.approx(rt.ledger.compute_dollars)
    # the instance stays busy through the backfill (it runs SOMEWHERE)
    inst = rt._instances[0]
    assert inst.busy_until == pytest.approx(
        rec.t_done + rec.backfill_s, abs=1e-12)
    # invocation 2: full after backfill — warm, no hydration, no backfill
    _, rec2 = rt.invoke("s", {"q": queries[1], "fetch_docs": False},
                        t_arrival=rt.clock + 1)
    assert not rec2.cold and rec2.hydrate_s == 0 and rec2.backfill_s == 0
    assert rt.ledger.backfill_invocations == 1


def test_lazy_results_match_eager_bitwise(packed, queries):
    _, cat = _publish(packed)
    eager_cfg = SearchConfig(sim_exec_s=0.002)
    lazy_cfg = SearchConfig(sim_exec_s=0.002, lazy_hydration=True)
    rt_e, rt_l = FaaSRuntime(RuntimeConfig()), FaaSRuntime(RuntimeConfig())
    rt_e.register("s", make_search_handler(cat, KVStore(), "idx", eager_cfg))
    rt_l.register("s", make_search_handler(cat, KVStore(), "idx", lazy_cfg))
    for q in queries:
        re_, _ = rt_e.invoke("s", {"q": q, "fetch_docs": False})
        rl_, _ = rt_l.invoke("s", {"q": q, "fetch_docs": False})
        assert re_["ids"] == rl_["ids"]
        assert [np.float32(s).view(np.uint32) for s in re_["scores"]] == \
               [np.float32(s).view(np.uint32) for s in rl_["scores"]]


def test_handler_falls_back_to_eager_for_old_segments(packed, queries):
    store, cat = _publish(packed)
    _, directory = cat.open("idx", "v1")
    store.delete(directory.prefix + SUPERINDEX_FILE)
    store.delete(directory.prefix + PAYLOAD_FILE)
    cfg = SearchConfig(sim_exec_s=0.002, lazy_hydration=True)
    rt = FaaSRuntime(RuntimeConfig())
    rt.register("s", make_search_handler(cat, KVStore(), "idx", cfg))
    res, rec = rt.invoke("s", {"q": queries[0], "fetch_docs": False})
    assert rec.cold and rec.hydrate_s > 0 and rec.backfill_s == 0
    assert res["ids"]


def test_cache_note_backfill_grows_entry_bytes():
    cache = HydrationCache(1 << 30)

    class Asset:
        nbytes = 100
    a = Asset()
    cache.get_or_hydrate("x", "v1", lambda: (a, 0.01))
    assert cache.used_bytes == 100
    assert cache.stats.hydrate_seconds == pytest.approx(0.01)
    cache.note_hydration(0.02)
    assert cache.stats.hydrate_seconds == pytest.approx(0.03)
    assert cache.stats.backfill_seconds == 0.0
    a.nbytes = 5000
    cache.note_backfill("x", "v1", 0.5)
    assert cache.stats.backfill_seconds == pytest.approx(0.5)
    assert cache.stats.hydrate_seconds == pytest.approx(0.03)  # untouched
    assert cache.used_bytes == 5000
    cache.note_backfill("x", "v1", 0.1, nbytes=7000)   # explicit override
    assert cache.used_bytes == 7000
    cache.note_backfill("ghost", "v1", 0.1)            # absent entry: time only
    assert cache.stats.backfill_seconds == pytest.approx(0.7)


# -- policy re-derivation -----------------------------------------------------


def test_hedge_policy_from_cold_profile():
    from repro.core.partition import HedgePolicy
    # full profile (cold ~0.47s, warm ~25ms) → more conservative than 2.0
    full = HedgePolicy.from_cold_profile(0.47, 0.025)
    assert full.scale == pytest.approx(1 + 0.47 / 0.25)
    # lazy profile (cold ~0.2s) → more eager: backups are cheap to be
    # wrong about when cold legs are cheap
    lazy = HedgePolicy.from_cold_profile(0.20, 0.025)
    assert lazy.scale < full.scale
    assert HedgePolicy.from_cold_profile(100.0, 0.001).scale == 4.0  # clamp hi
    assert HedgePolicy.from_cold_profile(0.0, 1.0).scale == 1.25     # clamp lo
    # degenerate warm history: fall back to defaults
    assert HedgePolicy.from_cold_profile(0.2, 0.0).scale == 2.0
    assert HedgePolicy.from_cold_profile(0.2, float("nan")).scale == 2.0
    # passthrough kwargs survive
    assert HedgePolicy.from_cold_profile(0.2, 0.025, window=64).window == 64


def test_autoscale_floor_tracks_cold_profile():
    from repro.core.autoscale import AutoscalePolicy, FleetController
    from repro.core.partition import ScatterGather

    def make(policy):
        rt = FaaSRuntime(RuntimeConfig())
        rt.register("p0", lambda cache, payload: (payload, 0.001))
        sg = ScatterGather(rt, [["p0"]])
        return FleetController(rt, sg, [lambda: lambda c, p: (p, 0.001)],
                               policy)
    default = make(AutoscalePolicy())
    assert default._overhead_threshold(["p0"]) == pytest.approx(0.150 / 2)
    lazy = make(AutoscalePolicy(cold_overhead_s=0.2))
    assert lazy._overhead_threshold(["p0"]) == pytest.approx(0.1)
    # explicit up_overhead_s still wins over everything
    fixed = make(AutoscalePolicy(cold_overhead_s=0.2, up_overhead_s=0.03))
    assert fixed._overhead_threshold(["p0"]) == pytest.approx(0.03)
