"""Per-architecture smoke tests (assignment deliverable): every assigned
arch instantiates a REDUCED config of the same family and runs one real
forward/train step on CPU, asserting output shapes and no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, build_cells, get_arch
from repro.train.steps import init_train_state


def _materialize_batch(abstract, key):
    leaves, tdef = jax.tree_util.tree_flatten(abstract)
    keys = jax.random.split(key, max(len(leaves), 2))
    out = []
    for l, k in zip(leaves, keys):
        if jnp.issubdtype(l.dtype, jnp.integer):
            out.append(jax.random.randint(k, l.shape, 0, 4).astype(l.dtype))
        else:
            out.append(jnp.abs(jax.random.normal(k, l.shape) * 0.05
                               ).astype(l.dtype))
    return jax.tree_util.tree_unflatten(tdef, out)


def _materialize_params(abstract, key):
    leaves, tdef = jax.tree_util.tree_flatten(abstract)
    keys = jax.random.split(key, len(leaves))
    out = [(jax.random.normal(k, l.shape) * 0.05).astype(l.dtype)
           for l, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(tdef, out)


def _finite(tree) -> bool:
    for l in jax.tree_util.tree_leaves(tree):
        if hasattr(l, "dtype") and jnp.issubdtype(l.dtype, jnp.floating):
            if not np.all(np.isfinite(np.asarray(l, np.float32))):
                return False
    return True


_ALL_CELLS = [(arch, shape)
              for arch in ASSIGNED
              for shape in build_cells(arch, reduced=True)]


@pytest.mark.parametrize("arch,shape", _ALL_CELLS,
                         ids=[f"{a}-{s}" for a, s in _ALL_CELLS])
def test_smoke_cell(arch, shape):
    cell = build_cells(arch, reduced=True)[shape]
    if cell.skip:
        pytest.skip(cell.note)
    key = jax.random.PRNGKey(0)
    if cell.kind == "train":
        state_abs, batch_abs = cell.args
        params = _materialize_params(state_abs["params"], key)
        state = init_train_state(params)
        batch = _materialize_batch(batch_abs, jax.random.PRNGKey(1))
        new_state, metrics = cell.fn(state, batch)
        assert np.isfinite(float(metrics["loss"])), metrics
        assert _finite(new_state["params"])
        # parameters actually moved
        before = jax.tree_util.tree_leaves(params)[0]
        after = jax.tree_util.tree_leaves(new_state["params"])[0]
        assert not np.allclose(np.asarray(before), np.asarray(after))
    else:
        args = [_materialize_params(a, jax.random.fold_in(key, i))
                if i == 0 else
                _materialize_batch(a, jax.random.fold_in(key, 100 + i))
                for i, a in enumerate(cell.args)]
        out = cell.fn(*args)
        assert _finite(out)
        # shape contract: outputs match the abstract eval_shape
        want = jax.eval_shape(cell.fn, *cell.args)
        got_leaves = jax.tree_util.tree_leaves(out)
        want_leaves = jax.tree_util.tree_leaves(want)
        assert len(got_leaves) == len(want_leaves)
        for g, w in zip(got_leaves, want_leaves):
            assert tuple(g.shape) == tuple(w.shape), (g.shape, w.shape)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_full_config_param_counts(arch):
    """Full configs match the public parameter-count claims (±25%)."""
    mod = get_arch(arch)
    if mod.FAMILY == "lm":
        cfg = mod.full_config()
        n = cfg.param_count()
        expected = {
            "olmoe-1b-7b": 6.9e9, "deepseek-v2-236b": 236e9,
            "starcoder2-3b": 3.0e9, "stablelm-3b": 2.8e9,
            "h2o-danube-1.8b": 1.8e9,
        }[arch]
        assert abs(n - expected) / expected < 0.25, (arch, n, expected)
        if arch == "olmoe-1b-7b":
            assert abs(cfg.active_param_count() - 1.3e9) / 1.3e9 < 0.25
        if arch == "deepseek-v2-236b":
            assert abs(cfg.active_param_count() - 21e9) / 21e9 < 0.3
    elif mod.FAMILY == "gnn":
        assert mod.full_config().param_count() > 1e7     # ~30M processor
    else:
        assert mod.full_config().param_count() > 1e6


def test_anlessini_reduced_cells_lower_on_host_mesh():
    """The paper's own arch cell lowers on a 1×1 mesh (full check is the
    512-device dry-run)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.parallel import compat
    cells = build_cells("anlessini", reduced=True)
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    cell = cells["serve_q1"]
    fn, args, specs = cell.build(mesh)
    sh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs,
                                is_leaf=lambda x: isinstance(x, P))
    with compat.use_mesh(mesh):
        compiled = jax.jit(fn, in_shardings=sh).lower(*args).compile()
    assert compiled is not None
