"""Checkpointing + fault tolerance: roundtrip, atomicity, restart recovery,
resumable data, straggler detection."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import (CheckpointConfig, CheckpointManager,
                                      load_pytree, save_pytree)
from repro.core.object_store import ObjectStore
from repro.ft.faults import (FailureInjector, InjectedFailure,
                             StragglerMonitor, run_with_restarts)


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 8)),
                       "b": jnp.zeros(8)},
            "opt": {"m": jnp.ones((8, 8)), "count": jnp.int32(3)}}


def test_pytree_roundtrip_exact():
    state = _state()
    d = save_pytree(state)
    back = load_pytree(d, jax.eval_shape(lambda: state))
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_manager_save_restore_and_gc():
    store = ObjectStore()
    mgr = CheckpointManager(store, "t",
                            CheckpointConfig(every_steps=10, keep=2,
                                             async_save=False))
    for step in range(0, 50, 10):
        mgr.maybe_save(step, _state(step))
    assert mgr.latest_step() == 40
    restored, step = mgr.restore(jax.eval_shape(lambda: _state()))
    assert step == 40
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(_state(40)["params"]["w"]))
    # gc kept the newest K versions only
    assert len(mgr.catalog.versions("t")) <= 2


def test_async_save_snapshot_isolated_from_donation():
    """Async save must snapshot; later mutation of the live state must not
    corrupt the checkpoint."""
    store = ObjectStore()
    mgr = CheckpointManager(store, "t", CheckpointConfig(async_save=True))
    state = {"w": np.ones(4, np.float32)}
    mgr.save(0, state)
    state["w"] *= 99.0            # mutate after handing off
    mgr.wait()
    back, _ = mgr.restore({"w": jax.ShapeDtypeStruct((4,), jnp.float32)})
    np.testing.assert_array_equal(np.asarray(back["w"]), np.ones(4))


def test_restore_or_init_fresh_and_existing():
    store = ObjectStore()
    mgr = CheckpointManager(store, "t", CheckpointConfig(async_save=False))
    state, step = mgr.restore_or_init(lambda: _state(1))
    assert step == 0
    mgr.save(7, state)
    state2, step2 = mgr.restore_or_init(lambda: _state(2))
    assert step2 == 7
    np.testing.assert_array_equal(np.asarray(state2["params"]["w"]),
                                  np.asarray(state["params"]["w"]))


def test_run_with_restarts_recovers_exactly():
    """Deterministic steps + injected failures == uninterrupted run."""
    def step_fn(state, step):
        return {"x": state["x"] + step}

    store = ObjectStore()
    mgr = CheckpointManager(store, "t",
                            CheckpointConfig(every_steps=5, async_save=False))
    init = {"x": jnp.float32(0)}
    final, stats = run_with_restarts(
        step_fn, init, 20, mgr,
        injector=FailureInjector(fail_at=(7, 13)))
    assert stats.restarts == 2
    assert float(final["x"]) == sum(range(20))
    assert stats.steps_lost > 0       # recovery cost is accounted


def test_run_with_restarts_gives_up():
    def step_fn(state, step):
        return state

    store = ObjectStore()
    mgr = CheckpointManager(store, "t",
                            CheckpointConfig(every_steps=5, async_save=False))
    inj = FailureInjector(rate=1.0)
    with pytest.raises(InjectedFailure):
        run_with_restarts(step_fn, {"x": jnp.float32(0)}, 10, mgr,
                          injector=inj, max_restarts=3)


def test_lm_stream_resumable():
    from repro.data.lm import LMDataConfig, LMTokenStream
    cfg = LMDataConfig(vocab=100, batch=4, seq=16, seed=5)
    a = LMTokenStream(cfg).batch(37)
    b = LMTokenStream(cfg).batch(37)    # fresh instance, same (seed, step)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_straggler_monitor_flags_tail():
    mon = StragglerMonitor(factor=3.0)
    for i in range(20):
        mon.record(i, 0.1)
    assert mon.record(20, 1.0)
    assert not mon.record(21, 0.12)
    assert mon.flagged == [20]


def test_checkpoint_restore_across_meshes():
    """Elastic rescale: save on one sharding, restore onto another."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.parallel import compat
    mesh1 = compat.make_mesh((1,), ("data",))
    state = {"w": jax.device_put(
        np.arange(16, dtype=np.float32).reshape(4, 4),
        NamedSharding(mesh1, P("data", None)))}
    store = ObjectStore()
    mgr = CheckpointManager(store, "t", CheckpointConfig(async_save=False))
    mgr.save(1, state)
    mesh2 = compat.make_mesh((1, 1), ("data", "model"))
    sh2 = {"w": NamedSharding(mesh2, P(None, "model"))}
    back, _ = mgr.restore({"w": jax.ShapeDtypeStruct((4, 4), jnp.float32)},
                          shardings=sh2)
    np.testing.assert_array_equal(np.asarray(back["w"]),
                                  np.asarray(state["w"]))
    assert back["w"].sharding == sh2["w"]


def test_straggler_monitor_first_step_never_flags():
    """The first step's median is ITSELF, so any factor <= 1 would flag a
    run's very first step on zero evidence — the warmup window guards it,
    and keeps flagging honest once real history exists."""
    mon = StragglerMonitor(factor=0.5)
    assert not mon.record(0, 1.0)          # median-of-one: no evidence
    for s in range(1, 4):
        assert not mon.record(s, 1.0)      # still inside warmup (5)
    assert mon.record(4, 1.0)              # warm: factor<1 flags honestly
    assert mon.flagged == [4]


def test_straggler_monitor_window_smaller_than_warmup_still_flags():
    """warmup clamps into [2, window]: a window-3 config must be able to
    flag once its window is full, not wait for 5 samples it can never
    hold."""
    mon = StragglerMonitor(factor=3.0, window=3)
    assert not mon.record(0, 0.1)
    assert not mon.record(1, 0.1)          # 2 samples < clamped warmup 3
    assert mon.record(2, 10.0)             # window full: 10 > 3×median(0.1)
    assert mon.flagged == [2]
