"""Search stack: JAX searcher vs exact oracle, accumulators, kernel path,
end-to-end app, baseline comparison, distributed partitioned search."""

import jax
import numpy as np
import pytest

from repro.baselines.kvstore_search import KVPostingsIndex
from repro.data.corpus import synth_corpus, synth_queries
from repro.index.builder import IndexWriter, read_segment, write_segment
from repro.search.bm25 import encode_queries
from repro.search.oracle import OracleSearcher
from repro.search.searcher import SearchConfig, Searcher
from repro.search.service import build_search_app


@pytest.fixture(scope="module")
def corpus():
    return synth_corpus(400, vocab=600, seed=7)


@pytest.fixture(scope="module")
def oracle(corpus):
    return OracleSearcher(corpus)


@pytest.fixture(scope="module")
def packed(corpus):
    w = IndexWriter()
    w.add_many(corpus)
    return w.pack()


def _ids(hits):
    return [h[0] for h in hits]


@pytest.mark.parametrize("accumulator", ["dense", "sorted"])
def test_searcher_matches_oracle(corpus, oracle, packed, accumulator):
    cfg = SearchConfig(max_blocks=64, k=10, accumulator=accumulator)
    s = Searcher(packed, cfg)
    for q in synth_queries(corpus, 20, seed=3):
        got = s.search_one(q)
        want = oracle.search(q, k=10)
        got_scores = {i: v for i, v in got}
        for doc, score in want:
            assert doc in got_scores
            assert got_scores[doc] == pytest.approx(score, rel=2e-4)


def test_kernel_path_matches_plain(corpus, packed):
    plain = Searcher(packed, SearchConfig(k=10, use_kernel=False))
    kern = Searcher(packed, SearchConfig(k=10, use_kernel=True,
                                         use_topk_kernel=True))
    for q in synth_queries(corpus, 10, seed=5):
        a = plain.search_one(q)
        b = kern.search_one(q)
        assert _ids(a) == _ids(b)
        np.testing.assert_allclose([v for _, v in a], [v for _, v in b],
                                   rtol=1e-4)


def test_impact_truncation_is_graceful(corpus, oracle, packed):
    """With tiny max_blocks the top hit should usually survive (impact
    ordering puts the highest-scoring docs in the first blocks)."""
    s = Searcher(packed, SearchConfig(max_blocks=2, k=10))
    hit = 0
    queries = synth_queries(corpus, 20, seed=9)
    for q in queries:
        want = oracle.search(q, k=1)
        if not want:
            continue
        got = _ids(s.search_one(q, k=10))
        hit += want[0][0] in got
    assert hit >= 0.8 * len(queries)


def test_segment_roundtrip(packed):
    d = write_segment(packed)
    back = read_segment(d)
    assert back.meta.n_docs == packed.meta.n_docs
    np.testing.assert_array_equal(back.block_docs, packed.block_docs)
    np.testing.assert_array_equal(back.term_offsets, packed.term_offsets)
    np.testing.assert_allclose(back.idf, packed.idf)
    assert back.vocab == packed.vocab


def test_end_to_end_app(corpus, oracle):
    app = build_search_app(corpus)
    q = synth_queries(corpus, 1, seed=11)[0]
    r = app.query(q, k=5)
    assert r.ok
    want = _ids(oracle.search(q, k=5))
    assert r.body["ids"] == want
    # raw documents fetched from the KV store (DynamoDB leg of Figure 1)
    assert all(doc is not None and "contents" in doc for doc in r.body["docs"])
    # cold first, warm after
    r2 = app.query(q, k=5, t_arrival=app.runtime.clock + 1)
    assert r2.record.hydrate_s == 0


def test_kvstore_baseline_matches_ranking_but_slower(corpus, oracle):
    kv = KVPostingsIndex()
    kv.build(corpus)
    app = build_search_app(corpus)
    q = synth_queries(corpus, 1, seed=13)[0]
    hits, kv_lat = kv.search(q, k=5)
    assert _ids(hits) == _ids(oracle.search(q, k=5))
    app.query(q)                                  # cold
    # warm; doc fetch excluded — both designs pay it, the comparison is
    # per-query postings traffic vs warm in-memory evaluation
    r = app.query(q, t_arrival=app.runtime.clock + 1, fetch_docs=False)
    assert kv_lat > r.record.exec_s


def test_distributed_search_matches_oracle(corpus, oracle):
    """Document-partitioned shard_map search == oracle on a 1×1 mesh ×4
    logical partitions is covered in test_distributed; here: partition build
    + the merged scoring math on a single device partitioning (n_parts=1)."""
    from repro.parallel import compat
    from repro.search.distributed import (build_partitioned_state,
                                          make_dist_search_fn)
    state, cfg, vocab = build_partitioned_state(
        corpus, 1, {"k": 10, "max_blocks": 64})
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    fn = make_dist_search_fn(cfg, ("data", "model"), mesh=mesh)
    queries = synth_queries(corpus, 8, seed=17)
    tids, qtf = encode_queries(vocab, queries, max_terms=cfg.max_terms)
    with compat.use_mesh(mesh):
        scores, ids = jax.jit(fn)(
            jax.tree_util.tree_map(jax.numpy.asarray, state), tids, qtf)
    for qi, q in enumerate(queries):
        want = oracle.search(q, k=10)
        got = [(int(i), float(v)) for v, i in zip(scores[qi], ids[qi])
               if v > 0]
        for (wd, ws), (gd, gs) in zip(want, got):
            assert gs == pytest.approx(ws, rel=2e-4)
            tied = any(abs(ws - w2) < 1e-5 for d2, w2 in want if d2 != wd)
            assert wd == gd or tied
