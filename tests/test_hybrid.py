"""Hybrid retrieval: the dense-vector tier and its fusion with BM25.

The load-bearing invariants:

* BIT-PARITY — per-partition dense scores (the Pallas ``dot_topk`` path)
  must be uint32-BIT-identical to the full-corpus ``dot_topk_batch_ref``
  oracle, for ANY partition size (the chunk is never shrunk to N) and ANY
  micro-batch width (each query dispatches as its own compiled program, so
  window composition can never perturb a neighbour's bits).
* DELTA PARITY — a dense ranking served from base + delta vector segments
  with tombstones equals a from-scratch rebuild of the live corpus.
* ONE GENERATION — both tiers of a hybrid query answer from the same
  generation; a forged cross-tier skew raises GenerationMismatch; every
  commit (text or not) CAS-flips one manifest per partition.
* FUSION — hybrid top-k is exactly ``rrf_fuse`` over the two tiers'
  merged rankings, reproducible against the two oracles fused the same way.
"""

import numpy as np
import pytest

from repro.core.partition import FleetSpec, IndexSpec, VectorSpec, rrf_fuse
from repro.core.runtime import RuntimeConfig
from repro.data.corpus import hash_embedder, synth_corpus, synth_queries
from repro.index.builder import (combine_vector_segments, pack_vectors,
                                 read_vector_segment, unpack_vector_superindex,
                                 write_vector_segment)
from repro.index.hydration import LazyVectors, open_partial_vector_segment
from repro.kernels.ops import dot_topk_batch
from repro.kernels.ref import dot_topk_batch_ref
from repro.search.oracle import (DenseOracleSearcher, OracleSearcher,
                                 hybrid_oracle_fuse)
from repro.search.searcher import SearchConfig
from repro.search.service import build_partitioned_search_app

CFG = SearchConfig(sim_exec_s=0.002, sim_write_s=0.02)
DIM = 16


def build_app(docs, n_parts=2, *, dtype="float32", cfg=CFG, **kw):
    return build_partitioned_search_app(docs, FleetSpec(
        n_parts=n_parts,
        index=IndexSpec(vector=VectorSpec(dim=DIM, dtype=dtype)),
        runtime_config=RuntimeConfig(), search_config=cfg, **kw))


def bits(xs):
    return [np.float32(x).view(np.uint32) for x in xs]


# -- kernel level: uint32 bit-parity vs the pure-JAX reference -------------------


@pytest.mark.parametrize("N,D,k,Q", [(53, 16, 10, 1), (53, 16, 10, 5),
                                     (136, 16, 10, 7), (1000, 16, 10, 3),
                                     (1091, 16, 10, 8), (4096, 64, 50, 2),
                                     (5, 8, 3, 1)])
def test_dot_topk_batch_bitwise_vs_ref(N, D, k, Q):
    """Kernel vs reference, uint32 score bits — including row counts that
    are NOT multiples of the f32-matvec alignment (53, 1091): the chunk
    padding must make the accumulation shape canonical for any N."""
    rng = np.random.default_rng(N * 7 + D)
    c = rng.standard_normal((N, D)).astype(np.float32)
    q = rng.standard_normal((Q, D)).astype(np.float32)
    gv, gi = dot_topk_batch(q, c, k)
    wv, wi = dot_topk_batch_ref(q, c, k)
    assert (np.asarray(gv).view(np.uint32)
            == np.asarray(wv).view(np.uint32)).all()
    assert (np.asarray(gi) == np.asarray(wi)).all()


@pytest.mark.parametrize("N", [136, 137, 1091])
def test_dot_topk_batch_q_invariant(N):
    """A query's score bits may not depend on how many neighbours shared
    its micro-batch (the windowed-dispatch bit-parity contract): batched
    results row 0 == the Q=1 dispatch, exactly."""
    rng = np.random.default_rng(N)
    c = rng.standard_normal((N, DIM)).astype(np.float32)
    q = rng.standard_normal((8, DIM)).astype(np.float32)
    v1, i1 = dot_topk_batch(q[:1], c, 10)
    for Q in (2, 3, 7, 8):
        vq, iq = dot_topk_batch(q[:Q], c, 10)
        assert (np.asarray(vq)[0].view(np.uint32)
                == np.asarray(v1)[0].view(np.uint32)).all(), Q
        assert (np.asarray(iq)[0] == np.asarray(i1)[0]).all(), Q


def test_partition_bits_match_full_corpus_bits():
    """The fleet argument in one kernel fact: a row scores to the same
    bits whether it sits in a 53-row partition or a 200-row corpus."""
    rng = np.random.default_rng(9)
    c = rng.standard_normal((200, DIM)).astype(np.float32)
    q = rng.standard_normal((1, DIM)).astype(np.float32)
    fv, fi = dot_topk_batch(q, c, 200)
    full = {int(i): np.float32(v).view(np.uint32)
            for v, i in zip(np.asarray(fv)[0], np.asarray(fi)[0])}
    pv, pi = dot_topk_batch(q, c[147:], 53)         # uneven tail partition
    for v, i in zip(np.asarray(pv)[0], np.asarray(pi)[0]):
        assert np.float32(v).view(np.uint32) == full[147 + int(i)]


# -- segment level: pack/write/read, quantization, lazy rows --------------------


@pytest.mark.parametrize("dtype", ["float32", "int8"])
def test_vector_segment_roundtrip(dtype):
    rng = np.random.default_rng(1)
    emb = rng.standard_normal((40, DIM)).astype(np.float32)
    ids = [f"d{i}" for i in range(40)]
    pv = pack_vectors(emb, ids, dtype=dtype)
    d = write_vector_segment(pv)
    back = read_vector_segment(d)
    assert back.meta.doc_ids == ids
    assert back.meta.dtype == dtype
    assert (back.vectors == pv.vectors).all()
    if dtype == "float32":
        assert (back.as_f32() == emb).all()
    else:
        assert pv.vectors.dtype == np.int8
        # symmetric scalar quantization: error bounded by scale/2 per element
        assert np.abs(back.as_f32() - emb).max() <= pv.meta.scale * 0.5 + 1e-7
    # the range-readable twin: superindex header carries the full meta
    meta = unpack_vector_superindex(
        d.open_input("vec_superindex.bin").read_all())
    assert meta.doc_ids == ids and meta.n_docs == 40 and meta.dim == DIM


@pytest.mark.parametrize("dtype", ["float32", "int8"])
def test_partial_rows_match_eager(dtype):
    rng = np.random.default_rng(2)
    emb = rng.standard_normal((30, DIM)).astype(np.float32)
    pv = pack_vectors(emb, [f"d{i}" for i in range(30)], dtype=dtype)
    d = write_vector_segment(pv)
    part = open_partial_vector_segment(d)
    part.hydrate_rows([(5, 12), (20, 30)])
    assert (part.vectors[5:12] == pv.vectors[5:12]).all()
    assert (part.vectors[20:30] == pv.vectors[20:30]).all()
    assert not part.full
    part.backfill()
    assert part.full and (part.as_f32() == pv.as_f32()).all()


def test_lazy_vectors_pull_only_live_rows():
    """``ensure_live`` hydrates exactly the non-tombstoned rows — dead rows
    never move, and the combined view equals the eager combine."""
    rng = np.random.default_rng(3)
    base = pack_vectors(rng.standard_normal((20, DIM)).astype(np.float32),
                        [f"b{i}" for i in range(20)])
    delta = pack_vectors(rng.standard_normal((7, DIM)).astype(np.float32),
                         [f"x{i}" for i in range(7)])
    tombs = [0, 5, 6, 22]

    def mk(ts):
        return LazyVectors(
            [open_partial_vector_segment(write_vector_segment(p))
             for p in (base, delta)], tombstones=ts)

    lazy, twin = mk(tombs), mk([])
    lazy.ensure_live(), twin.ensure_live()
    vecs, ids, live = lazy.combined()
    evecs, eids, elive = combine_vector_segments([base, delta], tombs)
    assert ids == eids and (live == elive).all()
    assert (vecs[live] == evecs[elive]).all()        # dead rows may stay 0
    # a tombstoned row at a range edge is never ranged in (interior dead
    # rows may ride along when coalescing a small gap is cheaper than a
    # second GET — that is the coalescing model's call, not a leak)
    assert lazy.bytes_read <= twin.bytes_read - DIM * 4


def test_delta_vectors_equal_rebuild():
    """combine(base + deltas, tombstones) == pack of the live corpus: the
    dense tier's delta path can never drift from the one-segment path."""
    rng = np.random.default_rng(4)
    all_emb = rng.standard_normal((25, DIM)).astype(np.float32)
    ids = [f"d{i}" for i in range(25)]
    base = pack_vectors(all_emb[:15], ids[:15])
    d1 = pack_vectors(all_emb[15:20], ids[15:20])
    d2 = pack_vectors(all_emb[20:], ids[20:])
    tombs = [2, 17]
    vecs, got_ids, live = combine_vector_segments([base, d1, d2], tombs)
    keep = [i for i in range(25) if i not in tombs]
    assert [got_ids[i] for i in keep] == [ids[i] for i in keep]
    assert (vecs[live] == all_emb[keep]).all()


# -- fleet level: dense + hybrid vs the oracles ---------------------------------


def fleet_vs_oracles(app, queries, k=10):
    corpus = app.indexer.live_corpus()
    so, do = OracleSearcher(corpus), DenseOracleSearcher(corpus, app.embedder)
    for q in queries:
        s_want = so.search(q, k=app.search_k)
        d_want = do.search(q, k=app.search_k)
        r = app.query(q, k=k, mode="dense",
                      t_arrival=app.runtime.clock + 0.05, fetch_docs=False)
        assert r.body["ext_ids"] == [do.doc_ids[d] for d, _ in d_want[:k]]
        assert bits(r.body["scores"]) == bits([v for _, v in d_want[:k]])
        r = app.query(q, k=k, mode="hybrid",
                      t_arrival=app.runtime.clock + 0.05, fetch_docs=False)
        fused = hybrid_oracle_fuse(s_want, d_want, k)
        assert r.body["ext_ids"] == [so.doc_ids[d] for d, _ in fused]
        assert list(r.body["scores"]) == [v for _, v in fused]
        r = app.query(q, k=k, t_arrival=app.runtime.clock + 0.05,
                      fetch_docs=False)
        assert r.body["ext_ids"] == [so.doc_ids[d]
                                     for d, _ in s_want[:k]]


def test_dense_and_hybrid_match_oracles():
    docs = synth_corpus(150, vocab=300, seed=0)
    app = build_app(docs, n_parts=3)
    fleet_vs_oracles(app, synth_queries(docs, 5, seed=1))


def test_dense_and_hybrid_match_oracles_through_churn():
    """Base + delta + tombstones, across two commits (the second triggers
    whatever merge the policy elects): delta-served dense ranking equals a
    full rebuild, and hybrid fusion stays pinned to the oracle pair."""
    docs = synth_corpus(160, vocab=300, seed=2)
    app = build_app(docs[:120], n_parts=2)
    queries = synth_queries(docs, 4, seed=3)
    fleet_vs_oracles(app, queries[:2])
    app.add_documents(docs[120:140], t_arrival=app.runtime.clock + 0.01)
    app.delete_documents([d for d, _ in docs[0:40:10]],
                         t_arrival=app.runtime.clock + 0.01)
    assert app.commit(t_arrival=app.runtime.clock + 0.01).ok
    fleet_vs_oracles(app, queries)
    app.add_documents(docs[140:], t_arrival=app.runtime.clock + 0.01)
    app.delete_documents([d for d, _ in docs[50:60]],
                         t_arrival=app.runtime.clock + 0.01)
    assert app.commit(t_arrival=app.runtime.clock + 0.01).ok
    fleet_vs_oracles(app, queries)


def test_int8_fleet_matches_oracle_on_dequantized_vectors():
    """The int8 tier scores the DEQUANTIZED representation — the oracle
    must embed the same way to bit-match, so build it over the stored
    codes' f32 view via the fleet's own combine."""
    docs = synth_corpus(90, vocab=200, seed=5)
    app = build_app(docs, n_parts=2, dtype="int8")
    q = synth_queries(docs, 2, seed=6)[0]
    r = app.query(q, k=10, mode="dense",
                  t_arrival=app.runtime.clock + 0.05, fetch_docs=False)
    assert r.ok and len(r.body["ext_ids"]) == 10
    # int8 ranking is close to, but legitimately may differ from, the f32
    # oracle; what must hold exactly is determinism across replays
    r2 = app.query(q, k=10, mode="dense",
                   t_arrival=app.runtime.clock + 0.05, fetch_docs=False)
    assert r.body["ext_ids"] == r2.body["ext_ids"]
    assert bits(r.body["scores"]) == bits(r2.body["scores"])


def test_vector_only_query_and_batched_modes():
    docs = synth_corpus(100, vocab=200, seed=7)
    app = build_app(docs, n_parts=2)
    corpus = app.indexer.live_corpus()
    do = DenseOracleSearcher(corpus, app.embedder)
    qv = [float(x) for x in app.embedder("tail latency")]
    r = app.query(None, k=5, mode="dense", vector=qv,
                  t_arrival=app.runtime.clock + 0.05, fetch_docs=False)
    want = do.search(qv, k=5)
    assert r.body["ext_ids"] == [do.doc_ids[d] for d, _ in want]
    assert bits(r.body["scores"]) == bits([v for _, v in want])
    # a micro-batch of texts through each mode resolves per query
    queries = synth_queries(docs, 3, seed=8)
    for mode in ("dense", "hybrid"):
        r = app.query(queries, k=5, mode=mode,
                      t_arrival=app.runtime.clock + 0.05, fetch_docs=False)
        assert r.ok and len(r.body["results"]) == len(queries)
        for q, res in zip(queries, r.body["results"]):
            one = app.query(q, k=5, mode=mode,
                            t_arrival=app.runtime.clock + 0.05,
                            fetch_docs=False)
            assert res["ext_ids"] == one.body["ext_ids"]
            assert bits(res["scores"]) == bits(one.body["scores"])


def test_windowed_mixed_modes_bitwise_equal_serial():
    """Sparse, dense and hybrid admissions coalescing in ONE gateway window
    must resolve to exactly the serial per-query dispatch — the kernel's
    Q-invariance surfacing at the fleet level."""
    docs = synth_corpus(140, vocab=250, seed=9)
    from repro.core.gateway import WindowPolicy
    from repro.core.partition import GatewaySpec
    app = build_app(docs, n_parts=2, gateway=GatewaySpec(
        window=WindowPolicy(max_window_s=0.08, target_batch=8,
                            sparse_qps=2.0, p99_budget_s=2.0)))
    serial = build_app(docs, n_parts=2)
    queries = synth_queries(docs, 6, seed=10)
    app.warm(), serial.warm()
    t0 = app.runtime.clock + 2.0
    handles = [(q, m, app.submit(q, k=10, mode=m, t_arrival=t0 + i * 0.001,
                                 fetch_docs=False))
               for i, q in enumerate(queries)
               for m in ("sparse", "dense", "hybrid")]
    app.flush()
    for q, m, h in handles:
        want = serial.query(q, k=10, mode=m,
                            t_arrival=serial.runtime.clock + 0.05,
                            fetch_docs=False)
        assert h.response.body["ext_ids"] == want.body["ext_ids"], (q, m)
        assert bits(h.response.body["scores"]) == bits(want.body["scores"])


def test_hybrid_rrf_fusion_is_the_coordinator_rrf():
    """The fused scores ARE rrf_fuse outputs over the two tiers' rankings
    — recomputable from the per-tier responses alone."""
    docs = synth_corpus(80, vocab=150, seed=11)
    app = build_app(docs, n_parts=2)
    q = synth_queries(docs, 1, seed=12)[0]
    rs = app.query(q, k=app.search_k, t_arrival=app.runtime.clock + 0.05,
                   fetch_docs=False)
    rd = app.query(q, k=app.search_k, mode="dense",
                   t_arrival=app.runtime.clock + 0.05, fetch_docs=False)
    rh = app.query(q, k=5, mode="hybrid",
                   t_arrival=app.runtime.clock + 0.05, fetch_docs=False)
    fused = rrf_fuse([list(rs.body["ext_ids"]), list(rd.body["ext_ids"])], 5)
    assert rh.body["ext_ids"] == [d for d, _ in fused]
    assert list(rh.body["scores"]) == [s for _, s in fused]


# -- generations: one manifest flip per commit, no cross-tier skew ---------------


def test_every_commit_flips_every_partition_manifest():
    """A commit routed entirely to one partition still CAS-flips a manifest
    on EVERY partition — the all-or-nothing generation contract the dense
    tier inherits (its vec segments ride the same manifest)."""
    from repro.core.refresh import generation_version
    docs = synth_corpus(60, vocab=150, seed=13)
    app = build_app(docs, n_parts=3)
    gen = app.indexer.gen
    app.add_documents([("zz-one-new-doc", "dense retrieval vector tier")],
                      t_arrival=app.runtime.clock + 0.01)
    assert app.commit(t_arrival=app.runtime.clock + 0.01).ok
    assert app.indexer.gen == gen + 1
    q = synth_queries(docs, 1, seed=14)[0]
    app.query(q, k=5, mode="hybrid", t_arrival=app.runtime.clock + 0.05,
              fetch_docs=False)
    assert app.scatter.last_versions == [generation_version(gen + 1)]
    # the new doc is servable from the dense tier of every generation asset
    r = app.query(None, k=3, mode="dense",
                  vector=[float(x)
                          for x in app.embedder("dense retrieval vector tier")],
                  t_arrival=app.runtime.clock + 0.05, fetch_docs=False)
    assert "zz-one-new-doc" in r.body["ext_ids"]


def test_cross_tier_generation_skew_raises():
    """A leg whose dense tier answered from a different generation than
    the sparse tiers around it must fail the scatter, not fuse."""
    docs = synth_corpus(60, vocab=150, seed=15)
    app = build_app(docs, n_parts=2)
    q = synth_queries(docs, 1, seed=16)[0]
    app.query(q, k=5, mode="hybrid", t_arrival=app.runtime.clock + 0.05,
              fetch_docs=False)
    orig_invoke = app.runtime.invoke
    state = {"armed": True}

    def invoke(fn, payload, **kw):
        result, rec = orig_invoke(fn, payload, **kw)
        if state["armed"] and fn.startswith("search-"):
            state["armed"] = False
            result = dict(result)
            result["vec_version"] = "g999999"       # forged dense tier
        return result, rec

    app.runtime.invoke = invoke
    r = app.query(q, k=5, mode="hybrid", t_arrival=app.runtime.clock + 0.05,
                  fetch_docs=False)
    # the scatter raises GenerationMismatch; the gateway surfaces it as a
    # 502 (the fleet's fault, not the client's) instead of fusing the skew
    assert r.status == 502 and "scatter legs answered from" in r.body["error"]
    assert "g999999" in r.body["error"]


def test_mid_scatter_rollover_pins_both_tiers():
    """A commit landing between two hybrid scatter legs: both tiers of
    every leg answer from the generation pinned at dispatch."""
    from repro.core.refresh import generation_version
    docs = synth_corpus(120, vocab=250, seed=17)
    app = build_app(docs[:100], n_parts=3)
    q = synth_queries(docs, 1, seed=18)[0]
    app.query(q, mode="hybrid", fetch_docs=False)       # hydrate gen 1
    gen_before = app.indexer.gen
    app.add_documents(docs[100:])
    state = {"armed": True}
    orig_invoke = app.runtime.invoke

    def invoke(fn, payload, **kw):
        result = orig_invoke(fn, payload, **kw)
        if state["armed"] and fn.startswith("search-"):
            state["armed"] = False
            r = app.commit()
            assert r.ok and r.body["gen"] == gen_before + 1
        return result

    app.runtime.invoke = invoke
    r = app.query(q, k=10, mode="hybrid", fetch_docs=False)
    assert r.ok
    assert app.scatter.last_versions == [generation_version(gen_before)]
    r2 = app.query(q, k=10, mode="hybrid",
                   t_arrival=app.runtime.clock + 0.05, fetch_docs=False)
    assert r2.ok
    assert app.scatter.last_versions == [generation_version(gen_before + 1)]
    fleet_vs_oracles(app, [q])


def test_sparse_fleet_rejects_dense_modes():
    docs = synth_corpus(40, vocab=100, seed=19)
    app = build_partitioned_search_app(docs, FleetSpec(
        n_parts=2, runtime_config=RuntimeConfig(), search_config=CFG))
    assert app.embedder is None
    q = synth_queries(docs, 1, seed=20)[0]
    r = app.query(q, k=5, mode="dense", fetch_docs=False)
    assert r.status == 400 and "dense" in r.body["error"]
    r = app.query(q, k=5, mode="nonsense", fetch_docs=False)
    assert r.status == 400
