"""Fleet autoscaling: the cost-ledger control loop, layer by layer.

* ledger — ``dollars_per_1k`` bills per LOGICAL query while hedge and idle
  (keep-alive) spend are attributed separately; the three attribution lines
  always sum to the compute bill.
* runtime — ``retire`` blocks new invocations and drains in-flight work;
  keep-alive invocations bill as idle capacity and stay out of latency
  percentiles and hedge-policy history.
* scatter — replica groups are mutable (with a last-replica guard), and
  aware routing rotates primaries away from pools with recent kills or the
  worst projected overhead.
* controller — bursts grow a partition's group (new ``search-p{p}rN`` over
  the SAME published segment), sustained idleness shrinks it, retiring an
  idle replica strictly reduces what the same traffic costs, and results
  stay bit-identical to an unscaled fleet and the oracle throughout.
"""

import pytest

from repro.core.autoscale import AutoscalePolicy
from repro.core.cost import CostLedger, Invocation
from repro.core.partition import HedgePolicy, ScatterGather
from repro.core.runtime import FaaSRuntime, RuntimeConfig, RuntimeError_
from repro.data.corpus import synth_corpus, synth_queries
from repro.search.oracle import OracleSearcher
from repro.search.searcher import SearchConfig
from repro.search.service import build_partitioned_search_app

K = 10
N_PARTS = 2
GB2 = 2 << 30


@pytest.fixture(scope="module")
def corpus():
    return synth_corpus(240, vocab=400, seed=41)


@pytest.fixture(scope="module")
def queries(corpus):
    return synth_queries(corpus, 40, seed=43)


def _det_cfg():
    # modeled exec clock: latencies and charges in these tests are exact
    return SearchConfig(sim_exec_s=0.002)


def _build(corpus, **kw):
    kw.setdefault("search_config", _det_cfg())
    return build_partitioned_search_app(corpus, n_parts=N_PARTS, **kw)


# -- ledger layer -------------------------------------------------------------


def test_dollars_per_1k_counts_logical_queries_under_hedging():
    led = CostLedger()
    for _ in range(10):
        led.charge(Invocation(GB2, 0.1))
    for _ in range(3):                      # backup legs: bill, answer nothing
        led.charge(Invocation(GB2, 0.1, hedge=True))
    assert led.invocations == 13
    # 10 logical queries paid for 13 invocations — the denominator is the
    # caller's query count, so hedging shows up as a higher $/1k, never as
    # phantom extra queries
    assert led.dollars_per_1k(10) == pytest.approx(
        led.total_dollars / 10 * 1000.0)
    assert led.hedge_dollars > 0
    assert led.dollars_per_1k(0) != led.dollars_per_1k(0)  # NaN guard


def test_attribution_partitions_the_compute_bill():
    led = CostLedger()
    led.charge(Invocation(GB2, 0.2))
    led.charge(Invocation(GB2, 0.2, hedge=True))
    led.charge(Invocation(GB2, 0.05, idle=True))
    att = led.attribution()
    assert att["hedge"] > 0 and att["idle"] > 0 and att["serving"] > 0
    assert sum(att.values()) == pytest.approx(led.compute_dollars)
    assert led.idle_invocations == 1 and led.hedge_invocations == 1


# -- runtime layer ------------------------------------------------------------


def _sleepy_handler(cache, payload):
    cache.get_or_hydrate("state", "v1", lambda: (object(), 0.2))
    return payload, 0.01


def test_keepalive_bills_idle_and_stays_out_of_percentiles():
    rt = FaaSRuntime(RuntimeConfig())
    rt.register("f", _sleepy_handler)
    _, rec = rt.invoke("f", 0, keepalive=True)
    assert rec.keepalive
    assert rt.ledger.idle_invocations == 1 and rt.ledger.idle_gb_seconds > 0
    # pings are not queries: the percentile log must be empty without them
    p = rt.latency_percentiles("f", qs=(0.5,))
    assert p[0.5] != p[0.5]                 # NaN
    _, rec2 = rt.invoke("f", 1, t_arrival=rt.clock + 1)
    assert not rec2.keepalive
    assert rt.ledger.idle_invocations == 1  # unchanged by a real query
    assert rt.latency_percentiles("f", qs=(0.5,))[0.5] == pytest.approx(
        rec2.latency_s)


def test_hedge_policy_ignores_keepalive_history():
    rt = FaaSRuntime(RuntimeConfig())
    rt.register("p", _sleepy_handler)
    rt.register("r", _sleepy_handler)
    pol = HedgePolicy(min_history=2)
    for i in range(4):                      # warm pings only
        rt.invoke("p", i, t_arrival=rt.clock + 1, keepalive=True)
    assert pol.threshold_s(rt, ["p", "r"]) is None
    for i in range(2):                      # real warm traffic
        rt.invoke("p", i, t_arrival=rt.clock + 1)
    assert pol.threshold_s(rt, ["p", "r"]) is not None


def test_retire_blocks_new_invocations_and_drains():
    rt = FaaSRuntime(RuntimeConfig())
    rt.register("f", _sleepy_handler)
    rt.register("g", _sleepy_handler)
    _, rec = rt.invoke("f", 0)              # busy until ~0.36 (cold+hydrate)
    busy_until = rec.t_done
    rt.retire("f", t=busy_until - 0.05)     # mid-flight: must drain, not kill
    assert not rt.registered("f")
    assert rt.fleet_size == 1               # in-flight instance still there
    with pytest.raises(RuntimeError_, match="retired"):
        rt.invoke("f", 1, t_arrival=busy_until + 1)
    # any later fleet sweep (here: an unrelated invocation) reaps the
    # drained instance
    rt.invoke("g", 0, t_arrival=busy_until + 1)
    assert all(i.fn != "f" for i in rt._instances)
    # an idle pool retires immediately
    rt.retire("g", t=rt.clock + 1)
    assert rt.fleet_size == 0
    # re-registering reinstates
    rt.register("g", _sleepy_handler)
    rt.invoke("g", 0, t_arrival=rt.clock + 2)


def test_pool_introspection():
    rt = FaaSRuntime(RuntimeConfig(idle_timeout_s=100.0))
    rt.register("f", _sleepy_handler)
    assert rt.pool_expiry_s("f") is None
    _, rec = rt.invoke("f", 0)
    assert rt.pool_busy("f", rec.t_done - 0.01)
    assert not rt.pool_busy("f", rec.t_done + 0.01)
    exp = rt.pool_expiry_s("f", rec.t_done + 10.0)
    assert exp == pytest.approx(90.0)
    assert rt.kill_instance(fn="f")
    assert rt.recent_kills("f", now=rt.clock, window_s=30.0) == 1
    assert rt.recent_kills("f", now=rt.clock + 60.0, window_s=30.0) == 0


# -- scatter layer ------------------------------------------------------------


def test_replica_groups_are_mutable_with_last_replica_guard():
    rt = FaaSRuntime(RuntimeConfig())
    for fn in ("a", "a1", "b"):
        rt.register(fn, _sleepy_handler)
    sc = ScatterGather(rt, [["a"], ["b"]])
    sc.add_replica(0, "a1")
    assert sc.groups[0] == ["a", "a1"]
    with pytest.raises(ValueError):
        sc.add_replica(0, "a1")             # duplicate
    sc.remove_replica(0, "a1")
    with pytest.raises(ValueError):
        sc.remove_replica(0, "a")           # last member
    with pytest.raises(ValueError):
        sc.remove_replica(1, "a")           # not a member


def test_aware_routing_rotates_primary_off_killed_pool(corpus, queries):
    apps = {r: _build(corpus, replicas=2, routing=r)
            for r in ("static", "aware")}
    outs = {}
    for routing, app in apps.items():
        app.warm()
        app.query(queries[0], k=K, t_arrival=app.runtime.clock + 0.5,
                  fetch_docs=False)
        assert app.runtime.kill_instance(fn=app.fn_names[0])
        n0 = len(app.runtime.records)
        r = app.query(queries[1], k=K, t_arrival=app.runtime.clock + 0.5,
                      fetch_docs=False)
        outs[routing] = (tuple(r.body["ids"]),
                         tuple(round(s, 6) for s in r.body["scores"]))
        rec0 = next(rec for rec in app.runtime.records[n0:]
                    if rec.fn in app.fn_groups[0])
        if routing == "aware":
            # primary rotated to the warm replica: no cold start at all
            assert rec0.fn == app.fn_groups[0][1]
            assert not rec0.cold
        else:
            # static keeps the killed pool as primary (and, with no hedge
            # policy here, eats the cold start the kill caused)
            assert rec0.fn == app.fn_groups[0][0]
            assert rec0.cold
    assert outs["aware"] == outs["static"]  # same PackedIndex either way


# -- controller layer ---------------------------------------------------------


def _policy(**kw):
    kw.setdefault("min_replicas", 1)
    kw.setdefault("max_replicas", 2)
    kw.setdefault("tick_s", 0.25)
    kw.setdefault("rate_window_s", 1.0)
    kw.setdefault("up_qps_per_replica", 5.0)
    kw.setdefault("down_qps_per_replica", 1.0)
    kw.setdefault("idle_ticks_to_retire", 2)
    return AutoscalePolicy(**kw)


def _drive(app, qs, gap):
    for q in qs:
        r = app.query(q, k=K, t_arrival=app.runtime.clock + gap,
                      fetch_docs=False)
        assert r.ok, r.body
        yield r


def test_controller_scales_up_on_burst_and_down_when_idle(corpus, queries):
    app = _build(corpus, replicas=1, hedge=HedgePolicy(),
                 autoscale=_policy())
    assert app.controller is not None
    assert app.scatter.routing == "aware"   # autoscale default
    app.warm()
    list(_drive(app, queries[:12], gap=0.04))      # 25 q/s burst
    assert app.controller.replica_counts() == [2] * N_PARTS
    # scale-up registered a FRESH function over the same asset and
    # prewarmed its pool — no re-publish, segments untouched
    assert app.fn_groups[0][1] == "search-p0r1"
    assert app.runtime.registered("search-p0r1")
    assert app.runtime.pool_expiry_s("search-p0r1") is not None
    assert len(app.assets) == N_PARTS
    ups = [e for e in app.controller.events if e["action"] == "scale_up"]
    assert len(ups) == N_PARTS and all("demand" in e["reason"] for e in ups)

    list(_drive(app, queries[12:18], gap=60.0))    # sustained idleness
    assert app.controller.replica_counts() == [1] * N_PARTS
    downs = [e for e in app.controller.events if e["action"] == "retire"]
    assert {e["fn"] for e in downs} == {"search-p0r1", "search-p1r1"}
    assert not app.runtime.registered("search-p0r1")
    # a retired replica's pool is gone after the drain sweep
    assert all(i.fn not in {"search-p0r1", "search-p1r1"}
               for i in app.runtime._instances)


def test_retiring_idle_replica_strictly_cuts_cost(corpus, queries):
    """The scale-down economics: over an identical quiet stretch, the fleet
    that retired its standby replicas must spend strictly less — retirement
    stops the keep-alive pings that make standby capacity cost money."""
    def run(policy):
        app = _build(corpus, replicas=2, hedge=HedgePolicy(),
                     autoscale=policy,
                     runtime_config=RuntimeConfig(idle_timeout_s=60.0))
        app.warm()
        list(_drive(app, queries[:4], gap=0.5))
        led = app.runtime.ledger
        d0 = led.total_dollars
        idle0 = led.idle_dollars
        # a long quiet stretch, timer-ticked like a scheduled pinger
        tick = app.runtime.clock
        for q in queries[4:8]:
            t_arr = app.runtime.clock + 600.0
            while tick + 15.0 < t_arr:
                tick += 15.0
                app.controller.maybe_tick(tick)
            tick = max(tick, t_arr)
            app.query(q, k=K, t_arrival=t_arr, fetch_docs=False)
        return app, led.total_dollars - d0, led.idle_dollars - idle0

    fixed_app, fixed_cost, fixed_idle = run(
        _policy(min_replicas=2, max_replicas=2))
    auto_app, auto_cost, auto_idle = run(_policy())
    assert fixed_app.controller.replica_counts() == [2] * N_PARTS
    assert auto_app.controller.replica_counts() == [1] * N_PARTS
    assert any(e["action"] == "retire" for e in auto_app.controller.events)
    assert auto_idle < fixed_idle           # the pings stopped...
    assert auto_cost < fixed_cost           # ...and the bill strictly shrank


def test_results_bit_identical_through_scale_events(corpus, queries, oracle=None):
    plain = _build(corpus, replicas=1)
    auto = _build(corpus, replicas=1, hedge=HedgePolicy(),
                  autoscale=_policy())
    outs = {}
    for name, app in (("plain", plain), ("auto", auto)):
        app.warm()
        out = []
        # burst (scales auto up) with a kill, then quiet (scales it down)
        for i, q in enumerate(queries[:16]):
            if i == 12:
                app.runtime.kill_instance(fn=app.fn_names[0])
            r = app.query(q, k=K, t_arrival=app.runtime.clock + 0.04,
                          fetch_docs=False)
            out.append((tuple(r.body["ids"]),
                        tuple(round(s, 6) for s in r.body["scores"])))
        for q in queries[16:22]:
            r = app.query(q, k=K, t_arrival=app.runtime.clock + 60.0,
                          fetch_docs=False)
            out.append((tuple(r.body["ids"]),
                        tuple(round(s, 6) for s in r.body["scores"])))
        outs[name] = out
    assert auto.controller.events          # scaling actually happened
    assert outs["auto"] == outs["plain"]
    oracle = OracleSearcher(corpus)
    for q, (ids, _) in zip(queries[:22], outs["auto"]):
        want = [d for d, _ in oracle.search(q, k=K)]
        assert list(ids) == want, q
