"""Fleet autoscaling: the cost-ledger control loop, layer by layer.

* ledger — ``dollars_per_1k`` bills per LOGICAL query while hedge and idle
  (keep-alive) spend are attributed separately; the three attribution lines
  always sum to the compute bill.
* runtime — ``retire`` blocks new invocations and drains in-flight work;
  keep-alive invocations bill as idle capacity and stay out of latency
  percentiles and hedge-policy history.
* scatter — replica groups are mutable (with a last-replica guard), and
  aware routing rotates primaries away from pools with recent kills or the
  worst projected overhead.
* controller — bursts grow a partition's group (new ``search-p{p}rN`` over
  the SAME published segment), sustained idleness shrinks it, retiring an
  idle replica strictly reduces what the same traffic costs, and results
  stay bit-identical to an unscaled fleet and the oracle throughout.
"""

import pytest

from repro.core.autoscale import AutoscalePolicy
from repro.core.cost import CostLedger, Invocation
from repro.core.partition import HedgePolicy, ScatterGather
from repro.core.runtime import FaaSRuntime, RuntimeConfig, RuntimeError_
from repro.data.corpus import synth_corpus, synth_queries
from repro.search.oracle import OracleSearcher
from repro.search.searcher import SearchConfig
from repro.search.service import build_partitioned_search_app

K = 10
N_PARTS = 2
GB2 = 2 << 30


@pytest.fixture(scope="module")
def corpus():
    return synth_corpus(240, vocab=400, seed=41)


@pytest.fixture(scope="module")
def queries(corpus):
    return synth_queries(corpus, 40, seed=43)


def _det_cfg():
    # modeled exec clock: latencies and charges in these tests are exact
    return SearchConfig(sim_exec_s=0.002)


def _build(corpus, **kw):
    kw.setdefault("search_config", _det_cfg())
    return build_partitioned_search_app(corpus, n_parts=N_PARTS, **kw)


# -- ledger layer -------------------------------------------------------------


def test_dollars_per_1k_counts_logical_queries_under_hedging():
    led = CostLedger()
    for _ in range(10):
        led.charge(Invocation(GB2, 0.1))
    for _ in range(3):                      # backup legs: bill, answer nothing
        led.charge(Invocation(GB2, 0.1, hedge=True))
    assert led.invocations == 13
    # 10 logical queries paid for 13 invocations — the denominator is the
    # caller's query count, so hedging shows up as a higher $/1k, never as
    # phantom extra queries
    assert led.dollars_per_1k(10) == pytest.approx(
        led.total_dollars / 10 * 1000.0)
    assert led.hedge_dollars > 0
    assert led.dollars_per_1k(0) != led.dollars_per_1k(0)  # NaN guard


def test_empty_ledger_reports_zero_not_an_error():
    """A just-built fleet with no traffic reports $0 per 1k queries and an
    all-zero attribution — dashboards before the first query must see a
    bill of zero, never a ZeroDivisionError (and never NaN for a fleet
    that truly spent nothing)."""
    led = CostLedger()
    assert led.dollars_per_1k(0) == 0.0
    assert led.total_dollars == 0.0
    att = led.attribution()
    assert set(att) == {"serving", "hedge", "idle", "write", "backfill"}
    assert all(v == 0.0 for v in att.values())
    assert led.queries_per_dollar() == float("inf")
    # spend with zero queries stays NaN: no per-query number honestly
    # describes a bill no query caused (prewarm pings, writer work)
    led.charge(Invocation(GB2, 0.05, idle=True))
    assert led.dollars_per_1k(0) != led.dollars_per_1k(0)   # NaN
    assert led.dollars_per_1k(10) > 0


def test_attribution_partitions_the_compute_bill():
    led = CostLedger()
    led.charge(Invocation(GB2, 0.2))
    led.charge(Invocation(GB2, 0.2, hedge=True))
    led.charge(Invocation(GB2, 0.05, idle=True))
    att = led.attribution()
    assert att["hedge"] > 0 and att["idle"] > 0 and att["serving"] > 0
    assert sum(att.values()) == pytest.approx(led.compute_dollars)
    assert led.idle_invocations == 1 and led.hedge_invocations == 1


# -- runtime layer ------------------------------------------------------------


def _sleepy_handler(cache, payload):
    cache.get_or_hydrate("state", "v1", lambda: (object(), 0.2))
    return payload, 0.01


def test_keepalive_bills_idle_and_stays_out_of_percentiles():
    rt = FaaSRuntime(RuntimeConfig())
    rt.register("f", _sleepy_handler)
    _, rec = rt.invoke("f", 0, keepalive=True)
    assert rec.keepalive
    assert rt.ledger.idle_invocations == 1 and rt.ledger.idle_gb_seconds > 0
    # pings are not queries: the percentile log must be empty without them
    p = rt.latency_percentiles("f", qs=(0.5,))
    assert p[0.5] != p[0.5]                 # NaN
    _, rec2 = rt.invoke("f", 1, t_arrival=rt.clock + 1)
    assert not rec2.keepalive
    assert rt.ledger.idle_invocations == 1  # unchanged by a real query
    assert rt.latency_percentiles("f", qs=(0.5,))[0.5] == pytest.approx(
        rec2.latency_s)


def test_hedge_policy_ignores_keepalive_history():
    rt = FaaSRuntime(RuntimeConfig())
    rt.register("p", _sleepy_handler)
    rt.register("r", _sleepy_handler)
    pol = HedgePolicy(min_history=2)
    for i in range(4):                      # warm pings only
        rt.invoke("p", i, t_arrival=rt.clock + 1, keepalive=True)
    assert pol.threshold_s(rt, ["p", "r"]) is None
    for i in range(2):                      # real warm traffic
        rt.invoke("p", i, t_arrival=rt.clock + 1)
    assert pol.threshold_s(rt, ["p", "r"]) is not None


def test_retire_blocks_new_invocations_and_drains():
    rt = FaaSRuntime(RuntimeConfig())
    rt.register("f", _sleepy_handler)
    rt.register("g", _sleepy_handler)
    _, rec = rt.invoke("f", 0)              # busy until ~0.36 (cold+hydrate)
    busy_until = rec.t_done
    rt.retire("f", t=busy_until - 0.05)     # mid-flight: must drain, not kill
    assert not rt.registered("f")
    assert rt.fleet_size == 1               # in-flight instance still there
    with pytest.raises(RuntimeError_, match="retired"):
        rt.invoke("f", 1, t_arrival=busy_until + 1)
    # any later fleet sweep (here: an unrelated invocation) reaps the
    # drained instance
    rt.invoke("g", 0, t_arrival=busy_until + 1)
    assert all(i.fn != "f" for i in rt._instances)
    # an idle pool retires immediately
    rt.retire("g", t=rt.clock + 1)
    assert rt.fleet_size == 0
    # re-registering reinstates
    rt.register("g", _sleepy_handler)
    rt.invoke("g", 0, t_arrival=rt.clock + 2)


def test_pool_introspection():
    rt = FaaSRuntime(RuntimeConfig(idle_timeout_s=100.0))
    rt.register("f", _sleepy_handler)
    assert rt.pool_expiry_s("f") is None
    _, rec = rt.invoke("f", 0)
    assert rt.pool_busy("f", rec.t_done - 0.01)
    assert not rt.pool_busy("f", rec.t_done + 0.01)
    exp = rt.pool_expiry_s("f", rec.t_done + 10.0)
    assert exp == pytest.approx(90.0)
    assert rt.kill_instance(fn="f")
    assert rt.recent_kills("f", now=rt.clock, window_s=30.0) == 1
    assert rt.recent_kills("f", now=rt.clock + 60.0, window_s=30.0) == 0


def test_pool_expiry_boundary_semantics():
    """The keepalive margin math rests on a pinned boundary contract: an
    instance idle EXACTLY ``idle_timeout_s`` is still alive (reaping is
    strictly-greater), ``pool_expiry_s`` reports 0.0 for it, and the
    controller's ``expiry < margin`` rule therefore PINGS it (an expiry of
    0 is a pingable pool, not a lost one) while a margin of 0 never
    pings."""
    cfg = RuntimeConfig(idle_timeout_s=100.0)
    rt = FaaSRuntime(cfg)
    rt.register("f", _sleepy_handler)
    _, rec = rt.invoke("f", 0)
    t_exact = rec.t_done + cfg.idle_timeout_s    # last_used == t_done
    # at the boundary: alive, expiry exactly 0, probe projects a WARM hit
    assert rt.pool_expiry_s("f", t_exact) == pytest.approx(0.0)
    assert rt.probe("f", t_exact) == (0.0, 0.0)
    # strictly past the boundary: reaped — probe projects a cold provision
    eps = 1e-6
    assert rt.probe("f", t_exact + eps) == (0.0, cfg.provision_s)
    assert rt.pool_expiry_s("f", t_exact + eps) == pytest.approx(-eps)
    # an invocation AT the boundary reuses the warm instance (no cold)
    _, rec2 = rt.invoke("f", 1, t_arrival=t_exact)
    assert not rec2.cold and rec2.instance_id == rec.instance_id
    # ...and one strictly past it pays the cold boot the probe projected
    rt2 = FaaSRuntime(cfg)
    rt2.register("f", _sleepy_handler)
    _, r1 = rt2.invoke("f", 0)
    _, r2 = rt2.invoke("f", 1, t_arrival=r1.t_done + cfg.idle_timeout_s + eps)
    assert r2.cold and r2.provisioned and r2.instance_id != r1.instance_id


def test_latency_percentile_window_tracks_regime_shift():
    """The warm-latency window reconciliation: HedgePolicy scans the newest
    ``window`` warm records, and ``latency_percentiles(window=...)`` now
    gives its consumers the SAME recency — a fleet whose latency regime
    shifts mid-run must hedge AND scale on the regime it is in, not scale
    on hours-stale history."""
    rt = FaaSRuntime(RuntimeConfig())
    rt.register("f", lambda cache, payload: (payload, payload))
    t = 0.0
    for _ in range(400):                 # old regime: 10 ms exec
        t += 1.0
        rt.invoke("f", 0.01, t_arrival=t)
    for _ in range(200):                 # new regime: 100 ms exec
        t += 1.0
        rt.invoke("f", 0.1, t_arrival=t)
    unwindowed = rt.latency_percentiles("f", qs=(0.5,), warm_only=True)[0.5]
    windowed = rt.latency_percentiles("f", qs=(0.5,), warm_only=True,
                                      window=256)[0.5]
    assert unwindowed < 0.05             # stale history drags the quantile
    assert windowed == pytest.approx(0.1)    # the window sees the shift
    # HedgePolicy and the controller's threshold read the SAME regime now
    pol = HedgePolicy(percentile=0.5, scale=2.0, min_history=4, window=256)
    assert pol.threshold_s(rt, ["f"]) == pytest.approx(2.0 * windowed)
    sc = ScatterGather(rt, [["f"]])
    from repro.core.autoscale import FleetController
    ctl = FleetController(rt, sc, [lambda: _sleepy_handler],
                          AutoscalePolicy(warm_window=256))
    assert ctl._overhead_threshold(["f"]) == pytest.approx(2.0 * windowed)
    # newest-first capped scan returns at most `window` records
    assert len(rt.recent_latencies("f", window=256)) == 256
    assert len(rt.recent_latencies("f")) == 600


# -- scatter layer ------------------------------------------------------------


def test_replica_groups_are_mutable_with_last_replica_guard():
    rt = FaaSRuntime(RuntimeConfig())
    for fn in ("a", "a1", "b"):
        rt.register(fn, _sleepy_handler)
    sc = ScatterGather(rt, [["a"], ["b"]])
    sc.add_replica(0, "a1")
    assert sc.groups[0] == ["a", "a1"]
    with pytest.raises(ValueError):
        sc.add_replica(0, "a1")             # duplicate
    sc.remove_replica(0, "a1")
    with pytest.raises(ValueError):
        sc.remove_replica(0, "a")           # last member
    with pytest.raises(ValueError):
        sc.remove_replica(1, "a")           # not a member


def test_aware_routing_rotates_primary_off_killed_pool(corpus, queries):
    apps = {r: _build(corpus, replicas=2, routing=r)
            for r in ("static", "aware")}
    outs = {}
    for routing, app in apps.items():
        app.warm()
        app.query(queries[0], k=K, t_arrival=app.runtime.clock + 0.5,
                  fetch_docs=False)
        assert app.runtime.kill_instance(fn=app.fn_names[0])
        n0 = len(app.runtime.records)
        r = app.query(queries[1], k=K, t_arrival=app.runtime.clock + 0.5,
                      fetch_docs=False)
        outs[routing] = (tuple(r.body["ids"]),
                         tuple(round(s, 6) for s in r.body["scores"]))
        rec0 = next(rec for rec in app.runtime.records[n0:]
                    if rec.fn in app.fn_groups[0])
        if routing == "aware":
            # primary rotated to the warm replica: no cold start at all
            assert rec0.fn == app.fn_groups[0][1]
            assert not rec0.cold
        else:
            # static keeps the killed pool as primary (and, with no hedge
            # policy here, eats the cold start the kill caused)
            assert rec0.fn == app.fn_groups[0][0]
            assert rec0.cold
    assert outs["aware"] == outs["static"]  # same PackedIndex either way


# -- controller layer ---------------------------------------------------------


def _policy(**kw):
    kw.setdefault("min_replicas", 1)
    kw.setdefault("max_replicas", 2)
    kw.setdefault("tick_s", 0.25)
    kw.setdefault("rate_window_s", 1.0)
    kw.setdefault("up_qps_per_replica", 5.0)
    kw.setdefault("down_qps_per_replica", 1.0)
    kw.setdefault("idle_ticks_to_retire", 2)
    return AutoscalePolicy(**kw)


def _drive(app, qs, gap):
    for q in qs:
        r = app.query(q, k=K, t_arrival=app.runtime.clock + gap,
                      fetch_docs=False)
        assert r.ok, r.body
        yield r


def test_controller_scales_up_on_burst_and_down_when_idle(corpus, queries):
    app = _build(corpus, replicas=1, hedge=HedgePolicy(),
                 autoscale=_policy())
    assert app.controller is not None
    assert app.scatter.routing == "aware"   # autoscale default
    app.warm()
    list(_drive(app, queries[:12], gap=0.04))      # 25 q/s burst
    assert app.controller.replica_counts() == [2] * N_PARTS
    # scale-up registered a FRESH function over the same asset and
    # prewarmed its pool — no re-publish, segments untouched
    assert app.fn_groups[0][1] == "search-p0r1"
    assert app.runtime.registered("search-p0r1")
    assert app.runtime.pool_expiry_s("search-p0r1") is not None
    assert len(app.assets) == N_PARTS
    ups = [e for e in app.controller.events if e["action"] == "scale_up"]
    assert len(ups) == N_PARTS and all("demand" in e["reason"] for e in ups)

    list(_drive(app, queries[12:18], gap=60.0))    # sustained idleness
    assert app.controller.replica_counts() == [1] * N_PARTS
    downs = [e for e in app.controller.events if e["action"] == "retire"]
    assert {e["fn"] for e in downs} == {"search-p0r1", "search-p1r1"}
    assert not app.runtime.registered("search-p0r1")
    # a retired replica's pool is gone after the drain sweep
    assert all(i.fn not in {"search-p0r1", "search-p1r1"}
               for i in app.runtime._instances)


def test_retiring_idle_replica_strictly_cuts_cost(corpus, queries):
    """The scale-down economics: over an identical quiet stretch, the fleet
    that retired its standby replicas must spend strictly less — retirement
    stops the keep-alive pings that make standby capacity cost money."""
    def run(policy):
        app = _build(corpus, replicas=2, hedge=HedgePolicy(),
                     autoscale=policy,
                     runtime_config=RuntimeConfig(idle_timeout_s=60.0))
        app.warm()
        list(_drive(app, queries[:4], gap=0.5))
        led = app.runtime.ledger
        d0 = led.total_dollars
        idle0 = led.idle_dollars
        # a long quiet stretch, timer-ticked like a scheduled pinger
        tick = app.runtime.clock
        for q in queries[4:8]:
            t_arr = app.runtime.clock + 600.0
            while tick + 15.0 < t_arr:
                tick += 15.0
                app.controller.maybe_tick(tick)
            tick = max(tick, t_arr)
            app.query(q, k=K, t_arrival=t_arr, fetch_docs=False)
        return app, led.total_dollars - d0, led.idle_dollars - idle0

    fixed_app, fixed_cost, fixed_idle = run(
        _policy(min_replicas=2, max_replicas=2))
    auto_app, auto_cost, auto_idle = run(_policy())
    assert fixed_app.controller.replica_counts() == [2] * N_PARTS
    assert auto_app.controller.replica_counts() == [1] * N_PARTS
    assert any(e["action"] == "retire" for e in auto_app.controller.events)
    assert auto_idle < fixed_idle           # the pings stopped...
    assert auto_cost < fixed_cost           # ...and the bill strictly shrank


def test_heterogeneous_targets_scale_head_not_tail():
    """The per-group target rule under skew: two partitions, the head
    holding ~6× the documents (so ~6× the modeled eval time), served at a
    sustained rate that saturates the head's single pool but leaves the
    tail mostly idle. The controller must scale the HEAD to its
    concurrency target while the tail never grows — then drain the head
    back once the traffic goes quiet."""
    corpus = synth_corpus(350, vocab=400, seed=45)
    queries = synth_queries(corpus, 60, seed=46)
    app = build_partitioned_search_app(
        corpus, n_parts=2, replicas=1, hedge=HedgePolicy(),
        autoscale=AutoscalePolicy(
            min_replicas=1, max_replicas=3, tick_s=0.25, rate_window_s=1.0,
            up_qps_per_replica=float("inf"), down_qps_per_replica=1.0,
            idle_ticks_to_retire=2, target_utilization=0.6),
        partition_weights=[6.0, 1.0],
        runtime_config=RuntimeConfig(idle_timeout_s=60.0),
        search_config=SearchConfig(sim_exec_s=0.002,
                                   sim_exec_per_kdoc_s=0.4))
    assert len(app.indexer.parts[0].seg_docs) == 300
    assert len(app.indexer.parts[1].seg_docs) == 50
    app.warm()
    # fixed external schedule, 6 inv/s: the ~122 ms head eval offers
    # 0.73 concurrency on one pool — NO queue, NO cold boot, NO hedge
    # fires, so the ONLY signal that can grow the head is Little's law
    # (6/s × 122 ms ÷ 0.6 util → 2 pools); the tail's ~22 ms eval offers
    # 0.13 and keeps its single pool
    t0 = app.runtime.clock + 1.0
    for i, q in enumerate(queries[:40]):
        r = app.query(q, k=K, t_arrival=t0 + (1 / 6) * i, fetch_docs=False)
        assert r.ok, r.body
    assert app.controller.replica_counts() == [2, 1]
    assert app.controller.replica_targets() == [2, 1]
    ups = [e for e in app.controller.events if e["action"] == "scale_up"]
    assert ups and all(e["partition"] == 0 for e in ups)
    assert all("concurrency" in e["reason"] for e in ups)
    # quiet: the head's extra pools drain back to the per-group minimum
    t = t0 + (1 / 6) * 40
    tick = t
    for q in queries[40:46]:
        t += 120.0
        while tick + 15.0 < t:
            tick += 15.0
            app.controller.maybe_tick(tick)
        app.query(q, k=K, t_arrival=t, fetch_docs=False)
    assert app.controller.replica_counts() == [1, 1]


def test_exec_scale_feeds_b9b_fraction_into_concurrency_rule():
    """B9b feed-forward: a pruned fleet's OBSERVED warm p50 carries the
    dense-path constant (the modeled clock charges ``sim_exec_s``
    calibrated against the dense pass), but the work its kernel sustains
    is linear in blocks touched — ~0.02 of the dense pass under tight
    bounds (the gated ``b9b_pruned_blocks_touched_frac_*`` rows). Fed that
    fraction, the concurrency rule must NOT buy the pools the raw p50
    says it needs: identical traffic, identical observed latencies,
    opposite decision. Per-partition sequences let a mixed fleet scale
    only its dense partitions off the unscaled constant."""
    def run(exec_scale):
        corpus = synth_corpus(350, vocab=400, seed=45)
        queries = synth_queries(corpus, 40, seed=46)
        app = build_partitioned_search_app(
            corpus, n_parts=2, replicas=1, hedge=HedgePolicy(),
            autoscale=AutoscalePolicy(
                min_replicas=1, max_replicas=3, tick_s=0.25,
                rate_window_s=1.0, up_qps_per_replica=float("inf"),
                down_qps_per_replica=1.0, idle_ticks_to_retire=2,
                target_utilization=0.6, exec_scale=exec_scale),
            partition_weights=[6.0, 1.0],
            runtime_config=RuntimeConfig(idle_timeout_s=60.0),
            search_config=SearchConfig(sim_exec_s=0.002,
                                       sim_exec_per_kdoc_s=0.4))
        app.warm()
        t0 = app.runtime.clock + 1.0
        for i, q in enumerate(queries):
            r = app.query(q, k=K, t_arrival=t0 + (1 / 6) * i,
                          fetch_docs=False)
            assert r.ok, r.body
        return app

    dense = run(1.0)                 # the default: observed time IS the work
    assert dense.controller.replica_counts() == [2, 1]
    pruned = run(0.02)               # B9b's measured blocks-touched fraction
    assert pruned.controller.replica_counts() == [1, 1]
    assert not any(e["action"] == "scale_up"
                   for e in pruned.controller.events)
    # per-partition feed: scale only partition 1's model down — the head
    # still buys its pool off the unscaled constant
    mixed = run([1.0, 0.02])
    assert mixed.controller.replica_counts() == [2, 1]
    # a wrong-length sequence is rejected at construction, like bounds
    from repro.core.autoscale import FleetController
    with pytest.raises(ValueError, match="per-partition exec_scale"):
        FleetController(mixed.runtime, mixed.scatter,
                        [lambda: _sleepy_handler] * 2,
                        AutoscalePolicy(exec_scale=[1.0, 0.5, 0.2]))


def test_over_provisioned_group_drains_under_live_traffic():
    """A transient (here: simply starting at R=2) must not pin capacity
    forever just because traffic keeps flowing: when the group's own
    concurrency math says one pool suffices and no pressure shows for
    ``idle_ticks_to_retire`` ticks, the controller retires toward the
    target even though the idle rule (rate < down_qps) can never fire."""
    corpus = synth_corpus(240, vocab=400, seed=47)
    queries = synth_queries(corpus, 30, seed=48)
    app = build_partitioned_search_app(
        corpus, n_parts=2, replicas=2, hedge=HedgePolicy(),
        autoscale=AutoscalePolicy(
            min_replicas=1, max_replicas=3, tick_s=0.25, rate_window_s=1.0,
            up_qps_per_replica=float("inf"), down_qps_per_replica=1.0,
            idle_ticks_to_retire=2, target_utilization=0.6),
        runtime_config=RuntimeConfig(idle_timeout_s=60.0),
        search_config=SearchConfig(sim_exec_s=0.002))
    app.warm()
    t0 = app.runtime.clock + 1.0
    for i, q in enumerate(queries):            # 5 inv/s: alive, easy load
        r = app.query(q, k=K, t_arrival=t0 + 0.2 * i, fetch_docs=False)
        assert r.ok, r.body
    assert app.controller.replica_counts() == [1, 1]
    downs = [e for e in app.controller.events if e["action"] == "retire"]
    assert downs and all("over-provisioned" in e["reason"] for e in downs)


def test_per_partition_replica_bounds():
    """Heterogeneous bounds: a per-partition min/max sequence pins each
    group's range independently (and a wrong-length sequence is rejected
    at construction)."""
    corpus = synth_corpus(240, vocab=400, seed=49)
    queries = synth_queries(corpus, 20, seed=50)
    app = build_partitioned_search_app(
        corpus, n_parts=2, replicas=2, hedge=HedgePolicy(),
        autoscale=AutoscalePolicy(
            min_replicas=[2, 1], max_replicas=[3, 1], tick_s=0.25,
            rate_window_s=1.0, up_qps_per_replica=float("inf"),
            down_qps_per_replica=1.0, idle_ticks_to_retire=2,
            target_utilization=0.6),
        runtime_config=RuntimeConfig(idle_timeout_s=60.0),
        search_config=SearchConfig(sim_exec_s=0.002))
    app.warm()
    t0 = app.runtime.clock + 1.0
    for i, q in enumerate(queries):
        app.query(q, k=K, t_arrival=t0 + 0.2 * i, fetch_docs=False)
    # partition 0 may never drop below 2; partition 1 may never exceed 1,
    # so its over-provisioned second pool drains to its own bound
    assert app.controller.replica_counts() == [2, 1]
    from repro.core.autoscale import FleetController
    with pytest.raises(ValueError, match="per-partition replica bounds"):
        FleetController(app.runtime, app.scatter,
                        [lambda: _sleepy_handler] * 2,
                        AutoscalePolicy(min_replicas=[1, 1, 1]))


def test_results_bit_identical_through_scale_events(corpus, queries, oracle=None):
    plain = _build(corpus, replicas=1)
    auto = _build(corpus, replicas=1, hedge=HedgePolicy(),
                  autoscale=_policy())
    outs = {}
    for name, app in (("plain", plain), ("auto", auto)):
        app.warm()
        out = []
        # burst (scales auto up) with a kill, then quiet (scales it down)
        for i, q in enumerate(queries[:16]):
            if i == 12:
                app.runtime.kill_instance(fn=app.fn_names[0])
            r = app.query(q, k=K, t_arrival=app.runtime.clock + 0.04,
                          fetch_docs=False)
            out.append((tuple(r.body["ids"]),
                        tuple(round(s, 6) for s in r.body["scores"])))
        for q in queries[16:22]:
            r = app.query(q, k=K, t_arrival=app.runtime.clock + 60.0,
                          fetch_docs=False)
            out.append((tuple(r.body["ids"]),
                        tuple(round(s, 6) for s in r.body["scores"])))
        outs[name] = out
    assert auto.controller.events          # scaling actually happened
    assert outs["auto"] == outs["plain"]
    oracle = OracleSearcher(corpus)
    for q, (ids, _) in zip(queries[:22], outs["auto"]):
        want = [d for d, _ in oracle.search(q, k=K)]
        assert list(ids) == want, q
