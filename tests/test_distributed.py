"""Multi-device semantics, run in subprocesses with forced host device
counts (the main pytest process must keep the default 1-CPU view — the
dry-run is the only place that sees 512 devices)."""

import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_ep_moe_matches_oracle_on_4x2_mesh():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.models.moe import MoEConfig, moe_defs, moe_ffn_dense_oracle
        from repro.models.moe_ep import ep_moe_ffn
        from repro.models.common import init_params
        from repro.parallel import compat
        cfg = MoEConfig(n_experts=8, top_k=2, d_model=16, d_ff=8, n_shared=1,
                        capacity_factor=8.0)
        params = init_params(moe_defs(cfg, jnp.float32), jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 4, 16))
        mesh = compat.make_mesh((4, 2), ("data", "model"))
        with compat.use_mesh(mesh):
            y, aux = jax.jit(
                lambda p, x: ep_moe_ffn(p, x, cfg, mesh=mesh))(params, x)
        y_ref = moe_ffn_dense_oracle(params, x, cfg)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=2e-5, atol=2e-5)
        print("ok")
    """)


def test_sharded_train_step_matches_single_device():
    """pjit on a 4×2 mesh computes the same loss/params as 1 device."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_arch
        from repro.configs.cells import train_state_specs
        from repro.models.transformer import lm_loss, lm_param_defs
        from repro.models.common import init_params
        from repro.parallel import compat
        from repro.parallel.sharding import lm_rules, tree_named
        from repro.train.optim import OptConfig
        from repro.train.steps import init_train_state, make_train_step

        mod = get_arch("stablelm-3b")
        cfg = mod.reduced_config()
        defs = lm_param_defs(cfg)
        params = init_params(defs, jax.random.PRNGKey(0))
        state = init_train_state(params)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16),
                                              0, cfg.vocab),
                 "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 16),
                                              0, cfg.vocab)}
        step = make_train_step(lambda p, b: lm_loss(p, b, cfg),
                               OptConfig(lr=1e-3))
        # single device
        s1, m1 = jax.jit(step)(state, batch)
        # sharded
        mesh = compat.make_mesh((4, 2), ("data", "model"))
        rules = lm_rules(fsdp=True)
        sh = tree_named(mesh, train_state_specs(defs, rules))
        bsh = tree_named(mesh, {"tokens": rules.batch_spec(None),
                                "labels": rules.batch_spec(None)})
        with compat.use_mesh(mesh):
            state2 = jax.device_put(init_train_state(
                init_params(defs, jax.random.PRNGKey(0))), sh)
            batch2 = jax.device_put(batch, bsh)
            s2, m2 = jax.jit(step, in_shardings=(sh, bsh))(state2, batch2)
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4, (
            float(m1["loss"]), float(m2["loss"]))
        w1 = np.asarray(jax.tree_util.tree_leaves(s1["params"])[0])
        w2 = np.asarray(jax.tree_util.tree_leaves(s2["params"])[0])
        np.testing.assert_allclose(w1, w2, rtol=5e-4, atol=5e-4)
        print("ok")
    """)


def test_distributed_search_8_partitions_matches_oracle():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.data.corpus import synth_corpus, synth_queries
        from repro.parallel import compat
        from repro.search.bm25 import encode_queries
        from repro.search.distributed import (build_partitioned_state,
                                              make_dist_search_fn)
        from repro.search.oracle import OracleSearcher
        docs = synth_corpus(256, vocab=400, seed=3)
        oracle = OracleSearcher(docs)
        state, cfg, vocab = build_partitioned_state(docs, 8,
                                                    {"k": 10, "max_blocks": 64})
        mesh = compat.make_mesh((4, 2), ("data", "model"))
        fn = make_dist_search_fn(cfg, ("data", "model"), mesh=mesh)
        queries = synth_queries(docs, 10, seed=5)
        tids, qtf = encode_queries(vocab, queries, max_terms=cfg.max_terms)
        with compat.use_mesh(mesh):
            scores, ids = jax.jit(fn)(
                jax.tree_util.tree_map(jnp.asarray, state), tids, qtf)
        for qi, q in enumerate(queries):
            want = oracle.search(q, k=10)
            got = [(int(i), float(v)) for v, i in zip(scores[qi], ids[qi])
                   if v > 0]
            # scores must agree rank-by-rank; ids must agree unless tied
            # (tie order between equal scores is implementation-defined)
            for r, ((wd, ws), (gd, gs)) in enumerate(zip(want, got)):
                assert abs(gs - ws) < 2e-4 * max(1.0, abs(ws)), (q, r)
                tied = any(abs(ws - w2) < 1e-5 for d2, w2 in want
                           if d2 != wd)
                assert wd == gd or tied, (q, r, want[:8], got[:8])
        print("ok")
    """)


def test_elastic_reshard_across_mesh_shapes():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.ft.faults import reshard_state
        from repro.parallel import compat
        m1 = compat.make_mesh((8, 1), ("data", "model"))
        m2 = compat.make_mesh((2, 4), ("data", "model"))
        x = np.arange(64, dtype=np.float32).reshape(8, 8)
        state = {"w": jax.device_put(x, NamedSharding(m1, P("data", None)))}
        new = reshard_state(state, {"w": NamedSharding(m2, P(None, "model"))})
        np.testing.assert_array_equal(np.asarray(new["w"]), x)
        assert new["w"].sharding.spec == P(None, "model")
        print("ok")
    """)


def test_multipod_mesh_cell_lowering_smoke():
    """Reduced LM train cell lowers+compiles on a tiny (pod,data,model) mesh
    — the multi-pod axis plumbing, without the 512-device cost."""
    _run("""
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import build_cells
        from repro.parallel import compat
        mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
        cells = build_cells("h2o-danube-1.8b", multi_pod=True, reduced=True)
        cell = cells["train_4k"]
        sh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s),
                                    cell.in_specs,
                                    is_leaf=lambda x: isinstance(x, P))
        with compat.use_mesh(mesh):
            compiled = jax.jit(cell.fn, in_shardings=sh,
                               donate_argnums=cell.donate
                               ).lower(*cell.args).compile()
        ca = compiled.cost_analysis()
        if isinstance(ca, list):      # 0.4.x returns [dict], newer a dict
            ca = ca[0]
        assert ca["flops"] > 0
        print("ok")
    """)
