"""Property-based tests (hypothesis) on the system's invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.cost import (CostLedger, Invocation,
                             fungibility_check)


# -- cost fungibility: the paper's central economic claim ------------------------


@settings(max_examples=50, deadline=None)
@given(qps=st.floats(0.1, 1000), secs=st.floats(1, 1e5),
       scale=st.floats(0.01, 100))
def test_cost_fungibility(qps, secs, scale):
    """qps × secs total queries cost the same under any (qps·s, secs/s)
    reshaping — load shape is irrelevant under per-invocation billing."""
    a, b = fungibility_check(qps, secs, qps * scale, secs / scale)
    assert a == np.float64(b) or abs(a - b) <= 1e-9 * max(a, b, 1e-12)


@settings(max_examples=30, deadline=None)
@given(durations=st.lists(st.floats(0.001, 10.0), min_size=1, max_size=40),
       mem_gb=st.integers(1, 8))
def test_ledger_order_invariance(durations, mem_gb):
    """Total cost is invariant to invocation order (associativity)."""
    l1, l2 = CostLedger(), CostLedger()
    for d in durations:
        l1.charge(Invocation(mem_gb << 30, d))
    for d in reversed(durations):
        l2.charge(Invocation(mem_gb << 30, d))
    assert l1.compute_dollars == np.float64(l2.compute_dollars) or \
        abs(l1.compute_dollars - l2.compute_dollars) < 1e-12


# -- partition/merge == global top-k (paper §3's correctness condition) -----------


@settings(max_examples=40, deadline=None)
@given(n=st.integers(8, 300), parts=st.integers(1, 8), k=st.integers(1, 10),
       seed=st.integers(0, 2 ** 31))
def test_partitioned_topk_equals_global(n, parts, k, seed):
    rng = np.random.default_rng(seed)
    scores = rng.normal(size=n).astype(np.float32)
    # unique scores → unambiguous ranking
    scores += np.arange(n) * 1e-6
    bounds = np.linspace(0, n, parts + 1).astype(int)
    survivors = []
    for p in range(parts):
        lo, hi = bounds[p], bounds[p + 1]
        part = scores[lo:hi]
        kk = min(k, len(part))
        idx = np.argsort(-part)[:kk]
        survivors.extend((part[i], lo + i) for i in idx)
    survivors.sort(key=lambda t: -t[0])
    got = [i for _, i in survivors[:k]]
    want = list(np.argsort(-scores)[:min(k, n)])
    assert got == want


# -- sorted-accumulator == dense scatter accumulator --------------------------------


@settings(max_examples=30, deadline=None)
@given(n_docs=st.integers(4, 64), n_post=st.integers(1, 120),
       seed=st.integers(0, 2 ** 31))
def test_accumulators_agree(n_docs, n_post, seed):
    from repro.search.bm25 import accumulate_dense, accumulate_sorted
    rng = np.random.default_rng(seed)
    docs = rng.integers(0, n_docs + 1, n_post).astype(np.int32)  # incl. pad
    imp = np.where(docs < n_docs,
                   rng.uniform(0.01, 5, n_post), 0.0).astype(np.float32)
    k = min(10, n_docs)
    dense_acc = accumulate_dense(jnp.asarray(docs), jnp.asarray(imp), n_docs)
    dv, di = jax.lax.top_k(dense_acc, k)
    sv, si = accumulate_sorted(jnp.asarray(docs), jnp.asarray(imp), n_docs, k)
    np.testing.assert_allclose(np.asarray(sv), np.asarray(dv), rtol=1e-5,
                               atol=1e-5)
    # ids agree wherever scores are positive & untied
    dvn, svn = np.asarray(dv), np.asarray(sv)
    for i in range(k):
        if dvn[i] > 0 and (i == 0 or dvn[i] < dvn[i - 1] - 1e-6):
            sc = np.asarray(dense_acc)
            assert abs(sc[np.asarray(si)[i]] - dvn[i]) < 1e-5


# -- embedding bag vs naive loop -----------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(v=st.integers(2, 50), d=st.integers(1, 16),
       bags=st.lists(st.lists(st.integers(0, 49), max_size=6), min_size=1,
                     max_size=8),
       seed=st.integers(0, 2 ** 31))
def test_embedding_bag_offsets_property(v, d, bags, seed):
    from repro.models.embedding import embedding_bag
    rng = np.random.default_rng(seed)
    table = rng.normal(size=(v, d)).astype(np.float32)
    indices, offsets = [], []
    for bag in bags:
        offsets.append(len(indices))
        indices.extend(i % v for i in bag)
    if not indices:
        indices = [0]
        offsets = [0] + offsets[1:]
    out = embedding_bag(jnp.asarray(table), jnp.asarray(indices, jnp.int32),
                        jnp.asarray(offsets, jnp.int32), len(bags))
    want = np.zeros((len(bags), d), np.float32)
    for b, off in enumerate(offsets):
        end = offsets[b + 1] if b + 1 < len(offsets) else len(indices)
        for i in range(off, end):
            want[b] += table[indices[i] % v]
    np.testing.assert_allclose(np.asarray(out), want, rtol=2e-4, atol=2e-4)


# -- searcher == oracle on random corpora ----------------------------------------------


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_searcher_oracle_random_corpora(seed):
    from repro.data.corpus import synth_corpus, synth_queries
    from repro.index.builder import IndexWriter
    from repro.search.oracle import OracleSearcher
    from repro.search.searcher import SearchConfig, Searcher
    docs = synth_corpus(60, vocab=120, mean_len=20, seed=seed)
    oracle = OracleSearcher(docs)
    w = IndexWriter()
    w.add_many(docs)
    s = Searcher(w.pack(), SearchConfig(max_blocks=64, k=5))
    for q in synth_queries(docs, 3, seed=seed + 1):
        got = s.search_one(q, k=5)
        want = oracle.search(q, k=5)
        assert [g for g, _ in got] == [w_ for w_, _ in want]


# -- LRU hydration-cache invariant -------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(ops=st.lists(st.tuples(st.integers(0, 8), st.integers(50, 400)),
                    min_size=1, max_size=40))
def test_cache_capacity_invariant(ops):
    from repro.core.cache import HydrationCache
    cap = 1000
    cache = HydrationCache(cap)
    for name, size in ops:
        cache.get_or_hydrate(str(name), "v",
                             lambda s=size: (np.zeros(s, np.uint8), 0.0))
        # invariant: within capacity whenever more than one entry is held
        if len(cache) > 1:
            assert cache.used_bytes <= cap + 400  # at most one over-admit


# -- ring-buffer cache: decode equals forward at arbitrary lengths -------------------------


@settings(max_examples=6, deadline=None)
@given(extra=st.integers(1, 6), seed=st.integers(0, 100))
def test_swa_ring_decode_property(extra, seed):
    from repro.models.common import init_params
    from repro.models.transformer import (LMConfig, lm_decode, lm_forward,
                                          lm_param_defs, lm_prefill)
    cfg = LMConfig(name="t", n_layers=1, d_model=32, n_heads=2, n_kv_heads=1,
                   d_ff=64, vocab=64, window=4, dtype=jnp.float32)
    params = init_params(lm_param_defs(cfg), jax.random.PRNGKey(0))
    S = 9
    toks = jax.random.randint(jax.random.PRNGKey(seed), (1, S + extra), 0, 64)
    full, _ = lm_forward(params, toks, cfg)
    _, cache = lm_prefill(params, toks[:, :S], cfg, max_len=S + extra)
    for t in range(extra):
        logits, cache = lm_decode(params, cache, toks[:, S + t:S + t + 1],
                                  jnp.int32(S + t), cfg)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full[:, S + t]), rtol=2e-2,
                                   atol=2e-2)
