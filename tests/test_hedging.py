"""Replicated partitions + hedged scatter legs: the tail-latency path.

The contract under test, layer by layer:

* runtime — ``probe`` projects the next invocation's overhead without
  mutating the fleet; ``invoke_hedged`` fires two legs at one arrival
  instant, appends ONE record (the winner's latency), and bills BOTH legs
  (no cancellation in FaaS), tagging the backup in the ledger.
* scatter — a replica group serves one published segment from R independent
  instance pools; a ``HedgePolicy`` triggers the backup only when the
  primary's projection exceeds a quantile of recent warm latencies; the
  gather/merge term ``merge_cost_s`` is charged identically on the
  single-query and batched paths.
* app — with one partition's pool deliberately killed mid-run, hedging
  flattens p99 while the merged top-k stays bit-identical to the unhedged
  run and equal to the exact-BM25 oracle; total cost strictly rises with R.
"""

import pytest

from repro.core.partition import MERGE_COST_S, HedgePolicy, ScatterGather
from repro.core.runtime import FaaSRuntime, RuntimeConfig
from repro.data.corpus import synth_corpus, synth_queries
from repro.search.oracle import OracleSearcher
from repro.search.service import build_partitioned_search_app

K = 10
N_PARTS = 3


@pytest.fixture(scope="module")
def corpus():
    return synth_corpus(240, vocab=400, seed=31)


@pytest.fixture(scope="module")
def queries(corpus):
    return synth_queries(corpus, 32, seed=33)


@pytest.fixture(scope="module")
def oracle(corpus):
    return OracleSearcher(corpus)


# -- runtime layer -----------------------------------------------------------


def _sleepy_handler(cache, payload):
    cache.get_or_hydrate("state", "v1", lambda: (object(), 0.2))
    return payload, 0.01


def test_probe_projects_without_mutating():
    rt = FaaSRuntime(RuntimeConfig())
    rt.register("f", _sleepy_handler)
    # empty pool: a fresh provision is projected, and projecting it twice
    # must not boot anything
    assert rt.probe("f") == (0.0, rt.config.provision_s)
    assert rt.fleet_size == 0
    rt.invoke("f", 0)
    assert rt.probe("f", rt.clock + 0.1) == (0.0, 0.0)      # idle warm
    assert rt.kill_instance(fn="f")
    assert rt.probe("f", rt.clock + 0.1) == (0.0, rt.config.provision_s)


def test_invoke_hedged_one_record_both_legs_billed():
    rt = FaaSRuntime(RuntimeConfig())
    rt.register("a", _sleepy_handler)
    rt.register("b", _sleepy_handler)
    rt.invoke("b", 0)                       # warm the replica pool only
    n_recs = len(rt.records)
    n_inv = rt.ledger.invocations
    out, rec = rt.invoke_hedged("a", "b", 7, t_arrival=rt.clock + 1)
    assert out == 7
    assert len(rt.records) - n_recs == 1    # one LOGICAL record
    assert rt.ledger.invocations - n_inv == 2   # both legs billed
    assert rt.ledger.hedge_invocations == 1 and rt.ledger.hedge_gb_seconds > 0
    # cold primary loses to the warm replica; the record carries the winner
    assert rec.hedged and rec.fn == "b" and rec.backup_fn == "a"
    assert not rec.cold
    assert rec.loser_latency_s > rec.latency_s


# -- scatter layer -----------------------------------------------------------


def test_merge_cost_charged_consistently_single_and_batch(corpus, queries):
    app = build_partitioned_search_app(corpus, n_parts=N_PARTS)
    sc = app.scatter
    assert sc.merge_cost_s == MERGE_COST_S > 0
    payload = {"q": queries[0], "k": K, "fetch_docs": False}
    _, lat, recs = sc.search(payload, K, t_arrival=app.runtime.clock + 1)
    assert lat == pytest.approx(
        max(r.latency_s for r in recs) + sc.merge_cost_s)
    bpayload = {"queries": list(queries[:4]), "k": K, "fetch_docs": False}
    _, blat, brecs = sc.search_batch(bpayload, K,
                                     t_arrival=app.runtime.clock + 1)
    assert blat == pytest.approx(
        max(r.latency_s for r in brecs) + sc.merge_cost_s)


def test_policy_needs_history_before_quantile_hedging():
    rt = FaaSRuntime(RuntimeConfig())
    rt.register("p", _sleepy_handler)
    rt.register("r", _sleepy_handler)
    pol = HedgePolicy(percentile=0.95, min_history=4)
    assert pol.threshold_s(rt, ["p", "r"]) is None     # no basis yet
    rt.invoke("p", 0)                                  # cold — still no basis
    assert pol.threshold_s(rt, ["p", "r"]) is None
    for i in range(4):
        rt.invoke("p", i, t_arrival=rt.clock + 1)      # warm history
    thresh = pol.threshold_s(rt, ["p", "r"])
    assert thresh is not None and 0 < thresh < rt.config.provision_s
    # fixed-threshold policies need no history at all
    assert HedgePolicy(after_s=0.05).threshold_s(rt, ["p", "r"]) == 0.05
    # scatter over a cold fleet with a fresh quantile policy fires NO backups
    rt2 = FaaSRuntime(RuntimeConfig())
    rt2.register("p", _sleepy_handler)
    rt2.register("r", _sleepy_handler)
    sc2 = ScatterGather(rt2, [["p", "r"]], hedge=HedgePolicy())
    _, _, recs = sc2.scatter({"x": 1})
    assert not any(r.hedged for r in recs)
    assert rt2.ledger.hedge_invocations == 0


# -- app layer ---------------------------------------------------------------


def test_replicas_share_segment_but_not_pools(corpus):
    app = build_partitioned_search_app(corpus, n_parts=N_PARTS, replicas=2)
    assert app.replicas == 2
    assert len(app.assets) == N_PARTS          # each segment published ONCE
    assert [len(g) for g in app.fn_groups] == [2] * N_PARTS
    assert app.fn_names == [g[0] for g in app.fn_groups]
    recs = app.warm()
    assert len(recs) == 2 * N_PARTS
    assert all(r.cold and r.hydrate_s > 0 for r in recs)   # per-pool hydration
    # every function got its own instance (separate pools, shared asset)
    assert app.runtime.fleet_size == 2 * N_PARTS


def _drive(app, queries, kill_fn=None, kill_every=6):
    """Warm phase (unmeasured) then a measured phase with cold injection."""
    app.warm()
    for q in queries[:8]:
        app.query(q, k=K, t_arrival=app.runtime.clock + 0.05,
                  fetch_docs=False)
    app.runtime.records.clear()               # steady state starts here
    out = []
    for i, q in enumerate(queries[8:]):
        # first kill lands only after the cleared record log regrows the
        # policy's min_history of warm latencies
        if kill_fn is not None and i % kill_every == kill_every - 1:
            assert app.runtime.kill_instance(fn=kill_fn)
        r = app.query(q, k=K, t_arrival=app.runtime.clock + 0.05,
                      fetch_docs=False)
        assert r.ok, r.body
        out.append((tuple(r.body["ext_ids"]),
                    tuple(round(s, 6) for s in r.body["scores"])))
    return out


def test_hedging_flattens_p99_and_keeps_topk_exact(corpus, queries, oracle):
    plain = build_partitioned_search_app(corpus, n_parts=N_PARTS)
    hedged = build_partitioned_search_app(
        corpus, n_parts=N_PARTS, replicas=2, hedge=HedgePolicy())
    res_plain = _drive(plain, queries, kill_fn=plain.fn_names[0])
    res_hedged = _drive(hedged, queries, kill_fn=hedged.fn_names[0])

    # identical PackedIndex behind every replica ⇒ bit-identical merged top-k
    assert res_hedged == res_plain
    for q, (ext_ids, scores) in zip(queries[8:], res_hedged):
        want = oracle.search(q, k=K)
        assert len(ext_ids) >= min(len(want), K)
        for (wd, ws), gs in zip(want, scores):
            assert gs == pytest.approx(ws, rel=2e-4), q

    # backups actually fired, and only on the cold-injected partition's group
    hedge_recs = [r for r in hedged.runtime.records if r.hedged]
    assert hedge_recs
    assert {r.fn for r in hedge_recs} <= set(hedged.fn_groups[0])
    assert all(r.loser_latency_s > r.latency_s for r in hedge_recs)

    # the tail: every injected cold start sets p99 unhedged; hedged, the
    # warm replica wins and p99 stays in the warm band (>> the 30% target)
    p_plain = plain.runtime.latency_percentiles(qs=(0.99,))[0.99]
    p_hedged = hedged.runtime.latency_percentiles(qs=(0.99,))[0.99]
    assert p_hedged < 0.5 * p_plain
    # same story end-to-end at the gateway (proxy + merge + fetch included)
    gw_plain = plain.gateway.latency_percentiles("GET", "/search")[0.99]
    gw_hedged = hedged.gateway.latency_percentiles("GET", "/search")[0.99]
    assert gw_hedged < gw_plain


def test_total_cost_strictly_increases_with_replication(corpus, queries):
    from repro.search.searcher import SearchConfig
    dollars = []
    for R in (1, 2, 3):
        # modeled exec clock: the STRICT dollar ordering below compares
        # costs dominated by a few hedged legs' exec time — measured wall
        # time makes that a coin flip under host load (jit/GC noise
        # between the R runs), the model makes it a theorem
        app = build_partitioned_search_app(
            corpus, n_parts=N_PARTS, replicas=R,
            hedge=HedgePolicy() if R > 1 else None,
            search_config=SearchConfig(sim_exec_s=0.002))
        _drive(app, queries, kill_fn=app.fn_names[0])
        led = app.runtime.ledger
        assert (led.hedge_invocations > 0) == (R > 1)
        assert led.hedge_gb_seconds <= led.gb_seconds
        dollars.append(led.total_dollars)
    assert dollars[0] < dollars[1] < dollars[2]


# -- hedged-leg retries: attribution stays honest ----------------------------


class _ScriptedRng:
    """Deterministic stand-in for the runtime's failure-injection RNG."""

    def __init__(self, draws):
        self.draws = list(draws)

    def random(self):
        return self.draws.pop(0)


def test_hedged_leg_retry_bills_as_hedge_not_serving():
    """When a hedged call's BACKUP leg dies and is client-side retried, the
    retry must bill on the hedge line — a retry that forgot its attribution
    flag would shift hedge tax onto the serving line and make
    ``attribution()`` lie about what tail mitigation costs."""
    rt = FaaSRuntime(RuntimeConfig(failure_rate=0.5, seed=0))
    rt.register("a", lambda cache, p: ("a", 0.010))
    rt.register("b", lambda cache, p: ("b", 0.010))
    # primary survives; backup dies once, then its retry survives
    rt._rng = _ScriptedRng([0.9, 0.1, 0.9])
    led = rt.ledger
    res, rec = rt.invoke_hedged("a", "b", {}, t_arrival=0.0)
    assert rec.hedged
    # the dead attempt billed NOTHING (failure fires before any charge);
    # the retried backup kept its hedge flag
    assert led.invocations == 2
    assert led.hedge_invocations == 1
    assert led.hedge_gb_seconds > 0.0
    att = led.attribution()
    assert sum(att.values()) == pytest.approx(led.compute_dollars)
    # the serving line carries exactly the primary leg, not the retry
    serving_gbs = (led.gb_seconds - led.hedge_gb_seconds
                   - led.idle_gb_seconds - led.write_gb_seconds)
    assert serving_gbs == pytest.approx(led.hedge_gb_seconds)  # legs equal


def test_hedged_call_survives_when_one_leg_exhausts_retries():
    """A leg whose bounded retries all land on dying instances must not
    sink the hedged call — the surviving sibling's result is the whole
    point of sending two legs."""
    rt = FaaSRuntime(RuntimeConfig(failure_rate=0.5, max_retries=2, seed=0))
    rt.register("a", lambda cache, p: ("a", 0.010))
    rt.register("b", lambda cache, p: ("b", 0.010))
    # primary's 3 attempts all die; backup survives first try
    rt._rng = _ScriptedRng([0.1, 0.1, 0.1, 0.9])
    res, rec = rt.invoke_hedged("a", "b", {}, t_arrival=0.0)
    assert res == "b"
    assert rec.hedged and rec.fn == "b" and rec.backup_fn == "a"
    assert rec.loser_latency_s == float("inf")
    # only the surviving (hedge) leg billed
    assert rt.ledger.invocations == 1
    assert rt.ledger.hedge_invocations == 1
    # both legs dead -> the typed exhaustion error surfaces
    rt._rng = _ScriptedRng([0.1] * 6)
    from repro.core.runtime import RetriesExhausted
    with pytest.raises(RetriesExhausted):
        rt.invoke_hedged("a", "b", {}, t_arrival=1.0)
