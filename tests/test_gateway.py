"""The gateway's admission queue + adaptive micro-batch window.

The contract under test, layer by layer:

* policy — the window is sized from the trailing arrival rate
  (``target_batch / rate`` capped at ``max_window_s``), clamped by the
  route's p99 budget, and ZERO under sparse traffic, so a lone query never
  waits on a window no second query will join.
* gateway — ``submit`` coalesces concurrent arrivals into ONE batch
  dispatch per window; a submission past the open window's close flushes
  it first; ``max_batch`` hard-flushes; malformed bodies 400 at admission
  without dispatching anything.
* app — the windowed path's merged top-k is BIT-IDENTICAL to serial
  dispatch (and the oracle); duplicate query strings in one window each
  get a full result; a commit landing inside an open window splits the
  flush into per-generation scatters, every response matching its OWN
  generation's from-scratch oracle rebuild.
"""

import pytest

from repro.core.gateway import (GATEWAY_OVERHEAD_S, Gateway, PendingResponse,
                                WindowPolicy)
from repro.core.runtime import FaaSRuntime, RuntimeConfig
from repro.data.corpus import synth_corpus, synth_queries
from repro.search.oracle import OracleSearcher
from repro.search.searcher import SearchConfig
from repro.search.service import build_partitioned_search_app

K = 10
N_PARTS = 3


@pytest.fixture(scope="module")
def corpus():
    return synth_corpus(260, vocab=400, seed=51)


@pytest.fixture(scope="module")
def queries(corpus):
    return synth_queries(corpus, 24, seed=53)


def _build(corpus, **kw):
    kw.setdefault("search_config", SearchConfig(sim_exec_s=0.002,
                                                sim_write_s=0.02))
    kw.setdefault("n_parts", N_PARTS)
    return build_partitioned_search_app(corpus, **kw)


# -- policy layer -------------------------------------------------------------


def test_window_sizing_rate_budget_and_sparse_collapse():
    pol = WindowPolicy(max_window_s=0.05, target_batch=8, sparse_qps=2.0,
                       p99_budget_s=0.300)
    nan = float("nan")
    # sparse traffic: zero window, a lone query never waits
    assert pol.window_s(0.0, nan) == 0.0
    assert pol.window_s(1.9, nan) == 0.0
    # sized from the rate: long enough to expect ~target_batch arrivals
    assert pol.window_s(400.0, nan) == pytest.approx(8 / 400.0)
    # capped at max_window_s
    assert pol.window_s(10.0, nan) == pytest.approx(0.05)
    # clamped by the p99 budget: the added wait may not breach it
    assert pol.window_s(400.0, 0.290) == pytest.approx(0.010)
    assert pol.window_s(400.0, 0.350) == 0.0
    # no budget configured -> no clamp
    assert WindowPolicy(p99_budget_s=None).window_s(400.0, 9.9) > 0


# -- gateway layer ------------------------------------------------------------


def test_submit_without_batch_route_dispatches_immediately():
    rt = FaaSRuntime(RuntimeConfig())
    rt.register("f", lambda cache, p: (p, 0.001))
    gw = Gateway(rt)
    gw.route("GET", "/x", "f")
    h = gw.submit("GET", "/x", 7, t_arrival=1.0)
    assert isinstance(h, PendingResponse) and h.done()
    assert h.response.ok and h.response.body == 7


def test_window_coalesces_one_invocation_per_partition(corpus, queries):
    app = _build(corpus)
    app.warm()
    for q in queries[:4]:                      # rate history
        app.query(q, k=K, t_arrival=app.runtime.clock + 0.05,
                  fetch_docs=False)
    t0 = app.runtime.clock + 1.0
    n0 = len(app.runtime.records)
    # 6 arrivals 5 ms apart: rate >= sparse_qps from the 2nd on; the 1st
    # dispatches alone (no rate basis yet), the rest share ONE window
    hs = [app.submit(q, k=K, t_arrival=t0 + 0.005 * i, fetch_docs=False)
          for i, q in enumerate(queries[:6])]
    assert not hs[-1].done()                   # window still open
    app.flush()
    assert all(h.done() and h.response.ok for h in hs)
    ws = app.gateway.window_stats("GET", "/search")
    assert ws["batches"] == 2 and ws["mean_batch"] == 3.0
    # the 5-query window cost ONE invocation per partition, not five
    recs = [r for r in app.runtime.records[n0:] if not r.keepalive]
    assert len(recs) == 2 * N_PARTS
    # reading an unresolved handle is a driver bug, loudly
    h_open = app.submit(queries[0], k=K,
                        t_arrival=app.runtime.clock + 0.004)
    h_open2 = app.submit(queries[1], k=K,
                         t_arrival=app.runtime.clock + 0.008)
    if not h_open.done():
        with pytest.raises(RuntimeError, match="window still open"):
            _ = h_open.response
    app.flush()
    assert h_open.done() and h_open2.done()


def test_windowed_results_bit_identical_to_serial_and_oracle(corpus, queries):
    serial = _build(corpus)
    windowed = _build(corpus)
    for app in (serial, windowed):
        app.warm()
        for q in queries[:4]:
            app.query(q, k=K, t_arrival=app.runtime.clock + 0.05,
                      fetch_docs=False)
    res_serial = [serial.query(q, k=K,
                               t_arrival=serial.runtime.clock + 0.05,
                               fetch_docs=False)
                  for q in queries]
    t0 = windowed.runtime.clock + 1.0
    hs = [windowed.submit(q, k=K, t_arrival=t0 + 0.004 * i,
                          fetch_docs=False)
          for i, q in enumerate(queries)]
    windowed.flush()
    assert windowed.gateway.window_stats("GET", "/search")["mean_batch"] > 1
    oracle = OracleSearcher(corpus)
    for q, h, r in zip(queries, hs, res_serial):
        assert h.response.ok
        assert h.response.body["ext_ids"] == r.body["ext_ids"]
        assert [round(s, 9) for s in h.response.body["scores"]] == \
            [round(s, 9) for s in r.body["scores"]]
        want = [oracle.doc_ids[i] for i, _ in oracle.search(q, k=K)]
        assert h.response.body["ext_ids"] == want
    # the window's latency accounting is explicit: earlier arrivals in a
    # window waited for its close, and that wait is IN their latency
    ws = windowed.gateway.window_stats("GET", "/search")
    assert ws["max_wait_s"] > 0


def test_sparse_submit_equals_query_latency_exactly(corpus, queries):
    """The no-added-latency contract: under sparse traffic the window is
    zero and a submitted query's latency equals the serial path's to the
    last bit."""
    a, b = _build(corpus), _build(corpus)
    for app in (a, b):
        app.warm()
    for q in queries[:6]:
        t_a = a.runtime.clock + 30.0           # < sparse_qps either way
        r = a.query(q, k=K, t_arrival=t_a, fetch_docs=False)
        h = b.submit(q, k=K, t_arrival=b.runtime.clock + 30.0,
                     fetch_docs=False)
        assert h.done()                        # resolved AT its own arrival
        assert h.response.latency_s == pytest.approx(r.latency_s, abs=0.0)
        assert h.response.body["ext_ids"] == r.body["ext_ids"]
    assert b.gateway.window_stats("GET", "/search")["max_wait_s"] == 0.0


def test_max_batch_hard_flush(corpus, queries):
    app = _build(corpus, window=WindowPolicy(max_window_s=10.0,
                                             target_batch=64, sparse_qps=0.0,
                                             p99_budget_s=None, max_batch=4))
    app.warm()
    t0 = app.runtime.clock + 1.0
    hs = [app.submit(q, k=K, t_arrival=t0 + 1e-4 * i, fetch_docs=False)
          for i, q in enumerate(queries[:4])]
    # the 4th admission hits max_batch and flushes without waiting out
    # the (10 s!) window
    assert all(h.done() for h in hs)
    assert app.gateway.window_stats("GET", "/search")["batches"] == 1


# -- malformed bodies: 400 at the edge, nothing dispatched --------------------


def test_empty_batch_400s_cleanly_on_both_paths(corpus):
    app = _build(corpus)
    app.warm()
    n_inv = app.runtime.ledger.invocations
    # serial path
    r = app.query([], k=K, t_arrival=app.runtime.clock + 1.0)
    assert r.status == 400 and "queries" in r.body["error"]
    # windowed path: rejected at ADMISSION, never occupies the window
    h = app.submit([], k=K, t_arrival=app.runtime.clock + 2.0)
    assert h.done() and h.response.status == 400
    ws = app.gateway.window_stats("GET", "/search")
    assert ws["batches"] == 0
    # neither path dispatched (or billed) anything
    assert app.runtime.ledger.invocations == n_inv
    # a well-formed request on the same route still works
    r = app.query("hello", k=K, t_arrival=app.runtime.clock + 3.0,
                  fetch_docs=False)
    assert r.ok


def test_duplicate_queries_in_batch_do_not_collapse(corpus, queries):
    app = _build(corpus)
    app.warm()
    q = queries[0]
    # one body carrying duplicates: every slot gets its own full result
    r = app.query([q, q, queries[1]], k=K,
                  t_arrival=app.runtime.clock + 1.0, fetch_docs=False)
    assert r.ok and len(r.body["results"]) == 3
    assert r.body["results"][0]["ext_ids"] == r.body["results"][1]["ext_ids"]
    assert r.body["results"][0]["scores"] == r.body["results"][1]["scores"]
    assert r.body["results"][0]["ext_ids"]            # non-empty
    # duplicates across one admission window: both handles resolve fully
    t0 = app.runtime.clock + 1.0
    app.submit(queries[2], k=K, t_arrival=t0, fetch_docs=False)
    h1 = app.submit(q, k=K, t_arrival=t0 + 0.003, fetch_docs=False)
    h2 = app.submit(q, k=K, t_arrival=t0 + 0.006, fetch_docs=False)
    app.flush()
    assert h1.response.ok and h2.response.ok
    assert h1.response.body["ext_ids"] == h2.response.body["ext_ids"] \
        == r.body["results"][0]["ext_ids"]


# -- generation pinning at admission ------------------------------------------


def test_commit_inside_open_window_splits_by_generation(corpus, queries):
    """A commit landing while the window is open must not move admitted
    queries to the new index: the flush dispatches one single-generation
    scatter per pinned generation, and each response matches ITS OWN
    generation's oracle rebuild."""
    app = _build(corpus, n_parts=2)
    extra = [(f"new-{i}", t) for i, (_, t) in enumerate(corpus[:30])]
    app.warm()
    for q in queries[:4]:
        app.query(q, k=K, t_arrival=app.runtime.clock + 0.05,
                  fetch_docs=False)
    old_corpus = list(app.indexer.live_corpus())
    t0 = app.runtime.clock + 1.0
    pre = [app.submit(q, k=K, t_arrival=t0 + 0.004 * i, fetch_docs=False)
           for i, q in enumerate(queries[:4])]
    r = app.commit(t_arrival=t0 + 0.016)       # nothing staged: no-op commit
    assert r.ok and r.body["committed"] is False
    app.add_documents(extra, t_arrival=t0 + 0.017)
    r = app.commit(t_arrival=t0 + 0.018)
    assert r.ok and r.body["gen"] == 2
    post = [app.submit(q, k=K, t_arrival=t0 + 0.02 + 0.004 * i,
                       fetch_docs=False)
            for i, q in enumerate(queries[4:8])]
    app.flush()
    assert {h.response.body["generation"] for h in pre} == {1}
    assert {h.response.body["generation"] for h in post} == {2}
    o_old = OracleSearcher(old_corpus)
    o_new = OracleSearcher(app.indexer.live_corpus())
    for h, q in zip(pre, queries[:4]):
        assert h.response.body["ext_ids"] == \
            [o_old.doc_ids[i] for i, _ in o_old.search(q, k=K)]
    for h, q in zip(post, queries[4:8]):
        assert h.response.body["ext_ids"] == \
            [o_new.doc_ids[i] for i, _ in o_new.search(q, k=K)]


# -- misc gateway envelope ----------------------------------------------------


def test_unknown_route_404_and_overhead_charged(corpus):
    app = _build(corpus)
    assert app.gateway.request("GET", "/nope").status == 404
    r = app.query("anything", k=K, t_arrival=app.runtime.clock + 1.0,
                  fetch_docs=False)
    assert r.ok and r.latency_s > GATEWAY_OVERHEAD_S


def test_flush_is_idempotent(corpus, queries):
    app = _build(corpus)
    app.warm()
    assert app.flush() == 0                    # nothing pending: no-op
    h = app.submit(queries[0], k=K, t_arrival=app.runtime.clock + 5.0,
                   fetch_docs=False)
    assert h.done()                            # sparse -> immediate
    assert app.flush() == 0


# -- admission backpressure (overload shedding) -------------------------------


def test_max_batch_boundary_dispatches_once_and_resets_rate(corpus, queries):
    """A submit landing EXACTLY at max_batch must dispatch the batch once —
    neither zero times (waiting out a window that will never fill further)
    nor twice — and the flushed burst's arrivals must not leak into the
    NEXT window's rate estimate (a spike-sized estimate would collapse the
    reopened window toward zero and re-flush instantly)."""
    app = _build(corpus, window=WindowPolicy(
        max_window_s=10.0, target_batch=8, sparse_qps=2.0,
        p99_budget_s=None, rate_window_s=1.0, max_batch=4))
    app.warm()
    key = ("GET", "/search")
    coord, admit = app.gateway._batched[key]
    dispatches = []

    def counting(bodies, arrivals, t_dispatch):
        dispatches.append(len(bodies))
        return coord(bodies, arrivals, t_dispatch)

    app.gateway._batched[key] = (counting, admit)
    t0 = app.runtime.clock + 1.0
    # the first arrival reads as sparse (rate not yet built) and goes out
    # alone; the next four land inside one window and fill it to the cap
    hs = [app.submit(queries[i], k=K, t_arrival=t0 + 1e-4 * i,
                     fetch_docs=False)
          for i in range(5)]
    # the capped window dispatched exactly ONCE, with exactly max_batch
    assert dispatches == [1, 4]
    assert all(h.done() for h in hs)
    # the reopened window's size estimate must not inherit the burst: the
    # trailing-rate history restarts from just the dispatch instant (not
    # empty — a falsely-sparse solo dispatch would soft-reset the
    # backpressure streak under sustained overload)
    q = app.gateway._queues[key]
    assert q.arrivals == [pytest.approx(t0 + 4e-4)]
    h = app.submit(queries[5], k=K, t_arrival=t0 + 0.010, fetch_docs=False)
    # the follow-up's window is sized from the calm restart rate (2 within
    # rate_window_s: the reseed + itself -> target_batch/2), NOT the ~5-qps
    # spike (which would shrink it to target_batch/5)
    assert not h.done()
    assert q.window_close - (t0 + 0.010) == pytest.approx(8 / 2.0)
    app.flush()
    assert h.done() and h.response.ok
    assert dispatches == [1, 4, 1]


def _bp_policy(threshold=2):
    from repro.core.gateway import BackpressurePolicy
    return WindowPolicy(
        max_window_s=10.0, target_batch=64, sparse_qps=0.0,
        p99_budget_s=None, max_batch=4,
        backpressure=BackpressurePolicy(
            consecutive_hard_flushes=threshold, drain_window_s=1.0,
            min_retry_after_s=0.050, max_retry_after_s=2.0))


def test_backpressure_sheds_with_retry_after_and_bills_nothing(corpus,
                                                               queries):
    """Past the consecutive-hard-flush threshold the route sheds: 429 with
    an honest Retry-After, counted on the ledger's shed line, dispatched
    nowhere, billed to nothing."""
    app = _build(corpus, window=_bp_policy(threshold=2))
    app.warm()
    led = app.runtime.ledger
    q = app.gateway._queues[("GET", "/search")]
    t0 = app.runtime.clock + 1.0
    # two back-to-back max_batch bursts -> two consecutive hard flushes
    for i in range(8):
        app.submit(queries[i % len(queries)], k=K, t_arrival=t0 + 1e-4 * i,
                   fetch_docs=False)
    assert q.shed_until > t0            # threshold tripped
    inv_before = led.invocations
    t_shed = t0 + 0.001
    h = app.submit(queries[0], k=K, t_arrival=t_shed, fetch_docs=False)
    assert h.done() and h.response.status == 429
    retry_after = h.response.body["retry_after_s"]
    assert retry_after == pytest.approx(q.shed_until - t_shed)
    assert retry_after >= 0.050
    # billed to NOTHING: no invocation, no GB·s — just the shed count
    assert led.invocations == inv_before
    assert led.shed_requests == 1 and led.shed_gb_seconds == 0.0
    assert app.gateway.window_stats("GET", "/search")["sheds"] == 1
    # recovery: an arrival past the shed horizon is admitted and served
    h2 = app.submit(queries[1], k=K, t_arrival=q.shed_until + 0.01,
                    fetch_docs=False)
    app.flush()
    assert h2.response.ok and h2.response.body["ext_ids"]
    assert led.shed_requests == 1       # no further sheds


def test_backpressure_soft_flush_resets_hard_streak(corpus, queries):
    """A window that closes WITHOUT hitting max_batch proves the arrival
    process fits the pipe again — the consecutive-hard-flush streak must
    reset, so an isolated burst never pushes a healthy route into
    shedding."""
    app = _build(corpus, window=_bp_policy(threshold=2))
    app.warm()
    q = app.gateway._queues[("GET", "/search")]
    t0 = app.runtime.clock + 1.0
    for i in range(4):                  # ONE hard flush
        app.submit(queries[i], k=K, t_arrival=t0 + 1e-4 * i,
                   fetch_docs=False)
    assert q.hard_flushes == 1 and q.shed_until == 0.0
    # a soft (window-timed) flush in between resets the streak
    app.submit(queries[4], k=K, t_arrival=t0 + 0.02, fetch_docs=False)
    app.submit(queries[5], k=K, t_arrival=t0 + 0.021, fetch_docs=False)
    app.flush()
    assert q.hard_flushes == 0
    # the next burst is the FIRST of a new streak: still no shedding
    t1 = t0 + 1.0
    for i in range(4):
        app.submit(queries[i], k=K, t_arrival=t1 + 1e-4 * i,
                   fetch_docs=False)
    assert q.hard_flushes == 1 and q.shed_until == 0.0
    assert app.runtime.ledger.shed_requests == 0
