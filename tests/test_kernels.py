"""Per-kernel allclose sweeps: Pallas (interpret=True) vs pure-jnp oracles.

Every kernel is swept over shapes and dtypes and asserted against its
ref.py oracle, per the assignment contract.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand(key, shape, dtype):
    if dtype == jnp.uint8:
        return jax.random.randint(key, shape, 0, 20).astype(jnp.uint8)
    if jnp.issubdtype(dtype, jnp.integer):
        return jax.random.randint(key, shape, 0, 100).astype(dtype)
    return jax.random.normal(key, shape).astype(dtype)


# -- BM25 impact kernel --------------------------------------------------------


@pytest.mark.parametrize("T,M,B", [(1, 1, 128), (4, 8, 128), (16, 3, 128),
                                   (7, 5, 128)])
def test_bm25_block_scores(T, M, B):
    key = jax.random.PRNGKey(T * 100 + M)
    tf = _rand(key, (T, M, B), jnp.uint8)
    dl = jax.random.uniform(key, (T, M, B), minval=1.0, maxval=200.0)
    idf = jax.random.uniform(key, (T,), minval=0.1, maxval=8.0)
    got = ops.bm25_block_scores(tf, dl, idf, 0.9, 0.4, 60.0, interpret=True)
    want = ref.bm25_block_scores_ref(tf, dl, idf, 0.9, 0.4, 60.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("block_rows", [1, 8, 32])
def test_bm25_block_rows_sweep(block_rows):
    key = jax.random.PRNGKey(0)
    tf = _rand(key, (5, 7, 128), jnp.uint8)
    dl = jax.random.uniform(key, (5, 7, 128), minval=1.0, maxval=100.0)
    idf = jax.random.uniform(key, (5,), minval=0.1, maxval=5.0)
    got = ops.bm25_block_scores(tf, dl, idf, 1.2, 0.75, 40.0,
                                block_rows=block_rows, interpret=True)
    want = ref.bm25_block_scores_ref(tf, dl, idf, 1.2, 0.75, 40.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-6)


# -- streaming top-k ------------------------------------------------------------


@pytest.mark.parametrize("N,k,chunk", [(1000, 10, 256), (16384, 100, 4096),
                                       (777, 5, 128), (128, 128, 128)])
def test_topk(N, k, chunk):
    scores = jax.random.normal(jax.random.PRNGKey(N), (N,))
    gv, gi = ops.topk(scores, k, chunk=chunk, interpret=True)
    wv, wi = ref.topk_ref(scores, k)
    np.testing.assert_allclose(np.asarray(gv), np.asarray(wv), rtol=1e-6)
    # ids must point at equal scores (ties may reorder)
    np.testing.assert_allclose(np.asarray(scores)[np.asarray(gi)],
                               np.asarray(wv), rtol=1e-6)


def test_topk_with_ties_and_negatives():
    scores = jnp.concatenate([jnp.full(100, -5.0), jnp.full(50, 2.0),
                              jnp.arange(20, dtype=jnp.float32)])
    gv, gi = ops.topk(scores, 30, chunk=64, interpret=True)
    wv, _ = ref.topk_ref(scores, 30)
    np.testing.assert_allclose(np.asarray(gv), np.asarray(wv), rtol=1e-6)


# -- fused dot + top-k (retrieval) ------------------------------------------------


@pytest.mark.parametrize("N,D,k", [(1000, 16, 10), (4096, 64, 100),
                                   (513, 32, 7)])
def test_dot_topk(N, D, k):
    key = jax.random.PRNGKey(N + D)
    q = jax.random.normal(key, (D,))
    c = jax.random.normal(jax.random.fold_in(key, 1), (N, D))
    gv, gi = ops.dot_topk(q, c, k, interpret=True)
    wv, wi = ref.dot_topk_ref(q, c, k)
    np.testing.assert_allclose(np.asarray(gv), np.asarray(wv), rtol=1e-4,
                               atol=1e-4)
    scores = np.asarray(c) @ np.asarray(q)
    np.testing.assert_allclose(scores[np.asarray(gi)], np.asarray(wv),
                               rtol=1e-4, atol=1e-4)


# -- embedding bag -----------------------------------------------------------------


@pytest.mark.parametrize("V,D,B,L", [(64, 8, 4, 3), (1000, 32, 16, 10),
                                     (50, 128, 7, 5)])
def test_embedding_bag_kernel(V, D, B, L):
    key = jax.random.PRNGKey(V)
    table = jax.random.normal(key, (V, D))
    idx = jax.random.randint(jax.random.fold_in(key, 1), (B, L), -1, V)
    w = jax.random.normal(jax.random.fold_in(key, 2), (B, L))
    got = ops.embedding_bag(table, idx, w, interpret=True)
    want = ref.embedding_bag_ref(table, idx, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                               atol=1e-5)


# -- flash attention ---------------------------------------------------------------


@pytest.mark.parametrize("B,Hq,Hkv,Sq,Skv,D", [
    (1, 2, 2, 128, 128, 32),       # MHA square
    (2, 4, 2, 128, 128, 64),       # GQA
    (1, 8, 1, 128, 256, 32),       # MQA, longer kv
    (2, 4, 4, 1, 384, 64),         # decode (Sq=1)
])
@pytest.mark.parametrize("causal", [False, True])
def test_flash_attention(B, Hq, Hkv, Sq, Skv, D, causal):
    if causal and Sq not in (Skv, 1):
        pytest.skip("causal requires aligned positions")
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, Hq, Sq, D), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Hkv, Skv, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Hkv, Skv, D))
    got = ops.flash_attention(q, k, v, causal=causal, interpret=True)
    want = ref.mha_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3,
                               atol=2e-3)


def test_flash_attention_window():
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (1, 2, 256, 32))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 256, 32))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 256, 32))
    got = ops.flash_attention(q, k, v, causal=True, window=64, interpret=True)
    want = ref.mha_attention_ref(q, k, v, causal=True, window=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3,
                               atol=2e-3)


def test_flash_attention_kv_len_mask():
    key = jax.random.PRNGKey(4)
    q = jax.random.normal(key, (2, 2, 1, 32))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 2, 512, 32))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 2, 512, 32))
    got = ops.flash_attention(q, k, v, kv_len=100, interpret=True)
    want = ref.mha_attention_ref(q, k, v, kv_len=100)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3,
                               atol=2e-3)


def test_flash_attention_mla_vdim():
    """v head dim ≠ qk head dim (MLA-style)."""
    key = jax.random.PRNGKey(5)
    q = jax.random.normal(key, (1, 4, 128, 48))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 4, 128, 48))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 4, 128, 32))
    got = ops.flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.mha_attention_ref(q, k, v, causal=True)
    assert got.shape == (1, 4, 128, 32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3,
                               atol=2e-3)


def test_flash_vs_chunked_attention():
    """The two attention impls agree (chunked is the model default)."""
    from repro.models.attention import chunked_attention
    key = jax.random.PRNGKey(6)
    q = jax.random.normal(key, (2, 4, 256, 32))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 2, 256, 32))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 2, 256, 32))
    a = ops.flash_attention(q, k, v, causal=True, interpret=True)
    b = chunked_attention(q, k, v, causal=True, block_q=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3,
                               atol=2e-3)
