"""Per-kernel allclose sweeps: Pallas (interpret=True) vs pure-jnp oracles.

Every kernel is swept over shapes and dtypes and asserted against its
ref.py oracle, per the assignment contract.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand(key, shape, dtype):
    if dtype == jnp.uint8:
        return jax.random.randint(key, shape, 0, 20).astype(jnp.uint8)
    if jnp.issubdtype(dtype, jnp.integer):
        return jax.random.randint(key, shape, 0, 100).astype(dtype)
    return jax.random.normal(key, shape).astype(dtype)


# -- BM25 impact kernel --------------------------------------------------------


@pytest.mark.parametrize("T,M,B", [(1, 1, 128), (4, 8, 128), (16, 3, 128),
                                   (7, 5, 128)])
def test_bm25_block_scores(T, M, B):
    key = jax.random.PRNGKey(T * 100 + M)
    tf = _rand(key, (T, M, B), jnp.uint8)
    dl = jax.random.uniform(key, (T, M, B), minval=1.0, maxval=200.0)
    idf = jax.random.uniform(key, (T,), minval=0.1, maxval=8.0)
    got = ops.bm25_block_scores(tf, dl, idf, 0.9, 0.4, 60.0, interpret=True)
    want = ref.bm25_block_scores_ref(tf, dl, idf, 0.9, 0.4, 60.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("block_rows", [1, 8, 32])
def test_bm25_block_rows_sweep(block_rows):
    key = jax.random.PRNGKey(0)
    tf = _rand(key, (5, 7, 128), jnp.uint8)
    dl = jax.random.uniform(key, (5, 7, 128), minval=1.0, maxval=100.0)
    idf = jax.random.uniform(key, (5,), minval=0.1, maxval=5.0)
    got = ops.bm25_block_scores(tf, dl, idf, 1.2, 0.75, 40.0,
                                block_rows=block_rows, interpret=True)
    want = ref.bm25_block_scores_ref(tf, dl, idf, 1.2, 0.75, 40.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-6)


# -- fused block-max pruned scoring + top-k ---------------------------------------


def _pruned_args(seed, T, M, n_docs, zipf_a=2.0):
    from repro.data.corpus import synth_pruned_blocks
    a = synth_pruned_blocks(seed, n_terms=T, max_blocks=M, n_docs=n_docs,
                            zipf_a=zipf_a)
    return tuple(map(jnp.asarray, a))


_F32 = (jnp.float32(0.9), jnp.float32(0.4), jnp.float32(12.0))


def _assert_bitwise(got, want):
    gv, gi = np.asarray(got[0]), np.asarray(got[1])
    wv, wi = np.asarray(want[0]), np.asarray(want[1])
    assert np.array_equal(gv.view(np.uint32), wv.view(np.uint32)), \
        f"vals not bit-identical: {gv} vs {wv}"
    assert np.array_equal(gi, wi), f"ids differ: {gi} vs {wi}"


@pytest.mark.parametrize("T,M,n_docs,k", [
    (1, 1, 200, 10), (4, 6, 900, 10), (8, 4, 2000, 25), (2, 8, 1024, 5),
    (5, 8, 4000, 50),
])
@pytest.mark.parametrize("zipf_a", [1.3, 4.0])
def test_bm25_pruned_topk_bitwise(T, M, n_docs, k, zipf_a):
    """Pruned fused kernel == UNPRUNED dense ref, bit-for-bit (losslessness)."""
    args = _pruned_args(T * 31 + M, T, M, n_docs, zipf_a)
    gv, gi, _ = ops.bm25_pruned_topk(*args, *_F32, k=k, n_docs=n_docs,
                                     interpret=True)
    want = ref.bm25_pruned_topk_ref(*args, *_F32, k=k, n_docs=n_docs)
    _assert_bitwise((gv, gi), want)


def test_bm25_pruned_actually_prunes():
    """Single-term query over impact-skewed blocks: later blocks' ceilings
    fall below θ from the first block, so touched < valid — the kernel must
    skip work, not just match the oracle — while staying bit-identical."""
    args = _pruned_args(13, 1, 8, 4000, zipf_a=1.3)
    n_valid = int(np.asarray(args[5]).sum())
    gv, gi, touched = ops.bm25_pruned_topk(*args, *_F32, k=10, n_docs=4000,
                                           interpret=True)
    assert 0 < int(touched) < n_valid
    want = ref.bm25_pruned_topk_ref(*args, *_F32, k=10, n_docs=4000)
    _assert_bitwise((gv, gi), want)


def test_bm25_pruned_uniform_ties_and_exact_threshold():
    """Every posting identical → every block's bound EQUALS θ exactly;
    ties at the k boundary must resolve like lax.top_k (lowest ids), and
    the >=-keep rule must not drop the boundary blocks."""
    T, M, B, n_docs, k = 1, 8, 128, 1024, 16
    docs = np.arange(T * M * B, dtype=np.int32).reshape(T, M, B) % n_docs
    tf = np.ones((T, M, B), np.uint8)
    dl = np.full((T, M, B), 12.0, np.float32)    # == avgdl → norm term = 1
    idf_q = np.ones(T, np.float32)
    valid = np.ones((T, M), bool)
    # per-posting impact (f32 math, as the kernel computes it); with a
    # single term, bound(0, m) == ub == the impact == θ for every block
    one = np.float32(1.0) / (np.float32(1.0) + np.float32(0.9))
    ub = np.full((T, M), one, np.float32)    # block_max == the impact
    args = tuple(map(jnp.asarray, (tf, dl, docs, idf_q, ub, valid)))
    gv, gi, touched = ops.bm25_pruned_topk(*args, *_F32, k=k, n_docs=n_docs,
                                           interpret=True)
    want = ref.bm25_pruned_topk_ref(*args, *_F32, k=k, n_docs=n_docs)
    _assert_bitwise((gv, gi), want)
    assert int(touched) == T * M            # equality keeps, never skips


def test_bm25_pruned_tombstone_zeroed_blocks():
    """Blocks whose tf was zeroed (combine_segments tombstones) carry
    block_max 0 and impact 0 — pruned must stay bit-identical."""
    tf, dl, docs, idf_q, ub, valid = map(
        np.asarray, _pruned_args(11, 4, 6, 900, 2.0))
    tf, ub = tf.copy(), ub.copy()
    tf[1, 2] = 0                         # tombstone a mid-impact block
    ub[1, 2] = 0.0
    tf[3, 0] = 0                         # and a FIRST block (θ seed)
    ub[3, 0] = 0.0
    args = tuple(map(jnp.asarray, (tf, dl, docs, idf_q, ub, valid)))
    gv, gi, _ = ops.bm25_pruned_topk(*args, *_F32, k=10, n_docs=900,
                                     interpret=True)
    want = ref.bm25_pruned_topk_ref(*args, *_F32, k=10, n_docs=900)
    _assert_bitwise((gv, gi), want)


def test_bm25_pruned_fewer_postings_than_k():
    """T·B < k in phase 1 → θ must fall back to 0 (prune nothing) rather
    than overestimate from an under-full candidate set."""
    args = _pruned_args(3, 1, 2, 300, 2.0)
    n_valid = int(np.asarray(args[5]).sum())
    gv, gi, touched = ops.bm25_pruned_topk(*args, *_F32, k=200, n_docs=300,
                                           interpret=True)
    want = ref.bm25_pruned_topk_ref(*args, *_F32, k=200, n_docs=300)
    _assert_bitwise((gv, gi), want)
    assert int(touched) == n_valid


# -- streaming top-k ------------------------------------------------------------


@pytest.mark.parametrize("N,k,chunk", [(1000, 10, 256), (16384, 100, 4096),
                                       (777, 5, 128), (128, 128, 128)])
def test_topk(N, k, chunk):
    scores = jax.random.normal(jax.random.PRNGKey(N), (N,))
    gv, gi = ops.topk(scores, k, chunk=chunk, interpret=True)
    wv, wi = ref.topk_ref(scores, k)
    np.testing.assert_allclose(np.asarray(gv), np.asarray(wv), rtol=1e-6)
    # ids must point at equal scores (ties may reorder)
    np.testing.assert_allclose(np.asarray(scores)[np.asarray(gi)],
                               np.asarray(wv), rtol=1e-6)


def test_topk_with_ties_and_negatives():
    scores = jnp.concatenate([jnp.full(100, -5.0), jnp.full(50, 2.0),
                              jnp.arange(20, dtype=jnp.float32)])
    gv, gi = ops.topk(scores, 30, chunk=64, interpret=True)
    wv, _ = ref.topk_ref(scores, 30)
    np.testing.assert_allclose(np.asarray(gv), np.asarray(wv), rtol=1e-6)


@pytest.mark.parametrize("N,k,chunk", [(13, 6, 8), (5, 8, 4), (100, 40, 64),
                                       (129, 3, 128)])
def test_topk_pad_never_leaks(N, k, chunk):
    """Short final chunk: a padded lane (or an exhausted chunk when
    k > live elements) must emit the sentinel id N, never a padded index."""
    scores = jax.random.normal(jax.random.PRNGKey(N * 7 + k), (N,))
    gv, gi = ops.topk(scores, k, chunk=chunk, interpret=True)
    gi = np.asarray(gi)
    gv = np.asarray(gv)
    live = min(k, N)
    assert np.all(gi[:live] < N)                  # real hits: real indices
    wv, _ = ref.topk_ref(scores, live)
    np.testing.assert_allclose(gv[:live], np.asarray(wv), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(scores)[gi[:live]], gv[:live],
                               rtol=1e-6)
    if k > N:                                     # k > live: sentinel tail
        assert np.all(gi[N:] == N)
        assert np.all(gv[N:] == -np.inf)


def test_topk_k_exceeds_live_with_neg_inf_inputs():
    """Legit -inf scores count as absent too (the sorted accumulator's
    isfinite convention): with only 3 finite scores and k=6, slots 3+ are
    (-inf, N)."""
    scores = jnp.asarray([-jnp.inf, 2.0, -jnp.inf, 1.0, 3.0, -jnp.inf,
                          -jnp.inf])
    gv, gi = ops.topk(scores, 6, chunk=4, interpret=True)
    np.testing.assert_allclose(np.asarray(gv)[:3], [3.0, 2.0, 1.0])
    assert list(np.asarray(gi)[:3]) == [4, 1, 3]
    assert np.all(np.asarray(gi)[3:] == 7)
    assert np.all(np.asarray(gv)[3:] == -np.inf)


# -- interpret-mode selection -----------------------------------------------------


def test_interpret_defaults_to_backend():
    from repro.kernels.interpret import default_interpret, resolve_interpret
    import os
    assert jax.default_backend() == "cpu"     # this container
    assert default_interpret() is True
    assert resolve_interpret(None) is True
    assert resolve_interpret(False) is False  # explicit override wins
    assert resolve_interpret(True) is True
    old = os.environ.get("REPRO_PALLAS_INTERPRET")
    try:
        os.environ["REPRO_PALLAS_INTERPRET"] = "0"
        assert default_interpret() is False   # env overrides the backend
        assert resolve_interpret(None) is False
        assert resolve_interpret(True) is True
        os.environ["REPRO_PALLAS_INTERPRET"] = "1"
        assert default_interpret() is True
    finally:
        if old is None:
            os.environ.pop("REPRO_PALLAS_INTERPRET", None)
        else:
            os.environ["REPRO_PALLAS_INTERPRET"] = old


# -- fused dot + top-k (retrieval) ------------------------------------------------


@pytest.mark.parametrize("N,D,k", [(1000, 16, 10), (4096, 64, 100),
                                   (513, 32, 7)])
def test_dot_topk(N, D, k):
    key = jax.random.PRNGKey(N + D)
    q = jax.random.normal(key, (D,))
    c = jax.random.normal(jax.random.fold_in(key, 1), (N, D))
    gv, gi = ops.dot_topk(q, c, k, interpret=True)
    wv, wi = ref.dot_topk_ref(q, c, k)
    np.testing.assert_allclose(np.asarray(gv), np.asarray(wv), rtol=1e-4,
                               atol=1e-4)
    scores = np.asarray(c) @ np.asarray(q)
    np.testing.assert_allclose(scores[np.asarray(gi)], np.asarray(wv),
                               rtol=1e-4, atol=1e-4)


# -- embedding bag -----------------------------------------------------------------


@pytest.mark.parametrize("V,D,B,L", [(64, 8, 4, 3), (1000, 32, 16, 10),
                                     (50, 128, 7, 5)])
def test_embedding_bag_kernel(V, D, B, L):
    key = jax.random.PRNGKey(V)
    table = jax.random.normal(key, (V, D))
    idx = jax.random.randint(jax.random.fold_in(key, 1), (B, L), -1, V)
    w = jax.random.normal(jax.random.fold_in(key, 2), (B, L))
    got = ops.embedding_bag(table, idx, w, interpret=True)
    want = ref.embedding_bag_ref(table, idx, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                               atol=1e-5)


# -- flash attention ---------------------------------------------------------------


@pytest.mark.parametrize("B,Hq,Hkv,Sq,Skv,D", [
    (1, 2, 2, 128, 128, 32),       # MHA square
    (2, 4, 2, 128, 128, 64),       # GQA
    (1, 8, 1, 128, 256, 32),       # MQA, longer kv
    (2, 4, 4, 1, 384, 64),         # decode (Sq=1)
])
@pytest.mark.parametrize("causal", [False, True])
def test_flash_attention(B, Hq, Hkv, Sq, Skv, D, causal):
    if causal and Sq not in (Skv, 1):
        pytest.skip("causal requires aligned positions")
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, Hq, Sq, D), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Hkv, Skv, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Hkv, Skv, D))
    got = ops.flash_attention(q, k, v, causal=causal, interpret=True)
    want = ref.mha_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3,
                               atol=2e-3)


def test_flash_attention_window():
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (1, 2, 256, 32))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 256, 32))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 256, 32))
    got = ops.flash_attention(q, k, v, causal=True, window=64, interpret=True)
    want = ref.mha_attention_ref(q, k, v, causal=True, window=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3,
                               atol=2e-3)


def test_flash_attention_kv_len_mask():
    key = jax.random.PRNGKey(4)
    q = jax.random.normal(key, (2, 2, 1, 32))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 2, 512, 32))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 2, 512, 32))
    got = ops.flash_attention(q, k, v, kv_len=100, interpret=True)
    want = ref.mha_attention_ref(q, k, v, kv_len=100)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3,
                               atol=2e-3)


def test_flash_attention_mla_vdim():
    """v head dim ≠ qk head dim (MLA-style)."""
    key = jax.random.PRNGKey(5)
    q = jax.random.normal(key, (1, 4, 128, 48))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 4, 128, 48))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 4, 128, 32))
    got = ops.flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.mha_attention_ref(q, k, v, causal=True)
    assert got.shape == (1, 4, 128, 32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3,
                               atol=2e-3)


def test_flash_vs_chunked_attention():
    """The two attention impls agree (chunked is the model default)."""
    from repro.models.attention import chunked_attention
    key = jax.random.PRNGKey(6)
    q = jax.random.normal(key, (2, 4, 256, 32))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 2, 256, 32))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 2, 256, 32))
    a = ops.flash_attention(q, k, v, causal=True, interpret=True)
    b = chunked_attention(q, k, v, causal=True, block_q=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3,
                               atol=2e-3)
