"""Structured retrieval, layer by layer (PR 10 unit tier).

* tokenizer — the fielded views the v2 format builds on: empty and
  stopword-only fields, duplicate terms keeping distinct positions, and
  the flatten invariant (a fielded doc's bag-of-words identity equals its
  concatenation's).
* query DSL — parse/payload round-trips, duplicate-term qtf merging,
  conjunction detection, and every admission-mapped parse error.
* format — v1 superindex/payload bytes pinned against a hand-framed
  serialization (backward compat is a byte contract, not a behaviour);
  v2 blobs extend v1 as a strict prefix at the section AND payload-row
  level, and round-trip their occurrence arrays exactly.
* evaluator — packed-array scoring vs the oracle's dict-based ``exact_*``
  twins (no shared code), POS_SLOTS truncation on both sides, facet
  merging determinism, snippet coverage guarantees.
"""

import dataclasses

import numpy as np
import pytest

from repro.index.builder import (POS_SLOTS, IndexWriter, compute_global_stats,
                                 field_avgdl, pack_payload, pack_superindex,
                                 payload_row_bytes, unpack_payload_rows,
                                 unpack_superindex)
from repro.index.tokenizer import (field_items, field_token_counts,
                                   flatten_text, tokenize, tokenize_positions,
                                   tokenize_spans)
from repro.search.oracle import OracleSearcher, StructuredOracleSearcher
from repro.search.query import (QueryParseError, parse_query,
                                query_from_payload)
from repro.search.structured import (facet_counts, make_snippet,
                                     merge_facet_counts)

# -- tokenizer: the edge cases the field split exposes -------------------------


def test_empty_field_contributes_nothing_but_stays_declared():
    doc = {"title": "", "body": "hello world"}
    assert field_items(doc) == [("title", ""), ("body", "hello world")]
    assert tokenize(doc) == ["hello", "world"]
    assert tokenize_positions(doc) == [("body", "hello", 0),
                                       ("body", "world", 1)]
    # the per-field length table still carries the empty field at length 0
    assert field_token_counts(doc) == {"title": 0, "body": 2}


def test_stopword_only_field_has_zero_kept_length():
    doc = {"title": "the of and a", "body": "serverless lucene"}
    assert tokenize(doc) == ["serverless", "lucene"]
    assert [p for p in tokenize_positions(doc) if p[0] == "title"] == []
    assert field_token_counts(doc)["title"] == 0
    # an overlength token is dropped by the same keep rule
    long = "x" * 65
    assert tokenize({"t": long}) == []
    assert tokenize_positions({"t": f"{long} ok"}) == [("t", "ok", 0)]


def test_duplicate_terms_keep_distinct_positions():
    doc = {"body": "data big data"}
    assert tokenize_positions(doc) == [("body", "data", 0), ("body", "big", 1),
                                       ("body", "data", 2)]
    # positions index the KEPT stream: the stopword consumes no slot
    assert tokenize_positions("the big data") == [("body", "big", 0),
                                                  ("body", "data", 1)]
    # the same term in two fields restarts at 0 per field
    two = {"title": "data", "body": "data"}
    assert tokenize_positions(two) == [("title", "data", 0),
                                       ("body", "data", 0)]


def test_flatten_invariant_fielded_doc_equals_concatenation():
    doc = {"title": "Serverless Lucene", "body": "big data engines"}
    assert flatten_text(doc) == "Serverless Lucene big data engines"
    assert tokenize(doc) == tokenize(flatten_text(doc))
    assert sum(field_token_counts(doc).values()) == len(tokenize(doc))
    # a plain string is one implicit body field
    assert field_items("hi world") == [("body", "hi world")]
    assert tokenize_positions("hi world") == [("body", "hi", 0),
                                              ("body", "world", 1)]


def test_spans_index_the_original_text():
    text = "The BIG-data engine"
    spans = tokenize_spans(text)
    assert [t for t, _, _ in spans] == ["big", "data", "engine"]
    for tok, s, e in spans:
        assert text[s:e].lower() == tok      # casing preserved by slicing


# -- query DSL -----------------------------------------------------------------


def test_parse_clause_shapes():
    q = parse_query('title:"serverless lucene" body:big^2 data')
    assert not q.conjunctive
    ph, bt, dt = q.leaves
    assert (ph.kind, ph.field, ph.terms) == ("phrase", "title",
                                             ["serverless", "lucene"])
    assert (bt.kind, bt.field, bt.boost) == ("term", "body", 2.0)
    assert (dt.kind, dt.field, dt.terms) == ("term", None, ["data"])
    assert q.terms == ["serverless", "lucene", "big", "data"]


def test_any_and_makes_the_query_conjunctive():
    assert not parse_query("a1 OR b1").conjunctive
    assert parse_query("a1 AND b1").conjunctive
    assert parse_query("a1 AND b1 OR c1").conjunctive   # one AND flips all


def test_duplicate_terms_merge_qtf_but_phrases_never_merge():
    q = parse_query("data data title:data")
    assert [(lf.terms[0], lf.field, lf.qtf) for lf in q.leaves] == [
        ("data", None, 2), ("data", "title", 1)]
    p = parse_query('"big data" "big data"')
    assert [lf.kind for lf in p.leaves] == ["phrase", "phrase"]
    # a one-token phrase is just a term (and merges like one)
    assert parse_query('"data" data').leaves[0].qtf == 2


def test_analyzer_runs_inside_clauses():
    q = parse_query('"the big data" of')
    # stopword dropped from the phrase; the stopword-only clause vanishes
    assert q.leaves[0].terms == ["big", "data"]
    assert len(q.leaves) == 1
    assert parse_query("of the").leaves == []      # zero leaves is legal


def test_parse_errors():
    for bad in ('"unbalanced', "x^nope", "x^0", "x^-1", "AND x", "x AND"):
        with pytest.raises(QueryParseError):
            parse_query(bad)
    with pytest.raises(QueryParseError):
        parse_query(None)


def test_payload_round_trip():
    q = parse_query('title:"serverless lucene"^1.5 AND body:big data data')
    rt = query_from_payload(q.to_payload())
    assert rt == q


# -- format: v1 byte identity, v2 prefix + round-trip --------------------------

DOCS = [
    ("d0", {"title": "serverless lucene", "body": "a prototype of serverless "
            "lucene", "cat": "systems"}),
    ("d1", {"title": "big data", "body": "serverless big data engines",
            "cat": "systems"}),
    ("d2", {"title": "tails", "body": "tail latency in big fleets",
            "cat": "cloud"}),
    ("d3", {"title": "facets", "body": "faceted navigation data data data",
            "cat": "ir"}),
]
FLAT = [(e, flatten_text(t)) for e, t in DOCS]


def _pack(docs, **kw):
    w = IndexWriter(**kw)
    for e, t in docs:
        w.add(e, t)
    return w.pack()


def test_v1_superindex_bytes_pinned_to_hand_framed_serialization():
    """Backward compat is a byte contract: a segment packed WITHOUT the
    structured option must serialize to exactly the v1 framing — SUPX
    magic, six length-prefixed sections, nothing else."""
    from repro.core import jsonutil as orjson
    from repro.index.builder import _npy_bytes
    packed = _pack(FLAT)
    assert packed.fields is None
    blob = pack_superindex(packed)
    want = b"SUPX"
    for s in (packed.meta.to_json(), orjson.dumps(packed.vocab),
              _npy_bytes(packed.term_offsets), _npy_bytes(packed.block_max),
              _npy_bytes(packed.doc_len), _npy_bytes(packed.idf)):
        want += len(s).to_bytes(4, "little") + s
    assert blob == want
    meta, vocab, arrays, fh = unpack_superindex(blob)
    assert fh is None and vocab == packed.vocab
    # v1 payload rows stay at the 5 B/lane pitch
    pay = pack_payload(packed)
    assert len(pay) == packed.meta.n_blocks * payload_row_bytes(
        packed.meta.block)
    docs, tf = unpack_payload_rows(pay, packed.meta.block)
    np.testing.assert_array_equal(docs, np.asarray(packed.block_docs))
    np.testing.assert_array_equal(tf, np.asarray(packed.block_tf))


def test_v2_extends_v1_as_a_strict_prefix():
    """A v2 pack of fielded docs and a v1 pack of their flattened texts
    must agree on every v1 array — and the v2 superindex's first six
    sections / each payload row's first 5·B bytes must equal the v1
    serialization byte-for-byte, so a v1 reader's view is untouched."""
    v1 = _pack(FLAT)
    v2 = _pack(DOCS, structured=True, facet_fields=("cat",))
    assert v2.fields is not None and v2.fields.pos_slots == POS_SLOTS
    for name in ("term_offsets", "block_docs", "block_tf", "block_max",
                 "doc_len", "idf"):
        np.testing.assert_array_equal(np.asarray(getattr(v1, name)),
                                      np.asarray(getattr(v2, name)), name)
    b1, b2 = pack_superindex(v1), pack_superindex(v2)
    assert b1[:4] == b"SUPX" and b2[:4] == b"SUP2"
    assert b2[4:4 + len(b1) - 4] == b1[4:]        # section-level prefix
    B = v1.meta.block
    r1 = np.frombuffer(pack_payload(v1), np.uint8).reshape(
        -1, payload_row_bytes(B))
    r2 = np.frombuffer(pack_payload(v2), np.uint8).reshape(
        -1, payload_row_bytes(B, POS_SLOTS))
    np.testing.assert_array_equal(r1, r2[:, :payload_row_bytes(B)])


def test_v2_round_trip_restores_occurrence_arrays():
    v2 = _pack(DOCS, structured=True, facet_fields=("cat",))
    fd = v2.fields
    meta, vocab, arrays, fh = unpack_superindex(pack_superindex(v2))
    assert fh["field_names"] == fd.field_names
    assert fh["pos_slots"] == fd.pos_slots
    assert fh["facet_names"] == fd.facet_names
    assert fh["facet_values"] == fd.facet_values
    np.testing.assert_array_equal(fh["field_len"], np.asarray(fd.field_len))
    np.testing.assert_array_equal(fh["facet_ids"], np.asarray(fd.facet_ids))
    out = unpack_payload_rows(pack_payload(v2), meta.block, fh["pos_slots"])
    docs, tf, nocc, occf, occp = out
    np.testing.assert_array_equal(nocc, np.asarray(fd.block_nocc))
    np.testing.assert_array_equal(occf, np.asarray(fd.block_occ_field))
    np.testing.assert_array_equal(occp, np.asarray(fd.block_occ_pos))


def test_stripping_fields_restores_v1_bytes_exactly():
    """The SuperIndexMissing-style fallback shape: dropping the fields
    attachment from a v2 pack yields a pack whose v1 serialization is
    byte-identical to one never built with fields — nothing v2 leaks
    into the v1 sections."""
    v1 = _pack(FLAT)
    v2 = _pack(DOCS, structured=True, facet_fields=("cat",))
    stripped = dataclasses.replace(v2, fields=None)
    assert pack_superindex(stripped) == pack_superindex(v1)
    assert pack_payload(stripped) == pack_payload(v1)


# -- evaluator vs the oracle's independent twins -------------------------------

CORPUS = DOCS + [
    ("d4", {"title": "big big big", "body": " ".join(["big"] * 12),
            "cat": "systems"}),               # > POS_SLOTS occurrences
    ("d5", {"title": "", "body": "the of and", "cat": "cloud"}),  # empty-ish
]

QUERIES = [
    'title:"serverless lucene" OR big',
    'body:big AND data',
    '"big data"^2 systems',
    'cat:systems',
    'title:big',
    'serverless lucene',                      # plain bag-of-words
    '"big big" OR facets',                    # repeated-term phrase
]


@pytest.fixture(scope="module")
def oracle():
    return StructuredOracleSearcher(CORPUS, facet_fields=("cat",))


@pytest.mark.parametrize("sq", QUERIES)
def test_packed_match_sets_equal_dict_twins(oracle, sq):
    assert oracle.match_set(sq) == oracle.exact_match_set(sq), sq


@pytest.mark.parametrize("sq", QUERIES)
def test_packed_facets_equal_dict_twins(oracle, sq):
    assert oracle.facet_counts(sq, "cat") == \
        oracle.exact_facet_counts(sq, "cat"), sq


def test_pos_slots_truncation_is_symmetric(oracle):
    """d4's body holds 12 'big' occurrences but the format stores only the
    first POS_SLOTS per posting — both evaluator and dict twin apply the
    truncation, so a phrase needing a late occurrence misses on BOTH."""
    assert POS_SLOTS < 12
    d4 = next(i for i, (e, _) in enumerate(CORPUS) if e == "d4")
    m = oracle.match_set('body:"big big"')
    assert d4 in m and m == oracle.exact_match_set('body:"big big"')


def test_bag_of_words_structured_matches_legacy_oracle_ranking(oracle):
    """A structured query with no field/phrase syntax must rank exactly
    like the legacy analyzer path (same docs, same tie-breaks) — the
    grammar is a superset, not a fork."""
    legacy = OracleSearcher([(e, flatten_text(t)) for e, t in CORPUS])
    for q in ("serverless lucene", "big data", "data data big"):
        want = legacy.search(q, 10)
        got = oracle.search(q, 10)
        assert [d for d, _ in got] == [d for d, _ in want], q
        for (_, a), (_, b) in zip(got, want):
            assert a == pytest.approx(b, rel=1e-5), q


def test_unknown_terms_fields_and_values_match_nothing(oracle):
    assert oracle.match_set("zzzz") == set()
    assert oracle.match_set("nofield:big") == set()
    assert oracle.match_set('"serverless zzzz"') == set()
    assert oracle.search("zzzz", 5) == []
    assert oracle.facet_counts("zzzz", "cat") == {}


def test_conjunction_needs_every_leaf(oracle):
    both = oracle.match_set("serverless AND data")
    assert both == oracle.match_set("serverless") & oracle.match_set("data")
    assert oracle.match_set("serverless OR data") == \
        oracle.match_set("serverless") | oracle.match_set("data")


def test_facet_counts_cover_full_match_set_not_topk():
    oracle = StructuredOracleSearcher(CORPUS, facet_fields=("cat",))
    _, eligible = oracle.evaluate("big")
    got = facet_counts(oracle.packed, eligible, "cat")
    assert sum(got.values()) == int(eligible.sum())
    with pytest.raises(Exception, match="not declared"):
        facet_counts(oracle.packed, eligible, "title")


def test_merge_facet_counts_orders_deterministically():
    merged = merge_facet_counts([{"b": 2, "a": 1}, {"a": 1, "c": 2}])
    assert list(merged.items()) == [("a", 2), ("b", 2), ("c", 2)]
    assert merge_facet_counts([]) == {}


# -- snippets ------------------------------------------------------------------


def test_snippet_covers_every_matched_term():
    doc = {"title": "Serverless Lucene", "body":
           "A prototype of serverless Lucene running on cloud functions, "
           "where big data workloads meet pay-per-query economics."}
    snip = make_snippet(doc, ["serverless", "big", "economics"])
    for t in ("serverless", "big", "economics"):
        assert f"<em>" in snip and t in snip.lower()
    # original casing survives (slices index the raw text)
    assert "<em>Serverless</em>" in snip


def test_snippet_falls_back_to_head_when_nothing_matches():
    doc = {"body": "x" * 200}
    snip = make_snippet(doc, ["absent"])
    assert snip.startswith("x") and snip.endswith("…")
    assert "<em>" not in snip
    assert make_snippet({"body": ""}, ["absent"]) == ""


def test_snippet_merges_overlapping_windows():
    body = "alpha beta gamma " * 3 + "delta"
    snip = make_snippet({"body": body}, ["beta", "gamma"])
    assert "<em>beta</em> <em>gamma</em>" in snip


# -- per-field stats -----------------------------------------------------------


def test_field_avgdl_from_global_stats():
    stats = compute_global_stats(DOCS, fields=True)
    lens = [field_token_counts(t)["title"] for _, t in DOCS]
    assert field_avgdl(stats, "title") == pytest.approx(sum(lens) / len(DOCS))
    assert field_avgdl(stats, "absent") == 1.0
