"""Parity suite: every BM25 evaluation path == the exact oracle.

Four paths over ONE corpus and query set, all fed by the single scoring
core in ``search/bm25.py`` and the single packer in ``index/builder.py``:

    dense   Searcher, dense scatter-add accumulator
    sorted  Searcher, sort/segment-sum accumulator
    pruned  Searcher, block-max WAND pruning (pure-JAX ref + fused Pallas
            kernel) — additionally BIT-identical to dense on every path
    mesh    shard_map'd distributed path (1 partition on this host's mesh;
            multi-device geometry is covered in test_distributed)
    fleet   build_partitioned_search_app: N Lambda functions + ScatterGather
            through the Gateway

M·B (max_blocks × block) covers every posting of every query term, so each
path must reproduce the oracle's scores to float tolerance — plus the
distributed-IR invariant that the merged ranking is independent of the
partition count (global idf/avgdl), and scatter-gather's latency model
(max over partitions, not sum).
"""

import jax
import pytest

from repro.data.corpus import synth_corpus, synth_queries
from repro.search.oracle import OracleSearcher
from repro.search.searcher import SearchConfig, Searcher
from repro.search.service import build_partitioned_search_app

K = 10


@pytest.fixture(scope="module")
def corpus():
    # 300 docs / vocab 500: every term's postings fit 64 blocks × 128 lanes
    return synth_corpus(300, vocab=500, seed=21)


@pytest.fixture(scope="module")
def queries(corpus):
    return synth_queries(corpus, 12, seed=23)


@pytest.fixture(scope="module")
def oracle(corpus):
    return OracleSearcher(corpus)


def assert_matches_oracle(got, want, ctx=""):
    """Scores rank-by-rank to float tolerance; ids equal unless score-tied."""
    assert len(got) >= min(len(want), K), (ctx, len(got), len(want))
    for r, ((wd, ws), (gd, gs)) in enumerate(zip(want, got)):
        assert gs == pytest.approx(ws, rel=2e-4), (ctx, r, want[:5], got[:5])
        tied = any(abs(ws - w2) < 1e-5 for d2, w2 in want if d2 != wd)
        assert wd == gd or tied, (ctx, r, want[:8], got[:8])


@pytest.fixture(scope="module")
def packed(corpus):
    from repro.index.builder import IndexWriter
    w = IndexWriter()
    w.add_many(corpus)
    return w.pack()


@pytest.mark.parametrize("accumulator", ["dense", "sorted", "pruned"])
def test_single_node_paths_match_oracle(packed, oracle, queries, accumulator):
    s = Searcher(packed, SearchConfig(max_blocks=64, k=K,
                                      accumulator=accumulator))
    for q in queries:
        assert_matches_oracle(s.search_one(q), oracle.search(q, k=K),
                              ctx=(accumulator, q))


def _bitwise_equal_searches(sa, sb, queries):
    import numpy as np
    for q in queries:
        va, ia = sa.search([q])
        vb, ib = sb.search([q])
        assert np.array_equal(va.view(np.uint32), vb.view(np.uint32)), \
            (q, va, vb)
        assert np.array_equal(ia, ib), (q, ia, ib)


@pytest.mark.parametrize("use_kernel", [False, True])
def test_pruned_bit_identical_to_dense(packed, queries, use_kernel):
    """The pruning invariant, single node: ``accumulator="pruned"`` (pure
    reference AND fused Pallas kernel) returns the exact bits the dense
    scatter-add path returns — pruning may only skip blocks that provably
    cannot enter the top-k, with lax.top_k tie order."""
    dense = Searcher(packed, SearchConfig(max_blocks=64, k=K))
    pruned = Searcher(packed, SearchConfig(max_blocks=64, k=K,
                                           accumulator="pruned",
                                           use_kernel=use_kernel))
    _bitwise_equal_searches(dense, pruned, queries)


def test_pruned_bit_identical_under_truncated_blocks(packed, queries):
    """M smaller than some terms' block counts (the production shape):
    pruning must still be exact w.r.t. dense at the SAME truncation."""
    dense = Searcher(packed, SearchConfig(max_blocks=2, k=K))
    pruned = Searcher(packed, SearchConfig(max_blocks=2, k=K,
                                           accumulator="pruned"))
    _bitwise_equal_searches(dense, pruned, queries)


def test_mesh_path_matches_oracle(corpus, oracle, queries):
    from repro.parallel import compat
    from repro.search.bm25 import encode_queries
    from repro.search.distributed import (build_partitioned_state,
                                          make_dist_search_fn)
    n_parts = 1                      # host pytest process sees one device
    state, cfg, vocab = build_partitioned_state(
        corpus, n_parts, {"k": K, "max_blocks": 64})
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    fn = make_dist_search_fn(cfg, ("data", "model"), mesh=mesh)
    tids, qtf = encode_queries(vocab, queries, max_terms=cfg.max_terms,
                               idf=state["idf"])
    with compat.use_mesh(mesh):
        scores, ids = jax.jit(fn)(
            jax.tree_util.tree_map(jax.numpy.asarray, state), tids, qtf)
    for qi, q in enumerate(queries):
        got = [(int(i), float(v)) for v, i in zip(scores[qi], ids[qi])
               if v > 0]
        assert_matches_oracle(got, oracle.search(q, k=K), ctx=("mesh", q))


def test_mesh_pruned_bit_identical_to_mesh_dense(corpus, oracle, queries):
    """shard_map path with ``accumulator="pruned"``: same bits as the dense
    mesh run, and still oracle-exact."""
    import numpy as np

    from repro.parallel import compat
    from repro.search.bm25 import encode_queries
    from repro.search.distributed import (build_partitioned_state,
                                          make_dist_search_fn)
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    out = {}
    for acc in ("dense", "pruned"):
        state, cfg, vocab = build_partitioned_state(
            corpus, 1, {"k": K, "max_blocks": 64, "accumulator": acc})
        fn = make_dist_search_fn(cfg, ("data", "model"), mesh=mesh)
        tids, qtf = encode_queries(vocab, queries, max_terms=cfg.max_terms,
                                   idf=state["idf"])
        with compat.use_mesh(mesh):
            scores, ids = jax.jit(fn)(
                jax.tree_util.tree_map(jax.numpy.asarray, state), tids, qtf)
        out[acc] = (np.asarray(scores), np.asarray(ids))
    assert np.array_equal(out["dense"][0].view(np.uint32),
                          out["pruned"][0].view(np.uint32))
    assert np.array_equal(out["dense"][1], out["pruned"][1])
    for qi, q in enumerate(queries):
        got = [(int(i), float(v)) for v, i in
               zip(out["pruned"][0][qi], out["pruned"][1][qi]) if v > 0]
        assert_matches_oracle(got, oracle.search(q, k=K),
                              ctx=("mesh-pruned", q))


def test_fleet_path_matches_oracle_through_gateway(corpus, oracle, queries):
    app = build_partitioned_search_app(corpus, n_parts=4)
    for q in queries:
        r = app.query(q, k=K)
        assert r.ok, r.body
        got = list(zip(r.body["ids"], r.body["scores"]))
        assert_matches_oracle(got, oracle.search(q, k=K), ctx=("fleet", q))
    # per-partition cold start + hydration recorded in the runtime ledger
    cold = [rec for rec in app.runtime.records if rec.cold]
    assert {rec.fn for rec in cold} == set(app.fn_names)
    assert all(rec.hydrate_s > 0 for rec in cold)
    assert app.runtime.ledger.invocations >= len(queries) * len(app.fn_names)


def test_fleet_batched_queries_match_single(corpus, oracle, queries):
    """A Q>1 micro-batch is ONE invocation per partition, same results."""
    app = build_partitioned_search_app(corpus, n_parts=4)
    n_before = len(app.runtime.records)
    r = app.query(list(queries), k=K, fetch_docs=False)
    assert r.ok, r.body
    assert len(app.runtime.records) - n_before == len(app.fn_names)
    assert len(r.body["results"]) == len(queries)
    for q, res in zip(queries, r.body["results"]):
        got = list(zip(res["ids"], res["scores"]))
        assert_matches_oracle(got, oracle.search(q, k=K), ctx=("batch", q))


def test_fleet_pruned_matches_dense_and_oracle(corpus, oracle, queries):
    """The wired-through flag: ``SearchConfig(accumulator="pruned")`` →
    ``build_partitioned_search_app`` handlers. Results identical to the
    dense fleet (scores bitwise via repr equality on floats) and
    oracle-exact."""
    dense_app = build_partitioned_search_app(corpus, n_parts=4)
    pruned_app = build_partitioned_search_app(
        corpus, n_parts=4,
        search_config=SearchConfig(accumulator="pruned"))
    rd = dense_app.query(list(queries), k=K, fetch_docs=False)
    rp = pruned_app.query(list(queries), k=K, fetch_docs=False)
    assert rd.ok and rp.ok
    for q, res_d, res_p in zip(queries, rd.body["results"],
                               rp.body["results"]):
        assert res_d["ids"] == res_p["ids"], q
        assert res_d["scores"] == res_p["scores"], q   # exact float equality
        assert_matches_oracle(list(zip(res_p["ids"], res_p["scores"])),
                              oracle.search(q, k=K), ctx=("fleet-pruned", q))


def test_global_stats_invariant_across_partition_counts(corpus, queries):
    """idf/avgdl AND the vocab are corpus-global: the merged ranking must
    be bitwise stable under repartitioning (the §3 subtlety the one-core
    build enforces by construction). Includes a query with far more than
    max_terms distinct terms — idf truncation must select the SAME term
    subset in every partition, which only holds with a shared vocab."""
    long_q = " ".join(t for _, text in corpus[:8] for t in text.split()[:6])
    qs = list(queries) + [long_q]
    per_n = {}
    for n in (1, 2, 4):
        app = build_partitioned_search_app(corpus, n_parts=n)
        r = app.query(qs, k=K, fetch_docs=False)
        assert r.ok, r.body
        per_n[n] = [
            (tuple(res["ext_ids"]),
             tuple(round(s, 6) for s in res["scores"]))
            for res in r.body["results"]]
    assert per_n[1] == per_n[2] == per_n[4]


def test_pruned_invariant_across_partition_counts(corpus, queries):
    """Partition-count invariance holds for the pruned path too — and at
    every partition count the pruned fleet returns the dense fleet's
    results (pruning decisions are per-partition, results must not be)."""
    per_n = {}
    for n in (1, 2, 4):
        out = {}
        for acc in ("dense", "pruned"):
            app = build_partitioned_search_app(
                corpus, n_parts=n,
                search_config=SearchConfig(accumulator=acc))
            r = app.query(list(queries), k=K, fetch_docs=False)
            assert r.ok, r.body
            out[acc] = [(tuple(res["ext_ids"]), tuple(res["scores"]))
                        for res in r.body["results"]]
        assert out["dense"] == out["pruned"]      # exact, per count
        per_n[n] = [(ids, tuple(round(s, 6) for s in ss))
                    for ids, ss in out["pruned"]]
    assert per_n[1] == per_n[2] == per_n[4]


def test_scatter_gather_latency_is_max_not_sum(corpus, queries):
    """All partitions fan out at the same arrival instant; end-to-end
    latency is the slowest partition (+merge/fetch), never the sum."""
    app = build_partitioned_search_app(corpus, n_parts=4)
    r = app.query(queries[0], k=K)          # all-cold fan-out
    lats = [p["latency_s"] for p in r.body["partitions"]]
    assert len(lats) == 4 and min(lats) > 0
    # every partition leg saw the same arrival time (un-mutated fleet)
    assert len({rec.t_arrival for rec in app.runtime.records}) == 1
    assert max(lats) <= r.latency_s < sum(lats)
    # warm repeat, straight at the ScatterGather layer: latency == max leg
    # plus the constant gather/merge term (charged on every scatter)
    hits, lat, recs = app.scatter.search(
        {"q": queries[0], "k": K, "fetch_docs": False}, K,
        t_arrival=app.runtime.clock + 1.0)
    assert hits and all(not rec.cold for rec in recs)
    assert lat == pytest.approx(
        max(rec.latency_s for rec in recs) + app.scatter.merge_cost_s)
    assert lat < sum(rec.latency_s for rec in recs)
    assert len({rec.t_arrival for rec in recs}) == 1


@pytest.mark.parametrize("use_kernel", [False, True])
def test_pruned_bit_identical_on_nrt_combined_segments(use_kernel):
    """NRT delta-served generations: ``combine_segments`` zeroes tombstoned
    postings (whole blocks can go dead, tf=0) and recomputes ``block_max``
    under live stats. The pruned path must return the dense path's exact
    bits on the combined index — a zeroed block has block_max 0 and must
    prune away or contribute nothing, never corrupt θ."""
    from repro.index.builder import (IndexWriter, combine_segments,
                                     compute_global_stats, extend_vocab,
                                     global_vocab, update_stats)
    from repro.index.tokenizer import tokenize
    docs = synth_corpus(240, vocab=400, seed=5)
    base_docs, new_docs = docs[:180], docs[180:]
    deleted = {docs[3][0], docs[100][0], docs[200][0]}

    stats = compute_global_stats(base_docs)
    vocab = global_vocab(stats)
    w = IndexWriter(global_stats=stats, vocab=vocab)
    w.add_many(base_docs)
    base = w.pack()
    vocab2 = extend_vocab(vocab, (t for _, txt in new_docs
                                  for t in tokenize(txt)))
    delta = IndexWriter.delta(new_docs, stats, vocab=vocab2)
    live_stats = dict(stats, df=dict(stats["df"]))
    by_id = dict(docs)
    for _, t in new_docs:
        update_stats(live_stats, t, sign=1)
    for e in deleted:
        update_stats(live_stats, by_id[e], sign=-1)
    dead = [i for i, (e, _) in enumerate(base_docs + new_docs)
            if e in deleted]
    combined = combine_segments([base, delta], vocab=vocab2,
                                stats=live_stats, tombstones=dead)

    dense = Searcher(combined, SearchConfig(max_blocks=64, k=K))
    pruned = Searcher(combined, SearchConfig(max_blocks=64, k=K,
                                             accumulator="pruned",
                                             use_kernel=use_kernel))
    _bitwise_equal_searches(dense, pruned, synth_queries(docs, 15, seed=6))


@pytest.mark.parametrize("seed", [101, 202, 303])
def test_property_random_corpora_all_paths_match_oracle(seed):
    """Property-style: random corpora/queries, all four single-node
    evaluation paths (dense, sorted, pruned, pruned+fused-kernel) against
    the exact oracle, and both pruned variants bitwise against dense."""
    import numpy as np
    rng = np.random.default_rng(seed)
    corpus = synth_corpus(int(rng.integers(80, 250)),
                          vocab=int(rng.integers(150, 600)), seed=seed)
    queries = synth_queries(corpus, 8, seed=seed + 1,
                            terms_per_query=int(rng.integers(1, 5)))
    oracle = OracleSearcher(corpus)
    from repro.index.builder import IndexWriter
    w = IndexWriter()
    w.add_many(corpus)
    packed = w.pack()
    variants = {
        "dense": SearchConfig(max_blocks=64, k=K),
        "sorted": SearchConfig(max_blocks=64, k=K, accumulator="sorted"),
        "pruned": SearchConfig(max_blocks=64, k=K, accumulator="pruned"),
        "pruned+kernel": SearchConfig(max_blocks=64, k=K,
                                      accumulator="pruned", use_kernel=True),
    }
    searchers = {name: Searcher(packed, cfg)
                 for name, cfg in variants.items()}
    for q in queries:
        for name, s in searchers.items():
            assert_matches_oracle(s.search_one(q), oracle.search(q, k=K),
                                  ctx=(seed, name, q))
    _bitwise_equal_searches(searchers["dense"], searchers["pruned"], queries)
    _bitwise_equal_searches(searchers["dense"], searchers["pruned+kernel"],
                            queries)


def test_long_query_truncation_keeps_high_idf_terms(corpus, packed):
    """encode_queries sheds the LOWEST-idf terms when a query overflows
    max_terms, so truncated evaluation tracks the full-query ranking."""
    from repro.search.bm25 import encode_queries
    # one long query from many docs' terms
    long_q = " ".join(t for _, text in corpus[:6] for t in text.split()[:8])
    tids, _ = encode_queries(packed.vocab, [long_q], max_terms=8,
                             idf=packed.idf)
    kept = [t for t in tids[0] if t >= 0]
    assert len(kept) == 8
    all_ids = [packed.vocab[t] for t in set(long_q.split())
               if t in packed.vocab]
    dropped = [t for t in all_ids if t not in kept]
    assert dropped, "query should overflow max_terms"
    assert min(packed.idf[kept]) >= max(packed.idf[dropped]) - 1e-6


@pytest.mark.parametrize("accumulator", ["dense", "pruned"])
def test_partial_hydration_bit_identical_under_nrt(accumulator):
    """Lazy partial-hydration views under an NRT generation (base + delta +
    tombstones): with only the QUERY terms' posting blocks hydrated, the
    fused view must rank bit-identically to full hydration — masked blocks
    carry tf=0 and land after the live blocks of their term in
    ``combine_segments``'s impact re-sort, so query terms' rows sit at
    identical positions. Backfill then reproduces the full index
    bit-for-bit."""
    import numpy as np

    from repro.core.object_store import ObjectStore
    from repro.core.refresh import AssetCatalog
    from repro.index.builder import (IndexWriter, combine_segments,
                                     compute_global_stats, extend_vocab,
                                     global_vocab, read_segment, update_stats,
                                     write_segment)
    from repro.index.hydration import LazyIndex, open_partial_segment
    from repro.index.tokenizer import tokenize

    docs = synth_corpus(240, vocab=400, seed=5)
    base_docs, new_docs = docs[:180], docs[180:]
    deleted = {docs[3][0], docs[100][0], docs[200][0]}

    stats = compute_global_stats(base_docs)
    vocab = global_vocab(stats)
    w = IndexWriter(global_stats=stats, vocab=vocab)
    w.add_many(base_docs)
    base = w.pack()
    vocab2 = extend_vocab(vocab, (t for _, txt in new_docs
                                  for t in tokenize(txt)))
    delta = IndexWriter.delta(new_docs, stats, vocab=vocab2)
    live_stats = dict(stats, df=dict(stats["df"]))
    by_id = dict(docs)
    for _, t in new_docs:
        update_stats(live_stats, t, sign=1)
    for e in deleted:
        update_stats(live_stats, by_id[e], sign=-1)
    dead = [i for i, (e, _) in enumerate(base_docs + new_docs)
            if e in deleted]
    combined = combine_segments([base, delta], vocab=vocab2,
                                stats=live_stats, tombstones=dead)

    store = ObjectStore()
    cat = AssetCatalog(store)
    cat.publish_segment("idx", "base", write_segment(base))
    cat.publish_segment("idx", "delta", write_segment(delta))
    lazy = LazyIndex(
        [open_partial_segment(cat.open_segment("idx", "base")),
         open_partial_segment(cat.open_segment("idx", "delta"))],
        vocab=vocab2, stats=live_stats, tombstones=dead)
    assert lazy.state == "partial"

    queries = synth_queries(docs, 15, seed=6)
    lazy.ensure_terms({t for q in queries for t in tokenize(q)})
    cfg = SearchConfig(max_blocks=64, k=K, accumulator=accumulator)
    full_s = Searcher(combined, cfg)
    _bitwise_equal_searches(full_s, Searcher(lazy.packed(), cfg), queries)

    lazy.backfill()
    assert lazy.state == "full"
    for seg, eager in zip(lazy.segments, (base, delta)):
        assert np.array_equal(seg.block_docs, np.asarray(eager.block_docs))
        assert np.array_equal(seg.block_tf, np.asarray(eager.block_tf))
    _bitwise_equal_searches(full_s, Searcher(lazy.packed(), cfg), queries)
