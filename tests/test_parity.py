"""Parity suite: every BM25 evaluation path == the exact oracle.

Four paths over ONE corpus and query set, all fed by the single scoring
core in ``search/bm25.py`` and the single packer in ``index/builder.py``:

    dense   Searcher, dense scatter-add accumulator
    sorted  Searcher, sort/segment-sum accumulator
    mesh    shard_map'd distributed path (1 partition on this host's mesh;
            multi-device geometry is covered in test_distributed)
    fleet   build_partitioned_search_app: N Lambda functions + ScatterGather
            through the Gateway

M·B (max_blocks × block) covers every posting of every query term, so each
path must reproduce the oracle's scores to float tolerance — plus the
distributed-IR invariant that the merged ranking is independent of the
partition count (global idf/avgdl), and scatter-gather's latency model
(max over partitions, not sum).
"""

import jax
import pytest

from repro.data.corpus import synth_corpus, synth_queries
from repro.search.oracle import OracleSearcher
from repro.search.searcher import SearchConfig, Searcher
from repro.search.service import build_partitioned_search_app

K = 10


@pytest.fixture(scope="module")
def corpus():
    # 300 docs / vocab 500: every term's postings fit 64 blocks × 128 lanes
    return synth_corpus(300, vocab=500, seed=21)


@pytest.fixture(scope="module")
def queries(corpus):
    return synth_queries(corpus, 12, seed=23)


@pytest.fixture(scope="module")
def oracle(corpus):
    return OracleSearcher(corpus)


def assert_matches_oracle(got, want, ctx=""):
    """Scores rank-by-rank to float tolerance; ids equal unless score-tied."""
    assert len(got) >= min(len(want), K), (ctx, len(got), len(want))
    for r, ((wd, ws), (gd, gs)) in enumerate(zip(want, got)):
        assert gs == pytest.approx(ws, rel=2e-4), (ctx, r, want[:5], got[:5])
        tied = any(abs(ws - w2) < 1e-5 for d2, w2 in want if d2 != wd)
        assert wd == gd or tied, (ctx, r, want[:8], got[:8])


@pytest.fixture(scope="module")
def packed(corpus):
    from repro.index.builder import IndexWriter
    w = IndexWriter()
    w.add_many(corpus)
    return w.pack()


@pytest.mark.parametrize("accumulator", ["dense", "sorted"])
def test_single_node_paths_match_oracle(packed, oracle, queries, accumulator):
    s = Searcher(packed, SearchConfig(max_blocks=64, k=K,
                                      accumulator=accumulator))
    for q in queries:
        assert_matches_oracle(s.search_one(q), oracle.search(q, k=K),
                              ctx=(accumulator, q))


def test_mesh_path_matches_oracle(corpus, oracle, queries):
    from repro.parallel import compat
    from repro.search.bm25 import encode_queries
    from repro.search.distributed import (build_partitioned_state,
                                          make_dist_search_fn)
    n_parts = 1                      # host pytest process sees one device
    state, cfg, vocab = build_partitioned_state(
        corpus, n_parts, {"k": K, "max_blocks": 64})
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    fn = make_dist_search_fn(cfg, ("data", "model"), mesh=mesh)
    tids, qtf = encode_queries(vocab, queries, max_terms=cfg.max_terms,
                               idf=state["idf"])
    with compat.use_mesh(mesh):
        scores, ids = jax.jit(fn)(
            jax.tree_util.tree_map(jax.numpy.asarray, state), tids, qtf)
    for qi, q in enumerate(queries):
        got = [(int(i), float(v)) for v, i in zip(scores[qi], ids[qi])
               if v > 0]
        assert_matches_oracle(got, oracle.search(q, k=K), ctx=("mesh", q))


def test_fleet_path_matches_oracle_through_gateway(corpus, oracle, queries):
    app = build_partitioned_search_app(corpus, n_parts=4)
    for q in queries:
        r = app.query(q, k=K)
        assert r.ok, r.body
        got = list(zip(r.body["ids"], r.body["scores"]))
        assert_matches_oracle(got, oracle.search(q, k=K), ctx=("fleet", q))
    # per-partition cold start + hydration recorded in the runtime ledger
    cold = [rec for rec in app.runtime.records if rec.cold]
    assert {rec.fn for rec in cold} == set(app.fn_names)
    assert all(rec.hydrate_s > 0 for rec in cold)
    assert app.runtime.ledger.invocations >= len(queries) * len(app.fn_names)


def test_fleet_batched_queries_match_single(corpus, oracle, queries):
    """A Q>1 micro-batch is ONE invocation per partition, same results."""
    app = build_partitioned_search_app(corpus, n_parts=4)
    n_before = len(app.runtime.records)
    r = app.query(list(queries), k=K, fetch_docs=False)
    assert r.ok, r.body
    assert len(app.runtime.records) - n_before == len(app.fn_names)
    assert len(r.body["results"]) == len(queries)
    for q, res in zip(queries, r.body["results"]):
        got = list(zip(res["ids"], res["scores"]))
        assert_matches_oracle(got, oracle.search(q, k=K), ctx=("batch", q))


def test_global_stats_invariant_across_partition_counts(corpus, queries):
    """idf/avgdl AND the vocab are corpus-global: the merged ranking must
    be bitwise stable under repartitioning (the §3 subtlety the one-core
    build enforces by construction). Includes a query with far more than
    max_terms distinct terms — idf truncation must select the SAME term
    subset in every partition, which only holds with a shared vocab."""
    long_q = " ".join(t for _, text in corpus[:8] for t in text.split()[:6])
    qs = list(queries) + [long_q]
    per_n = {}
    for n in (1, 2, 4):
        app = build_partitioned_search_app(corpus, n_parts=n)
        r = app.query(qs, k=K, fetch_docs=False)
        assert r.ok, r.body
        per_n[n] = [
            (tuple(res["ext_ids"]),
             tuple(round(s, 6) for s in res["scores"]))
            for res in r.body["results"]]
    assert per_n[1] == per_n[2] == per_n[4]


def test_scatter_gather_latency_is_max_not_sum(corpus, queries):
    """All partitions fan out at the same arrival instant; end-to-end
    latency is the slowest partition (+merge/fetch), never the sum."""
    app = build_partitioned_search_app(corpus, n_parts=4)
    r = app.query(queries[0], k=K)          # all-cold fan-out
    lats = [p["latency_s"] for p in r.body["partitions"]]
    assert len(lats) == 4 and min(lats) > 0
    # every partition leg saw the same arrival time (un-mutated fleet)
    assert len({rec.t_arrival for rec in app.runtime.records}) == 1
    assert max(lats) <= r.latency_s < sum(lats)
    # warm repeat, straight at the ScatterGather layer: latency == max leg
    # plus the constant gather/merge term (charged on every scatter)
    hits, lat, recs = app.scatter.search(
        {"q": queries[0], "k": K, "fetch_docs": False}, K,
        t_arrival=app.runtime.clock + 1.0)
    assert hits and all(not rec.cold for rec in recs)
    assert lat == pytest.approx(
        max(rec.latency_s for rec in recs) + app.scatter.merge_cost_s)
    assert lat < sum(rec.latency_s for rec in recs)
    assert len({rec.t_arrival for rec in recs}) == 1


def test_long_query_truncation_keeps_high_idf_terms(corpus, packed):
    """encode_queries sheds the LOWEST-idf terms when a query overflows
    max_terms, so truncated evaluation tracks the full-query ranking."""
    from repro.search.bm25 import encode_queries
    # one long query from many docs' terms
    long_q = " ".join(t for _, text in corpus[:6] for t in text.split()[:8])
    tids, _ = encode_queries(packed.vocab, [long_q], max_terms=8,
                             idf=packed.idf)
    kept = [t for t in tids[0] if t >= 0]
    assert len(kept) == 8
    all_ids = [packed.vocab[t] for t in set(long_q.split())
               if t in packed.vocab]
    dropped = [t for t in all_ids if t not in kept]
    assert dropped, "query should overflow max_terms"
    assert min(packed.idf[kept]) >= max(packed.idf[dropped]) - 1e-6
