"""Structured queries end-to-end through the partitioned fleet (PR 10).

The acceptance pin: a ``field:``-scoped phrase query with a facet request,
through a 4-partition ×2-replica fleet, returns top-k scores BIT-identical
to :class:`StructuredOracleSearcher` over the live corpus (same order,
same f32 bits), facet counts exactly equal to a full-corpus count, and
snippets containing every matched term — including across a mid-window
delta commit (admitted queries stay pinned to their generation) and on
lazily-hydrated all-cold instances.
"""

import pytest

from repro.core.gateway import WindowPolicy
from repro.core.partition import (FleetSpec, GatewaySpec, IndexSpec,
                                  ReplicationSpec)
from repro.index.tokenizer import flatten_text, tokenize
from repro.search.oracle import StructuredOracleSearcher
from repro.search.query import parse_query
from repro.search.searcher import SearchConfig
from repro.search.service import build_partitioned_search_app

DOCS = [
    (f"d{i:03d}", {"title": t, "body": b, "cat": c})
    for i, (t, b, c) in enumerate([
        ("serverless lucene", "a prototype of serverless lucene on lambda",
         "systems"),
        ("big data systems", "serverless big data engines at scale",
         "systems"),
        ("cloud functions", "functions as a service with big latency tails",
         "cloud"),
        ("information retrieval", "bm25 ranking for information retrieval",
         "ir"),
        ("vector search", "dense vector retrieval with big data", "ir"),
        ("lambda tails", "tail latency in serverless lambda fleets", "cloud"),
        ("index formats", "packed segment formats for lucene indexes",
         "systems"),
        ("query parsing", "structured query parsing with phrases", "ir"),
        ("scatter gather", "scatter gather merge over partitions", "systems"),
        ("facet counts", "faceted navigation over categorical fields", "ir"),
        ("cold starts", "cold start hydration of serverless search", "cloud"),
        ("phrase search", "positional phrase search needs positions", "ir"),
    ])
]


def _build(**fleet_kw):
    spec = FleetSpec(
        n_parts=4,
        replication=ReplicationSpec(replicas=2),
        index=IndexSpec(structured=True, facet_fields=("cat",)),
        search_config=SearchConfig(k=10, sim_exec_s=0.0002),
        **fleet_kw)
    return build_partitioned_search_app(DOCS, spec)


def _check(app, sq, *, facets=("cat",), k=10, resp=None, corpus=None):
    """Fleet response vs oracle over the live corpus: exact (ext_id, score)
    list equality — order AND f32 bits — plus exact facets and snippet
    term coverage."""
    live = corpus if corpus is not None else app.indexer.live_corpus()
    oracle = StructuredOracleSearcher(live, facet_fields=("cat",))
    if resp is None:
        resp = app.query(sq=sq, k=k, facets=list(facets), snippets=True)
    assert resp.status == 200, (resp.status, resp.body)
    r = resp.body
    want = [(live[i][0], s) for i, s in oracle.search(sq, k)]
    assert list(zip(r["ext_ids"], r["scores"])) == want, sq
    for f in facets:
        assert r["facets"][f] == oracle.facet_counts(sq, f), (sq, f)
        assert r["facets"][f] == oracle.exact_facet_counts(sq, f), (sq, f)
    if "snippets" in r:
        terms = set(parse_query(sq).terms)
        for doc, snip in zip(r["docs"], r["snippets"]):
            for t in terms & set(tokenize(doc["contents"])):
                assert "<em>" in snip and t in snip.lower(), (sq, t, snip)
    return r


@pytest.fixture()
def app():
    return _build()


QUERIES = [
    'title:"serverless lucene" OR big',      # the acceptance query shape
    'body:big AND data',
    '"big data"^2 systems',
    'cat:systems',
    'serverless',                            # structured bag-of-words
]


@pytest.mark.parametrize("sq", QUERIES)
def test_fleet_matches_oracle_bit_for_bit(app, sq):
    _check(app, sq)


def test_legacy_path_serves_unchanged_on_a_structured_fleet(app):
    """Plain ``q`` queries on a v2 fleet return bit-identical results to a
    v1 fleet over the flattened texts — the structured option must not
    perturb the bag-of-words path (same packs at the v1 lanes, same
    kernels, same merge)."""
    v1 = build_partitioned_search_app(
        [(e, flatten_text(t)) for e, t in DOCS],
        FleetSpec(n_parts=4, replication=ReplicationSpec(replicas=2),
                  search_config=SearchConfig(k=10, sim_exec_s=0.0002)))
    for q in ("serverless lucene", "big data", "latency"):
        a = app.query(q, k=10, fetch_docs=False)
        b = v1.query(q, k=10, fetch_docs=False)
        assert a.status == b.status == 200
        assert a.body["ext_ids"] == b.body["ext_ids"], q
        assert a.body["scores"] == b.body["scores"], q


def test_structured_on_v1_fleet_and_bad_queries_rejected_at_admission(app):
    v1 = build_partitioned_search_app(
        [(e, flatten_text(t)) for e, t in DOCS], FleetSpec(n_parts=2))
    assert v1.query(sq="title:foo").status == 400
    assert app.query(sq="x", facets=["nope"]).status == 400   # undeclared
    assert app.query(sq='"unbalanced').status == 400
    assert app.query(sq="AND x").status == 400
    assert app.query(sq="x", mode="dense").status == 400
    # and nothing above poisoned the fleet
    assert app.query(sq="serverless").status == 200


def test_parity_holds_across_delta_commit_with_new_facet_value(app):
    _check(app, 'body:big AND data')
    app.add_documents([
        ("n000", {"title": "stream processing",
                  "body": "serverless big data streams", "cat": "streams"}),
        ("n001", {"title": "big graphs",
                  "body": "graph systems with big data", "cat": "systems"}),
    ])
    app.delete_documents(["d001"])           # was 'big data systems'
    resp = app.commit()
    assert resp.status == 200 and resp.body["committed"], resp.body
    _check(app, 'body:big AND data')
    _check(app, '"big data" OR title:big')
    _check(app, 'cat:streams OR serverless')  # the new facet value counts


def test_mid_window_commit_pins_admitted_queries_to_their_generation():
    """Queries admitted before a commit that lands inside the same open
    batching window score against generation 1's corpus and stats; a query
    admitted after it scores against generation 2 — same flush."""
    app = _build(gateway=GatewaySpec(window=WindowPolicy(
        max_window_s=0.5, sparse_qps=0.0, max_batch=64)))
    t0 = app.runtime.clock
    corpus_g1 = app.indexer.live_corpus()
    h1 = app.submit(sq='title:"serverless lucene" OR big', facets=["cat"],
                    t_arrival=t0 + 0.01)
    h2 = app.submit(sq='body:big AND data', facets=["cat"],
                    t_arrival=t0 + 0.02)
    h3 = app.submit("serverless", t_arrival=t0 + 0.03)   # plain, same window
    app.add_documents([("n000", {"title": "streams",
                                 "body": "big data streams",
                                 "cat": "streams"})], t_arrival=t0 + 0.05)
    assert app.commit(t_arrival=t0 + 0.06).body["committed"]
    corpus_g2 = app.indexer.live_corpus()
    h4 = app.submit(sq='cat:streams OR serverless', facets=["cat"],
                    t_arrival=app.runtime.clock + 0.01)
    app.flush(None)
    r1, r2, r3, r4 = h1.response, h2.response, h3.response, h4.response
    assert r1.status == r2.status == r3.status == r4.status == 200
    assert r1.body["generation"] == 1 and r2.body["generation"] == 1
    assert r4.body["generation"] == 2
    _check(app, 'title:"serverless lucene" OR big', resp=r1, corpus=corpus_g1)
    _check(app, 'body:big AND data', resp=r2, corpus=corpus_g1)
    _check(app, 'cat:streams OR serverless', resp=r4, corpus=corpus_g2)
    assert r3.body["ext_ids"]
    # windowed admission still 400s malformed structured bodies
    bad = app.submit(sq='"unbalanced', t_arrival=app.runtime.clock + 0.01)
    assert bad.response.status == 400


def test_cold_lazy_instances_hold_bit_parity(app):
    """Kill EVERY instance: the next structured query cold-starts each leg
    through lazy block-range hydration (only the queried terms' v2 rows)
    and must still match the oracle bit-for-bit, facets and snippets
    included."""
    assert app.query(sq="serverless").status == 200   # warm the fleet first
    killed = 0
    while app.runtime.kill_instance():
        killed += 1
    assert killed > 0
    resp = app.query(sq='"big data" OR title:phrase', facets=["cat"],
                     snippets=True)
    r = _check(app, '"big data" OR title:phrase', resp=resp)
    assert any(p["cold"] for p in r["partitions"])
