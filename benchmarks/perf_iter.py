"""Perf-iteration runner (EXPERIMENTS.md §Perf).

Re-lowers ONE (arch × shape) cell under a named config variant on the
single-pod mesh and records the three roofline terms next to the baseline,
so every hypothesis → change → measure cycle is one command:

    PYTHONPATH=src python -m benchmarks.perf_iter \
        --cell olmoe-1b-7b/train_4k --variant ep

Variants are declared in VARIANTS below (config-field overrides per cell);
results land in benchmarks/results/perf/<cell>__<variant>.json and the
table prints with deltas vs the recorded baseline.

``--kernel-bench`` skips the mesh entirely and microbenches the scoring
accumulators (dense vs sorted vs pruned block-max) on one device over an
n_docs × terms × blocks sweep of fabricated impact-ordered postings.
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import dataclasses
import json

import jax

# (cell) -> variant name -> {config field overrides}
VARIANTS: dict[str, dict[str, dict]] = {
    "olmoe-1b-7b/train_4k": {
        "gspmd-baseline": {"moe_impl": "gspmd"},
        "ep": {"moe_impl": "ep"},
        "ep-dots": {"moe_impl": "ep", "remat_policy": "dots_saveable"},
        "ep-noremat": {"moe_impl": "ep", "remat_policy": "none"},
        "ep-bq1024": {"moe_impl": "ep", "attn_block_q": 1024},
        "ep-bq2048": {"moe_impl": "ep", "attn_block_q": 2048},
    },
    "deepseek-v2-236b/train_4k": {
        "ep-baseline": {"moe_impl": "ep"},
        "gspmd": {"moe_impl": "gspmd"},
        "ep-dots": {"moe_impl": "ep", "remat_policy": "dots_saveable"},
    },
    "stablelm-3b/prefill_32k": {
        "baseline": {},
        "bq1024": {"attn_block_q": 1024},
        "bq2048": {"attn_block_q": 2048},
        "noremat": {"remat_policy": "none"},
    },
    "stablelm-3b/train_4k": {
        "baseline": {},
        "dots": {"remat_policy": "dots_saveable"},
        "noremat": {"remat_policy": "none"},
        "bq1024": {"attn_block_q": 1024},
        "bq2048": {"attn_block_q": 2048},
        "bq2048-dots": {"attn_block_q": 2048,
                        "remat_policy": "dots_saveable"},
    },
    "h2o-danube-1.8b/prefill_32k": {
        "baseline": {},
        "bq2048": {"attn_block_q": 2048},
    },
    "graphcast/ogb_products": {
        "baseline": {},
        "dots": {"remat_policy": "dots_saveable"},
        "noremat": {"remat_policy": "none"},
    },
    "starcoder2-3b/prefill_32k": {
        "baseline": {},
        "kv-replicated": {"shard_kv_proj": False},
        "kv-replicated-bq2048": {"shard_kv_proj": False,
                                 "attn_block_q": 2048},
    },
    "starcoder2-3b/train_4k": {
        "baseline": {},
        "kv-replicated": {"shard_kv_proj": False},
    },
    "h2o-danube-1.8b/train_4k": {
        "baseline": {},
        "kv-replicated": {"shard_kv_proj": False},
    },
    "bert4rec/serve_bulk": {
        "baseline": {},
        "sharded-topk": {"sharded_topk": True},
    },
    "anlessini/serve_q64": {
        "baseline": {},
        "compact-ids": {"compact_ids": True},
        "fused-gather": {"fused_gather": True},
        "compact+fused": {"compact_ids": True, "fused_gather": True},
        "compact+fused+m16": {"compact_ids": True, "fused_gather": True,
                              "max_blocks": 16},
        "pruned": {"accumulator": "pruned"},
        "pruned+compact+fused": {"accumulator": "pruned",
                                 "compact_ids": True, "fused_gather": True},
    },
    "anlessini/serve_q1": {
        "baseline": {},
        "compact+fused": {"compact_ids": True, "fused_gather": True},
        "pruned": {"accumulator": "pruned"},
    },
}

PERF_DIR = os.path.join(os.path.dirname(__file__), "results", "perf")


def build_variant_cell(arch: str, shape: str, over: dict):
    """Rebuild one full-config cell with config overrides applied."""
    from repro.configs import get_arch
    from repro.configs.cells import gnn_cells, lm_cells, recsys_cells
    mod = get_arch(arch)
    rules = mod.rules()
    fam = mod.FAMILY
    if fam == "search":
        # late-bound cell: wrap build() to apply config overrides
        cell = mod.cells(rules)[shape]

        def build(mesh):
            import repro.configs.anlessini as an
            from repro.search.distributed import (abstract_dist_state,
                                                  dist_state_specs,
                                                  make_dist_search_fn)
            import jax.numpy as _jnp
            from repro.configs.cells import SDS
            from jax.sharding import PartitionSpec as _P
            axes = tuple(rules.batch) + ("model",)
            n_parts = 1
            for ax in axes:
                n_parts *= mesh.shape[ax]
            cfg = dataclasses.replace(an.full_config(n_parts), **over)
            fn = make_dist_search_fn(cfg, axes, mesh=mesh)
            Q = an.SHAPES[shape]["Q"]
            args = (abstract_dist_state(cfg),
                    SDS((Q, cfg.max_terms), _jnp.int32),
                    SDS((Q, cfg.max_terms), _jnp.float32))
            specs = (dist_state_specs(axes), _P(None, None), _P(None, None))
            return fn, args, specs

        cell.build = build
        return cell
    if fam == "lm":
        cfg = mod.full_config(unroll=True,
                              ep_batch_axes=tuple(rules.batch))
        cfg = dataclasses.replace(cfg, **over)
        return lm_cells(arch, cfg, rules)[shape]
    if fam == "gnn":
        from repro.configs.cells import GNN_SHAPES
        cfg = mod.full_config(d_feat=GNN_SHAPES[shape]["d_feat"], unroll=True)
        cfg = dataclasses.replace(cfg, **over)
        return gnn_cells(arch, cfg, rules)[shape]
    if fam == "recsys":
        cfg = dataclasses.replace(mod.full_config(unroll=True), **over)
        return recsys_cells(arch, cfg, rules)[shape]
    raise ValueError(fam)


def kernel_bench() -> int:
    """Single-device microbench of the three scoring accumulators over
    fabricated impact-ordered postings (``synth_pruned_blocks`` — no index
    build, no mesh):

      dense   impacts → scatter-add into a (n_docs+1,) accumulator → top_k
      sorted  impacts → sort-and-segment-sum → top-k (``accumulate_sorted``)
      pruned  fused ``bm25_pruned_topk`` Pallas pass (block-max WAND)

    Wall times here are CPU interpret-mode numbers — the pruned kernel does
    dense-superset work on this backend, so read the ``touched`` column (the
    kernel's own kept-block count) for the HBM story; ``benchmarks.run
    --only b9b`` turns the same sweep into regression-gated roofline rows.
    """
    import functools
    import time

    import jax.numpy as jnp
    import numpy as np
    from repro.data.corpus import synth_pruned_blocks
    from repro.kernels.ops import bm25_pruned_topk
    from repro.search.bm25 import accumulate_dense, accumulate_sorted

    k = 10
    params = (jnp.float32(0.9), jnp.float32(0.4), jnp.float32(12.0))

    @functools.partial(jax.jit, static_argnames=("n_docs", "strategy"))
    def score(tf, dl, docs, idf_q, *, n_docs, strategy):
        k1, b, avgdl = params
        tff = tf.astype(jnp.float32)
        denom = tff + k1 * (1.0 - b + b * dl / avgdl)
        imp = jnp.where((docs < n_docs) & (tf > 0),
                        idf_q[:, None, None] * tff / denom, 0.0)
        if strategy == "sorted":
            return accumulate_sorted(docs, imp, n_docs, k)
        return jax.lax.top_k(accumulate_dense(docs, imp, n_docs), k)

    def timed(fn):
        jax.block_until_ready(fn())              # warm: compile + caches
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        return out, (time.perf_counter() - t0) * 1e3

    print(f"{'cell':24s} {'dense ms':>9s} {'sorted ms':>10s} "
          f"{'pruned ms':>10s} {'touched':>12s}")
    for n_docs in (100_000, 1_000_000):
        for T, M in ((1, 32), (2, 32), (2, 8)):
            raw = synth_pruned_blocks(7 + T + M, n_terms=T, max_blocks=M,
                                      n_docs=n_docs, zipf_a=1.3)
            tf, dl, docs, idf_q, ub, valid = [jnp.asarray(x) for x in raw]
            (_, t_d) = timed(lambda: score(tf, dl, docs, idf_q,
                                           n_docs=n_docs, strategy="dense"))
            (_, t_s) = timed(lambda: score(tf, dl, docs, idf_q,
                                           n_docs=n_docs, strategy="sorted"))
            out, t_p = timed(lambda: bm25_pruned_topk(
                tf, dl, docs, idf_q, ub, valid, *params,
                k=k, n_docs=n_docs))
            touched = int(out[2])
            n_valid = int(np.asarray(raw[5]).sum())
            cell = f"n{n_docs // 1000}k_T{T}_M{M}"
            print(f"{cell:24s} {t_d:9.2f} {t_s:10.2f} {t_p:10.2f} "
                  f"{touched:5d}/{n_valid} blk")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None)
    ap.add_argument("--variant", default=None,
                    help="one variant (default: all declared for the cell)")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--kernel-bench", action="store_true",
                    help="microbench dense/sorted/pruned scoring on one "
                         "device (no mesh, no --cell)")
    args = ap.parse_args()

    if args.kernel_bench:
        return kernel_bench()
    if not args.cell:
        ap.error("--cell is required (unless --kernel-bench)")

    from repro.launch.dryrun import run_cell
    from repro.launch.mesh import make_production_mesh

    arch, shape = args.cell.split("/")
    variants = VARIANTS.get(args.cell, {"baseline": {}})
    if args.variant:
        variants = {args.variant: variants[args.variant]}

    mesh = make_production_mesh()
    os.makedirs(PERF_DIR, exist_ok=True)
    rows = []
    for vname, over in variants.items():
        cell = build_variant_cell(arch, shape, over)
        name = f"{args.cell}@{vname}"
        rec = run_cell(name, cell, mesh, "pod1_16x16", PERF_DIR,
                       force=args.force)
        rows.append((vname, rec))

    print(f"\n{'variant':18s} {'flops/dev':>11s} {'bytes/dev':>11s} "
          f"{'coll B/dev':>11s} {'temp GiB':>9s} {'compile s':>9s}")
    for vname, rec in rows:
        if not rec.get("ok"):
            print(f"{vname:18s} FAIL {rec.get('error', '')[:70]}")
            continue
        pd = rec["per_device"]
        print(f"{vname:18s} {pd['flops']:11.3e} {pd['bytes_accessed']:11.3e} "
              f"{rec['collectives']['total_bytes']:11.3e} "
              f"{pd['temp_bytes'] / 2**30:9.2f} {rec['compile_s']:9.1f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
