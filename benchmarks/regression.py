"""Bench-regression gate: diff BENCH_pr.json against the committed baseline.

    PYTHONPATH=src python -m benchmarks.regression BENCH_baseline.json \
        BENCH_pr.json [--summary $GITHUB_STEP_SUMMARY]

Both files must come from ``benchmarks.run --det --seed 0`` — the modeled
exec clock makes the gated metrics machine-independent, so the committed
baseline is comparable across CI runners and laptops alike (regenerate it
with ``--fast --det --seed 0 --only
b1,b3,b6,b6b,b7,b8,b9b,b10,b11,b12,b13,b14,b15,b16 --json
BENCH_baseline.json``
whenever a deliberate perf change moves a metric).

Gated metrics (lower is better for all of them):

* B6/B7 gateway latencies     — fail on a regression > 25%
* B8 refresh/rollover latency — fail on a regression > 25%
* B9b pruned-scoring model    — fail on blocks-touched fraction or
  modeled per-query ms regression > 25% (model rows are µs-scale, so
  their absolute floor is 1e-4 ms, not the gateway 0.2 ms)
* B11 NRT gateway latencies   — fail on a regression > 25%
* B12 skewed-fleet latencies  — fail on a regression > 25%
* B13 cold-start profile      — fail on cold-hydration/cold-latency p50
  regression > 25% (both the full-hydrate reference and the lazy path:
  a layout change that quietly re-fattens the partial read set must
  trip the lazy rows, one that slows eager streaming trips the full
  rows) or backfill GB·s regression > 15%
* B14 hybrid-fleet latencies  — fail on a per-mode p99 regression > 25%
  or on the dense-vs-sparse p99 ratio drifting past 25% (the "dense is
  not a second-class tier" claim)
* B15 overload survival      — fail on an admitted-under-burst p99 or
  staggered-rollover ratio regression > 25%
* B16 structured queries     — fail on a bag-of-words or structured p99
  regression > 25%, or on the structured-vs-bag p99 ratio drifting past
  25% (the "structured costs at most 2× bag-of-words" claim)
* B7/B11/B12/B13/B14/B15/B16 $-and-GB·s — fail on a regression > 15%

B14, B15 and B16 also carry exactness bits (sparse-vs-oracle, dense uint32
bitwise, hybrid fused-score, race-vs-serialized-oracle, shed-billed-zero,
retry-storm-free, structured top-k/facet/phrase/snippet parity) gated by
PARITY_GATES: the PR value must be exactly 1 — parity is pass/fail, a
"25% regression" of a bit is meaningless.

A tiny absolute floor per metric class absorbs float jitter without hiding
real regressions (a forgotten merge-cost term or a doubled invocation count
clears the floor by orders of magnitude). Improvements never fail the gate.
The per-metric table goes to stdout and, with ``--summary``, to the GitHub
job summary as markdown.
"""

from __future__ import annotations

import argparse
import json
import sys

# (metric name, limit as max allowed +delta fraction, absolute floor)
LATENCY_LIMIT, COST_LIMIT = 0.25, 0.15
LATENCY_FLOOR_MS, COST_FLOOR = 0.2, 1e-6

GATES: list[tuple[str, float, float]] = [
    ("partitions_1_gw_p50_ms", LATENCY_LIMIT, LATENCY_FLOOR_MS),
    ("partitions_2_gw_p50_ms", LATENCY_LIMIT, LATENCY_FLOOR_MS),
    ("partitions_4_gw_p50_ms", LATENCY_LIMIT, LATENCY_FLOOR_MS),
    ("unhedged_R1_gw_p50_ms", LATENCY_LIMIT, LATENCY_FLOOR_MS),
    ("hedged_R2_gw_p50_ms", LATENCY_LIMIT, LATENCY_FLOOR_MS),
    ("hedged_R2_gw_p99_ms", LATENCY_LIMIT, LATENCY_FLOOR_MS),
    ("unhedged_R1_dollars_per_1k_q", COST_LIMIT, COST_FLOOR),
    ("hedged_R2_dollars_per_1k_q", COST_LIMIT, COST_FLOOR),
    ("refresh_rollover_ms", LATENCY_LIMIT, LATENCY_FLOOR_MS),
    # B9b rows are modeled HBM-roofline values (µs-scale): floors are a
    # fraction tick / 1e-4 ms, not the gateway-latency 0.2 ms floor
    ("b9b_pruned_blocks_touched_frac_100k", LATENCY_LIMIT, 0.02),
    ("b9b_pruned_blocks_touched_frac_1m", LATENCY_LIMIT, 0.02),
    ("b9b_pruned_model_ms_100k", LATENCY_LIMIT, 1e-4),
    ("b9b_pruned_model_ms_1m", LATENCY_LIMIT, 1e-4),
    ("b9b_dense_model_ms_1m", LATENCY_LIMIT, 1e-4),
    ("b11_steady_gw_p99_ms", LATENCY_LIMIT, LATENCY_FLOOR_MS),
    ("b11_rollover_gw_p99_ms", LATENCY_LIMIT, LATENCY_FLOOR_MS),
    ("b11_commit_p50_ms", LATENCY_LIMIT, LATENCY_FLOOR_MS),
    ("b11_dollars_per_1k_q", COST_LIMIT, COST_FLOOR),
    ("b12_hetero_gw_p50_ms", LATENCY_LIMIT, LATENCY_FLOOR_MS),
    ("b12_hetero_gw_p99_ms", LATENCY_LIMIT, LATENCY_FLOOR_MS),
    ("b12_hetero_dollars_per_1k_q", COST_LIMIT, COST_FLOOR),
    ("b12_uniform_R2_dollars_per_1k_q", COST_LIMIT, COST_FLOOR),
    # B13 cold-start profile: hydration p50s are the tentpole metric (the
    # 1/3 ratio itself is asserted in bench-smoke); latency rows catch
    # end-to-end drift; backfill GB·s is a cost line like $/1k
    ("b13_full_cold_p50_ms", LATENCY_LIMIT, LATENCY_FLOOR_MS),
    ("b13_lazy_cold_p50_ms", LATENCY_LIMIT, LATENCY_FLOOR_MS),
    ("b13_full_cold_latency_p50_ms", LATENCY_LIMIT, LATENCY_FLOOR_MS),
    ("b13_lazy_cold_latency_p50_ms", LATENCY_LIMIT, LATENCY_FLOOR_MS),
    ("b13_backfill_gb_s", COST_LIMIT, COST_FLOOR),
    # B14 hybrid fleet: per-mode tails + cost, and the cross-tier p99
    # ratio (dimensionless — floor is a ratio tick, not a ms floor)
    ("b14_sparse_gw_p99_ms", LATENCY_LIMIT, LATENCY_FLOOR_MS),
    ("b14_dense_gw_p99_ms", LATENCY_LIMIT, LATENCY_FLOOR_MS),
    ("b14_hybrid_gw_p99_ms", LATENCY_LIMIT, LATENCY_FLOOR_MS),
    ("b14_dense_p99_vs_sparse", LATENCY_LIMIT, 0.05),
    ("b14_sparse_dollars_per_1k_q", COST_LIMIT, COST_FLOOR),
    ("b14_dense_dollars_per_1k_q", COST_LIMIT, COST_FLOOR),
    ("b14_hybrid_dollars_per_1k_q", COST_LIMIT, COST_FLOOR),
    # B15 overload survival: the admitted tail under burst + shedding, the
    # staggered-rollover ratio (dimensionless floor), and the all-phase
    # bill; shed-rate bounds and retry-storm-freedom are hard-asserted in
    # bench-smoke (they're pass/fail claims, not drifting metrics)
    ("b15_admitted_gw_p99_ms", LATENCY_LIMIT, LATENCY_FLOOR_MS),
    ("b15_rollover_p99_vs_steady", LATENCY_LIMIT, 0.05),
    ("b15_dollars_per_1k_q", COST_LIMIT, COST_FLOOR),
    # B16 structured queries: both paths' tails + cost, and the
    # structured-vs-bag p99 ratio (dimensionless floor); parity is all
    # bits, gated below
    ("b16_bag_gw_p99_ms", LATENCY_LIMIT, LATENCY_FLOOR_MS),
    ("b16_structured_gw_p99_ms", LATENCY_LIMIT, LATENCY_FLOOR_MS),
    ("b16_structured_p99_vs_bag", LATENCY_LIMIT, 0.05),
    ("b16_bag_dollars_per_1k_q", COST_LIMIT, COST_FLOOR),
    ("b16_structured_dollars_per_1k_q", COST_LIMIT, COST_FLOOR),
]

# exactness bits: the PR value must be exactly 1 (baseline drift is
# irrelevant — these are correctness claims, not perf metrics)
PARITY_GATES: list[str] = [
    "b14_sparse_topk_equals_oracle",
    "b14_dense_bitwise_equal",
    "b14_hybrid_topk_equals_oracle",
    "b15_race_topk_equals_serialized_oracle",
    "b15_shed_billed_zero",
    "b15_retry_storm_free",
    "b16_structured_topk_bitwise_equal",
    "b16_facets_equal_oracle",
    "b16_phrase_sets_equal_oracle",
    "b16_snippets_cover_matched_terms",
]


def _load(path: str) -> dict[str, float]:
    with open(path) as f:
        return {r["name"]: r["value"] for r in json.load(f)}


def compare(baseline: dict[str, float], pr: dict[str, float]
            ) -> tuple[list[dict], bool]:
    rows, failed = [], False
    for name, limit, floor in GATES:
        if name not in baseline or name not in pr:
            rows.append({"name": name, "status": "MISSING",
                         "base": baseline.get(name), "pr": pr.get(name),
                         "delta_pct": None, "limit_pct": limit * 100})
            failed = True       # a silently vanished metric is a regression
            continue
        base, cur = float(baseline[name]), float(pr[name])
        delta = cur - base
        delta_pct = (delta / base * 100.0) if base else float("inf")
        bad = delta > floor and delta > limit * base
        failed = failed or bad
        rows.append({"name": name, "base": base, "pr": cur,
                     "delta_pct": delta_pct, "limit_pct": limit * 100,
                     "status": "FAIL" if bad else "ok"})
    for name in PARITY_GATES:
        if name not in pr:
            rows.append({"name": name, "status": "MISSING",
                         "base": baseline.get(name), "pr": None,
                         "delta_pct": None, "limit_pct": 0.0})
            failed = True
            continue
        cur = float(pr[name])
        bad = cur != 1.0
        failed = failed or bad
        rows.append({"name": name, "base": baseline.get(name), "pr": cur,
                     "delta_pct": None, "limit_pct": 0.0,
                     "status": "FAIL" if bad else "ok"})
    return rows, failed


def render(rows: list[dict], markdown: bool) -> str:
    head = ["metric", "baseline", "PR", "Δ%", "limit", "status"]
    body = []
    for r in rows:
        dp = "—" if r["delta_pct"] is None else f"{r['delta_pct']:+.1f}%"
        body.append([r["name"],
                     "—" if r["base"] is None else f"{r['base']:g}",
                     "—" if r["pr"] is None else f"{r['pr']:g}",
                     dp,
                     "==1" if r["limit_pct"] == 0.0
                     else f"+{r['limit_pct']:.0f}%",
                     r["status"]])
    if markdown:
        lines = ["| " + " | ".join(head) + " |",
                 "|" + "---|" * len(head)]
        lines += ["| " + " | ".join(row) + " |" for row in body]
        return "\n".join(lines)
    widths = [max(len(h), *(len(row[i]) for row in body))
              for i, h in enumerate(head)]
    lines = ["  ".join(h.ljust(w) for h, w in zip(head, widths))]
    lines += ["  ".join(c.ljust(w) for c, w in zip(row, widths))
              for row in body]
    return "\n".join(lines)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline")
    ap.add_argument("pr")
    ap.add_argument("--summary", default=None, metavar="PATH",
                    help="append the markdown table here "
                         "(e.g. $GITHUB_STEP_SUMMARY)")
    args = ap.parse_args()
    rows, failed = compare(_load(args.baseline), _load(args.pr))
    print(render(rows, markdown=False))
    if args.summary:
        with open(args.summary, "a") as f:
            f.write("## Bench regression vs committed baseline\n\n")
            f.write(render(rows, markdown=True) + "\n\n")
            f.write(("**FAIL** — regression past the limit\n" if failed
                     else "all gated metrics within limits\n"))
    if failed:
        print("\nFAIL: regression past the limit "
              f"(latency > {LATENCY_LIMIT:.0%}, cost > {COST_LIMIT:.0%})")
        return 1
    print("\nok: all gated metrics within limits")
    return 0


if __name__ == "__main__":
    sys.exit(main())
