"""Benchmark harness — one benchmark per paper table/claim.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--det] [--seed 0]
                                            [--only b1,b7]
                                            [--json BENCH_pr.json]

Paper claims reproduced (Lin, "A Prototype of Serverless Lucene", 2020):
  B1  end-to-end warm latency < 300 ms; cold vs warm split        (§2)
  B2  ~10× faster than Crane & Lin '17 KV-postings design         (§2)
  B3  ~100,000 queries per US dollar at 2 GB × 300 ms             (§2)
  B4  cost fungibility: 10 QPS × 10,000 s == 100 QPS × 1,000 s    (§2)
  B5  index size: ~700 MB for 8.8 M passages (bytes/doc parity)   (§2)
  B6  document partitioning scale-out (§3) — latency vs partitions
  B6b micro-batched (Q>1) handler invocations — per-query amortization
  B7  replicated partitions + hedged scatter legs — p50/p99 and
      $/1k-queries, unhedged R=1 vs hedged R=2, under cold injection
  B8  batch reindex + zero-downtime switch-over (§3) — deterministic
      virtual-clock rollover latencies (regression-gated)
  B9  roofline summary over the dry-run artifacts (if present)
  B9b fused block-max pruned scoring vs dense on the modeled HBM
      roofline — blocks-touched fraction, bytes/query and modeled
      per-query latency at 100k/1M-doc partitions, bitwise parity
      with the unpruned oracle (regression-gated under --det)
  B10 cost-ledger fleet autoscaler on a bursty diurnal arrival
      pattern — $/1k and p99 at fixed-R=1, fixed-R=2, autoscaled
  B11 near-real-time indexing: sustained query traffic at fixed QPS
      while committing delta batches — rollover p99 vs steady state,
      $/1k including writer invocations, post-commit parity vs a
      from-scratch oracle rebuild
  B12 skew-aware serving: Zipf-skewed partition load through the
      gateway's adaptive micro-batch window — heterogeneous autoscaled
      fleet (head partition R=3, tails R=1) vs uniform R=2 on $/1k and
      p99, top-k pinned to per-generation oracles across mid-run commits
  B13 cold-start profile: full-segment hydration vs lazy block-range
      hydration (superindex + queried terms' posting blocks only, backfill
      off the critical path on its own ledger line) — cold hydration p50s,
      end-to-end cold latency, oracle + bitwise parity, re-derived
      hedge/provision constants (regression-gated under --det)
  B14 hybrid retrieval: sparse vs dense vs hybrid on ONE skewed fleet
      (dense-vector tier next to BM25 on the same partitions) — per-mode
      p50/p99 and $/1k over the identical burst schedule, dense scores
      uint32-bit-identical to the kernel reference oracle, hybrid RRF
      fusion equal to the two-oracle fusion (regression-gated)
  B15 overload survival: admission backpressure (429 + Retry-After,
      billed to nothing) through a 4× burst, bounded-backoff retries
      with no retry storm, two racing writers converging to the
      serialized-oracle answer, and a staggered mid-traffic rollover
      (regression-gated under --det)
  B16 structured queries: fielded/phrase/facet/snippet mix through the
      windowed structured (format-v2) fleet vs the bag-of-words baseline
      on the same fleet — per-phase p50/p99 and $/1k, top-k bit-identical
      to StructuredOracleSearcher, facets equal to the exact dict twin,
      phrase result sets exact, snippets covering every matched term
      (regression-gated under --det)

Determinism: every RNG is seeded per-benchmark from ``--seed`` (so the
bench-smoke gate and the CI regression diff don't depend on which
benchmarks ran before, or on ``--only`` selection), and ``--det`` swaps
measured jitted-eval wall time for the modeled exec clock
(``SearchConfig.sim_exec_s``) in the fleet benchmarks (B6/B6b/B7/B10) —
latencies and ledger charges then reproduce bit-for-bit across machines,
which is what lets CI diff BENCH_pr.json against a committed baseline
with tight thresholds.

Output: "name,value,unit,derived" CSV lines + a human summary; ``--json``
additionally writes the rows as a JSON list (the CI bench-smoke artifact).
"""

from __future__ import annotations

import argparse
import json
import random
import time

import numpy as np

ROWS: list[tuple] = []

# set from --det in main(): fleet benchmarks use the modeled exec clock
DET = False
SEED = 0


def _seed_all(seed: int) -> None:
    """Reset the global RNGs. Called before EVERY benchmark so each is
    deterministic in isolation — a run with ``--only b7`` sees exactly the
    RNG streams a full run does."""
    random.seed(seed)
    np.random.seed(seed)


def _fleet_search_cfg():
    """SearchConfig for the fleet benchmarks: modeled exec clock under
    --det (machine-independent latencies/costs), measured otherwise. The
    writer model (sim_write_s) rides along so B11's commit costs and
    rollover latencies are just as machine-independent."""
    from repro.search.searcher import SearchConfig
    return (SearchConfig(sim_exec_s=0.002, sim_write_s=0.02)
            if DET else None)


def emit(name: str, value, unit: str, derived: str = "") -> None:
    ROWS.append((name, value, unit, derived))
    print(f"  {name:42s} {value!s:>12} {unit:12s} {derived}")


def bench_latency(n_docs: int, n_queries: int) -> None:
    from repro.core.runtime import RuntimeConfig
    from repro.data.corpus import synth_corpus, synth_queries
    from repro.search.service import build_search_app

    print("\nB1: end-to-end latency (paper: <300 ms warm, interactive)")
    docs = synth_corpus(n_docs, vocab=max(2000, n_docs // 2), seed=0)
    queries = synth_queries(docs, n_queries, seed=1)
    app = build_search_app(docs, runtime_config=RuntimeConfig())
    t = 0.0
    for q in queries:
        app.query(q, t_arrival=t)
        t = app.runtime.clock + 0.05          # 20 QPS steady state
    recs = list(app.runtime.records)
    warm = [r.latency_s for r in recs if not r.cold]
    cold = [r.latency_s for r in recs if r.cold]
    emit("warm_latency_p50_ms", round(float(np.median(warm)) * 1e3, 2), "ms",
         "paper budget: <300")
    emit("warm_latency_p99_ms",
         round(float(np.quantile(warm, 0.99)) * 1e3, 2), "ms")
    emit("cold_latency_p50_ms",
         round(float(np.median(cold)) * 1e3, 2) if cold else 0, "ms",
         "hydration + container boot")
    emit("warm_under_300ms",
         int(100 * np.mean(np.asarray(warm) < 0.3)), "%", "pass if 100")


def bench_baseline(n_docs: int, n_queries: int) -> None:
    from repro.baselines.kvstore_search import KVPostingsIndex
    from repro.data.corpus import synth_corpus, synth_queries
    from repro.search.service import build_search_app

    print("\nB2: vs Crane & Lin '17 (paper: ~3 s → <300 ms, ≥10×)")
    docs = synth_corpus(n_docs, vocab=max(2000, n_docs // 2), seed=0)
    queries = synth_queries(docs, n_queries, seed=2)
    kv = KVPostingsIndex()
    kv.build(docs)
    kv_lat = []
    for q in queries:
        _, s = kv.search(q)
        kv_lat.append(s)
    app = build_search_app(docs)
    t = 0.0
    for q in queries:
        app.query(q, t_arrival=t)
        t = app.runtime.clock + 0.05
    warm = [r.latency_s for r in app.runtime.records if not r.cold]
    kv_p50 = float(np.median(kv_lat))
    our_p50 = float(np.median(warm))
    emit("kvstore_baseline_p50_ms", round(kv_p50 * 1e3, 1), "ms",
         "Crane&Lin'17 design")
    emit("anlessini_warm_p50_ms", round(our_p50 * 1e3, 1), "ms")
    emit("speedup_x", round(kv_p50 / max(our_p50, 1e-9), 1), "x",
         "paper: ~10x")


def bench_cost() -> None:
    from repro.core.cost import (CostLedger, Invocation, fungibility_check,
                                 paper_headline_cost)

    print("\nB3/B4: Lambda cost model (paper: 100k q/$; load fungibility)")
    emit("queries_per_dollar_2GB_300ms", round(paper_headline_cost()), "q/$",
         "paper: 100,000")
    a, b = fungibility_check(10, 10_000, 100, 1_000)
    emit("fungibility_10qps_10000s", round(a, 4), "$")
    emit("fungibility_100qps_1000s", round(b, 4), "$", "must be equal")
    led = CostLedger()
    for _ in range(1000):
        led.charge(Invocation(2 << 30, 0.3))
    emit("ledger_1000q_cost", round(led.compute_dollars, 4), "$",
         "≈ 0.01 (1000 q at 100k q/$)")


def bench_index_size(n_docs: int) -> None:
    from repro.data.corpus import synth_corpus
    from repro.index.builder import IndexWriter, write_segment

    print("\nB5: index size (paper: ~700 MB for 8.8 M passages ≈ 83 B/doc)")
    docs = synth_corpus(n_docs, vocab=max(2000, n_docs // 2), seed=0)
    w = IndexWriter()
    w.add_many(docs)
    packed = w.pack()
    seg = write_segment(packed)
    total = sum(len(seg.files[f]) for f in seg.list())
    n_postings = int((packed.block_docs < packed.meta.n_docs).sum())
    pad_frac = 1 - n_postings / packed.block_docs.size
    emit("index_bytes", total, "B")
    emit("index_bytes_per_doc", round(total / n_docs, 1), "B/doc",
         "paper: ~83 B/doc")
    emit("index_bytes_per_posting", round(total / n_postings, 2), "B/posting",
         f"pad={pad_frac:.0%}; Lucene ≈1.4 B/posting (compressed)")
    # MS MARCO: 8.8M passages ≈ 495M postings; at scale padding amortizes
    # toward the 5 B/posting floor of the uncompressed blocked format.
    emit("extrapolated_msmarco_MB",
         round(5.0 * 495e6 / 2 ** 20), "MB",
         "paper: ~700 MB (ours uncompressed: dense-blocked trade-off)")


def bench_partitions(n_docs: int, n_queries: int) -> None:
    print("\nB6: document partitioning (paper §3 scale-out path)")
    from repro.core.partition import FleetSpec
    from repro.core.runtime import RuntimeConfig
    from repro.data.corpus import synth_corpus, synth_queries
    from repro.search.service import build_partitioned_search_app

    docs = synth_corpus(n_docs, vocab=max(2000, n_docs // 2), seed=0)
    queries = synth_queries(docs, n_queries, seed=3)
    for p in (1, 2, 4):
        app = build_partitioned_search_app(docs, FleetSpec(
            n_parts=p, runtime_config=RuntimeConfig(),
            search_config=_fleet_search_cfg()))
        lats = []
        for q in queries:
            r = app.query(q, k=10, t_arrival=app.runtime.clock + 0.05,
                          fetch_docs=False)
            lats.append(r.latency_s)
        # new key: measured at the gateway (incl. proxy overhead, excl. doc
        # fetch) — NOT comparable to pre-refactor partitions_{p}_p50_ms,
        # which was raw scatter latency including per-partition doc fetch
        emit(f"partitions_{p}_gw_p50_ms",
             round(float(np.median(lats)) * 1e3, 1), "ms",
             f"fleet={app.runtime.fleet_size}")


def bench_batched(n_docs: int, n_queries: int) -> None:
    """Micro-batching: Q queries per invocation vs Q invocations.

    The vmapped scoring fn evaluates the whole batch in one device call,
    so per-query cost amortizes invocation + gateway overhead — the knob
    the gateway uses to absorb concurrent traffic."""
    print("\nB6b: batched (Q>1) handler invocations vs one-at-a-time")
    from repro.core.partition import FleetSpec
    from repro.core.runtime import RuntimeConfig
    from repro.data.corpus import synth_corpus, synth_queries
    from repro.search.service import build_partitioned_search_app

    docs = synth_corpus(n_docs, vocab=max(2000, n_docs // 2), seed=0)
    queries = synth_queries(docs, n_queries, seed=4)
    app = build_partitioned_search_app(docs, FleetSpec(
        n_parts=2, runtime_config=RuntimeConfig(),
        search_config=_fleet_search_cfg()))
    for Q in (1, 8):
        batches = [queries[i:i + Q] for i in range(0, len(queries), Q)]
        batches = [b for b in batches if len(b) == Q]
        if not batches:                   # fewer queries than one Q-batch
            emit(f"batchQ{Q}_per_query_ms", float("nan"), "ms/q",
                 f"needs >= {Q} queries")
            continue
        app.query(batches[0], k=10, fetch_docs=False)     # warm + compile
        n_inv0 = len(app.runtime.records)
        lats = []
        for b in batches:
            r = app.query(b, k=10, t_arrival=app.runtime.clock + 0.05,
                          fetch_docs=False)
            lats.append(r.latency_s)
        n_inv = len(app.runtime.records) - n_inv0
        per_q = float(np.median(lats)) / Q
        emit(f"batchQ{Q}_per_query_ms", round(per_q * 1e3, 2), "ms/q",
             f"{n_inv} invocations for {len(batches) * Q} queries")


def bench_hedged_tail(n_docs: int, n_queries: int) -> None:
    """B7: replicated partitions + hedged scatter legs under cold injection.

    One partition's primary pool is repeatedly killed mid-run; unhedged
    (R=1) every such query eats a full cold start at the fan-out max, while
    hedged (R=2) the projected cold start triggers a backup leg on the warm
    replica pool and the tail stays flat. Both legs bill (no cancellation in
    FaaS), so $/1k-queries shows the hedging tax next to the p99 it buys.

    Reproduce the tail plot:
        PYTHONPATH=src python -m benchmarks.run --fast --only b7
    then plot the latency CDF from ``app.gateway.latencies[("GET",
    "/search")]`` per config (p50/p99 rows below are its quantiles); bump
    --docs/--queries for smoother tails.

    Read the "hedge tax" column — not the raw $/1k difference — for the
    cost of hedging: exec_s is measured wall time of the jitted eval, so at
    small N run-to-run jit noise between the two configs can exceed the
    (tiny, warm) backup legs' systematic cost.
    """
    print("\nB7: hedged scatter legs (R=2) vs unhedged (R=1), 1 cold partition")
    from repro.core.partition import FleetSpec, HedgePolicy, ReplicationSpec
    from repro.core.runtime import RuntimeConfig, nearest_rank_percentiles
    from repro.data.corpus import synth_corpus, synth_queries
    from repro.search.oracle import OracleSearcher
    from repro.search.service import build_partitioned_search_app

    docs = synth_corpus(n_docs, vocab=max(2000, n_docs // 2), seed=0)
    queries = synth_queries(docs, n_queries, seed=5)
    n_warm = max(8, len(queries) // 5)
    warmup, measured = queries[:n_warm], queries[n_warm:]
    kill_every = 8
    p99s, results = {}, {}
    for replicas, hedge in ((1, None), (2, HedgePolicy())):
        app = build_partitioned_search_app(docs, FleetSpec(
            n_parts=4,
            replication=ReplicationSpec(replicas=replicas, hedge=hedge),
            runtime_config=RuntimeConfig(),
            search_config=_fleet_search_cfg()))
        app.warm()
        for q in warmup:                   # unmeasured: hydrate + history
            app.query(q, k=10, t_arrival=app.runtime.clock + 0.05,
                      fetch_docs=False)
        # cost and latency over the SAME measured window — warm-up spend
        # scales with R and would otherwise pollute the $/1k comparison
        led = app.runtime.ledger
        n0 = len(app.gateway.latencies[("GET", "/search")])
        dollars0, hedge0 = led.total_dollars, led.hedge_dollars
        out = []
        for i, q in enumerate(measured):
            if i % kill_every == 0:        # partition 0 goes cold, replicas warm
                app.runtime.kill_instance(fn=app.fn_names[0])
            r = app.query(q, k=10, t_arrival=app.runtime.clock + 0.05,
                          fetch_docs=False)
            out.append((tuple(r.body["ids"]),
                        tuple(round(s, 6) for s in r.body["scores"])))
        results[replicas] = out
        p = nearest_rank_percentiles(
            app.gateway.latencies[("GET", "/search")][n0:], qs=(0.5, 0.99))
        p99s[replicas] = p[0.99]
        dollars = led.total_dollars - dollars0
        tag = f"hedged_R{replicas}" if hedge else f"unhedged_R{replicas}"
        emit(f"{tag}_gw_p50_ms", round(p[0.5] * 1e3, 1), "ms")
        emit(f"{tag}_gw_p99_ms", round(p[0.99] * 1e3, 1), "ms",
             f"{sum(rec.hedged for rec in app.runtime.records)} backup legs")
        emit(f"{tag}_dollars_per_1k_q",
             round(dollars / len(measured) * 1000.0, 6), "$",
             f"hedge tax ${led.hedge_dollars - hedge0:.6f}")
    emit("hedged_p99_improvement",
         round(100 * (1 - p99s[2] / p99s[1])), "%", "target: >= 30")
    # hedging must not change results: bit-identical to the unhedged run...
    emit("hedged_results_bitwise_equal", int(results[1] == results[2]),
         "bool", "same PackedIndex behind every replica")
    # ...and both equal to the exact-BM25 oracle's ranking
    oracle = OracleSearcher(docs)
    ok = all(list(ids) == [d for d, _ in oracle.search(q, k=10)]
             for q, (ids, _) in zip(measured, results[2]))
    emit("hedged_topk_equals_oracle", int(ok), "bool")


def bench_autoscale(n_docs: int, n_queries: int) -> None:
    """B10: the $/1k-queries vs. p99 operating point as a control loop.

    A bursty diurnal arrival pattern — long quiet stretches (one query
    every ~10 min, an order of magnitude past the 60 s instance idle
    timeout, with a 15 s virtual timer ticking the controller so
    keep-alive pings land every ~30-45 s) punctuated by 25 QPS bursts
    with cold injection (a primary pool killed every 8th burst query) —
    drives three fleets over the SAME schedule:

      fixed R=1   no replicas: cheap, but every kill lands a cold start
                  at the fan-out max (the p99 blowup B7 documents)
      fixed R=2   PR 2's hedged fleet + keep-warm pings: flat p99, but the
                  standby pools bill keep-alive spend through every quiet
                  stretch whether or not a hedge ever fires
      autoscaled  FleetController: scales each partition 1↔2 against the
                  ledger — replicas exist (and get keep-warm pings) only
                  around the bursts that need them; hedge-aware routing
                  sends primaries around killed pools

    All three run the same keep-alive policy (ping a pool the provider
    would reap), so the comparison isolates SCALING, not warmth. Targets:
    autoscaled p99 within 2× of fixed-R=2 while cutting $/1k by ≥20%, and
    merged top-k bit-identical across fleets and equal to the exact-BM25
    oracle throughout scale events.
    """
    print("\nB10: autoscaled fleet vs fixed R=1 / R=2, bursty diurnal load")
    from repro.core.autoscale import AutoscalePolicy
    from repro.core.partition import FleetSpec, HedgePolicy, ReplicationSpec
    from repro.core.runtime import RuntimeConfig, nearest_rank_percentiles
    from repro.data.corpus import synth_corpus, synth_queries
    from repro.search.oracle import OracleSearcher
    from repro.search.service import build_partitioned_search_app

    n_parts = 4
    docs = synth_corpus(n_docs, vocab=max(2000, n_docs // 2), seed=0)
    queries = synth_queries(docs, n_queries, seed=6)
    n_warm = 8
    warmup, measured = queries[:n_warm], queries[n_warm:]

    # the diurnal schedule: (gap_s, kill_partition | None) per measured
    # query — quiet/burst/quiet/burst quarters. Quiet stretches are LONG in
    # virtual time (one query per ~10 min, hours per phase): standby upkeep
    # accrues with wall time while a scale-up's one-off rehydration does
    # not, and that asymmetry is the whole operating-point argument. Kills
    # land only inside bursts, and only after the burst is old enough for a
    # controller to have reacted (j >= 16 at 25 QPS ≈ 600 ms in).
    rng = np.random.default_rng(SEED + 10)
    quarter = len(measured) // 4
    schedule: list[tuple[float, "int | None"]] = []
    kill_idx = 0
    for phase in range(4):
        burst = phase % 2 == 1
        n_phase = quarter if phase < 3 else len(measured) - 3 * quarter
        for j in range(n_phase):
            gap = (0.04 if burst else 600.0) * rng.uniform(0.9, 1.1)
            kill = None
            if burst and j >= 16 and (j - 16) % 8 == 0:
                kill = kill_idx % n_parts
                kill_idx += 1
            schedule.append((gap, kill))

    # the controller also ticks on a virtual timer between arrivals (the
    # scheduled-pinger analog of CloudWatch rules) — a keep-warm policy
    # that only ran when traffic arrived couldn't keep anything warm
    # through a quiet stretch longer than the idle timeout
    timer_s = 15.0

    def run_fleet(replicas: int, hedge, policy):
        app = build_partitioned_search_app(docs, FleetSpec(
            n_parts=n_parts,
            replication=ReplicationSpec(replicas=replicas, hedge=hedge,
                                        autoscale=policy),
            runtime_config=RuntimeConfig(idle_timeout_s=60.0),
            search_config=_fleet_search_cfg()))
        app.warm()
        # warm-latency history for the policies; 2 q/s stays under the
        # demand trigger so the warmup itself doesn't read as a burst
        for q in warmup:
            app.query(q, k=10, t_arrival=app.runtime.clock + 0.5,
                      fetch_docs=False)
        led = app.runtime.ledger
        n0 = len(app.gateway.latencies[("GET", "/search")])
        dollars0 = led.total_dollars
        idle0, hedge0 = led.idle_dollars, led.hedge_dollars
        out = []
        tick = app.runtime.clock
        for q, (gap, kill) in zip(measured, schedule):
            t_arr = app.runtime.clock + gap
            while tick + timer_s < t_arr:
                tick += timer_s
                app.controller.maybe_tick(tick)
            tick = max(tick, t_arr)
            if kill is not None:
                app.runtime.kill_instance(fn=app.fn_names[kill])
            r = app.query(q, k=10, t_arrival=t_arr, fetch_docs=False)
            out.append((tuple(r.body["ids"]),
                        tuple(round(s, 6) for s in r.body["scores"])))
        p = nearest_rank_percentiles(
            app.gateway.latencies[("GET", "/search")][n0:], qs=(0.5, 0.99))
        return app, out, p, (led.total_dollars - dollars0,
                             led.idle_dollars - idle0,
                             led.hedge_dollars - hedge0)

    configs = {
        # min == max pins the fleet: the controller only keeps pools warm,
        # so fixed and autoscaled fleets pay the identical keep-alive
        # policy and the comparison isolates scaling
        "fixed_R1": (1, None,
                     AutoscalePolicy(min_replicas=1, max_replicas=1,
                                     tick_s=0.25)),
        "fixed_R2": (2, HedgePolicy(),
                     AutoscalePolicy(min_replicas=2, max_replicas=2,
                                     tick_s=0.25)),
        "auto": (1, HedgePolicy(),
                 AutoscalePolicy(min_replicas=1, max_replicas=2, tick_s=0.25,
                                 rate_window_s=1.0, up_qps_per_replica=5.0,
                                 down_qps_per_replica=1.0,
                                 idle_ticks_to_retire=2)),
    }
    p99s, dollars_1k, results = {}, {}, {}
    for tag, (replicas, hedge, policy) in configs.items():
        app, out, p, (dollars, idle_d, hedge_d) = run_fleet(
            replicas, hedge, policy)
        results[tag] = out
        p99s[tag] = p[0.99]
        dollars_1k[tag] = dollars / len(measured) * 1000.0
        emit(f"b10_{tag}_gw_p50_ms", round(p[0.5] * 1e3, 1), "ms")
        emit(f"b10_{tag}_gw_p99_ms", round(p[0.99] * 1e3, 1), "ms")
        emit(f"b10_{tag}_dollars_per_1k_q", round(dollars_1k[tag], 6), "$",
             f"idle ${idle_d:.6f} hedge ${hedge_d:.6f}")
        if tag == "auto":
            st = app.controller.stats()
            emit("b10_auto_scale_events",
                 st["scale_ups"] + st["retires"], "events",
                 f"{st['scale_ups']} up / {st['retires']} down, "
                 f"{st['pings']} pings, final R={st['replica_counts']}")

    emit("b10_auto_vs_R2_p99_ratio",
         round(p99s["auto"] / p99s["fixed_R2"], 2), "x", "target: <= 2")
    emit("b10_auto_cost_saving_vs_R2_pct",
         round(100 * (1 - dollars_1k["auto"] / dollars_1k["fixed_R2"])),
         "%", "target: >= 20")
    # scaling must never change results: bit-identical across all three
    # fleets (same PackedIndex behind every pool) and equal to the oracle
    emit("b10_results_bitwise_equal",
         int(results["auto"] == results["fixed_R1"] == results["fixed_R2"]),
         "bool")
    oracle = OracleSearcher(docs)
    ok = all(list(ids) == [d for d, _ in oracle.search(q, k=10)]
             for q, (ids, _) in zip(measured, results["auto"]))
    emit("b10_auto_topk_equals_oracle", int(ok), "bool",
         "throughout scale events")


def bench_refresh() -> None:
    """B8: batch reindex + atomic switch-over, on the VIRTUAL clock.

    Every number here is simulated (fixed hydrate/exec model, no wall
    time), so the rows are machine-independent and regression-gated —
    the pre-PR4 ``switchover_wall_ms`` measured host wall time of a dict
    swap, which no baseline could diff meaningfully.
    """
    print("\nB8: batch reindex + atomic switch-over (paper §3)")
    from repro.core.directory import RamDirectory
    from repro.core.object_store import ObjectStore
    from repro.core.refresh import AssetCatalog, refresh_fleet
    from repro.core.runtime import FaaSRuntime

    s = ObjectStore()
    cat = AssetCatalog(s)
    cat.publish("idx", "v1", RamDirectory({"seg": b"x" * 1024}))

    def handler(cache, payload):
        v = cat.current_version("idx")
        cache.get_or_hydrate("idx", v, lambda: (v, 0.05))
        return v, 0.001

    rt = FaaSRuntime()
    rt.register("f", handler)
    rt.invoke("f", None)                                   # cold: hydrate v1
    _, warm = rt.invoke("f", None, t_arrival=rt.clock + 0.1)
    cat.publish("idx", "v2", RamDirectory({"seg": b"y" * 1024}))
    n = refresh_fleet(rt, "idx")
    out, roll = rt.invoke("f", None, t_arrival=rt.clock + 0.2)
    _, after = rt.invoke("f", None, t_arrival=rt.clock + 0.3)
    emit("refresh_warm_ms", round(warm.latency_s * 1e3, 2), "ms",
         "steady state before the publish")
    emit("refresh_rollover_ms", round(roll.latency_s * 1e3, 2), "ms",
         "first request after publish+invalidate re-hydrates v2")
    emit("refresh_post_rollover_ms", round(after.latency_s * 1e3, 2), "ms",
         "back to steady state one request later")
    emit("post_refresh_version_ok", int(out == "v2"), "bool",
         f"instances refreshed: {n}")


def bench_nrt(n_docs: int, n_queries: int) -> None:
    """B11: near-real-time indexing under sustained query traffic.

    The paper's open limitation — a static index — exercised end to end:
    a fleet serves fixed-QPS traffic while the writer path commits delta
    batches (adds + tombstone deletes) and rolls every pool over to each
    new generation. Three claims measured:

    * rollover is cheap: query p99 over the queries immediately following
      each commit stays within 2× the steady-state p99 (the prewarmed
      rollover keeps hydration+recompile off the query path);
    * writes are visible and exact: after EVERY commit the fleet's top-k
      is identical to a from-scratch ``OracleSearcher`` rebuild of the
      live corpus (adds searchable, deletes gone — including through
      merge compactions);
    * the ingestion bill is attributed: $/1k logical queries is reported
      both serving-only and including writer invocations, next to the
      ledger's write line.

    Reproduce: PYTHONPATH=src python -m benchmarks.run --fast --det --only b11
    """
    print("\nB11: NRT indexing — fixed-QPS traffic across delta commits")
    from repro.core.partition import FleetSpec
    from repro.core.runtime import RuntimeConfig, nearest_rank_percentiles
    from repro.data.corpus import synth_corpus, synth_queries
    from repro.search.oracle import OracleSearcher
    from repro.search.service import build_partitioned_search_app

    if n_queries < 40:       # enough for warmup + 4 rollover windows + steady
        emit("b11_skipped", 1, "bool", "needs --queries >= 40")
        return
    docs = synth_corpus(n_docs, vocab=max(2000, n_docs // 2), seed=0)
    n_init = int(0.6 * len(docs))
    init, incoming = docs[:n_init], docs[n_init:]
    queries = synth_queries(docs, n_queries, seed=7)
    n_warm = 8
    warmup, measured = queries[:n_warm], queries[n_warm:]
    probes = queries[:12]                   # parity probes after each commit

    app = build_partitioned_search_app(init, FleetSpec(
        n_parts=2, runtime_config=RuntimeConfig(),
        search_config=_fleet_search_cfg()))
    app.warm()
    for q in warmup:
        app.query(q, k=10, t_arrival=app.runtime.clock + 0.05,
                  fetch_docs=False)

    n_commits = 4
    batch = -(-len(incoming) // n_commits)
    commit_every = max(1, len(measured) // (n_commits + 1))
    rollover_window = 5                     # queries right after each commit
    led = app.runtime.ledger
    dollars0, write0 = led.total_dollars, led.write_dollars
    steady, rollover, commit_lats = [], [], []
    parity_ok, single_gen = True, True
    since_commit, batch_i = rollover_window, 0
    parity_pending = False

    def check_parity() -> bool:
        oracle = OracleSearcher(app.indexer.live_corpus())
        for q in probes:
            r = app.query(q, k=10, t_arrival=app.runtime.clock + 0.05,
                          fetch_docs=False)
            oids = [oracle.doc_ids[i] for i, _ in oracle.search(q, k=10)]
            if r.body["ext_ids"] != oids:
                return False
        return True

    for i, q in enumerate(measured):
        if i and i % commit_every == 0 and batch_i < n_commits:
            adds = incoming[batch_i * batch:(batch_i + 1) * batch]
            # delete ~2% of the live corpus per commit, oldest first
            live = app.indexer.live_corpus()
            dels = [e for e, _ in live[batch_i::50][:max(1, len(live) // 50)]]
            batch_i += 1
            app.add_documents(adds, t_arrival=app.runtime.clock + 0.01)
            app.delete_documents(dels, t_arrival=app.runtime.clock + 0.01)
            r = app.commit(t_arrival=app.runtime.clock + 0.01)
            commit_lats.append(r.latency_s)
            since_commit = 0
            parity_pending = True       # verified AFTER the rollover window —
            #                             probes before it would warm the very
            #                             pools whose rollover cost we measure
        r = app.query(q, k=10, t_arrival=app.runtime.clock + 0.05,
                      fetch_docs=False)
        single_gen = single_gen and len(app.scatter.last_versions) == 1
        (rollover if since_commit < rollover_window else steady).append(
            r.latency_s)
        since_commit += 1
        if parity_pending and since_commit >= rollover_window:
            parity_ok = parity_ok and check_parity()
            parity_pending = False
    if parity_pending:                  # a commit landed near the end
        parity_ok = parity_ok and check_parity()

    n_logical = len(measured) + len(probes) * batch_i   # parity probes count
    p_s = nearest_rank_percentiles(steady, qs=(0.5, 0.99))
    p_r = nearest_rank_percentiles(rollover, qs=(0.5, 0.99))
    merges = sum(len(c["merged"]) for c in app.indexer.commits)
    emit("b11_steady_gw_p99_ms", round(p_s[0.99] * 1e3, 1), "ms",
         f"{len(steady)} queries between rollovers")
    emit("b11_rollover_gw_p99_ms", round(p_r[0.99] * 1e3, 1), "ms",
         f"{len(rollover)} queries inside {batch_i} rollover windows")
    emit("b11_rollover_vs_steady_p99", round(p_r[0.99] / p_s[0.99], 2), "x",
         "target: <= 2 (prewarmed generation swap)")
    emit("b11_commit_p50_ms",
         round(float(np.median(commit_lats)) * 1e3, 1), "ms",
         f"delta pack + CAS publish + fleet prewarm; {merges} merge(s)")
    emit("b11_dollars_per_1k_q",
         round((led.total_dollars - dollars0) / n_logical * 1000.0, 6), "$",
         f"write ${led.write_dollars - write0:.6f} of it")
    emit("b11_topk_equals_oracle_rebuild", int(parity_ok), "bool",
         "checked after every commit, deletes + merges included")
    emit("b11_single_generation_per_query", int(single_gen), "bool",
         "no query merged hits across generations")


def bench_skew(n_docs: int, n_queries: int) -> None:
    """B12: skew-aware serving — adaptive micro-batch window + per-partition
    heterogeneous replica targets under Zipf-skewed partition load.

    Real collections are skewed: one head partition holds most of the
    documents (here ~73% via ``partition_weights``), so its vmapped eval
    runs ~7× longer per invocation than a tail partition's. Two fleets
    serve the IDENTICAL arrival schedule through the gateway's adaptive
    window (sustained ~100 QPS burst coalescing into ~8-query windows —
    one vmapped invocation per partition per window — then a long sparse
    stretch where the window collapses to zero):

      uniform_R2  fixed R=2 everywhere (min==max pins the controller to
                  keep-alive only): the head partition runs hot at ~93%
                  utilization while three tail partitions' standby pools
                  bill keep-alive spend through every quiet stretch;
      hetero      heterogeneous autoscaled: each group chases its OWN
                  Little's-law target, so the head partition runs R=3
                  (~62% utilization) while tails stay R=1 and the quiet
                  stretch drains the head back down.

    Two delta commits land MID-BURST — one inside an open window — so the
    run also proves the window and NRT rollover compose: admitted queries
    keep their admission-pinned generation, the flush splits into
    per-generation scatters, and every response matches an OracleSearcher
    rebuild of its own generation's live corpus.

    Targets: hetero beats uniform R=2 on $/1k by ≥20% at equal-or-better
    p99; sparse traffic pays ZERO added window wait; merged top-k
    bit-identical across fleets and equal to the per-generation oracle
    throughout scale events and commits.

    Reproduce: PYTHONPATH=src python -m benchmarks.run --fast --det --only b12
    """
    print("\nB12: skew-aware serving — adaptive window + heterogeneous fleet")
    from repro.core.autoscale import AutoscalePolicy
    from repro.core.gateway import WindowPolicy
    from repro.core.partition import (FleetSpec, GatewaySpec, HedgePolicy,
                                      IndexSpec, ReplicationSpec)
    from repro.core.runtime import RuntimeConfig, nearest_rank_percentiles
    from repro.data.corpus import synth_corpus, synth_queries
    from repro.search.oracle import OracleSearcher
    from repro.search.service import build_partitioned_search_app

    n_parts = 4
    weights = [8.0, 1.0, 1.0, 1.0]          # Zipf-ish head/tail split
    docs = synth_corpus(n_docs, vocab=max(2000, n_docs // 2), seed=0)
    n_init = int(0.9 * len(docs))
    init, incoming = docs[:n_init], docs[n_init:]
    queries = synth_queries(docs, n_queries, seed=8)

    # the arrival schedule, as OFFSETS from each fleet's own t0 so both
    # fleets see identical window formation: a lead-in burst (unmeasured —
    # the controller converges here, exactly like B7's warm-up), a
    # measured sustained burst with two commits, then a sparse stretch
    rng = np.random.default_rng(SEED + 12)
    n_lead, n_meas = 200, 800
    gaps = 0.01 * rng.uniform(0.9, 1.1, size=n_lead + n_meas)  # ~100 QPS
    burst_offsets = np.cumsum(gaps)
    commit_at = (burst_offsets[n_lead + n_meas // 3],
                 burst_offsets[n_lead + (2 * n_meas) // 3])
    n_quiet = 24                            # sparse: ~1 query / 10 min —
    quiet_gaps = 600.0 * rng.uniform(0.9, 1.1, size=n_quiet)  # pre-drawn,
    #                       so BOTH fleets replay the identical timeline
    timer_s = 15.0                          # out-of-band controller timer

    window = WindowPolicy(max_window_s=0.08, target_batch=8, sparse_qps=2.0,
                          p99_budget_s=2.0)
    cfg = _fleet_search_cfg()
    if cfg is not None:
        # the skew model: eval time grows with the partition's documents,
        # so the head partition's handler runs ~7× a tail's
        import dataclasses as _dc
        cfg = _dc.replace(cfg, sim_exec_per_kdoc_s=0.1)

    def run_fleet(replicas: int, policy: AutoscalePolicy):
        app = build_partitioned_search_app(init, FleetSpec(
            n_parts=n_parts,
            replication=ReplicationSpec(replicas=replicas,
                                        hedge=HedgePolicy(),
                                        autoscale=policy),
            gateway=GatewaySpec(window=window),
            index=IndexSpec(partition_weights=weights),
            runtime_config=RuntimeConfig(idle_timeout_s=60.0),
            search_config=cfg))
        app.warm()
        for q in queries[:8]:               # warm-latency history
            app.query(q, k=10, t_arrival=app.runtime.clock + 0.5,
                      fetch_docs=False)
        t0 = app.runtime.clock + 2.0
        led = app.runtime.ledger
        handles, meas_idx, commits, batch_i = [], [], [], 0
        gen_corpora = {app.indexer.gen: list(app.indexer.live_corpus())}
        snap = None                         # ledger snapshot at measure start
        for i, off in enumerate(burst_offsets):
            if batch_i < len(commit_at) and off >= commit_at[batch_i]:
                # commits land mid-burst — the second lands while a window
                # is open, so one flush spans two generations
                n_inc = len(incoming) // 2
                adds = incoming[batch_i * n_inc:(batch_i + 1) * n_inc]
                dels = [e for e, _ in gen_corpora[app.indexer.gen][::301]]
                app.add_documents(adds, t_arrival=t0 + off)
                app.delete_documents(dels, t_arrival=t0 + off)
                r = app.commit(t_arrival=t0 + off)
                assert r.ok, r.body
                commits.append(r.body["gen"])
                gen_corpora[r.body["gen"]] = list(app.indexer.live_corpus())
                batch_i += 1
            if i == n_lead:                 # measured window opens here:
                app.flush()                 # close the lead-in's window,
                snap = (led.total_dollars, led.idle_dollars,  # then snapshot
                        led.hedge_dollars, len(app.runtime.records))
            h = app.submit(queries[i % len(queries)], k=10,
                           t_arrival=t0 + off, fetch_docs=False)
            handles.append(h)
            if i >= n_lead:
                meas_idx.append(i)
        app.flush()
        # the sparse stretch: the window must collapse to zero — every
        # lone query resolves AT its own arrival, no added wait
        t = t0 + float(burst_offsets[-1])
        tick = t
        sparse_immediate = True
        for j in range(n_quiet):
            t += float(quiet_gaps[j])
            while tick + timer_s < t:       # scheduled-pinger analogue
                tick += timer_s
                app.controller.maybe_tick(tick)
                app.flush(tick)
            tick = max(tick, t)
            h = app.submit(queries[j % len(queries)], k=10, t_arrival=t,
                           fetch_docs=False)
            sparse_immediate = sparse_immediate and h.done()
            handles.append(h)
            meas_idx.append(len(burst_offsets) + j)
        dollars = (led.total_dollars - snap[0], led.idle_dollars - snap[1],
                   led.hedge_dollars - snap[2])
        measured = set(meas_idx)
        out = [(tuple(h.response.body["ext_ids"]),
                tuple(round(s, 6) for s in h.response.body["scores"]),
                h.response.body.get("generation"),
                h.response.latency_s, i in measured)
               for i, h in enumerate(handles)]
        return app, out, dollars, gen_corpora, sparse_immediate, commits

    uniform_pol = AutoscalePolicy(min_replicas=2, max_replicas=2,
                                  tick_s=0.25, rate_window_s=1.0,
                                  up_qps_per_replica=float("inf"))
    hetero_pol = AutoscalePolicy(min_replicas=1, max_replicas=3,
                                 tick_s=0.25, rate_window_s=1.0,
                                 up_qps_per_replica=float("inf"),
                                 down_qps_per_replica=1.0,
                                 idle_ticks_to_retire=2,
                                 up_ticks_to_scale=3,
                                 target_utilization=0.6)
    p99s, dollars_1k, results = {}, {}, {}
    sparse_ok, hetero_counts = True, None
    for tag, (replicas, pol) in (("uniform_R2", (2, uniform_pol)),
                                 ("hetero", (1, hetero_pol))):
        app, out, (dollars, idle_d, hedge_d), gen_corpora, sparse, commits \
            = run_fleet(replicas, pol)
        results[tag] = [(ids, scores, gen) for ids, scores, gen, _, _ in out]
        sparse_ok = sparse_ok and sparse
        meas = [lat for _, _, _, lat, measured in out if measured]
        p = nearest_rank_percentiles(meas, qs=(0.5, 0.99))
        p99s[tag] = p[0.99]
        n_meas_q = len(meas)
        dollars_1k[tag] = dollars / n_meas_q * 1000.0
        emit(f"b12_{tag}_gw_p50_ms", round(p[0.5] * 1e3, 1), "ms")
        emit(f"b12_{tag}_gw_p99_ms", round(p[0.99] * 1e3, 1), "ms",
             f"{n_meas_q} measured queries, {len(commits)} commits mid-run")
        emit(f"b12_{tag}_dollars_per_1k_q", round(dollars_1k[tag], 6), "$",
             f"idle ${idle_d:.6f} hedge ${hedge_d:.6f}")
        if tag == "hetero":
            hetero_counts = app.controller.replica_counts()
            st = app.controller.stats()
            # per-partition peak R over the whole run: the heterogeneity
            # claim is that the head's peak strictly exceeds every tail's
            peaks = [1] * n_parts
            for e in app.controller.events:
                if e["action"] == "scale_up":
                    p_i = e["partition"]
                    peaks[p_i] = max(peaks[p_i], e["replicas"])
            emit("b12_hetero_peak_head_R", peaks[0], "replicas",
                 f"peaks {peaks}, final {hetero_counts}, "
                 f"{st['scale_ups']} up / {st['retires']} down")
            emit("b12_hetero_head_exceeds_tails",
                 int(peaks[0] > max(peaks[1:])), "bool",
                 "the head partition's capacity scaled past every tail's")
            ws = app.gateway.window_stats("GET", "/search")
            emit("b12_mean_window_batch", round(ws["mean_batch"], 2),
                 "queries/window", f"{ws['batches']} windows")
            # oracle parity, per pinned generation: every response equals a
            # from-scratch rebuild of the generation it was admitted under
            oracles = {g: OracleSearcher(c) for g, c in gen_corpora.items()}
            want_cache: dict = {}
            ok = True
            for i, (ids, _, gen, _, _) in enumerate(out):
                q = queries[(i if i < len(burst_offsets)
                             else i - len(burst_offsets)) % len(queries)]
                key = (gen, q)
                if key not in want_cache:
                    o = oracles[gen]
                    want_cache[key] = [o.doc_ids[d]
                                       for d, _ in o.search(q, k=10)]
                ok = ok and list(ids) == want_cache[key]
            emit("b12_topk_equals_oracle", int(ok), "bool",
                 "per pinned generation, through scale events + commits")

    emit("b12_hetero_final_R", str(hetero_counts).replace(",", ";"),
         "replicas", "head partition scaled independently of the tail")
    emit("b12_hetero_p99_vs_uniform", round(p99s["hetero"]
                                            / p99s["uniform_R2"], 2),
         "x", "target: <= 1 (equal-or-better)")
    emit("b12_hetero_cost_saving_vs_uniform_pct",
         round(100 * (1 - dollars_1k["hetero"] / dollars_1k["uniform_R2"])),
         "%", "target: >= 20")
    emit("b12_sparse_zero_added_wait", int(sparse_ok), "bool",
         "lone queries resolve at their own arrival instant")
    emit("b12_results_bitwise_equal",
         int(results["hetero"] == results["uniform_R2"]), "bool",
         "same windows, same generations, same merged top-k")


def bench_roofline_summary() -> None:
    print("\nB9: roofline summary (from dry-run artifacts, if present)")
    from benchmarks.roofline import analyze
    for mesh in ("pod1_16x16", "pod2_2x16x16"):
        rows = [r for r in analyze(mesh) if "t_compute_s" in r]
        if not rows:
            emit(f"{mesh}_cells", 0, "cells", "run repro.launch.dryrun first")
            continue
        dom: dict[str, int] = {}
        for r in rows:
            dom[r["dominant"]] = dom.get(r["dominant"], 0) + 1
        fracs = [r["roofline_frac"] for r in rows if r["roofline_frac"]]
        emit(f"{mesh}_cells", len(rows), "cells", f"dominant: {dom}")
        if fracs:
            emit(f"{mesh}_roofline_frac_median",
                 round(float(np.median(fracs)), 3), "frac")
            emit(f"{mesh}_roofline_frac_best",
                 round(float(np.max(fracs)), 3), "frac")


def bench_pruned_roofline() -> None:
    """B9b: fused block-max pruned scoring vs dense, on the modeled roofline.

    Fabricates impact-ordered kernel inputs directly (``synth_pruned_blocks``
    — no IndexWriter, so 1M-doc partitions cost milliseconds to set up), runs
    the fused ``bm25_pruned_topk`` Pallas pass, and reports:

    * blocks-touched fraction — the kernel's own ``touched`` count over the
      valid blocks a dense pass would score (single-term rows are the gated
      headline: tight bounds, ~10× fewer blocks; the multi-term row shows
      the loose-bound regime honestly);
    * modeled HBM bytes/query and per-query ms for pruned vs dense, on the
      same byte model:  blocks×B×17 B/lane (docs 4 + tf 1 + dl 4 + scatter
      read/write 8) + n_docs×4 for the top-k scan of the accumulator, at
      roofline HBM_BW.  Deterministic — these are the regression-gated rows
      (the pruned kernel's modeled latency may never exceed dense's);
    * measured kernel wall time (NOT gated — CPU interpret mode does
      dense-superset work, the modeled rows carry the claim);
    * bitwise parity: pruned (vals, ids) vs the jitted unpruned oracle
      ``bm25_pruned_topk_ref`` — vals compared as uint32 bit patterns.

    Reproduce: PYTHONPATH=src python -m benchmarks.run --det --only b9b
    """
    print("\nB9b: block-max pruned scoring vs dense (modeled HBM roofline)")
    import jax
    import jax.numpy as jnp
    from benchmarks.roofline import HBM_BW
    from repro.data.corpus import synth_pruned_blocks
    from repro.kernels.ops import bm25_pruned_topk
    from repro.kernels.ref import bm25_pruned_topk_ref

    B, M, k, n_q = 128, 32, 10, 4
    lane_bytes = 17            # docs 4B + tf 1B + dl 4B + scatter r/w 8B
    params = (jnp.float32(0.9), jnp.float32(0.4), jnp.float32(12.0))
    parity = True
    for label, n_docs in (("100k", 100_000), ("1m", 1_000_000)):
        scan_bytes = 4 * n_docs                  # top-k pass over the acc
        for T, tag in ((1, "pruned"), (2, "multiterm")):
            touched_b, dense_b, fracs, wall = [], [], [], []
            for qi in range(n_q):
                raw = synth_pruned_blocks(SEED * 7919 + 101 * T + qi,
                                          n_terms=T, max_blocks=M,
                                          n_docs=n_docs, block=B, zipf_a=1.3)
                a = [jnp.asarray(x) for x in raw]
                vals, ids, touched = bm25_pruned_topk(
                    *a, *params, k=k, n_docs=n_docs)
                t0 = time.perf_counter()         # shapes warm: re-run timed
                vals2, _, _ = bm25_pruned_topk(*a, *params, k=k,
                                               n_docs=n_docs)
                jax.block_until_ready(vals2)
                wall.append(time.perf_counter() - t0)
                rv, ri = bm25_pruned_topk_ref(*a, *params, k=k,
                                              n_docs=n_docs)
                parity = parity and bool(
                    (np.asarray(vals).view(np.uint32)
                     == np.asarray(rv).view(np.uint32)).all()
                    and (np.asarray(ids) == np.asarray(ri)).all())
                n_valid = int(raw[5].sum())
                touched_b.append(int(touched) * B * lane_bytes + scan_bytes)
                dense_b.append(n_valid * B * lane_bytes + scan_bytes)
                fracs.append(int(touched) / n_valid)
            p_ms = float(np.mean(touched_b)) / HBM_BW * 1e3
            d_ms = float(np.mean(dense_b)) / HBM_BW * 1e3
            emit(f"b9b_{tag}_blocks_touched_frac_{label}",
                 round(float(np.mean(fracs)), 4), "frac",
                 f"T={T}, {n_q} queries, M={M} blocks/term")
            if tag == "multiterm":      # loose Σ-of-ceilings bounds: the
                continue                # frac row alone tells that story
            emit(f"b9b_pruned_model_ms_{label}", round(p_ms, 6), "ms",
                 f"{float(np.mean(touched_b)) / 1e6:.3f} MB/query modeled")
            emit(f"b9b_dense_model_ms_{label}", round(d_ms, 6), "ms",
                 f"{float(np.mean(dense_b)) / 1e6:.3f} MB/query modeled")
            emit(f"b9b_pruned_vs_dense_model_{label}",
                 round(p_ms / d_ms, 4), "x", "must be <= 1")
            emit(f"b9b_pruned_kernel_wall_ms_{label}",
                 round(float(np.median(wall)) * 1e3, 2), "ms",
                 "measured, not gated (CPU interpret mode)")
    emit("b9b_pruned_bitwise_equal", int(parity), "bool",
         "pruned == unpruned oracle, uint32 val bits + ids")


def bench_cold_start(n_docs: int, n_queries: int) -> None:
    """B13: cold-start profile — full-hydrate vs lazy block-range hydration.

    The cold-start demolition claim, measured head-to-head: two identical
    2-partition fleets over the same packed segments, every query forced
    cold (instances cleared between trials). The FULL fleet streams whole
    segments before the first byte of scoring; the LAZY fleet answers from
    one superindex range-GET plus the queried terms' coalesced posting-block
    ranges, then backfills OFF the critical path (billed to the ledger's
    backfill line, excluded from latency — both asserted here). Gates:

    * lazy cold p50 HYDRATION ≤ 1/3 of full's (the profile the layout
      attacks; end-to-end latency rows also emitted, but the constant
      ``provision_s`` container boot sits on both sides of that ratio),
    * merged cold top-k rank-equal to the OracleSearcher and BITWISE-equal
      (uint32 score views) between the lazy and full fleets,
    * backfill billed > 0 GB·s on its own line with every cold latency
      exactly provision + hydrate + exec (backfill never on the critical
      path).

    Also re-derives the downstream operating constants from the measured
    profile: the hedge scale (``HedgePolicy.from_cold_profile``) and the
    autoscaler's cold-overhead floor (``AutoscalePolicy.cold_overhead_s``).
    """
    import dataclasses as _dc

    from repro.core.kvstore import KVStore
    from repro.core.object_store import ObjectStore
    from repro.core.partition import MERGE_COST_S, HedgePolicy, _merge_hits
    from repro.core.refresh import AssetCatalog
    from repro.core.runtime import FaaSRuntime, RuntimeConfig
    from repro.data.corpus import synth_corpus, synth_queries
    from repro.index.builder import (IndexWriter, compute_global_stats,
                                     global_vocab, write_segment)
    from repro.search.oracle import OracleSearcher
    from repro.search.searcher import SearchConfig, make_search_handler

    print("\nB13: cold-start profile — full-hydrate vs lazy range hydration")
    P, k = 2, 10
    docs = synth_corpus(n_docs, vocab=max(800, n_docs // 4), seed=0)
    queries = synth_queries(docs, n_queries, seed=1)
    # contiguous partitions packed against GLOBAL stats/vocab, so the
    # merged ranking is the single-index ranking (PR 1 invariant) and the
    # _merge_hits tie-break matches ascending global id
    stats = compute_global_stats(docs)
    vocab = global_vocab(stats)
    cut = len(docs) // 2
    parts = [docs[:cut], docs[cut:]]
    offsets = [0, cut]
    packs = []
    for pdocs in parts:
        w = IndexWriter(global_stats=stats, vocab=vocab)
        w.add_many(pdocs)
        packs.append(w.pack())

    base_cfg = _fleet_search_cfg() or SearchConfig()

    def run(mode: str):
        cat = AssetCatalog(ObjectStore())
        rt = FaaSRuntime(RuntimeConfig(seed=SEED))
        cfg = _dc.replace(base_cfg, lazy_hydration=(mode == "lazy"))
        fns = []
        for p in range(P):
            asset = f"b13-{mode}-p{p}"
            cat.publish(asset, "v1", write_segment(packs[p]))
            fn = f"b13-{mode}-s{p}"
            rt.register(fn, make_search_handler(cat, KVStore(), asset, cfg))
            fns.append(fn)
        hyd, lats, results, clean = [], [], [], True
        for q in queries:
            rt._instances.clear()               # force a true cold start
            t = rt.clock + 1.0
            per_part, recs = [], []
            for p, fn in enumerate(fns):
                res, rec = rt.invoke(fn, {"q": q, "k": k,
                                          "fetch_docs": False},
                                     t_arrival=t)
                per_part.append(res)
                recs.append(rec)
                hyd.append(rec.hydrate_s)
                # the off-critical-path contract, per record: latency is
                # exactly boot + hydrate + exec; backfill (lazy) rides after
                ok = abs(rec.latency_s - (rt.config.provision_s
                                          + rec.hydrate_s + rec.exec_s)) < 1e-9
                if mode == "lazy":
                    ok = ok and rec.backfill_s > 0
                clean = clean and ok and rec.cold
            lats.append(max(r.latency_s for r in recs) + MERGE_COST_S)
            results.append([(offsets[h.partition] + h.doc_id,
                             np.float32(h.score)) for h in
                            _merge_hits(per_part, k)])
        # warm profile for the re-derived constants (no instance clearing)
        for q in queries[:4]:
            for fn in fns:
                rt.invoke(fn, {"q": q, "k": k, "fetch_docs": False},
                          t_arrival=rt.clock + 0.5)
        warm_p50 = rt.latency_percentiles(fns, qs=(0.5,), warm_only=True)[0.5]
        return hyd, lats, results, clean, rt.ledger, warm_p50

    full_hyd, full_lat, full_res, _, _, _ = run("full")
    lazy_hyd, lazy_lat, lazy_res, lazy_clean, lazy_led, warm_p50 = run("lazy")

    oracle = OracleSearcher(docs)
    rank_ok = True
    for q, merged in zip(queries, lazy_res):
        want = oracle.search(q, k)
        for (gid, score), (wd, ws) in zip(merged, want):
            tied = any(abs(ws - w2) < 1e-5 for d2, w2 in want if d2 != wd)
            if not (gid == wd or tied):
                rank_ok = False
    bitwise = all(
        [(g, np.float32(s).view(np.uint32)) for g, s in a]
        == [(g, np.float32(s).view(np.uint32)) for g, s in b]
        for a, b in zip(lazy_res, full_res))

    fp50 = float(np.median(full_hyd))
    lp50 = float(np.median(lazy_hyd))
    emit("b13_full_cold_p50_ms", round(fp50 * 1e3, 4), "ms",
         "whole-segment streaming before first scoring byte")
    emit("b13_lazy_cold_p50_ms", round(lp50 * 1e3, 4), "ms",
         "superindex + queried terms' block ranges only")
    emit("b13_lazy_vs_full_cold_ratio", round(lp50 / fp50, 4), "x",
         "gate: <= 1/3")
    emit("b13_full_cold_latency_p50_ms",
         round(float(np.median(full_lat)) * 1e3, 4), "ms",
         "end-to-end incl. provision_s (constant on both sides)")
    emit("b13_lazy_cold_latency_p50_ms",
         round(float(np.median(lazy_lat)) * 1e3, 4), "ms")
    emit("b13_cold_topk_equals_oracle", int(rank_ok), "bool",
         "merged cold top-k rank-equal to OracleSearcher")
    emit("b13_cold_results_bitwise_equal", int(bitwise), "bool",
         "lazy cold hits == full-hydrate hits, uint32 score views")
    emit("b13_backfill_off_critical_path", int(lazy_clean), "bool",
         "every cold latency == provision + hydrate + exec; backfill > 0")
    emit("b13_backfill_gb_s", round(lazy_led.backfill_gb_seconds, 6), "GB*s",
         "partial->full upgrades, own ledger line")
    # the downstream constants, re-derived from the measured cold profile
    cold_overhead = 0.150 + lp50
    emit("b13_rederived_cold_overhead_s", round(cold_overhead, 4), "s",
         "provision_s + lazy cold hydrate p50 -> "
         "AutoscalePolicy.cold_overhead_s")
    emit("b13_rederived_hedge_scale",
         round(HedgePolicy.from_cold_profile(cold_overhead, warm_p50).scale,
               4), "x",
         "HedgePolicy.from_cold_profile(cold, warm p50)")


def bench_hybrid(n_docs: int, n_queries: int) -> None:
    """B14: hybrid retrieval — sparse vs dense vs hybrid on ONE fleet.

    One skewed fleet (B12's [8,1,1,1] ``partition_weights``) carries a
    dense-vector tier next to BM25 on the SAME partitions, functions and
    manifests (``IndexSpec.vector``). The identical burst arrival schedule
    (~100 QPS through the gateway's adaptive window) is replayed once per
    ``mode`` — ``sparse``, ``dense``, ``hybrid`` — so the three rows below
    compare tiers, not fleets: same instances, same skew, same windows.

    Per mode: gateway p50/p99 and $/1k-queries (ledger-snapshot deltas per
    phase). Gates (regression-rowed under --det):

    * dense fleet scores are uint32-BIT-identical to the full-corpus
      ``DenseOracleSearcher`` (the jitted ``dot_topk_batch_ref``) — the
      per-partition Pallas kernel path vs one brute-force scan;
    * hybrid fused top-k equals ``hybrid_oracle_fuse`` over the two
      oracles' rankings — ids AND fused RRF scores exactly;
    * dense p99 ≤ 2× sparse p99 at equal fleet shape (one extra device
      call per invocation, not a new latency regime).

    Reproduce: PYTHONPATH=src python -m benchmarks.run --fast --det --only b14
    """
    print("\nB14: hybrid retrieval — sparse vs dense vs hybrid, one fleet")
    import dataclasses as _dc

    from repro.core.gateway import WindowPolicy
    from repro.core.partition import (FleetSpec, GatewaySpec, IndexSpec,
                                      VectorSpec)
    from repro.core.runtime import RuntimeConfig, nearest_rank_percentiles
    from repro.data.corpus import hash_embedder, synth_corpus, synth_queries
    from repro.search.oracle import (DenseOracleSearcher, OracleSearcher,
                                     hybrid_oracle_fuse)
    from repro.search.service import build_partitioned_search_app

    n_parts, dim, k = 4, 16, 10
    weights = [8.0, 1.0, 1.0, 1.0]          # B12's Zipf-ish head/tail skew
    docs = synth_corpus(n_docs, vocab=max(2000, n_docs // 2), seed=0)
    queries = synth_queries(docs, n_queries, seed=9)
    embed = hash_embedder(dim)

    cfg = _fleet_search_cfg()
    if cfg is not None:                     # B12's skew model: eval time
        cfg = _dc.replace(cfg, sim_exec_per_kdoc_s=0.1)   # ~ partition size
    window = WindowPolicy(max_window_s=0.08, target_batch=8, sparse_qps=2.0,
                          p99_budget_s=2.0)
    app = build_partitioned_search_app(docs, FleetSpec(
        n_parts=n_parts,
        gateway=GatewaySpec(window=window),
        index=IndexSpec(partition_weights=weights,
                        vector=VectorSpec(dim=dim, embedder=embed)),
        runtime_config=RuntimeConfig(),
        search_config=cfg))
    app.warm()                              # warms BOTH tiers (hybrid ping)
    for q in queries[:4]:                   # per-mode compile + hydrate,
        for mode in ("sparse", "dense", "hybrid"):   # off the measured clock
            app.query(q, k=k, mode=mode, t_arrival=app.runtime.clock + 0.05,
                      fetch_docs=False)

    # the SAME burst offsets replayed per mode (B12's window regime)
    rng = np.random.default_rng(SEED + 14)
    n_meas = 3 * len(queries)
    offsets = np.cumsum(0.01 * rng.uniform(0.9, 1.1, size=n_meas))

    led = app.runtime.ledger
    p99s, results = {}, {}
    for mode in ("sparse", "dense", "hybrid"):
        t0 = app.runtime.clock + 2.0
        dollars0 = led.total_dollars
        handles = [app.submit(queries[i % len(queries)], k=k, mode=mode,
                              t_arrival=t0 + float(off), fetch_docs=False)
                   for i, off in enumerate(offsets)]
        app.flush()
        lats = [h.response.latency_s for h in handles]
        results[mode] = [(tuple(h.response.body["ext_ids"]),
                          tuple(h.response.body["scores"]))
                         for h in handles]
        p = nearest_rank_percentiles(lats, qs=(0.5, 0.99))
        p99s[mode] = p[0.99]
        emit(f"b14_{mode}_gw_p50_ms", round(p[0.5] * 1e3, 1), "ms")
        emit(f"b14_{mode}_gw_p99_ms", round(p[0.99] * 1e3, 1), "ms",
             f"{n_meas} queries, same fleet + schedule per mode")
        emit(f"b14_{mode}_dollars_per_1k_q",
             round((led.total_dollars - dollars0) / n_meas * 1000.0, 6), "$")
    emit("b14_dense_p99_vs_sparse", round(p99s["dense"] / p99s["sparse"], 2),
         "x", "target: <= 2 (one extra device call, same fleet shape)")
    emit("b14_hybrid_p99_vs_sparse",
         round(p99s["hybrid"] / p99s["sparse"], 2), "x", "both tiers/query")

    # oracle parity, over the live corpus in fleet partition order
    corpus = app.indexer.live_corpus()
    so = OracleSearcher(corpus)
    do = DenseOracleSearcher(corpus, embed)
    sparse_ok = dense_bits_ok = hybrid_ok = True
    for i in range(n_meas):
        q = queries[i % len(queries)]
        s_want = so.search(q, k=app.search_k)
        d_want = do.search(q, k=app.search_k)
        ids, scores = results["sparse"][i]
        sparse_ok = sparse_ok and list(ids) == [so.doc_ids[d]
                                                for d, _ in s_want[:k]]
        ids, scores = results["dense"][i]
        dense_bits_ok = dense_bits_ok and (
            list(ids) == [do.doc_ids[d] for d, _ in d_want[:k]]
            and [np.float32(s).view(np.uint32) for s in scores]
            == [np.float32(v).view(np.uint32) for _, v in d_want[:k]])
        ids, scores = results["hybrid"][i]
        fused = hybrid_oracle_fuse(s_want, d_want, k)
        hybrid_ok = hybrid_ok and (
            list(ids) == [so.doc_ids[d] for d, _ in fused]
            and list(scores) == [v for _, v in fused])
    emit("b14_sparse_topk_equals_oracle", int(sparse_ok), "bool")
    emit("b14_dense_bitwise_equal", int(dense_bits_ok), "bool",
         "fleet kernel scores == dot_topk_batch_ref oracle, uint32 views")
    emit("b14_hybrid_topk_equals_oracle", int(hybrid_ok), "bool",
         "RRF fusion of the two oracles' rankings, ids + fused scores")


def bench_overload(n_docs: int, n_queries: int) -> None:
    """B15: overload survival — ONE fleet through a 4× burst, two racing
    writers, and a mid-traffic staggered rollover.

    The fleet (2 partitions × R=2, autoscaled, adaptive window) is pushed
    through four phases on one virtual clock:

    * unloaded baseline: batched traffic near the window's design rate —
      the admitted-latency yardstick;
    * overload burst: arrivals at ~4× the drain rate force consecutive
      ``max_batch`` hard flushes until ``BackpressurePolicy`` sheds with
      429 + Retry-After. Gates: the shed fraction stays inside (0, 0.9],
      admitted p99 within 25% of unloaded (overload is refused, not
      queued), and every shed bills NOTHING;
    * racing writers: a forked writer commits against the winner's
      generation, rebases in-commit, and the converged fleet's top-k must
      equal a from-scratch oracle rebuild — the serialized-writer answer
      (bit-level twin equality is pinned in tests/test_nrt.py);
    * staggered rollover: a commit lands mid-stream; the rollover-window
      p99 stays ≤ 1.5× steady (pools prewarm one replica group at a time,
      so the fleet never hydrates everywhere at once);
    * bounded retries: 5% injected instance deaths — every query still
      answers (no 503 escapes the retry budget) and retried invocations
      track the death rate, never a storm.

    Reproduce: PYTHONPATH=src python -m benchmarks.run --fast --det --only b15
    """
    print("\nB15: overload survival — backpressure, racing writers, rollover")
    from repro.core.gateway import BackpressurePolicy, WindowPolicy
    from repro.core.partition import (FleetSpec, GatewaySpec,
                                      ReplicationSpec)
    from repro.core.runtime import (RetryPolicy, RuntimeConfig,
                                    nearest_rank_percentiles)
    from repro.data.corpus import synth_corpus, synth_queries
    from repro.search.oracle import OracleSearcher
    from repro.search.service import build_partitioned_search_app

    docs = synth_corpus(n_docs, vocab=max(2000, n_docs // 2), seed=0)
    n_init = int(0.8 * len(docs))
    init, incoming = docs[:n_init], docs[n_init:]
    queries = synth_queries(docs, n_queries, seed=15)

    window = WindowPolicy(
        max_window_s=0.08, target_batch=8, sparse_qps=2.0,
        p99_budget_s=None,          # no budget clamp: hard flushes must
        max_batch=8,                # reach the backpressure threshold
        backpressure=BackpressurePolicy(consecutive_hard_flushes=3,
                                        drain_window_s=1.0,
                                        min_retry_after_s=0.050,
                                        max_retry_after_s=2.0))
    app = build_partitioned_search_app(init, FleetSpec(
        n_parts=2,
        replication=ReplicationSpec(replicas=2, autoscale=True),
        gateway=GatewaySpec(window=window),
        runtime_config=RuntimeConfig(
            retry=RetryPolicy(max_attempts=4, base_backoff_s=0.010,
                              multiplier=2.0, max_backoff_s=0.200,
                              jitter=0.1)),
        search_config=_fleet_search_cfg()))
    app.warm()
    led = app.runtime.ledger
    n_logical = 0

    def stream(n: int, spacing: float):
        nonlocal n_logical
        t0 = app.runtime.clock + 2.0        # idle gap: fresh window state
        handles = [app.submit(queries[i % len(queries)], k=10,
                              t_arrival=t0 + i * spacing, fetch_docs=False)
                   for i in range(n)]
        app.flush()
        n_logical += n
        return [h.response for h in handles]

    # concurrency warmup (unmeasured): two back-to-back windows provision
    # the second warm instance per pool, so the measured burst compares
    # steady-state admission against steady-state serving — not against a
    # one-off cold provision the very first overlapping scatter pays
    stream(16, 0.002)
    app.warm()      # hydrate any pool the controller added during warmup
    n_logical = 0
    dollars0 = led.total_dollars

    # -- phase 1: unloaded baseline (design-rate batched traffic) -------------
    n_base = max(16, n_queries // 3)
    base = stream(n_base, 0.050)            # ~20 QPS: windows form, no shed
    assert all(r.status == 200 for r in base)
    p_base = nearest_rank_percentiles([r.latency_s for r in base],
                                      qs=(0.5, 0.99))
    emit("b15_unloaded_gw_p99_ms", round(p_base[0.99] * 1e3, 1), "ms",
         f"{n_base} queries at the window design rate")

    # -- phase 2: 4× overload burst ------------------------------------------
    n_burst = 4 * n_base
    burst = stream(n_burst, 0.002)          # ~500 QPS into a ~100 QPS fleet
    shed = [r for r in burst if r.status == 429]
    admitted = [r for r in burst if r.status == 200]
    errors = [r for r in burst if r.status not in (200, 429)]
    assert not errors, [r.body for r in errors[:3]]
    assert admitted and shed, "burst must both admit and shed"
    shed_frac = len(shed) / n_burst
    p_adm = nearest_rank_percentiles([r.latency_s for r in admitted],
                                     qs=(0.5, 0.99))
    emit("b15_burst_shed_frac", round(shed_frac, 4), "frac",
         "gate: in (0, 0.9] — sheds, but never collapses to all-429")
    emit("b15_admitted_gw_p99_ms", round(p_adm[0.99] * 1e3, 1), "ms",
         f"{len(admitted)} admitted of {n_burst} burst arrivals")
    emit("b15_admitted_p99_vs_unloaded",
         round(p_adm[0.99] / p_base[0.99], 3), "x",
         "gate: <= 1.25 — overload is refused at admission, not queued")
    retry_after_ok = all(r.body["retry_after_s"] >= 0.050 for r in shed)
    emit("b15_shed_billed_zero",
         int(led.shed_requests == len(shed)
             and led.shed_gb_seconds == 0.0 and retry_after_ok), "bool",
         "every 429 carried Retry-After and charged no GB·s")
    ctl = app.controller
    emit("b15_autoscaler_sheds_seen",
         ctl.sheds_seen if ctl is not None else 0, "sheds",
         "backpressure feeds the scale-up loop")

    # -- phase 3: racing writers ---------------------------------------------
    a = app.indexer
    b = a.fork(1)
    half = len(incoming) // 2
    a.stage_add(incoming[:half])
    b.stage_add(incoming[half:])
    ping = {"q": "", "k": 1, "fetch_docs": False}
    t = app.runtime.clock + 2.0
    ra, _ = a.commit(app.fn_groups, t_arrival=t, ping_payload=ping)
    rb, _ = b.commit(app.fn_groups, t_arrival=app.runtime.clock + 0.1,
                     ping_payload=ping)
    race_ok = (rb["rebased"] == 1 and rb["gen"] == ra["gen"] + 1
               and a.sync() is True)
    oracle = OracleSearcher(a.live_corpus())
    for q in queries[:10]:
        r = app.query(q, k=10, t_arrival=app.runtime.clock + 0.05,
                      fetch_docs=False)
        n_logical += 1
        race_ok = race_ok and r.ok and r.body["ext_ids"] == [
            oracle.doc_ids[i] for i, _ in oracle.search(q, k=10)]
    emit("b15_race_topk_equals_serialized_oracle", int(race_ok), "bool",
         "loser rebased in-commit; converged fleet == oracle rebuild")

    # -- phase 4: staggered rollover under steady traffic ---------------------
    steady_l, roll_l = [], []
    since_commit, committed = 99, False
    n_roll = max(24, n_queries // 4)
    for i in range(n_roll):
        if i == n_roll // 3 and not committed:
            live = a.live_corpus()
            app.delete_documents([e for e, _ in live[::50][:8]],
                                 t_arrival=app.runtime.clock + 0.01)
            r = app.commit(t_arrival=app.runtime.clock + 0.01)
            assert r.ok, r.body
            since_commit, committed = 0, True
        r = app.query(queries[i % len(queries)], k=10,
                      t_arrival=app.runtime.clock + 0.05, fetch_docs=False)
        n_logical += 1
        (roll_l if since_commit < 5 else steady_l).append(r.latency_s)
        since_commit += 1
    p_st = nearest_rank_percentiles(steady_l, qs=(0.5, 0.99))
    p_ro = nearest_rank_percentiles(roll_l, qs=(0.5, 0.99))
    emit("b15_rollover_p99_vs_steady", round(p_ro[0.99] / p_st[0.99], 2),
         "x", "gate: <= 1.5 — one replica group prewarms at a time")

    # -- phase 5: bounded retries under injected instance deaths --------------
    rec0 = len(app.runtime.records)
    app.runtime.config.failure_rate = 0.05
    n_inj = 40
    inj_ok = True
    for i in range(n_inj):
        r = app.query(queries[i % len(queries)], k=10,
                      t_arrival=app.runtime.clock + 0.05, fetch_docs=False)
        inj_ok = inj_ok and r.ok
    app.runtime.config.failure_rate = 0.0
    n_logical += n_inj
    recs = list(app.runtime.records)[rec0:]
    n_retries = sum(r.retries for r in recs)
    # a storm would retry far beyond the injected death rate; exhaustion
    # (a 503 escaping the retry budget) would flip inj_ok
    storm_free = int(inj_ok and 1 <= n_retries
                     <= max(8, int(4 * 0.05 * len(recs))))
    emit("b15_retry_storm_free", storm_free, "bool",
         f"{n_retries} retried invocation(s) over {len(recs)} at 5% "
         "injected deaths — backoff-bounded, no 503 escaped")
    emit("b15_dollars_per_1k_q",
         round((led.total_dollars - dollars0) / n_logical * 1000.0, 6), "$",
         f"{n_logical} logical queries; sheds billed $0")


def bench_structured(n_docs: int, n_queries: int) -> None:
    """B16: structured queries — fielded scoring, phrases, facets, snippets
    through the windowed fleet, vs the bag-of-words baseline on the SAME
    fleet.

    One 4-partition ×2-replica structured (format-v2) fleet serves two
    phases over the identical burst arrival schedule: plain ``q``
    bag-of-words queries (the legacy path — unchanged kernels on the v1
    lanes of the v2 pack), then a structured-query mix (``synth_structured_
    queries``: fielded terms, quoted phrases, boosts, conjunctions) with a
    facet request per query. Gates (regression-rowed under --det):

    * structured top-k (ext ids AND f32 score bits, merge order included)
      equals ``StructuredOracleSearcher`` over the live corpus;
    * merged facet counts equal BOTH the oracle's packed count and its
      dict-based ``exact_facet_counts`` twin (full match set, not top-k);
    * phrase-query result sets equal the oracle's ``exact_match_set``
      (position adjacency survives partitioning and the merge);
    * snippets cover every query term present in each returned doc;
    * structured p99 ≤ 2× bag-of-words p99 — the structured surface rides
      the same windows and fleet shape, not a new latency regime.

    Reproduce: PYTHONPATH=src python -m benchmarks.run --fast --det --only b16
    """
    print("\nB16: structured queries — fielded/phrase/facet fleet vs oracle")
    import dataclasses as _dc

    from repro.core.gateway import WindowPolicy
    from repro.core.partition import (FleetSpec, GatewaySpec, IndexSpec,
                                      ReplicationSpec)
    from repro.core.runtime import nearest_rank_percentiles
    from repro.data.corpus import (synth_fielded_corpus, synth_queries,
                                   synth_structured_queries)
    from repro.index.tokenizer import flatten_text, tokenize
    from repro.search.oracle import StructuredOracleSearcher
    from repro.search.query import parse_query
    from repro.search.searcher import SearchConfig
    from repro.search.service import build_partitioned_search_app

    k = 10
    docs = synth_fielded_corpus(n_docs, vocab=max(2000, n_docs // 2), seed=0)
    sqs = synth_structured_queries(docs, n_queries, seed=16)
    bag = synth_queries([(e, flatten_text(t)) for e, t in docs], n_queries,
                        seed=17)
    window = WindowPolicy(max_window_s=0.08, target_batch=8, sparse_qps=2.0,
                          p99_budget_s=2.0)
    # k=100 fleet ceiling: requests still default to k=10, but the
    # phrase-set rows below need the FULL (≤100-doc) match set back — the
    # app clamps every request's k at the fleet's compiled search_k
    cfg = _dc.replace(_fleet_search_cfg() or SearchConfig(), k=100)
    app = build_partitioned_search_app(docs, FleetSpec(
        n_parts=4,
        replication=ReplicationSpec(replicas=2),
        gateway=GatewaySpec(window=window),
        index=IndexSpec(structured=True, facet_fields=("cat",)),
        search_config=cfg))
    app.warm()
    for q, sq in zip(bag[:4], sqs[:4]):      # compile + hydrate, off-clock
        app.query(q, k=k, t_arrival=app.runtime.clock + 0.05,
                  fetch_docs=False)
        app.query(sq=sq, k=k, facets=["cat"],
                  t_arrival=app.runtime.clock + 0.05, fetch_docs=False)

    # the SAME burst offsets replayed per phase (B14's window regime)
    rng = np.random.default_rng(SEED + 16)
    n_meas = 3 * n_queries
    offsets = np.cumsum(0.01 * rng.uniform(0.9, 1.1, size=n_meas))
    led = app.runtime.ledger
    p99s, results = {}, {}
    for phase in ("bag", "structured"):
        t0 = app.runtime.clock + 2.0
        dollars0 = led.total_dollars
        handles = []
        for i, off in enumerate(offsets):
            if phase == "bag":
                h = app.submit(bag[i % n_queries], k=k,
                               t_arrival=t0 + float(off), fetch_docs=False)
            else:
                h = app.submit(sq=sqs[i % n_queries], k=k, facets=["cat"],
                               t_arrival=t0 + float(off), fetch_docs=False)
            handles.append(h)
        app.flush()
        lats = [h.response.latency_s for h in handles]
        results[phase] = [h.response.body for h in handles]
        p = nearest_rank_percentiles(lats, qs=(0.5, 0.99))
        p99s[phase] = p[0.99]
        emit(f"b16_{phase}_gw_p50_ms", round(p[0.5] * 1e3, 1), "ms")
        emit(f"b16_{phase}_gw_p99_ms", round(p[0.99] * 1e3, 1), "ms",
             f"{n_meas} queries, same fleet + schedule per phase")
        emit(f"b16_{phase}_dollars_per_1k_q",
             round((led.total_dollars - dollars0) / n_meas * 1000.0, 6), "$")
    emit("b16_structured_p99_vs_bag",
         round(p99s["structured"] / p99s["bag"], 2), "x",
         "gate: <= 2 — same windows, host-side dense eval per partition")

    # oracle parity over the live corpus in fleet partition order
    live = app.indexer.live_corpus()
    oracle = StructuredOracleSearcher(live, facet_fields=("cat",))
    topk_ok = facets_ok = True
    for i, body in enumerate(results["structured"]):
        sq = sqs[i % n_queries]
        want = [(live[d][0], s) for d, s in oracle.search(sq, k)]
        topk_ok = topk_ok and \
            list(zip(body["ext_ids"], body["scores"])) == want
        counts = body["facets"]["cat"]
        facets_ok = facets_ok and counts == oracle.facet_counts(sq, "cat") \
            and counts == oracle.exact_facet_counts(sq, "cat")
    emit("b16_structured_topk_bitwise_equal", int(topk_ok), "bool",
         "fleet (ext id, f32 score) lists == StructuredOracleSearcher, "
         "order included")
    emit("b16_facets_equal_oracle", int(facets_ok), "bool",
         "merged counts == packed oracle == dict-twin exact counts")

    # phrase-only queries: the RESULT SET is the claim (exact adjacency)
    phrase_ok, n_ph = True, 0
    for sq in (s for s in sqs if s.startswith('"')):
        want_set = {live[d][0] for d in oracle.exact_match_set(sq)}
        if not want_set or len(want_set) > 100:
            continue
        r = app.query(sq=sq, k=100, t_arrival=app.runtime.clock + 0.05,
                      fetch_docs=False)
        phrase_ok = phrase_ok and r.ok and set(r.body["ext_ids"]) == want_set
        n_ph += 1
    assert n_ph > 0, "query mix produced no checkable phrase queries"
    emit("b16_phrase_sets_equal_oracle", int(phrase_ok), "bool",
         f"{n_ph} pure-phrase queries, exact match-set equality")

    # snippets ride the merge's deduped doc fetch: term coverage per hit
    snip_ok = True
    for sq in sqs[:8]:
        r = app.query(sq=sq, k=k, facets=["cat"], snippets=True,
                      t_arrival=app.runtime.clock + 0.05)
        terms = set(parse_query(sq).terms)
        for doc, snip in zip(r.body["docs"], r.body["snippets"]):
            for t in terms & set(tokenize(doc["contents"])):
                snip_ok = snip_ok and "<em>" in snip and t in snip.lower()
    emit("b16_snippets_cover_matched_terms", int(snip_ok), "bool",
         "every query term present in a returned doc is highlighted")


def main() -> None:
    global DET, SEED
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller corpora (CI-speed)")
    ap.add_argument("--det", action="store_true",
                    help="modeled exec clock in fleet benchmarks — "
                         "machine-independent latencies/costs for the CI "
                         "regression diff")
    ap.add_argument("--seed", type=int, default=0,
                    help="base seed for every benchmark RNG")
    ap.add_argument("--docs", type=int, default=None)
    ap.add_argument("--queries", type=int, default=None)
    ap.add_argument("--only", type=str, default=None,
                    help="comma-separated benchmark keys, e.g. b1,b6,b7")
    ap.add_argument("--json", type=str, default=None, metavar="PATH",
                    help="also write rows as JSON (CI bench-smoke artifact)")
    args = ap.parse_args()
    DET, SEED = args.det, args.seed
    n_docs = args.docs or (2_000 if args.fast else 20_000)
    n_q = args.queries or (100 if args.fast else 400)

    benches = {
        "b1": lambda: bench_latency(n_docs, n_q),
        "b2": lambda: bench_baseline(n_docs, min(n_q, 200)),
        "b3": bench_cost,                  # b3 covers B3+B4 (one cost table)
        "b5": lambda: bench_index_size(n_docs),
        "b6": lambda: bench_partitions(min(n_docs, 8_000), min(n_q, 100)),
        "b6b": lambda: bench_batched(min(n_docs, 8_000), min(n_q, 64)),
        "b7": lambda: bench_hedged_tail(min(n_docs, 8_000), min(n_q, 100)),
        "b8": bench_refresh,
        "b9": bench_roofline_summary,
        "b9b": bench_pruned_roofline,
        "b10": lambda: bench_autoscale(min(n_docs, 8_000), min(n_q, 108)),
        "b11": lambda: bench_nrt(min(n_docs, 6_000), min(n_q, 120)),
        "b12": lambda: bench_skew(min(n_docs, 2_000), min(n_q, 100)),
        "b13": lambda: bench_cold_start(min(n_docs, 8_000), min(n_q, 12)),
        "b14": lambda: bench_hybrid(min(n_docs, 1_500), min(n_q, 48)),
        "b15": lambda: bench_overload(min(n_docs, 2_000), min(n_q, 96)),
        "b16": lambda: bench_structured(min(n_docs, 1_500), min(n_q, 40)),
    }
    only = None
    if args.only:
        only = {k.strip().lower() for k in args.only.split(",") if k.strip()}
        unknown = only - set(benches)
        if unknown:
            ap.error(f"unknown benchmark keys {sorted(unknown)}; "
                     f"choose from {sorted(benches)}")

    t0 = time.time()
    for key, fn in benches.items():
        if only is None or key in only:
            _seed_all(args.seed)    # per-bench: immune to --only selection
            fn()

    print(f"\n# total bench wall time: {time.time() - t0:.1f}s")
    print("\nname,value,unit,derived")
    for r in ROWS:
        print(",".join(str(x) for x in r))
    if args.json:
        with open(args.json, "w") as f:
            json.dump([{"name": n, "value": v, "unit": u, "derived": d}
                       for n, v, u, d in ROWS], f, indent=2)
        print(f"# wrote {len(ROWS)} rows to {args.json}")


if __name__ == "__main__":
    main()
