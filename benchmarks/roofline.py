"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads benchmarks/results/dryrun/<mesh>/*.json (written by
repro.launch.dryrun) and derives, per (arch × shape) cell:

    compute term    = HLO_FLOPs/dev   / peak_FLOP/s          [s]
    memory term     = HLO_bytes/dev   / HBM_bw               [s]
    collective term = coll_bytes/dev  / link_bw              [s]

plus the dominant term, MODEL_FLOPS (6·N·D dense / 6·N_active·D MoE, and the
family-appropriate analogue for GNN/recsys/search), the useful-compute ratio
MODEL_FLOPS / HLO_FLOPs, and the roofline fraction

    frac = model_compute_time / max(compute, memory, collective)

— the fraction of the binding roofline actually spent on model math (1.0 ⇔
the cell runs at the hardware bound with zero waste).

Usage: PYTHONPATH=src python -m benchmarks.roofline [--mesh pod1_16x16]
"""

from __future__ import annotations

import argparse
import json
import os

PEAK = 197e12          # bf16 FLOP/s per chip (TPU v5e)
HBM_BW = 819e9         # B/s per chip
ICI_BW = 50e9          # B/s per link per chip

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def _chips(mesh_name: str) -> int:
    return 512 if "2x16x16" in mesh_name else 256


# -- analytic MODEL_FLOPS per cell (global, forward-equivalent useful math) ----


def lm_model_flops(arch: str, shape: str) -> float:
    from repro.configs import get_arch
    cfg = get_arch(arch).full_config()
    n_active = cfg.active_param_count()
    B = {"train_4k": 256, "prefill_32k": 32, "decode_32k": 128,
         "long_500k": 1}[shape]
    S = {"train_4k": 4096, "prefill_32k": 32768, "decode_32k": 1,
         "long_500k": 1}[shape]
    tokens = B * S
    mult = 6 if shape == "train_4k" else 2      # fwd+bwd vs fwd
    flops = mult * n_active * tokens
    # decode attention reads: 2·B·L·Hkv-width dot over kv_len — count the
    # attention math for decode cells (it dominates decode usefulness)
    if shape == "decode_32k":
        kv = 32768
        flops += 4 * B * cfg.n_layers * cfg.n_heads * cfg.qk_dim * kv
    if shape == "long_500k" and cfg.window:
        flops += 4 * B * cfg.n_layers * cfg.n_heads * cfg.qk_dim * cfg.window
    return flops


def gnn_model_flops(shape: str) -> float:
    from repro.configs.cells import GNN_SHAPES
    from repro.configs import get_arch
    sh = GNN_SHAPES[shape]
    cfg = get_arch("graphcast").full_config(d_feat=sh["d_feat"])
    h, L = cfg.d_hidden, cfg.n_layers
    E = sh["n_edges"] * sh.get("batch", 1)
    N = sh["n_nodes"] * sh.get("batch", 1)
    per_edge = 2 * (3 * h * h + h * h)          # edge MLP
    per_node = 2 * (2 * h * h + h * h)          # node MLP
    enc_dec = 2 * N * (sh["d_feat"] * h + h * h) * 2 + 2 * E * (2 * h * h + h * h)
    fwd = L * (E * per_edge + N * per_node) + enc_dec
    return 3 * fwd                               # train: fwd + bwd


def recsys_model_flops(arch: str, shape: str) -> float:
    from repro.configs import get_arch
    from repro.configs.cells import RECSYS_SHAPES
    cfg = get_arch(arch).full_config()
    B = RECSYS_SHAPES[shape]["batch"]
    d = cfg.embed_dim
    if cfg.kind == "fm":
        per = 4 * cfg.n_sparse * d
    elif cfg.kind == "dcn":
        d0 = cfg.n_dense + cfg.n_sparse * d
        per = 2 * (cfg.n_cross_layers * d0 * d0)
        dims = (d0,) + tuple(cfg.mlp_dims)
        per += 2 * sum(a * b for a, b in zip(dims, dims[1:]))
    else:
        S = cfg.seq_len + (1 if cfg.kind == "bst" else 0)
        per_block = 2 * (4 * d * d * S + 2 * S * S * d + 8 * d * d * S)
        per = cfg.n_blocks * per_block
        if cfg.kind == "bst":
            dims = ((S) * d,) + tuple(cfg.mlp_dims) + (1,)
            per += 2 * sum(a * b for a, b in zip(dims, dims[1:]))
    if shape == "retrieval_cand":
        return 2 * RECSYS_SHAPES[shape]["cands"] * d
    mult = 3 if shape == "train_batch" else 1
    if cfg.kind == "bert4rec" and shape == "train_batch":
        per += 2 * 32 * 1025 * d                 # sampled softmax
    if cfg.kind == "bert4rec" and shape.startswith("serve"):
        per += 2 * (cfg.n_items + 2) * d         # full-vocab last-position
    return mult * B * per


def search_model_flops(shape: str, n_parts: int) -> float:
    from repro.configs.anlessini import SHAPES, full_config
    cfg = full_config(n_parts)
    Q = SHAPES[shape]["Q"]
    # per query-term-block BM25: ~6 flops per posting slot
    return Q * cfg.max_terms * cfg.max_blocks * cfg.block * 6.0 * n_parts


def model_flops(cell: str, mesh_name: str) -> float | None:
    arch, shape = cell.split("/")
    try:
        if arch == "graphcast":
            return gnn_model_flops(shape)
        if arch == "anlessini":
            return search_model_flops(shape, _chips(mesh_name))
        from repro.configs import get_arch
        fam = get_arch(arch).FAMILY
        if fam == "lm":
            return lm_model_flops(arch, shape)
        if fam == "recsys":
            return recsys_model_flops(arch, shape)
    except Exception:
        return None
    return None


# -- table ------------------------------------------------------------------------


def analyze(mesh_name: str) -> list[dict]:
    d = os.path.join(RESULTS, mesh_name)
    rows = []
    if not os.path.isdir(d):
        return rows
    for fn in sorted(os.listdir(d)):
        if not fn.endswith(".json"):
            continue
        rec = json.load(open(os.path.join(d, fn)))
        if rec.get("skip"):
            rows.append({"cell": rec["cell"], "skip": True,
                         "note": rec.get("note", "")})
            continue
        if not rec.get("ok"):
            rows.append({"cell": rec["cell"], "error": rec.get("error")})
            continue
        pd = rec["per_device"]
        t_c = pd["flops"] / PEAK
        t_m = pd["bytes_accessed"] / HBM_BW
        t_x = rec["collectives"]["total_bytes"] / ICI_BW
        dominant = max(("compute", t_c), ("memory", t_m),
                       ("collective", t_x), key=lambda kv: kv[1])[0]
        mf = model_flops(rec["cell"], mesh_name)
        chips = _chips(mesh_name)
        mf_dev = mf / chips if mf else None
        rows.append({
            "cell": rec["cell"],
            "kind": rec.get("kind"),
            "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
            "dominant": dominant,
            "hlo_flops_dev": pd["flops"],
            "model_flops_dev": mf_dev,
            "useful_ratio": (mf_dev / pd["flops"]) if mf_dev and pd["flops"]
                            else None,
            "roofline_frac": (mf_dev / PEAK) / max(t_c, t_m, t_x, 1e-30)
                             if mf_dev else None,
            "peak_gib": pd["peak_bytes"] / 2 ** 30,
            "args_gib": pd["argument_bytes"] / 2 ** 30,
            "fits_16g": (pd["argument_bytes"] + pd["output_bytes"]) < 16 * 2 ** 30,
        })
    return rows


def fmt_table(rows: list[dict]) -> str:
    hdr = (f"{'cell':34s} {'dom':10s} {'t_comp':>9s} {'t_mem':>9s} "
           f"{'t_coll':>9s} {'useful':>7s} {'roofl%':>7s} {'args GiB':>9s}")
    out = [hdr, "-" * len(hdr)]
    for r in rows:
        if r.get("skip"):
            out.append(f"{r['cell']:34s} {'—  (N/A: sub-quadratic gate)'}")
            continue
        if r.get("error"):
            out.append(f"{r['cell']:34s} ERROR {r['error'][:60]}")
            continue
        u = f"{r['useful_ratio']:.2f}" if r["useful_ratio"] else "  —"
        rf = f"{100 * r['roofline_frac']:.1f}" if r["roofline_frac"] else "  —"
        out.append(
            f"{r['cell']:34s} {r['dominant']:10s} "
            f"{r['t_compute_s']:9.2e} {r['t_memory_s']:9.2e} "
            f"{r['t_collective_s']:9.2e} {u:>7s} {rf:>7s} "
            f"{r['args_gib']:9.2f}")
    return "\n".join(out)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod1_16x16")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    rows = analyze(args.mesh)
    print(f"Roofline — mesh {args.mesh} ({_chips(args.mesh)} chips), "
          f"peak {PEAK/1e12:.0f} TF/s bf16, HBM {HBM_BW/1e9:.0f} GB/s, "
          f"ICI {ICI_BW/1e9:.0f} GB/s")
    print(fmt_table(rows))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
