"""Serving driver — the paper's architecture end to end (Figure 1).

Builds the full serverless stack on a synthetic MS-MARCO-like corpus:
ObjectStore (S3) ← index segments, KVStore (DynamoDB) ← raw docs,
FaaSRuntime (Lambda fleet) + Gateway (API Gateway) → search clients.
Replays a query load, reports the paper's numbers: end-to-end latency
percentiles (target < 300 ms warm), cold/warm split, queries-per-dollar
(target ~100k/$ at 2GB×300ms), and load fungibility.

    PYTHONPATH=src python -m repro.launch.serve --docs 20000 --queries 500
    PYTHONPATH=src python -m repro.launch.serve --partitions 4   # §3 scale-out
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core.cost import paper_headline_cost
from repro.core.runtime import RuntimeConfig
from repro.data.corpus import synth_corpus, synth_queries
from repro.search.searcher import SearchConfig
from repro.search.service import build_search_app


def run_single(args) -> dict:
    docs = synth_corpus(args.docs, vocab=args.vocab, seed=0)
    queries = synth_queries(docs, args.queries, seed=1)
    app = build_search_app(
        docs,
        runtime_config=RuntimeConfig(memory_bytes=args.memory_gb << 30,
                                     hedge_after_s=args.hedge or None),
        search_config=SearchConfig(k=args.k, use_kernel=args.kernel),
    )
    # Poisson arrivals at --qps
    rng = np.random.default_rng(2)
    arrivals = np.cumsum(rng.exponential(1.0 / args.qps, len(queries)))
    t0 = time.perf_counter()
    n_hits = 0
    for q, t in zip(queries, arrivals):
        r = app.query(q, k=args.k, t_arrival=float(t))
        assert r.ok, r
        n_hits += len(r.body["ids"])
    wall = time.perf_counter() - t0

    lat = app.runtime.latency_percentiles("search")
    ledger = app.runtime.ledger
    out = {
        "queries": len(queries),
        "wall_s": round(wall, 2),
        "latency_p50_ms": round(lat[0.5] * 1e3, 1),
        "latency_p90_ms": round(lat[0.9] * 1e3, 1),
        "latency_p99_ms": round(lat[0.99] * 1e3, 1),
        "warm_fraction": round(app.runtime.warm_fraction("search"), 3),
        "fleet_size": app.runtime.fleet_size,
        "queries_per_dollar": round(ledger.queries_per_dollar()),
        "paper_headline_q_per_dollar": round(paper_headline_cost()),
        "index_bytes": sum(m.size for m in app.store.list("assets/")),
        "avg_hits": n_hits / len(queries),
    }
    return out


def run_partitioned(args) -> dict:
    from repro.core.partition import FleetSpec, HedgePolicy, ReplicationSpec
    from repro.search.service import build_partitioned_search_app

    docs = synth_corpus(args.docs, vocab=args.vocab, seed=0)
    queries = synth_queries(docs, args.queries, seed=1)
    hedge = None
    if args.replicas > 1:
        hedge = HedgePolicy(after_s=args.hedge or None)
    app = build_partitioned_search_app(docs, FleetSpec(
        n_parts=args.partitions,
        replication=ReplicationSpec(replicas=args.replicas, hedge=hedge),
        runtime_config=RuntimeConfig(memory_bytes=args.memory_gb << 30),
        search_config=SearchConfig(k=args.k)))
    if args.replicas > 1:
        app.warm()           # replica pools see no traffic until a hedge fires

    for q in queries:
        r = app.query(q, k=args.k, fetch_docs=False)
        assert r.ok, r
    lat = app.gateway.latency_percentiles("GET", "/search")
    ledger = app.runtime.ledger
    # gw_* keys: measured at the gateway (incl. proxy overhead, excl. doc
    # fetch) — NOT comparable to the pre-refactor latency_p*_ms, which was
    # raw scatter latency including per-partition doc fetch
    return {
        "partitions": args.partitions,
        "replicas": args.replicas,
        "queries": len(queries),
        "gw_latency_p50_ms": round(lat[0.5] * 1e3, 1),
        "gw_latency_p99_ms": round(lat[0.99] * 1e3, 1),
        "hedged_legs": sum(r.hedged for r in app.runtime.records),
        # per LOGICAL query — ledger.queries_per_dollar() counts invocations,
        # which a partitioned (and hedged) fan-out multiplies per query
        "queries_per_dollar": round(len(queries) / ledger.total_dollars)
        if ledger.total_dollars else float("inf"),
        "dollars_per_1k_queries": round(ledger.dollars_per_1k(len(queries)), 6),
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=20_000)
    ap.add_argument("--queries", type=int, default=500)
    ap.add_argument("--vocab", type=int, default=20_000)
    ap.add_argument("--qps", type=float, default=20.0)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--memory-gb", type=int, default=2)
    ap.add_argument("--partitions", type=int, default=0)
    ap.add_argument("--replicas", type=int, default=1,
                    help="replica functions per partition (hedged scatter)")
    ap.add_argument("--hedge", type=float, default=0.0)
    ap.add_argument("--kernel", action="store_true",
                    help="use the Pallas BM25 kernel (interpret on CPU)")
    args = ap.parse_args()

    out = run_partitioned(args) if args.partitions else run_single(args)
    print(json.dumps(out, indent=2))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
