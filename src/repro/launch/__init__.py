"""Launchers: mesh construction, multi-pod dry-run, train and serve drivers.

NOTE: repro.launch.dryrun sets XLA_FLAGS at import — import it only in a
dedicated process (python -m repro.launch.dryrun)."""
