"""Training driver: config → mesh → sharded jit step → fault-tolerant loop.

The conventional (non-serverless) half of the framework, bridged to the
paper's world by checkpointing into the same ObjectStore the serving fleet
hydrates from (paper §3 batch-rebuild → refresh).

CPU-runnable end to end with reduced/custom configs, e.g.:

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-3b \
        --preset 100m --steps 300 --batch 16 --seq 256

On a real cluster the same driver runs the full configs on the production
mesh (--mesh prod / prod-multipod).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointConfig, CheckpointManager
from repro.configs import get_arch
from repro.core.object_store import FilesystemBackend, ObjectStore
from repro.data.lm import LMDataConfig, LMTokenStream
from repro.ft.faults import FailureInjector, StragglerMonitor, run_with_restarts
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.common import init_params
from repro.parallel import compat
from repro.parallel.sharding import tree_named
from repro.train.optim import OptConfig
from repro.train.steps import init_train_state, make_train_step


def _preset_100m(arch_mod, vocab: int = 8192):
    """~100M-param variant of an LM arch family (example driver scale),
    preserving the family's GQA ratio / MoE / MLA structure.

    ~102M params for the dense families; ≈12 s/step on a 1-core CPU host at
    batch 8 × seq 128 — 'a few hundred steps' is a real-accelerator run,
    examples/train_lm.py defaults to a shorter CPU drill."""
    import dataclasses as dc
    cfg = arch_mod.reduced_config()
    ratio = max(1, cfg.n_heads // max(cfg.n_kv_heads, 1))
    return dc.replace(cfg, n_layers=10, d_model=896, n_heads=14,
                      n_kv_heads=max(1, 14 // ratio), d_ff=2048, vocab=vocab)


def build_lm_training(arch: str, preset: str, batch: int, seq: int,
                      steps: int, lr: float):
    mod = get_arch(arch)
    if preset == "100m":
        cfg = _preset_100m(mod)
    elif preset == "reduced":
        cfg = mod.reduced_config()
    elif preset == "full":
        cfg = mod.full_config()
    else:
        raise ValueError(preset)

    from repro.models.transformer import lm_loss, lm_param_defs
    defs = lm_param_defs(cfg)
    opt_cfg = OptConfig(lr=lr, warmup_steps=min(100, steps // 10 + 1),
                        total_steps=steps)
    step_fn = make_train_step(lambda p, b: lm_loss(p, b, cfg), opt_cfg)
    data = LMTokenStream(LMDataConfig(vocab=cfg.vocab, batch=batch, seq=seq))
    return cfg, defs, step_fn, data


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--preset", default="100m",
                    choices=["100m", "reduced", "full"])
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", default="host",
                    choices=["host", "prod", "prod-multipod"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[],
                    help="inject failures at these steps (FT drill)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args()

    mod = get_arch(args.arch)
    if mod.FAMILY != "lm":
        raise SystemExit("train driver currently drives LM archs; "
                         "see examples/ for GNN/recsys training")

    cfg, defs, step_fn, data = build_lm_training(
        args.arch, args.preset, args.batch, args.seq, args.steps, args.lr)

    if args.mesh == "host":
        n = len(jax.devices())
        mesh = make_host_mesh((n, 1))
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "prod-multipod")
    rules = mod.rules()
    if "pod" in mesh.axis_names:
        rules = rules.with_pod()

    from repro.configs.cells import train_state_specs
    sspecs = train_state_specs(defs, rules)
    shardings = tree_named(mesh, sspecs)
    bspec = {"tokens": rules.batch_spec(None), "labels": rules.batch_spec(None)}
    bshard = tree_named(mesh, bspec)

    with compat.use_mesh(mesh):
        jstep = jax.jit(step_fn, in_shardings=(shardings, bshard),
                        donate_argnums=(0,))

        store = ObjectStore(FilesystemBackend(args.ckpt_dir))
        ckpt = CheckpointManager(
            store, name=f"{args.arch}-{args.preset}",
            config=CheckpointConfig(every_steps=args.ckpt_every))

        def init_fn():
            params = init_params(defs, jax.random.PRNGKey(0))
            return init_train_state(params)

        state, start = ckpt.restore_or_init(init_fn, shardings=shardings)
        if start:
            print(f"resumed from checkpoint step {start}")

        monitor = StragglerMonitor()
        injector = FailureInjector(fail_at=tuple(args.fail_at))
        history: list[dict] = []
        t_start = time.time()

        def one_step(state, step):
            t0 = time.perf_counter()
            batch = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), data.batch(step), bshard)
            state, metrics = jstep(state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            monitor.record(step, dt)
            if step % args.log_every == 0:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"({dt * 1e3:.0f} ms/step)")
            history.append({"step": step, "loss": loss, "sec": dt})
            return state

        state, stats = run_with_restarts(
            one_step, state, args.steps, ckpt, injector=injector)
        ckpt.save(args.steps, state)
        ckpt.wait()

    wall = time.time() - t_start
    print(f"done: {args.steps} steps in {wall:.1f}s; "
          f"restarts={stats.restarts} steps_lost={stats.steps_lost} "
          f"stragglers={len(monitor.flagged)}")
    first = np.mean([h["loss"] for h in history[:10]])
    last = np.mean([h["loss"] for h in history[-10:]])
    print(f"loss: first10={first:.4f} last10={last:.4f}")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump({"history": history, "restarts": stats.restarts,
                       "steps_lost": stats.steps_lost}, f)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
