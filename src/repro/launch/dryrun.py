import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape) cell
on the production meshes, and dump memory/cost/collective analysis.

The two lines above MUST stay the first two statements of this module —
jax locks the device count on first init, and the dry-run needs 512
placeholder CPU devices to build the (pod=2, data=16, model=16) mesh.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                 # all cells, both meshes
    PYTHONPATH=src python -m repro.launch.dryrun --arch fm --shape train_batch
    PYTHONPATH=src python -m repro.launch.dryrun --multi-pod     # 2×16×16 only
    PYTHONPATH=src python -m repro.launch.dryrun --force         # ignore cache

Per cell it writes benchmarks/results/dryrun/<mesh>/<arch>__<shape>.json with
per-device FLOPs, bytes, peak memory, and collective-bytes-by-op parsed from
the post-SPMD optimized HLO — the inputs to the roofline analysis
(benchmarks/roofline.py, EXPERIMENTS.md §Roofline).
"""

import argparse
import json
import re
import time
import traceback

import jax

from repro.parallel import compat
from repro.configs import all_cells, build_cells
from repro.launch.mesh import make_production_mesh

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device collective bytes by op, from post-partitioning HLO.

    Convention: bytes = output-shape bytes; all-reduce counted twice
    (ring = send+recv of ~the full payload each way)."""
    out: dict[str, float] = {}
    counts: dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape, op = m.group(1), m.group(2)
        b = _shape_bytes(shape)
        if op == "all-reduce":
            b *= 2
        out[op] = out.get(op, 0) + b
        counts[op] = counts.get(op, 0) + 1
    return {"bytes_by_op": out, "counts": counts,
            "total_bytes": float(sum(out.values()))}


def run_cell(name: str, cell, mesh, mesh_name: str, out_dir: str,
             *, force: bool = False, verbose: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, name.replace("/", "__") + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            rec = json.load(f)
        if rec.get("ok") or rec.get("skip"):
            if verbose:
                print(f"[cache] {mesh_name} {name}: "
                      f"{'skip' if rec.get('skip') else 'ok'}")
            return rec

    if cell.skip:
        rec = {"cell": name, "mesh": mesh_name, "skip": True,
               "note": cell.note}
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        if verbose:
            print(f"[skip ] {mesh_name} {name}: {cell.note[:80]}")
        return rec

    t0 = time.time()
    try:
        if hasattr(cell, "build"):                 # late-bound (anlessini)
            fn, args, specs = cell.build(mesh)
        else:
            fn, args, specs = cell.fn, cell.args, cell.in_specs
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P
        shardings = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), specs,
            is_leaf=lambda x: isinstance(x, P))
        with compat.use_mesh(mesh):
            jitted = jax.jit(fn, in_shardings=shardings,
                             donate_argnums=cell.donate)
            lowered = jitted.lower(*args)
            compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        rec = {
            "cell": name, "mesh": mesh_name, "ok": True,
            "kind": cell.kind,
            "compile_s": round(time.time() - t0, 2),
            "per_device": {
                "flops": float(cost.get("flops", 0.0)),
                "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
                "argument_bytes": int(mem.argument_size_in_bytes),
                "output_bytes": int(mem.output_size_in_bytes),
                "temp_bytes": int(mem.temp_size_in_bytes),
                "peak_bytes": int(mem.peak_memory_in_bytes),
            },
            "collectives": coll,
            "hlo_bytes": len(hlo),
        }
        if verbose:
            pd = rec["per_device"]
            print(f"[ok   ] {mesh_name} {name}: "
                  f"flops/dev={pd['flops']:.3g} "
                  f"bytes/dev={pd['bytes_accessed']:.3g} "
                  f"peak={pd['peak_bytes'] / 2**30:.2f}GiB "
                  f"coll={coll['total_bytes']:.3g}B "
                  f"({rec['compile_s']}s)")
    except Exception as e:
        rec = {"cell": name, "mesh": mesh_name, "ok": False,
               "error": f"{type(e).__name__}: {e}",
               "compile_s": round(time.time() - t0, 2)}
        if verbose:
            print(f"[FAIL ] {mesh_name} {name}: {rec['error'][:160]}")
            traceback.print_exc(limit=4)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape name")
    ap.add_argument("--multi-pod", action="store_true",
                    help="only the 2×16×16 mesh")
    ap.add_argument("--single-pod", action="store_true",
                    help="only the 16×16 mesh")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--reduced", action="store_true", help="debug: tiny configs")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    meshes = []
    if not args.multi_pod:
        meshes.append(("pod1_16x16", False))
    if not args.single_pod:
        meshes.append(("pod2_2x16x16", True))

    base_out = args.out or os.path.normpath(RESULTS_DIR)
    n_fail = 0
    for mesh_name, multi_pod in meshes:
        mesh = make_production_mesh(multi_pod=multi_pod)
        if args.arch:
            cells = {f"{args.arch}/{k}": v for k, v in build_cells(
                args.arch, multi_pod=multi_pod, reduced=args.reduced).items()}
        else:
            cells = all_cells(multi_pod=multi_pod, reduced=args.reduced)
        if args.shape:
            cells = {k: v for k, v in cells.items()
                     if k.endswith("/" + args.shape)}
        out_dir = os.path.join(base_out, mesh_name)
        for name, cell in cells.items():
            rec = run_cell(name, cell, mesh, mesh_name, out_dir,
                           force=args.force)
            if not (rec.get("ok") or rec.get("skip")):
                n_fail += 1
    print(f"\ndry-run complete; failures: {n_fail}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
