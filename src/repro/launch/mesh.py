"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init; smoke tests and benches see the 1 real CPU device.

Mesh topology (TPU v5e target):
  single pod : (data=16, model=16)            — 256 chips
  multi-pod  : (pod=2, data=16, model=16)     — 512 chips, `pod` = outer DP
"""

from __future__ import annotations

from jax.sharding import Mesh

from repro.parallel import compat


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(shape=(1, 1), axes=("data", "model")) -> Mesh:
    """Tiny mesh over however many (CPU) devices exist — tests/examples."""
    return compat.make_mesh(shape, axes)


def mesh_devices(mesh: Mesh) -> int:
    return mesh.devices.size


# TPU v5e hardware constants used by the roofline analysis (per chip).
PEAK_BF16_FLOPS = 197e12        # 197 TFLOP/s bf16
HBM_BW = 819e9                  # 819 GB/s
ICI_BW = 50e9                   # ~50 GB/s per link (per-direction, per chip)
HBM_PER_CHIP = 16 * 1024 ** 3   # 16 GiB
