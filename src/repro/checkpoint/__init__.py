"""Checkpointing through the versioned ObjectStore asset machinery."""

from repro.checkpoint.manager import (CheckpointConfig, CheckpointManager,
                                      load_pytree, save_pytree)

__all__ = ["CheckpointConfig", "CheckpointManager", "load_pytree",
           "save_pytree"]
