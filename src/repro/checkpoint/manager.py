"""Checkpointing to the ObjectStore — training's durable state, through the
same versioned-asset machinery the paper uses for index switch-over (§3).

A checkpoint is one asset version (``ckpt/<name>`` at version ``step-%09d``):
each pytree leaf is one ``.npy`` object plus a JSON manifest of paths/shapes/
dtypes. Publishing is atomic (AssetCatalog's compare-and-set manifest), so a
crash mid-save never corrupts the restore point — the manifest still names
the previous complete version. This *is* the paper's "new indexes placed
alongside the old, then switch" pattern applied to train state.

``CheckpointManager`` adds: save-every-N cadence, async save (background
thread — training continues while bytes stream out), keep-last-K GC, and
restore-latest. Restore reshards to the live mesh via ``jax.device_put``
with the caller's shardings — which is also the *elastic rescale* path: a
checkpoint written on one mesh restores onto any other.
"""

from __future__ import annotations

import dataclasses
import io
import threading
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.core import jsonutil as orjson   # orjson when installed

from repro.core.directory import RamDirectory
from repro.core.object_store import ObjectStore
from repro.core.refresh import AssetCatalog


def _leaf_key(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts) or "_root"


def save_pytree(tree: Any) -> RamDirectory:
    """Serialize a pytree of arrays into Directory files + manifest."""
    d = RamDirectory()
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    manifest = []
    for i, (path, leaf) in enumerate(leaves):
        arr = np.asarray(leaf)
        buf = io.BytesIO()
        np.save(buf, arr, allow_pickle=False)
        fname = f"leaf{i:05d}.npy"
        d.write(fname, buf.getvalue())
        manifest.append({"key": _leaf_key(path), "file": fname,
                         "shape": list(arr.shape), "dtype": str(arr.dtype)})
    d.write("manifest.json", orjson.dumps(manifest))
    return d


def load_pytree(directory, like: Any, *, shardings: Any = None) -> Any:
    """Read leaves back and unflatten into `like`'s structure; device_put
    with `shardings` if given (elastic restore onto a different mesh)."""
    manifest = orjson.loads(directory.open_input("manifest.json").read_all())
    leaves_like, tdef = jax.tree_util.tree_flatten(like)
    if len(manifest) != len(leaves_like):
        raise ValueError(f"checkpoint has {len(manifest)} leaves, "
                         f"expected {len(leaves_like)}")
    for ent, leaf in zip(manifest, leaves_like):
        if tuple(ent["shape"]) != tuple(leaf.shape):
            raise ValueError(
                f"checkpoint leaf {ent['key']!r} has shape "
                f"{tuple(ent['shape'])}, expected {tuple(leaf.shape)} — "
                "stale checkpoint for a different config?")
    arrs = []
    for ent in manifest:
        data = directory.open_input(ent["file"]).read_all()
        arrs.append(np.load(io.BytesIO(data), allow_pickle=False))
    if shardings is not None:
        sh_leaves = jax.tree_util.tree_leaves(shardings)
        arrs = [jax.device_put(a, s) for a, s in zip(arrs, sh_leaves)]
    return jax.tree_util.tree_unflatten(tdef, arrs)


@dataclasses.dataclass
class CheckpointConfig:
    every_steps: int = 50
    keep: int = 3
    async_save: bool = True


class CheckpointManager:
    def __init__(self, store: ObjectStore, name: str = "train",
                 config: CheckpointConfig | None = None) -> None:
        self.catalog = AssetCatalog(store, root="ckpt")
        self.name = name
        self.config = config or CheckpointConfig()
        self._pending: threading.Thread | None = None
        self.saves = 0
        self.save_seconds = 0.0

    # -- write ------------------------------------------------------------------

    def _version(self, step: int) -> str:
        return f"step-{step:09d}"

    def maybe_save(self, step: int, state: Any) -> bool:
        if step % self.config.every_steps != 0:
            return False
        self.save(step, state)
        return True

    def save(self, step: int, state: Any) -> None:
        # snapshot to host BEFORE handing to the writer thread (donated
        # buffers may be reused by the next step otherwise)
        host_state = jax.tree_util.tree_map(lambda x: np.asarray(x), state)
        self.wait()                       # one in-flight save at a time

        def _write():
            t0 = time.perf_counter()
            d = save_pytree(host_state)
            self.catalog.publish(self.name, self._version(step), d)
            self.catalog.gc(self.name, keep=self.config.keep)
            self.save_seconds += time.perf_counter() - t0
            self.saves += 1

        if self.config.async_save:
            self._pending = threading.Thread(target=_write, daemon=True)
            self._pending.start()
        else:
            _write()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    # -- read -------------------------------------------------------------------

    def latest_step(self) -> int | None:
        try:
            v = self.catalog.current_version(self.name)
        except Exception:
            return None
        return int(v.split("-")[1])

    def restore(self, like: Any, *, step: int | None = None,
                shardings: Any = None) -> tuple[Any, int]:
        """Returns (state, step). Raises if no checkpoint exists."""
        self.wait()
        version = None if step is None else self._version(step)
        v, directory = self.catalog.open(self.name, version)
        state = load_pytree(directory, like, shardings=shardings)
        return state, int(v.split("-")[1])

    def restore_or_init(self, init_fn: Callable[[], Any], *,
                        shardings: Any = None) -> tuple[Any, int]:
        like = jax.eval_shape(init_fn)
        try:
            return self.restore(like, shardings=shardings)
        except Exception:
            state = init_fn()
            if shardings is not None:
                state = jax.tree_util.tree_map(
                    lambda x, s: jax.device_put(x, s), state, shardings)
            return state, 0
