"""Inverted-index builder → packed, blocked, impact-ordered arrays.

Lucene stores postings as compressed, doc-ordered skip-list streams —
pointer-chasing that the TPU's vector units cannot traverse. The TPU-native
equivalent (DESIGN.md §2) packs each term's postings into fixed-width blocks:

    term_offsets : (V+1,)      int32   block range of term t = [off[t], off[t+1])
    block_docs   : (NB, B)     int32   doc ids, PAD = n_docs (dump slot)
    block_tf     : (NB, B)     uint8   term frequency, clamped to 255
    block_max    : (NB,)       float32 max BM25 impact within the block
    doc_len      : (n_docs+1,) float32 document length (dump slot appended)
    idf          : (V,)        float32 BM25 idf per term

Blocks within a term are sorted by descending ``block_max`` (impact ordering,
Lin & Trotman '17 — cited by the paper): truncating evaluation to the first M
blocks of each term is the classic score-at-a-time approximation, and gives
the fixed shapes jit needs. B = 128 matches the TPU lane width.

BM25 (Lucene's variant, k1=0.9, b=0.4 Anserini defaults):

    idf(t)   = ln(1 + (N - df + 0.5)/(df + 0.5))
    score    = idf(t) * tf / (tf + k1 * (1 - b + b * dl/avgdl))

(Lucene folds the (k1+1) numerator constant away since it is rank-neutral;
we follow Lucene.)
"""

from __future__ import annotations

import dataclasses
import io
import math
from typing import Iterable

import numpy as np

from repro.core import jsonutil as orjson   # orjson when installed

from repro.core.directory import Directory, RamDirectory
from repro.index.tokenizer import tokenize

BLOCK = 128          # lane width
K1_DEFAULT = 0.9     # Anserini defaults
B_DEFAULT = 0.4


@dataclasses.dataclass
class IndexMeta:
    n_docs: int
    n_terms: int
    n_blocks: int
    block: int
    avgdl: float
    k1: float
    b: float
    doc_ids: list[str]          # external ids, position = internal id

    def to_json(self) -> bytes:
        return orjson.dumps(dataclasses.asdict(self))

    @classmethod
    def from_json(cls, data: bytes) -> "IndexMeta":
        return cls(**orjson.loads(data))


@dataclasses.dataclass
class PackedIndex:
    """The hydrated, array-form index (a pytree of numpy/jax arrays)."""

    meta: IndexMeta
    vocab: dict[str, int]
    term_offsets: np.ndarray    # (V+1,) int32
    block_docs: np.ndarray      # (NB, B) int32
    block_tf: np.ndarray        # (NB, B) uint8
    block_max: np.ndarray       # (NB,) float32
    doc_len: np.ndarray         # (n_docs+1,) float32
    idf: np.ndarray             # (V,) float32

    def term_id(self, term: str) -> int:
        return self.vocab.get(term, -1)

    @property
    def nbytes(self) -> int:
        return sum(a.nbytes for a in (
            self.term_offsets, self.block_docs, self.block_tf,
            self.block_max, self.doc_len, self.idf))


def compute_global_stats(docs: Iterable[tuple[str, str]]) -> dict:
    """Corpus-wide BM25 statistics for document-partitioned indexing.

    Distributed IR subtlety the paper's §3 glosses over: each partition's
    index must score with GLOBAL idf/avgdl, or the merged ranking diverges
    from a single-index build. The offline batch indexer computes these
    once and passes them to every partition's writer.
    """
    from collections import Counter
    df: Counter = Counter()
    total_len = 0
    n_docs = 0
    for _, text in docs:
        toks = tokenize(text)
        total_len += len(toks)
        n_docs += 1
        df.update(set(toks))
    return {"n_docs": n_docs,
            "avgdl": total_len / max(1, n_docs),
            "df": dict(df)}


def global_vocab(stats: dict) -> dict[str, int]:
    """Deterministic corpus-global term→id map from compute_global_stats.

    This ordering IS the cross-path term-id contract: the mesh state's
    shared ``term_offsets``/``idf`` indexing and the fleet handlers'
    idf-ranked ``max_terms`` truncation both assume every partition was
    packed against exactly this map."""
    return {t: i for i, t in enumerate(sorted(stats["df"]))}


class IndexWriter:
    """Accumulates documents, then packs. Offline batch side of paper §3.

    ``global_stats`` (from :func:`compute_global_stats`) overrides the
    local corpus statistics — required when this writer packs one
    partition of a document-partitioned deployment.

    ``vocab`` fixes the term-id mapping (global term → id). Partitioned
    deployments that evaluate queries against a SHARED id space (the
    mesh-level path) pass the corpus-wide vocab so every partition's
    ``term_offsets`` is indexed identically; terms absent from this
    partition simply get zero blocks. With a fixed vocab an empty
    partition packs to a valid zero-doc index (scatter-gather over a
    corpus that does not divide evenly).
    """

    def __init__(self, *, k1: float = K1_DEFAULT, b: float = B_DEFAULT,
                 block: int = BLOCK, global_stats: dict | None = None,
                 vocab: dict[str, int] | None = None) -> None:
        self.k1 = k1
        self.b = b
        self.block = block
        self.global_stats = global_stats
        self.vocab = vocab
        self._postings: dict[str, dict[int, int]] = {}   # term -> {doc: tf}
        self._doc_ids: list[str] = []
        self._doc_len: list[int] = []

    def add(self, ext_id: str, text: str) -> int:
        doc = len(self._doc_ids)
        self._doc_ids.append(ext_id)
        toks = tokenize(text)
        self._doc_len.append(len(toks))
        for t in toks:
            self._postings.setdefault(t, {})
            self._postings[t][doc] = self._postings[t].get(doc, 0) + 1
        return doc

    def add_many(self, docs: Iterable[tuple[str, str]]) -> None:
        for ext_id, text in docs:
            self.add(ext_id, text)

    # -- packing ----------------------------------------------------------------

    def pack(self) -> PackedIndex:
        n_docs = len(self._doc_ids)
        if self.vocab is not None:
            vocab = dict(self.vocab)
            uncovered = [t for t in self._postings if t not in vocab]
            if uncovered:        # a stale vocab would silently lose postings
                raise ValueError(
                    f"{len(uncovered)} added term(s) missing from the fixed "
                    f"vocab (e.g. {sorted(uncovered)[:5]}) — rebuild the "
                    "global vocab before packing")
            terms = [None] * len(vocab)
            for t, i in vocab.items():
                terms[i] = t
        else:
            if n_docs == 0:
                raise ValueError("empty index")
            terms = sorted(self._postings)
            vocab = {t: i for i, t in enumerate(terms)}
        V = len(terms)
        avgdl = float(np.mean(self._doc_len)) if self._doc_len else 0.0
        gs = self.global_stats
        stat_docs = gs["n_docs"] if gs else n_docs
        if gs:
            avgdl = gs["avgdl"]
        doc_len = np.asarray(self._doc_len + [1.0], dtype=np.float32)  # +dump

        idf = np.zeros(V, dtype=np.float32)
        blocks_docs: list[np.ndarray] = []
        blocks_tf: list[np.ndarray] = []
        blocks_max: list[float] = []
        offsets = np.zeros(V + 1, dtype=np.int32)

        B = self.block
        k1, b = self.k1, self.b
        for ti, term in enumerate(terms):
            plist = self._postings.get(term) or {}   # {} when the term is
            local_df = len(plist)                    # global-vocab-only here
            df = gs["df"].get(term, local_df) if gs else local_df  # global
            idf[ti] = math.log(1.0 + (stat_docs - df + 0.5) / (df + 0.5))
            docs = np.fromiter(plist.keys(), dtype=np.int32, count=local_df)
            tfs = np.fromiter(plist.values(), dtype=np.int64, count=local_df)
            # per-posting impact for ordering
            dl = doc_len[docs]
            imp = idf[ti] * tfs / (tfs + k1 * (1 - b + b * dl / avgdl))
            # impact-sort postings descending, then cut into blocks: the
            # first blocks of each term carry its highest-scoring docs.
            order = np.argsort(-imp, kind="stable")
            docs, tfs, imp = docs[order], tfs[order], imp[order]
            n_blk = -(-local_df // B)
            pad = n_blk * B - local_df
            docs = np.concatenate([docs, np.full(pad, n_docs, np.int32)])
            tfs = np.concatenate([np.minimum(tfs, 255).astype(np.uint8),
                                  np.zeros(pad, np.uint8)])
            imp = np.concatenate([imp, np.zeros(pad)])
            for j in range(n_blk):
                sl = slice(j * B, (j + 1) * B)
                blocks_docs.append(docs[sl])
                blocks_tf.append(tfs[sl])
                blocks_max.append(float(imp[sl].max(initial=0.0)))
            offsets[ti + 1] = offsets[ti] + n_blk

        NB = len(blocks_docs)
        meta = IndexMeta(
            n_docs=n_docs, n_terms=V, n_blocks=NB, block=B, avgdl=avgdl,
            k1=k1, b=b, doc_ids=self._doc_ids,
        )
        return PackedIndex(
            meta=meta,
            vocab=vocab,
            term_offsets=offsets,
            block_docs=np.stack(blocks_docs) if NB else np.zeros((0, B), np.int32),
            block_tf=np.stack(blocks_tf) if NB else np.zeros((0, B), np.uint8),
            block_max=np.asarray(blocks_max, dtype=np.float32),
            doc_len=doc_len,
            idf=idf,
        )


# -- segment (de)serialization through the Directory seam ------------------------


def _npy_bytes(arr: np.ndarray) -> bytes:
    buf = io.BytesIO()
    np.save(buf, arr, allow_pickle=False)
    return buf.getvalue()


def _npy_load(data: bytes) -> np.ndarray:
    return np.load(io.BytesIO(data), allow_pickle=False)


SEGMENT_FILES = ("term_offsets", "block_docs", "block_tf", "block_max",
                 "doc_len", "idf")


def write_segment(index: PackedIndex, directory: RamDirectory | None = None) -> RamDirectory:
    """Serialize to Directory files (then publish via AssetCatalog)."""
    d = directory if directory is not None else RamDirectory()
    d.write("meta.json", index.meta.to_json())
    d.write("vocab.json", orjson.dumps(index.vocab))
    for name in SEGMENT_FILES:
        d.write(name + ".npy", _npy_bytes(getattr(index, name)))
    return d


def read_segment(directory: Directory) -> PackedIndex:
    """Hydrate a PackedIndex through any Directory (Ram or Store-backed).

    Reading through :class:`StoreDirectory` charges simulated network time to
    the store's stats — that is the cold-start hydration cost the runtime
    bills (paper §2 cold/warm distinction).
    """
    meta = IndexMeta.from_json(directory.open_input("meta.json").read_all())
    vocab = orjson.loads(directory.open_input("vocab.json").read_all())
    arrays = {
        name: _npy_load(directory.open_input(name + ".npy").read_all())
        for name in SEGMENT_FILES
    }
    return PackedIndex(meta=meta, vocab=vocab, **arrays)
