"""Inverted-index builder → packed, blocked, impact-ordered arrays.

Lucene stores postings as compressed, doc-ordered skip-list streams —
pointer-chasing that the TPU's vector units cannot traverse. The TPU-native
equivalent (DESIGN.md §2) packs each term's postings into fixed-width blocks:

    term_offsets : (V+1,)      int32   block range of term t = [off[t], off[t+1])
    block_docs   : (NB, B)     int32   doc ids, PAD = n_docs (dump slot)
    block_tf     : (NB, B)     uint8   term frequency, clamped to 255
    block_max    : (NB,)       float32 max BM25 impact within the block
    doc_len      : (n_docs+1,) float32 document length (dump slot appended)
    idf          : (V,)        float32 BM25 idf per term

Blocks within a term are sorted by descending ``block_max`` (impact ordering,
Lin & Trotman '17 — cited by the paper): truncating evaluation to the first M
blocks of each term is the classic score-at-a-time approximation, and gives
the fixed shapes jit needs. B = 128 matches the TPU lane width.

BM25 (Lucene's variant, k1=0.9, b=0.4 Anserini defaults):

    idf(t)   = ln(1 + (N - df + 0.5)/(df + 0.5))
    score    = idf(t) * tf / (tf + k1 * (1 - b + b * dl/avgdl))

(Lucene folds the (k1+1) numerator constant away since it is rank-neutral;
we follow Lucene.)
"""

from __future__ import annotations

import dataclasses
import io
import math
from typing import Iterable

import numpy as np

from repro.core import jsonutil as orjson   # orjson when installed

from repro.core.directory import Directory, DirectoryError, RamDirectory
from repro.index.tokenizer import (DEFAULT_FIELD, field_items, tokenize,
                                   tokenize_positions)

BLOCK = 128          # lane width
K1_DEFAULT = 0.9     # Anserini defaults
B_DEFAULT = 0.4

# Format v2 (structured queries): per-posting STORED OCCURRENCES. Each
# posting keeps its first POS_SLOTS (field, position) occurrences in
# tokenize_positions order — a fixed-pitch truncation (like the uint8
# tf-255 clamp) that keeps payload rows range-readable. Fielded tf and
# phrase matching are computed from the STORED occurrences, and the
# structured oracle applies the identical rule, so fleet/oracle parity is
# exact by construction even where the cap bites.
POS_SLOTS = 8
_POS_MAX = 0xFFFF    # positions clamp to uint16 (oracle-identical rule)


@dataclasses.dataclass
class IndexMeta:
    n_docs: int
    n_terms: int
    n_blocks: int
    block: int
    avgdl: float
    k1: float
    b: float
    doc_ids: list[str]          # external ids, position = internal id

    def to_json(self) -> bytes:
        return orjson.dumps(dataclasses.asdict(self))

    @classmethod
    def from_json(cls, data: bytes) -> "IndexMeta":
        return cls(**orjson.loads(data))


@dataclasses.dataclass
class FieldData:
    """Format-v2 sidecar: per-document field data + per-posting stored
    occurrences + declared facet fields.

    The block_* arrays are row-aligned with the segment's posting blocks
    (same (NB, B) grid, same impact ordering), so the lazy cold path
    hydrates them with the SAME coalesced payload-row ranges it already
    pulls for docs/tf. Slots past ``block_nocc`` are zero."""

    field_names: list[str]          # field id -> name, first-seen order
    pos_slots: int                  # P: stored occurrences per posting
    field_len: np.ndarray           # (n_docs+1, F) float32 kept-token lengths
    block_nocc: np.ndarray          # (NB, B) uint8 stored-occurrence count
    block_occ_field: np.ndarray     # (NB, B, P) uint8 field id per occurrence
    block_occ_pos: np.ndarray       # (NB, B, P) uint16 position per occurrence
    facet_names: list[str]          # declared categorical facet fields
    facet_values: list[list[str]]   # per facet field: value id -> string
    facet_ids: np.ndarray           # (n_docs, NF) int32, -1 = absent

    def field_id(self, name: str) -> int:
        try:
            return self.field_names.index(name)
        except ValueError:
            return -1

    @property
    def nbytes(self) -> int:
        return sum(a.nbytes for a in (
            self.field_len, self.block_nocc, self.block_occ_field,
            self.block_occ_pos, self.facet_ids))


@dataclasses.dataclass
class PackedIndex:
    """The hydrated, array-form index (a pytree of numpy/jax arrays)."""

    meta: IndexMeta
    vocab: dict[str, int]
    term_offsets: np.ndarray    # (V+1,) int32
    block_docs: np.ndarray      # (NB, B) int32
    block_tf: np.ndarray        # (NB, B) uint8
    block_max: np.ndarray       # (NB,) float32
    doc_len: np.ndarray         # (n_docs+1,) float32
    idf: np.ndarray             # (V,) float32
    fields: "FieldData | None" = None   # format v2 only; None = v1

    def term_id(self, term: str) -> int:
        return self.vocab.get(term, -1)

    @property
    def nbytes(self) -> int:
        n = sum(a.nbytes for a in (
            self.term_offsets, self.block_docs, self.block_tf,
            self.block_max, self.doc_len, self.idf))
        if self.fields is not None:
            n += self.fields.nbytes
        return n


def compute_global_stats(docs: Iterable[tuple[str, str]], *,
                         fields: bool = False) -> dict:
    """Corpus-wide BM25 statistics for document-partitioned indexing.

    Distributed IR subtlety the paper's §3 glosses over: each partition's
    index must score with GLOBAL idf/avgdl, or the merged ranking diverges
    from a single-index build. The offline batch indexer computes these
    once and passes them to every partition's writer.

    ``fields=True`` (structured fleets only — the stats blob's byte size
    feeds hydration pricing, so v1 fleets must not grow it) additionally
    records per-field totals under ``stats["fields"]``:
    ``{field: {"total": kept tokens, "docs": docs carrying the field}}``,
    the inputs to per-field avgdl for BM25F-style normalization.
    """
    from collections import Counter
    df: Counter = Counter()
    total_len = 0
    n_docs = 0
    fstats: dict[str, dict] = {}
    for _, text in docs:
        toks = tokenize(text)
        total_len += len(toks)
        n_docs += 1
        df.update(set(toks))
        if fields:
            for field, ftext in field_items(text):
                e = fstats.setdefault(field, {"total": 0, "docs": 0})
                e["total"] += len(tokenize(ftext))
                e["docs"] += 1
    out = {"n_docs": n_docs,
           "avgdl": total_len / max(1, n_docs),
           "df": dict(df)}
    if fields:
        out["fields"] = fstats
    return out


def field_avgdl(stats: dict, field: str) -> float:
    """Live per-field average length from ``stats["fields"]`` (1.0 for a
    field the live corpus does not carry — any fielded tf there is 0, so
    the denominator never matters)."""
    e = stats.get("fields", {}).get(field)
    if not e or e["docs"] <= 0 or e["total"] <= 0:
        return 1.0
    return e["total"] / e["docs"]


def global_vocab(stats: dict) -> dict[str, int]:
    """Deterministic corpus-global term→id map from compute_global_stats.

    This ordering IS the cross-path term-id contract: the mesh state's
    shared ``term_offsets``/``idf`` indexing and the fleet handlers'
    idf-ranked ``max_terms`` truncation both assume every partition was
    packed against exactly this map."""
    return {t: i for i, t in enumerate(sorted(stats["df"]))}


def extend_vocab(vocab: dict[str, int], terms: Iterable[str]) -> dict[str, int]:
    """Append-only vocab growth for incremental indexing.

    Existing term ids NEVER move (already-published segments index
    ``term_offsets``/``idf`` by them); genuinely new terms get fresh ids
    appended in sorted order, deterministically. Segments packed against a
    shorter vocab stay valid — their ``term_offsets`` is edge-padded at
    hydration (new terms have zero blocks there)."""
    out = dict(vocab)
    for t in sorted(set(terms) - out.keys()):
        out[t] = len(out)
    return out


def update_stats(stats: dict, text: str, *, sign: int = 1,
                 counts: "dict | None" = None) -> dict:
    """Incrementally fold one document into (sign=+1) or out of (sign=-1)
    ``compute_global_stats``-shaped stats, in place. The NRT writer calls
    this per add/delete so commit-time stats are O(changed docs), while
    staying exactly equal to a from-scratch ``compute_global_stats`` over
    the live corpus (the delta-vs-rebuild parity requirement). Pass
    ``counts`` (``token_counts(text)``) when the caller already tokenized
    the doc for other bookkeeping — the text is not re-tokenized."""
    if counts is None:
        from repro.index.tokenizer import token_counts
        counts = token_counts(text)
    n = stats["n_docs"] + sign
    total_len = stats["avgdl"] * max(1, stats["n_docs"]) \
        if stats["n_docs"] else 0.0
    # avgdl is stored, not the raw total — keep an exact integer token total
    # alongside so repeated +/- cannot accumulate float drift
    total = stats.setdefault("_total_len", round(total_len))
    total += sign * sum(counts.values())
    stats["_total_len"] = total
    df = stats["df"]
    for t in counts:
        new = df.get(t, 0) + sign
        if new > 0:
            df[t] = new
        else:
            df.pop(t, None)
    stats["n_docs"] = n
    stats["avgdl"] = total / max(1, n)
    # structured fleets (stats carry a "fields" entry) maintain per-field
    # totals the same incremental way, staying exactly equal to a
    # from-scratch compute_global_stats(fields=True) over the live corpus
    if "fields" in stats:
        fs = stats["fields"]
        for field, ftext in field_items(text):
            e = fs.setdefault(field, {"total": 0, "docs": 0})
            e["total"] += sign * len(tokenize(ftext))
            e["docs"] += sign
            if e["docs"] <= 0:
                fs.pop(field, None)
    return stats


class IndexWriter:
    """Accumulates documents, then packs. Offline batch side of paper §3.

    ``global_stats`` (from :func:`compute_global_stats`) overrides the
    local corpus statistics — required when this writer packs one
    partition of a document-partitioned deployment.

    ``vocab`` fixes the term-id mapping (global term → id). Partitioned
    deployments that evaluate queries against a SHARED id space (the
    mesh-level path) pass the corpus-wide vocab so every partition's
    ``term_offsets`` is indexed identically; terms absent from this
    partition simply get zero blocks. With a fixed vocab an empty
    partition packs to a valid zero-doc index (scatter-gather over a
    corpus that does not divide evenly).

    ``structured=True`` packs format v2: per-posting stored occurrences
    (first ``pos_slots`` per posting), per-field kept-token lengths, and
    per-doc values for each declared ``facet_fields`` entry (the raw
    field text is the facet value). OFF by default — a v1 pack's bytes
    are unchanged by this feature's existence.
    """

    def __init__(self, *, k1: float = K1_DEFAULT, b: float = B_DEFAULT,
                 block: int = BLOCK, global_stats: dict | None = None,
                 vocab: dict[str, int] | None = None,
                 structured: bool = False,
                 facet_fields: "tuple[str, ...] | list[str]" = (),
                 pos_slots: int = POS_SLOTS) -> None:
        self.k1 = k1
        self.b = b
        self.block = block
        self.global_stats = global_stats
        self.vocab = vocab
        self._postings: dict[str, dict[int, int]] = {}   # term -> {doc: tf}
        self._doc_ids: list[str] = []
        self._doc_len: list[int] = []
        self.structured = structured or bool(facet_fields)
        self.facet_fields = list(facet_fields)
        self.pos_slots = pos_slots
        # v2 bookkeeping (empty unless structured)
        self._field_names: list[str] = []
        self._field_ids: dict[str, int] = {}
        self._field_len_rows: list[dict[int, int]] = []  # doc -> {fid: len}
        self._occ: dict[str, dict[int, list]] = {}  # term -> doc -> [(f, p)]
        self._facet_maps: list[dict[str, int]] = [
            {} for _ in self.facet_fields]
        self._facet_rows: list[list[int]] = []

    def _field_id(self, name: str) -> int:
        fid = self._field_ids.get(name)
        if fid is None:
            fid = self._field_ids[name] = len(self._field_names)
            self._field_names.append(name)
        return fid

    def add(self, ext_id: str, text: "str | dict") -> int:
        doc = len(self._doc_ids)
        self._doc_ids.append(ext_id)
        toks = tokenize(text)
        self._doc_len.append(len(toks))
        for t in toks:
            self._postings.setdefault(t, {})
            self._postings[t][doc] = self._postings[t].get(doc, 0) + 1
        if self.structured:
            # fielded views: per-field kept lengths + (field, position)
            # occurrence lists per posting, in tokenize_positions order
            # (field insertion order, then kept-stream position) — the
            # order the pos_slots truncation is defined over
            flen: dict[int, int] = {}
            for field, _ in field_items(text):
                flen.setdefault(self._field_id(field), 0)
            for field, tok, pos in tokenize_positions(text):
                fid = self._field_id(field)
                flen[fid] = flen.get(fid, 0) + 1
                self._occ.setdefault(tok, {}).setdefault(doc, []).append(
                    (fid, min(pos, _POS_MAX)))
            self._field_len_rows.append(flen)
            fmap = dict(field_items(text))
            row = []
            for fi, fname in enumerate(self.facet_fields):
                val = fmap.get(fname)
                if val is None or val == "":
                    row.append(-1)
                else:
                    vmap = self._facet_maps[fi]
                    row.append(vmap.setdefault(str(val), len(vmap)))
            self._facet_rows.append(row)
        return doc

    def add_many(self, docs: Iterable[tuple[str, str]]) -> None:
        for ext_id, text in docs:
            self.add(ext_id, text)

    @classmethod
    def delta(cls, docs: Iterable[tuple[str, str]], base_stats: dict, *,
              vocab: dict[str, int], k1: float = K1_DEFAULT,
              b: float = B_DEFAULT, block: int = BLOCK,
              structured: bool = False,
              facet_fields: "tuple[str, ...] | list[str]" = (),
              pos_slots: int = POS_SLOTS) -> PackedIndex:
        """Pack ONLY ``docs`` as a delta segment against the frozen global
        ``vocab`` and ``base_stats`` — the NRT increment: a commit uploads
        just these blocks, never touching the published base segment.

        Delta doc ids are segment-local (0..len(docs)); the serving side
        shifts them when it combines base + deltas
        (:func:`combine_segments`). The frozen stats only shape the
        IMPACT ORDERING baked into ``block_max`` — idf/avgdl applied at
        query time come from the generation manifest's live stats, which
        is what keeps delta-served scores equal to a full rebuild's.
        Extend the vocab first (:func:`extend_vocab`) when the new docs
        carry unseen terms; ``pack`` refuses stale vocabs."""
        w = cls(k1=k1, b=b, block=block, global_stats=base_stats, vocab=vocab,
                structured=structured, facet_fields=facet_fields,
                pos_slots=pos_slots)
        w.add_many(docs)
        return w.pack()

    # -- packing ----------------------------------------------------------------

    def pack(self) -> PackedIndex:
        n_docs = len(self._doc_ids)
        if self.vocab is not None:
            vocab = dict(self.vocab)
            uncovered = [t for t in self._postings if t not in vocab]
            if uncovered:        # a stale vocab would silently lose postings
                raise ValueError(
                    f"{len(uncovered)} added term(s) missing from the fixed "
                    f"vocab (e.g. {sorted(uncovered)[:5]}) — rebuild the "
                    "global vocab before packing")
            terms = [None] * len(vocab)
            for t, i in vocab.items():
                terms[i] = t
        else:
            if n_docs == 0:
                raise ValueError("empty index")
            terms = sorted(self._postings)
            vocab = {t: i for i, t in enumerate(terms)}
        V = len(terms)
        avgdl = float(np.mean(self._doc_len)) if self._doc_len else 0.0
        gs = self.global_stats
        stat_docs = gs["n_docs"] if gs else n_docs
        if gs:
            avgdl = gs["avgdl"]
        doc_len = np.asarray(self._doc_len + [1.0], dtype=np.float32)  # +dump

        idf = np.zeros(V, dtype=np.float32)
        blocks_docs: list[np.ndarray] = []
        blocks_tf: list[np.ndarray] = []
        blocks_max: list[float] = []
        offsets = np.zeros(V + 1, dtype=np.int32)
        P = self.pos_slots
        blocks_nocc: list[np.ndarray] = []
        blocks_occf: list[np.ndarray] = []
        blocks_occp: list[np.ndarray] = []

        B = self.block
        k1, b = self.k1, self.b
        for ti, term in enumerate(terms):
            plist = self._postings.get(term) or {}   # {} when the term is
            local_df = len(plist)                    # global-vocab-only here
            df = gs["df"].get(term, local_df) if gs else local_df  # global
            idf[ti] = math.log(1.0 + (stat_docs - df + 0.5) / (df + 0.5))
            docs = np.fromiter(plist.keys(), dtype=np.int32, count=local_df)
            tfs = np.fromiter(plist.values(), dtype=np.int64, count=local_df)
            # per-posting impact for ordering
            dl = doc_len[docs]
            imp = idf[ti] * tfs / (tfs + k1 * (1 - b + b * dl / avgdl))
            # impact-sort postings descending, then cut into blocks: the
            # first blocks of each term carry its highest-scoring docs.
            order = np.argsort(-imp, kind="stable")
            docs, tfs, imp = docs[order], tfs[order], imp[order]
            n_blk = -(-local_df // B)
            pad = n_blk * B - local_df
            if self.structured:
                # stored occurrences, aligned with the impact-sorted
                # postings then padded like docs/tf
                occ_map = self._occ.get(term) or {}
                nocc = np.zeros(n_blk * B, np.uint8)
                occf = np.zeros((n_blk * B, P), np.uint8)
                occp = np.zeros((n_blk * B, P), np.uint16)
                for i, d in enumerate(docs[:local_df]):
                    lst = occ_map.get(int(d), ())[:P]
                    nocc[i] = len(lst)
                    for s, (fid, pos) in enumerate(lst):
                        occf[i, s] = fid
                        occp[i, s] = pos
                for j in range(n_blk):
                    sl = slice(j * B, (j + 1) * B)
                    blocks_nocc.append(nocc[sl])
                    blocks_occf.append(occf[sl])
                    blocks_occp.append(occp[sl])
            docs = np.concatenate([docs, np.full(pad, n_docs, np.int32)])
            tfs = np.concatenate([np.minimum(tfs, 255).astype(np.uint8),
                                  np.zeros(pad, np.uint8)])
            imp = np.concatenate([imp, np.zeros(pad)])
            for j in range(n_blk):
                sl = slice(j * B, (j + 1) * B)
                blocks_docs.append(docs[sl])
                blocks_tf.append(tfs[sl])
                blocks_max.append(float(imp[sl].max(initial=0.0)))
            offsets[ti + 1] = offsets[ti] + n_blk

        NB = len(blocks_docs)
        meta = IndexMeta(
            n_docs=n_docs, n_terms=V, n_blocks=NB, block=B, avgdl=avgdl,
            k1=k1, b=b, doc_ids=self._doc_ids,
        )
        fields = None
        if self.structured:
            F = len(self._field_names)
            field_len = np.zeros((n_docs + 1, F), np.float32)
            for d, flen in enumerate(self._field_len_rows):
                for fid, n in flen.items():
                    field_len[d, fid] = n
            field_len[n_docs] = 1.0                     # dump slot
            NF = len(self.facet_fields)
            facet_ids = (np.asarray(self._facet_rows, np.int32)
                         if self._facet_rows
                         else np.zeros((0, NF), np.int32)).reshape(n_docs, NF)
            facet_values = []
            for vmap in self._facet_maps:
                vals = [None] * len(vmap)
                for v, i in vmap.items():
                    vals[i] = v
                facet_values.append(vals)
            fields = FieldData(
                field_names=list(self._field_names), pos_slots=P,
                field_len=field_len,
                block_nocc=(np.stack(blocks_nocc) if NB
                            else np.zeros((0, B), np.uint8)),
                block_occ_field=(np.stack(blocks_occf) if NB
                                 else np.zeros((0, B, P), np.uint8)),
                block_occ_pos=(np.stack(blocks_occp) if NB
                               else np.zeros((0, B, P), np.uint16)),
                facet_names=list(self.facet_fields),
                facet_values=facet_values, facet_ids=facet_ids)
        return PackedIndex(
            meta=meta,
            vocab=vocab,
            term_offsets=offsets,
            block_docs=np.stack(blocks_docs) if NB else np.zeros((0, B), np.int32),
            block_tf=np.stack(blocks_tf) if NB else np.zeros((0, B), np.uint8),
            block_max=np.asarray(blocks_max, dtype=np.float32),
            doc_len=doc_len,
            idf=idf,
            fields=fields,
        )


# -- segment (de)serialization through the Directory seam ------------------------


def _npy_bytes(arr: np.ndarray) -> bytes:
    buf = io.BytesIO()
    np.save(buf, arr, allow_pickle=False)
    return buf.getvalue()


def _npy_load(data: bytes) -> np.ndarray:
    return np.load(io.BytesIO(data), allow_pickle=False)


SEGMENT_FILES = ("term_offsets", "block_docs", "block_tf", "block_max",
                 "doc_len", "idf")

# Lazy-hydration layout (PR 7, Airphant-style): every segment additionally
# carries a compact HEADER (superindex.bin — meta, vocab, term→block extents
# via term_offsets, the block_max table, doc lengths, idf) serialized ahead
# of an interleaved BLOCK PAYLOAD (blocks.bin — row i is block i's B int32
# doc ids followed by its B uint8 tfs). A cold instance reads the header in
# ONE ranged GET, then pulls exactly the payload row ranges the query's
# terms name (term t's rows are [off[t], off[t+1]) — contiguous by
# construction), instead of streaming the whole segment. The eager *.npy
# files stay byte-identical so full hydration (read_segment) is unchanged.
SUPERINDEX_FILE = "superindex.bin"
PAYLOAD_FILE = "blocks.bin"
_SUPERINDEX_MAGIC = b"SUPX"      # format v1: 6 sections, 5 B/lane payload
_SUPERINDEX_MAGIC_V2 = b"SUP2"   # format v2: + fields/positions/facets

# v2 superindex extra sections (after the 6 v1 sections): fields header
# json, field_len npy, facet_ids npy
_V2_SECTIONS = 3
FIELDS_FILE = "fields.json"
FIELD_NPY_FILES = ("field_len", "block_nocc", "block_occ_field",
                   "block_occ_pos", "facet_ids")


def payload_row_bytes(block: int, pos_slots: int = 0) -> int:
    """Bytes per payload row: B int32 doc ids + B uint8 tfs, interleaved so
    one coalesced range read covers both arrays of a term's blocks. A v2
    row (``pos_slots`` > 0) appends B uint8 occurrence counts, B×P uint8
    field ids and B×P uint16 positions — same row pitch discipline, so
    the ranged-GET machinery needs only the wider stride."""
    base = block * 4 + block
    if pos_slots:
        base += block * (1 + 3 * pos_slots)
    return base


def _fields_header(fd: FieldData) -> dict:
    return {"field_names": fd.field_names, "pos_slots": fd.pos_slots,
            "facet_names": fd.facet_names, "facet_values": fd.facet_values}


def pack_superindex(index: PackedIndex) -> bytes:
    """The segment header: everything a query-sufficient partial view needs
    EXCEPT the posting blocks themselves, framed as length-prefixed
    sections (meta json, vocab json, then term_offsets / block_max /
    doc_len / idf as npy). A v2 segment (``index.fields``) appends the
    fields header json, field_len and facet_ids — still one ranged GET;
    the per-posting occurrence arrays live in the payload rows. A v1
    segment's bytes are unchanged."""
    sections = [
        index.meta.to_json(),
        orjson.dumps(index.vocab),
        _npy_bytes(index.term_offsets),
        _npy_bytes(index.block_max),
        _npy_bytes(index.doc_len),
        _npy_bytes(index.idf),
    ]
    magic = _SUPERINDEX_MAGIC
    if index.fields is not None:
        fd = index.fields
        magic = _SUPERINDEX_MAGIC_V2
        sections += [orjson.dumps(_fields_header(fd)),
                     _npy_bytes(fd.field_len),
                     _npy_bytes(fd.facet_ids)]
    out = io.BytesIO()
    out.write(magic)
    for s in sections:
        out.write(len(s).to_bytes(4, "little"))
        out.write(s)
    return out.getvalue()


def unpack_superindex(data: bytes) -> tuple[IndexMeta, dict,
                                            list[np.ndarray], "dict | None"]:
    """Inverse of :func:`pack_superindex` →
    (meta, vocab, [term_offsets, block_max, doc_len, idf], fields_header).

    ``fields_header`` is None for a v1 blob; for v2 it carries
    field_names/pos_slots/facet_names/facet_values plus the hydrated
    ``field_len`` and ``facet_ids`` arrays (the block-aligned occurrence
    arrays hydrate from payload rows, not the header)."""
    magic = data[:4]
    if magic not in (_SUPERINDEX_MAGIC, _SUPERINDEX_MAGIC_V2):
        raise ValueError("not a superindex blob")
    n_sections = 6 + (_V2_SECTIONS if magic == _SUPERINDEX_MAGIC_V2 else 0)
    sections, pos = [], 4
    for _ in range(n_sections):
        n = int.from_bytes(data[pos:pos + 4], "little")
        pos += 4
        sections.append(data[pos:pos + n])
        pos += n
    meta = IndexMeta.from_json(sections[0])
    vocab = orjson.loads(sections[1])
    arrays = [_npy_load(s) for s in sections[2:6]]
    fields_header = None
    if magic == _SUPERINDEX_MAGIC_V2:
        fields_header = orjson.loads(sections[6])
        fields_header["field_len"] = _npy_load(sections[7])
        fields_header["facet_ids"] = _npy_load(sections[8])
    return meta, vocab, arrays, fields_header


def pack_payload(index: PackedIndex) -> bytes:
    """Interleaved block payload: row i = block i's doc ids (B × int32,
    little-endian) followed by its tfs (B × uint8); a v2 row appends the
    block's stored-occurrence arrays (nocc, field ids, uint16-LE
    positions) so positions/fields hydrate in the same coalesced row
    ranges as docs/tf."""
    NB = index.meta.n_blocks
    if NB == 0:
        return b""
    B = index.meta.block
    fd = index.fields
    P = fd.pos_slots if fd is not None else 0
    rows = np.empty((NB, payload_row_bytes(B, P)), np.uint8)
    docs = np.ascontiguousarray(index.block_docs.astype("<i4"))
    rows[:, :B * 4] = docs.view(np.uint8).reshape(NB, B * 4)
    rows[:, B * 4:B * 5] = index.block_tf.astype(np.uint8)
    if fd is not None:
        o = B * 5
        rows[:, o:o + B] = fd.block_nocc.astype(np.uint8)
        o += B
        rows[:, o:o + B * P] = fd.block_occ_field.astype(
            np.uint8).reshape(NB, B * P)
        o += B * P
        occp = np.ascontiguousarray(fd.block_occ_pos.astype("<u2"))
        rows[:, o:] = occp.view(np.uint8).reshape(NB, B * P * 2)
    return rows.tobytes()


def unpack_payload_rows(chunk: bytes, block: int, pos_slots: int = 0):
    """Decode a contiguous payload row range → (docs (n,B) int32,
    tf (n,B) uint8) for v1 rows, plus (nocc (n,B) uint8,
    occ_field (n,B,P) uint8, occ_pos (n,B,P) uint16) when ``pos_slots``
    names a v2 pitch."""
    B, P = block, pos_slots
    row = payload_row_bytes(B, P)
    n = len(chunk) // row
    rows = np.frombuffer(chunk, np.uint8, count=n * row).reshape(n, row)
    docs = rows[:, :B * 4].copy().view("<i4").astype(np.int32, copy=False)
    docs = docs.reshape(n, B)
    tf = rows[:, B * 4:B * 5].copy()
    if not P:
        return docs, tf
    o = B * 5
    nocc = rows[:, o:o + B].copy()
    o += B
    occf = rows[:, o:o + B * P].copy().reshape(n, B, P)
    o += B * P
    occp = rows[:, o:].copy().view("<u2").astype(np.uint16, copy=False)
    return docs, tf, nocc, occf, occp.reshape(n, B, P)


def write_segment(index: PackedIndex, directory: RamDirectory | None = None) -> RamDirectory:
    """Serialize to Directory files (then publish via AssetCatalog)."""
    d = directory if directory is not None else RamDirectory()
    d.write("meta.json", index.meta.to_json())
    d.write("vocab.json", orjson.dumps(index.vocab))
    for name in SEGMENT_FILES:
        d.write(name + ".npy", _npy_bytes(getattr(index, name)))
    if index.fields is not None:        # v2 eager twin files
        d.write(FIELDS_FILE, orjson.dumps(_fields_header(index.fields)))
        for name in FIELD_NPY_FILES:
            d.write(name + ".npy", _npy_bytes(getattr(index.fields, name)))
    # lazy-hydration layout: header ahead of the interleaved block payload
    d.write(SUPERINDEX_FILE, pack_superindex(index))
    d.write(PAYLOAD_FILE, pack_payload(index))
    return d


def read_segment(directory: Directory) -> PackedIndex:
    """Hydrate a PackedIndex through any Directory (Ram or Store-backed).

    Reading through :class:`StoreDirectory` charges simulated network time to
    the store's stats — that is the cold-start hydration cost the runtime
    bills (paper §2 cold/warm distinction).
    """
    meta = IndexMeta.from_json(directory.open_input("meta.json").read_all())
    vocab = orjson.loads(directory.open_input("vocab.json").read_all())
    arrays = {
        name: _npy_load(directory.open_input(name + ".npy").read_all())
        for name in SEGMENT_FILES
    }
    fields = None
    try:
        # v2 sidecar probe: a miss raises before any simulated network
        # charge, so v1 full hydration pays nothing extra (a LIST here
        # would bill a metadata round-trip on every v1 cold start)
        hdr = orjson.loads(directory.open_input(FIELDS_FILE).read_all())
    except DirectoryError:
        hdr = None
    if hdr is not None:
        fnpy = {name: _npy_load(
            directory.open_input(name + ".npy").read_all())
            for name in FIELD_NPY_FILES}
        fields = FieldData(field_names=hdr["field_names"],
                           pos_slots=hdr["pos_slots"],
                           facet_names=hdr["facet_names"],
                           facet_values=hdr["facet_values"], **fnpy)
    return PackedIndex(meta=meta, vocab=vocab, fields=fields, **arrays)


# -- NRT: combining base + delta segments at hydration ---------------------------


def combine_segments(packs: list[PackedIndex], *, vocab: dict[str, int],
                     stats: dict, tombstones: Iterable[int] = ()) -> PackedIndex:
    """Fuse one base segment + its ordered deltas into ONE PackedIndex.

    The TPU analogue of Lucene's multi-segment reader: fixed-shape jitted
    evaluation wants one array set per compiled fn, so segments fuse at
    HYDRATION (per generation, off the query path) instead of per query —
    base + deltas then score in one vmapped device call.

    * Doc ids concatenate: pack ``i``'s local ids shift by the doc count of
      packs before it (delta docs append after the base, in commit order).
    * Per term, blocks concatenate across packs and re-sort by impact under
      the LIVE stats, preserving the impact-ordering truncation contract.
      The whole fuse is vectorized over blocks (one lexsort by (term,
      -block_max)), never a Python loop over the vocab — hydration cost
      scales with postings, not V × segments.
    * ``stats``/``vocab`` are the generation's live values: idf and avgdl
      are recomputed HERE, at hydration — segment blocks carry only tf and
      doc lengths, which is what makes a delta-served index score exactly
      like a from-scratch rebuild of the live corpus.
    * ``tombstones`` are INTERNAL doc positions in the combined id space
      (a doc deleted and later re-added gets a fresh position, so the old
      copy's tombstone can never kill the new copy). Their postings' tf
      zeroes out, so deleted docs score exactly 0 and can never enter the
      partition-local top-k — subtraction BEFORE top-k, not
      post-filtering (a post-filter would silently shrink k).
    """
    if not packs:
        raise ValueError("combine_segments needs at least a base segment")
    V = len(vocab)
    B = packs[0].meta.block
    k1, b = packs[0].meta.k1, packs[0].meta.b
    for p in packs[1:]:
        if p.meta.block != B or (p.meta.k1, p.meta.b) != (k1, b):
            raise ValueError("segments disagree on block size or BM25 params")

    doc_offsets, n_docs = [], 0
    for p in packs:
        doc_offsets.append(n_docs)
        n_docs += p.meta.n_docs
    doc_ids: list[str] = []
    for p in packs:
        doc_ids.extend(p.meta.doc_ids)
    dead_mask = np.zeros(n_docs + 1, dtype=bool)
    dead_mask[np.asarray(sorted(tombstones), dtype=np.int64)] = True

    n_live = int(stats["n_docs"])
    avgdl = float(stats["avgdl"]) or 1.0
    df_map = stats["df"]
    df = np.zeros(V, dtype=np.float64)
    for t, i in vocab.items():
        df[i] = df_map.get(t, 0)
    idf = np.log(1.0 + (n_live - df + 0.5) / (df + 0.5)).astype(np.float32)

    doc_len = np.concatenate(
        [p.doc_len[:p.meta.n_docs] for p in packs] + [[1.0]]).astype(np.float32)

    # v2 carry-through: occurrence/field/facet arrays ride the SAME block
    # permutation as docs/tf when every pack is structured (a mixed tier
    # degrades to a v1 combine — positions can't be trusted half-present)
    have_fields = all(p.fields is not None for p in packs)
    if have_fields:
        P = packs[0].fields.pos_slots
        fnames0 = packs[0].fields.facet_names
        have_fields = all(p.fields.pos_slots == P
                          and p.fields.facet_names == fnames0
                          for p in packs)
    if have_fields:
        # combined field-id space: union by name, first-seen across packs
        field_names: list[str] = []
        fmap: dict[str, int] = {}
        for p in packs:
            for nm in p.fields.field_names:
                if nm not in fmap:
                    fmap[nm] = len(field_names)
                    field_names.append(nm)
        fid_remaps = [np.asarray([fmap[nm] for nm in p.fields.field_names]
                                 + [0], np.int64) for p in packs]
        # facet value vocabs: union by string per facet field, -1 preserved
        NF = len(fnames0)
        facet_values: list[list[str]] = []
        facet_remaps: list[list[np.ndarray]] = []  # [facet][pack] id remap
        for fi in range(NF):
            vals: list[str] = []
            vmap: dict[str, int] = {}
            remaps = []
            for p in packs:
                r = []
                for v in p.fields.facet_values[fi]:
                    if v not in vmap:
                        vmap[v] = len(vals)
                        vals.append(v)
                    r.append(vmap[v])
                remaps.append(np.asarray(r, np.int64))
            facet_values.append(vals)
            facet_remaps.append(remaps)

    # per pack, vectorized over ALL its blocks at once: shift local ids to
    # the combined space, zero tombstoned/pad tf, recompute block_max under
    # the live stats
    cat_docs, cat_tf, cat_max, cat_term = [], [], [], []
    cat_nocc, cat_occf, cat_occp = [], [], []
    flen_rows, facet_rows = [], []
    for pi, p in enumerate(packs):
        if have_fields:
            fd = p.fields
            # field_len remapped into the combined field-id space
            flen = np.zeros((p.meta.n_docs, len(field_names)), np.float32)
            src = fd.field_len[:p.meta.n_docs]
            if src.shape[1]:
                flen[:, fid_remaps[pi][:src.shape[1]]] = src
            flen_rows.append(flen)
            if NF:
                old = fd.facet_ids.astype(np.int64)
                new = np.empty_like(old, dtype=np.int32)
                for fi in range(NF):
                    remap = facet_remaps[fi][pi]
                    col = old[:, fi]
                    new[:, fi] = np.where(
                        col < 0, -1,
                        remap[np.maximum(col, 0)] if remap.size else -1)
                facet_rows.append(new)
            else:
                facet_rows.append(np.zeros((p.meta.n_docs, 0), np.int32))
        if p.meta.n_blocks == 0:
            continue
        docs = p.block_docs.astype(np.int64)             # (NB_p, B)
        pad = docs >= p.meta.n_docs
        docs = np.where(pad, n_docs, docs + doc_offsets[pi])
        dead = pad | dead_mask[docs]
        tf = np.where(dead, 0, p.block_tf).astype(np.uint8)
        to = p.term_offsets.astype(np.int64)
        n_blk = to[1:] - to[:-1]                         # (V_p,)
        term_of_block = np.repeat(np.arange(len(n_blk)), n_blk)
        dl = doc_len[np.minimum(docs, n_docs)]
        tff = tf.astype(np.float64)
        imp = idf[term_of_block][:, None] * tff / np.where(
            tff > 0, tff + k1 * (1 - b + b * dl / avgdl), 1.0)
        cat_docs.append(docs.astype(np.int32))
        cat_tf.append(tf)
        cat_max.append(imp.max(axis=1))
        cat_term.append(term_of_block)
        if have_fields:
            fd = p.fields
            # tombstoned postings lose their occurrences too (tf is the
            # match indicator; stale positions must not resurrect phrases)
            nocc = np.where(dead, 0, fd.block_nocc).astype(np.uint8)
            slot = np.arange(P)
            live_slot = slot[None, None, :] < nocc[..., None]
            occf = np.where(
                live_slot,
                fid_remaps[pi][fd.block_occ_field.astype(np.int64)], 0
            ).astype(np.uint8)
            occp = np.where(live_slot, fd.block_occ_pos, 0).astype(np.uint16)
            cat_nocc.append(nocc)
            cat_occf.append(occf)
            cat_occp.append(occp)

    if cat_docs:
        docs_all = np.concatenate(cat_docs)
        tf_all = np.concatenate(cat_tf)
        max_all = np.concatenate(cat_max)
        term_all = np.concatenate(cat_term)
        # group by term, impact-descending within; lexsort is stable, so
        # equal-impact blocks keep pack order (base before deltas)
        order = np.lexsort((-max_all, term_all))
        docs_all, tf_all = docs_all[order], tf_all[order]
        max_all, term_all = max_all[order], term_all[order]
        if have_fields:
            nocc_all = np.concatenate(cat_nocc)[order]
            occf_all = np.concatenate(cat_occf)[order]
            occp_all = np.concatenate(cat_occp)[order]
    else:
        docs_all = np.zeros((0, B), np.int32)
        tf_all = np.zeros((0, B), np.uint8)
        max_all = np.zeros(0)
        term_all = np.zeros(0, np.int64)
        if have_fields:
            nocc_all = np.zeros((0, B), np.uint8)
            occf_all = np.zeros((0, B, P), np.uint8)
            occp_all = np.zeros((0, B, P), np.uint16)
    new_off = np.zeros(V + 1, dtype=np.int32)
    new_off[1:] = np.cumsum(np.bincount(term_all, minlength=V)[:V])

    NB = docs_all.shape[0]
    meta = IndexMeta(
        n_docs=n_docs, n_terms=V, n_blocks=NB, block=B,
        avgdl=avgdl, k1=k1, b=b, doc_ids=doc_ids)
    fields = None
    if have_fields:
        field_len = np.concatenate(
            flen_rows + [np.ones((1, len(field_names)), np.float32)]) \
            if flen_rows else np.ones((1, len(field_names)), np.float32)
        facet_ids = np.concatenate(facet_rows) if facet_rows \
            else np.zeros((0, NF), np.int32)
        fields = FieldData(
            field_names=field_names, pos_slots=P, field_len=field_len,
            block_nocc=nocc_all, block_occ_field=occf_all,
            block_occ_pos=occp_all, facet_names=list(fnames0),
            facet_values=facet_values, facet_ids=facet_ids)
    return PackedIndex(
        meta=meta, vocab=dict(vocab), term_offsets=new_off,
        block_docs=docs_all, block_tf=tf_all,
        block_max=max_all.astype(np.float32),
        doc_len=doc_len, idf=idf, fields=fields)


# -- dense-vector tier (hybrid retrieval) -----------------------------------------
#
# "Vector Search with OpenAI Embeddings: Lucene Is All You Need" — a dense
# tier rides the exact same segment machinery as the BM25 tier: immutable
# base + delta segments referenced from the generation manifest, tombstoned
# at query time, served eagerly OR through the same header+range-readable
# twin layout the lazy cold path reads. Row-major (doc, dim) embeddings:
# scoring is one matvec per query (kernels/dot_topk.py), and row r of the
# payload is doc r's vector, so partial hydration can pull exactly the LIVE
# rows of a tombstone-carrying segment with coalesced range reads.

VECTOR_META_FILE = "vec_meta.json"
VECTOR_NPY_FILE = "vectors.npy"
VECTOR_SUPERINDEX_FILE = "vec_superindex.bin"
VECTOR_ROWS_FILE = "vec_rows.bin"
_VECTOR_SUPERINDEX_MAGIC = b"SUPV"
VECTOR_DTYPES = ("float32", "int8")


@dataclasses.dataclass
class VectorMeta:
    n_docs: int
    dim: int
    dtype: str                  # "float32" | "int8" (scale-dequantized)
    scale: float                # f32 value = int8 code × scale (1.0 for f32)
    doc_ids: list[str]          # external ids, position = internal id

    def to_json(self) -> bytes:
        return orjson.dumps(dataclasses.asdict(self))

    @classmethod
    def from_json(cls, data: bytes) -> "VectorMeta":
        return cls(**orjson.loads(data))


@dataclasses.dataclass
class PackedVectors:
    """The hydrated, array-form dense tier of one segment."""

    meta: VectorMeta
    vectors: np.ndarray         # (n_docs, dim) in the STORED dtype

    def as_f32(self) -> np.ndarray:
        if self.meta.dtype == "float32":
            return self.vectors.astype(np.float32, copy=False)
        return (self.vectors.astype(np.float32)
                * np.float32(self.meta.scale))

    @property
    def nbytes(self) -> int:
        return self.vectors.nbytes


def pack_vectors(embeddings: np.ndarray, doc_ids: list[str], *,
                 dtype: str = "float32") -> PackedVectors:
    """Pack (n_docs, dim) f32 embeddings as a dense segment tier.

    ``dtype="int8"`` scalar-quantizes symmetrically (scale = max|v|/127),
    trading recall for 4× smaller segments; the dequantized f32 values are
    what the scorer sees, so delta-vs-rebuild parity holds per stored
    representation."""
    emb = np.asarray(embeddings, dtype=np.float32)
    if emb.ndim != 2 or emb.shape[0] != len(doc_ids):
        raise ValueError(f"embeddings {emb.shape} do not match "
                         f"{len(doc_ids)} doc ids")
    if dtype not in VECTOR_DTYPES:
        raise ValueError(f"vector dtype must be one of {VECTOR_DTYPES}, "
                         f"got {dtype!r}")
    if dtype == "int8":
        amax = float(np.abs(emb).max(initial=0.0))
        scale = amax / 127.0 if amax else 1.0
        codes = np.clip(np.round(emb / scale), -127, 127).astype(np.int8)
        meta = VectorMeta(n_docs=len(doc_ids), dim=emb.shape[1],
                          dtype="int8", scale=scale, doc_ids=list(doc_ids))
        return PackedVectors(meta=meta, vectors=codes)
    meta = VectorMeta(n_docs=len(doc_ids), dim=emb.shape[1],
                      dtype="float32", scale=1.0, doc_ids=list(doc_ids))
    return PackedVectors(meta=meta, vectors=emb)


def vector_row_bytes(dim: int, dtype: str) -> int:
    """Bytes per payload row: one doc's ``dim`` elements in the stored
    dtype — the range-read unit of the dense tier's lazy layout."""
    return dim * (4 if dtype == "float32" else 1)


def pack_vector_superindex(pv: PackedVectors) -> bytes:
    """The dense tier's header: just the meta (ids, shape, dtype, scale) —
    everything a partial view needs except the rows themselves."""
    blob = pv.meta.to_json()
    out = io.BytesIO()
    out.write(_VECTOR_SUPERINDEX_MAGIC)
    out.write(len(blob).to_bytes(4, "little"))
    out.write(blob)
    return out.getvalue()


def unpack_vector_superindex(data: bytes) -> VectorMeta:
    if data[:4] != _VECTOR_SUPERINDEX_MAGIC:
        raise ValueError("not a vector superindex blob")
    n = int.from_bytes(data[4:8], "little")
    return VectorMeta.from_json(data[8:8 + n])


def pack_vector_rows(pv: PackedVectors) -> bytes:
    """Row-major payload: row r = doc r's vector, little-endian stored
    dtype — contiguous row ranges are one coalesced ranged GET each."""
    dt = "<f4" if pv.meta.dtype == "float32" else "i1"
    return np.ascontiguousarray(pv.vectors.astype(dt)).tobytes()


def unpack_vector_rows(chunk: bytes, dim: int, dtype: str) -> np.ndarray:
    dt = "<f4" if dtype == "float32" else "i1"
    row = vector_row_bytes(dim, dtype)
    n = len(chunk) // row
    arr = np.frombuffer(chunk, dtype=dt, count=n * dim).reshape(n, dim)
    return arr.astype(np.float32 if dtype == "float32" else np.int8)


def write_vector_segment(pv: PackedVectors,
                         directory: RamDirectory | None = None) -> RamDirectory:
    """Serialize the dense tier: eager npy + the same header/range-readable
    twin layout the BM25 tier carries, so PR 7's lazy cold hydration
    applies to vectors unchanged."""
    d = directory if directory is not None else RamDirectory()
    d.write(VECTOR_META_FILE, pv.meta.to_json())
    d.write(VECTOR_NPY_FILE, _npy_bytes(pv.vectors))
    d.write(VECTOR_SUPERINDEX_FILE, pack_vector_superindex(pv))
    d.write(VECTOR_ROWS_FILE, pack_vector_rows(pv))
    return d


def read_vector_segment(directory: Directory) -> PackedVectors:
    """Eager (full) hydration of one dense-tier segment."""
    meta = VectorMeta.from_json(
        directory.open_input(VECTOR_META_FILE).read_all())
    vectors = _npy_load(directory.open_input(VECTOR_NPY_FILE).read_all())
    return PackedVectors(meta=meta, vectors=vectors)


def combine_vector_segments(packs: list[PackedVectors],
                            tombstones: Iterable[int] = ()
                            ) -> tuple[np.ndarray, list[str], np.ndarray]:
    """Fuse base + ordered delta vector segments into one row-major view.

    Returns (vectors (n_docs, dim) f32, doc_ids, live (n_docs,) bool).
    Row positions concatenate in segment order — the SAME internal id
    space the BM25 tier's :func:`combine_segments` builds, so one
    tombstone list kills a doc in both tiers. Dead rows stay in place
    (ids must not shift) but are flagged ``live=False``; the dense scorer
    excludes them BEFORE its top-k, the dense analogue of
    subtraction-before-top-k (dense scores are legitimately negative, so
    zeroing a dead doc's score would not remove it from the ranking)."""
    if not packs:
        raise ValueError("combine_vector_segments needs at least a base")
    dim = packs[0].meta.dim
    for p in packs[1:]:
        if p.meta.dim != dim:
            raise ValueError("vector segments disagree on dim")
    vectors = np.concatenate([p.as_f32() for p in packs], axis=0)
    doc_ids: list[str] = []
    for p in packs:
        doc_ids.extend(p.meta.doc_ids)
    live = np.ones(len(doc_ids), dtype=bool)
    ts = np.asarray(sorted(tombstones), dtype=np.int64)
    if ts.size:
        live[ts] = False
    return vectors, doc_ids, live


@dataclasses.dataclass
class MergePolicy:
    """Size-tiered delta compaction: when does the delta tier fold back
    into the base segment?

    A growing delta tier costs on three axes — more blocks to hydrate and
    evaluate per query, dead weight (a tombstoned posting's tf zeroes at
    hydration, but it still occupies a block slot that gathers, scores to
    0, and pads the doc-id space — wasted lanes and accumulator width),
    and manifest bloat. Compaction rebuilds the partition's base from its
    LIVE docs (purging tombstones) at the cost of one full re-pack +
    re-upload. Triggers, any of:

    * ``max_deltas``  — the tier is longer than this many segments;
    * ``ratio``       — delta-tier docs outgrow ``ratio`` × base docs
                        (the size-tiered criterion);
    * ``tombstone_ratio`` — deleted docs outgrow this fraction of all docs
                        (the dead-weight bound).
    """

    max_deltas: int = 4
    ratio: float = 0.5
    tombstone_ratio: float = 0.2

    def should_merge(self, base_docs: int, delta_docs: int,
                     n_deltas: int, n_tombstones: int) -> bool:
        total = base_docs + delta_docs
        if n_deltas == 0 and n_tombstones == 0:
            return False
        return (n_deltas > self.max_deltas
                or delta_docs > self.ratio * max(1, base_docs)
                or n_tombstones > self.tombstone_ratio * max(1, total))
