"""Minimal Lucene-style analyzer: lowercase, alnum tokenization, stopwords.

Anserini's default analyzer additionally applies Porter stemming; we keep
analysis deliberately simple (documented deviation — ranking-quality
parity with Anserini is not a claim of this reproduction; latency/cost are).

Structured (fielded) documents: a document's text may be either a plain
string (one implicit ``body`` field) or a mapping ``{field: text}``. Every
bag-of-words consumer keeps working on either shape — :func:`tokenize`
flattens a mapping to the concatenation of its field texts (insertion
order), so document length, term frequencies, and global stats are
identical whether a doc arrived flat or fielded. The fielded views
(:func:`tokenize_positions`, :func:`tokenize_spans`) feed the v2 packed-
segment format: per-posting (field, position) occurrence lists and
per-field lengths for BM25F-style normalization, plus character spans for
snippet highlighting.

Positions index the KEPT token stream of one field (0-based, after
stopword/overlength removal) — a documented deviation from Lucene's
position-increment gaps: phrase adjacency here means "consecutive kept
tokens of the same field", and the oracle applies the identical rule.
"""

from __future__ import annotations

import re
from typing import Iterable, Mapping

_TOKEN_RE = re.compile(r"[a-z0-9]+")

# Lucene's classic English stopword set.
STOPWORDS = frozenset(
    "a an and are as at be but by for if in into is it no not of on or such "
    "that the their then there these they this to was will with".split()
)

# the implicit field a plain-string document's text lives in
DEFAULT_FIELD = "body"


def field_items(text: "str | Mapping[str, str]") -> list[tuple[str, str]]:
    """A document's (field, text) pairs: a plain string is one implicit
    ``body`` field; a mapping yields its items in insertion order (that
    order defines the flattened token stream, so it is part of the
    document's identity)."""
    if isinstance(text, Mapping):
        return [(str(f), str(v)) for f, v in text.items()]
    return [(DEFAULT_FIELD, text)]


def flatten_text(text: "str | Mapping[str, str]") -> str:
    """One analyzable string for bag-of-words consumers (stats, embedders):
    field texts joined with a single space, in field order."""
    if isinstance(text, Mapping):
        return " ".join(str(v) for v in text.values())
    return text


def tokenize(text: "str | Mapping[str, str]", *,
             stopwords: frozenset[str] = STOPWORDS,
             max_token_len: int = 64) -> list[str]:
    if isinstance(text, Mapping):
        text = flatten_text(text)
    return [
        t for t in _TOKEN_RE.findall(text.lower())
        if t not in stopwords and len(t) <= max_token_len
    ]


def tokenize_positions(text: "str | Mapping[str, str]", *,
                       stopwords: frozenset[str] = STOPWORDS,
                       max_token_len: int = 64
                       ) -> list[tuple[str, str, int]]:
    """(field, token, position) for every kept token, in field order then
    position order. Positions are 0-based per field over the KEPT stream;
    duplicate terms within one field keep their distinct positions."""
    out: list[tuple[str, str, int]] = []
    for field, ftext in field_items(text):
        pos = 0
        for t in _TOKEN_RE.findall(ftext.lower()):
            if t in stopwords or len(t) > max_token_len:
                continue
            out.append((field, t, pos))
            pos += 1
    return out


def tokenize_spans(text: str, *, stopwords: frozenset[str] = STOPWORDS,
                   max_token_len: int = 64
                   ) -> list[tuple[str, int, int]]:
    """Kept tokens of ONE field's raw text with their [start, end) character
    offsets — the snippet cutter's input (offsets index the ORIGINAL text,
    so slices preserve the author's casing and punctuation)."""
    out: list[tuple[str, int, int]] = []
    for m in _TOKEN_RE.finditer(text.lower()):
        t = m.group()
        if t in stopwords or len(t) > max_token_len:
            continue
        out.append((t, m.start(), m.end()))
    return out


def field_token_counts(text: "str | Mapping[str, str]") -> dict[str, int]:
    """field -> kept-token count for one document — the per-field length the
    v2 format stores for BM25F-style normalization. Sums to ``len(tokenize
    (text))`` exactly (flattening concatenates the per-field streams)."""
    out: dict[str, int] = {}
    for field, ftext in field_items(text):
        out[field] = len(tokenize(ftext))
    return out


def token_counts(text: "str | Mapping[str, str]") -> "Counter[str]":
    """term -> tf for one document — the unit the incremental stats
    maintenance (df/avgdl updates on add/delete) works in."""
    from collections import Counter
    return Counter(tokenize(text))
