"""Minimal Lucene-style analyzer: lowercase, alnum tokenization, stopwords.

Anserini's default analyzer additionally applies Porter stemming; we keep
analysis deliberately simple (documented deviation — ranking-quality
parity with Anserini is not a claim of this reproduction; latency/cost are).
"""

from __future__ import annotations

import re

_TOKEN_RE = re.compile(r"[a-z0-9]+")

# Lucene's classic English stopword set.
STOPWORDS = frozenset(
    "a an and are as at be but by for if in into is it no not of on or such "
    "that the their then there these they this to was will with".split()
)


def tokenize(text: str, *, stopwords: frozenset[str] = STOPWORDS,
             max_token_len: int = 64) -> list[str]:
    return [
        t for t in _TOKEN_RE.findall(text.lower())
        if t not in stopwords and len(t) <= max_token_len
    ]


def token_counts(text: str) -> "Counter[str]":
    """term -> tf for one document — the unit the incremental stats
    maintenance (df/avgdl updates on add/delete) works in."""
    from collections import Counter
    return Counter(tokenize(text))
