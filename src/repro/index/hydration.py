"""Partial (lazy) hydration — cold starts from byte-range reads.

Eager hydration (:func:`repro.index.builder.read_segment`) streams a whole
segment before the first byte of scoring; at fleet scale that is the ~full
cold-start cost the paper's serverless bet stumbles on. This layer instead
answers a cold query from the segment's compact header plus targeted range
reads (the Airphant move):

1. ONE ranged GET pulls ``superindex.bin`` — meta, vocab, term → block
   extents (``term_offsets``), the ``block_max`` table, doc lengths, idf.
2. The query's terms name exact payload row ranges in ``blocks.bin``
   (term t's blocks are rows ``[off[t], off[t+1])``, contiguous by
   construction); nearby extents COALESCE when the gap's bandwidth cost is
   below another GET's first-byte cost, so a multi-term query stays a
   handful of range reads, not one per term.
3. The result is a full-shape :class:`~repro.index.builder.PackedIndex`
   VIEW: hydrated terms carry their true blocks, absent terms' blocks stay
   masked non-live (doc = pad, tf = 0) — ``gather_query_blocks`` indexes
   blocks only through ``term_offsets`` of the query's terms, so every
   accumulator (dense / sorted / pruned) and :func:`~repro.index.builder.
   combine_segments` NRT fusion rank BIT-identically to full hydration.
4. ``backfill()`` later upgrades the view partial → full OFF the critical
   path (the runtime bills it on the ledger's backfill line, never into
   query latency).
"""

from __future__ import annotations

import numpy as np

from repro.core.directory import Directory, DirectoryError, StoreDirectory
from repro.core.object_store import NoSuchKey
from repro.index.builder import (PAYLOAD_FILE, SUPERINDEX_FILE,
                                 VECTOR_ROWS_FILE, VECTOR_SUPERINDEX_FILE,
                                 FieldData, IndexMeta, PackedIndex,
                                 VectorMeta, combine_segments,
                                 payload_row_bytes, unpack_payload_rows,
                                 unpack_superindex, unpack_vector_rows,
                                 unpack_vector_superindex, vector_row_bytes)


class SuperIndexMissing(Exception):
    """The segment predates the lazy layout (no superindex.bin) — the
    caller must fall back to eager full hydration."""


def _read_full(directory: Directory, name: str) -> bytes:
    """One whole-object GET, bypassing the StoreDirectory block cache (and
    its HEAD round-trip) — the header read is the partial path's floor."""
    if isinstance(directory, StoreDirectory):
        try:
            return directory.store.get(directory.prefix + name)
        except NoSuchKey:
            raise SuperIndexMissing(name) from None
    try:
        return directory.open_input(name).read_all()
    except DirectoryError:
        raise SuperIndexMissing(name) from None


def _range_reader(directory: Directory, name: str):
    """(start, n) -> bytes over one file, as raw ranged GETs when store-backed
    (each call is one billed GET of exactly n bytes)."""
    if isinstance(directory, StoreDirectory):
        store, key = directory.store, directory.prefix + name
        return lambda s, n: store.get(key, start=s, length=n)
    inp = directory.open_input(name)

    def read(s: int, n: int) -> bytes:
        inp.seek(s)
        return inp.read_bytes(n)

    return read


def _coalesce_gap_bytes(directory: Directory) -> int:
    """Merge two extents when reading the gap costs less than a fresh GET:
    gap < first_byte_s × bandwidth (the network model's own break-even)."""
    if isinstance(directory, StoreDirectory):
        nm = directory.store.network
        return int(nm.first_byte_s * nm.bandwidth_Bps)
    return 1 << 16


def coalesce_extents(extents: list[tuple[int, int]],
                     gap: int) -> list[tuple[int, int]]:
    """Merge sorted-or-not [lo, hi) byte extents whose gaps are ≤ ``gap``."""
    out: list[tuple[int, int]] = []
    for lo, hi in sorted(e for e in extents if e[1] > e[0]):
        if out and lo - out[-1][1] <= gap:
            out[-1] = (out[-1][0], max(out[-1][1], hi))
        else:
            out.append((lo, hi))
    return out


class PartialSegment:
    """One segment's partial → full hydration state.

    Arrays are allocated FULL-SHAPE up front with non-hydrated blocks
    masked non-live (doc ids = n_docs pad, tf = 0): the search state built
    from a partial view has the same shapes as the full one, so jit
    specializations are shared and ``combine_segments`` works unchanged.
    """

    def __init__(self, directory: Directory, meta: IndexMeta, vocab: dict,
                 term_offsets: np.ndarray, block_max: np.ndarray,
                 doc_len: np.ndarray, idf: np.ndarray,
                 header_bytes: int,
                 fields_header: "dict | None" = None) -> None:
        self.directory = directory
        self.meta = meta
        self.vocab = vocab
        self.term_offsets = term_offsets.astype(np.int32, copy=False)
        self.block_max = block_max
        self.doc_len = doc_len
        self.idf = idf
        NB, B = meta.n_blocks, meta.block
        self.block_docs = np.full((NB, B), meta.n_docs, np.int32)
        self.block_tf = np.zeros((NB, B), np.uint8)
        # format v2: the header carries field names / per-field lengths /
        # facet tables; the per-posting occurrence arrays hydrate with the
        # SAME payload-row ranges as docs/tf (one wider row pitch), masked
        # rows staying all-zero exactly like tf
        self.fields_header = fields_header
        self.pos_slots = fields_header["pos_slots"] if fields_header else 0
        if fields_header is not None:
            P = self.pos_slots
            self.block_nocc = np.zeros((NB, B), np.uint8)
            self.block_occ_field = np.zeros((NB, B, P), np.uint8)
            self.block_occ_pos = np.zeros((NB, B, P), np.uint16)
        self._rows_live = np.zeros(NB, bool)
        self._reader = None
        self.bytes_read = header_bytes   # data bytes moved so far (header +
        #                                  payload ranges) — the deserialize
        #                                  model charges against this, not
        #                                  the full-shape array footprint

    @classmethod
    def open(cls, directory: Directory) -> "PartialSegment":
        """Read ONLY the header (one GET); no payload rows yet."""
        blob = _read_full(directory, SUPERINDEX_FILE)
        meta, vocab, (term_offsets, block_max, doc_len, idf), fields = \
            unpack_superindex(blob)
        return cls(directory, meta, vocab, term_offsets, block_max,
                   doc_len, idf, header_bytes=len(blob),
                   fields_header=fields)

    @property
    def full(self) -> bool:
        return bool(self._rows_live.all())

    def term_rows(self, term_ids) -> list[tuple[int, int]]:
        """Payload row ranges for ``term_ids`` (segment-local block index
        space); out-of-vocab ids are skipped (zero blocks here)."""
        V = len(self.term_offsets) - 1
        off = self.term_offsets
        out = []
        for t in term_ids:
            if 0 <= t < V and off[t + 1] > off[t]:
                out.append((int(off[t]), int(off[t + 1])))
        return out

    def _fetch_rows(self, rows: list[tuple[int, int]]) -> None:
        todo = [(lo, hi) for lo, hi in rows
                if not self._rows_live[lo:hi].all()]
        if not todo:
            return
        if self._reader is None:
            self._reader = _range_reader(self.directory, PAYLOAD_FILE)
        row = payload_row_bytes(self.meta.block, self.pos_slots)
        gap = _coalesce_gap_bytes(self.directory)
        spans = coalesce_extents(
            [(lo * row, hi * row) for lo, hi in todo], gap)
        for blo, bhi in spans:
            chunk = self._reader(blo, bhi - blo)
            self.bytes_read += len(chunk)
            lo = blo // row
            if self.pos_slots:
                docs, tf, nocc, occf, occp = unpack_payload_rows(
                    chunk, self.meta.block, self.pos_slots)
                self.block_nocc[lo:lo + len(docs)] = nocc
                self.block_occ_field[lo:lo + len(docs)] = occf
                self.block_occ_pos[lo:lo + len(docs)] = occp
            else:
                docs, tf = unpack_payload_rows(chunk, self.meta.block)
            self.block_docs[lo:lo + len(docs)] = docs
            self.block_tf[lo:lo + len(tf)] = tf
            self._rows_live[lo:lo + len(docs)] = True

    def hydrate_terms(self, term_ids) -> bool:
        """Pull the payload rows of ``term_ids``; True if anything moved."""
        before = self.bytes_read
        self._fetch_rows(self.term_rows(term_ids))
        return self.bytes_read != before

    def backfill(self) -> bool:
        """Fetch every still-masked row (coalesced) — partial → full."""
        if self.full:
            return False
        self._fetch_rows([(0, self.meta.n_blocks)])
        return True

    def to_packed(self) -> PackedIndex:
        """The current view as a PackedIndex (shares the live arrays)."""
        fields = None
        if self.fields_header is not None:
            fh = self.fields_header
            fields = FieldData(
                field_names=list(fh["field_names"]),
                pos_slots=self.pos_slots,
                field_len=fh["field_len"],
                block_nocc=self.block_nocc,
                block_occ_field=self.block_occ_field,
                block_occ_pos=self.block_occ_pos,
                facet_names=list(fh["facet_names"]),
                facet_values=[list(v) for v in fh["facet_values"]],
                facet_ids=fh["facet_ids"])
        return PackedIndex(
            meta=self.meta, vocab=self.vocab,
            term_offsets=self.term_offsets, block_docs=self.block_docs,
            block_tf=self.block_tf, block_max=self.block_max,
            doc_len=self.doc_len, idf=self.idf, fields=fields)


def open_partial_segment(directory: Directory) -> PartialSegment:
    return PartialSegment.open(directory)


class PartialVectorSegment:
    """One dense-tier segment's partial hydration state (PR 7's move,
    applied to vectors): ONE ranged GET pulls the tiny header
    (``vec_superindex.bin`` — meta only), then row ranges of
    ``vec_rows.bin`` stream in on demand. Row r is doc r's vector, so a
    tombstone-carrying segment hydrates exactly its LIVE rows — the dense
    tier's equivalent of reading only the queried terms' blocks."""

    def __init__(self, directory: Directory, meta: VectorMeta,
                 header_bytes: int) -> None:
        self.directory = directory
        self.meta = meta
        dt = np.float32 if meta.dtype == "float32" else np.int8
        self.vectors = np.zeros((meta.n_docs, meta.dim), dt)
        self._rows_live = np.zeros(meta.n_docs, bool)
        self._reader = None
        self.bytes_read = header_bytes

    @classmethod
    def open(cls, directory: Directory) -> "PartialVectorSegment":
        blob = _read_full(directory, VECTOR_SUPERINDEX_FILE)
        return cls(directory, unpack_vector_superindex(blob),
                   header_bytes=len(blob))

    @property
    def full(self) -> bool:
        return bool(self._rows_live.all())

    def hydrate_rows(self, rows: list[tuple[int, int]]) -> bool:
        """Pull the [lo, hi) row ranges (coalesced); True if bytes moved."""
        todo = [(lo, hi) for lo, hi in rows
                if hi > lo and not self._rows_live[lo:hi].all()]
        if not todo:
            return False
        if self._reader is None:
            self._reader = _range_reader(self.directory, VECTOR_ROWS_FILE)
        row = vector_row_bytes(self.meta.dim, self.meta.dtype)
        gap = _coalesce_gap_bytes(self.directory)
        before = self.bytes_read
        for blo, bhi in coalesce_extents(
                [(lo * row, hi * row) for lo, hi in todo], gap):
            chunk = self._reader(blo, bhi - blo)
            self.bytes_read += len(chunk)
            lo = blo // row
            vecs = unpack_vector_rows(chunk, self.meta.dim, self.meta.dtype)
            self.vectors[lo:lo + len(vecs)] = vecs
            self._rows_live[lo:lo + len(vecs)] = True
        return self.bytes_read != before

    def backfill(self) -> bool:
        if self.full:
            return False
        return self.hydrate_rows([(0, self.meta.n_docs)])

    def as_f32(self) -> np.ndarray:
        if self.meta.dtype == "float32":
            return self.vectors
        return self.vectors.astype(np.float32) * np.float32(self.meta.scale)


def open_partial_vector_segment(directory: Directory) -> PartialVectorSegment:
    return PartialVectorSegment.open(directory)


class LazyVectors:
    """The dense tier's lazy view over one generation's vector segments.

    Unlike the sparse tier there is no query-dependent subset: EVERY live
    row participates in every matvec, so ``ensure_live`` IS the critical-
    path hydration — it pulls exactly the non-tombstoned rows of each
    segment (coalesced ranges) and nothing else. There is no backfill
    stage: dead rows are never needed for this generation, so a "full"
    upgrade would stream bytes no query can ever read."""

    def __init__(self, segments: list[PartialVectorSegment],
                 tombstones=()) -> None:
        if not segments:
            raise ValueError("LazyVectors needs at least one segment")
        self.segments = segments
        self.tombstones = sorted(tombstones)

    @property
    def bytes_read(self) -> int:
        return sum(s.bytes_read for s in self.segments)

    def _live_ranges(self) -> list[list[tuple[int, int]]]:
        """Per segment, the [lo, hi) LOCAL row ranges of live docs."""
        out = []
        offset = 0
        ts = np.asarray(self.tombstones, np.int64)
        for seg in self.segments:
            n = seg.meta.n_docs
            dead = np.zeros(n, bool)
            local = ts[(ts >= offset) & (ts < offset + n)] - offset
            dead[local] = True
            ranges, lo = [], None
            for i in range(n + 1):
                alive = i < n and not dead[i]
                if alive and lo is None:
                    lo = i
                elif not alive and lo is not None:
                    ranges.append((lo, i))
                    lo = None
            out.append(ranges)
            offset += n
        return out

    def ensure_live(self) -> bool:
        changed = False
        for seg, ranges in zip(self.segments, self._live_ranges()):
            changed |= seg.hydrate_rows(ranges)
        return changed

    def combined(self) -> tuple[np.ndarray, list[str], np.ndarray]:
        """(vectors f32, doc_ids, live) over base + deltas — the same
        row space :func:`~repro.index.builder.combine_vector_segments`
        builds eagerly; hydrated live rows are byte-exact (raw little-
        endian roundtrip), so lazy dense scores are bit-identical."""
        vectors = np.concatenate([s.as_f32() for s in self.segments], axis=0)
        doc_ids: list[str] = []
        for s in self.segments:
            doc_ids.extend(s.meta.doc_ids)
        live = np.ones(len(doc_ids), bool)
        if self.tombstones:
            live[np.asarray(self.tombstones, np.int64)] = False
        return vectors, doc_ids, live


class LazyIndex:
    """A query-sufficient view over one asset version's segment set.

    Plain versions hold one segment; NRT generations hold base + deltas
    fused under the generation's LIVE stats/vocab. Either way the contract
    is the same: after ``ensure_terms(terms)``, ``packed()`` ranks those
    terms' queries bit-identically to the fully-hydrated oracle, and
    ``backfill()`` upgrades to the full index without touching the
    critical path.
    """

    def __init__(self, segments: list[PartialSegment], *,
                 vocab: dict | None = None, stats: dict | None = None,
                 tombstones=()) -> None:
        if not segments:
            raise ValueError("LazyIndex needs at least one segment")
        self.segments = segments
        self._gen_state = (vocab, stats) if stats is not None else None
        self.tombstones = list(tombstones)
        self.vocab = vocab if vocab is not None else segments[0].vocab

    @property
    def state(self) -> str:
        return "full" if all(s.full for s in self.segments) else "partial"

    @property
    def bytes_read(self) -> int:
        return sum(s.bytes_read for s in self.segments)

    def term_ids(self, terms) -> list[int]:
        return [tid for t in terms
                if (tid := self.vocab.get(t, -1)) >= 0]

    def top_terms(self, n: int) -> list[str]:
        """The ``n`` highest-document-frequency terms of this view — the
        rollover-prewarm ranking: under Zipfian traffic the head terms
        cover most of the next queries' posting bytes, so prewarming just
        them approaches a full backfill's warm-hit rate at a fraction of
        the GET bytes. Deterministic (df desc, then term asc). Plain
        (non-generation) versions rank by ascending idf — the same order,
        since idf is monotone-decreasing in df."""
        if self._gen_state is not None:
            _, stats = self._gen_state
            ranked = sorted(stats["df"].items(), key=lambda kv: (-kv[1], kv[0]))
            return [t for t, _ in ranked[:n]]
        seg = self.segments[0]
        terms = sorted(self.vocab, key=lambda t: (seg.idf[self.vocab[t]], t))
        return terms[:n]

    def ensure_terms(self, terms) -> bool:
        """Hydrate the posting blocks of ``terms`` (strings, mapped through
        the live vocab — segment term ids agree because the vocab grows
        append-only); True if any segment moved bytes."""
        tids = self.term_ids(terms)
        changed = False
        for seg in self.segments:
            changed |= seg.hydrate_terms(tids)
        return changed

    def backfill(self) -> bool:
        changed = False
        for seg in self.segments:
            changed |= seg.backfill()
        return changed

    def packed(self) -> PackedIndex:
        """The current (partial or full) view, NRT-fused when this version
        is a generation. Masked blocks carry tf = 0, so the fuse's
        recomputed impacts and per-term block ordering match full
        hydration EXACTLY for every hydrated term."""
        if self._gen_state is None:
            return self.segments[0].to_packed()
        vocab, stats = self._gen_state
        return combine_segments([s.to_packed() for s in self.segments],
                                vocab=vocab, stats=stats,
                                tombstones=self.tombstones)
