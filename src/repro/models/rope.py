"""Rotary position embeddings (RoPE), interleaved-free (GPT-NeoX style)."""

from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(dim: int, *, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x, positions, *, theta: float = 10000.0):
    """x (..., S, D) with D even; positions (S,) int32."""
    D = x.shape[-1]
    freqs = rope_freqs(D, theta=theta)                      # (D/2,)
    ang = positions[:, None].astype(jnp.float32) * freqs[None, :]  # (S, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., : D // 2], x[..., D // 2:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)
