"""GraphCast-style encoder–processor–decoder GNN (interaction networks).

The assigned ``graphcast`` architecture: 16 processor layers, d_hidden=512,
sum aggregation, 227 output vars [arXiv:2212.12794]. GraphCast's
encoder-processor-decoder runs on an icosahedral mesh (refinement 6); the
assigned *shapes* are generic graph benchmarks (cora / reddit-minibatch /
ogb_products / batched molecules), so the mesh-construction stage is replaced
by the given edge lists — the processor (the compute core) is faithful.

Message passing is built on ``jax.ops.segment_sum`` over an edge-index →
node scatter (JAX has no sparse SpMM for this; the segment formulation IS
the system, per the assignment note). Interaction-network layer l:

    e' = e + MLP_e([e, v_src, v_dst])            (edge update)
    v' = v + MLP_v([v, Σ_{e' into v} e'])        (node update, sum agg)

Processor layers are scan-stacked + remat (16 deep). Padding convention:
``src/dst == n_nodes`` marks padded edges; the dump row is sliced off after
every scatter.

Sharding (see repro.parallel.sharding.gnn_rules): edge arrays shard over
``data``; node states replicate (small/medium graphs) or shard over ``data``
with psum-merged partial aggregates (ogb_products) — the baseline lets GSPMD
place the gather/scatter collectives; the hillclimb iterates on them.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import ParamDef, mlp_stack, mlp_stack_defs


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    d_feat: int                   # input node-feature dim
    d_out: int = 227              # graphcast n_vars
    n_layers: int = 16
    d_hidden: int = 512
    aggregator: str = "sum"       # sum | mean | max
    mesh_refinement: int = 6      # metadata (icosahedral stage not used)
    dtype: Any = jnp.float32
    remat: bool = True
    remat_policy: str = "nothing_saveable"   # | "dots_saveable" | "none"
    unroll: bool = False          # unroll the layer scan (dry-run accounting)

    def param_count(self) -> int:
        from repro.models.common import count_params
        return count_params(gnn_param_defs(self))


def _stack(defs, n):
    return jax.tree_util.tree_map(
        lambda p: ParamDef((n,) + p.shape, ("layers",) + p.axes,
                           init=p.init, scale=p.scale, dtype=p.dtype),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


def gnn_param_defs(cfg: GNNConfig) -> dict:
    h, dt = cfg.d_hidden, cfg.dtype
    layer = {
        "edge_mlp": mlp_stack_defs((3 * h, h, h), dt),
        "node_mlp": mlp_stack_defs((2 * h, h, h), dt),
    }
    return {
        "node_enc": mlp_stack_defs((cfg.d_feat, h, h), dt),
        "edge_enc": mlp_stack_defs((2 * h, h, h), dt),
        "layers": _stack(layer, cfg.n_layers),
        "node_dec": mlp_stack_defs((h, h, cfg.d_out), dt),
    }


def _aggregate(messages, dst, n_nodes: int, how: str):
    """Scatter edge messages to destination nodes. Padded edges must carry
    dst == n_nodes (dump row, sliced off)."""
    if how == "sum":
        out = jax.ops.segment_sum(messages, dst, num_segments=n_nodes + 1)
    elif how == "mean":
        s = jax.ops.segment_sum(messages, dst, num_segments=n_nodes + 1)
        c = jax.ops.segment_sum(jnp.ones((messages.shape[0], 1), messages.dtype),
                                dst, num_segments=n_nodes + 1)
        out = s / jnp.maximum(c, 1.0)
    elif how == "max":
        out = jax.ops.segment_max(messages, dst, num_segments=n_nodes + 1)
        out = jnp.where(jnp.isfinite(out), out, 0.0)
    else:
        raise ValueError(how)
    return out[:n_nodes]


def gnn_forward(params, graph: dict, cfg: GNNConfig):
    """graph = {feat (N,F), src (E,), dst (E,)} — padded edges use id N.

    Returns per-node predictions (N, d_out).
    """
    feat, src, dst = graph["feat"], graph["src"], graph["dst"]
    N = feat.shape[0]
    v = mlp_stack(params["node_enc"], feat.astype(cfg.dtype))        # (N,h)
    vpad = jnp.concatenate([v, jnp.zeros((1, v.shape[1]), v.dtype)], 0)
    e = mlp_stack(params["edge_enc"],
                  jnp.concatenate([vpad[src], vpad[dst]], -1))        # (E,h)

    def layer(carry, lp):
        v, e = carry
        vpad = jnp.concatenate([v, jnp.zeros((1, v.shape[1]), v.dtype)], 0)
        msg_in = jnp.concatenate([e, vpad[src], vpad[dst]], -1)
        e = e + mlp_stack(lp["edge_mlp"], msg_in)
        agg = _aggregate(e, dst, N, cfg.aggregator)
        v = v + mlp_stack(lp["node_mlp"], jnp.concatenate([v, agg], -1))
        return (v, e), None

    body = layer
    if cfg.remat and cfg.remat_policy != "none":
        policy = {
            "nothing_saveable": jax.checkpoint_policies.nothing_saveable,
            "dots_saveable": jax.checkpoint_policies.dots_saveable,
        }[cfg.remat_policy]
        body = jax.checkpoint(body, policy=policy)
    (v, _), _ = jax.lax.scan(body, (v, e), params["layers"],
                             unroll=cfg.n_layers if cfg.unroll else 1)
    return mlp_stack(params["node_dec"], v)


def gnn_forward_batched(params, graphs: dict, cfg: GNNConfig):
    """Batched small graphs: feat (G,N,F), src/dst (G,E). vmap over G."""
    return jax.vmap(lambda f, s, d: gnn_forward(
        params, {"feat": f, "src": s, "dst": d}, cfg))(
        graphs["feat"], graphs["src"], graphs["dst"])


def gnn_loss(params, batch: dict, cfg: GNNConfig):
    """MSE regression to (…,d_out) targets over masked nodes (graphcast's
    per-variable regression). batch: graph fields + target + node_mask."""
    if batch["feat"].ndim == 3:
        pred = gnn_forward_batched(params, batch, cfg)
    else:
        pred = gnn_forward(params, batch, cfg)
    target = batch["target"]
    mask = batch["node_mask"].astype(jnp.float32)
    err = (pred.astype(jnp.float32) - target.astype(jnp.float32)) ** 2
    per_node = jnp.mean(err, axis=-1)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(per_node * mask) / denom
    return loss, {"loss": loss, "rmse": jnp.sqrt(loss)}
