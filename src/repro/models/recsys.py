"""Recsys architectures: FM, DCN-v2, BST, BERT4Rec.

Shared anatomy (the assignment's recsys regime): huge row-sharded embedding
tables → feature-interaction op → small MLP → logit. The embedding lookup is
the hot path; tables carry the "rows" logical axis (→ model mesh axis). The
serving side plugs into the paper's serverless runtime: tables are the
immutable "index" hydrated from the object store.

* FM        — 2-way factorization machine, O(nk) sum-square trick [Rendle '10]
* DCN-v2    — 3 cross layers (x0 ⊙ (W xl + b) + xl) + deep tower [2008.13535]
* BST       — behavior-sequence transformer: 1 block over the last 20 item
              embeddings (+target), then MLP [1905.06874]
* BERT4Rec  — bidirectional 2-block transformer over 200-item sequences,
              masked-item CE over the item vocab (tied embedding) [1904.06690]

Retrieval (`retrieval_cand`, 1 query × 1M candidates) uses each model's
two-tower factorization: a user vector dotted against the candidate item
matrix → top-k (the Pallas `dot_topk` fused kernel on TPU; jnp fallback
here). For FM the dot IS the model's pairwise term; for DCN/BST/BERT4Rec it
is the standard retrieval-tower deployment (documented simplification).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.attention import attention
from repro.models.common import (ParamDef, dense, layer_norm, mlp_stack,
                                 mlp_stack_defs)
from repro.models.embedding import embedding_lookup


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    kind: str                       # fm | dcn | bst | bert4rec
    n_sparse: int = 26              # sparse fields (fm/dcn)
    n_dense: int = 0                # dense features (dcn)
    rows_per_field: int = 1_000_000
    embed_dim: int = 16
    n_items: int = 1_000_000        # item vocab (bst/bert4rec)
    seq_len: int = 20               # behavior-sequence length
    n_blocks: int = 1
    n_heads: int = 8
    mlp_dims: tuple[int, ...] = (1024, 512, 256)
    n_cross_layers: int = 3
    dtype: Any = jnp.float32
    unroll: bool = False            # unroll batch-chunk loops (dry-run)
    sharded_topk: bool = False      # shard_map local-topk serve (perf)

    def param_count(self) -> int:
        from repro.models.common import count_params
        return count_params(recsys_param_defs(self))


# -- parameter defs ---------------------------------------------------------------


def _field_table(cfg: RecsysConfig, dim: int) -> ParamDef:
    """All sparse fields share one hashed (F·R, dim) table, row-sharded."""
    return ParamDef((cfg.n_sparse * cfg.rows_per_field, dim),
                    ("rows", None), init="embed", dtype=cfg.dtype)


def _tx_block_defs(d: int, n_heads: int, dt) -> dict:
    return {
        "wq": ParamDef((d, d), ("embed", "heads"), dtype=dt),
        "wk": ParamDef((d, d), ("embed", "heads"), dtype=dt),
        "wv": ParamDef((d, d), ("embed", "heads"), dtype=dt),
        "wo": ParamDef((d, d), ("heads", "embed"), dtype=dt),
        "ln1_g": ParamDef((d,), (None,), init="ones", dtype=dt),
        "ln1_b": ParamDef((d,), (None,), init="zeros", dtype=dt),
        "ln2_g": ParamDef((d,), (None,), init="ones", dtype=dt),
        "ln2_b": ParamDef((d,), (None,), init="zeros", dtype=dt),
        "ffn": mlp_stack_defs((d, 4 * d, d), dt),
    }


def recsys_param_defs(cfg: RecsysConfig) -> dict:
    dt = cfg.dtype
    if cfg.kind == "fm":
        return {
            "emb": _field_table(cfg, cfg.embed_dim),
            "linear": _field_table(cfg, 1),
            "bias": ParamDef((1,), (None,), init="zeros", dtype=dt),
        }
    if cfg.kind == "dcn":
        d0 = cfg.n_dense + cfg.n_sparse * cfg.embed_dim
        out = {
            "emb": _field_table(cfg, cfg.embed_dim),
            "head": ParamDef((cfg.mlp_dims[-1], 1), (None, None), dtype=dt),
            "head_b": ParamDef((1,), (None,), init="zeros", dtype=dt),
            "mlp": mlp_stack_defs((d0,) + tuple(cfg.mlp_dims), dt),
        }
        for i in range(cfg.n_cross_layers):
            out[f"cross_w{i}"] = ParamDef((d0, d0), (None, "mlp"), dtype=dt)
            out[f"cross_b{i}"] = ParamDef((d0,), (None,), init="zeros", dtype=dt)
        return out
    if cfg.kind == "bst":
        d = cfg.embed_dim
        blocks = {f"b{i}": _tx_block_defs(d, cfg.n_heads, dt)
                  for i in range(cfg.n_blocks)}
        feat_dim = (cfg.seq_len + 1) * d
        return {
            "item_emb": ParamDef((cfg.n_items, d), ("rows", None),
                                 init="embed", dtype=dt),
            "pos_emb": ParamDef((cfg.seq_len + 1, d), (None, None),
                                init="embed", dtype=dt),
            **blocks,
            "mlp": mlp_stack_defs((feat_dim,) + tuple(cfg.mlp_dims) + (1,), dt),
        }
    if cfg.kind == "bert4rec":
        d = cfg.embed_dim
        blocks = {f"b{i}": _tx_block_defs(d, cfg.n_heads, dt)
                  for i in range(cfg.n_blocks)}
        return {
            # +2 rows: [PAD]=0 is row n_items, [MASK] is row n_items+1
            "item_emb": ParamDef((cfg.n_items + 2, d), ("rows", None),
                                 init="embed", dtype=dt),
            "pos_emb": ParamDef((cfg.seq_len, d), (None, None),
                                init="embed", dtype=dt),
            **blocks,
            "out_b": ParamDef((cfg.n_items + 2,), ("rows",), init="zeros",
                              dtype=dt),
        }
    raise ValueError(cfg.kind)


# -- forward passes ------------------------------------------------------------------


def _flat_ids(cfg: RecsysConfig, sparse_ids: jax.Array) -> jax.Array:
    """(B,F) per-field ids → global rows in the shared (F·R, ·) table."""
    F = cfg.n_sparse
    base = jnp.arange(F, dtype=jnp.int32) * cfg.rows_per_field
    return sparse_ids + base[None, :]


def fm_forward(params, batch, cfg: RecsysConfig):
    """batch = {sparse (B,F) int32}. Returns logits (B,)."""
    ids = _flat_ids(cfg, batch["sparse"])
    v = embedding_lookup(params["emb"], ids)              # (B,F,D)
    lin = embedding_lookup(params["linear"], ids)[..., 0]  # (B,F)
    # 2-way term via the O(nk) identity: ½[(Σv)² − Σv²] summed over dims
    s = jnp.sum(v, axis=1)                                # (B,D)
    pair = 0.5 * jnp.sum(s * s - jnp.sum(v * v, axis=1), axis=-1)
    return params["bias"][0] + jnp.sum(lin, axis=1) + pair


def dcn_forward(params, batch, cfg: RecsysConfig):
    """batch = {dense (B,13) f32, sparse (B,26) int32}. Returns logits (B,)."""
    ids = _flat_ids(cfg, batch["sparse"])
    v = embedding_lookup(params["emb"], ids)              # (B,F,D)
    x0 = jnp.concatenate(
        [batch["dense"].astype(cfg.dtype), v.reshape(v.shape[0], -1)], -1)
    x = x0
    for i in range(cfg.n_cross_layers):
        xw = dense(x, params[f"cross_w{i}"]) + params[f"cross_b{i}"]
        x = x0 * xw + x                                   # DCN-v2 cross
    h = mlp_stack(params["mlp"], x)
    return (dense(h, params["head"]) + params["head_b"])[..., 0]


def _tx_block(p, x, n_heads: int):
    """Post-LN encoder block (BST/BERT4Rec style), bidirectional."""
    B, S, d = x.shape
    dh = d // n_heads
    q = dense(x, p["wq"]).reshape(B, S, n_heads, dh).transpose(0, 2, 1, 3)
    k = dense(x, p["wk"]).reshape(B, S, n_heads, dh).transpose(0, 2, 1, 3)
    v = dense(x, p["wv"]).reshape(B, S, n_heads, dh).transpose(0, 2, 1, 3)
    o = attention(q, k, v)                                # bidirectional
    o = o.transpose(0, 2, 1, 3).reshape(B, S, d)
    x = layer_norm(x + dense(o, p["wo"]), p["ln1_g"], p["ln1_b"])
    h = mlp_stack(p["ffn"], x)
    return layer_norm(x + h, p["ln2_g"], p["ln2_b"])


def bst_forward(params, batch, cfg: RecsysConfig):
    """batch = {seq (B,S) int32 item history, target (B,) int32}.

    Transformer over [history ; target] with position embeddings, then the
    flattened sequence through the MLP tower → CTR logit (B,).
    """
    seq = jnp.concatenate([batch["seq"], batch["target"][:, None]], axis=1)
    x = embedding_lookup(params["item_emb"], seq)         # (B,S+1,D)
    x = x + params["pos_emb"][None]
    for i in range(cfg.n_blocks):
        x = _tx_block(params[f"b{i}"], x, cfg.n_heads)
    flat = x.reshape(x.shape[0], -1)
    return mlp_stack(params["mlp"], flat)[..., 0]


def bert4rec_forward(params, batch, cfg: RecsysConfig):
    """batch = {seq (B,S) int32 with [MASK]=n_items+1, [PAD]=n_items}.

    Returns logits (B,S,n_items+2) via the tied item embedding.
    """
    x = embedding_lookup(params["item_emb"], batch["seq"])
    x = x + params["pos_emb"][None]
    for i in range(cfg.n_blocks):
        x = _tx_block(params[f"b{i}"], x, cfg.n_heads)
    return x @ params["item_emb"].T + params["out_b"]


def recsys_forward(params, batch, cfg: RecsysConfig):
    fn = {"fm": fm_forward, "dcn": dcn_forward, "bst": bst_forward,
          "bert4rec": bert4rec_forward}[cfg.kind]
    return fn(params, batch, cfg)


# -- losses ---------------------------------------------------------------------------


def ctr_loss(params, batch, cfg: RecsysConfig):
    """Binary logloss for fm/dcn/bst. batch['label'] (B,) in {0,1}."""
    logits = recsys_forward(params, batch, cfg).astype(jnp.float32)
    y = batch["label"].astype(jnp.float32)
    ll = jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    loss = jnp.mean(ll)
    auc_proxy = jnp.mean((logits > 0) == (y > 0.5))
    return loss, {"loss": loss, "acc": auc_proxy}


def masked_item_loss(params, batch, cfg: RecsysConfig):
    """BERT4Rec masked-item CE. batch = {seq, labels (B,S) int32, -1=unmasked}."""
    logits = bert4rec_forward(params, batch, cfg).astype(jnp.float32)
    labels = batch["labels"]
    valid = labels >= 0
    lab = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
    nll = jnp.where(valid, lse - gold, 0.0)
    loss = jnp.sum(nll) / jnp.maximum(jnp.sum(valid), 1)
    return loss, {"loss": loss}


def _bert4rec_hidden(params, seq, cfg: RecsysConfig):
    x = embedding_lookup(params["item_emb"], seq)
    x = x + params["pos_emb"][None]
    for i in range(cfg.n_blocks):
        x = _tx_block(params[f"b{i}"], x, cfg.n_heads)
    return x                                             # (B,S,D)


def masked_item_loss_sampled(params, batch, cfg: RecsysConfig):
    """Sampled-softmax masked-item loss — the production path for 10⁶-item
    vocabs (full softmax over B·S·V is petabyte-scale at train_batch=65536).

    batch = {seq (B,S), mask_pos (B,P) i32, labels (B,P) i32 (-1 pad),
             neg_ids (N,) i32} — negatives shared across the batch (uniform
    sampling; the log-uniform correction term is omitted, noted in DESIGN).
    """
    x = _bert4rec_hidden(params, batch["seq"], cfg)
    xm = jnp.take_along_axis(x, batch["mask_pos"][..., None], axis=1)  # (B,P,D)
    labels = batch["labels"]
    valid = labels >= 0
    lab = jnp.maximum(labels, 0)
    pos_emb = embedding_lookup(params["item_emb"], lab)               # (B,P,D)
    pos_b = jnp.take(params["out_b"], lab)
    neg_emb = embedding_lookup(params["item_emb"], batch["neg_ids"])  # (N,D)
    neg_b = jnp.take(params["out_b"], batch["neg_ids"])
    logit_pos = jnp.sum(xm * pos_emb, -1) + pos_b                     # (B,P)
    logit_neg = jnp.einsum("bpd,nd->bpn", xm, neg_emb) + neg_b        # (B,P,N)
    # CE of the positive against [pos ; negs]
    all_logits = jnp.concatenate([logit_pos[..., None], logit_neg], -1)
    lse = jax.nn.logsumexp(all_logits.astype(jnp.float32), axis=-1)
    nll = jnp.where(valid, lse - logit_pos.astype(jnp.float32), 0.0)
    loss = jnp.sum(nll) / jnp.maximum(jnp.sum(valid), 1)
    return loss, {"loss": loss}


def bert4rec_serve_topk(params, seq, cfg: RecsysConfig, *, k: int = 100,
                        chunk: int = 2048):
    """Next-item top-k over the full vocab, batch-chunked so the (chunk, V)
    score tile never exceeds device memory. Returns (vals, ids) (B,k)."""
    B = seq.shape[0]
    chunk = min(chunk, B)
    pad = (-B) % chunk
    if pad:
        seq = jnp.pad(seq, ((0, pad), (0, 0)), constant_values=cfg.n_items)
    seqc = seq.reshape(-1, chunk, seq.shape[1])

    def score_chunk(s):
        x = _bert4rec_hidden(params, s, cfg)[:, -1]        # (chunk, D)
        if cfg.sharded_topk:
            return _sharded_vocab_topk(x, params["item_emb"],
                                       params["out_b"], k)
        logits = x @ params["item_emb"].T + params["out_b"]
        v, i = jax.lax.top_k(logits, k)
        return v, i.astype(jnp.int32)

    n_chunks = seqc.shape[0]
    _, (vals, ids) = jax.lax.scan(
        lambda _, s: (None, score_chunk(s)), None, seqc,
        unroll=n_chunks if cfg.unroll else 1)
    return (vals.reshape(-1, k)[:B], ids.reshape(-1, k)[:B])


def _sharded_vocab_topk(x, emb, bias, k: int, *, axis: str = "model"):
    """Per-vocab-shard scoring + local top-k + k·M merge — replaces the
    full (chunk, V) logits gather GSPMD otherwise inserts before top_k.
    Requires an ambient mesh with `axis`; emb rows sharded over `axis`."""
    from jax.sharding import PartitionSpec as P

    def local(xl, el, bl):
        j = jax.lax.axis_index(axis)
        v_loc = el.shape[0]
        logits = xl @ el.T + bl                            # (chunk, V_loc)
        lv, li = jax.lax.top_k(logits, k)
        li = li + j * v_loc
        gv = jax.lax.all_gather(lv, axis, axis=-1, tiled=True)
        gi = jax.lax.all_gather(li, axis, axis=-1, tiled=True)
        mv, mi = jax.lax.top_k(gv, k)
        return mv, jnp.take_along_axis(gi, mi, axis=-1).astype(jnp.int32)

    from repro.parallel import compat
    return compat.shard_map(local, None,
                            in_specs=(P(), P(axis, None), P(axis)),
                            out_specs=(P(), P()))(x, emb, bias)


def recsys_loss(params, batch, cfg: RecsysConfig):
    if cfg.kind == "bert4rec":
        if "mask_pos" in batch:
            return masked_item_loss_sampled(params, batch, cfg)
        return masked_item_loss(params, batch, cfg)
    return ctr_loss(params, batch, cfg)


# -- retrieval tower ------------------------------------------------------------------


def user_vector(params, batch, cfg: RecsysConfig) -> jax.Array:
    """User-side tower → (B, D) for candidate dot-scoring."""
    if cfg.kind == "fm":
        ids = _flat_ids(cfg, batch["sparse"])
        return jnp.sum(embedding_lookup(params["emb"], ids), axis=1)
    if cfg.kind == "dcn":
        ids = _flat_ids(cfg, batch["sparse"])
        v = embedding_lookup(params["emb"], ids)
        return jnp.mean(v, axis=1)
    if cfg.kind == "bst":
        x = embedding_lookup(params["item_emb"], batch["seq"])
        x = x + params["pos_emb"][None, : x.shape[1]]
        for i in range(cfg.n_blocks):
            x = _tx_block(params[f"b{i}"], x, cfg.n_heads)
        return jnp.mean(x, axis=1)
    if cfg.kind == "bert4rec":
        x = embedding_lookup(params["item_emb"], batch["seq"])
        x = x + params["pos_emb"][None]
        for i in range(cfg.n_blocks):
            x = _tx_block(params[f"b{i}"], x, cfg.n_heads)
        return x[:, -1]                                   # last position
    raise ValueError(cfg.kind)


def retrieval_topk(params, batch, cfg: RecsysConfig, cand: jax.Array,
                   k: int = 100, *, use_kernel: bool = False):
    """Score 1 query (batch of 1) against cand (N,D) → top-k (vals, ids)."""
    u = user_vector(params, batch, cfg)[0]                # (D,)
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.dot_topk(u, cand, k)
    scores = cand.astype(jnp.float32) @ u.astype(jnp.float32)
    v, i = jax.lax.top_k(scores, k)
    return v, i.astype(jnp.int32)
