"""Model substrate: parameter definitions with logical sharding axes.

Every model declares its parameters once as a pytree of :class:`ParamDef`
(shape + logical axes + initializer). From that single declaration we derive:

* ``init_params``   — materialized arrays (CPU smoke tests, real training),
* ``abstract_params`` — ShapeDtypeStructs (dry-run lowering, no allocation),
* ``param_specs``   — PartitionSpecs via the logical→mesh rules in
  :mod:`repro.parallel.sharding`.

Logical axis names used across the zoo:
    "embed"   d_model-sized dims            (replicated; MLP-partner dims shard)
    "vocab"   vocabulary/output rows        → model
    "heads"   attention-head dims           → model
    "mlp"     FFN hidden dims               → model
    "experts" MoE expert axis               → model (EP)
    "rows"    huge embedding-table rows     → model (row-sharded tables)
    "layers"  scan-stacked layer axis       (never sharded)
    None      replicated dim
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"          # normal | zeros | ones | embed
    scale: float | None = None    # None → 1/sqrt(fan_in)
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_param_def(x) -> bool:
    return isinstance(x, ParamDef)


def _tree_map_defs(fn, defs):
    return jax.tree_util.tree_map(fn, defs, is_leaf=is_param_def)


def abstract_params(defs) -> Any:
    """ShapeDtypeStruct tree for .lower() — zero allocation."""
    return _tree_map_defs(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs)


def param_axes(defs) -> Any:
    return _tree_map_defs(lambda d: d.axes, defs)


def init_params(defs, key: jax.Array) -> Any:
    leaves, treedef = jax.tree_util.tree_flatten(defs, is_leaf=is_param_def)
    keys = jax.random.split(key, len(leaves))
    out = []
    for d, k in zip(leaves, keys):
        if d.init == "zeros":
            out.append(jnp.zeros(d.shape, d.dtype))
        elif d.init == "ones":
            out.append(jnp.ones(d.shape, d.dtype))
        else:
            fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
            scale = d.scale if d.scale is not None else 1.0 / math.sqrt(fan_in)
            if d.init == "embed":
                scale = d.scale if d.scale is not None else 0.02
            out.append((jax.random.normal(k, d.shape) * scale).astype(d.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def count_params(defs) -> int:
    leaves = jax.tree_util.tree_leaves(defs, is_leaf=is_param_def)
    return sum(int(np.prod(d.shape)) for d in leaves)


# -- building blocks (pure fns over param dicts) ---------------------------------


def rms_norm(x, gamma, *, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma


def layer_norm(x, gamma, beta, *, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * gamma + beta


def dense(x, w, b=None):
    y = x @ w
    if b is not None:
        y = y + b
    return y


def gelu_mlp_defs(d_model: int, d_ff: int, dtype) -> dict:
    return {
        "wi": ParamDef((d_model, d_ff), ("embed", "mlp"), dtype=dtype),
        "bi": ParamDef((d_ff,), ("mlp",), init="zeros", dtype=dtype),
        "wo": ParamDef((d_ff, d_model), ("mlp", "embed"), dtype=dtype),
        "bo": ParamDef((d_model,), ("embed",), init="zeros", dtype=dtype),
    }


def gelu_mlp(p, x):
    return dense(jax.nn.gelu(dense(x, p["wi"], p["bi"])), p["wo"], p["bo"])


def swiglu_mlp_defs(d_model: int, d_ff: int, dtype) -> dict:
    return {
        "wg": ParamDef((d_model, d_ff), ("embed", "mlp"), dtype=dtype),
        "wi": ParamDef((d_model, d_ff), ("embed", "mlp"), dtype=dtype),
        "wo": ParamDef((d_ff, d_model), ("mlp", "embed"), dtype=dtype),
    }


def swiglu_mlp(p, x):
    return dense(jax.nn.silu(dense(x, p["wg"])) * dense(x, p["wi"]), p["wo"])


def mlp_stack_defs(dims: tuple[int, ...], dtype, *, final_axis: str | None = None) -> dict:
    """Plain ReLU MLP tower (recsys/GNN). dims = (in, h1, ..., out)."""
    out = {}
    for i in range(len(dims) - 1):
        ax_in = "embed" if i == 0 else None
        ax_out = final_axis if i == len(dims) - 2 else None
        out[f"w{i}"] = ParamDef((dims[i], dims[i + 1]), (ax_in, ax_out), dtype=dtype)
        out[f"b{i}"] = ParamDef((dims[i + 1],), (ax_out,), init="zeros", dtype=dtype)
    return out


def mlp_stack(p, x, *, act=jax.nn.relu, final_act: bool = False):
    n = len([k for k in p if k.startswith("w")])
    for i in range(n):
        x = dense(x, p[f"w{i}"], p[f"b{i}"])
        if i < n - 1 or final_act:
            x = act(x)
    return x
