"""Decoder-only LM transformer: GQA / RoPE / SWA / MoE / MLA, scanned layers.

One definition covers all five assigned LM architectures:

* dense GQA (starcoder2, stablelm, h2o-danube) — `moe=None, mla=None`
* MoE (olmoe: 64e top-8)                       — `moe=MoEConfig(...)`
* MLA + MoE (deepseek-v2: kv_lora 512, 160e top-6 + 2 shared) — `mla=...`

Layers are `lax.scan`-stacked (small HLO, remat-friendly — mandatory for
512-device dry-run compiles on a CPU host). Three entry points:

* ``lm_loss``       — causal-LM cross entropy (the train_step body)
* ``lm_prefill``    — full-sequence forward → (last-token logits, kv cache)
* ``lm_decode``     — one token against a cache → (logits, updated cache)

KV caches: GQA caches (L,B,Hkv,S,Dh) k/v pairs; MLA caches the *latent*
(L,B,S,kv_lora) + shared rope key (L,B,S,rope_dim) — the compressed-KV point
of DeepSeek-V2 — and decode uses the weight-absorption trick (w_kv_b folded
into the query / output projections) so the latent is never re-expanded.
Sliding-window models may use a ring-buffer cache of `window` slots
(sub-linear memory — what makes `long_500k` servable).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.attention import attention
from repro.models.common import (ParamDef, dense, rms_norm, swiglu_mlp,
                                 swiglu_mlp_defs)
from repro.models.moe import MoEConfig, moe_defs, moe_ffn
from repro.models.rope import apply_rope


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention dims."""

    q_lora: int = 1536
    kv_lora: int = 512
    rope_dim: int = 64
    nope_dim: int = 128
    v_dim: int = 128


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None          # default d_model // n_heads
    rope_theta: float = 10000.0
    rope_pct: float = 1.0                # partial rotary (stablelm: 0.25)
    ffn_act: str = "swiglu"              # "swiglu" | "gelu" (starcoder2)
    window: int | None = None            # sliding-window attention (tokens)
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    dtype: Any = jnp.bfloat16
    remat: bool = True
    attn_block_q: int = 512
    moe_impl: str = "gspmd"              # "gspmd" | "ep" (shard_map EP)
    ep_batch_axes: tuple = ("data",)     # mesh batch axes for the EP path
    aux_loss_weight: float = 0.01
    unroll: bool = False                 # unroll scans (dry-run cost analysis)
    remat_policy: str = "nothing_saveable"   # | "dots_saveable" | "none"
    shard_kv_proj: bool = True           # False: replicate k/v projections
                                         # (GQA with Hkv < mesh: avoids the
                                         # per-layer kv reshard collective)

    @property
    def dh(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def qk_dim(self) -> int:
        return (self.mla.nope_dim + self.mla.rope_dim) if self.mla else self.dh

    def param_count(self) -> int:
        from repro.models.common import count_params
        return count_params(lm_param_defs(self))

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        cfg = self.moe
        per_expert = 3 * cfg.d_model * cfg.d_ff
        inactive = (cfg.n_experts - cfg.top_k) * per_expert * self.n_layers
        return self.param_count() - inactive


# -- parameters ----------------------------------------------------------------


def _attn_defs(cfg: LMConfig) -> dict:
    d, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.dh
    dt = cfg.dtype
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "wq_a": ParamDef((d, m.q_lora), ("embed", None), dtype=dt),
            "q_norm": ParamDef((m.q_lora,), (None,), init="ones", dtype=dt),
            "wq_b": ParamDef((m.q_lora, H * (m.nope_dim + m.rope_dim)),
                             (None, "heads"), dtype=dt),
            "wkv_a": ParamDef((d, m.kv_lora + m.rope_dim), ("embed", None), dtype=dt),
            "kv_norm": ParamDef((m.kv_lora,), (None,), init="ones", dtype=dt),
            "wkv_b": ParamDef((m.kv_lora, H * (m.nope_dim + m.v_dim)),
                              (None, "heads"), dtype=dt),
            "wo": ParamDef((H * m.v_dim, d), ("heads", "embed"), dtype=dt),
        }
    kv_ax = "heads" if cfg.shard_kv_proj else None
    return {
        "wq": ParamDef((d, H * Dh), ("embed", "heads"), dtype=dt),
        "wk": ParamDef((d, Hkv * Dh), ("embed", kv_ax), dtype=dt),
        "wv": ParamDef((d, Hkv * Dh), ("embed", kv_ax), dtype=dt),
        "wo": ParamDef((H * Dh, d), ("heads", "embed"), dtype=dt),
    }


def _layer_defs(cfg: LMConfig) -> dict:
    d, dt = cfg.d_model, cfg.dtype
    if cfg.moe is not None:
        ffn = moe_defs(cfg.moe, dt)
    elif cfg.ffn_act == "gelu":
        from repro.models.common import gelu_mlp_defs
        ffn = gelu_mlp_defs(d, cfg.d_ff, dt)
    else:
        ffn = swiglu_mlp_defs(d, cfg.d_ff, dt)
    return {
        "ln1": ParamDef((d,), ("embed",), init="ones", dtype=dt),
        "attn": _attn_defs(cfg),
        "ln2": ParamDef((d,), ("embed",), init="ones", dtype=dt),
        "ffn": ffn,
    }


def _stack_defs(defs: Any, n: int) -> Any:
    """Prepend a scanned 'layers' axis to every ParamDef."""
    return jax.tree_util.tree_map(
        lambda p: ParamDef((n,) + p.shape, ("layers",) + p.axes,
                           init=p.init, scale=p.scale, dtype=p.dtype),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


def lm_param_defs(cfg: LMConfig) -> dict:
    d, dt = cfg.d_model, cfg.dtype
    return {
        "embed": ParamDef((cfg.vocab, d), ("vocab", "embed"), init="embed", dtype=dt),
        "layers": _stack_defs(_layer_defs(cfg), cfg.n_layers),
        "ln_f": ParamDef((d,), ("embed",), init="ones", dtype=dt),
        "unembed": ParamDef((d, cfg.vocab), ("embed", "vocab"), dtype=dt),
    }


# -- attention sublayers -----------------------------------------------------------


def _gqa_attn(p, x, cfg: LMConfig, positions, *, kv_len=None, cache_kv=None):
    """GQA attention. Returns (out, (k_new, v_new)) — new kv for caching.

    cache_kv: (k (B,Hkv,S,Dh), v) from a cache; new token's k/v attend
    against cache (decode path). Without cache: self-attention over x.
    """
    B, S, d = x.shape
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
    q = dense(x, p["wq"]).reshape(B, S, H, Dh)
    k = dense(x, p["wk"]).reshape(B, S, Hkv, Dh)
    v = dense(x, p["wv"]).reshape(B, S, Hkv, Dh)
    q = _rope(q.transpose(0, 2, 1, 3).reshape(B * H, S, Dh), positions,
              cfg).reshape(B, H, S, Dh)
    k = _rope(k.transpose(0, 2, 1, 3).reshape(B * Hkv, S, Dh), positions,
              cfg).reshape(B, Hkv, S, Dh)
    v = v.transpose(0, 2, 1, 3)
    if cache_kv is None:
        o = attention(q, k, v, causal=True, window=cfg.window,
                      block_q=cfg.attn_block_q, unroll=cfg.unroll)
    else:
        ck, cv = cache_kv                                  # (B,Hkv,Sc,Dh)
        o = attention(q, ck, cv, causal=False, kv_len=kv_len,
                      block_q=cfg.attn_block_q, unroll=cfg.unroll)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, H * Dh)
    return dense(o, p["wo"]), (k, v)


def _mla_qkv(p, x, cfg: LMConfig, positions):
    """MLA projections. Returns (q_nope, q_rope, c_kv, k_rope).

    q_nope (B,H,S,nope), q_rope (B,H,S,rope), c_kv (B,S,kv_lora) latent,
    k_rope (B,S,rope) shared-across-heads rope key.
    """
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    cq = rms_norm(dense(x, p["wq_a"]), p["q_norm"])
    q = dense(cq, p["wq_b"]).reshape(B, S, H, m.nope_dim + m.rope_dim)
    q = q.transpose(0, 2, 1, 3)                            # (B,H,S,*)
    q_nope, q_rope = q[..., :m.nope_dim], q[..., m.nope_dim:]
    q_rope = apply_rope(q_rope.reshape(B * H, S, m.rope_dim), positions,
                        theta=cfg.rope_theta).reshape(B, H, S, m.rope_dim)

    ckv = dense(x, p["wkv_a"])                             # (B,S,kv_lora+rope)
    c_kv = rms_norm(ckv[..., :m.kv_lora], p["kv_norm"])
    k_rope = apply_rope(ckv[..., m.kv_lora:], positions, theta=cfg.rope_theta)
    return q_nope, q_rope, c_kv, k_rope


def _mla_attn_full(p, x, cfg: LMConfig, positions):
    """MLA self-attention (training/prefill): expand latent per head."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, x, cfg, positions)
    kv = dense(c_kv, p["wkv_b"]).reshape(B, S, H, m.nope_dim + m.v_dim)
    kv = kv.transpose(0, 2, 1, 3)
    k_nope, v = kv[..., :m.nope_dim], kv[..., m.nope_dim:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, None], (B, H, S, m.rope_dim))], -1)
    q = jnp.concatenate([q_nope, q_rope], -1)
    scale = 1.0 / math.sqrt(m.nope_dim + m.rope_dim)
    o = attention(q, k, v, causal=True, sm_scale=scale,
                  block_q=cfg.attn_block_q, unroll=cfg.unroll)  # (B,H,S,v_dim)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, H * m.v_dim)
    return dense(o, p["wo"]), (c_kv, k_rope)


def _mla_attn_core(p, q_nope, q_rope, cache, kv_len, cfg: LMConfig):
    """MLA decode attention with weight absorption: the latent cache is
    attended *directly* — w_kv_b's k-half folds into q, its v-half into the
    output — so per-step FLOPs/bytes scale with kv_lora, not H·Dh
    (DeepSeek-V2 §2.1). Returns the attention output (B,S,H·v_dim)@wo."""
    m = cfg.mla
    B, H, S, _ = q_nope.shape                              # S == 1
    c_cache, r_cache = cache                               # (B,Sc,kv_lora),(B,Sc,rope)

    wkv_b = p["wkv_b"].reshape(m.kv_lora, H, m.nope_dim + m.v_dim)
    wk = wkv_b[..., :m.nope_dim]                           # (kv_lora,H,nope)
    wv = wkv_b[..., m.nope_dim:]                           # (kv_lora,H,v)

    # absorb: q_lat = q_nope @ wk^T  → (B,H,S,kv_lora)
    q_lat = jnp.einsum("bhsn,lhn->bhsl", q_nope, wk)
    scale = 1.0 / math.sqrt(m.nope_dim + m.rope_dim)
    s_lat = jnp.einsum("bhsl,bcl->bhsc", q_lat.astype(jnp.float32),
                       c_cache.astype(jnp.float32))
    s_rope = jnp.einsum("bhsr,bcr->bhsc", q_rope.astype(jnp.float32),
                        r_cache.astype(jnp.float32))
    s = (s_lat + s_rope) * scale                           # (B,H,S,Sc)
    Sc = c_cache.shape[1]
    mask = jnp.arange(Sc)[None, None, None, :] < kv_len
    s = jnp.where(mask, s, -jnp.inf)
    pr = jax.nn.softmax(s, axis=-1)
    pr = jnp.where(jnp.isnan(pr), 0.0, pr)
    o_lat = jnp.einsum("bhsc,bcl->bhsl", pr, c_cache.astype(jnp.float32))
    o = jnp.einsum("bhsl,lhv->bhsv", o_lat.astype(q_nope.dtype), wv)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, H * m.v_dim)
    return dense(o, p["wo"])


def _mla_attn_decode(p, x, cfg: LMConfig, positions, cache, kv_len):
    """Convenience: project one token then attend against the latent cache."""
    q_nope, q_rope, c_kv_new, k_rope_new = _mla_qkv(p, x, cfg, positions)
    o = _mla_attn_core(p, q_nope, q_rope, cache, kv_len, cfg)
    return o, (c_kv_new, k_rope_new)


# -- layer body / scan ----------------------------------------------------------


def _ffn(p, x, cfg: LMConfig):
    if cfg.moe is not None:
        if cfg.moe_impl == "ep":
            from repro.models.moe_ep import ep_moe_ffn
            return ep_moe_ffn(p, x, cfg.moe,
                              batch_axes=tuple(cfg.ep_batch_axes))
        return moe_ffn(p, x, cfg.moe)
    if cfg.ffn_act == "gelu":
        from repro.models.common import gelu_mlp
        return gelu_mlp(p, x), jnp.float32(0.0)
    return swiglu_mlp(p, x), jnp.float32(0.0)


def _rope(x, positions, cfg: LMConfig):
    """RoPE over the first rope_pct fraction of the head dim (partial
    rotary, stablelm-style); pass-through tail dims."""
    D = x.shape[-1]
    rd = int(D * cfg.rope_pct)
    rd -= rd % 2
    if rd == D:
        return apply_rope(x, positions, theta=cfg.rope_theta)
    head = apply_rope(x[..., :rd], positions, theta=cfg.rope_theta)
    return jnp.concatenate([head, x[..., rd:]], axis=-1)


def _layer(p, x, cfg: LMConfig, positions, *, decode_cache=None, kv_len=None):
    """Pre-norm block. Returns (x, aux, cache_entry)."""
    h = rms_norm(x, p["ln1"])
    if cfg.mla is not None:
        if decode_cache is not None:
            a, entry = _mla_attn_decode(p["attn"], h, cfg, positions,
                                        decode_cache, kv_len)
        else:
            a, entry = _mla_attn_full(p["attn"], h, cfg, positions)
    else:
        a, entry = _gqa_attn(p["attn"], h, cfg, positions,
                             kv_len=kv_len, cache_kv=decode_cache)
    x = x + a
    h = rms_norm(x, p["ln2"])
    f, aux = _ffn(p["ffn"], h, cfg)
    return x + f, aux, entry


def _maybe_remat(body, cfg):
    if not cfg.remat or cfg.remat_policy == "none":
        return body
    policy = {
        "nothing_saveable": jax.checkpoint_policies.nothing_saveable,
        "dots_saveable": jax.checkpoint_policies.dots_saveable,
        "dots_with_no_batch_dims_saveable":
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    }[cfg.remat_policy]
    return jax.checkpoint(body, policy=policy)


def lm_forward(params, tokens, cfg: LMConfig, *, positions=None):
    """tokens (B,S) int32 → (logits (B,S,V), aux scalar)."""
    B, S = tokens.shape
    x = params["embed"][tokens]                           # (B,S,d)
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)

    def body(x, lp):
        y, aux, _ = _layer(lp, x, cfg, positions)
        return y, aux

    body = _maybe_remat(body, cfg)
    x, auxes = jax.lax.scan(body, x, params["layers"],
                            unroll=cfg.n_layers if cfg.unroll else 1)
    x = rms_norm(x, params["ln_f"])
    logits = dense(x, params["unembed"])
    return logits, jnp.sum(auxes)


def lm_loss(params, batch, cfg: LMConfig):
    """batch = {tokens (B,S), labels (B,S) int32, -1 = ignore}."""
    logits, aux = lm_forward(params, batch["tokens"], cfg)
    labels = batch["labels"]
    valid = labels >= 0
    lab = jnp.maximum(labels, 0)
    logits32 = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits32, axis=-1)
    gold = jnp.take_along_axis(logits32, lab[..., None], axis=-1)[..., 0]
    nll = jnp.where(valid, lse - gold, 0.0)
    n = jnp.maximum(jnp.sum(valid), 1)
    loss = jnp.sum(nll) / n
    total = loss + cfg.aux_loss_weight * aux
    return total, {"loss": loss, "aux": aux,
                   "ppl": jnp.exp(jnp.minimum(loss, 20.0))}


# -- serving: prefill + decode ------------------------------------------------------


def make_cache(cfg: LMConfig, batch: int, max_len: int) -> dict:
    """Abstract/zero cache pytree. GQA: k/v (L,B,Hkv,S,Dh); MLA: latent."""
    L = cfg.n_layers
    S = min(max_len, cfg.window) if cfg.window is not None else max_len
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "ckv": jnp.zeros((L, batch, S, m.kv_lora), cfg.dtype),
            "krope": jnp.zeros((L, batch, S, m.rope_dim), cfg.dtype),
        }
    return {
        "k": jnp.zeros((L, batch, cfg.n_kv_heads, S, cfg.dh), cfg.dtype),
        "v": jnp.zeros((L, batch, cfg.n_kv_heads, S, cfg.dh), cfg.dtype),
    }


def cache_spec(cfg: LMConfig, batch: int, max_len: int):
    return jax.eval_shape(lambda: make_cache(cfg, batch, max_len))


def _cache_slots(cfg: LMConfig, max_len: int) -> int:
    return min(max_len, cfg.window) if cfg.window is not None else max_len


def lm_prefill(params, tokens, cfg: LMConfig, *, max_len: int):
    """tokens (B,S) → (last-token logits (B,V), cache filled to S)."""
    B, S = tokens.shape
    slots = _cache_slots(cfg, max_len)
    positions = jnp.arange(S, dtype=jnp.int32)
    x = params["embed"][tokens]

    def body(x, lp):
        y, _, entry = _layer(lp, x, cfg, positions)
        return y, entry

    body = _maybe_remat(body, cfg)
    x, entries = jax.lax.scan(body, x, params["layers"],
                              unroll=cfg.n_layers if cfg.unroll else 1)
    x = rms_norm(x[:, -1:], params["ln_f"])
    logits = dense(x, params["unembed"])[:, 0]            # (B,V)

    # Lay entries into the cache, ring-truncated to the last `slots` tokens.
    # Ring invariant shared with lm_decode: position p lives at slot
    # p % slots — for the kept positions [S-take, S) that is a circular
    # roll by (S - take) % slots. (take == slots whenever the roll is
    # nonzero, so padding and rolling never interact.)
    take = min(S, slots)
    shift = (S - take) % slots
    if cfg.mla is not None:
        ckv, krope = entries                              # (L,B,S,*)
        cache = {
            "ckv": _ring(_fit(ckv[:, :, S - take:], slots, axis=2), shift, 2),
            "krope": _ring(_fit(krope[:, :, S - take:], slots, axis=2),
                           shift, 2),
        }
    else:
        k, v = entries                                    # (L,B,Hkv,S,Dh)
        cache = {
            "k": _ring(_fit(k[:, :, :, S - take:], slots, axis=3), shift, 3),
            "v": _ring(_fit(v[:, :, :, S - take:], slots, axis=3), shift, 3),
        }
    return logits, cache


def _ring(x, shift: int, axis: int) -> jax.Array:
    return jnp.roll(x, shift, axis=axis) if shift else x


def _fit(x, slots: int, *, axis: int) -> jax.Array:
    """Pad (or keep) x so the cache axis has exactly `slots` entries."""
    cur = x.shape[axis]
    if cur == slots:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, slots - cur)
    return jnp.pad(x, pad)


def lm_decode(params, cache, token, pos, cfg: LMConfig):
    """One decode step.

    token (B,1) int32; pos () int32 — absolute position of `token`.
    Returns (logits (B,V), updated cache). Ring-buffer caches (SWA) wrap
    writes mod window; attention masks to min(pos+1, slots) valid entries.
    """
    x = params["embed"][token]                            # (B,1,d)
    positions = pos[None].astype(jnp.int32)
    if cfg.mla is not None:
        slots = cache["ckv"].shape[2]
    else:
        slots = cache["k"].shape[3]
    slot = (pos % slots).astype(jnp.int32)
    kv_len = jnp.minimum(pos + 1, slots).astype(jnp.int32)

    # Each layer writes its token's k/v (or latent) into its cache slot
    # *before* attending, so the query sees itself; kv_len includes the slot.
    if cfg.mla is not None:
        xs = (params["layers"], cache["ckv"], cache["krope"])

        def body(x, layer_in):
            lp, ckv_l, kr_l = layer_in
            h = rms_norm(x, lp["ln1"])
            q_nope, q_rope, c_new, r_new = _mla_qkv(lp["attn"], h, cfg, positions)
            ckv_l = jax.lax.dynamic_update_slice(
                ckv_l, c_new.astype(ckv_l.dtype), (0, slot, 0))
            kr_l = jax.lax.dynamic_update_slice(
                kr_l, r_new.astype(kr_l.dtype), (0, slot, 0))
            a = _mla_attn_core(lp["attn"], q_nope, q_rope, (ckv_l, kr_l),
                               kv_len, cfg)
            x = x + a
            h2 = rms_norm(x, lp["ln2"])
            f, _ = _ffn(lp["ffn"], h2, cfg)
            return x + f, (ckv_l, kr_l)
    else:
        xs = (params["layers"], cache["k"], cache["v"])

        def body(x, layer_in):
            lp, k_l, v_l = layer_in
            h = rms_norm(x, lp["ln1"])
            a, (k_new, v_new) = _gqa_attn_decode_write(
                lp["attn"], h, cfg, positions, k_l, v_l, slot, kv_len)
            x = x + a
            h2 = rms_norm(x, lp["ln2"])
            f, _ = _ffn(lp["ffn"], h2, cfg)
            return x + f, (k_new, v_new)

    x, new_entries = jax.lax.scan(body, x, xs,
                                  unroll=cfg.n_layers if cfg.unroll else 1)
    x = rms_norm(x, params["ln_f"])
    logits = dense(x, params["unembed"])[:, 0]

    if cfg.mla is not None:
        new_cache = {"ckv": new_entries[0], "krope": new_entries[1]}
    else:
        new_cache = {"k": new_entries[0], "v": new_entries[1]}
    return logits, new_cache


def _gqa_attn_decode_write(p, x, cfg: LMConfig, positions, k_cache, v_cache,
                           slot, kv_len):
    """Project one token's q/k/v, write k/v into the cache slot, attend."""
    B, S, d = x.shape                                     # S == 1
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
    q = dense(x, p["wq"]).reshape(B, S, H, Dh)
    k = dense(x, p["wk"]).reshape(B, S, Hkv, Dh)
    v = dense(x, p["wv"]).reshape(B, S, Hkv, Dh)
    q = _rope(q.transpose(0, 2, 1, 3).reshape(B * H, S, Dh), positions,
              cfg).reshape(B, H, S, Dh)
    k = _rope(k.transpose(0, 2, 1, 3).reshape(B * Hkv, S, Dh), positions,
              cfg).reshape(B, Hkv, S, Dh)
    v = v.transpose(0, 2, 1, 3)
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k.astype(k_cache.dtype), (0, 0, slot, 0))
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v.astype(v_cache.dtype), (0, 0, slot, 0))
    o = attention(q, k_cache, v_cache, causal=False, kv_len=kv_len,
                  block_q=cfg.attn_block_q, unroll=cfg.unroll)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, H * Dh)
    return dense(o, p["wo"]), (k_cache, v_cache)
