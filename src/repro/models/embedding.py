"""Embedding lookup / EmbeddingBag for huge row-sharded tables.

JAX has no native EmbeddingBag and no CSR sparse — the lookup is built here
from ``jnp.take`` + ``jax.ops.segment_sum`` (the assignment's explicit
requirement). Two execution paths:

* ``embedding_lookup`` — plain ``jnp.take``; under pjit with the table
  row-sharded (rows → model axis), GSPMD partitions the gather into
  clamp + masked local gather + all-reduce. Baseline path.
* ``sharded_lookup_shardmap`` — the same mod-sharding written explicitly
  with shard_map + psum, used when we want to control the collective
  (perf iterations) and to test GSPMD against a hand-written reference.
* ``embedding_bag`` — gather + weighted segment-sum over ragged bags
  (offsets form), mirroring torch.nn.EmbeddingBag("sum").

This is also where the paper's state/compute split bites for recsys: the
tables are the "index in S3" — hydrated into device HBM by the serving
runtime, row-partitioned exactly like the paper's §3 document partitions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel import compat


def embedding_lookup(table: jax.Array, idx: jax.Array) -> jax.Array:
    """table (R,D), idx (...,) int32 in [0,R) → (..., D)."""
    return jnp.take(table, idx, axis=0)


def sharded_lookup_local(table_shard, idx, axis_name: str = "model"):
    """Inside shard_map: each shard owns rows [lo, lo+R_local); masked local
    gather + psum reconstructs the full lookup."""
    R_local = table_shard.shape[0]
    shard = jax.lax.axis_index(axis_name)
    local = idx - shard * R_local
    ok = (local >= 0) & (local < R_local)
    safe = jnp.clip(local, 0, R_local - 1)
    vals = jnp.where(ok[..., None], jnp.take(table_shard, safe, axis=0), 0.0)
    return jax.lax.psum(vals, axis_name)


def sharded_lookup_shardmap(mesh, table, idx, *, axis_name: str = "model",
                            batch_axis: str | None = "data"):
    """Explicit mod-sharded lookup: table rows on `axis_name`, batch on
    `batch_axis`; output batch-sharded, feature-replicated."""
    bspec = P(batch_axis) if batch_axis else P()
    fn = compat.shard_map(
        lambda t, i: sharded_lookup_local(t, i, axis_name),
        mesh,
        in_specs=(P(axis_name, None), bspec),
        out_specs=bspec,
    )
    return fn(table, idx)


def embedding_bag(table: jax.Array, indices: jax.Array, offsets: jax.Array,
                  n_bags: int, *, weights: jax.Array | None = None,
                  mode: str = "sum") -> jax.Array:
    """torch.nn.EmbeddingBag semantics (offsets form, fixed n_bags).

    indices (L,) int32; offsets (n_bags,) int32 — bag b covers
    indices[offsets[b]:offsets[b+1]]; weights (L,) optional.
    """
    L = indices.shape[0]
    pos = jnp.arange(L, dtype=jnp.int32)
    # bag id of each index = #offsets <= pos  - 1  (searchsorted right)
    bag = jnp.searchsorted(offsets, pos, side="right") - 1
    rows = jnp.take(table, indices, axis=0)
    if weights is not None:
        rows = rows * weights[:, None]
    out = jax.ops.segment_sum(rows, bag, num_segments=n_bags)
    if mode == "mean":
        cnt = jax.ops.segment_sum(jnp.ones((L, 1), rows.dtype), bag,
                                  num_segments=n_bags)
        out = out / jnp.maximum(cnt, 1.0)
    elif mode != "sum":
        raise ValueError(mode)
    return out
