"""Model zoo: one LM transformer definition (GQA/SWA/MoE/MLA), GraphCast-
style GNN, and four recsys architectures — all declared via ParamDef trees
with logical sharding axes (repro.models.common)."""

from repro.models.common import (ParamDef, abstract_params, count_params,
                                 init_params)
from repro.models.gnn import GNNConfig
from repro.models.moe import MoEConfig
from repro.models.recsys import RecsysConfig
from repro.models.transformer import LMConfig, MLAConfig

__all__ = ["ParamDef", "abstract_params", "count_params", "init_params",
           "LMConfig", "MLAConfig", "MoEConfig", "GNNConfig", "RecsysConfig"]
