"""Attention implementations: chunked-reference (pure JAX) and Pallas.

``chunked_attention`` is the default everywhere: a q-block ``lax.scan`` with
online softmax — O(bq·Skv) peak score memory instead of O(Sq·Skv), lowers on
any backend (the dry-run path), and is numerically identical to the oracle.
On real TPU hardware, ``impl="pallas"`` dispatches to the FlashAttention
kernel in :mod:`repro.kernels.flash_attention`.

Conventions: q (B,Hq,Sq,D), k/v (B,Hkv,Skv,D), GQA via Hq % Hkv == 0;
queries occupy the LAST Sq positions of the kv axis (prefill Sq==Skv,
decode Sq==1); ``window`` = sliding-window size; ``kv_len`` masks a
partially-filled cache.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp


def _mask(qpos, kpos, *, causal, window, kv_len):
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), dtype=jnp.bool_)
    if causal:
        m &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        m &= kpos[None, :] > qpos[:, None] - window
    if kv_len is not None:
        m &= (kpos < kv_len)[None, :]
    return m


def chunked_attention(q, k, v, *, causal=False, window=None, kv_len=None,
                      sm_scale=None, block_q: int = 512, unroll: bool = False):
    """Memory-efficient attention via scan over q blocks.

    v may have a different head dim than q/k (MLA's v_dim ≠ qk_dim).
    kv_len may be a traced scalar (decode over a growing cache).
    unroll: unroll the q-block loop — REQUIRED for dry-run cost analysis
    (XLA counts a while-loop body once, not ×trip-count).
    """
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    Dv = v.shape[-1]
    G = Hq // Hkv
    scale = sm_scale if sm_scale is not None else float(D) ** -0.5
    qg = q.reshape(B, Hkv, G, Sq, D)

    bq = min(block_q, Sq)
    if Sq % bq:
        bq = Sq
    nq = Sq // bq
    kpos = jnp.arange(Skv)

    k32 = k.astype(jnp.float32)
    v32 = v.astype(jnp.float32)

    def one_block(qi):
        qb = jax.lax.dynamic_slice_in_dim(qg, qi * bq, bq, axis=3)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qb.astype(jnp.float32), k32) * scale
        qpos = qi * bq + jnp.arange(bq) + (Skv - Sq)
        m = _mask(qpos, kpos, causal=causal, window=window, kv_len=kv_len)
        s = jnp.where(m[None, None, None], s, -jnp.inf)
        mx = jnp.max(s, axis=-1, keepdims=True)
        mx_safe = jnp.where(jnp.isfinite(mx), mx, 0.0)
        p = jnp.where(m[None, None, None], jnp.exp(s - mx_safe), 0.0)
        l = jnp.sum(p, axis=-1, keepdims=True)
        o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v32)
        return jnp.where(l > 0, o / l, 0.0)

    if nq == 1:
        out = one_block(0)
    else:
        _, out = jax.lax.scan(lambda _, qi: (None, one_block(qi)), None,
                              jnp.arange(nq), unroll=nq if unroll else 1)
        out = jnp.moveaxis(out, 0, 3).reshape(B, Hkv, G, Sq, Dv)
    return out.reshape(B, Hq, Sq, Dv).astype(q.dtype)


def attention(q, k, v, *, impl: str = "chunked", **kw):
    if impl == "pallas":
        kw.pop("unroll", None)
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, **kw)
    block_q = kw.pop("block_q", 512)
    return chunked_attention(q, k, v, block_q=block_q, **kw)
