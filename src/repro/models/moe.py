"""Mixture-of-Experts FFN: top-k routing, capacity dispatch, EP-shardable.

Dispatch is the scatter-by-rank scheme (GShard/Switch semantics with token
dropping on overflow) — memory scales with tokens·topk·cf·d, never with a
(tokens, E, capacity) one-hot:

    logits → top-k (experts, weights)
    rank r of each assignment within its expert (masked cumsum)
    keep if r < capacity; scatter token index into (E, C) slot table
    gather x → (E, C, d); per-expert GEMMs; combine by scatter-add

Expert weights carry the "experts" logical axis → sharded over the `model`
mesh axis (expert parallelism); the token axis stays on `data`. XLA inserts
the all-to-all pair at the dispatch/combine boundaries.

Supports DeepSeek-style shared experts (always-on dense experts added to the
routed output) and an auxiliary load-balance loss (Switch §2.2).

``moe_ffn_dense_oracle`` computes every expert for every token (dropless) —
the small-scale correctness oracle: with ample capacity the two must agree.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.models.common import ParamDef, dense


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_model: int
    d_ff: int                      # per-expert hidden
    n_shared: int = 0              # DeepSeek shared experts
    capacity_factor: float = 1.25
    router_dtype: any = jnp.float32


def moe_defs(cfg: MoEConfig, dtype) -> dict:
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    defs = {
        "router": ParamDef((d, E), ("embed", None), dtype=jnp.float32),
        "wg": ParamDef((E, d, f), ("experts", "embed", "mlp"), dtype=dtype),
        "wi": ParamDef((E, d, f), ("experts", "embed", "mlp"), dtype=dtype),
        "wo": ParamDef((E, f, d), ("experts", "mlp", "embed"), dtype=dtype),
    }
    if cfg.n_shared:
        S = cfg.n_shared
        defs["shared_wg"] = ParamDef((S, d, f), (None, "embed", "mlp"), dtype=dtype)
        defs["shared_wi"] = ParamDef((S, d, f), (None, "embed", "mlp"), dtype=dtype)
        defs["shared_wo"] = ParamDef((S, f, d), (None, "mlp", "embed"), dtype=dtype)
    return defs


def _expert_ffn(wg, wi, wo, x):
    """x (E, C, d) → (E, C, d); SwiGLU per expert."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x, wg))
    h = h * jnp.einsum("ecd,edf->ecf", x, wi)
    return jnp.einsum("ecf,efd->ecd", h, wo)


def moe_ffn(p, x, cfg: MoEConfig, *, capacity: int | None = None):
    """x (..., d) → (y (..., d), aux_loss scalar)."""
    orig_shape = x.shape
    d = orig_shape[-1]
    xt = x.reshape(-1, d)                                   # (T, d)
    T = xt.shape[0]
    E, K = cfg.n_experts, cfg.top_k

    logits = dense(xt.astype(cfg.router_dtype),
                   p["router"].astype(cfg.router_dtype))    # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert = jax.lax.top_k(probs, K)                  # (T, K)
    gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch): E * Σ_e f_e · P_e
    me = jnp.mean(probs, axis=0)                            # (E,)
    onehot_top1 = jax.nn.one_hot(expert[:, 0], E, dtype=jnp.float32)
    ce = jnp.mean(onehot_top1, axis=0)
    aux = E * jnp.sum(me * ce)

    C = capacity if capacity is not None else max(
        1, int(math.ceil(T * K / E * cfg.capacity_factor)))

    # rank of each (token, k) assignment within its expert
    flat_e = expert.reshape(-1)                             # (T*K,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)     # (T*K, E)
    ranks = (jnp.cumsum(onehot, axis=0) - onehot)           # exclusive
    rank = jnp.take_along_axis(ranks, flat_e[:, None], axis=1)[:, 0]
    keep = rank < C

    tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    slot_e = jnp.where(keep, flat_e, 0)
    slot_c = jnp.where(keep, rank, C)                       # overflow → dump col

    # scatter token ids into the slot table; dump column sliced off
    slots = jnp.full((E, C + 1), T, dtype=jnp.int32)        # T = pad token
    slots = slots.at[slot_e, slot_c].set(jnp.where(keep, tok, T),
                                         mode="drop")
    slots = slots[:, :C]                                    # (E, C)

    xpad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], 0)
    xe = xpad[slots]                                        # (E, C, d)
    ye = _expert_ffn(p["wg"], p["wi"], p["wo"], xe)         # (E, C, d)

    # combine: weight each slot by its token's gate, scatter-add back
    gflat = jnp.where(keep, gate.reshape(-1), 0.0)          # (T*K,)
    gslot = jnp.zeros((E, C + 1), jnp.float32).at[slot_e, slot_c].set(
        gflat, mode="drop")[:, :C]
    y = jnp.zeros((T + 1, d), ye.dtype).at[slots.reshape(-1)].add(
        (ye * gslot[..., None].astype(ye.dtype)).reshape(E * C, d),
        mode="drop")[:T]

    if cfg.n_shared:
        sh = _expert_ffn(p["shared_wg"], p["shared_wi"], p["shared_wo"],
                         jnp.broadcast_to(xt[None], (cfg.n_shared, T, d)))
        y = y + jnp.sum(sh, axis=0)

    return y.reshape(orig_shape), aux


def moe_ffn_dense_oracle(p, x, cfg: MoEConfig):
    """Dropless oracle: every expert on every token, weighted by gates."""
    orig_shape = x.shape
    xt = x.reshape(-1, orig_shape[-1])
    T = xt.shape[0]
    E, K = cfg.n_experts, cfg.top_k
    logits = dense(xt.astype(cfg.router_dtype),
                   p["router"].astype(cfg.router_dtype))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert = jax.lax.top_k(probs, K)
    gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)
    w = jnp.zeros((T, E), jnp.float32).at[
        jnp.arange(T)[:, None], expert].set(gate)           # (T, E)
    ye = _expert_ffn(p["wg"], p["wi"], p["wo"],
                     jnp.broadcast_to(xt[None], (E, T, xt.shape[-1])))
    y = jnp.einsum("etd,te->td", ye, w.astype(ye.dtype))
    if cfg.n_shared:
        sh = _expert_ffn(p["shared_wg"], p["shared_wi"], p["shared_wo"],
                         jnp.broadcast_to(xt[None], (cfg.n_shared, T, xt.shape[-1])))
        y = y + jnp.sum(sh, axis=0)
    return y.reshape(orig_shape)
