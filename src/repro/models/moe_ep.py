"""Expert-parallel MoE via shard_map — the optimized dispatch path.

The baseline ``moe_ffn`` (repro.models.moe) is written globally and leaves
dispatch partitioning to GSPMD, which materializes scatter/gather collectives
it chooses itself. This module writes the distributed algorithm explicitly:

* tokens are sharded over the batch axes (``data`` [, ``pod``]) and
  replicated over ``model``;
* experts are sharded over ``model`` (E_loc = E / M per shard);
* each (data, model) device routes *its* token shard, keeps only the
  assignments that land on *its* local experts, runs the local expert FFNs
  at fixed capacity, and the routed outputs are psum'd over ``model``.

Because activations are already replicated over the model axis under TP,
no all_to_all is needed at all — dispatch/combine collapse into the one
psum TP already pays. This is the TPU-native EP mapping (contrast GPU
EP, which all_to_alls tokens between expert hosts).

Numerics match ``moe_ffn_dense_oracle`` whenever capacity is ample
(tests enforce on 1- and 4-device meshes).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import dense
from repro.models.moe import MoEConfig, _expert_ffn


def _ep_local(router, wg, wi, wo, shared, x, *, cfg: MoEConfig,
              ep_axis: str, batch_axes: tuple[str, ...]):
    """Per-device body. x (B_loc, S, d); wg/wi/wo (E_loc, ·, ·)."""
    Bl, S, d = x.shape
    xt = x.reshape(-1, d)
    T = xt.shape[0]
    E, K = cfg.n_experts, cfg.top_k
    E_loc = wg.shape[0]
    j = jax.lax.axis_index(ep_axis)

    logits = dense(xt.astype(cfg.router_dtype), router.astype(cfg.router_dtype))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert = jax.lax.top_k(probs, K)                 # (T, K)
    gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)

    # aux load-balance on GLOBAL stats (pmean over the token shards)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(expert[:, 0], E, dtype=jnp.float32), axis=0)
    for ax in batch_axes:
        me = jax.lax.pmean(me, ax)
        ce = jax.lax.pmean(ce, ax)
    aux = E * jnp.sum(me * ce)

    # local-expert dispatch: this shard owns experts [j·E_loc, (j+1)·E_loc)
    C = max(1, int(math.ceil(T * K / E * cfg.capacity_factor)))
    flat_e = expert.reshape(-1)                            # (T*K,)
    local_e = flat_e - j * E_loc
    mine = (local_e >= 0) & (local_e < E_loc)
    onehot = jnp.where(mine[:, None],
                       jax.nn.one_hot(jnp.clip(local_e, 0, E_loc - 1), E_loc,
                                      dtype=jnp.int32), 0)
    ranks = jnp.cumsum(onehot, axis=0) - onehot            # exclusive
    rank = jnp.take_along_axis(
        ranks, jnp.clip(local_e, 0, E_loc - 1)[:, None], axis=1)[:, 0]
    keep = mine & (rank < C)

    tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    slot_e = jnp.where(keep, local_e, 0)
    slot_c = jnp.where(keep, rank, C)
    slots = jnp.full((E_loc, C + 1), T, dtype=jnp.int32)
    slots = slots.at[slot_e, slot_c].set(jnp.where(keep, tok, T), mode="drop")
    slots = slots[:, :C]

    xpad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], 0)
    xe = xpad[slots]                                       # (E_loc, C, d)
    ye = _expert_ffn(wg, wi, wo, xe)

    gflat = jnp.where(keep, gate.reshape(-1), 0.0)
    gslot = jnp.zeros((E_loc, C + 1), jnp.float32).at[slot_e, slot_c].set(
        gflat, mode="drop")[:, :C]
    y = jnp.zeros((T + 1, d), ye.dtype).at[slots.reshape(-1)].add(
        (ye * gslot[..., None].astype(ye.dtype)).reshape(E_loc * C, d),
        mode="drop")[:T]

    y = jax.lax.psum(y, ep_axis)                           # combine experts

    if shared is not None:
        swg, swi, swo = shared
        sh = _expert_ffn(swg, swi, swo,
                         jnp.broadcast_to(xt[None], (swg.shape[0], T, d)))
        y = y + jnp.sum(sh, axis=0)
    return y.reshape(Bl, S, d), aux


def ep_moe_ffn(p, x, cfg: MoEConfig, *, ep_axis: str = "model",
               batch_axes: tuple[str, ...] = ("data",), mesh=None):
    """x (B, S, d) → (y, aux). Requires an ambient mesh (jax.set_mesh) whose
    axes include `ep_axis` and `batch_axes`, and E % mesh[ep_axis] == 0 —
    or pass ``mesh`` explicitly (required on JAX without ambient meshes)."""
    if x.ndim == 2:                                        # (T, d) → (T, 1, d)
        y, aux = ep_moe_ffn(p, x[:, None, :], cfg, ep_axis=ep_axis,
                            batch_axes=batch_axes, mesh=mesh)
        return y[:, 0, :], aux

    bax = batch_axes[0] if len(batch_axes) == 1 else tuple(batch_axes)
    bspec = P(bax, None, None)
    pspec_e = P(ep_axis, None, None)
    shared = None
    shared_specs = None
    if "shared_wg" in p:
        shared = (p["shared_wg"], p["shared_wi"], p["shared_wo"])
        shared_specs = (P(), P(), P())

    from repro.parallel import compat
    fn = compat.shard_map(
        functools.partial(_ep_local, cfg=cfg, ep_axis=ep_axis,
                          batch_axes=tuple(batch_axes)),
        mesh,
        in_specs=(P(), pspec_e, pspec_e, pspec_e, shared_specs, bspec),
        out_specs=(bspec, P()),
    )
    return fn(p["router"], p["wg"], p["wi"], p["wo"], shared, x)
