"""Fault tolerance for the training loop: failure injection, checkpoint
restart, elastic rescale, and straggler accounting.

The serving side's fault tolerance lives in the FaaS runtime (instance
death → retry; hedged backup requests — repro.core.runtime). This module
covers the *training* side, which the paper's §3 batch-rebuild story feeds
(training publishes versioned assets; serving refreshes):

* ``FailureInjector`` — deterministic pseudo-random step failures
  (preemption / device loss) for tests and drills.
* ``run_with_restarts`` — the supervisor loop: run steps, on failure restore
  the latest checkpoint and continue; bounded restart budget; counts
  lost steps (the recovery-cost metric).
* ``reshard_state`` — elastic rescale: move a state pytree onto a different
  mesh (grown or shrunk data axis) via device_put with the new shardings.
  Combined with CheckpointManager.restore(shardings=...) this is
  checkpoint-free *in-flight* rescaling on a live cluster, or
  checkpoint-based rescaling across restarts.
* ``StragglerMonitor`` — flags steps ≥ k·median (tail-at-scale detection);
  the mitigation at serving level is request hedging (runtime), at training
  level the monitor drives exclusion/rescale decisions.
"""

from __future__ import annotations

import dataclasses
import random
from typing import Any, Callable

import jax
import numpy as np


class InjectedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    rate: float = 0.0               # per-step failure probability
    seed: int = 0
    fail_at: tuple[int, ...] = ()   # deterministic failure steps

    def __post_init__(self):
        self._rng = random.Random(self.seed)
        self._pending = set(self.fail_at)     # deterministic faults fire once

    def check(self, step: int) -> None:
        if step in self._pending:
            self._pending.discard(step)
            raise InjectedFailure(f"injected failure at step {step}")
        if self.rate and self._rng.random() < self.rate:
            raise InjectedFailure(f"injected failure at step {step}")


@dataclasses.dataclass
class RestartStats:
    restarts: int = 0
    steps_lost: int = 0
    steps_run: int = 0


def run_with_restarts(step_fn: Callable[[Any, int], Any], init_state: Any,
                      n_steps: int, ckpt, *,
                      injector: FailureInjector | None = None,
                      max_restarts: int = 10) -> tuple[Any, RestartStats]:
    """Supervisor: run ``state = step_fn(state, step)`` for n_steps with
    checkpoint/restart recovery. `ckpt` is a CheckpointManager."""
    stats = RestartStats()
    state = init_state
    step = 0
    while step < n_steps:
        try:
            if injector is not None:
                injector.check(step)
            state = step_fn(state, step)
            stats.steps_run += 1
            ckpt.maybe_save(step, state)
            step += 1
        except InjectedFailure:
            stats.restarts += 1
            if stats.restarts > max_restarts:
                raise
            like = jax.tree_util.tree_map(lambda x: x, state)
            try:
                state, restored_step = ckpt.restore(like)
            except Exception:
                state, restored_step = init_state, -1
            stats.steps_lost += step - (restored_step + 1)
            step = restored_step + 1
    ckpt.wait()
    return state, stats


def reshard_state(state: Any, shardings: Any) -> Any:
    """Elastic rescale: place every leaf onto the new mesh's shardings."""
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, s), state, shardings)


@dataclasses.dataclass
class StragglerMonitor:
    factor: float = 3.0
    window: int = 50
    warmup: int = 5      # min samples before flagging (floored at 2: the
                         # first step's median is ITSELF, so any factor < 1
                         # would flag a run's very first step)

    def __post_init__(self):
        self._times: list[float] = []
        self.flagged: list[int] = []

    def record(self, step: int, seconds: float) -> bool:
        self._times.append(seconds)
        if len(self._times) > self.window:
            self._times.pop(0)
        med = float(np.median(self._times))
        # warmup is clamped into [2, window]: a window smaller than the
        # warmup must still be able to flag once it is full
        need = max(2, min(self.warmup, self.window))
        slow = len(self._times) >= need and seconds > self.factor * med
        if slow:
            self.flagged.append(step)
        return slow
