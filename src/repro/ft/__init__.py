"""Fault tolerance: failure injection, checkpoint/restart supervision,
elastic resharding, straggler detection (training side; the serving side's
retry/hedging lives in repro.core.runtime)."""

from repro.ft.faults import (FailureInjector, InjectedFailure, RestartStats,
                             StragglerMonitor, reshard_state,
                             run_with_restarts)

__all__ = ["FailureInjector", "InjectedFailure", "RestartStats",
           "StragglerMonitor", "reshard_state", "run_with_restarts"]
