"""Deterministic, resumable synthetic LM token pipeline.

Batches are a pure function of (seed, step) — a restarted/rescaled trainer
regenerates the exact stream from any step, which is what makes the
checkpoint/restart tests byte-exact. The token process is a Zipf-mixture
Markov chain so a ~100M model actually has structure to learn (loss drops
well below the unigram entropy within a few hundred steps).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class LMDataConfig:
    vocab: int
    batch: int
    seq: int
    seed: int = 0
    zipf_a: float = 1.2
    n_states: int = 64          # Markov mixture states


class LMTokenStream:
    def __init__(self, cfg: LMDataConfig) -> None:
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # per-state token distributions: shifted Zipf over a state-local slice
        self._offsets = rng.integers(0, cfg.vocab, cfg.n_states)
        self._trans = rng.integers(0, cfg.n_states, (cfg.n_states, 4))

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        state = rng.integers(0, cfg.n_states, cfg.batch)
        toks = np.empty((cfg.batch, cfg.seq + 1), np.int32)
        z = rng.zipf(cfg.zipf_a, (cfg.batch, cfg.seq + 1)).astype(np.int64)
        pick = rng.integers(0, 4, (cfg.batch, cfg.seq + 1))
        for t in range(cfg.seq + 1):
            toks[:, t] = (self._offsets[state] + z[:, t]) % cfg.vocab
            state = self._trans[state, pick[:, t]]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}
