"""Synthetic graphs + the neighbor sampler for minibatch GNN training.

``NeighborSampler`` is the real host-side component the `minibatch_lg` shape
requires (fanout 15-10 over a large graph): CSR adjacency, per-seed uniform
neighbor sampling with replacement-free truncation, padded fixed-shape
subgraph output (src/dst index arrays with the dump-node convention of
repro.models.gnn).
"""

from __future__ import annotations

import dataclasses

import numpy as np


def synth_graph(n_nodes: int, avg_degree: int, d_feat: int, *, seed: int = 0):
    """Power-law-ish random graph as CSR + features + targets."""
    rng = np.random.default_rng(seed)
    n_edges = n_nodes * avg_degree
    # preferential-attachment-flavored endpoints (hub-heavy like real graphs)
    src = (rng.zipf(1.5, n_edges) % n_nodes).astype(np.int32)
    dst = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    order = np.argsort(dst, kind="stable")
    src, dst = src[order], dst[order]
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.add.at(indptr, dst + 1, 1)
    indptr = np.cumsum(indptr)
    feat = rng.normal(size=(n_nodes, d_feat)).astype(np.float32)
    return {"indptr": indptr, "neighbors": src, "feat": feat,
            "n_nodes": n_nodes}


@dataclasses.dataclass
class NeighborSampler:
    """Layer-wise uniform neighbor sampling (GraphSAGE style)."""

    graph: dict
    fanout: tuple[int, ...] = (15, 10)
    seed: int = 0

    def sample(self, seeds: np.ndarray, step: int = 0) -> dict:
        """Returns a padded subgraph:

        feat (N_pad, F), src/dst (E_pad,) with dump id N_pad for padding,
        seed_mask (N_pad,) float — 1.0 on the seed nodes (loss mask),
        n_real_nodes/int. Subgraph node 0..len(seeds)-1 == seeds.
        """
        g = self.graph
        rng = np.random.default_rng((self.seed, step))
        indptr, nbrs = g["indptr"], g["neighbors"]

        nodes = list(seeds.astype(np.int64))
        node_ix = {int(n): i for i, n in enumerate(nodes)}
        edges_src: list[int] = []
        edges_dst: list[int] = []
        frontier = list(seeds.astype(np.int64))
        for f in self.fanout:
            nxt: list[int] = []
            for u in frontier:
                lo, hi = indptr[u], indptr[u + 1]
                deg = hi - lo
                if deg == 0:
                    continue
                take = min(f, deg)
                picks = rng.choice(deg, size=take, replace=False)
                for p in picks:
                    v = int(nbrs[lo + p])
                    if v not in node_ix:
                        node_ix[v] = len(nodes)
                        nodes.append(v)
                    # message flows v -> u
                    edges_src.append(node_ix[v])
                    edges_dst.append(node_ix[int(u)])
                    nxt.append(v)
            frontier = nxt

        # pad to the static shapes of the minibatch cell:
        # N_pad = seeds + seeds·f1 + seeds·f1·f2 ...
        n_seeds = len(seeds)
        N_pad, E_pad = padded_sizes(n_seeds, self.fanout)
        n_real = len(nodes)
        feat = np.zeros((N_pad, g["feat"].shape[1]), np.float32)
        feat[:n_real] = g["feat"][np.asarray(nodes)]
        src = np.full(E_pad, N_pad, np.int32)
        dst = np.full(E_pad, N_pad, np.int32)
        src[:len(edges_src)] = edges_src
        dst[:len(edges_dst)] = edges_dst
        seed_mask = np.zeros(N_pad, np.float32)
        seed_mask[:n_seeds] = 1.0
        return {"feat": feat, "src": src, "dst": dst,
                "node_mask": seed_mask, "n_real_nodes": n_real}


def padded_sizes(n_seeds: int, fanout: tuple[int, ...]) -> tuple[int, int]:
    """Static (N_pad, E_pad) for a fanout sample rooted at n_seeds."""
    N = n_seeds
    E = 0
    layer = n_seeds
    for f in fanout:
        layer = layer * f
        N += layer
        E += layer
    return N, E


def molecule_batch(n_graphs: int, n_nodes: int, n_edges: int, d_feat: int,
                   d_out: int, *, seed: int = 0) -> dict:
    """Batched random molecular graphs (undirected edge pairs)."""
    rng = np.random.default_rng(seed)
    feat = rng.normal(size=(n_graphs, n_nodes, d_feat)).astype(np.float32)
    src = rng.integers(0, n_nodes, (n_graphs, n_edges)).astype(np.int32)
    dst = rng.integers(0, n_nodes, (n_graphs, n_edges)).astype(np.int32)
    target = rng.normal(size=(n_graphs, n_nodes, d_out)).astype(np.float32)
    mask = np.ones((n_graphs, n_nodes), np.float32)
    return {"feat": feat, "src": src, "dst": dst, "target": target,
            "node_mask": mask}
