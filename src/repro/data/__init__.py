"""Data pipelines: synthetic corpora (search), resumable LM token streams,
graphs + neighbor sampler (GNN), click/sequence streams (recsys)."""
