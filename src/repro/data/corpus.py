"""Synthetic corpora with Zipfian term statistics (MS MARCO stand-in).

No datasets ship with this container, so benchmarks/examples generate
corpora whose statistics mimic web passages: Zipf-distributed vocabulary,
log-normal document lengths, queries sampled from document terms (so every
query has matches, like MS MARCO's passage-sourced queries).
"""

from __future__ import annotations

import zlib

import numpy as np

# pronounceable fake terms: cheap bijection id -> string
_SYL = ["ba", "be", "bi", "bo", "bu", "da", "de", "di", "do", "du",
        "ka", "ke", "ki", "ko", "ku", "ma", "me", "mi", "mo", "mu",
        "na", "ne", "ni", "no", "nu", "ra", "re", "ri", "ro", "ru",
        "sa", "se", "si", "so", "su", "ta", "te", "ti", "to", "tu"]


def term_string(tid: int) -> str:
    s = []
    tid += 1
    while tid:
        tid, r = divmod(tid, len(_SYL))
        s.append(_SYL[r])
    return "".join(s)


def synth_corpus(n_docs: int, *, vocab: int = 5000, mean_len: int = 60,
                 seed: int = 0, zipf_a: float = 1.3) -> list[tuple[str, str]]:
    rng = np.random.default_rng(seed)
    lens = np.maximum(4, rng.lognormal(np.log(mean_len), 0.4, n_docs)).astype(int)
    docs = []
    for i in range(n_docs):
        tids = rng.zipf(zipf_a, lens[i]) % vocab
        text = " ".join(term_string(int(t)) for t in tids)
        docs.append((f"doc{i}", text))
    return docs


def synth_pruned_blocks(seed: int, *, n_terms: int, max_blocks: int,
                        n_docs: int, block: int = 128, zipf_a: float = 2.0,
                        k1: float = 0.9, b: float = 0.4, avgdl: float = 12.0):
    """Fabricate one query's gathered, IMPACT-ORDERED postings blocks —
    kernel-shaped inputs for ``bm25_pruned_topk`` without paying
    ``IndexWriter`` costs (1M-doc partitions pack in ms, not minutes).

    Reproduces exactly what ``IndexWriter.pack`` + ``gather_query_blocks``
    would hand the kernel: per term, Zipf-skewed tf postings sorted by f64
    BM25 impact descending, cut into B-lane blocks with f64-computed
    ``block_max`` (cast f32), tf pre-zeroed on invalid blocks, pad lanes
    carrying doc id ``n_docs``. Impact ordering is load-bearing — the
    pruning bound assumes block 0 holds each term's max impact.

    Returns the ``bm25_pruned_topk`` positional inputs
    (tf, dl, docs, idf_q, ub, valid) as numpy arrays.
    """
    rng = np.random.default_rng(seed)
    T, M, B = n_terms, max_blocks, block
    doc_len = rng.integers(5, 4 * int(avgdl), n_docs).astype(np.float32)
    docs = np.full((T, M, B), n_docs, np.int32)
    tf = np.zeros((T, M, B), np.uint8)
    bmax = np.zeros((T, M), np.float64)
    valid = np.zeros((T, M), bool)
    idf = rng.uniform(0.5, 3.0, T).astype(np.float32)
    qtf = rng.integers(1, 3, T).astype(np.float32)
    for t in range(T):
        n_post = int(rng.integers(B // 2, min(M * B, n_docs) + 1))
        d = rng.choice(n_docs, n_post, replace=False).astype(np.int32)
        f = np.minimum(rng.zipf(zipf_a, n_post), 255).astype(np.float64)
        dl = doc_len[d].astype(np.float64)
        imp = idf[t] * f / (f + k1 * (1.0 - b + b * dl / avgdl))
        order = np.argsort(-imp, kind="stable")
        d, f, imp = d[order], f[order], imp[order]
        for m in range(min(M, -(-n_post // B))):
            sl = slice(m * B, min((m + 1) * B, n_post))
            nn = sl.stop - sl.start
            docs[t, m, :nn] = d[sl]
            tf[t, m, :nn] = f[sl]
            bmax[t, m] = imp[sl].max(initial=0.0)
            valid[t, m] = True
    dl_g = np.concatenate([doc_len, np.ones(1, np.float32)])[
        np.minimum(docs, n_docs)]
    idf_q = (idf * qtf).astype(np.float32)
    ub = np.where(valid, qtf[:, None] * bmax, 0.0).astype(np.float32)
    tf = np.where(valid[..., None], tf, 0).astype(np.uint8)
    return tf, dl_g, docs, idf_q, ub, valid


def synth_fielded_corpus(n_docs: int, *, vocab: int = 5000,
                         mean_len: int = 60, n_facets: int = 8,
                         seed: int = 0, zipf_a: float = 1.3
                         ) -> list[tuple[str, dict]]:
    """Fielded twin of :func:`synth_corpus` for the structured (v2) tier:
    every document is ``{"title", "body", "cat"}`` — a short Zipf-sampled
    title, a :func:`synth_corpus`-shaped body, and one categorical facet
    value with Zipf-skewed popularity (realistic facet histograms: a fat
    head value, a long tail)."""
    rng = np.random.default_rng(seed)
    lens = np.maximum(4, rng.lognormal(np.log(mean_len), 0.4,
                                       n_docs)).astype(int)
    tlens = rng.integers(2, 6, n_docs)
    docs = []
    for i in range(n_docs):
        ttids = rng.zipf(zipf_a, tlens[i]) % vocab
        btids = rng.zipf(zipf_a, lens[i]) % vocab
        cat = int(rng.zipf(1.6) - 1) % n_facets
        docs.append((f"doc{i}", {
            "title": " ".join(term_string(int(t)) for t in ttids),
            "body": " ".join(term_string(int(t)) for t in btids),
            "cat": f"c{cat}",
        }))
    return docs


def synth_structured_queries(docs: list[tuple[str, dict]], n_queries: int, *,
                             seed: int = 1) -> list[str]:
    """A structured-query mix over a fielded corpus, cycling the DSL's
    clause shapes: bag-of-words, field-scoped terms, quoted phrases
    (adjacent KEPT tokens of one document's body, so the phrase is
    guaranteed to match post-analysis), field-scoped phrases, and boosted
    conjunctions. Terms are sampled from the target document itself, like
    :func:`synth_queries` — every query has matches."""
    from repro.index.tokenizer import tokenize
    rng = np.random.default_rng(seed)
    out: list[str] = []
    while len(out) < n_queries:
        _, text = docs[rng.integers(len(docs))]
        title = tokenize(text["title"])
        body = tokenize(text["body"])
        if len(body) < 3:
            continue
        i = int(rng.integers(len(body) - 1))
        a, b = body[i], body[i + 1]
        c = body[int(rng.integers(len(body)))]
        t = title[int(rng.integers(len(title)))] if title else c
        shape = len(out) % 5
        if shape == 0:                       # plain bag-of-words
            out.append(f"{a} {c}")
        elif shape == 1:                     # fielded term, disjunctive
            out.append(f"title:{t} OR {c}")
        elif shape == 2:                     # unscoped phrase
            out.append(f'"{a} {b}"')
        elif shape == 3:                     # field-scoped phrase + term
            out.append(f'body:"{a} {b}" OR {c}')
        else:                                # boosted conjunction
            out.append(f"title:{t}^2 AND {c}")
    return out


def hash_embedder(dim: int = 16):
    """Deterministic text → unit-norm f32 embedding (no model weights ship
    with the container, so the dense tier embeds with a content-hash-seeded
    Gaussian — the OpenAI-embeddings stand-in). The CRC32 seed depends only
    on the text bytes, so every process, commit, and rebuild derives the
    IDENTICAL vector for a doc — the property the delta-vs-rebuild dense
    parity tests lean on."""
    def embed(text: str) -> np.ndarray:
        rng = np.random.default_rng(zlib.crc32(text.encode("utf-8")))
        v = rng.standard_normal(dim).astype(np.float32)
        n = float(np.linalg.norm(v))
        return (v / np.float32(n)) if n else v

    embed.dim = dim
    return embed


def synth_queries(docs: list[tuple[str, str]], n_queries: int, *,
                  terms_per_query: int = 3, seed: int = 1) -> list[str]:
    rng = np.random.default_rng(seed)
    queries = []
    for _ in range(n_queries):
        _, text = docs[rng.integers(len(docs))]
        toks = text.split()
        take = min(terms_per_query, len(toks))
        picks = rng.choice(len(toks), size=take, replace=False)
        queries.append(" ".join(toks[p] for p in picks))
    return queries
