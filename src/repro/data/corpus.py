"""Synthetic corpora with Zipfian term statistics (MS MARCO stand-in).

No datasets ship with this container, so benchmarks/examples generate
corpora whose statistics mimic web passages: Zipf-distributed vocabulary,
log-normal document lengths, queries sampled from document terms (so every
query has matches, like MS MARCO's passage-sourced queries).
"""

from __future__ import annotations

import numpy as np

# pronounceable fake terms: cheap bijection id -> string
_SYL = ["ba", "be", "bi", "bo", "bu", "da", "de", "di", "do", "du",
        "ka", "ke", "ki", "ko", "ku", "ma", "me", "mi", "mo", "mu",
        "na", "ne", "ni", "no", "nu", "ra", "re", "ri", "ro", "ru",
        "sa", "se", "si", "so", "su", "ta", "te", "ti", "to", "tu"]


def term_string(tid: int) -> str:
    s = []
    tid += 1
    while tid:
        tid, r = divmod(tid, len(_SYL))
        s.append(_SYL[r])
    return "".join(s)


def synth_corpus(n_docs: int, *, vocab: int = 5000, mean_len: int = 60,
                 seed: int = 0, zipf_a: float = 1.3) -> list[tuple[str, str]]:
    rng = np.random.default_rng(seed)
    lens = np.maximum(4, rng.lognormal(np.log(mean_len), 0.4, n_docs)).astype(int)
    docs = []
    for i in range(n_docs):
        tids = rng.zipf(zipf_a, lens[i]) % vocab
        text = " ".join(term_string(int(t)) for t in tids)
        docs.append((f"doc{i}", text))
    return docs


def synth_queries(docs: list[tuple[str, str]], n_queries: int, *,
                  terms_per_query: int = 3, seed: int = 1) -> list[str]:
    rng = np.random.default_rng(seed)
    queries = []
    for _ in range(n_queries):
        _, text = docs[rng.integers(len(docs))]
        toks = text.split()
        take = min(terms_per_query, len(toks))
        picks = rng.choice(len(toks), size=take, replace=False)
        queries.append(" ".join(toks[p] for p in picks))
    return queries
