"""Synthetic click-log / sequence pipelines for the recsys archs.

Labels come from a hidden FM teacher over the same id space, so CTR training
has real signal (logloss decreases); sequences follow item-popularity Zipf
with short-range repetition like production behavior logs. Deterministic in
(seed, step) — resumable, like the LM stream.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CTRStream:
    """Batches for fm/dcn/bst: sparse ids (+dense), teacher-scored labels."""

    n_sparse: int
    rows_per_field: int
    batch: int
    n_dense: int = 0
    seq_len: int = 0            # >0 → also emit behavior sequences (bst)
    n_items: int = 0
    seed: int = 0
    teacher_dim: int = 8

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self._tv = rng.normal(size=(self.n_sparse, self.teacher_dim)) * 0.5
        self._bias = rng.normal() * 0.1

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        B = self.batch
        sparse = (rng.zipf(1.3, (B, self.n_sparse)) %
                  self.rows_per_field).astype(np.int32)
        # teacher: hash id → pseudo-embedding via sin features
        phase = (sparse[..., None] * 0.37 + np.arange(self.teacher_dim) * 1.7)
        emb = np.sin(phase) * self._tv[None]
        score = emb.sum((1, 2)) + self._bias
        label = (rng.random(B) < 1 / (1 + np.exp(-score))).astype(np.float32)
        out = {"sparse": sparse, "label": label}
        if self.n_dense:
            out["dense"] = rng.normal(size=(B, self.n_dense)).astype(np.float32)
        if self.seq_len:
            out["seq"] = (rng.zipf(1.3, (B, self.seq_len)) %
                          self.n_items).astype(np.int32)
            out["target"] = (rng.zipf(1.3, B) % self.n_items).astype(np.int32)
        return out


@dataclasses.dataclass
class SequenceStream:
    """bert4rec masked-item batches (mask_pos/labels/neg_ids form)."""

    n_items: int
    seq_len: int
    batch: int
    n_mask: int = 32
    n_neg: int = 1024
    seed: int = 0

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        B, S = self.batch, self.seq_len
        n_mask = min(self.n_mask, S)
        n_neg = min(self.n_neg, self.n_items)
        seq = (rng.zipf(1.2, (B, S)) % self.n_items).astype(np.int32)
        # short-range repetition: 20% of positions repeat an earlier item
        rep = rng.random((B, S)) < 0.2
        shift = rng.integers(1, 5, (B, S))
        idx = np.maximum(np.arange(S)[None] - shift, 0)
        seq = np.where(rep, np.take_along_axis(seq, idx, 1), seq)

        mask_pos = np.stack([rng.choice(S, n_mask, replace=False)
                             for _ in range(B)]).astype(np.int32)
        labels = np.take_along_axis(seq, mask_pos, 1).astype(np.int32)
        masked = seq.copy()
        np.put_along_axis(masked, mask_pos, self.n_items + 1, 1)  # [MASK]
        neg = (rng.zipf(1.2, n_neg) % self.n_items).astype(np.int32)
        return {"seq": masked, "mask_pos": mask_pos, "labels": labels,
                "neg_ids": neg}
