"""Pallas kernel: fused candidate scoring + streaming top-k (retrieval).

The recsys ``retrieval_cand`` shape — one query against 10⁶ candidates — is
the paper's query-evaluation problem in dense form. The fusion matters: an
unfused pipeline writes the (N,) score vector to HBM and reads it back for
top-k; fusing the matvec with the local top-k keeps each candidate chunk's
scores in VMEM, so candidate embeddings are read exactly once and *nothing*
per-candidate is ever written back (output is n_chunks·k survivors).

    chunk scores (MXU):  s = C_chunk @ q        (chunk, D) × (D,)
    local top-k  (VPU):  k rounds of max/argmax/mask
    merge (XLA):         lax.top_k over survivors
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_CHUNK = 1024


def _dot_topk_kernel(q_ref, c_ref, vals_ref, ids_ref, *, k: int, chunk: int,
                     n_valid: int):
    ci = pl.program_id(0)
    q = q_ref[...].astype(jnp.float32)                     # (1, D)
    c = c_ref[...].astype(jnp.float32)                     # (chunk, D)
    s = jax.lax.dot_general(c, q, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)[:, 0]
    base = ci * chunk
    idx = jax.lax.broadcasted_iota(jnp.int32, (chunk,), 0)
    s = jnp.where(base + idx < n_valid, s, -jnp.inf)       # mask pad rows

    def body(i, carry):
        s_cur, = carry
        m = jnp.max(s_cur)
        am = jnp.argmax(s_cur).astype(jnp.int32)
        vals_ref[i] = m
        ids_ref[i] = base + am
        return (jnp.where(idx == am, -jnp.inf, s_cur),)

    jax.lax.fori_loop(0, k, body, (s,))


@functools.partial(jax.jit, static_argnames=("k", "chunk", "interpret"))
def dot_topk(query, cands, k: int, *, chunk: int = DEFAULT_CHUNK,
             interpret: bool = True):
    """query (D,), cands (N,D) → (vals (k,), ids (k,) i32)."""
    N, D = cands.shape
    chunk = max(min(chunk, N), k)
    pad = (-N) % chunk
    if pad:
        cands = jnp.pad(cands, ((0, pad), (0, 0)))
    n_chunks = (N + pad) // chunk
    q2 = query[None, :]

    vals, ids = pl.pallas_call(
        functools.partial(_dot_topk_kernel, k=k, chunk=chunk, n_valid=N),
        grid=(n_chunks,),
        in_specs=[
            pl.BlockSpec((1, D), lambda i: (0, 0)),
            pl.BlockSpec((chunk, D), lambda i: (i, 0)),
        ],
        out_specs=[pl.BlockSpec((k,), lambda i: (i,)),
                   pl.BlockSpec((k,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((n_chunks * k,), jnp.float32),
                   jax.ShapeDtypeStruct((n_chunks * k,), jnp.int32)],
        interpret=interpret,
    )(q2, cands)

    # mask padded candidates (their score is 0·q = 0, could beat negatives)
    valid = ids < N
    vals = jnp.where(valid, vals, -jnp.inf)
    mv, mi = jax.lax.top_k(vals, k)
    return mv, ids[mi]
