"""Pallas kernel: fused candidate scoring + streaming top-k (retrieval).

The recsys ``retrieval_cand`` shape — one query against 10⁶ candidates — is
the paper's query-evaluation problem in dense form. The fusion matters: an
unfused pipeline writes the (N,) score vector to HBM and reads it back for
top-k; fusing the matvec with the local top-k keeps each candidate chunk's
scores in VMEM, so candidate embeddings are read exactly once and *nothing*
per-candidate is ever written back (output is n_chunks·k survivors).

    chunk scores (MXU):  s = C_chunk @ q        (chunk, D) × (D,)
    local top-k  (VPU):  k rounds of max/argmax/mask
    merge (XLA):         lax.top_k over survivors
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_CHUNK = 1024


def _dot_topk_kernel(q_ref, c_ref, vals_ref, ids_ref, *, k: int, chunk: int,
                     n_valid: int):
    ci = pl.program_id(0)
    q = q_ref[...].astype(jnp.float32)                     # (1, D)
    c = c_ref[...].astype(jnp.float32)                     # (chunk, D)
    s = jax.lax.dot_general(c, q, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)[:, 0]
    base = ci * chunk
    idx = jax.lax.broadcasted_iota(jnp.int32, (chunk,), 0)
    s = jnp.where(base + idx < n_valid, s, -jnp.inf)       # mask pad rows

    def body(i, carry):
        s_cur, = carry
        m = jnp.max(s_cur)
        am = jnp.argmax(s_cur).astype(jnp.int32)
        vals_ref[i] = m
        ids_ref[i] = base + am
        return (jnp.where(idx == am, -jnp.inf, s_cur),)

    jax.lax.fori_loop(0, k, body, (s,))


@functools.partial(jax.jit, static_argnames=("k", "chunk", "interpret"))
def dot_topk(query, cands, k: int, *, chunk: int = DEFAULT_CHUNK,
             interpret: bool = True):
    """query (D,), cands (N,D) → (vals (k,), ids (k,) i32).

    ``chunk`` is NEVER shrunk to N: every grid step scores a full
    (chunk, D) block (short inputs pad with masked rows), so the matvec's
    shape — and therefore its f32 accumulation bit pattern, which on CPU
    XLA depends on the row count's alignment — is canonical for any N.
    A 53-row partition and a 207-row full corpus score a shared row to
    IDENTICAL bits, which is what lets a fleet of uneven partitions be
    checked uint32-bitwise against one full-corpus reference."""
    N, D = cands.shape
    chunk = max(chunk, k)
    pad = (-N) % chunk
    if pad:
        cands = jnp.pad(cands, ((0, pad), (0, 0)))
    n_chunks = (N + pad) // chunk
    q2 = query[None, :]

    vals, ids = pl.pallas_call(
        functools.partial(_dot_topk_kernel, k=k, chunk=chunk, n_valid=N),
        grid=(n_chunks,),
        in_specs=[
            pl.BlockSpec((1, D), lambda i: (0, 0)),
            pl.BlockSpec((chunk, D), lambda i: (i, 0)),
        ],
        out_specs=[pl.BlockSpec((k,), lambda i: (i,)),
                   pl.BlockSpec((k,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((n_chunks * k,), jnp.float32),
                   jax.ShapeDtypeStruct((n_chunks * k,), jnp.int32)],
        interpret=interpret,
    )(q2, cands)

    # mask padded candidates (their score is 0·q = 0, could beat negatives)
    valid = ids < N
    vals = jnp.where(valid, vals, -jnp.inf)
    mv, mi = jax.lax.top_k(vals, k)
    return mv, ids[mi]


def dot_topk_batch(queries, cands, k: int, *, chunk: int = DEFAULT_CHUNK,
                   interpret: bool = True):
    """queries (Q, D), cands (N, D) → (vals (Q, k), ids (Q, k) i32).

    The fleet's dense micro-batch path. Q-invariant BY CONSTRUCTION: each
    query dispatches as its own single-query ``dot_topk`` executable
    (shape-cached, so all Q dispatches reuse one compiled program), never
    traced together into a batched graph. Any whole-batch program — vmap,
    ``lax.map``, an unrolled loop under one jit — lets XLA fuse across or
    around the query axis, and the (chunk, D) matvec's f32 accumulation
    bits then differ (~1 ulp) from the standalone single-query lowering,
    making a query's scores depend on how many neighbours shared its
    micro-batch window. Per-program dispatch is what lets windowed fleet
    results be checked uint32-bitwise against the one-query-at-a-time
    reference oracle."""
    if len(queries) == 0:
        return (jnp.zeros((0, k), jnp.float32),
                jnp.zeros((0, k), jnp.int32))
    out = [dot_topk(q, cands, k, chunk=chunk, interpret=interpret)
           for q in queries]
    return (jnp.stack([v for v, _ in out]),
            jnp.stack([i for _, i in out]))
