"""Backend-driven interpret-mode selection for the Pallas kernels.

Only the CPU backend has no kernel lowering path — TPU lowers through
Mosaic and GPU through Triton — so ``interpret`` defaults to
``jax.default_backend() == "cpu"`` and real accelerators actually compile
the kernels. ``REPRO_PALLAS_INTERPRET`` overrides both ways (forcing the
interpreter on device for debugging, or off to smoke-test lowering), and
every kernel keeps an explicit ``interpret=`` argument for tests.
"""

from __future__ import annotations

import os

import jax


def default_interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() == "cpu"


def resolve_interpret(interpret: "bool | None") -> bool:
    """``None`` → backend default; an explicit bool always wins.

    Called at trace time (interpret is a static arg), so the env/backend is
    read once per jit cache entry — pass an explicit value to pin it.
    """
    return default_interpret() if interpret is None else bool(interpret)
