"""Public jit'd wrappers for the Pallas kernels.

On this CPU container every kernel runs in ``interpret=True`` (the kernel
body executes as traced jnp on CPU — bit-accurate semantics, no Mosaic).
On a real TPU set ``REPRO_PALLAS_INTERPRET=0`` (or pass interpret=False) to
lower through Mosaic.
"""

from __future__ import annotations

import os

import jax

from repro.kernels.bm25_block import bm25_block_scores as _bm25
from repro.kernels.dot_topk import dot_topk as _dot_topk
from repro.kernels.embedding_bag import embedding_bag as _embedding_bag
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.topk import topk as _topk


def _interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def bm25_block_scores(tf, dl, idf, k1, b, avgdl, **kw):
    kw.setdefault("interpret", _interpret())
    return _bm25(tf, dl, idf, k1, b, avgdl, **kw)


def topk(scores, k, **kw):
    kw.setdefault("interpret", _interpret())
    return _topk(scores, k, **kw)


def dot_topk(query, cands, k, **kw):
    kw.setdefault("interpret", _interpret())
    return _dot_topk(query, cands, k, **kw)


def flash_attention(q, k, v, **kw):
    kw.setdefault("interpret", _interpret())
    return _flash(q, k, v, **kw)


def embedding_bag(table, idx, weights, **kw):
    kw.setdefault("interpret", _interpret())
    return _embedding_bag(table, idx, weights, **kw)
