"""Public jit'd wrappers for the Pallas kernels.

Interpret-mode selection lives in :mod:`repro.kernels.interpret`: CPU (the
only backend with no kernel lowering) interprets, TPU/GPU compile, and
``REPRO_PALLAS_INTERPRET`` overrides both ways. These wrappers just forward
``interpret=None`` so the kernels resolve the backend default themselves;
pass ``interpret=`` explicitly to pin a mode.
"""

from __future__ import annotations

from repro.kernels.bm25_block import bm25_block_scores as _bm25
from repro.kernels.bm25_pruned import bm25_pruned_topk as _bm25_pruned
from repro.kernels.dot_topk import dot_topk as _dot_topk
from repro.kernels.dot_topk import dot_topk_batch as _dot_topk_batch
from repro.kernels.embedding_bag import embedding_bag as _embedding_bag
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.interpret import default_interpret as _interpret  # noqa: F401  (compat)
from repro.kernels.topk import topk as _topk


def bm25_block_scores(tf, dl, idf, k1, b, avgdl, **kw):
    return _bm25(tf, dl, idf, k1, b, avgdl, **kw)


def bm25_pruned_topk(tf, dl, docs, idf_q, ub, valid, k1, b, avgdl, *,
                     k, n_docs, **kw):
    return _bm25_pruned(tf, dl, docs, idf_q, ub, valid, k1, b, avgdl,
                        k=k, n_docs=n_docs, **kw)


def topk(scores, k, **kw):
    return _topk(scores, k, **kw)


def dot_topk(query, cands, k, **kw):
    kw.setdefault("interpret", _interpret())
    return _dot_topk(query, cands, k, **kw)


def dot_topk_batch(queries, cands, k, **kw):
    kw.setdefault("interpret", _interpret())
    return _dot_topk_batch(queries, cands, k, **kw)


def flash_attention(q, k, v, **kw):
    kw.setdefault("interpret", _interpret())
    return _flash(q, k, v, **kw)


def embedding_bag(table, idx, weights, **kw):
    kw.setdefault("interpret", _interpret())
    return _embedding_bag(table, idx, weights, **kw)
