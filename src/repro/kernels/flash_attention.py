"""Pallas kernel: FlashAttention for TPU (causal / GQA / sliding-window).

Online-softmax tiling (Dao et al. '22, adapted to TPU memory hierarchy):
grid = (batch·kv_heads, q_blocks, kv_blocks) with the kv axis innermost as a
sequential reduction; running max/denominator/accumulator live in VMEM
scratch across kv steps. Q/K/V tiles stream HBM→VMEM per BlockSpec; scores
never touch HBM. MXU does the two matmuls per tile; masking (causal,
sliding-window, kv-length) is applied in-register.

GQA is handled by folding the G = Hq/Hkv query heads of one kv head into the
q-row axis: q tile rows are (g, s) pairs; the row's *sequence* position is
row % Sq (the wrapper guarantees block_q | Sq so a block never straddles g).

Decode (Sq=1, long cache) reuses the same kernel: the G folded rows form the
q tile, causal=False, kv_len masks the unwritten cache tail. Sliding-window
decode masks kpos ≤ qpos − window with qpos = kv_len − 1 via the same
position formula (queries sit at the end of the kv axis).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  sm_scale: float, causal: bool, window: int | None,
                  q_seq: int, kv_seq: int, kv_len: int | None,
                  block_q: int, block_k: int, n_kv_blocks: int):
    ki = pl.program_id(2)
    qi = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                       # (bq, d)
    k = k_ref[0].astype(jnp.float32)                       # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale

    # sequence positions: query rows are (g, s) folded; queries sit at the
    # END of the kv axis (prefill: q_seq == kv_seq; decode: q_seq == 1).
    row = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    qpos = row % q_seq + (kv_seq - q_seq)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    if kv_len is not None:
        mask &= kpos < kv_len
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[:, 0:1]                                 # (bq, 1)
    l_prev = l_scr[:, 0:1]
    m_cur = jnp.max(s, axis=-1, keepdims=True)             # (bq, 1)
    m_new = jnp.maximum(m_prev, m_cur)
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.where(mask, jnp.exp(s - m_safe), 0.0)          # (bq, bk)
    alpha = jnp.where(m_prev == NEG_INF, 0.0, jnp.exp(m_prev - m_safe))
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    v = v_ref[0].astype(jnp.float32)                       # (bk, d)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * alpha + pv
    m_scr[:, 0:1] = m_new
    l_scr[:, 0:1] = l_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        l = l_scr[:, 0:1]
        o_ref[0, :, :] = jnp.where(l > 0, acc_scr[...] / l, 0.0)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "kv_len", "sm_scale", "block_q",
                     "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = False, window: int | None = None,
                    kv_len: int | None = None, sm_scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """q (B,Hq,Sq,D), k (B,Hkv,Skv,D), v (B,Hkv,Skv,Dv) → (B,Hq,Sq,Dv).

    Hq % Hkv == 0; Dv may differ from D (MLA's v_dim ≠ qk_dim)."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    Dv = v.shape[-1]
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    scale = sm_scale if sm_scale is not None else float(D) ** -0.5

    # fold GQA groups into q rows: (B*Hkv, G*Sq, D)
    qf = q.reshape(B, Hkv, G, Sq, D).reshape(B * Hkv, G * Sq, D)
    kf = k.reshape(B * Hkv, Skv, D)
    vf = v.reshape(B * Hkv, Skv, Dv)

    bq = min(block_q, Sq) if Sq >= 8 else Sq   # block never straddles g
    if Sq % bq:
        bq = Sq
    bk = min(block_k, Skv)
    pad_k = (-Skv) % bk
    if pad_k:
        kf = jnp.pad(kf, ((0, 0), (0, pad_k), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad_k), (0, 0)))
        kv_len = Skv if kv_len is None else kv_len
    rows = G * Sq
    pad_q = (-rows) % bq
    assert pad_q == 0, (rows, bq)
    n_kv_blocks = (Skv + pad_k) // bk
    grid = (B * Hkv, rows // bq, n_kv_blocks)

    kernel = functools.partial(
        _flash_kernel, sm_scale=scale, causal=causal, window=window,
        q_seq=Sq, kv_seq=Skv, kv_len=kv_len, block_q=bq, block_k=bk,
        n_kv_blocks=n_kv_blocks)

    of = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, bk, D), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, bk, Dv), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, Dv), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hkv, rows, Dv), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)

    return of.reshape(B, Hkv, G, Sq, Dv).reshape(B, Hq, Sq, Dv).astype(q.dtype)
