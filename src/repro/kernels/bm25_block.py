"""Pallas kernel: fused BM25 impact computation over gathered postings blocks.

The query-evaluation hot loop of the paper's system, TPU-adapted: after the
(T, M) impact-ordered blocks of a query's terms are gathered, each posting's
partial score is

    impact = idf_t * tf / (tf + k1 * (1 - b + b * dl / avgdl))

This is elementwise over (T*M, B) with a per-row broadcast of idf — a pure
VPU kernel. Fusing the uint8→f32 dequant, the length-norm, and the idf scale
into one pass avoids materializing three (T,M,B) f32 intermediates in HBM
(XLA usually fuses this too; the kernel makes the tiling explicit and is the
substrate for the fused scatter-accumulate variant).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.interpret import resolve_interpret

DEFAULT_BLOCK_ROWS = 8   # rows of (T*M) per grid step; B=128 lanes fixed


def _bm25_kernel(tf_ref, dl_ref, idf_ref, params_ref, out_ref):
    tf = tf_ref[...].astype(jnp.float32)        # (R, B)
    dl = dl_ref[...]                            # (R, B)
    idf = idf_ref[...]                          # (R, 1)
    k1, b, avgdl = params_ref[0], params_ref[1], params_ref[2]
    denom = tf + k1 * (1.0 - b + b * dl / avgdl)
    out_ref[...] = idf * tf / denom


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def bm25_block_scores(tf, dl, idf, k1, b, avgdl, *,
                      block_rows: int = DEFAULT_BLOCK_ROWS,
                      interpret: "bool | None" = None):
    """tf (T,M,B) uint8, dl (T,M,B) f32, idf (T,) f32 → (T,M,B) f32."""
    interpret = resolve_interpret(interpret)
    T, M, B = tf.shape
    rows = T * M
    tf2 = tf.reshape(rows, B)
    dl2 = dl.reshape(rows, B)
    idf_rows = jnp.repeat(idf.astype(jnp.float32), M)[:, None]  # (rows, 1)
    params = jnp.stack([jnp.asarray(k1, jnp.float32),
                        jnp.asarray(b, jnp.float32),
                        jnp.asarray(avgdl, jnp.float32)])

    R = block_rows
    pad = (-rows) % R
    if pad:
        tf2 = jnp.pad(tf2, ((0, pad), (0, 0)))
        dl2 = jnp.pad(dl2, ((0, pad), (0, 0)), constant_values=1.0)
        idf_rows = jnp.pad(idf_rows, ((0, pad), (0, 0)))
    grid = ((rows + pad) // R,)

    out = pl.pallas_call(
        _bm25_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((R, B), lambda i: (i, 0)),
            pl.BlockSpec((R, B), lambda i: (i, 0)),
            pl.BlockSpec((R, 1), lambda i: (i, 0)),
            pl.BlockSpec((3,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((R, B), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows + pad, B), jnp.float32),
        interpret=interpret,
    )(tf2, dl2, idf_rows, params)
    return out[:rows].reshape(T, M, B)
