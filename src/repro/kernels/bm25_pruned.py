"""Pallas kernel: fused block-max pruned BM25 scoring + on-chip top-k.

One pass over a query's gathered (T, M) postings blocks that fuses what the
dense path does in four HBM round-trips (impacts → (T,M,B) f32 intermediate →
(n_docs,) accumulator → top-k scan):

1. **BM25 impacts** — the `bm25_block.py` VPU math, computed per block row
   in VMEM, never materialized in HBM.
2. **Block-max pruning** — a block (t, m) is skipped when its score ceiling
   cannot reach the running k-th-best threshold θ:

       bound(t, m) = qtf_t·block_max(t, m) + Σ_{t'≠t} qtf_{t'}·block_max(t', 0)

   Any doc inside block (t, m) draws at most qtf_t·block_max(t, m) from term
   t and at most the FIRST (impact-ordered ⇒ largest) block's ceiling from
   every other term, so bound(t, m) upper-bounds the doc's total score; when
   bound·SAFETY < θ every doc in the block finishes strictly below the k-th
   best and the whole block — its HBM reads included — is dead weight.
3. **Streaming top-k** — `topk.py`'s k rounds of (max, argmax, mask) over
   the VMEM accumulator; ties resolve to the lowest doc id, exactly like
   ``lax.top_k`` over the dense accumulator.

θ is bootstrapped from phase 1: the m = 0 block of every query term (each
term's highest-impact postings) is always scored, and θ is the k-th best of
the per-doc totals over just those T·B postings — a LOWER bound on the k-th
best final score, since totals only grow as more blocks accumulate, and
missing candidates count as score-0 docs (which exist whenever n_docs ≥ k).

**Losslessness** (the parity invariant tests pin): a doc tied with or above
the final k-th-best score has every one of its blocks kept — each such
block's bound is ≥ the doc's own total ≥ θ — so top-k docs accumulate
exactly the same additions, in the same order, as the dense path, and the
skipped docs' partial sums stay strictly below θ (float-monotone: dropping
non-negative addends never raises a float sum). PRUNE_SAFETY widens the
keep test by 1e-4 relative so float rounding in the bound/θ arithmetic
(~1e-6: the packer computes block_max in f64 and stores f32; impacts are
f32) can never flip a keep into a skip; blocks whose bound EQUALS θ are
kept outright (``>=``), which is what keeps boundary ties bit-identical.

Interpret-mode notes (this container is CPU-only): the accumulator is one
predicated (n_docs+1,) scatter-add — deliberately the SAME op the dense
path issues, so duplicate-index rounding order matches bit-for-bit — and
θ's phase-1 segment-sum uses sort+cumsum (Mosaic would want the scalar
unit / a small scratch pass instead); semantics are bit-accurate either
way.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.interpret import resolve_interpret

# Relative widening of the keep test (bound * PRUNE_SAFETY >= θ): absorbs
# float rounding between the builder's f64 block_max and the query-time f32
# impact sums. ~1e-6 of real noise vs 1e-4 of margin — pruning loses only
# blocks whose ceiling is >1e-4 relative below θ, which provably cannot
# contain a top-k doc.
PRUNE_SAFETY = 1.0 + 1e-4


def theta_lower_bound(d: jax.Array, v: jax.Array, k: int, n_docs: int):
    """k-th best per-doc total over postings (d ids, v impacts) — a lower
    bound on the k-th best FINAL score when v covers a subset of each doc's
    postings and every doc not present counts as 0 (true for n_docs ≥ k).

    Same cummax segment-sum trick as ``bm25.accumulate_sorted``; pad/dump
    postings (d == n_docs) and non-group-end positions contribute 0 — a
    valid "some doc scores ≥ 0" claim, never an overcount. Returns 0.0
    (prune nothing) when fewer than k postings exist.
    """
    d = d.reshape(-1)
    v = v.reshape(-1)
    if d.shape[0] < k:
        return jnp.float32(0.0)
    order = jnp.argsort(d)
    d, v = d[order], v[order]
    c = jnp.cumsum(v)
    p = c - v
    is_start = jnp.concatenate([jnp.ones(1, bool), d[1:] != d[:-1]])
    is_end = jnp.concatenate([d[1:] != d[:-1], jnp.ones(1, bool)])
    start_p = jax.lax.cummax(jnp.where(is_start, p, -jnp.inf))
    totals = jnp.where(is_end & (d < n_docs), c - start_p, 0.0)
    return jax.lax.top_k(totals, k)[0][-1]


def block_bounds(ub: jax.Array) -> jax.Array:
    """(T, M) per-block query ceilings → (T, M) whole-score bounds.

    ub[t, m] = qtf_t · block_max(t, m), zeroed where invalid. Impact
    ordering makes ub[t, 0] the term-wide ceiling, so a doc in block (t, m)
    totals at most ub[t, m] + Σ_{t'≠t} ub[t', 0].
    """
    first = ub[:, 0]
    return ub + (jnp.sum(first) - first)[:, None]


def _pruned_kernel(tf_ref, dl_ref, docs_ref, iq_ref, ub_ref, valid_ref,
                   params_ref, vals_ref, ids_ref, touched_ref, *,
                   T: int, M: int, B: int, k: int, n_docs: int):
    k1, b, avgdl = params_ref[0], params_ref[1], params_ref[2]
    tf = tf_ref[...].astype(jnp.float32)               # (R, B), R = T·M
    dl = dl_ref[...]                                   # (R, B)
    docs = docs_ref[...]                               # (R, B) i32
    iq = iq_ref[...]                                   # (R, 1) idf·qtf
    valid = valid_ref[...][:, 0] > 0                   # (R,)

    # BM25 impacts in VMEM (tf is pre-zeroed on invalid rows ⇒ imp = 0)
    imp = iq * tf / (tf + k1 * (1.0 - b + b * dl / avgdl))
    imp = jnp.where(docs < n_docs, imp, 0.0)           # pad/dump lanes

    # pruning schedule: phase-1 θ from each term's first block, then the
    # bound test decides every remaining block
    ub = ub_ref[...][:, 0].reshape(T, M)
    bound = block_bounds(ub)
    first_rows = jax.lax.broadcasted_iota(jnp.int32, (T, M), 1) == 0
    d0 = docs.reshape(T, M, B)[:, 0]
    v0 = imp.reshape(T, M, B)[:, 0]
    theta = theta_lower_bound(d0, v0, k, n_docs)
    keep = valid.reshape(T, M) & (
        first_rows | (bound * PRUNE_SAFETY >= theta))
    keep_rows = keep.reshape(-1)

    # predicated accumulation: ONE flat scatter-add, the exact op the dense
    # path issues, with skipped blocks contributing 0.0 (x + 0.0 == x
    # bitwise for the non-negative sums here) — kept docs' totals are
    # therefore bit-identical to the dense accumulator, whatever duplicate-
    # index order the backend's scatter uses, because it is the SAME order.
    imp = jnp.where(keep_rows[:, None], imp, 0.0)
    acc = jnp.zeros(n_docs + 1, jnp.float32)
    acc = acc.at[jnp.minimum(docs, n_docs).reshape(-1)].add(imp.reshape(-1))

    # streaming top-k over the accumulator (dump slot excluded); k rounds of
    # (max, argmax, mask) — first-occurrence argmax == lax.top_k tie order
    scores = acc[:n_docs]
    idx = jax.lax.broadcasted_iota(jnp.int32, (n_docs,), 0)

    def select(i, carry):
        s, = carry
        m = jnp.max(s)
        am = jnp.argmax(s).astype(jnp.int32)
        vals_ref[i] = m
        ids_ref[i] = am
        return (jnp.where(idx == am, -jnp.inf, s),)

    jax.lax.fori_loop(0, k, select, (scores,))
    touched_ref[0] = jnp.sum(keep).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k", "n_docs", "interpret"))
def bm25_pruned_topk(tf, dl, docs, idf_q, ub, valid, k1, b, avgdl, *,
                     k: int, n_docs: int, interpret: "bool | None" = None):
    """Fused pruned scoring + top-k for ONE query.

    tf (T,M,B) uint8 — pre-zeroed on invalid blocks; dl (T,M,B) f32;
    docs (T,M,B) i32 (pad = n_docs); idf_q (T,) f32 = idf·qtf;
    ub (T,M) f32 = qtf·block_max, zeroed where invalid; valid (T,M) bool.
    Requires k ≤ n_docs (callers clamp). Returns (vals (k,), ids (k,) i32,
    touched () i32 — blocks scored, the pruning-accounting numerator).
    """
    interpret = resolve_interpret(interpret)
    T, M, B = tf.shape
    R = T * M
    iq_rows = jnp.repeat(idf_q.astype(jnp.float32), M)[:, None]   # (R, 1)
    params = jnp.stack([jnp.asarray(k1, jnp.float32),
                        jnp.asarray(b, jnp.float32),
                        jnp.asarray(avgdl, jnp.float32)])
    vals, ids, touched = pl.pallas_call(
        functools.partial(_pruned_kernel, T=T, M=M, B=B, k=k, n_docs=n_docs),
        out_shape=[jax.ShapeDtypeStruct((k,), jnp.float32),
                   jax.ShapeDtypeStruct((k,), jnp.int32),
                   jax.ShapeDtypeStruct((1,), jnp.int32)],
        interpret=interpret,
    )(tf.reshape(R, B), dl.reshape(R, B), docs.astype(jnp.int32).reshape(R, B),
      iq_rows, ub.astype(jnp.float32).reshape(R, 1),
      valid.reshape(R, 1).astype(jnp.int32), params)
    return vals, ids, touched[0]
