"""Pallas kernel: EmbeddingBag (weighted gather + bag-sum) for recsys.

JAX has no native EmbeddingBag; this is the TPU-adapted lookup hot path for
the recsys architectures. Each grid step owns a tile of bags; per (bag, slot)
it DMAs one embedding row by dynamic index and accumulates into a VMEM tile:

    out[b] = Σ_l  weight[b,l] · table[idx[b,l]]        (idx < 0 = padding)

Indices/weights ride in SMEM (scalar-addressed); the table stays unblocked
(memory_space=ANY → HBM on real hardware) and rows are fetched with dynamic
``pl.load`` — the Pallas expression of FBGEMM's TBE row-gather. On a real
TPU deployment the table is additionally row-sharded across devices
(see repro.models.recsys) so each core gathers from its local shard only.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BLOCK_BAGS = 8


def _embag_kernel(idx_ref, w_ref, table_ref, out_ref, acc_scr, *,
                  bags: int, slots: int):
    acc_scr[...] = jnp.zeros_like(acc_scr)

    def slot_body(t, _):
        b = t // slots
        l = t % slots
        i = idx_ref[b, l]

        @pl.when(i >= 0)
        def _():
            row = pl.load(table_ref, (pl.dslice(i, 1), slice(None)))  # (1, D)
            w = w_ref[b, l]
            acc_scr[b, :] = acc_scr[b, :] + row[0].astype(jnp.float32) * w

        return 0

    jax.lax.fori_loop(0, bags * slots, slot_body, 0)
    out_ref[...] = acc_scr[...]


@functools.partial(jax.jit, static_argnames=("block_bags", "interpret"))
def embedding_bag(table, idx, weights, *, block_bags: int = DEFAULT_BLOCK_BAGS,
                  interpret: bool = True):
    """table (V,D), idx (B,L) i32 (pad<0), weights (B,L) f32 → (B,D) f32."""
    V, D = table.shape
    Bn, L = idx.shape
    bb = min(block_bags, Bn)
    pad = (-Bn) % bb
    if pad:
        idx = jnp.pad(idx, ((0, pad), (0, 0)), constant_values=-1)
        weights = jnp.pad(weights, ((0, pad), (0, 0)))
    grid = ((Bn + pad) // bb,)

    out = pl.pallas_call(
        functools.partial(_embag_kernel, bags=bb, slots=L),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, L), lambda i: (i, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((bb, L), lambda i: (i, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec((bb, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Bn + pad, D), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bb, D), jnp.float32)],
        interpret=interpret,
    )(idx, weights.astype(jnp.float32), table)
    return out[:Bn]
