"""Pallas kernel: streaming top-k over a long score vector.

Phase 1 (this kernel): the score vector is tiled into VMEM-sized chunks; each
chunk's local top-k is extracted by k rounds of (max, argmax, mask) — pure
VPU reductions, no sort. Survivors (n_chunks × k) land in HBM.
Phase 2 (XLA): one small ``lax.top_k`` merge over survivors.

Why this shape: ``lax.top_k`` over N=8.8M scores materializes/sorts the whole
vector in HBM; the streaming pass reads each score exactly once (memory-bound
at HBM bandwidth, the roofline floor) and reduces the sort to k·P elements,
P = n_chunks. Used for BM25 dense accumulation and recsys retrieval scoring.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.interpret import resolve_interpret

DEFAULT_CHUNK = 16384    # f32 chunk = 64KB of VMEM


def _local_topk_kernel(scores_ref, vals_ref, ids_ref, *, k: int, chunk: int,
                       n_live: int):
    ci = pl.program_id(0)
    s = scores_ref[...]                                   # (chunk,)
    base = ci * chunk
    idx = jax.lax.broadcasted_iota(jnp.int32, (chunk,), 0)

    def body(i, carry):
        s_cur, = carry
        m = jnp.max(s_cur)
        am = jnp.argmax(s_cur).astype(jnp.int32)
        vals_ref[i] = m
        # Pad-lane guard: the tail chunk is padded to `chunk` with -inf, so
        # once a round's max is -inf the chunk has no live element left (a
        # padded lane, or a short chunk exhausted by k > live rounds) — emit
        # the sentinel id n_live, never a padded index. A finite max always
        # points at a live lane (< n_live) because only pads carry -inf at
        # entry. Legit -inf inputs get the same "absent" treatment, matching
        # the sorted accumulator's isfinite convention.
        ids_ref[i] = jnp.where(m == -jnp.inf, n_live, base + am)
        s_cur = jnp.where(idx == am, -jnp.inf, s_cur)
        return (s_cur,)

    jax.lax.fori_loop(0, k, body, (s,))


@functools.partial(jax.jit, static_argnames=("k", "chunk", "interpret"))
def topk(scores, k: int, *, chunk: int = DEFAULT_CHUNK,
         interpret: "bool | None" = None):
    """scores (N,) f32 → (vals (k,), ids (k,) i32), descending order.

    Slots past the live elements (k > number of finite scores) return
    (-inf, N) — N is the caller-visible sentinel, the same dump-slot
    convention the search accumulators use.
    """
    interpret = resolve_interpret(interpret)
    (N,) = scores.shape
    chunk = max(chunk, k)   # a chunk must hold at least k survivors
    pad = (-N) % chunk
    if pad:
        scores = jnp.pad(scores, (0, pad), constant_values=-jnp.inf)
    n_chunks = (N + pad) // chunk

    vals, ids = pl.pallas_call(
        functools.partial(_local_topk_kernel, k=k, chunk=chunk, n_live=N),
        grid=(n_chunks,),
        in_specs=[pl.BlockSpec((chunk,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((k,), lambda i: (i,)),
                   pl.BlockSpec((k,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((n_chunks * k,), jnp.float32),
                   jax.ShapeDtypeStruct((n_chunks * k,), jnp.int32)],
        interpret=interpret,
    )(scores)

    # phase 2: tiny merge
    mv, mi = jax.lax.top_k(vals, k)
    return mv, ids[mi]
