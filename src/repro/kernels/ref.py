"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def bm25_block_scores_ref(tf, dl, idf, k1, b, avgdl):
    """tf (T,M,B) uint8, dl (T,M,B) f32, idf (T,) f32 → impacts (T,M,B) f32."""
    tff = tf.astype(jnp.float32)
    denom = tff + k1 * (1.0 - b + b * dl / avgdl)
    return idf[:, None, None] * tff / denom


@functools.partial(jax.jit, static_argnames=("k", "n_docs"))
def bm25_pruned_topk_ref(tf, dl, docs, idf_q, ub, valid, k1, b, avgdl, *,
                         k, n_docs):
    """UNPRUNED oracle for the fused pruned kernel: score every valid block
    densely, then ``lax.top_k``. The kernel must match this bit-for-bit —
    pruning is only allowed to skip blocks that cannot affect the top-k.
    Inputs as in :func:`repro.kernels.bm25_pruned.bm25_pruned_topk`
    (tf pre-zeroed on invalid blocks). ``touched`` is not modeled here.

    jit'd (unlike the allclose oracles above): bit-parity is only
    meaningful compiled-vs-compiled — XLA's elementwise rewrites round
    the BM25 chain differently than eager op-by-op execution.
    """
    # f32 scalars up front: python-float k1/b would make (1 - b) an exact
    # f64 before rounding, a different value than the kernel's f32 params
    k1 = jnp.asarray(k1, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    avgdl = jnp.asarray(avgdl, jnp.float32)
    tff = tf.astype(jnp.float32)
    denom = tff + k1 * (1.0 - b + b * dl / avgdl)
    imp = idf_q[:, None, None] * tff / denom
    imp = jnp.where(docs < n_docs, imp, 0.0)
    acc = jnp.zeros(n_docs + 1, jnp.float32)
    d = jnp.minimum(docs.reshape(-1), n_docs)
    acc = acc.at[d].add(imp.reshape(-1))
    v, i = jax.lax.top_k(acc[:n_docs], k)
    return v, i.astype(jnp.int32)


def topk_ref(scores, k):
    """scores (N,) f32 → (vals (k,), ids (k,) i32), descending."""
    v, i = jax.lax.top_k(scores, k)
    return v, i.astype(jnp.int32)


def dot_topk_ref(query, cands, k):
    """query (D,), cands (N, D) → top-k of cands @ query."""
    scores = cands.astype(jnp.float32) @ query.astype(jnp.float32)
    return topk_ref(scores, k)


@functools.partial(jax.jit, static_argnames=("k", "chunk"))
def _dot_topk_one_ref(query, cands, k, *, chunk: int = 1024):
    """Single-query pure-JAX twin of ``dot_topk`` — see batch docstring."""
    N, D = cands.shape
    chunk = max(chunk, k)
    pad = (-N) % chunk
    cp = jnp.pad(cands, ((0, pad), (0, 0))) if pad else cands
    n_chunks = (N + pad) // chunk
    parts = []
    for ci in range(n_chunks):
        c = jax.lax.dynamic_slice_in_dim(cp, ci * chunk, chunk)
        parts.append(jax.lax.dot_general(
            c.astype(jnp.float32), query.astype(jnp.float32)[None, :],
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)[:, 0])
    scores = jnp.concatenate(parts)[:N]
    v, i = jax.lax.top_k(scores, k)
    return v, i.astype(jnp.int32)


def dot_topk_batch_ref(queries, cands, k, *, chunk: int = 1024):
    """queries (Q, D), cands (N, D) → (vals (Q, k), ids (Q, k) i32).

    Pure-JAX twin of ``dot_topk_batch`` and the dense tier's
    uint32-bit-parity target. It reproduces the kernel's DOCUMENTED
    reduction structure — per query, per candidate chunk, one
    (chunk, D) × (D,) f32 dot — because f32 dot accumulation is
    shape-dependent on CPU XLA: a fused (N, D) @ (D, Q) matmul (or a
    vmapped matvec, which rebatches into one) reassociates the sum and
    is only an allclose oracle. ``chunk`` must match the kernel call's
    (both default to 1024).

    Like the kernel, ``chunk`` is never shrunk to N — short inputs pad up
    to one full (chunk, D) block, keeping the matvec shape (and its f32
    bit pattern) canonical for any N, so this full-corpus reference bit-
    matches per-partition kernel calls over uneven partition sizes. And
    like the kernel, each query dispatches as its own jit'd single-query
    program (NOT vmap/``lax.map``/one whole-batch jit): XLA's fusion
    around the query axis is context-dependent at the ~1-ulp level when
    N fits one chunk, so only per-program dispatch makes a query's bits
    independent of its batch neighbours."""
    if len(queries) == 0:
        return (jnp.zeros((0, k), jnp.float32),
                jnp.zeros((0, k), jnp.int32))
    out = [_dot_topk_one_ref(q, cands, k, chunk=chunk) for q in queries]
    return (jnp.stack([v for v, _ in out]),
            jnp.stack([i for _, i in out]))


def embedding_bag_ref(table, idx, weights):
    """table (V,D), idx (B,L) i32 (pad<0), weights (B,L) → (B,D) f32 sums."""
    safe = jnp.maximum(idx, 0)
    gathered = table[safe].astype(jnp.float32)            # (B, L, D)
    w = jnp.where(idx >= 0, weights, 0.0).astype(jnp.float32)
    return jnp.einsum("blD,bl->bD", gathered, w)


def mha_attention_ref(q, k, v, *, causal=False, window=None, sm_scale=None,
                      kv_len=None):
    """q (B,Hq,Sq,D), k (B,Hkv,Skv,D), v (B,Hkv,Skv,Dv); Hq % Hkv == 0.

    window: sliding-window size W (key j visible to query i iff
    i - W < j <= i, positions aligned at the sequence end).
    kv_len: number of valid kv positions (rest masked), for decode.
    """
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    scale = sm_scale if sm_scale is not None else 1.0 / jnp.sqrt(D)
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, Sq, D)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    # positions: queries occupy the LAST Sq positions of the kv axis
    qpos = jnp.arange(Sq) + (Skv - Sq)
    kpos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    if kv_len is not None:
        mask &= kpos[None, :] < kv_len
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)   # fully-masked rows
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, Sq, Dv).astype(q.dtype)
