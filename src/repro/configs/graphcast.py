"""graphcast [arXiv:2212.12794]: encoder-processor-decoder mesh GNN,
16 processor layers, d_hidden=512, sum aggregation, n_vars=227.

mesh_refinement=6 parameterizes GraphCast's icosahedral mesh construction;
the assigned shapes supply generic graph benchmarks instead, so the
encode-process-decode stack (the compute core) runs on the given edge lists
(DESIGN.md §Arch-applicability)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.cells import GNN_SHAPES, GNN_SHAPES_REDUCED, gnn_cells
from repro.models.gnn import GNNConfig
from repro.parallel.sharding import gnn_rules

ARCH_ID = "graphcast"
FAMILY = "gnn"


def full_config(d_feat: int = 100, **over) -> GNNConfig:
    kw = dict(name=ARCH_ID, d_feat=d_feat, d_out=227, n_layers=16,
              d_hidden=512, aggregator="sum", mesh_refinement=6,
              dtype=jnp.float32)
    kw.update(over)
    return GNNConfig(**kw)


def reduced_config(d_feat: int = 12) -> GNNConfig:
    return GNNConfig(name=ARCH_ID + "-reduced", d_feat=d_feat, d_out=8,
                     n_layers=2, d_hidden=32, dtype=jnp.float32)


def rules(**kw):
    return gnn_rules()


def cells(rules_, *, reduced: bool = False):
    # one config per shape (each graph regime has its own feature dim)
    shapes = GNN_SHAPES_REDUCED if reduced else GNN_SHAPES
    out = {}
    for sname, sh in shapes.items():
        cfg = (reduced_config(d_feat=sh["d_feat"]) if reduced
               else full_config(d_feat=sh["d_feat"], unroll=True))
        cell = gnn_cells(ARCH_ID, cfg, rules_, reduced=reduced)[sname]
        out[sname] = cell
    return out
