"""olmoe-1b-7b [arXiv:2409.02060]: 16L d2048 16H (kv=16) MoE 64e top-8,
d_ff(expert)=1024, vocab 50304. ~6.9B total / ~1.3B active params."""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.cells import lm_cells
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig
from repro.parallel.sharding import lm_rules

ARCH_ID = "olmoe-1b-7b"
FAMILY = "lm"


def full_config(**over) -> LMConfig:
    kw = dict(
        name=ARCH_ID, n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1024, vocab=50304,
        moe=MoEConfig(n_experts=64, top_k=8, d_model=2048, d_ff=1024,
                      capacity_factor=1.25),
        # Shipped dispatch = explicit expert parallelism: the GSPMD
        # global-scatter baseline materializes 304 GiB/device temp and
        # 1.1e12 B/device collectives at train_4k (EXPERIMENTS.md §Perf B).
        moe_impl="ep",
        dtype=jnp.bfloat16,
    )
    kw.update(over)
    return LMConfig(**kw)


def reduced_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=32, vocab=512,
        moe=MoEConfig(n_experts=8, top_k=2, d_model=64, d_ff=32,
                      capacity_factor=2.0),
        dtype=jnp.float32,
    )


def rules(**kw):
    # 6.9B params × (2B + 8B moments) replicated ≫ 16 GB HBM → FSDP
    return lm_rules(fsdp=True)


def cells(rules_, *, reduced: bool = False):
    cfg = reduced_config() if reduced else full_config(
        ep_batch_axes=tuple(rules_.batch), unroll=True)
    return lm_cells(ARCH_ID, cfg, rules_, reduced=reduced)
