"""Architecture registry: ``--arch <id>`` resolves here.

Ten assigned architectures + the paper's own (anlessini). Each module
exposes ``full_config() / reduced_config() / rules() / cells(rules, reduced)``.
"""

from __future__ import annotations

import importlib

ARCH_MODULES = {
    # LM family
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "starcoder2-3b": "repro.configs.starcoder2_3b",
    "stablelm-3b": "repro.configs.stablelm_3b",
    "h2o-danube-1.8b": "repro.configs.h2o_danube_1_8b",
    # GNN
    "graphcast": "repro.configs.graphcast",
    # recsys
    "fm": "repro.configs.fm",
    "bst": "repro.configs.bst",
    "dcn-v2": "repro.configs.dcn_v2",
    "bert4rec": "repro.configs.bert4rec",
    # the paper's own
    "anlessini": "repro.configs.anlessini",
}

ASSIGNED = [a for a in ARCH_MODULES if a != "anlessini"]


def get_arch(name: str):
    if name not in ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCH_MODULES)}")
    return importlib.import_module(ARCH_MODULES[name])


def build_cells(name: str, *, multi_pod: bool = False, reduced: bool = False):
    """dict[shape_name, CellSpec] for one arch under the given mesh kind."""
    mod = get_arch(name)
    rules = mod.rules()
    if multi_pod:
        rules = rules.with_pod()
    return mod.cells(rules, reduced=reduced)


def all_cells(*, multi_pod: bool = False, reduced: bool = False,
              include_paper_arch: bool = True):
    out = {}
    names = list(ASSIGNED) + (["anlessini"] if include_paper_arch else [])
    for name in names:
        for sname, cell in build_cells(
                name, multi_pod=multi_pod, reduced=reduced).items():
            out[f"{name}/{sname}"] = cell
    return out
