"""starcoder2-3b [arXiv:2402.19173]: 30L d3072 24H GQA kv=2, d_ff=12288
(non-gated GELU FFN), vocab 49152, RoPE."""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.cells import lm_cells
from repro.models.transformer import LMConfig
from repro.parallel.sharding import lm_rules

ARCH_ID = "starcoder2-3b"
FAMILY = "lm"


def full_config(**over) -> LMConfig:
    kw = dict(
        name=ARCH_ID, n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2,
        d_ff=12288, vocab=49152, ffn_act="gelu", rope_theta=1e5,
        dtype=jnp.bfloat16,
    )
    kw.update(over)
    return LMConfig(**kw)


def reduced_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=256, vocab=512, ffn_act="gelu",
        dtype=jnp.float32,
    )


def rules(**kw):
    # 3.5B params: TP-16 shards weights+moments to ~3 GB/chip — no FSDP.
    return lm_rules(fsdp=False)


def cells(rules_, *, reduced: bool = False):
    cfg = reduced_config() if reduced else full_config(unroll=True)
    return lm_cells(ARCH_ID, cfg, rules_, reduced=reduced)
