"""h2o-danube-1.8b [arXiv:2401.16818]: 24L d2560 32H GQA kv=8, d_ff=6912,
vocab 32000, llama+mistral mix with sliding-window attention (window 4096).

The only assigned LM arch with sub-quadratic attention → the one that runs
`long_500k` (ring-buffer KV cache of `window` slots: memory O(window), not
O(context))."""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.cells import lm_cells
from repro.models.transformer import LMConfig
from repro.parallel.sharding import lm_rules

ARCH_ID = "h2o-danube-1.8b"
FAMILY = "lm"


def full_config(**over) -> LMConfig:
    kw = dict(
        name=ARCH_ID, n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8,
        d_ff=6912, vocab=32000, window=4096,
        dtype=jnp.bfloat16,
    )
    kw.update(over)
    return LMConfig(**kw)


def reduced_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=512, window=16,
        dtype=jnp.float32,
    )


def rules(**kw):
    return lm_rules(fsdp=False)


def cells(rules_, *, reduced: bool = False):
    cfg = reduced_config() if reduced else full_config(unroll=True)
    return lm_cells(ARCH_ID, cfg, rules_, reduced=reduced)
