"""deepseek-v2-236b [arXiv:2405.04434]: 60L d5120 128H, MLA kv_lora=512,
MoE 2 shared + 160 routed top-6, expert d_ff=1536, vocab 102400.
~236B total / ~21B active params.

Faithfulness notes: q_lora=1536, qk nope/rope = 128/64, v_dim=128 per the
paper. Deviation: DeepSeek-V2's first layer is a dense FFN (12288); here all
60 layers are MoE (uniform scan) — recorded in DESIGN.md.

Dispatch: the explicit expert-parallel shard_map path (`moe_impl="ep"`) is
the baseline for this arch — the GSPMD global-scatter dispatch materializes
(E, C, d) tables that exceed per-chip HBM at train_4k scale.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.cells import lm_cells
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig, MLAConfig
from repro.parallel.sharding import lm_rules

ARCH_ID = "deepseek-v2-236b"
FAMILY = "lm"


def full_config(**over) -> LMConfig:
    kw = dict(
        name=ARCH_ID, n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
        d_ff=12288, vocab=102400,
        mla=MLAConfig(q_lora=1536, kv_lora=512, rope_dim=64, nope_dim=128,
                      v_dim=128),
        moe=MoEConfig(n_experts=160, top_k=6, d_model=5120, d_ff=1536,
                      n_shared=2, capacity_factor=1.25),
        moe_impl="ep",
        dtype=jnp.bfloat16,
    )
    kw.update(over)
    return LMConfig(**kw)


def reduced_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=512,
        mla=MLAConfig(q_lora=32, kv_lora=16, rope_dim=8, nope_dim=16, v_dim=16),
        moe=MoEConfig(n_experts=8, top_k=2, d_model=64, d_ff=32, n_shared=1,
                      capacity_factor=2.0),
        moe_impl="gspmd",       # 1-device smoke: no mesh context required
        dtype=jnp.float32,
    )


def rules(**kw):
    return lm_rules(fsdp=True)


def cells(rules_, *, reduced: bool = False):
    cfg = reduced_config() if reduced else full_config(
        ep_batch_axes=tuple(rules_.batch), unroll=True)
    return lm_cells(ARCH_ID, cfg, rules_, reduced=reduced)
