"""fm [Rendle, ICDM'10]: factorization machine, 39 sparse fields,
embed_dim=10, pairwise ⟨vᵢ,vⱼ⟩xᵢxⱼ via the O(nk) sum-square trick.
Hashed 2²⁰ rows per field → 40.9M-row shared table, row-sharded."""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.cells import recsys_cells
from repro.models.recsys import RecsysConfig
from repro.parallel.sharding import recsys_rules

ARCH_ID = "fm"
FAMILY = "recsys"


def full_config(**over) -> RecsysConfig:
    kw = dict(name=ARCH_ID, kind="fm", n_sparse=39, embed_dim=10,
              rows_per_field=1 << 20, dtype=jnp.float32)
    kw.update(over)
    return RecsysConfig(**kw)


def reduced_config() -> RecsysConfig:
    return RecsysConfig(name=ARCH_ID + "-reduced", kind="fm", n_sparse=6,
                        embed_dim=8, rows_per_field=128, dtype=jnp.float32)


def rules(**kw):
    return recsys_rules()


def cells(rules_, *, reduced: bool = False):
    cfg = reduced_config() if reduced else full_config(unroll=True)
    return recsys_cells(ARCH_ID, cfg, rules_, reduced=reduced)
