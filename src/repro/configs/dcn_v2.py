"""dcn-v2 [arXiv:2008.13535]: 13 dense + 26 sparse fields, embed_dim=16,
3 cross layers (x0 ⊙ (W xl + b) + xl), deep tower 1024-1024-512 (stacked)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.cells import recsys_cells
from repro.models.recsys import RecsysConfig
from repro.parallel.sharding import recsys_rules

ARCH_ID = "dcn-v2"
FAMILY = "recsys"


def full_config(**over) -> RecsysConfig:
    kw = dict(name=ARCH_ID, kind="dcn", n_sparse=26, n_dense=13,
              embed_dim=16, rows_per_field=1 << 20,
              mlp_dims=(1024, 1024, 512), n_cross_layers=3,
              dtype=jnp.float32)
    kw.update(over)
    return RecsysConfig(**kw)


def reduced_config() -> RecsysConfig:
    return RecsysConfig(name=ARCH_ID + "-reduced", kind="dcn", n_sparse=6,
                        n_dense=4, embed_dim=8, rows_per_field=128,
                        mlp_dims=(32, 16), n_cross_layers=2,
                        dtype=jnp.float32)


def rules(**kw):
    return recsys_rules()


def cells(rules_, *, reduced: bool = False):
    cfg = reduced_config() if reduced else full_config(unroll=True)
    return recsys_cells(ARCH_ID, cfg, rules_, reduced=reduced)
