"""Cell builders: one CellSpec per (architecture × input shape).

A *cell* is the unit of the multi-pod dry-run and the roofline table: a pure
step function + abstract (ShapeDtypeStruct) inputs + PartitionSpecs. The
dry-run binds a mesh, jits with the specs, lowers, compiles, and reads
memory/cost analysis — no arrays are ever allocated for the full configs.

Families: LM (train / prefill / decode / long-decode), GNN (train on four
graph regimes), recsys (train / serve / bulk / retrieval), plus the paper's
own search arch (see repro.configs.anlessini).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import abstract_params
from repro.parallel.sharding import ShardRules, param_specs
from repro.train.optim import OptConfig
from repro.train.steps import make_train_step


def SDS(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


@dataclasses.dataclass
class CellSpec:
    arch: str
    shape: str
    kind: str                       # train | prefill | decode | serve | retrieval
    fn: Callable | None
    args: tuple                     # abstract argument pytrees
    in_specs: tuple                 # PartitionSpec pytrees, same structure
    donate: tuple[int, ...] = ()
    note: str = ""
    skip: bool = False              # inapplicable cell (reason in note)

    @property
    def name(self) -> str:
        return f"{self.arch}/{self.shape}"


# -- train-state helpers -------------------------------------------------------


def abstract_train_state(defs) -> dict:
    params = abstract_params(defs)
    f32 = jax.tree_util.tree_map(
        lambda s: SDS(s.shape, jnp.float32), params)
    return {"params": params,
            "opt": {"m": f32, "v": f32, "count": SDS((), jnp.int32)}}


def train_state_specs(defs, rules: ShardRules) -> dict:
    ps = param_specs(defs, rules)
    return {"params": ps, "opt": {"m": ps, "v": ps, "count": P()}}


# ================================ LM family =====================================

LM_SHAPES = {
    "train_4k":    dict(kind="train",   seq=4_096,   batch=256),
    "prefill_32k": dict(kind="prefill", seq=32_768,  batch=32),
    "decode_32k":  dict(kind="decode",  seq=32_768,  batch=128),
    "long_500k":   dict(kind="decode",  seq=524_288, batch=1, long=True),
}

LM_SHAPES_REDUCED = {
    "train_4k":    dict(kind="train",   seq=32,  batch=4),
    "prefill_32k": dict(kind="prefill", seq=64,  batch=2),
    "decode_32k":  dict(kind="decode",  seq=64,  batch=2),
    "long_500k":   dict(kind="decode",  seq=128, batch=1, long=True),
}


def _lm_cache_abstract(cfg, batch: int, seq: int):
    from repro.models.transformer import make_cache
    return jax.tree_util.tree_map(
        lambda x: SDS(x.shape, x.dtype),
        jax.eval_shape(lambda: make_cache(cfg, batch, seq)))


def _lm_cache_specs(cfg, rules: ShardRules, *, batch: int, shard_seq: bool):
    """KV-cache sharding for decode.

    The cache SEQ dim shards over `model` (flash-decoding style): uniformly
    divisible (32768 % 16 == 0) regardless of Hkv — head-sharding breaks for
    GQA archs with Hkv < mesh (starcoder2 Hkv=2) — and the partial-softmax
    combine GSPMD inserts is the decode-attention pattern we want.
    long-decode (batch=1): batch replicated, seq over (data, model)."""
    if shard_seq:                       # long_500k: batch=1
        bax, seq_ax = None, ("data", "model")
    else:
        b = rules.batch_spec()
        bax = b[0] if len(b) else None
        seq_ax = "model"
    if cfg.mla is not None:
        return {"ckv": P(None, bax, seq_ax, None),
                "krope": P(None, bax, seq_ax, None)}
    return {"k": P(None, bax, None, seq_ax, None),
            "v": P(None, bax, None, seq_ax, None)}


def lm_cells(arch: str, cfg, rules: ShardRules, *, reduced: bool = False,
             opt: OptConfig | None = None) -> dict[str, CellSpec]:
    from repro.models.transformer import (lm_decode, lm_loss, lm_param_defs,
                                          lm_prefill)

    shapes = LM_SHAPES_REDUCED if reduced else LM_SHAPES
    defs = lm_param_defs(cfg)
    pspecs = param_specs(defs, rules)
    opt = opt or OptConfig()
    cells: dict[str, CellSpec] = {}

    for sname, sh in shapes.items():
        B, S = sh["batch"], sh["seq"]
        kind = sh["kind"]
        if sh.get("long") and cfg.window is None:
            cells[sname] = CellSpec(
                arch, sname, kind, None, (), (), skip=True,
                note=("N/A: pure full-attention arch — 512k-token KV cache "
                      "is architecturally unservable (DESIGN.md "
                      "§Arch-applicability); sub-quadratic attention "
                      "required. Runs for SWA archs."))
            continue

        if kind == "train":
            loss = functools.partial(_lm_loss_adapter, cfg=cfg)
            fn = make_train_step(loss, opt)
            args = (abstract_train_state(defs),
                    {"tokens": SDS((B, S), jnp.int32),
                     "labels": SDS((B, S), jnp.int32)})
            specs = (train_state_specs(defs, rules),
                     {"tokens": rules.batch_spec(None),
                      "labels": rules.batch_spec(None)})
            cells[sname] = CellSpec(arch, sname, kind, fn, args, specs,
                                    donate=(0,))
        elif kind == "prefill":
            fn = functools.partial(_lm_prefill_adapter, cfg=cfg, max_len=S)
            args = (abstract_params(defs), SDS((B, S), jnp.int32))
            specs = (pspecs, rules.batch_spec(None))
            cells[sname] = CellSpec(arch, sname, kind, fn, args, specs)
        elif kind == "decode":
            shard_seq = bool(sh.get("long"))
            cache = _lm_cache_abstract(cfg, B, S)
            fn = functools.partial(_lm_decode_adapter, cfg=cfg)
            args = (abstract_params(defs), cache,
                    SDS((B, 1), jnp.int32), SDS((), jnp.int32))
            specs = (pspecs,
                     _lm_cache_specs(cfg, rules, batch=B, shard_seq=shard_seq),
                     P() if shard_seq else rules.batch_spec(None), P())
            cells[sname] = CellSpec(arch, sname, kind, fn, args, specs,
                                    donate=(1,))
    return cells


def _lm_loss_adapter(params, batch, *, cfg):
    from repro.models.transformer import lm_loss
    return lm_loss(params, batch, cfg)


def _lm_prefill_adapter(params, tokens, *, cfg, max_len):
    from repro.models.transformer import lm_prefill
    return lm_prefill(params, tokens, cfg, max_len=max_len)


def _lm_decode_adapter(params, cache, token, pos, *, cfg):
    from repro.models.transformer import lm_decode
    return lm_decode(params, cache, token, pos, cfg)


# ================================ GNN family ====================================

# minibatch_lg: 1024 seeds, fanout 15 then 10 → padded sampled subgraph.
_MB_NODES = 1024 + 1024 * 15 + 1024 * 15 * 10     # 169,984
_MB_EDGES = 1024 * 15 + 1024 * 15 * 10            # 168,960

GNN_SHAPES = {
    "full_graph_sm": dict(n_nodes=2_708, n_edges=10_556, d_feat=1_433),
    "minibatch_lg":  dict(n_nodes=_MB_NODES, n_edges=_MB_EDGES, d_feat=602,
                          sampled=True),
    "ogb_products":  dict(n_nodes=2_449_029, n_edges=61_859_140, d_feat=100,
                          big=True),
    "molecule":      dict(n_nodes=30, n_edges=64, d_feat=32, batch=128),
}

GNN_SHAPES_REDUCED = {
    "full_graph_sm": dict(n_nodes=40, n_edges=120, d_feat=12),
    "minibatch_lg":  dict(n_nodes=8 + 8 * 3 + 8 * 6, n_edges=8 * 3 + 24 * 2,
                          d_feat=10, sampled=True),
    "ogb_products":  dict(n_nodes=64, n_edges=256, d_feat=8, big=True),
    "molecule":      dict(n_nodes=10, n_edges=20, d_feat=6, batch=4),
}


def gnn_cells(arch: str, cfg, rules: ShardRules, *, reduced: bool = False,
              opt: OptConfig | None = None) -> dict[str, CellSpec]:
    from repro.models.gnn import gnn_loss, gnn_param_defs

    shapes = GNN_SHAPES_REDUCED if reduced else GNN_SHAPES
    defs = gnn_param_defs(cfg)
    opt = opt or OptConfig()
    cells = {}

    def _pad(x: int, m: int = 256) -> int:
        return -(-x // m) * m

    for sname, sh in shapes.items():
        N, E, F = sh["n_nodes"], sh["n_edges"], sh["d_feat"]
        if not reduced and not sh.get("batch"):
            # pad sharded dims to the production-mesh multiple (dump-edge /
            # dump-node convention: padding is semantically a no-op)
            E = _pad(E)
            if sh.get("big"):
                N = _pad(N)
        G = sh.get("batch")
        loss = functools.partial(_gnn_loss_adapter, cfg=cfg)
        fn = make_train_step(loss, opt)
        if G:                                    # batched small graphs
            batch = {
                "feat": SDS((G, N, F), jnp.float32),
                "src": SDS((G, E), jnp.int32),
                "dst": SDS((G, E), jnp.int32),
                "target": SDS((G, N, cfg.d_out), jnp.float32),
                "node_mask": SDS((G, N), jnp.float32),
            }
            bspec = {
                "feat": rules.batch_spec(None, None),
                "src": rules.batch_spec(None),
                "dst": rules.batch_spec(None),
                "target": rules.batch_spec(None, None),
                "node_mask": rules.batch_spec(None),
            }
        else:
            # edges shard over (data [, model]); features/targets of big
            # graphs shard rows over data; small graphs replicate.
            big = bool(sh.get("big"))
            edge_spec = P(("data", "model")) if big else P("data")
            row = P("data", None) if big else P(None, None)
            batch = {
                "feat": SDS((N, F), jnp.float32),
                "src": SDS((E,), jnp.int32),
                "dst": SDS((E,), jnp.int32),
                "target": SDS((N, cfg.d_out), jnp.float32),
                "node_mask": SDS((N,), jnp.float32),
            }
            bspec = {
                "feat": row, "src": edge_spec, "dst": edge_spec,
                "target": row,
                "node_mask": P("data") if big else P(None),
            }
        args = (abstract_train_state(defs), batch)
        specs = (train_state_specs(defs, rules), bspec)
        cells[sname] = CellSpec(arch, sname, "train", fn, args, specs,
                                donate=(0,))
    return cells


def _gnn_loss_adapter(params, batch, *, cfg):
    from repro.models.gnn import gnn_loss
    return gnn_loss(params, batch, cfg)


# =============================== recsys family ===================================

RECSYS_SHAPES = {
    "train_batch":    dict(kind="train", batch=65_536),
    "serve_p99":      dict(kind="serve", batch=512),
    "serve_bulk":     dict(kind="serve", batch=262_144),
    "retrieval_cand": dict(kind="retrieval", batch=1, cands=1_000_000),
}

RECSYS_SHAPES_REDUCED = {
    "train_batch":    dict(kind="train", batch=64),
    "serve_p99":      dict(kind="serve", batch=8),
    "serve_bulk":     dict(kind="serve", batch=128),
    "retrieval_cand": dict(kind="retrieval", batch=1, cands=512),
}

_N_NEG = 1024        # bert4rec sampled-softmax negatives
_N_MASK = 32         # masked positions scored per sequence


def _recsys_batch(cfg, B: int, *, train: bool, reduced: bool):
    """(abstract batch, batch specs) for one arch kind."""
    i32, f32 = jnp.int32, jnp.float32
    if cfg.kind == "fm":
        b = {"sparse": SDS((B, cfg.n_sparse), i32)}
        s = {"sparse": "b1"}
    elif cfg.kind == "dcn":
        b = {"dense": SDS((B, cfg.n_dense), f32),
             "sparse": SDS((B, cfg.n_sparse), i32)}
        s = {"dense": "b1", "sparse": "b1"}
    elif cfg.kind == "bst":
        b = {"seq": SDS((B, cfg.seq_len), i32), "target": SDS((B,), i32)}
        s = {"seq": "b1", "target": "b0"}
    elif cfg.kind == "bert4rec":
        b = {"seq": SDS((B, cfg.seq_len), i32)}
        s = {"seq": "b1"}
        if train:
            n_mask = min(_N_MASK, cfg.seq_len)
            n_neg = min(_N_NEG, cfg.n_items)
            b.update({"mask_pos": SDS((B, n_mask), i32),
                      "labels": SDS((B, n_mask), i32),
                      "neg_ids": SDS((n_neg,), i32)})
            s.update({"mask_pos": "b1", "labels": "b1", "neg_ids": "r"})
    else:
        raise ValueError(cfg.kind)
    if train and cfg.kind != "bert4rec":
        b["label"] = SDS((B,), f32)
        s["label"] = "b0"
    return b, s


def _resolve_batch_specs(tags: dict, rules: ShardRules):
    out = {}
    for k, t in tags.items():
        if t == "b0":
            out[k] = rules.batch_spec()
        elif t == "b1":
            out[k] = rules.batch_spec(None)
        else:
            out[k] = P(*([None] * 1))
    return out


def recsys_cells(arch: str, cfg, rules: ShardRules, *, reduced: bool = False,
                 opt: OptConfig | None = None) -> dict[str, CellSpec]:
    from repro.models.recsys import recsys_param_defs

    shapes = RECSYS_SHAPES_REDUCED if reduced else RECSYS_SHAPES
    defs = recsys_param_defs(cfg)
    pspecs = param_specs(defs, rules)
    opt = opt or OptConfig()
    cells = {}
    for sname, sh in shapes.items():
        B = sh["batch"]
        kind = sh["kind"]
        if kind == "train":
            batch, tags = _recsys_batch(cfg, B, train=True, reduced=reduced)
            fn = make_train_step(
                functools.partial(_recsys_loss_adapter, cfg=cfg), opt)
            args = (abstract_train_state(defs), batch)
            specs = (train_state_specs(defs, rules),
                     _resolve_batch_specs(tags, rules))
            cells[sname] = CellSpec(arch, sname, kind, fn, args, specs,
                                    donate=(0,))
        elif kind == "serve":
            batch, tags = _recsys_batch(cfg, B, train=False, reduced=reduced)
            fn = functools.partial(_recsys_serve_adapter, cfg=cfg)
            args = (abstract_params(defs), batch)
            specs = (pspecs, _resolve_batch_specs(tags, rules))
            cells[sname] = CellSpec(arch, sname, kind, fn, args, specs)
        elif kind == "retrieval":
            batch, tags = _recsys_batch(cfg, B, train=False, reduced=reduced)
            D = cfg.embed_dim
            cand = SDS((sh["cands"], D), jnp.float32)
            fn = functools.partial(_recsys_retrieval_adapter, cfg=cfg)
            args = (abstract_params(defs), batch, cand)
            specs = (pspecs, _resolve_batch_specs_repl(tags), P("data", None))
            cells[sname] = CellSpec(arch, sname, kind, fn, args, specs)
    return cells


def _resolve_batch_specs_repl(tags: dict):
    return {k: P() if t == "b0" else P(None, None) if t == "b1" else P(None)
            for k, t in tags.items()}


def _recsys_loss_adapter(params, batch, *, cfg):
    from repro.models.recsys import recsys_loss
    return recsys_loss(params, batch, cfg)


def _recsys_serve_adapter(params, batch, *, cfg):
    from repro.models.recsys import bert4rec_serve_topk, recsys_forward
    if cfg.kind == "bert4rec":
        return bert4rec_serve_topk(params, batch["seq"], cfg,
                                   k=min(100, cfg.n_items))
    return recsys_forward(params, batch, cfg)


def _recsys_retrieval_adapter(params, batch, cand, *, cfg):
    from repro.models.recsys import retrieval_topk
    return retrieval_topk(params, batch, cfg, cand, k=min(100, cand.shape[0]))
