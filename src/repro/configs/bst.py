"""bst [arXiv:1905.06874]: Behavior Sequence Transformer (Alibaba).
embed_dim=32, seq_len=20, 1 block, 8 heads, MLP 1024-512-256; 2²² items."""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.cells import recsys_cells
from repro.models.recsys import RecsysConfig
from repro.parallel.sharding import recsys_rules

ARCH_ID = "bst"
FAMILY = "recsys"


def full_config(**over) -> RecsysConfig:
    kw = dict(name=ARCH_ID, kind="bst", embed_dim=32, seq_len=20,
              n_blocks=1, n_heads=8, mlp_dims=(1024, 512, 256),
              n_items=1 << 22, dtype=jnp.float32)
    kw.update(over)
    return RecsysConfig(**kw)


def reduced_config() -> RecsysConfig:
    return RecsysConfig(name=ARCH_ID + "-reduced", kind="bst", embed_dim=8,
                        seq_len=5, n_blocks=1, n_heads=2, mlp_dims=(16, 8),
                        n_items=256, dtype=jnp.float32)


def rules(**kw):
    return recsys_rules()


def cells(rules_, *, reduced: bool = False):
    cfg = reduced_config() if reduced else full_config(unroll=True)
    return recsys_cells(ARCH_ID, cfg, rules_, reduced=reduced)
