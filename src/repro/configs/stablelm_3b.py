"""stablelm-3b [hf:stabilityai/stablelm-2; unverified]: 32L d2560 32H
(kv=32 = MHA), d_ff=6912 SwiGLU, vocab 50304, partial rotary (25%)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.cells import lm_cells
from repro.models.transformer import LMConfig
from repro.parallel.sharding import lm_rules

ARCH_ID = "stablelm-3b"
FAMILY = "lm"


def full_config(**over) -> LMConfig:
    kw = dict(
        name=ARCH_ID, n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32,
        d_ff=6912, vocab=50304, rope_pct=0.25,
        dtype=jnp.bfloat16,
    )
    kw.update(over)
    return LMConfig(**kw)


def reduced_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=512, rope_pct=0.25,
        dtype=jnp.float32,
    )


def rules(**kw):
    return lm_rules(fsdp=False)


def cells(rules_, *, reduced: bool = False):
    cfg = reduced_config() if reduced else full_config(unroll=True)
    return lm_cells(ARCH_ID, cfg, rules_, reduced=reduced)
