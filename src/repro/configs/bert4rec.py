"""bert4rec [arXiv:1904.06690]: bidirectional 2-block transformer over
200-item sequences, embed_dim=64, 2 heads; masked-item objective.

Item vocab 2²⁰−2 (+[PAD]/[MASK] rows → 2²⁰ table rows, row-sharded).
Training uses sampled softmax (1024 shared negatives over 32 masked
positions per sequence) — full softmax over B·S·V is petabyte-scale at
train_batch=65536 (DESIGN.md §5)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.cells import recsys_cells
from repro.models.recsys import RecsysConfig
from repro.parallel.sharding import recsys_rules

ARCH_ID = "bert4rec"
FAMILY = "recsys"


def full_config(**over) -> RecsysConfig:
    kw = dict(name=ARCH_ID, kind="bert4rec", embed_dim=64, seq_len=200,
              n_blocks=2, n_heads=2, n_items=(1 << 20) - 2,
              dtype=jnp.float32)
    kw.update(over)
    return RecsysConfig(**kw)


def reduced_config() -> RecsysConfig:
    return RecsysConfig(name=ARCH_ID + "-reduced", kind="bert4rec",
                        embed_dim=8, seq_len=12, n_blocks=1, n_heads=2,
                        n_items=254, dtype=jnp.float32)


def rules(**kw):
    return recsys_rules()


def cells(rules_, *, reduced: bool = False):
    cfg = reduced_config() if reduced else full_config(unroll=True)
    return recsys_cells(ARCH_ID, cfg, rules_, reduced=reduced)
