"""anlessini — the paper's own architecture: serverless BM25 search over
MS MARCO passages (8.8M docs, ~700MB Anserini BM25 index).

Dry-run geometry (MS MARCO passage scale, document-partitioned over the
whole mesh per paper §3): 8,847,360 docs → 34,560 per partition on 256
chips; ~495M postings → ~3.93M blocks of 128 → 15,360 per partition;
vocab 2¹⁹. Two serve shapes: interactive (Q=1, the paper's <300 ms
operating point) and batched scatter-gather (Q=64).
"""

from __future__ import annotations

import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.cells import SDS, CellSpec
from repro.search.distributed import (DistSearchConfig, abstract_dist_state,
                                      dist_state_specs, make_dist_search_fn)

ARCH_ID = "anlessini"
FAMILY = "search"

SHAPES = {
    "serve_q1": dict(Q=1),
    "serve_q64": dict(Q=64),
}
SHAPES_REDUCED = {
    "serve_q1": dict(Q=1),
    "serve_q64": dict(Q=4),
}


def full_config(n_parts: int) -> DistSearchConfig:
    return DistSearchConfig(
        n_parts=n_parts,
        n_docs_local=8_847_360 // n_parts,
        n_blocks_local=3_932_160 // n_parts,
        vocab=1 << 19, block=128, max_terms=16, max_blocks=32, k=100)


def reduced_config(n_parts: int = 1) -> DistSearchConfig:
    return DistSearchConfig(n_parts=n_parts, n_docs_local=64,
                            n_blocks_local=32, vocab=256, block=128,
                            max_terms=8, max_blocks=4, k=10)


def rules(**kw):
    from repro.parallel.sharding import ShardRules
    return ShardRules(mapping={}, batch=("data",))


def cells(rules_, *, reduced: bool = False):
    # partition over every mesh axis (data, model [, pod])
    axes = tuple(rules_.batch) + ("model",)
    shapes = SHAPES_REDUCED if reduced else SHAPES
    out = {}
    for sname, sh in shapes.items():
        out[sname] = _search_cell(sname, sh["Q"], axes, reduced)
    return out


def _search_cell(sname: str, Q: int, axes, reduced: bool) -> CellSpec:
    # n_parts filled at dry-run time from the mesh; for building the abstract
    # cell we need the partition count — derive lazily via a builder fn.
    def build(mesh):
        n_parts = 1
        for ax in axes:
            n_parts *= mesh.shape[ax]
        cfg = reduced_config(n_parts) if reduced else full_config(n_parts)
        fn = make_dist_search_fn(cfg, axes, mesh=mesh)
        state = abstract_dist_state(cfg)
        args = (state, SDS((Q, cfg.max_terms), jnp.int32),
                SDS((Q, cfg.max_terms), jnp.float32))
        specs = (dist_state_specs(axes), P(None, None), P(None, None))
        return fn, args, specs

    cell = CellSpec(ARCH_ID, sname, "serve", None, (), (),
                    note="paper's own arch; geometry bound to mesh at dry-run")
    cell.build = build          # late-bound (needs mesh axis sizes)
    return cell
