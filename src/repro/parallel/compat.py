"""Version-compat shims over the mesh / shard_map API surface.

The repo targets the post-0.5 JAX API (``jax.shard_map``, ``jax.set_mesh``
ambient meshes, ``jax.sharding.AxisType``); CI containers may carry 0.4.x
where those names live in ``jax.experimental`` or do not exist. These
helpers pick whichever spelling the installed JAX provides so the
distributed search path runs on both.
"""

from __future__ import annotations

from typing import Any

import jax


def make_mesh(shape: tuple[int, ...], names: tuple[str, ...]):
    """``jax.make_mesh`` with Auto axis types when the API knows them."""
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(shape, names,
                             axis_types=(AxisType.Auto,) * len(shape))
    except (ImportError, TypeError):
        return jax.make_mesh(shape, names)


def use_mesh(mesh):
    """Context manager installing `mesh` as the ambient mesh.

    New JAX: ``jax.set_mesh``. Old JAX: the Mesh object itself is a context
    manager that installs the thread-resources physical mesh, which
    :func:`ambient_mesh` (and therefore ``shard_map(mesh=None)``) reads."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def ambient_mesh():
    """The ambient mesh on old JAX (``with mesh:`` / use_mesh), else None."""
    try:
        from jax._src.mesh import thread_resources
        m = thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None


def shard_map(body, mesh, in_specs: Any, out_specs: Any):
    """``jax.shard_map`` (check_vma) or the experimental one (check_rep).

    `mesh=None` means "use the ambient mesh" on both APIs: natively on new
    JAX, and via a call-time :func:`ambient_mesh` lookup (so the caller
    only needs to be inside ``use_mesh``) on old JAX.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    if mesh is not None:
        return _shard_map(body, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)

    def with_ambient(*args):
        m = ambient_mesh()
        if m is None:
            raise ValueError(
                "no ambient mesh on this JAX version — wrap the call in "
                "repro.parallel.compat.use_mesh(mesh) or pass mesh=")
        return _shard_map(body, mesh=m, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)(*args)

    return with_ambient


def flat_axis_index(axes: tuple[str, ...]):
    """Row-major flattened index over several mesh axes (works on JAX
    versions where ``jax.lax.axis_index`` rejects tuples)."""
    import jax.numpy as jnp
    pid = jnp.int32(0)
    for ax in axes:
        pid = pid * jax.lax.psum(1, ax) + jax.lax.axis_index(ax)
    return pid
