"""Mesh-axis sharding rules (DP/TP/EP/SP + pod) and collective helpers."""

from repro.parallel.sharding import (ShardRules, gnn_rules,
                                     hierarchical_psum, lm_rules,
                                     param_shardings, param_specs,
                                     recsys_rules, tree_named)

__all__ = ["ShardRules", "gnn_rules", "hierarchical_psum", "lm_rules",
           "param_shardings", "param_specs", "recsys_rules", "tree_named"]
