"""Logical-axis → mesh-axis sharding rules (DP / TP / EP / SP + pod axis).

Models declare parameters with *logical* axes (see repro.models.common);
configs pick a :class:`ShardRules` mapping those names onto mesh axes. The
same model lowers under any mesh by swapping rules — this is how the 40
(arch × shape) dry-run cells share one model zoo.

Conventions:

* mesh axes: ``("data", "model")`` single-pod, ``("pod", "data", "model")``
  multi-pod (see repro.launch.mesh). ``pod`` is an outer data-parallel axis.
* ``rules.mapping`` maps logical axis → mesh axis (or tuple of axes, or None
  for replicated).
* ``rules.batch`` lists the mesh axes the *batch* dimension of activations
  shards over — ``("data",)`` or ``("pod", "data")``.
* FSDP: mapping "embed" → "data" additionally shards the weight-stationary
  dim over the data axis (ZeRO-3 style); XLA inserts the all-gathers.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.common import ParamDef, is_param_def


@dataclasses.dataclass(frozen=True)
class ShardRules:
    """Logical→mesh mapping + batch axes."""

    mapping: Mapping[str, Any]          # logical name -> mesh axis | tuple | None
    batch: tuple[str, ...] = ("data",)

    def resolve(self, logical: str | None):
        if logical is None:
            return None
        return self.mapping.get(logical, None)

    def spec(self, axes: Sequence[str | None]) -> P:
        """PartitionSpec for one param's logical axes (duplicate mesh axes
        after the first occurrence are dropped — a mesh axis can shard only
        one dim)."""
        used: set[str] = set()
        out = []
        for ax in axes:
            m = self.resolve(ax)
            if m is None:
                out.append(None)
                continue
            ms = (m,) if isinstance(m, str) else tuple(m)
            ms = tuple(a for a in ms if a not in used)
            used.update(ms)
            if not ms:
                out.append(None)
            elif len(ms) == 1:
                out.append(ms[0])
            else:
                out.append(ms)
        return P(*out)

    def batch_spec(self, *trailing: Any) -> P:
        """PartitionSpec with the batch dim sharded over rules.batch."""
        lead = self.batch[0] if len(self.batch) == 1 else tuple(self.batch)
        return P(lead, *trailing)

    def with_pod(self) -> "ShardRules":
        """Extend rules for the multi-pod mesh: pod joins the batch axes."""
        if "pod" in self.batch:
            return self
        return dataclasses.replace(self, batch=("pod",) + tuple(self.batch))


# Canonical rule sets ---------------------------------------------------------

def lm_rules(*, fsdp: bool = False) -> ShardRules:
    """Transformer TP: heads/mlp/vocab/experts on `model`; optional FSDP
    (embed dim over `data`) for models whose replicated weights+optimizer
    exceed per-chip HBM."""
    return ShardRules(mapping={
        "embed": "data" if fsdp else None,
        "heads": "model",
        "mlp": "model",
        "vocab": "model",
        "experts": "model",
        "rows": "model",
        "kv_lora": None,
        "layers": None,
    })


def recsys_rules() -> ShardRules:
    """Row-sharded embedding tables (model-parallel lookup via shard_map);
    dense towers replicated; batch over data."""
    return ShardRules(mapping={
        "rows": "model",
        "embed": None,
        "mlp": None,
        "heads": None,
        "vocab": "model",
        "layers": None,
    })


def gnn_rules(*, shard_nodes: bool = False) -> ShardRules:
    """Edges shard over `data`; weights replicated (they are tiny); node
    states replicated (small graphs) or node-sharded (ogb_products)."""
    return ShardRules(mapping={
        "embed": None,
        "mlp": "model",
        "nodes": "data" if shard_nodes else None,
        "edges": "data",
        "layers": None,
    })


# Param / pytree shardings ----------------------------------------------------

def param_specs(defs: Any, rules: ShardRules) -> Any:
    """Tree of PartitionSpec matching a ParamDef tree."""
    return jax.tree_util.tree_map(
        lambda d: rules.spec(d.axes), defs, is_leaf=is_param_def)


def param_shardings(defs: Any, mesh: Mesh, rules: ShardRules) -> Any:
    return jax.tree_util.tree_map(
        lambda d: NamedSharding(mesh, rules.spec(d.axes)),
        defs, is_leaf=is_param_def)


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def tree_named(mesh: Mesh, specs: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        specs, is_leaf=lambda x: isinstance(x, P))


# Collective helpers -----------------------------------------------------------

def hierarchical_psum(x, *, inner: str = "data", outer: str | None = None):
    """Gradient reduction, pod-aware: reduce-scatter-free psum over the fast
    in-pod axis first, then the slow cross-pod axis — keeps inter-pod traffic
    to one reduced copy instead of raw gradients."""
    y = jax.lax.psum(x, inner)
    if outer is not None:
        y = jax.lax.psum(y, outer)
    return y
