"""Structured-query evaluation over a format-v2 packed index.

ONE host-side (numpy float32) evaluator shared verbatim by the fleet's
per-partition handler and the extended oracle — parity by construction:

* Each :class:`~repro.search.query.Leaf` produces a dense per-document
  contribution vector plus a boolean match mask, from the SAME packed
  arrays both sides hold (partition pack on the fleet, one full-corpus
  pack in the oracle). Every per-leaf input is partition-invariant: idf
  and avgdl (doc- and field-level) come from the generation's LIVE global
  stats, per-doc tf / lengths / occurrences from the doc's own rows.
* A document's score is the leaf contributions added in LEAF ORDER (one
  f32 add per leaf — doc ids are unique within a leaf), so fleet and
  oracle sums are bit-identical regardless of how docs are partitioned.
* Eligibility is one mask: a doc scores iff it matches ALL leaves
  (conjunctive) or ANY leaf (disjunctive); ineligible docs score 0.

Structured queries always evaluate on this dense path, even on fleets
configured with the ``pruned`` accumulator: field- and phrase-modified
impacts invalidate the v1 ``block_max`` ceilings, so block-max pruning
would be unsound (documented in README — the pruned fast path stays
bag-of-words-only).

Fielded tf and phrase adjacency are computed from the STORED occurrences
(first :data:`~repro.index.builder.POS_SLOTS` per posting, the format's
fixed-pitch truncation); the oracle holds v2 data built by the same
packer, so exact-set parity for phrases and facets is structural.

Also here: the facet counter (one bincount over the FULL eligible match
set — not the top-k — merged coordinator-side by string-keyed summation)
and the snippet cutter (coordinator-side, over the doc texts the merge's
deduped KV fetch already pulled).
"""

from __future__ import annotations

import numpy as np

from repro.index.builder import PackedIndex
from repro.index.tokenizer import field_items, tokenize_spans
from repro.search.query import Leaf, Query


class StructuredUnsupported(Exception):
    """Structured query against a v1 (no field/position data) index —
    admission maps this to HTTP 400."""


def _f32(x) -> np.float32:
    return np.float32(x)


def _term_postings(packed: PackedIndex, tid: int):
    """Flat live postings of one term: (docs, tf) with pad slots dropped."""
    off = np.asarray(packed.term_offsets)
    lo, hi = int(off[tid]), int(off[tid + 1])
    docs = np.asarray(packed.block_docs)[lo:hi].reshape(-1).astype(np.int64)
    tf = np.asarray(packed.block_tf)[lo:hi].reshape(-1)
    live = (docs < packed.meta.n_docs) & (tf > 0)
    return docs[live], tf[live], (lo, hi), live


def term_occurrences(packed: PackedIndex, tid: int):
    """Stored occurrences of one term over ALL its blocks (no max_blocks
    truncation — occurrence scans are exact-set): per live posting, a dict
    ``doc -> set[(field_id, position)]``."""
    fd = packed.fields
    docs, _, (lo, hi), live = _term_postings(packed, tid)
    P = fd.pos_slots
    nocc = np.asarray(fd.block_nocc)[lo:hi].reshape(-1)[live]
    occf = np.asarray(fd.block_occ_field)[lo:hi].reshape(-1, P)[live]
    occp = np.asarray(fd.block_occ_pos)[lo:hi].reshape(-1, P)[live]
    out: dict[int, set] = {}
    for i, d in enumerate(docs):
        n = int(nocc[i])
        if n:
            out[int(d)] = {(int(occf[i, s]), int(occp[i, s]))
                           for s in range(n)}
    return out


def _fielded_tf(packed: PackedIndex, tid: int, fid: int):
    """(docs, tf_field) of one term restricted to field ``fid``, from the
    stored occurrences (the format's documented undercount past P)."""
    fd = packed.fields
    docs, _, (lo, hi), live = _term_postings(packed, tid)
    P = fd.pos_slots
    nocc = np.asarray(fd.block_nocc)[lo:hi].reshape(-1)[live]
    occf = np.asarray(fd.block_occ_field)[lo:hi].reshape(-1, P)[live]
    slot_live = np.arange(P)[None, :] < nocc[:, None]
    tf_f = ((occf == fid) & slot_live).sum(axis=1).astype(np.float32)
    sel = tf_f > 0
    return docs[sel], tf_f[sel]


def _bm25_leaf(tf: np.ndarray, dl: np.ndarray, weight: np.float32,
               k1: float, b: float, avgdl: float) -> np.ndarray:
    """The shared f32 leaf formula (Lucene variant, no (k1+1) numerator)."""
    tf = tf.astype(np.float32)
    dl = dl.astype(np.float32)
    denom = tf + _f32(k1) * (_f32(1.0) - _f32(b) + _f32(b) * dl / _f32(avgdl))
    return (weight * tf / denom).astype(np.float32)


def leaf_contribution(packed: PackedIndex, leaf: Leaf, *,
                      field_avgdl: dict[str, float]
                      ) -> tuple[np.ndarray, np.ndarray]:
    """One leaf's dense (contrib f32 (n_docs,), match bool (n_docs,)).

    ``field_avgdl`` maps field name -> live per-field average length (the
    generation's global stats) — partition-invariant like idf/avgdl.
    """
    m = packed.meta
    n = m.n_docs
    contrib = np.zeros(n, np.float32)
    match = np.zeros(n, bool)
    vocab = packed.vocab
    idf = np.asarray(packed.idf, dtype=np.float32)
    fd = packed.fields

    if leaf.kind == "term":
        term = leaf.terms[0]
        tid = vocab.get(term, -1)
        if tid < 0:
            return contrib, match
        weight = _f32(leaf.boost) * _f32(leaf.qtf) * _f32(idf[tid])
        if leaf.field is None:
            docs, tf, _, _ = _term_postings(packed, tid)
            dl = np.asarray(packed.doc_len)[docs]
            contrib[docs] = _bm25_leaf(tf, dl, weight, m.k1, m.b, m.avgdl)
            match[docs] = True
        else:
            if fd is None:
                raise StructuredUnsupported("fielded term on a v1 index")
            fid = fd.field_id(leaf.field)
            if fid < 0:
                return contrib, match
            docs, tf_f = _fielded_tf(packed, tid, fid)
            dl_f = np.asarray(fd.field_len)[docs, fid]
            contrib[docs] = _bm25_leaf(
                tf_f, dl_f, weight, m.k1, m.b,
                field_avgdl.get(leaf.field, 1.0))
            match[docs] = True
        return contrib, match

    # phrase: adjacency over stored (field, position) occurrences —
    # consecutive kept tokens of the SAME field, field fixed when scoped
    if fd is None:
        raise StructuredUnsupported("phrase on a v1 index")
    fid = -2
    if leaf.field is not None:
        fid = fd.field_id(leaf.field)
        if fid < 0:
            return contrib, match
    tids = [vocab.get(t, -1) for t in leaf.terms]
    if any(t < 0 for t in tids):
        return contrib, match
    occ = [term_occurrences(packed, t) for t in tids]
    weight = _f32(leaf.boost) * _f32(
        np.sum(idf[np.asarray(tids)], dtype=np.float32))
    cand = set(occ[0])
    for o in occ[1:]:
        cand &= set(o)
    hits: list[tuple[int, int]] = []
    for d in cand:
        base = occ[0][d]
        tf_ph = 0
        for f, p in base:
            if fid != -2 and f != fid:
                continue
            if all((f, p + i) in occ[i][d] for i in range(1, len(occ))):
                tf_ph += 1
        if tf_ph:
            hits.append((d, tf_ph))
    if not hits:
        return contrib, match
    docs = np.asarray([d for d, _ in hits], np.int64)
    tf_ph = np.asarray([c for _, c in hits], np.float32)
    if leaf.field is None:
        dl = np.asarray(packed.doc_len)[docs]
        avg = m.avgdl
    else:
        dl = np.asarray(fd.field_len)[docs, fid]
        avg = field_avgdl.get(leaf.field, 1.0)
    contrib[docs] = _bm25_leaf(tf_ph, dl, weight, m.k1, m.b, avg)
    match[docs] = True
    return contrib, match


def evaluate_structured(packed: PackedIndex, query: Query, *,
                        field_avgdl: dict[str, float]
                        ) -> tuple[np.ndarray, np.ndarray]:
    """(scores f32 (n_docs,), eligible bool (n_docs,)) for one query.

    Leaf contributions accumulate in leaf order (bit-reproducible f32
    sums); ineligible docs — failing the AND/OR predicate — score 0.
    Tombstoned docs carry tf = 0 everywhere in the fused pack, so they
    match no leaf and drop out with no special casing.
    """
    n = packed.meta.n_docs
    acc = np.zeros(n, np.float32)
    nmatch = np.zeros(n, np.int32)
    for leaf in query.leaves:
        contrib, match = leaf_contribution(packed, leaf,
                                           field_avgdl=field_avgdl)
        acc += contrib
        nmatch += match
    if query.conjunctive:
        eligible = nmatch == len(query.leaves) if query.leaves \
            else np.zeros(n, bool)
    else:
        eligible = nmatch > 0
    return np.where(eligible, acc, np.float32(0.0)), eligible


def structured_topk(scores: np.ndarray, k: int
                    ) -> tuple[np.ndarray, np.ndarray]:
    """Top-k with ``lax.top_k`` tie-breaks (descending value, ascending
    index among equals), padded to k with (0.0, n_docs) like the dense
    path's contract."""
    n = len(scores)
    kk = min(k, n)
    order = np.argsort(-scores, kind="stable")[:kk]
    vals = scores[order].astype(np.float32)
    ids = order.astype(np.int32)
    if kk < k:
        vals = np.concatenate([vals, np.zeros(k - kk, np.float32)])
        ids = np.concatenate([ids, np.full(k - kk, n, np.int32)])
    return vals, ids


def facet_counts(packed: PackedIndex, eligible: np.ndarray,
                 facet_field: str) -> dict[str, int]:
    """value -> doc count over the FULL eligible set (not the top-k) for
    one declared facet field; absent docs (facet id -1) don't count."""
    fd = packed.fields
    if fd is None:
        raise StructuredUnsupported("facets on a v1 index")
    try:
        fi = fd.facet_names.index(facet_field)
    except ValueError:
        raise StructuredUnsupported(
            f"facet field {facet_field!r} not declared "
            f"(declared: {fd.facet_names})") from None
    col = np.asarray(fd.facet_ids)[:, fi]
    sel = eligible & (col >= 0)
    values = fd.facet_values[fi]
    counts = np.bincount(col[sel], minlength=len(values))
    return {values[v]: int(c) for v, c in enumerate(counts) if c > 0}


def merge_facet_counts(parts: list[dict[str, int]]) -> dict[str, int]:
    """String-keyed summation across partitions (facet value ids are
    segment-local; strings are the global join key), deterministically
    ordered: count desc, then value asc."""
    total: dict[str, int] = {}
    for p in parts:
        for v, c in p.items():
            total[v] = total.get(v, 0) + c
    return dict(sorted(total.items(), key=lambda kv: (-kv[1], kv[0])))


# -- snippets -------------------------------------------------------------------


def make_snippet(text, terms, *, width: int = 40, max_fragments: int = 4,
                 em: tuple[str, str] = ("<em>", "</em>")) -> str:
    """Highlighted fragments of one document covering EVERY matched term.

    Greedy anchor selection: walking fields in document order, each query
    term present in the doc anchors one fragment at its first occurrence;
    overlapping windows merge. Within a chosen window every query-term
    occurrence is wrapped in ``em`` tags, so snippets read naturally while
    the coverage guarantee stays per-term. Slices index the ORIGINAL text
    (casing and punctuation preserved); clipped edges get an ellipsis.

    Falls back to the head of the first field when nothing matches.
    """
    terms = set(terms)
    fields = field_items(text)
    # per field: all query-term token spans
    field_spans = [[(tok, s, e) for tok, s, e in tokenize_spans(ftext)
                    if tok in terms] for _, ftext in fields]
    covered: set[str] = set()
    anchors: list[tuple[int, int, int]] = []      # (field idx, start, end)
    for fi, spans in enumerate(field_spans):
        for tok, s, e in spans:
            if tok not in covered:
                covered.add(tok)
                anchors.append((fi, s, e))
    if not anchors:
        head = fields[0][1] if fields else ""
        frag = head[:2 * width]
        return frag + ("…" if len(head) > len(frag) else "")
    anchors = anchors[:max_fragments]
    # windows per field, merged when overlapping
    windows: dict[int, list[tuple[int, int]]] = {}
    for fi, s, e in anchors:
        ftext = fields[fi][1]
        windows.setdefault(fi, []).append(
            (max(0, s - width), min(len(ftext), e + width)))
    frags: list[str] = []
    for fi in sorted(windows):
        ftext = fields[fi][1]
        merged: list[list[int]] = []
        for lo, hi in sorted(windows[fi]):
            if merged and lo <= merged[-1][1]:
                merged[-1][1] = max(merged[-1][1], hi)
            else:
                merged.append([lo, hi])
        for lo, hi in merged:
            piece = ftext[lo:hi]
            # wrap every query-term occurrence inside the window
            marks = [(s - lo, e - lo) for tok, s, e in field_spans[fi]
                     if s >= lo and e <= hi]
            for s, e in sorted(marks, reverse=True):
                piece = piece[:s] + em[0] + piece[s:e] + em[1] + piece[e:]
            pre = "…" if lo > 0 else ""
            post = "…" if hi < len(ftext) else ""
            frags.append(pre + piece + post)
    return " ".join(frags)
