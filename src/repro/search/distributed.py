"""Document-partitioned BM25 query evaluation over the device mesh.

Paper §3: "separate Lambda instances are assigned to different partitions of
the document collection. Given the prototype presented here, building out
this design is mostly a matter of software engineering." — here it is, as a
shard_map program: every device owns one document partition's packed index
arrays (leading partition axis sharded over the whole mesh); a query fans
out to all partitions, each evaluates BM25 locally (same stateless scoring
fn as the single-partition searcher), and the k·P survivors are all-gathered
and merged — the scatter-gather of repro.core.partition, on-device.

idf is GLOBAL (computed over the whole corpus before partitioning), matching
a correctly-built distributed index; doc ids return globally offset.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.partition import local_topk, merge_topk


@dataclasses.dataclass(frozen=True)
class DistSearchConfig:
    """Static geometry of the partitioned index (per partition)."""

    n_parts: int             # total partitions = product of mesh axes used
    n_docs_local: int
    n_blocks_local: int      # NB per partition
    vocab: int
    block: int = 128
    max_terms: int = 16
    max_blocks: int = 32     # impact-ordered truncation per term
    k: int = 100
    compact_ids: bool = False   # uint16 partition-local doc ids (perf)
    fused_gather: bool = False  # one all-gather over (data,model) vs two


def abstract_dist_state(cfg: DistSearchConfig) -> dict:
    """ShapeDtypeStruct stand-ins for the partitioned index arrays."""
    Pn, NB, B = cfg.n_parts, cfg.n_blocks_local, cfg.block
    S = jax.ShapeDtypeStruct
    did = jnp.uint16 if cfg.compact_ids else jnp.int32
    assert not cfg.compact_ids or cfg.n_docs_local < 65535, \
        "compact_ids needs n_docs_local < 2^16 - 1"
    return {
        "term_offsets": S((Pn, cfg.vocab + 1), jnp.int32),
        "block_docs": S((Pn, NB, B), did),
        "block_tf": S((Pn, NB, B), jnp.uint8),
        "doc_len": S((Pn, cfg.n_docs_local + 1), jnp.float32),
        "idf": S((cfg.vocab,), jnp.float32),
        "params": S((3,), jnp.float32),          # k1, b, avgdl
    }


def dist_state_specs(axes: tuple[str, ...]) -> dict:
    part = axes[0] if len(axes) == 1 else tuple(axes)
    return {
        "term_offsets": P(part, None),
        "block_docs": P(part, None, None),
        "block_tf": P(part, None, None),
        "doc_len": P(part, None),
        "idf": P(None),
        "params": P(None),
    }


def _local_search(state: dict, term_ids, qtf, cfg: DistSearchConfig,
                  axes: tuple[str, ...]):
    """Per-device body: local BM25 over this partition, merged top-k out."""
    to = state["term_offsets"][0]                  # (V+1,)
    docs_b = state["block_docs"][0]                # (NB, B)
    tf_b = state["block_tf"][0]
    dl = state["doc_len"][0]                       # (n_docs_local+1,)
    idf = state["idf"]
    k1, b, avgdl = state["params"][0], state["params"][1], state["params"][2]
    n_loc = cfg.n_docs_local
    M = cfg.max_blocks

    def one_query(tids, w):
        tid = jnp.maximum(tids, 0)
        off = to[tid]
        n_blk = to[tid + 1] - off
        m = jnp.arange(M, dtype=jnp.int32)
        blk = off[:, None] + m[None, :]
        valid = (m[None, :] < n_blk[:, None]) & (tids[:, None] >= 0)
        blk = jnp.where(valid, blk, 0)
        docs = docs_b[blk].astype(jnp.int32)       # (T, M, B)
        tf = tf_b[blk]
        dlv = dl[jnp.minimum(docs, n_loc)]
        tff = tf.astype(jnp.float32)
        denom = tff + k1 * (1.0 - b + b * dlv / avgdl)
        imp = (idf[tid] * w)[:, None, None] * tff / denom
        imp = jnp.where(valid[..., None] & (docs < n_loc) & (tf > 0), imp, 0.0)
        acc = jnp.zeros(n_loc + 1, jnp.float32).at[
            jnp.minimum(docs.reshape(-1), n_loc)].add(imp.reshape(-1))
        return acc[:n_loc]

    scores = jax.vmap(one_query)(term_ids, qtf)    # (Q, n_loc)
    pid = jax.lax.axis_index(axes)                 # flattened partition id
    base = (pid * n_loc).astype(jnp.int32)
    ids = base + jnp.arange(n_loc, dtype=jnp.int32)
    ids = jnp.broadcast_to(ids[None], scores.shape)
    lv, li = local_topk(scores, ids, cfg.k)
    if cfg.fused_gather:                   # one collective over all axes
        gv = jax.lax.all_gather(lv, axes, axis=-1, tiled=True)
        gi = jax.lax.all_gather(li, axes, axis=-1, tiled=True)
    else:                                  # hierarchical: fast axis first
        gv, gi = lv, li
        for ax in axes:
            gv = jax.lax.all_gather(gv, ax, axis=-1, tiled=True)
            gi = jax.lax.all_gather(gi, ax, axis=-1, tiled=True)
    return merge_topk(gv, gi, cfg.k)


def make_dist_search_fn(cfg: DistSearchConfig, axes: tuple[str, ...] = ("data", "model")):
    """Build the shard_map'd global search fn.

    fn(state, term_ids (Q,T) i32, qtf (Q,T) f32) -> (scores (Q,k), ids (Q,k)),
    replicated. Requires an ambient mesh (jax.set_mesh) whose `axes` sizes
    multiply to cfg.n_parts — one partition per device."""
    sspecs = dist_state_specs(axes)
    body = functools.partial(_local_search, cfg=cfg, axes=axes)
    inner = jax.shard_map(
        body, mesh=None,
        in_specs=(sspecs, P(None, None), P(None, None)),
        out_specs=(P(None, None), P(None, None)),
        check_vma=False,
    )

    def fn(state, term_ids, qtf):
        mesh = jax.sharding.get_abstract_mesh()
        n_dev = 1
        for ax in axes:
            n_dev *= mesh.shape[ax]
        if cfg.n_parts != n_dev:
            raise ValueError(
                f"DistSearchConfig.n_parts={cfg.n_parts} must equal the mesh "
                f"extent over {axes} ({n_dev}) — one partition per device")
        return inner(state, term_ids, qtf)

    return fn


# -- host-side partitioned build (real arrays, for tests/examples) ----------------


def partition_corpus(docs: list[tuple[str, str]], n_parts: int):
    """Round-robin document partitioning; returns per-partition doc lists
    with a global-id map (global id = part * n_local + local id)."""
    per = -(-len(docs) // n_parts)
    parts = []
    for p in range(n_parts):
        parts.append(docs[p * per: (p + 1) * per])
    return parts, per


def build_partitioned_state(docs: list[tuple[str, str]], n_parts: int,
                            cfg_hint: dict | None = None):
    """Build real partitioned arrays (small corpora — tests/examples).

    Returns (state dict of np arrays, DistSearchConfig, vocab)."""
    from collections import Counter
    import math as _math

    from repro.index.tokenizer import tokenize

    parts, per = partition_corpus(docs, n_parts)
    # global stats for idf/avgdl
    all_toks = [tokenize(t) for _, t in docs]
    n_docs = len(docs)
    df: Counter = Counter()
    for toks in all_toks:
        df.update(set(toks))
    vocab = {t: i for i, t in enumerate(sorted(df))}
    V = len(vocab)
    avgdl = float(np.mean([len(t) for t in all_toks])) if all_toks else 1.0
    idf = np.zeros(V, np.float32)
    for t, i in vocab.items():
        idf[i] = _math.log(1.0 + (n_docs - df[t] + 0.5) / (df[t] + 0.5))

    hint = cfg_hint or {}
    B = hint.get("block", 128)
    k1, b = hint.get("k1", 0.9), hint.get("b", 0.4)

    # per-partition packing (impact-ordered blocks, like IndexWriter.pack)
    per_to, per_docs, per_tf, per_dl = [], [], [], []
    max_nb = 0
    for pdocs in parts:
        postings: dict[int, dict[int, int]] = {}
        dl = np.ones(per + 1, np.float32)
        for li, (_, text) in enumerate(pdocs):
            toks = tokenize(text)
            dl[li] = max(len(toks), 1)
            for t, tf in Counter(toks).items():
                postings.setdefault(vocab[t], {})[li] = min(tf, 255)
        to = np.zeros(V + 1, np.int32)
        bd, bt = [], []
        for ti in range(V):
            plist = postings.get(ti)
            if not plist:
                to[ti + 1] = to[ti]
                continue
            ds = np.fromiter(plist.keys(), np.int32)
            ts = np.fromiter(plist.values(), np.int64)
            imp = idf[ti] * ts / (ts + k1 * (1 - b + b * dl[ds] / avgdl))
            order = np.argsort(-imp, kind="stable")
            ds, ts = ds[order], ts[order]
            nb = -(-len(ds) // B)
            pad = nb * B - len(ds)
            ds = np.concatenate([ds, np.full(pad, per, np.int32)])
            ts = np.concatenate([np.minimum(ts, 255).astype(np.uint8),
                                 np.zeros(pad, np.uint8)])
            for j in range(nb):
                bd.append(ds[j * B:(j + 1) * B])
                bt.append(ts[j * B:(j + 1) * B])
            to[ti + 1] = to[ti] + nb
        per_to.append(to)
        per_docs.append(np.stack(bd) if bd else np.zeros((0, B), np.int32))
        per_tf.append(np.stack(bt) if bt else np.zeros((0, B), np.uint8))
        per_dl.append(dl)
        max_nb = max(max_nb, len(bd))

    NB = max(max_nb, 1)
    did = np.uint16 if hint.get("compact_ids") and per < 65535 else np.int32
    state = {
        "term_offsets": np.stack(per_to),
        "block_docs": np.stack([
            np.concatenate([d, np.full((NB - len(d), B), per, np.int32)])
            for d in per_docs]).astype(did),
        "block_tf": np.stack([
            np.concatenate([t, np.zeros((NB - len(t), B), np.uint8)])
            for t in per_tf]),
        "doc_len": np.stack(per_dl),
        "idf": idf,
        "params": np.asarray([k1, b, avgdl], np.float32),
    }
    cfg = DistSearchConfig(
        n_parts=n_parts, n_docs_local=per, n_blocks_local=NB, vocab=V,
        block=B, k=hint.get("k", 10), max_terms=hint.get("max_terms", 16),
        max_blocks=hint.get("max_blocks", 32),
        compact_ids=bool(did == np.uint16),
        fused_gather=bool(hint.get("fused_gather", False)))
    return state, cfg, vocab
