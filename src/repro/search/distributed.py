"""Document-partitioned BM25 query evaluation over the device mesh.

Paper §3: "separate Lambda instances are assigned to different partitions of
the document collection. Given the prototype presented here, building out
this design is mostly a matter of software engineering." — here it is, as a
shard_map program: every device owns one document partition's packed index
arrays (leading partition axis sharded over the whole mesh); a query fans
out to all partitions, each evaluates BM25 locally (the SAME scoring core,
``repro.search.bm25.score_dense``, as the single-partition searcher), and
the k·P survivors are all-gathered and merged — the scatter-gather of
repro.core.partition, on-device.

This module contains no BM25 math and no packing code of its own: scoring
lives in ``search/bm25.py``, impact-ordered block packing in
``index/builder.py`` (one ``IndexWriter`` per partition with global stats),
and this file only wires partitions to mesh axes.

idf is GLOBAL (computed over the whole corpus before partitioning), matching
a correctly-built distributed index; doc ids return globally offset.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.partition import local_topk, merge_topk
from repro.parallel import compat
from repro.search.bm25 import SearchState, score_dense, score_pruned


@dataclasses.dataclass(frozen=True)
class DistSearchConfig:
    """Static geometry of the partitioned index (per partition)."""

    n_parts: int             # total partitions = product of mesh axes used
    n_docs_local: int
    n_blocks_local: int      # NB per partition
    vocab: int
    block: int = 128
    max_terms: int = 16
    max_blocks: int = 32     # impact-ordered truncation per term
    k: int = 100
    accumulator: str = "dense"  # "dense" | "pruned" (block-max WAND)
    compact_ids: bool = False   # uint16 partition-local doc ids (perf)
    fused_gather: bool = False  # one all-gather over (data,model) vs two


def abstract_dist_state(cfg: DistSearchConfig) -> dict:
    """ShapeDtypeStruct stand-ins for the partitioned index arrays."""
    Pn, NB, B = cfg.n_parts, cfg.n_blocks_local, cfg.block
    S = jax.ShapeDtypeStruct
    did = jnp.uint16 if cfg.compact_ids else jnp.int32
    assert not cfg.compact_ids or cfg.n_docs_local < 65535, \
        "compact_ids needs n_docs_local < 2^16 - 1"
    return {
        "term_offsets": S((Pn, cfg.vocab + 1), jnp.int32),
        "block_docs": S((Pn, NB, B), did),
        "block_tf": S((Pn, NB, B), jnp.uint8),
        "block_max": S((Pn, NB), jnp.float32),
        "doc_len": S((Pn, cfg.n_docs_local + 1), jnp.float32),
        "idf": S((cfg.vocab,), jnp.float32),
        "params": S((3,), jnp.float32),          # k1, b, avgdl
    }


def dist_state_specs(axes: tuple[str, ...]) -> dict:
    part = axes[0] if len(axes) == 1 else tuple(axes)
    return {
        "term_offsets": P(part, None),
        "block_docs": P(part, None, None),
        "block_tf": P(part, None, None),
        "block_max": P(part, None),
        "doc_len": P(part, None),
        "idf": P(None),
        "params": P(None),
    }


def _local_search(state: dict, term_ids, qtf, cfg: DistSearchConfig,
                  axes: tuple[str, ...]):
    """Per-device body: local BM25 over this partition, merged top-k out.

    The scoring itself is the unified core (`bm25.score_dense`) applied to
    this device's partition slice; only the global-id offset and the
    survivor all-gather are mesh-specific.
    """
    local = SearchState(
        term_offsets=state["term_offsets"][0],     # (V+1,)
        block_docs=state["block_docs"][0],         # (NB, B)
        block_tf=state["block_tf"][0],
        block_max=state["block_max"][0],           # (NB,)
        doc_len=state["doc_len"][0],               # (n_docs_local+1,)
        idf=state["idf"],
        avgdl=state["params"][2],
        k1=state["params"][0],
        b=state["params"][1],
        n_docs=cfg.n_docs_local,
    )
    pid = compat.flat_axis_index(axes)             # flattened partition id
    base = (pid * cfg.n_docs_local).astype(jnp.int32)
    if cfg.accumulator == "pruned":
        # block-max pruned local scoring: top-k comes straight out of
        # score_pruned (lax.top_k over the pruned accumulator — same tie
        # order as local_topk over the dense accumulator, and bit-identical
        # scores since pruning only skips blocks that cannot enter top-k)
        kk = min(cfg.k, cfg.n_docs_local)
        lv, li, _ = jax.vmap(
            lambda t, w: score_pruned(local, t, w,
                                      max_blocks=cfg.max_blocks, k=kk)
        )(term_ids, qtf)                           # (Q, kk) each
        if kk < cfg.k:                             # pad to the (Q, k) merge
            q = lv.shape[0]
            lv = jnp.concatenate(
                [lv, jnp.zeros((q, cfg.k - kk), lv.dtype)], axis=-1)
            li = jnp.concatenate(
                [li, jnp.full((q, cfg.k - kk), cfg.n_docs_local,
                              jnp.int32)], axis=-1)
        li = base + li
    else:
        scores = jax.vmap(
            lambda t, w: score_dense(local, t, w, max_blocks=cfg.max_blocks)
        )(term_ids, qtf)                           # (Q, n_docs_local)
        ids = base + jnp.arange(cfg.n_docs_local, dtype=jnp.int32)
        ids = jnp.broadcast_to(ids[None], scores.shape)
        lv, li = local_topk(scores, ids, cfg.k)
    if cfg.fused_gather:                   # one collective over all axes
        gv = jax.lax.all_gather(lv, axes, axis=-1, tiled=True)
        gi = jax.lax.all_gather(li, axes, axis=-1, tiled=True)
    else:                                  # hierarchical: fast axis first
        gv, gi = lv, li
        for ax in axes:
            gv = jax.lax.all_gather(gv, ax, axis=-1, tiled=True)
            gi = jax.lax.all_gather(gi, ax, axis=-1, tiled=True)
    return merge_topk(gv, gi, cfg.k)


def make_dist_search_fn(cfg: DistSearchConfig,
                        axes: tuple[str, ...] = ("data", "model"),
                        mesh: jax.sharding.Mesh | None = None):
    """Build the shard_map'd global search fn.

    fn(state, term_ids (Q,T) i32, qtf (Q,T) f32) -> (scores (Q,k), ids (Q,k)),
    replicated. Either pass ``mesh`` explicitly, or (on JAX versions with
    ambient meshes) enter one via ``jax.set_mesh`` / ``compat.use_mesh``;
    the mesh extent over `axes` must equal cfg.n_parts — one partition per
    device."""
    sspecs = dist_state_specs(axes)
    body = functools.partial(_local_search, cfg=cfg, axes=axes)
    inner = compat.shard_map(
        body, mesh,
        in_specs=(sspecs, P(None, None), P(None, None)),
        out_specs=(P(None, None), P(None, None)),
    )

    def _check_extent(shape: dict) -> None:
        n_dev = 1
        for ax in axes:
            n_dev *= shape[ax]
        if cfg.n_parts != n_dev:
            raise ValueError(
                f"DistSearchConfig.n_parts={cfg.n_parts} must equal the mesh "
                f"extent over {axes} ({n_dev}) — one partition per device")

    def fn(state, term_ids, qtf):
        if mesh is not None:
            _check_extent(dict(mesh.shape))
        elif hasattr(jax.sharding, "get_abstract_mesh"):
            _check_extent(dict(jax.sharding.get_abstract_mesh().shape))
        else:
            ambient = compat.ambient_mesh()
            if ambient is not None:       # else compat.shard_map raises
                _check_extent(dict(ambient.shape))
        return inner(state, term_ids, qtf)

    return fn


# -- host-side partitioned build (real arrays, for tests/examples) ----------------


def partition_corpus(docs: list[tuple[str, str]], n_parts: int,
                     weights: "list[float] | None" = None):
    """Contiguous-chunk document partitioning; returns per-partition doc
    lists plus ``per``, the uniform per-partition size (global id =
    part * per + local id — the mesh path's id map).

    ``weights`` skews the split: partition ``p`` receives a share of the
    corpus proportional to ``weights[p]`` (largest-remainder rounding, so
    sizes sum exactly to the corpus). This is how a benchmark builds the
    Zipf-skewed fleet real collections look like — a head partition with
    most of the documents, a long cold tail — while every partition still
    packs against the same global stats. Weighted splits have no uniform
    ``per``; the returned ``per`` is the LARGEST partition (the fleet app
    maps global ids through actual per-partition offsets, never ``per``,
    whenever an indexer is attached — i.e. always)."""
    if weights is None:
        per = -(-len(docs) // n_parts)
        return [docs[p * per: (p + 1) * per] for p in range(n_parts)], per
    if len(weights) != n_parts or any(w < 0 for w in weights) \
            or sum(weights) <= 0:
        raise ValueError(f"need {n_parts} nonnegative weights with a "
                         f"positive sum, got {weights!r}")
    total = float(sum(weights))
    quotas = [len(docs) * w / total for w in weights]
    sizes = [int(q) for q in quotas]
    # largest remainder: hand leftover docs to the most-shortchanged parts
    for p in sorted(range(n_parts), key=lambda p: quotas[p] - sizes[p],
                    reverse=True)[: len(docs) - sum(sizes)]:
        sizes[p] += 1
    parts, at = [], 0
    for n in sizes:
        parts.append(docs[at: at + n])
        at += n
    return parts, max(sizes)


def stack_partitions(packs: list, n_docs_local: int,
                     cfg_hint: dict | None = None) -> tuple[dict, "DistSearchConfig"]:
    """PackedIndex-per-partition → stacked partitioned-state adapter.

    Stacks per-partition :class:`repro.index.builder.PackedIndex` arrays
    (all built against one global vocab + global stats) along a leading
    partition axis, padding each partition's blocks/doc_len to the common
    NB / n_docs_local extents. Padding entries carry tf=0 so the scoring
    core masks them; the packing itself (impact ordering, block layout,
    BM25 constants) has exactly one source of truth: ``IndexWriter.pack``.
    """
    hint = cfg_hint or {}
    V = packs[0].term_offsets.shape[0] - 1
    B = packs[0].meta.block
    m0 = packs[0].meta
    for p in packs[1:]:       # packs must share vocab + global BM25 stats,
        m = p.meta            # or partition 0's idf/params silently win
        if (p.term_offsets.shape[0] - 1 != V or m.block != B
                or (m.k1, m.b, m.avgdl) != (m0.k1, m0.b, m0.avgdl)
                or not np.array_equal(p.idf, packs[0].idf)):
            raise ValueError(
                "heterogeneous partition packs — build every partition with "
                "the same IndexWriter(vocab=global_vocab(stats), "
                "global_stats=stats)")
    NB = max(max(p.meta.n_blocks for p in packs), 1)
    compact = bool(hint.get("compact_ids")) and n_docs_local < 65535
    did = np.uint16 if compact else np.int32

    block_docs = np.stack([
        np.concatenate([
            p.block_docs,
            np.full((NB - p.meta.n_blocks, B), p.meta.n_docs, np.int32)])
        for p in packs]).astype(did)
    block_tf = np.stack([
        np.concatenate([
            p.block_tf, np.zeros((NB - p.meta.n_blocks, B), np.uint8)])
        for p in packs])
    block_max = np.stack([
        np.concatenate([
            np.asarray(p.block_max, np.float32),
            np.zeros(NB - p.meta.n_blocks, np.float32)])
        for p in packs])
    doc_len = np.ones((len(packs), n_docs_local + 1), np.float32)
    for i, p in enumerate(packs):
        doc_len[i, :p.meta.n_docs] = p.doc_len[:p.meta.n_docs]

    meta = packs[0].meta
    state = {
        "term_offsets": np.stack([p.term_offsets for p in packs]),
        "block_docs": block_docs,
        "block_tf": block_tf,
        "block_max": block_max,
        "doc_len": doc_len,
        "idf": packs[0].idf,               # global stats ⇒ identical per part
        "params": np.asarray([meta.k1, meta.b, meta.avgdl], np.float32),
    }
    cfg = DistSearchConfig(
        n_parts=len(packs), n_docs_local=n_docs_local, n_blocks_local=NB,
        vocab=V, block=B, k=hint.get("k", 10),
        accumulator=hint.get("accumulator", "dense"),
        max_terms=hint.get("max_terms", 16),
        max_blocks=hint.get("max_blocks", 32),
        compact_ids=compact,
        fused_gather=bool(hint.get("fused_gather", False)))
    return state, cfg


def build_partitioned_state(docs: list[tuple[str, str]], n_parts: int,
                            cfg_hint: dict | None = None):
    """Build real partitioned arrays (small corpora — tests/examples).

    Per partition: one ``IndexWriter`` packing against the corpus-global
    vocab and ``compute_global_stats`` (idf/avgdl), then
    :func:`stack_partitions` adapts the PackedIndexes to the shard_map
    state layout. Returns (state dict of np arrays, DistSearchConfig,
    vocab)."""
    from repro.index.builder import (IndexWriter, compute_global_stats,
                                     global_vocab)

    hint = cfg_hint or {}
    parts, per = partition_corpus(docs, n_parts)
    gstats = compute_global_stats(docs)
    vocab = global_vocab(gstats)
    packs = []
    for pdocs in parts:
        writer = IndexWriter(
            k1=hint.get("k1", 0.9), b=hint.get("b", 0.4),
            block=hint.get("block", 128),
            global_stats=gstats, vocab=vocab)
        writer.add_many(pdocs)
        packs.append(writer.pack())
    state, cfg = stack_partitions(packs, per, hint)
    return state, cfg, vocab
