"""Structured query AST + parser — the v2 format's query language.

The grammar is deliberately FLAT (no parentheses, no NOT): a query is a
sequence of clauses separated by whitespace and/or the bare keywords
``AND`` / ``OR``. Each clause is one of::

    term                  hello
    field:term            title:hello
    "quoted phrase"       "information retrieval"
    field:"phrase"        title:"serverless lucene"

and any clause may carry a trailing boost: ``title:hello^2.5``. The
presence of ANY explicit ``AND`` makes the whole query conjunctive (every
leaf must match); otherwise leaves are disjunctive (Lucene's default
SHOULD semantics). That single switch keeps evaluation a per-leaf
scatter-add plus one eligibility mask — no boolean tree walk on the
scoring path, which is what lets the fleet and the oracle share one
bit-exact accumulator.

Clause text is run through the SAME analyzer as indexing
(:func:`repro.index.tokenizer.tokenize`), so a clause may expand to
several term leaves (``foo-bar`` → ``foo``, ``bar``) or vanish entirely
(a stopword). Exact-duplicate term leaves merge with ``qtf`` summed — the
structured twin of the bag-of-words query-term-frequency weighting, so a
structured query that is plain bag-of-words scores exactly like the
legacy ``q`` path.

The AST is JSON-able (:meth:`Query.to_payload` /
:func:`query_from_payload`): the gateway parses ONCE at admission and the
scatter fan-out ships plain dicts, never re-parsing on workers.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

from repro.index.tokenizer import tokenize


class QueryParseError(ValueError):
    """Malformed structured query — admission maps this to HTTP 400."""


# field prefix, quoted phrase or bare word, optional ^boost
_CLAUSE_RE = re.compile(
    r'(?:(?P<field>[A-Za-z0-9_]+):)?'
    r'(?:"(?P<phrase>[^"]*)"|(?P<word>[^\s"^]+))'
    r'(?:\^(?P<boost>[^\s"]+))?')


@dataclass
class Leaf:
    """One scoring unit: a (possibly field-scoped) term or phrase.

    ``terms`` holds one analyzed token for kind ``term``, the in-order
    token sequence for kind ``phrase``. ``field`` of None means
    unscoped — a term leaf then scores with the doc-level BM25 formula
    (bit-identical to the legacy path); a field-scoped term leaf scores
    BM25F-style off the per-field length. ``qtf`` counts merged duplicate
    term leaves (phrases never merge)."""

    kind: str                     # "term" | "phrase"
    terms: list[str]
    field: "str | None" = None
    boost: float = 1.0
    qtf: int = 1

    def to_payload(self) -> dict:
        return {"kind": self.kind, "terms": list(self.terms),
                "field": self.field, "boost": self.boost, "qtf": self.qtf}


@dataclass
class Query:
    """A parsed structured query: flat leaves + one conjunction bit."""

    leaves: list[Leaf] = field(default_factory=list)
    conjunctive: bool = False

    @property
    def terms(self) -> list[str]:
        """Every analyzed term the query touches, deduped, first-seen
        order — the hydration set AND the snippet matcher's term list."""
        seen: dict[str, None] = {}
        for lf in self.leaves:
            for t in lf.terms:
                seen.setdefault(t)
        return list(seen)

    def to_payload(self) -> dict:
        return {"conj": self.conjunctive,
                "leaves": [lf.to_payload() for lf in self.leaves]}


def leaf_from_payload(d: dict) -> Leaf:
    return Leaf(kind=str(d["kind"]), terms=[str(t) for t in d["terms"]],
                field=d.get("field"), boost=float(d.get("boost", 1.0)),
                qtf=int(d.get("qtf", 1)))


def query_from_payload(d: dict) -> Query:
    return Query(leaves=[leaf_from_payload(x) for x in d.get("leaves", ())],
                 conjunctive=bool(d.get("conj", False)))


def _parse_boost(raw: "str | None", clause: str) -> float:
    if raw is None:
        return 1.0
    try:
        b = float(raw)
    except ValueError:
        raise QueryParseError(f"bad boost in clause {clause!r}") from None
    if not (b > 0.0):
        raise QueryParseError(f"boost must be > 0 in clause {clause!r}")
    return b


def parse_query(text: str) -> Query:
    """Parse the DSL into a :class:`Query`.

    Raises :class:`QueryParseError` on syntax errors (unbalanced quote,
    bad boost, dangling operator). Clauses whose text analyzes to nothing
    (stopwords, punctuation) are DROPPED, mirroring the analyzer's
    behaviour on the legacy path — a query may legitimately parse to zero
    leaves and simply match nothing.
    """
    if not isinstance(text, str):
        raise QueryParseError("structured query must be a string")
    if text.count('"') % 2:
        raise QueryParseError(f"unbalanced quote in query {text!r}")
    leaves: list[Leaf] = []
    merged: dict[tuple, int] = {}     # term-leaf key -> index into leaves
    conjunctive = False
    saw_clause = False
    pending_op = False
    for m in _CLAUSE_RE.finditer(text):
        word = m.group("word")
        if word in ("AND", "OR") and m.group("field") is None \
                and m.group("boost") is None:
            if not saw_clause:
                raise QueryParseError(f"dangling operator in query {text!r}")
            conjunctive |= word == "AND"
            pending_op = True
            continue
        pending_op = False
        saw_clause = True
        fld = m.group("field")
        boost = _parse_boost(m.group("boost"), m.group(0))
        phrase = m.group("phrase")
        if phrase is not None:
            toks = tokenize(phrase)
            if not toks:
                continue
            if len(toks) == 1:        # one-token "phrase" is just a term
                word, phrase = toks[0], None
            else:
                leaves.append(Leaf("phrase", toks, field=fld, boost=boost))
                continue
        for t in tokenize(word):
            key = (fld, t, boost)
            if key in merged:
                leaves[merged[key]].qtf += 1
            else:
                merged[key] = len(leaves)
                leaves.append(Leaf("term", [t], field=fld, boost=boost))
    if pending_op:
        raise QueryParseError(f"dangling operator in query {text!r}")
    return Query(leaves=leaves, conjunctive=conjunctive)
