"""JAX BM25 query evaluation over the packed blocked index.

Fixed-shape, jit-compatible score-at-a-time evaluation:

* gather the first M (impact-ordered) blocks of each of the query's T terms,
* compute per-posting BM25 impacts (optionally through the Pallas kernel),
* accumulate per-document scores, two strategies:
    - ``dense``  : scatter-add into a (Q, n_docs+1) accumulator. Simple,
                   exact, HBM-heavy for big corpora.
    - ``sorted`` : sort the (doc, impact) pairs and segment-sum via the
                   cummax prefix trick — no dense accumulator; memory scales
                   with T·M·B instead of n_docs. TPU-friendly for huge
                   corpora / many concurrent queries.
    - ``pruned`` : block-max WAND — skip whole blocks whose score ceiling
                   (``qtf·block_max`` plus every other term's first-block
                   ceiling) cannot reach a k-th-best lower bound θ taken
                   from the always-scored first blocks. Fused single-pass
                   Pallas kernel (``kernels/bm25_pruned.py``) or a pure-JAX
                   reference with the identical keep mask.
* top-k over accumulated scores.

All strategies must agree with :class:`repro.search.oracle.OracleSearcher`
whenever M·B covers every posting of every query term (tests enforce this);
``pruned`` must be BIT-identical — it only skips blocks provably unable to
enter the top-k, and ties break exactly like ``lax.top_k``.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.index.builder import PackedIndex


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SearchState:
    """Device-resident index arrays (the hydrated 'warm' state)."""

    term_offsets: jax.Array   # (V+1,) int32
    block_docs: jax.Array     # (NB, B) int32
    block_tf: jax.Array       # (NB, B) uint8
    block_max: jax.Array      # (NB,) float32 — per-block max impact
    doc_len: jax.Array        # (n_docs+1,) float32
    idf: jax.Array            # (V,) float32
    avgdl: jax.Array          # () float32
    k1: jax.Array             # () float32
    b: jax.Array              # () float32
    n_docs: int               # static

    def tree_flatten(self):
        leaves = (self.term_offsets, self.block_docs, self.block_tf,
                  self.block_max, self.doc_len, self.idf, self.avgdl,
                  self.k1, self.b)
        return leaves, self.n_docs

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, n_docs=aux)

    @classmethod
    def from_packed(cls, idx: PackedIndex) -> "SearchState":
        m = idx.meta
        return cls(
            term_offsets=jnp.asarray(idx.term_offsets),
            block_docs=jnp.asarray(idx.block_docs),
            block_tf=jnp.asarray(idx.block_tf),
            block_max=jnp.asarray(idx.block_max, dtype=jnp.float32),
            doc_len=jnp.asarray(idx.doc_len),
            idf=jnp.asarray(idx.idf),
            avgdl=jnp.float32(m.avgdl),
            k1=jnp.float32(m.k1),
            b=jnp.float32(m.b),
            n_docs=m.n_docs,
        )


def gather_query_blocks(state: SearchState, term_ids: jax.Array, max_blocks: int):
    """Gather (T, M) block indices + validity for one query's terms.

    term_ids: (T,) int32, -1 = pad. Returns docs (T,M,B) i32, tf (T,M,B) u8,
    bmax (T,M) f32 (0 where invalid), valid (T,M,1) bool.
    """
    tid = jnp.maximum(term_ids, 0)
    off = state.term_offsets[tid]                        # (T,)
    n_blk = state.term_offsets[tid + 1] - off            # (T,)
    m = jnp.arange(max_blocks, dtype=jnp.int32)          # (M,)
    blk = off[:, None] + m[None, :]                      # (T, M)
    valid = (m[None, :] < n_blk[:, None]) & (term_ids[:, None] >= 0)
    blk = jnp.where(valid, blk, 0)
    docs = state.block_docs[blk]                         # (T, M, B)
    tf = state.block_tf[blk]                             # (T, M, B)
    bmax = jnp.where(valid, state.block_max[blk], 0.0)   # (T, M)
    return docs, tf, bmax, valid[..., None]


def bm25_impacts(state: SearchState, term_ids: jax.Array, qtf: jax.Array,
                 docs: jax.Array, tf: jax.Array, valid: jax.Array,
                 *, use_kernel: bool = False) -> jax.Array:
    """Per-posting BM25 partial scores. (T,M,B) float32."""
    tid = jnp.maximum(term_ids, 0)
    idf = state.idf[tid] * qtf                            # (T,)
    dl = state.doc_len[jnp.minimum(docs, state.n_docs)]   # (T, M, B)
    if use_kernel:
        from repro.kernels import ops as kops
        imp = kops.bm25_block_scores(
            tf, dl, idf, state.k1, state.b, state.avgdl)
    else:
        tff = tf.astype(jnp.float32)
        denom = tff + state.k1 * (1.0 - state.b + state.b * dl / state.avgdl)
        imp = idf[:, None, None] * tff / denom
    pad = docs >= state.n_docs
    return jnp.where(valid & ~pad & (tf > 0), imp, 0.0)


def score_dense(state: SearchState, term_ids: jax.Array, qtf: jax.Array,
                *, max_blocks: int, use_kernel: bool = False) -> jax.Array:
    """One query's dense (n_docs,) BM25 scores — THE scoring core.

    gather → impacts → dense scatter-add, shared verbatim by the
    single-node searcher (`make_search_fn`) and the per-partition body of
    the mesh-level distributed path (`search.distributed._local_search`).
    """
    docs, tf, _, valid = gather_query_blocks(state, term_ids, max_blocks)
    docs = docs.astype(jnp.int32)        # block_docs may be uint16 (compact)
    imp = bm25_impacts(state, term_ids, qtf, docs, tf, valid,
                       use_kernel=use_kernel)
    return accumulate_dense(docs, imp, state.n_docs)


def pruned_keep(docs: jax.Array, imp: jax.Array, ub: jax.Array,
                valid: jax.Array, *, k: int, n_docs: int) -> jax.Array:
    """(T, M) bool keep mask for block-max pruning — the reference twin of
    the mask computed inside ``kernels/bm25_pruned._pruned_kernel``.

    Shares the kernel's θ / bound helpers so reference and kernel can never
    disagree on which blocks are skipped. ``ub`` is (T, M) ``qtf·block_max``
    zeroed where invalid; ``imp`` the full (T,M,B) impacts (only m=0 is
    read); first blocks are kept unconditionally (they seed θ).
    """
    from repro.kernels.bm25_pruned import (PRUNE_SAFETY, block_bounds,
                                           theta_lower_bound)
    T, M, _ = docs.shape
    bound = block_bounds(ub)
    first = jnp.arange(M, dtype=jnp.int32)[None, :] == 0         # (1, M)
    theta = theta_lower_bound(docs[:, 0], imp[:, 0], k, n_docs)
    return valid[..., 0] & (first | (bound * PRUNE_SAFETY >= theta))


def score_pruned(state: SearchState, term_ids: jax.Array, qtf: jax.Array,
                 *, max_blocks: int, k: int, use_kernel: bool = False,
                 use_topk_kernel: bool = False):
    """One query's block-max pruned top-k: (vals (k,), ids (k,) i32,
    touched () i32 = blocks actually scored).

    Requires k ≤ n_docs (``make_search_fn`` clamps). ``use_kernel=True``
    runs the fused Pallas pass (impacts + pruning + streaming top-k, no
    (T,M,B) intermediate and no HBM accumulator); otherwise a pure-JAX
    reference that zeroes skipped blocks' impacts before the dense
    scatter-add — adding 0.0 is a bitwise no-op for the non-negative sums
    here, so both are bit-identical to the dense path for every doc whose
    blocks are all kept, which covers every top-k doc (see the kernel
    module docstring for the losslessness argument).
    """
    docs, tf, bmax, valid = gather_query_blocks(state, term_ids, max_blocks)
    docs = docs.astype(jnp.int32)
    tf = jnp.where(valid, tf, jnp.uint8(0))   # invalid rows alias block 0
    ub = jnp.where(valid[..., 0], qtf[:, None] * bmax, 0.0)      # (T, M)
    if use_kernel:
        from repro.kernels import ops as kops
        tid = jnp.maximum(term_ids, 0)
        idf_q = state.idf[tid] * qtf                              # (T,)
        dl = state.doc_len[jnp.minimum(docs, state.n_docs)]
        return kops.bm25_pruned_topk(
            tf, dl, docs, idf_q, ub, valid[..., 0],
            state.k1, state.b, state.avgdl, k=k, n_docs=state.n_docs)
    imp = bm25_impacts(state, term_ids, qtf, docs, tf, valid)
    keep = pruned_keep(docs, imp, ub, valid, k=k, n_docs=state.n_docs)
    acc = accumulate_dense(docs, jnp.where(keep[..., None], imp, 0.0),
                           state.n_docs)
    if use_topk_kernel:
        from repro.kernels import ops as kops
        vals, ids = kops.topk(acc, k)
    else:
        vals, ids = jax.lax.top_k(acc, k)
    return vals, ids.astype(jnp.int32), jnp.sum(keep).astype(jnp.int32)


# -- accumulation strategies ----------------------------------------------------


def accumulate_dense(docs: jax.Array, impacts: jax.Array, n_docs: int) -> jax.Array:
    """Scatter-add into a dense (n_docs+1,) accumulator; last slot = dump."""
    acc = jnp.zeros(n_docs + 1, dtype=jnp.float32)
    d = jnp.minimum(docs.reshape(-1), n_docs)
    acc = acc.at[d].add(impacts.reshape(-1))
    return acc[:n_docs]


def accumulate_sorted(docs: jax.Array, impacts: jax.Array, n_docs: int,
                      k: int) -> tuple[jax.Array, jax.Array]:
    """Sort-and-segment-sum accumulation, returning top-k directly.

    The cummax prefix trick: after sorting pairs by doc id, the group total
    for the run ending at i is c[i] - p[start(i)] where c = inclusive cumsum
    and p = exclusive cumsum; p at group starts is recovered with a running
    max of p masked to starts (p is nondecreasing, impacts >= 0).
    """
    d = docs.reshape(-1)
    v = impacts.reshape(-1)
    order = jnp.argsort(d)
    d = d[order]
    v = v[order]
    c = jnp.cumsum(v)
    p = c - v                                            # exclusive prefix
    is_start = jnp.concatenate([jnp.ones(1, bool), d[1:] != d[:-1]])
    is_end = jnp.concatenate([d[1:] != d[:-1], jnp.ones(1, bool)])
    start_p = jax.lax.cummax(jnp.where(is_start, p, -jnp.inf))
    totals = jnp.where(is_end & (d < n_docs), c - start_p, -jnp.inf)
    if totals.shape[0] < k:                 # fewer postings than k: pad
        pad = k - totals.shape[0]
        totals = jnp.concatenate([totals, jnp.full(pad, -jnp.inf)])
        d = jnp.concatenate([d, jnp.full(pad, n_docs, d.dtype)])
    vals, pos = jax.lax.top_k(totals, k)
    ids = jnp.where(jnp.isfinite(vals), d[pos], n_docs)
    vals = jnp.where(jnp.isfinite(vals), vals, 0.0)
    return vals, ids.astype(jnp.int32)


# -- end-to-end search fns -------------------------------------------------------


def make_search_fn(n_docs: int, *, max_terms: int, max_blocks: int, k: int,
                   accumulator: str = "dense", use_kernel: bool = False,
                   use_topk_kernel: bool = False):
    """Build the stateless query-evaluation function (the 'Lambda body').

    Returns fn(state, term_ids (Q,T) i32, qtf (Q,T) f32) ->
    (scores (Q,k) f32, ids (Q,k) i32).
    """

    def one_query(state: SearchState, term_ids, qtf):
        if accumulator == "dense":
            acc = score_dense(state, term_ids, qtf, max_blocks=max_blocks,
                              use_kernel=use_kernel)
            kk = min(k, n_docs)          # a tiny partition may hold < k docs
            if use_topk_kernel:
                from repro.kernels import ops as kops
                vals, ids = kops.topk(acc, kk)
            else:
                vals, ids = jax.lax.top_k(acc, kk)
            if kk < k:                   # pad to the (Q, k) contract
                vals = jnp.concatenate([vals, jnp.zeros(k - kk, vals.dtype)])
                ids = jnp.concatenate(
                    [ids.astype(jnp.int32),
                     jnp.full(k - kk, n_docs, jnp.int32)])
            return vals, ids.astype(jnp.int32)
        elif accumulator == "sorted":
            docs, tf, _, valid = gather_query_blocks(state, term_ids, max_blocks)
            imp = bm25_impacts(state, term_ids, qtf, docs, tf, valid,
                               use_kernel=use_kernel)
            return accumulate_sorted(docs, imp, n_docs, k)
        elif accumulator == "pruned":
            kk = min(k, n_docs)          # θ needs "missing doc = score 0"
            vals, ids, _ = score_pruned(
                state, term_ids, qtf, max_blocks=max_blocks, k=kk,
                use_kernel=use_kernel, use_topk_kernel=use_topk_kernel)
            if kk < k:
                vals = jnp.concatenate([vals, jnp.zeros(k - kk, vals.dtype)])
                ids = jnp.concatenate(
                    [ids, jnp.full(k - kk, n_docs, jnp.int32)])
            return vals, ids
        raise ValueError(f"unknown accumulator {accumulator!r}")

    def search(state: SearchState, term_ids: jax.Array, qtf: jax.Array):
        return jax.vmap(lambda t, w: one_query(state, t, w))(term_ids, qtf)

    return search


# -- host-side query encoding ------------------------------------------------------


def encode_queries(vocab: dict[str, int], queries: list[str], *,
                   max_terms: int,
                   idf: np.ndarray | None = None) -> tuple[np.ndarray, np.ndarray]:
    """Tokenize + map to term ids + qtf weights, padded to (Q, T).

    When a query has more than ``max_terms`` distinct terms, pass ``idf`` to
    keep the highest-idf (most selective) terms — long queries then degrade
    by shedding stopword-ish terms instead of whatever dict order gives.
    """
    from collections import Counter

    from repro.index.tokenizer import tokenize

    Q = len(queries)
    tids = np.full((Q, max_terms), -1, dtype=np.int32)
    qtf = np.zeros((Q, max_terms), dtype=np.float32)
    for qi, q in enumerate(queries):
        counts = Counter(tokenize(q))
        items = [(vocab[t], c) for t, c in counts.items() if t in vocab]
        if idf is not None and len(items) > max_terms:
            items.sort(key=lambda tc: -float(idf[tc[0]]))
        items = items[:max_terms]
        for j, (tid, c) in enumerate(items):
            tids[qi, j] = tid
            qtf[qi, j] = c
    return tids, qtf
