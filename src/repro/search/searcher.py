"""Searcher: hydration + jitted query evaluation + document fetch.

The pieces assemble exactly like Figure 1 of the paper:

    client → Gateway → FaaSRuntime(search handler)
                         ├─ hydrate index   ← ObjectStore (S3)
                         ├─ evaluate query  (stateless JAX fn)
                         └─ fetch raw docs  ← KVStore (DynamoDB)
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import numpy as np

from repro.core.cache import HydrationCache
from repro.core.kvstore import KVStore
from repro.core.object_store import ObjectStore
from repro.core.refresh import AssetCatalog
from repro.index.builder import PackedIndex, read_segment
from repro.search.bm25 import SearchState, encode_queries, make_search_fn


@dataclasses.dataclass
class SearchConfig:
    max_terms: int = 16
    max_blocks: int = 64          # M: impact-ordered truncation per term
    k: int = 10
    accumulator: str = "dense"
    use_kernel: bool = False      # Pallas fused BM25 impacts
    use_topk_kernel: bool = False # Pallas streaming top-k
    # device→host transfer + deserialize throughput used to convert index
    # bytes into simulated hydration seconds (on top of store network time)
    hydrate_Bps: float = 2e9


class Searcher:
    """Holds the hydrated state + compiled search fn for one index version."""

    def __init__(self, packed: PackedIndex, config: SearchConfig | None = None):
        self.config = config or SearchConfig()
        self.packed = packed
        self.state = SearchState.from_packed(packed)
        self.vocab = packed.vocab
        cfg = self.config
        self._fn = jax.jit(make_search_fn(
            packed.meta.n_docs, max_terms=cfg.max_terms,
            max_blocks=cfg.max_blocks, k=cfg.k,
            accumulator=cfg.accumulator, use_kernel=cfg.use_kernel,
            use_topk_kernel=cfg.use_topk_kernel,
        ))

    def search(self, queries: list[str]) -> tuple[np.ndarray, np.ndarray]:
        tids, qtf = encode_queries(self.vocab, queries,
                                   max_terms=self.config.max_terms)
        vals, ids = self._fn(self.state, tids, qtf)
        return np.asarray(vals), np.asarray(ids)

    def search_one(self, query: str, k: int | None = None):
        vals, ids = self.search([query])
        hits = [(int(i), float(v)) for v, i in zip(vals[0], ids[0])
                if i < self.packed.meta.n_docs and v > 0]
        return hits[: (k or self.config.k)]


def hydrate_searcher(catalog: AssetCatalog, asset: str,
                     config: SearchConfig) -> tuple[Searcher, float]:
    """Cold-start hydration: resolve manifest, stream segment files through
    the StoreDirectory, unpack, compile. Returns (searcher, simulated_s)."""
    store = catalog.store
    before = store.stats.sim_seconds
    version, directory = catalog.open(asset)
    packed = read_segment(directory)
    network_s = store.stats.sim_seconds - before
    deserialize_s = packed.nbytes / config.hydrate_Bps
    return Searcher(packed, config), network_s + deserialize_s


def make_search_handler(catalog: AssetCatalog, doc_store: KVStore,
                        asset: str = "index",
                        config: SearchConfig | None = None):
    """Build the Lambda handler: (instance_cache, payload) -> (result, exec_s).

    The hydrated Searcher lives in the *instance's* HydrationCache — a warm
    instance skips straight to query evaluation (paper §2).
    """
    cfg = config or SearchConfig()

    def handler(cache: HydrationCache, payload: dict) -> tuple[dict, float]:
        version = catalog.current_version(asset)

        def _hydrate():
            searcher, sim_s = hydrate_searcher(catalog, asset, cfg)
            return searcher, sim_s

        searcher: Searcher = cache.get_or_hydrate(asset, version, _hydrate)

        query = payload["q"]
        k = int(payload.get("k", cfg.k))
        t0 = time.perf_counter()
        hits = searcher.search_one(query, k)
        exec_s = time.perf_counter() - t0

        ext = searcher.packed.meta.doc_ids
        ids = [h[0] for h in hits]
        raw = doc_store.batch_get([ext[i] for i in ids]) if payload.get(
            "fetch_docs", True) else {}
        exec_s += doc_store.model.batch_get_s if raw else 0.0
        return {
            "version": version,
            "ids": ids,
            "scores": [h[1] for h in hits],
            "docs": [raw.get(ext[i]) for i in ids] if raw else [],
        }, exec_s

    return handler
