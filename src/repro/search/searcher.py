"""Searcher: hydration + jitted query evaluation + document fetch.

The pieces assemble exactly like Figure 1 of the paper:

    client → Gateway → FaaSRuntime(search handler)
                         ├─ hydrate index   ← ObjectStore (S3)
                         ├─ evaluate query  (stateless JAX fn)
                         └─ fetch raw docs  ← KVStore (DynamoDB)
"""

from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.core.cache import HydrationCache
from repro.core.kvstore import KVStore
from repro.core.object_store import ObjectStore
from repro.core.refresh import GENERATION_FILE, AssetCatalog, generation_version
from repro.index.builder import (VECTOR_META_FILE, PackedIndex,
                                 combine_segments, combine_vector_segments,
                                 read_segment, read_vector_segment)
from repro.index.hydration import (LazyIndex, LazyVectors, SuperIndexMissing,
                                   open_partial_segment,
                                   open_partial_vector_segment)
from repro.index.tokenizer import tokenize
from repro.kernels.ops import dot_topk_batch
from repro.search.bm25 import SearchState, encode_queries, make_search_fn
from repro.search.query import query_from_payload
from repro.search.structured import (StructuredUnsupported,
                                     evaluate_structured, facet_counts,
                                     structured_topk)


@dataclasses.dataclass
class SearchConfig:
    max_terms: int = 16
    max_blocks: int = 64          # M: impact-ordered truncation per term
    k: int = 10
    accumulator: str = "dense"    # "dense" | "sorted" | "pruned" (block-max)
    use_kernel: bool = False      # Pallas fused BM25 impacts
    use_topk_kernel: bool = False # Pallas streaming top-k
    # device→host transfer + deserialize throughput used to convert index
    # bytes into simulated hydration seconds (on top of store network time)
    hydrate_Bps: float = 2e9
    # Deterministic exec-time model: when set, handlers report
    # sim_exec_s (+ sim_exec_per_query_s per extra batched query) as the
    # request's compute time instead of the measured wall time of the
    # jitted call. Results are still really computed — only the CLOCK is
    # modeled — so CI benchmarks produce machine-independent latencies and
    # ledger charges that a committed regression baseline can be diffed
    # against exactly. Leave None to measure (the paper's claims).
    sim_exec_s: float | None = None
    sim_exec_per_query_s: float = 0.0002
    # Per-1000-docs exec term of the model: evaluation work scales with the
    # partition's document count, so under a SKEWED partitioning a head
    # partition's handler models proportionally longer invocations — the
    # per-partition load heterogeneity B12 autoscales against. Default 0
    # keeps every pre-existing modeled benchmark bit-identical.
    sim_exec_per_kdoc_s: float = 0.0
    # Same idea for the NRT writer path: when set, indexer invocations
    # (delta pack / merge) report sim_write_s + sim_write_per_doc_s × docs
    # as their compute time — a commit's cost and rollover latency then
    # reproduce bit-for-bit in CI. Leave None to measure.
    sim_write_s: float | None = None
    sim_write_per_doc_s: float = 2e-5
    # Lazy (partial) hydration: a cold instance answers its first query from
    # range reads of the superindex + only the queried terms' posting blocks,
    # then backfills the rest OFF the critical path (billed to the ledger's
    # backfill line). Tri-state: None means "resolver's choice" — handlers
    # treat it as eager (bit-identical to the historical default) while
    # fleet assembly (build_partitioned_search_app) flips None→True, the
    # fleet default since PR 8. Pass an explicit bool to pin either mode.
    # Segments published before the lazy layout fall back to full hydration
    # automatically.
    lazy_hydration: bool | None = None


# How many highest-df terms a rollover prewarm ping hydrates on a lazy
# instance (instead of backfilling the whole partition). Head terms cover
# the bulk of query traffic, so the post-rollover cold-read tail shrinks
# while prewarm GET bytes stay a small fraction of the full index.
PREWARM_TOP_TERMS = 64


class DenseTierMissing(Exception):
    """This asset version carries no dense-vector tier."""


class Searcher:
    """Holds the hydrated state + compiled search fn for one index version."""

    def __init__(self, packed: PackedIndex, config: SearchConfig | None = None):
        self.config = config or SearchConfig()
        self.packed = packed
        self.state = SearchState.from_packed(packed)
        self.vocab = packed.vocab
        cfg = self.config
        self._fn = jax.jit(make_search_fn(
            packed.meta.n_docs, max_terms=cfg.max_terms,
            max_blocks=cfg.max_blocks, k=cfg.k,
            accumulator=cfg.accumulator, use_kernel=cfg.use_kernel,
            use_topk_kernel=cfg.use_topk_kernel,
        ))

    def search(self, queries: list[str]) -> tuple[np.ndarray, np.ndarray]:
        # Pad the batch to the next power of two: the jitted fn specializes
        # on Q, so micro-batched traffic compiles O(log max_batch) variants
        # instead of one per distinct batch size.
        Q = len(queries)
        Qp = 1 << max(0, (Q - 1).bit_length())
        tids, qtf = encode_queries(self.vocab, queries + [""] * (Qp - Q),
                                   max_terms=self.config.max_terms,
                                   idf=self.packed.idf)
        vals, ids = self._fn(self.state, tids, qtf)
        return np.asarray(vals)[:Q], np.asarray(ids)[:Q]

    def search_batch(self, queries: list[str],
                     k: int | None = None) -> list[list[tuple[int, float]]]:
        """Evaluate Q queries in ONE vmapped device call (the micro-batch
        path); returns per-query [(internal_id, score), ...] hit lists."""
        vals, ids = self.search(queries)
        n = self.packed.meta.n_docs
        out = []
        for qi in range(len(queries)):
            hits = [(int(i), float(v)) for v, i in zip(vals[qi], ids[qi])
                    if i < n and v > 0]
            out.append(hits[: (self.config.k if k is None else k)])
        return out

    def search_one(self, query: str, k: int | None = None):
        return self.search_batch([query], k)[0]


def hydrate_searcher(catalog: AssetCatalog, asset: str,
                     config: SearchConfig,
                     version: str | None = None) -> tuple[Searcher, float]:
    """Cold-start hydration: resolve manifest, stream segment files through
    the StoreDirectory, unpack, compile. Returns (searcher, simulated_s).

    Two version layouts hydrate through the same call:

    * a PLAIN version directory holding one segment's files (the original
      batch-publish path), read directly; or
    * a GENERATION manifest (NRT): base + ordered delta segments stream in
      and fuse into one PackedIndex (:func:`~repro.index.builder.
      combine_segments`) under the generation's live stats/vocab, with
      tombstones zeroed — so the compiled search fn never knows the index
      was built incrementally.
    """
    store = catalog.store
    before = store.stats.sim_seconds
    version, directory = catalog.open(asset, version)
    if GENERATION_FILE in directory.list():
        manifest = catalog.read_generation(asset, version)
        stats, vocab = catalog.resolve_generation_state(manifest)
        packs = [read_segment(catalog.open_segment(asset, seg))
                 for seg in manifest.segments]
        packed = combine_segments(packs, vocab=vocab, stats=stats,
                                  tombstones=manifest.tombstones)
    else:
        packed = read_segment(directory)
    network_s = store.stats.sim_seconds - before
    deserialize_s = packed.nbytes / config.hydrate_Bps
    return Searcher(packed, config), network_s + deserialize_s


class DenseSearcher:
    """Dense-tier twin of :class:`Searcher`: brute-force inner-product
    top-k over one partition's document embeddings via the fused
    ``dot_topk`` kernel, vmapped over the query micro-batch.

    Tombstoned rows are COMPACTED OUT before scoring (dense scores are
    legitimately negative, so masking-by-zero can't express deletion the
    way the sparse tier's tf-zeroing does); live rows keep their relative
    order, so internal-id ascending tie-breaks match a full rebuild.
    """

    def __init__(self, vectors: np.ndarray, doc_ids: list[str],
                 live: np.ndarray, config: SearchConfig | None = None):
        self.config = config or SearchConfig()
        self.doc_ids = doc_ids
        self.n_docs = len(doc_ids)
        vecs = np.asarray(vectors, dtype=np.float32)
        self.rows = np.ascontiguousarray(vecs[np.asarray(live, bool)])
        self.row_internal = np.flatnonzero(live).astype(np.int32)
        self.dim = vecs.shape[1] if vecs.ndim == 2 else 0
        self.nbytes = self.rows.nbytes

    def search_batch(self, qvecs, k: int | None = None
                     ) -> list[list[tuple[int, float]]]:
        """Score Q query vectors in ONE vmapped kernel call; returns
        per-query [(internal_id, score), ...] — same hit-list shape as the
        sparse tier, so the coordinator merges both identically."""
        Q = len(qvecs)
        n_live = self.rows.shape[0]
        want = self.config.k if k is None else min(k, self.config.k)
        if Q == 0 or n_live == 0:
            return [[] for _ in range(Q)]
        kk = min(self.config.k, n_live)
        # pow-2 batch pad, exactly like the sparse path: the jitted kernel
        # specializes on Q, padding bounds compile variants at O(log batch)
        Qp = 1 << max(0, (Q - 1).bit_length())
        qarr = np.zeros((Qp, self.rows.shape[1]), dtype=np.float32)
        for i, v in enumerate(qvecs):
            qarr[i] = np.asarray(v, dtype=np.float32)
        vals, ids = dot_topk_batch(qarr, self.rows, kk)
        vals = np.asarray(vals)[:Q]
        ids = np.asarray(ids)[:Q]
        out = []
        for qi in range(Q):
            hits = [(int(self.row_internal[i]), float(v))
                    for v, i in zip(vals[qi], ids[qi])]
            out.append(hits[:want])
        return out


def hydrate_dense_searcher(catalog: AssetCatalog, asset: str,
                           config: SearchConfig,
                           version: str | None = None
                           ) -> tuple[DenseSearcher, float]:
    """Eager dense-tier hydration: stream the generation's vector segments
    (base + deltas), fuse rows in segment order — the SAME internal-id
    space the sparse tier's ``combine_segments`` builds — and flag the
    generation's tombstones dead. Returns (searcher, simulated_s).

    Raises :class:`DenseTierMissing` when the version has no vector tier
    (sparse-only fleets); callers surface that as a bad-request, not a 500.
    """
    store = catalog.store
    before = store.stats.sim_seconds
    version, directory = catalog.open(asset, version)
    if GENERATION_FILE in directory.list():
        manifest = catalog.read_generation(asset, version)
        if manifest.vec_base is None:
            raise DenseTierMissing(asset)
        packs = [read_vector_segment(catalog.open_segment(asset, seg))
                 for seg in manifest.vec_segments]
        vectors, doc_ids, live = combine_vector_segments(
            packs, tombstones=manifest.tombstones)
    else:
        if VECTOR_META_FILE not in directory.list():
            raise DenseTierMissing(asset)
        vectors, doc_ids, live = combine_vector_segments(
            [read_vector_segment(directory)])
    network_s = store.stats.sim_seconds - before
    searcher = DenseSearcher(vectors, doc_ids, live, config)
    return searcher, network_s + searcher.nbytes / config.hydrate_Bps


class LazyDenseSearcher:
    """Cache entry for a lazily-hydrated dense tier.

    Cold start reads each vector segment's compact superindex (one ranged
    GET), then :meth:`ensure_live` range-reads exactly the LIVE row spans —
    tombstoned rows never move, so there is no backfill stage: once the
    live rows are resident the view is complete and queries are
    bit-identical to eager hydration.
    """

    def __init__(self, lazy: LazyVectors, config: SearchConfig,
                 store: ObjectStore) -> None:
        self.lazy = lazy
        self.config = config
        self._store = store
        self._searcher: DenseSearcher | None = None

    @property
    def nbytes(self) -> int:
        return self.lazy.bytes_read

    def ensure_live(self) -> tuple[bool, float]:
        """Hydrate every live row span; (changed, sim_s) priced like
        :meth:`LazySearcher._billed` (network + deserialize of new bytes)."""
        net0 = self._store.stats.sim_seconds
        bytes0 = self.lazy.bytes_read
        changed = self.lazy.ensure_live()
        sim_s = (self._store.stats.sim_seconds - net0
                 + (self.lazy.bytes_read - bytes0) / self.config.hydrate_Bps)
        if changed:
            self._searcher = None
        return changed, sim_s

    @property
    def searcher(self) -> DenseSearcher:
        if self._searcher is None:
            vectors, doc_ids, live = self.lazy.combined()
            self._searcher = DenseSearcher(vectors, doc_ids, live, self.config)
        return self._searcher


def lazy_hydrate_dense_searcher(catalog: AssetCatalog, asset: str,
                                config: SearchConfig,
                                version: str | None = None
                                ) -> tuple[LazyDenseSearcher, float]:
    """Lazy twin of :func:`hydrate_dense_searcher`: superindex-only cold
    read. Raises :class:`DenseTierMissing` when the version carries no
    vector tier, :class:`SuperIndexMissing` for pre-lazy vector segments
    (callers fall back to eager)."""
    store = catalog.store
    before = store.stats.sim_seconds
    version, directory = catalog.open(asset, version)
    if GENERATION_FILE in directory.list():
        manifest = catalog.read_generation(asset, version)
        if manifest.vec_base is None:
            raise DenseTierMissing(asset)
        segments = [open_partial_vector_segment(catalog.open_segment(asset, s))
                    for s in manifest.vec_segments]
        lazy = LazyVectors(segments, tombstones=manifest.tombstones)
    else:
        if VECTOR_META_FILE not in directory.list():
            raise DenseTierMissing(asset)
        lazy = LazyVectors([open_partial_vector_segment(directory)])
    network_s = store.stats.sim_seconds - before
    deserialize_s = lazy.bytes_read / config.hydrate_Bps
    return LazyDenseSearcher(lazy, config, store), network_s + deserialize_s


class LazySearcher:
    """Cache entry for a lazily-hydrated index version.

    Wraps a :class:`~repro.index.hydration.LazyIndex` and lends out a
    compiled :class:`Searcher` over its CURRENT view. The view's arrays are
    full-shape from the first byte (absent terms masked non-live), so every
    rebuild after incremental hydration reuses the same jit specialization;
    results over hydrated terms are bit-identical to full hydration.
    """

    def __init__(self, index: LazyIndex, config: SearchConfig,
                 store: ObjectStore) -> None:
        self.index = index
        self.config = config
        self._store = store           # billing seam: range-read sim seconds
        self._searcher: Searcher | None = None

    @property
    def full(self) -> bool:
        return self.index.state == "full"

    @property
    def nbytes(self) -> int:
        # what the cache's byte budget sees: the bytes actually streamed
        # into this instance so far (grows partial → full via note_backfill)
        return self.index.bytes_read

    def _billed(self, action) -> tuple[bool, float]:
        """Run ``action() -> changed`` and price it: store network seconds
        (range-read first-byte + bandwidth) + deserialize time for the new
        bytes. Invalidates the lent-out Searcher when the view grew."""
        net0 = self._store.stats.sim_seconds
        bytes0 = self.index.bytes_read
        changed = action()
        sim_s = (self._store.stats.sim_seconds - net0
                 + (self.index.bytes_read - bytes0) / self.config.hydrate_Bps)
        if changed:
            self._searcher = None
        return changed, sim_s

    def ensure_queries(self, queries: list[str]) -> tuple[bool, float]:
        """Hydrate the posting blocks every term of ``queries`` names;
        (changed, sim_s). On-critical-path: callers account ``sim_s`` as
        hydration."""
        return self.ensure_terms(
            {t for q in queries for t in tokenize(q)})

    def ensure_terms(self, terms) -> tuple[bool, float]:
        """Hydrate specific terms' posting blocks — the structured path
        hands in its ASTs' term set directly (the same coalesced ranged
        GETs also pull those rows' field/position payload on v2
        segments). Priced exactly like :meth:`ensure_queries`."""
        terms = set(terms)
        return self._billed(lambda: self.index.ensure_terms(terms))

    def ensure_top_terms(self, n: int) -> tuple[bool, float]:
        """Hydrate the ``n`` highest-document-frequency terms' blocks —
        the rollover-prewarm working set. (changed, sim_s), priced like
        :meth:`ensure_queries`."""
        terms = self.index.top_terms(n)
        return self._billed(lambda: self.index.ensure_terms(terms))

    def backfill(self) -> tuple[bool, float]:
        """Upgrade partial → full; (changed, sim_s). Off-critical-path:
        callers account ``sim_s`` as backfill, never latency."""
        return self._billed(self.index.backfill)

    @property
    def searcher(self) -> Searcher:
        if self._searcher is None:
            self._searcher = Searcher(self.index.packed(), self.config)
        return self._searcher


def lazy_hydrate_searcher(catalog: AssetCatalog, asset: str,
                          config: SearchConfig,
                          version: str | None = None
                          ) -> tuple[LazySearcher, float]:
    """Partial cold-start hydration: ONE ranged GET per segment pulls the
    compact superindex (term extents + block_max + doc lengths + idf); no
    posting payload moves yet. Returns (entry, simulated_s) — the lazy
    replacement for :func:`hydrate_searcher`'s full streaming.

    Raises :class:`~repro.index.hydration.SuperIndexMissing` for segments
    published before the lazy layout; callers fall back to full hydration.
    """
    store = catalog.store
    before = store.stats.sim_seconds
    version, directory = catalog.open(asset, version)
    if GENERATION_FILE in directory.list():
        manifest = catalog.read_generation(asset, version)
        stats, vocab = catalog.resolve_generation_state(manifest)
        segments = [open_partial_segment(catalog.open_segment(asset, seg))
                    for seg in manifest.segments]
        index = LazyIndex(segments, vocab=vocab, stats=stats,
                          tombstones=manifest.tombstones)
    else:
        index = LazyIndex([open_partial_segment(directory)])
    network_s = store.stats.sim_seconds - before
    deserialize_s = index.bytes_read / config.hydrate_Bps
    return LazySearcher(index, config, store), network_s + deserialize_s


def make_search_handler(catalog: AssetCatalog, doc_store: KVStore,
                        asset: str = "index",
                        config: SearchConfig | None = None):
    """Build the Lambda handler: (instance_cache, payload) -> (result, exec_s).

    The hydrated Searcher lives in the *instance's* HydrationCache — a warm
    instance skips straight to query evaluation (paper §2).

    Payloads carry either ``q`` (one query → flat result) or ``queries``
    (micro-batch → ``{"results": [...]}``, one vmapped device call for the
    whole batch — how the gateway absorbs concurrent traffic without one
    invocation per query).

    STRUCTURED payloads carry ``sq`` (one AST payload dict) or ``sqs`` (a
    micro-batch of them) instead of text — the coordinator parsed the DSL
    at admission; workers never re-parse. They evaluate host-side over
    the v2 packed arrays (:func:`~repro.search.structured.
    evaluate_structured`, bit-identical across partitioning), honouring
    ``facets`` (per-query facet-field requests, counted over the full
    eligible set) and ``favg`` (the generation's live per-field avgdls).
    Requires a segment published with field/position data — a structured
    payload against a v1 segment raises
    :class:`~repro.search.structured.StructuredUnsupported`.

    ``payload["mode"]`` selects the tier(s): ``"sparse"`` (BM25, the
    default — pre-hybrid payloads are unchanged), ``"dense"`` (embedding
    inner-product via the ``dot_topk`` kernel; query vectors arrive as
    ``qv``/``qvs``, embedded at the coordinator so every replica scores
    identical floats), or ``"hybrid"`` (both tiers evaluated on the SAME
    instance against the SAME pinned generation; dense hit lists ride along
    under ``result["dense"]`` for the coordinator's RRF fusion). Each tier
    hydrates only when a payload needs it — a sparse-only workload never
    touches vector bytes — and dense entries are cached under
    ``version + "+vec"`` so eviction drops both tiers together. Responses
    that served the dense tier stamp ``vec_version`` so the coordinator's
    generation check can refuse cross-tier generation skew.

    ``payload["prewarm_terms"]`` (with optional ``prewarm_dense``) marks a
    rollover-prewarm ping: hydrate the n highest-df terms' blocks (and the
    dense tier's live rows) on a lazy instance WITHOUT evaluating a query
    and WITHOUT triggering backfill.

    ``payload["gen"]`` (an int) PINS the index generation: the handler
    serves exactly that generation, hydrating it if this instance hasn't
    seen it yet (old generations stay readable until gc). The coordinator
    resolves the serving generation ONCE per query and pins every scatter
    leg — primaries, hedged backups, freshly-scaled replicas — so no query
    can ever merge hits across index generations, even when a commit's
    rollover lands mid-scatter. Unpinned payloads resolve the asset
    manifest's current version (the single-function app's path).
    """
    cfg = config or SearchConfig()
    lazy = bool(cfg.lazy_hydration)   # None (resolver's choice) → eager

    def handler(cache: HydrationCache, payload: dict) -> tuple[dict, float]:
        gen = payload.get("gen")
        version = (generation_version(gen) if gen is not None
                   else catalog.current_version(asset))
        mode = payload.get("mode", "sparse")
        if mode not in ("sparse", "dense", "hybrid"):
            raise ValueError(f"unknown search mode: {mode!r}")

        def _hydrate():
            if lazy:
                try:
                    return lazy_hydrate_searcher(catalog, asset, cfg, version)
                except SuperIndexMissing:
                    pass   # pre-lazy-layout segment: eager fallback
            return hydrate_searcher(catalog, asset, cfg, version)

        def _hydrate_dense():
            # cached under version+"+vec": HydrationCache.invalidate(asset)
            # drops every version of every key for the asset name, so both
            # tiers evict together on rollover/budget pressure
            if lazy:
                try:
                    dentry, sim_s = lazy_hydrate_dense_searcher(
                        catalog, asset, cfg, version)
                    # the live rows ARE the dense working set — pull them
                    # inside the hydration charge (header + live spans;
                    # tombstoned rows never move, so no backfill stage)
                    _, more = dentry.ensure_live()
                    return dentry, sim_s + more
                except SuperIndexMissing:
                    pass   # pre-lazy vector segment: eager fallback
            return hydrate_dense_searcher(catalog, asset, cfg, version)

        # Rollover prewarm ping: warm the head-term working set (and the
        # dense tier when asked) without evaluating a query and without
        # backfilling — hot terms serve warm post-rollover while the cold
        # tail still lazy-loads on demand.
        if "prewarm_terms" in payload:
            entry = cache.get_or_hydrate(asset, version, _hydrate)
            if isinstance(entry, LazySearcher) and not entry.full:
                changed, sim_s = entry.ensure_top_terms(
                    int(payload["prewarm_terms"]))
                if changed:
                    cache.note_hydration(sim_s)
            if payload.get("prewarm_dense"):
                cache.get_or_hydrate(asset, version + "+vec", _hydrate_dense)
            return {"version": version, "prewarmed": True}, 0.0

        need_sparse = mode in ("sparse", "hybrid")
        need_dense = mode in ("dense", "hybrid")
        batched = ("queries" in payload or "qvs" in payload
                   or "sqs" in payload)
        queries = (list(payload["queries"]) if "queries" in payload
                   else [payload["q"]] if "q" in payload else [])
        qvecs = (list(payload["qvs"]) if "qvs" in payload
                 else [payload["qv"]] if "qv" in payload else [])
        # structured (format-v2) queries arrive as admission-parsed AST
        # payloads (sq/sqs) — never re-parsed here — with per-query facet
        # requests and the generation's live field avgdls (favg)
        sq_payloads = (list(payload["sqs"]) if "sqs" in payload
                       else [payload["sq"]] if "sq" in payload else None)
        if sq_payloads is not None and mode != "sparse":
            raise StructuredUnsupported(
                "structured queries are sparse-tier only")
        k = int(payload.get("k", cfg.k))
        n_q = (len(sq_payloads) if sq_payloads is not None
               else len(qvecs) if mode == "dense" else len(queries))
        if need_dense and len(qvecs) != n_q:
            raise ValueError("hybrid query needs one vector per text query")

        t0 = time.perf_counter()
        exec_s = 0.0
        sparse_hits = dense_hits = facets_out = None
        searcher = dsearcher = None
        entry = None
        if need_sparse:
            entry = cache.get_or_hydrate(asset, version, _hydrate)
            if sq_payloads is not None:
                queries_ast = [query_from_payload(d) for d in sq_payloads]
                if isinstance(entry, LazySearcher):
                    # pull exactly the ASTs' term blocks — the same
                    # coalesced ranged GETs bring the v2 field/position
                    # rows along at the wider pitch
                    changed, sim_s = entry.ensure_terms(
                        {t for q in queries_ast for t in q.terms})
                    if changed:
                        cache.note_hydration(sim_s)
                    searcher = entry.searcher
                else:
                    searcher = entry
                packed = searcher.packed
                if packed.fields is None:
                    raise StructuredUnsupported(
                        "structured query against a v1 segment (publish "
                        "with IndexSpec(structured=True, ...))")
                favg = payload.get("favg") or {}
                facet_req = payload.get("facets") or [[]] * n_q
                n_docs = packed.meta.n_docs
                sparse_hits, facets_out = [], []
                for qi, ast in enumerate(queries_ast):
                    # host-side dense evaluation — ALWAYS, even on pruned
                    # fleets: field/phrase-modified impacts invalidate the
                    # v1 block_max ceilings, so block-max pruning would be
                    # unsound for structured queries
                    scores, eligible = evaluate_structured(
                        packed, ast, field_avgdl=favg)
                    vals, ids = structured_topk(scores, k)
                    sparse_hits.append(
                        [(int(i), float(v)) for v, i in zip(vals, ids)
                         if i < n_docs and v > 0])
                    facets_out.append(
                        {f: facet_counts(packed, eligible, f)
                         for f in facet_req[qi]})
            else:
                if isinstance(entry, LazySearcher):
                    # pull exactly this batch's term blocks — on the
                    # critical path, so it accounts as hydration (a warm
                    # instance whose view already covers the terms pays
                    # nothing here)
                    changed, sim_s = entry.ensure_queries(queries)
                    if changed:
                        cache.note_hydration(sim_s)
                    searcher = entry.searcher
                else:
                    searcher = entry
                sparse_hits = searcher.search_batch(queries, k)
            if cfg.sim_exec_s is not None:
                exec_s += (cfg.sim_exec_s
                           + cfg.sim_exec_per_query_s * (n_q - 1)
                           + cfg.sim_exec_per_kdoc_s
                           * searcher.packed.meta.n_docs / 1000.0)
        if need_dense:
            dentry = cache.get_or_hydrate(asset, version + "+vec",
                                          _hydrate_dense)
            dsearcher = (dentry.searcher
                         if isinstance(dentry, LazyDenseSearcher) else dentry)
            dense_hits = dsearcher.search_batch(qvecs, k)
            if cfg.sim_exec_s is not None:
                # each tier is its own device call, so the model charges
                # the per-invocation base once per tier
                exec_s += (cfg.sim_exec_s
                           + cfg.sim_exec_per_query_s * (n_q - 1)
                           + cfg.sim_exec_per_kdoc_s
                           * dsearcher.n_docs / 1000.0)
        if cfg.sim_exec_s is None:
            exec_s = time.perf_counter() - t0

        primary = sparse_hits if need_sparse else dense_hits
        ext_sparse = searcher.packed.meta.doc_ids if searcher else None
        ext_dense = dsearcher.doc_ids if dsearcher is not None else None
        primary_ext = ext_sparse if need_sparse else ext_dense
        fetch = payload.get("fetch_docs", True)
        # ONE batched KV fetch for the whole micro-batch — the per-query
        # round trip would otherwise eat the batching amortization. Hybrid
        # unions both tiers' hit ids so fused results materialize from one
        # round trip too.
        keys = dict.fromkeys(primary_ext[h[0]]
                             for hits in primary for h in hits)
        if mode == "hybrid":
            keys.update(dict.fromkeys(ext_dense[h[0]]
                                      for hits in dense_hits for h in hits))
        raw, fetch_s = doc_store.batch_get_billed(keys) if fetch else ({}, 0.0)
        exec_s += fetch_s
        results = []
        for qi in range(n_q):
            hits = primary[qi]
            ids = [h[0] for h in hits]
            ext_ids = [primary_ext[i] for i in ids]
            r = {
                "ids": ids,
                "scores": [h[1] for h in hits],
                "ext_ids": ext_ids,
                "docs": [raw.get(e) for e in ext_ids] if raw else [],
            }
            if facets_out is not None:
                # per-partition scatter-add over the FULL eligible match
                # set; the coordinator merges these at gather like top-k
                r["facets"] = facets_out[qi]
            if mode == "hybrid":
                dh = dense_hits[qi]
                r["dense"] = {
                    "ids": [h[0] for h in dh],
                    "scores": [h[1] for h in dh],
                    "ext_ids": [ext_dense[h[0]] for h in dh],
                }
            results.append(r)
        # response is fully computed — NOW backfill partial → full, off the
        # critical path: the runtime bills the cache's backfill delta to its
        # own ledger line and excludes it from this request's latency
        if (need_sparse and isinstance(entry, LazySearcher)
                and not entry.full):
            _, bf_s = entry.backfill()
            cache.note_backfill(asset, version, bf_s, nbytes=entry.nbytes)

        if batched:
            out = {"version": version, "results": results}
        else:
            out = results[0]
            out["version"] = version
        if need_dense:
            out["vec_version"] = version
        return out, exec_s

    return handler
