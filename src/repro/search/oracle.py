"""Exact reference searchers — the correctness oracles for the fleet.

:class:`OracleSearcher` is dict-based BM25: the same Lucene variant as the
builder (no (k1+1) numerator), with the same uint8 tf clamp, so the blocked
JAX path must match to float tolerance whenever block truncation (M) does
not drop postings.

:class:`DenseOracleSearcher` is the dense tier's twin: brute-force inner
products over the full corpus via the kernel's bitwise-matching pure-JAX
reference (``dot_topk_batch_ref``), so per-partition fleet scores must be
uint32-BIT-identical, not merely close. ``hybrid_oracle_fuse`` runs the
same Reciprocal Rank Fusion the coordinator runs, over the two oracles'
rankings — the hybrid tier's end-to-end pin.
"""

from __future__ import annotations

import math
from collections import Counter
from typing import Any, Callable, Sequence

import numpy as np

from repro.core.partition import rrf_fuse
from repro.index.tokenizer import tokenize
from repro.kernels.ref import dot_topk_batch_ref


class OracleSearcher:
    def __init__(self, docs: list[tuple[str, str]], *, k1: float = 0.9,
                 b: float = 0.4) -> None:
        self.k1, self.b = k1, b
        self.doc_ids = [d for d, _ in docs]
        self.doc_toks = [tokenize(t) for _, t in docs]
        self.doc_len = [len(t) for t in self.doc_toks]
        self.avgdl = sum(self.doc_len) / max(1, len(self.doc_len))
        self.postings: dict[str, dict[int, int]] = {}
        for i, toks in enumerate(self.doc_toks):
            for t, tf in Counter(toks).items():
                self.postings.setdefault(t, {})[i] = min(tf, 255)
        self.n_docs = len(docs)

    def idf(self, term: str) -> float:
        df = len(self.postings.get(term, {}))
        return math.log(1.0 + (self.n_docs - df + 0.5) / (df + 0.5))

    def search(self, query: str, k: int = 10) -> list[tuple[int, float]]:
        scores: dict[int, float] = {}
        for term, qtf in Counter(tokenize(query)).items():
            plist = self.postings.get(term)
            if not plist:
                continue
            idf = self.idf(term)
            for doc, tf in plist.items():
                dl = self.doc_len[doc]
                denom = tf + self.k1 * (1 - self.b + self.b * dl / self.avgdl)
                scores[doc] = scores.get(doc, 0.0) + qtf * idf * tf / denom
        ranked = sorted(scores.items(), key=lambda kv: (-kv[1], kv[0]))
        return ranked[:k]


class DenseOracleSearcher:
    """Exact dense ranking over the FULL corpus, scored by the kernel's
    bitwise reference.

    Index ``docs`` in the fleet's ``live_corpus()`` order: global index i
    here is then (partition, internal id) in ascending order, so the
    fleet's cross-partition (-score, partition, doc_id) merge and this
    oracle's (-score, index) ranking share tie-breaks exactly.
    """

    def __init__(self, docs: list[tuple[str, str]],
                 embedder: "Callable[[str], Any]") -> None:
        self.doc_ids = [d for d, _ in docs]
        self.embedder = embedder
        if docs:
            self.vectors = np.stack([embedder(t) for _, t in docs]
                                    ).astype(np.float32)
        else:
            self.vectors = np.zeros((0, 1), dtype=np.float32)

    def search(self, query: "str | Sequence[float]",
               k: int = 10) -> list[tuple[int, float]]:
        """Top-k (global index, score); ``query`` is text (embedded here,
        exactly as the coordinator embeds) or a pre-computed vector."""
        n = self.vectors.shape[0]
        if n == 0:
            return []
        qv = (self.embedder(query) if isinstance(query, str)
              else np.asarray(query, dtype=np.float32))
        kk = min(k, n)
        vals, ids = dot_topk_batch_ref(qv[None, :].astype(np.float32),
                                       self.vectors, kk)
        return [(int(i), float(v))
                for v, i in zip(np.asarray(vals)[0], np.asarray(ids)[0])]


def hybrid_oracle_fuse(sparse_ranked: Sequence[tuple[int, float]],
                       dense_ranked: Sequence[tuple[int, float]],
                       k: int) -> list[tuple[int, float]]:
    """RRF-fuse the two oracles' (global index, score) rankings with the
    SAME ``rrf_fuse`` call the fleet coordinator makes, in the same
    (sparse, dense) tier order — fused scores are bit-identical to the
    fleet's, and the keys are global doc indices."""
    return rrf_fuse([[d for d, _ in sparse_ranked],
                     [d for d, _ in dense_ranked]], k)
