"""Exact reference searchers — the correctness oracles for the fleet.

:class:`OracleSearcher` is dict-based BM25: the same Lucene variant as the
builder (no (k1+1) numerator), with the same uint8 tf clamp, so the blocked
JAX path must match to float tolerance whenever block truncation (M) does
not drop postings.

:class:`DenseOracleSearcher` is the dense tier's twin: brute-force inner
products over the full corpus via the kernel's bitwise-matching pure-JAX
reference (``dot_topk_batch_ref``), so per-partition fleet scores must be
uint32-BIT-identical, not merely close. ``hybrid_oracle_fuse`` runs the
same Reciprocal Rank Fusion the coordinator runs, over the two oracles'
rankings — the hybrid tier's end-to-end pin.

:class:`StructuredOracleSearcher` extends the pin to the v2 structured
surface: it packs the FULL corpus into one v2 segment and evaluates with
the very same :mod:`repro.search.structured` functions the fleet's
partitions run — top-k scores must be BIT-identical through the merge,
facet counts and phrase match sets exactly equal. Its ``exact_*``
methods are an independent dict-based twin computed straight from raw
text (applying the format's documented POS_SLOTS truncation rule), so
tests can pin the packed evaluator against an implementation that shares
none of its code.
"""

from __future__ import annotations

import math
from collections import Counter
from typing import Any, Callable, Sequence

import numpy as np

from repro.core.partition import rrf_fuse
from repro.index.tokenizer import tokenize
from repro.kernels.ref import dot_topk_batch_ref


class OracleSearcher:
    def __init__(self, docs: list[tuple[str, str]], *, k1: float = 0.9,
                 b: float = 0.4) -> None:
        self.k1, self.b = k1, b
        self.doc_ids = [d for d, _ in docs]
        self.doc_toks = [tokenize(t) for _, t in docs]
        self.doc_len = [len(t) for t in self.doc_toks]
        self.avgdl = sum(self.doc_len) / max(1, len(self.doc_len))
        self.postings: dict[str, dict[int, int]] = {}
        for i, toks in enumerate(self.doc_toks):
            for t, tf in Counter(toks).items():
                self.postings.setdefault(t, {})[i] = min(tf, 255)
        self.n_docs = len(docs)

    def idf(self, term: str) -> float:
        df = len(self.postings.get(term, {}))
        return math.log(1.0 + (self.n_docs - df + 0.5) / (df + 0.5))

    def search(self, query: str, k: int = 10) -> list[tuple[int, float]]:
        scores: dict[int, float] = {}
        for term, qtf in Counter(tokenize(query)).items():
            plist = self.postings.get(term)
            if not plist:
                continue
            idf = self.idf(term)
            for doc, tf in plist.items():
                dl = self.doc_len[doc]
                denom = tf + self.k1 * (1 - self.b + self.b * dl / self.avgdl)
                scores[doc] = scores.get(doc, 0.0) + qtf * idf * tf / denom
        ranked = sorted(scores.items(), key=lambda kv: (-kv[1], kv[0]))
        return ranked[:k]


class DenseOracleSearcher:
    """Exact dense ranking over the FULL corpus, scored by the kernel's
    bitwise reference.

    Index ``docs`` in the fleet's ``live_corpus()`` order: global index i
    here is then (partition, internal id) in ascending order, so the
    fleet's cross-partition (-score, partition, doc_id) merge and this
    oracle's (-score, index) ranking share tie-breaks exactly.
    """

    def __init__(self, docs: list[tuple[str, str]],
                 embedder: "Callable[[str], Any]") -> None:
        self.doc_ids = [d for d, _ in docs]
        self.embedder = embedder
        if docs:
            self.vectors = np.stack([embedder(t) for _, t in docs]
                                    ).astype(np.float32)
        else:
            self.vectors = np.zeros((0, 1), dtype=np.float32)

    def search(self, query: "str | Sequence[float]",
               k: int = 10) -> list[tuple[int, float]]:
        """Top-k (global index, score); ``query`` is text (embedded here,
        exactly as the coordinator embeds) or a pre-computed vector."""
        n = self.vectors.shape[0]
        if n == 0:
            return []
        qv = (self.embedder(query) if isinstance(query, str)
              else np.asarray(query, dtype=np.float32))
        kk = min(k, n)
        vals, ids = dot_topk_batch_ref(qv[None, :].astype(np.float32),
                                       self.vectors, kk)
        return [(int(i), float(v))
                for v, i in zip(np.asarray(vals)[0], np.asarray(ids)[0])]


class StructuredOracleSearcher:
    """Exact structured retrieval over the full corpus — the fleet's pin
    for fielded scoring, phrases, facets, and match sets.

    Scores come from ONE full-corpus v2 pack evaluated by the shared
    :func:`repro.search.structured.evaluate_structured` (bit-parity with
    the partitioned fleet is structural: every per-leaf input is global or
    per-doc). The ``exact_*`` twins recompute match sets and facet counts
    from raw text with the identical stored-occurrence truncation, sharing
    no code with the packer — the independent cross-check."""

    def __init__(self, docs: "list[tuple[str, Any]]", *,
                 facet_fields: Sequence[str] = (), k1: float = 0.9,
                 b: float = 0.4) -> None:
        from repro.index.builder import (IndexWriter, POS_SLOTS,
                                         compute_global_stats, field_avgdl)
        self.docs = list(docs)
        self.doc_ids = [d for d, _ in self.docs]
        self.pos_slots = POS_SLOTS
        w = IndexWriter(k1=k1, b=b, structured=True,
                        facet_fields=tuple(facet_fields))
        for ext_id, text in self.docs:
            w.add(ext_id, text)
        self.packed = w.pack()
        stats = compute_global_stats(self.docs, fields=True)
        self.field_avgdl = {f: field_avgdl(stats, f)
                            for f in stats.get("fields", {})}

    def _query(self, query):
        from repro.search.query import Query, parse_query
        return query if isinstance(query, Query) else parse_query(query)

    def evaluate(self, query) -> tuple["np.ndarray", "np.ndarray"]:
        from repro.search.structured import evaluate_structured
        return evaluate_structured(self.packed, self._query(query),
                                   field_avgdl=self.field_avgdl)

    def search(self, query, k: int = 10) -> list[tuple[int, float]]:
        """Top-k (global doc index, f32 score), ties (-score, index) —
        the same order the fleet's (-score, partition, doc_id) merge
        induces on ``live_corpus()`` global indices."""
        from repro.search.structured import structured_topk
        scores, _ = self.evaluate(query)
        vals, ids = structured_topk(scores, k)
        return [(int(i), float(v)) for v, i in zip(vals, ids) if v > 0.0]

    def match_set(self, query) -> set[int]:
        _, eligible = self.evaluate(query)
        import numpy as _np
        return set(_np.nonzero(eligible)[0].tolist())

    def facet_counts(self, query, facet_field: str) -> dict[str, int]:
        from repro.search.structured import facet_counts
        _, eligible = self.evaluate(query)
        return facet_counts(self.packed, eligible, facet_field)

    # -- independent dict-based twins (no packed-array code shared) --------

    def _stored_occurrences(self, text) -> dict[str, list[tuple[str, int]]]:
        """term -> first POS_SLOTS (field, position) occurrences, in
        tokenize_positions order — the format's truncation rule restated
        from the raw text."""
        from repro.index.tokenizer import tokenize_positions
        occ: dict[str, list[tuple[str, int]]] = {}
        for fld, tok, pos in tokenize_positions(text):
            lst = occ.setdefault(tok, [])
            if len(lst) < self.pos_slots:
                lst.append((fld, pos))
        return occ

    def _leaf_matches(self, leaf, text) -> bool:
        occ = self._stored_occurrences(text)
        if leaf.kind == "term":
            t = leaf.terms[0]
            if leaf.field is None:
                return t in occ      # every present term stores ≥1 occurrence
            return any(f == leaf.field for f, _ in occ.get(t, ()))
        sets = [set(occ.get(t, ())) for t in leaf.terms]
        if not all(sets):
            return False
        for f, p in sets[0]:
            if leaf.field is not None and f != leaf.field:
                continue
            if all((f, p + i) in sets[i] for i in range(1, len(sets))):
                return True
        return False

    def exact_match_set(self, query) -> set[int]:
        q = self._query(query)
        if not q.leaves:
            return set()
        out = set()
        for i, (_, text) in enumerate(self.docs):
            hits = sum(self._leaf_matches(lf, text) for lf in q.leaves)
            ok = hits == len(q.leaves) if q.conjunctive else hits > 0
            if ok:
                out.add(i)
        return out

    def exact_facet_counts(self, query, facet_field: str) -> dict[str, int]:
        from repro.index.tokenizer import field_items
        counts: dict[str, int] = {}
        for i in self.exact_match_set(query):
            val = dict(field_items(self.docs[i][1])).get(facet_field)
            if val:
                counts[str(val)] = counts.get(str(val), 0) + 1
        return counts


def hybrid_oracle_fuse(sparse_ranked: Sequence[tuple[int, float]],
                       dense_ranked: Sequence[tuple[int, float]],
                       k: int) -> list[tuple[int, float]]:
    """RRF-fuse the two oracles' (global index, score) rankings with the
    SAME ``rrf_fuse`` call the fleet coordinator makes, in the same
    (sparse, dense) tier order — fused scores are bit-identical to the
    fleet's, and the keys are global doc indices."""
    return rrf_fuse([[d for d, _ in sparse_ranked],
                     [d for d, _ in dense_ranked]], k)
