"""Exact, dict-based BM25 — the correctness oracle for the JAX searcher.

Implements the same Lucene BM25 variant as the builder (no (k1+1) numerator),
with the same uint8 tf clamp, so the blocked JAX path must match to float
tolerance whenever block truncation (M) does not drop postings.
"""

from __future__ import annotations

import math
from collections import Counter

from repro.index.tokenizer import tokenize


class OracleSearcher:
    def __init__(self, docs: list[tuple[str, str]], *, k1: float = 0.9,
                 b: float = 0.4) -> None:
        self.k1, self.b = k1, b
        self.doc_ids = [d for d, _ in docs]
        self.doc_toks = [tokenize(t) for _, t in docs]
        self.doc_len = [len(t) for t in self.doc_toks]
        self.avgdl = sum(self.doc_len) / max(1, len(self.doc_len))
        self.postings: dict[str, dict[int, int]] = {}
        for i, toks in enumerate(self.doc_toks):
            for t, tf in Counter(toks).items():
                self.postings.setdefault(t, {})[i] = min(tf, 255)
        self.n_docs = len(docs)

    def idf(self, term: str) -> float:
        df = len(self.postings.get(term, {}))
        return math.log(1.0 + (self.n_docs - df + 0.5) / (df + 0.5))

    def search(self, query: str, k: int = 10) -> list[tuple[int, float]]:
        scores: dict[int, float] = {}
        for term, qtf in Counter(tokenize(query)).items():
            plist = self.postings.get(term)
            if not plist:
                continue
            idf = self.idf(term)
            for doc, tf in plist.items():
                dl = self.doc_len[doc]
                denom = tf + self.k1 * (1 - self.b + self.b * dl / self.avgdl)
                scores[doc] = scores.get(doc, 0.0) + qtf * idf * tf / denom
        ranked = sorted(scores.items(), key=lambda kv: (-kv[1], kv[0]))
        return ranked[:k]
