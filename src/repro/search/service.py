"""End-to-end Anlessini application assembly (Figure 1 of the paper).

``build_search_app`` wires corpus → index → object store → FaaS runtime →
gateway and returns the pieces; used by examples, benchmarks, and tests.

``build_partitioned_search_app`` is the §3 scale-out assembly: the corpus
splits into N partitions, each published as its own versioned segment
(packed with GLOBAL idf/avgdl) and served by its own Lambda function;
``/search`` fans out through ScatterGather and merges per-partition top-k
into a globally-ranked result. Cold starts, hydration, refresh, and cost
all account per partition in the shared runtime. With ``replicas=R`` each
segment is served by R independent instance pools and a ``HedgePolicy``
fires backup legs on replicas when a primary projects cold/queued — the
tail-latency path (flat p99 under cold injection, hedging tax on the
ledger).
"""

from __future__ import annotations

import dataclasses
from typing import Iterable

from repro.core.autoscale import AutoscalePolicy, FleetController
from repro.core.gateway import Gateway
from repro.core.kvstore import KVStore
from repro.core.object_store import Backend, ObjectStore
from repro.core.partition import HedgePolicy, PartitionHit, ScatterGather
from repro.core.refresh import AssetCatalog
from repro.core.runtime import FaaSRuntime, InvocationRecord, RuntimeConfig
from repro.index.builder import (IndexWriter, compute_global_stats,
                                 global_vocab, write_segment)
from repro.search.distributed import partition_corpus
from repro.search.searcher import SearchConfig, make_search_handler


def _search_body(q: "str | list[str]", k: int, fetch_docs: bool) -> dict:
    body = {"k": k, "fetch_docs": fetch_docs}
    if isinstance(q, str):
        body["q"] = q
    else:
        body["queries"] = list(q)         # micro-batch: one invocation
    return body


@dataclasses.dataclass
class SearchApp:
    store: ObjectStore
    catalog: AssetCatalog
    doc_store: KVStore
    runtime: FaaSRuntime
    gateway: Gateway
    asset: str

    def query(self, q: "str | list[str]", k: int = 10, *,
              t_arrival: float | None = None, fetch_docs: bool = True):
        return self.gateway.request(
            "GET", "/search", _search_body(q, k, fetch_docs),
            t_arrival=t_arrival)


def index_corpus(docs: Iterable[tuple[str, str]], store: ObjectStore,
                 doc_store: KVStore, *, asset: str = "index",
                 version: str = "v1",
                 global_stats: dict | None = None,
                 vocab: dict[str, int] | None = None) -> AssetCatalog:
    """The offline batch side: build, pack, publish (paper §3).

    Pass ``global_stats`` (index.builder.compute_global_stats over the FULL
    corpus) — and the corpus-global ``vocab`` — when these docs are one
    partition of a larger deployment: global idf/avgdl keep the merged
    ranking build-invariant, and a shared vocab makes per-partition query
    encoding (idf-ranked max_terms truncation) identical everywhere."""
    writer = IndexWriter(global_stats=global_stats, vocab=vocab)
    for ext_id, text in docs:
        writer.add(ext_id, text)
        doc_store.put(ext_id, {"id": ext_id, "contents": text})
    packed = writer.pack()
    catalog = AssetCatalog(store)
    catalog.publish(asset, version, write_segment(packed))
    return catalog


def build_search_app(
    docs: Iterable[tuple[str, str]],
    *,
    runtime_config: RuntimeConfig | None = None,
    search_config: SearchConfig | None = None,
    backend: Backend | None = None,
    asset: str = "index",
) -> SearchApp:
    store = ObjectStore(backend)
    doc_store = KVStore()
    catalog = index_corpus(docs, store, doc_store, asset=asset)
    runtime = FaaSRuntime(runtime_config)
    runtime.register(
        "search", make_search_handler(catalog, doc_store, asset, search_config))
    gateway = Gateway(runtime)
    gateway.route("GET", "/search", "search")
    return SearchApp(store, catalog, doc_store, runtime, gateway, asset)


# -- fleet-level partitioned app (paper §3's scale-out, assembled) -----------------


@dataclasses.dataclass
class PartitionedSearchApp:
    """N document partitions behind one gateway route.

    Global doc id = partition * n_docs_local + partition-local id (the
    contiguous partitioning of ``partition_corpus``) — the same id space
    the mesh-level path and the oracle rank in.
    """

    store: ObjectStore
    catalog: AssetCatalog
    doc_store: KVStore
    runtime: FaaSRuntime
    gateway: Gateway
    scatter: ScatterGather
    assets: list[str]
    fn_names: list[str]      # primaries, one per partition
    n_parts: int
    n_docs_local: int
    search_k: int = 10       # per-partition compiled top-k (SearchConfig.k)
    fn_groups: list[list[str]] = dataclasses.field(default_factory=list)
    replicas: int = 1
    controller: FleetController | None = None

    def query(self, q: "str | list[str]", k: int = 10, *,
              t_arrival: float | None = None, fetch_docs: bool = True):
        """One query (str) or a micro-batch (list of str) through the
        gateway; batches evaluate as ONE invocation per partition.

        ``k`` is capped at the per-partition ``SearchConfig.k``: each
        partition's jitted fn returns its top ``search_k`` candidates, so
        merged ranks beyond that are not sound and are never returned."""
        return self.gateway.request(
            "GET", "/search", _search_body(q, k, fetch_docs),
            t_arrival=t_arrival)

    def warm(self, *, t_arrival: float | None = None) -> list[InvocationRecord]:
        """Touch EVERY function — primaries and replicas — once, hydrating
        each pool (replicas otherwise only see traffic when a hedge fires,
        so a backup leg would land as cold as the straggler it covers).
        The paper's "keep the fleet warm" pinger, fleet-wide. Pings are
        capacity maintenance, not queries: they bill to the ledger's idle
        line and stay out of latency percentiles and controller signals."""
        t0 = self.runtime.clock if t_arrival is None else t_arrival
        recs = []
        for group in self.fn_groups:
            for fn in group:
                _, rec = self.runtime.invoke(
                    fn, {"q": "", "k": 1, "fetch_docs": False}, t_arrival=t0,
                    keepalive=True)
                recs.append(rec)
        return recs

    # -- the /search coordinator (Gateway → ScatterGather → merge) ---------------

    def _global_id(self, hit: PartitionHit) -> int:
        return hit.partition * self.n_docs_local + hit.doc_id

    def _fetch_raw(self, merged: list[list[PartitionHit]],
                   fetch_docs: bool) -> tuple[dict, float]:
        """ONE batched KV fetch for the union of all merged hits — per-query
        (or per-partition) round trips would defeat the batching. Charged
        per BatchGetItem-sized chunk (the store's own accounting)."""
        ext = dict.fromkeys(
            h.ext_id for hits in merged for h in hits if h.ext_id is not None)
        if not fetch_docs:
            return {}, 0.0
        return self.doc_store.batch_get_billed(ext)

    def _materialize(self, hits: list[PartitionHit], raw: dict) -> dict:
        ext_ids = [h.ext_id for h in hits]
        return {
            "ids": [self._global_id(h) for h in hits],
            "scores": [h.score for h in hits],
            "ext_ids": ext_ids,
            "docs": [raw.get(e) for e in ext_ids] if raw else [],
        }

    def _search_route(self, body: dict, t_arrival: float | None
                      ) -> tuple[dict, float, InvocationRecord | None]:
        # a partition only surfaces its top search_k candidates — a merged
        # rank past that could silently miss docs, so clamp rather than lie
        k = min(int(body.get("k", self.search_k)), self.search_k)
        fetch_docs = body.get("fetch_docs", True)
        batched = "queries" in body
        payload = {"k": k, "fetch_docs": False}
        if batched:
            payload["queries"] = list(body["queries"])
            merged, lat, records = self.scatter.search_batch(
                payload, k, t_arrival=t_arrival)
            raw, fetch_s = self._fetch_raw(merged, fetch_docs)
            result: dict = {"results": [self._materialize(hits, raw)
                                        for hits in merged]}
        else:
            payload["q"] = body["q"]
            hits, lat, records = self.scatter.search(
                payload, k, t_arrival=t_arrival)
            raw, fetch_s = self._fetch_raw([hits], fetch_docs)
            result = self._materialize(hits, raw)
        result["partitions"] = [
            {"fn": r.fn, "cold": r.cold, "hydrate_s": r.hydrate_s,
             "latency_s": r.latency_s, "hedged": r.hedged} for r in records]
        slowest = max(records, key=lambda r: r.latency_s, default=None) \
            if records else None
        # the control loop rides the request path: the controller ticks at
        # the arrival instant AFTER dispatch — scale decisions see this
        # arrival in their window, and keep-alive pings can never race the
        # request itself for a pool's idle instance (the legs just
        # dispatched hold their instances busy at t0, so their pools are
        # skipped as traffic-warmed)
        if self.controller is not None:
            self.controller.maybe_tick(
                self.runtime.clock if t_arrival is None else t_arrival)
        return result, lat + fetch_s, slowest


def build_partitioned_search_app(
    docs: Iterable[tuple[str, str]],
    n_parts: int = 4,
    *,
    replicas: int = 1,
    hedge: "HedgePolicy | float | None" = None,
    autoscale: "AutoscalePolicy | bool | None" = None,
    routing: str | None = None,
    runtime_config: RuntimeConfig | None = None,
    search_config: SearchConfig | None = None,
    backend: Backend | None = None,
    asset_prefix: str = "index",
) -> PartitionedSearchApp:
    """Assemble the partitioned fleet: one segment per partition, ``replicas``
    Lambda functions serving it, global BM25 stats, scatter-gather behind
    ``/search``.

    Every partition's segment is packed with ``compute_global_stats`` over
    the FULL corpus — the distributed-IR invariant that makes the merged
    ranking identical to a single-index build at any partition count.

    ``replicas=R`` publishes each segment ONCE (shared ``AssetCatalog``
    entry) but registers R functions per partition — separate instance
    pools over identical ``PackedIndex``es, so a backup leg returns
    bit-identical hits. ``hedge`` is a :class:`HedgePolicy` (or a float
    shorthand for a fixed ``after_s`` threshold) enabling projection-based
    backup legs; replicas without a policy are standby-only.

    ``autoscale`` (an :class:`AutoscalePolicy`, or ``True`` for defaults)
    attaches a :class:`FleetController`: ``replicas`` then only sets the
    STARTING group size, and the controller grows/shrinks each partition's
    pool count between ``min_replicas`` and ``max_replicas`` against the
    cost ledger, ticking on the request path. ``routing`` selects the
    scatter's primary-choice rule (``"static"`` or ``"aware"``); it
    defaults to ``"aware"`` whenever a controller is attached — a fleet
    whose pools come and go should not pin primaries to pool zero — and to
    the PR 2 ``"static"`` behaviour otherwise.
    """
    if replicas < 1:
        raise ValueError(f"replicas must be >= 1, got {replicas}")
    if isinstance(hedge, (int, float)):
        hedge = HedgePolicy(after_s=float(hedge))
    if autoscale is True:
        autoscale = AutoscalePolicy()
    if routing is None:
        routing = "aware" if autoscale else "static"
    docs = list(docs)
    store = ObjectStore(backend)
    doc_store = KVStore()
    catalog = AssetCatalog(store)
    runtime = FaaSRuntime(runtime_config)
    gstats = compute_global_stats(docs)
    # every partition packs against the corpus-global vocab: queries then
    # encode (and idf-truncate, for > max_terms) identically per partition
    gvocab = global_vocab(gstats)
    parts, per = partition_corpus(docs, n_parts)
    assets, fn_groups = [], []
    for p, pdocs in enumerate(parts):
        if not pdocs:        # corpus didn't fill the last partition(s)
            continue
        asset = f"{asset_prefix}-p{p}"
        index_corpus(pdocs, store, doc_store, asset=asset,
                     global_stats=gstats, vocab=gvocab)
        group = []
        for r in range(replicas):
            fn = f"search-p{p}" if r == 0 else f"search-p{p}r{r}"
            runtime.register(fn, make_search_handler(
                catalog, doc_store, asset, search_config))
            group.append(fn)
        assets.append(asset)
        fn_groups.append(group)
    scatter = ScatterGather(runtime, fn_groups, hedge=hedge, routing=routing)
    gateway = Gateway(runtime)
    controller = None
    if autoscale:
        # one factory per partition: a scale-up registers a fresh handler
        # over the SAME published asset — no re-publish, no new segment
        factories = [
            (lambda a=asset_name: make_search_handler(
                catalog, doc_store, a, search_config))
            for asset_name in assets]
        controller = FleetController(
            runtime, scatter, factories, autoscale,
            ping_payload={"q": "", "k": 1, "fetch_docs": False})
    app = PartitionedSearchApp(
        store=store, catalog=catalog, doc_store=doc_store, runtime=runtime,
        gateway=gateway, scatter=scatter, assets=assets,
        fn_names=scatter.fn_names, n_parts=n_parts, n_docs_local=per,
        search_k=(search_config or SearchConfig()).k,
        fn_groups=scatter.groups, replicas=replicas, controller=controller)
    gateway.route("GET", "/search", app._search_route)
    return app
