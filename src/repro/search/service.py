"""End-to-end Anlessini application assembly (Figure 1 of the paper).

``build_search_app`` wires corpus → index → object store → FaaS runtime →
gateway and returns the pieces; used by examples, benchmarks, and tests.

``build_partitioned_search_app`` is the §3 scale-out assembly: the corpus
splits into N partitions, each published as its own versioned segment
(packed with GLOBAL idf/avgdl) and served by its own Lambda function;
``/search`` fans out through ScatterGather and merges per-partition top-k
into a globally-ranked result. Cold starts, hydration, refresh, and cost
all account per partition in the shared runtime. With ``replicas=R`` each
segment is served by R independent instance pools and a ``HedgePolicy``
fires backup legs on replicas when a primary projects cold/queued — the
tail-latency path (flat p99 under cold injection, hedging tax on the
ledger).
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Callable, Iterable

import numpy as np

from repro.core.autoscale import AutoscalePolicy, FleetController
from repro.core.gateway import (BadRequest, Gateway, PendingResponse,
                                WindowPolicy)
from repro.core.kvstore import KVStore
from repro.core.object_store import Backend, ObjectStore
from repro.core.partition import (FleetSpec, GatewaySpec, HedgePolicy,
                                  IndexSpec, PartitionHit, ReplicationSpec,
                                  ScatterGather, _merge_hits, rrf_fuse)
from repro.core.refresh import (AssetCatalog, GenerationManifest,
                                PublishConflict, parse_generation,
                                rollover_fleet)
from repro.core.runtime import FaaSRuntime, InvocationRecord, RuntimeConfig
from repro.data.corpus import hash_embedder
from repro.index.builder import (IndexWriter, MergePolicy,
                                 compute_global_stats, extend_vocab,
                                 field_avgdl, global_vocab, pack_vectors,
                                 read_segment, update_stats, write_segment,
                                 write_vector_segment)
from repro.index.tokenizer import flatten_text, token_counts
from repro.search.distributed import partition_corpus
from repro.search.query import Query, QueryParseError, parse_query
from repro.search.searcher import (PREWARM_TOP_TERMS, SearchConfig,
                                   make_search_handler)
from repro.search.structured import make_snippet, merge_facet_counts

SEARCH_MODES = ("sparse", "dense", "hybrid")


def _search_body(q: "str | list[str] | None", k: int, fetch_docs: bool,
                 mode: str = "sparse", vector=None, sq=None,
                 facets=None, snippets: bool = False) -> dict:
    body = {"k": k, "fetch_docs": fetch_docs}
    if mode != "sparse":
        body["mode"] = mode
    if sq is not None:
        # structured DSL: one query string, or a micro-batch of them
        if isinstance(sq, str):
            body["sq"] = sq
        else:
            body["sqs"] = list(sq)
    if facets:
        body["facets"] = list(facets)
    if snippets:
        body["snippets"] = True
    # batch shape follows the text queries when given, else the vectors:
    # a flat number sequence is ONE query vector, a sequence of sequences
    # is a micro-batch of them
    if q is not None:
        batch = not isinstance(q, str)
    else:
        batch = (vector is not None and len(vector) > 0
                 and hasattr(vector[0], "__len__"))
    if q is not None:
        if batch:
            body["queries"] = list(q)     # micro-batch: one invocation
        else:
            body["q"] = q
    if vector is not None:
        if batch:
            body["qvs"] = [[float(x) for x in v] for v in vector]
        else:
            body["qv"] = [float(x) for x in vector]
    return body


@dataclasses.dataclass
class SearchApp:
    store: ObjectStore
    catalog: AssetCatalog
    doc_store: KVStore
    runtime: FaaSRuntime
    gateway: Gateway
    asset: str

    def query(self, q: "str | list[str]", k: int = 10, *,
              t_arrival: float | None = None, fetch_docs: bool = True):
        return self.gateway.request(
            "GET", "/search", _search_body(q, k, fetch_docs),
            t_arrival=t_arrival)


def index_corpus(docs: Iterable[tuple[str, str]], store: ObjectStore,
                 doc_store: KVStore, *, asset: str = "index",
                 version: str = "v1",
                 global_stats: dict | None = None,
                 vocab: dict[str, int] | None = None) -> AssetCatalog:
    """The offline batch side: build, pack, publish (paper §3).

    Pass ``global_stats`` (index.builder.compute_global_stats over the FULL
    corpus) — and the corpus-global ``vocab`` — when these docs are one
    partition of a larger deployment: global idf/avgdl keep the merged
    ranking build-invariant, and a shared vocab makes per-partition query
    encoding (idf-ranked max_terms truncation) identical everywhere."""
    writer = IndexWriter(global_stats=global_stats, vocab=vocab)
    for ext_id, text in docs:
        writer.add(ext_id, text)
        doc_store.put(ext_id, {"id": ext_id, "contents": text})
    packed = writer.pack()
    catalog = AssetCatalog(store)
    catalog.publish(asset, version, write_segment(packed))
    return catalog


def build_search_app(
    docs: Iterable[tuple[str, str]],
    *,
    runtime_config: RuntimeConfig | None = None,
    search_config: SearchConfig | None = None,
    backend: Backend | None = None,
    asset: str = "index",
) -> SearchApp:
    store = ObjectStore(backend)
    doc_store = KVStore()
    catalog = index_corpus(docs, store, doc_store, asset=asset)
    runtime = FaaSRuntime(runtime_config)
    runtime.register(
        "search", make_search_handler(catalog, doc_store, asset, search_config))
    gateway = Gateway(runtime)
    gateway.route("GET", "/search", "search")
    return SearchApp(store, catalog, doc_store, runtime, gateway, asset)


# -- NRT ingestion: the fleet's writer path ---------------------------------------


ENQUEUE_COST_S = 0.0005    # staging one add/delete batch at the coordinator


def _copy_stats(stats: dict) -> dict:
    """Deep-enough copy of compute_global_stats-shaped stats: ``df`` and
    (on structured fleets) every ``fields`` entry are fresh containers.
    ``update_stats`` mutates the per-field dicts IN PLACE, so a shallow
    ``dict(stats, df=...)`` checkpoint would let a failed commit's
    mutations leak into what gets restored."""
    out = dict(stats, df=dict(stats["df"]))
    if "fields" in stats:
        out["fields"] = {f: dict(e) for f, e in stats["fields"].items()}
    return out


@dataclasses.dataclass
class _PartitionState:
    """One partition's segment tier, as the writer tracks it."""

    asset: str
    seg_docs: list                # (ext_id, text) in indexed order (base+deltas)
    tombstones: set               # deleted INTERNAL positions (not yet merged)
    base_seg: str
    deltas: list                  # delta segment ids, oldest first
    base_docs: int
    delta_docs: int
    staged_docs: list = dataclasses.field(default_factory=list)
    # dense tier twins (None/[] on sparse-only fleets): row r of the vector
    # segments is doc r of the sparse segments — one internal-id space, one
    # tombstone list, one generation number governs both tiers
    vec_base: "str | None" = None
    vec_deltas: list = dataclasses.field(default_factory=list)

    def live_docs(self) -> list:
        return [d for pos, d in enumerate(self.seg_docs)
                if pos not in self.tombstones]


class FleetIndexer:
    """Near-real-time document ingestion for a partitioned fleet.

    The paper serves a STATIC index — Lin names updates as the key open
    limitation. This closes it with Lucene's own shape, adapted to object
    storage: adds/deletes stage at the coordinator; ``commit`` packs each
    touched partition's staged docs into a small immutable DELTA segment
    (a billed ``indexer-p{i}`` Lambda invocation — the writer's side of
    the cost ledger), CAS-publishes a new generation manifest per
    partition (base + ordered deltas + tombstones + LIVE global stats),
    prewarms every serving pool on the new generation, and only then
    flips the serving generation — a zero-downtime rollover.

    Invariants the tests pin:

    * global stats/vocab are maintained INCREMENTALLY (``update_stats`` /
      ``extend_vocab``) and stay exactly equal to ``compute_global_stats``
      over the live corpus — so a delta-served index ranks identically to
      a from-scratch rebuild, always;
    * every partition gets a manifest at every generation (a delete in
      partition 0 moves idf for ALL partitions — stats refresh is global);
    * deletes are tombstones until the :class:`MergePolicy` folds the
      delta tier back into the base (one full re-pack, purging them).
    """

    def __init__(self, catalog: AssetCatalog, doc_store: KVStore,
                 runtime: FaaSRuntime, *, stats: dict, vocab: dict,
                 merge_policy: MergePolicy | None = None,
                 sim_write_s: float | None = None,
                 sim_write_per_doc_s: float = 2e-5,
                 stats_asset: str = "index-stats",
                 embedder: "Callable | None" = None,
                 vec_dim: int = 16, vec_dtype: str = "float32",
                 structured: bool = False,
                 facet_fields: "tuple[str, ...]" = ()) -> None:
        self.catalog = catalog
        self.doc_store = doc_store
        self.runtime = runtime
        self.stats = stats
        self.vocab = vocab
        self.merge_policy = merge_policy or MergePolicy()
        self.sim_write_s = sim_write_s
        self.sim_write_per_doc_s = sim_write_per_doc_s
        # dense tier (optional): the SAME writer invocation that packs a
        # sparse delta/base also embeds + packs its vector twin, so both
        # tiers always publish under one generation and one CAS flip
        self.embedder = embedder
        self.vec_dim = vec_dim
        self.vec_dtype = vec_dtype
        # structured (format-v2) tier: every segment this writer packs —
        # base, delta, merge — carries field/position/facet data, so a
        # rollover can never demote the fleet's structured surface
        self.structured = structured or bool(facet_fields)
        self.facet_fields = tuple(facet_fields)
        self.stats_asset = stats_asset    # shared per-generation stats/vocab
        self._stats_ref: list | None = None
        self.gen = 0
        self.parts: list[_PartitionState] = []
        self.pending_adds: list[tuple[str, str]] = []
        self.pending_deletes: set[str] = set()
        self._pending_ids: set[str] = set()   # O(1) dedup over pending_adds
        # ext id -> (partition, internal position, text) for LIVE docs
        self._ext_index: dict[str, tuple[int, int, str]] = {}
        self._rr = 0                      # round-robin add assignment
        # segment-id sequence: every writer execution publishes under a
        # FRESH id, so a hedged re-execution (FaaSRuntime.hedge_after_s
        # runs handlers twice) or a post-failure retry can never collide
        # with an already-published segment — orphans (the hedge loser,
        # a failed attempt's uploads) are unreferenced and reclaimed by
        # the reference-based gc. NEVER rolled back by _restore: a retry
        # must keep advancing past the failed attempt's ids.
        self._seg_seq = 0
        self.commits: list[dict] = []     # commit log (gen, merged, counts)
        # multi-writer identity: 0 is the primary; ``fork`` mints clones
        # with nonzero ids (distinct handler names + segment-id tags so two
        # writers racing one generation never collide before the CAS).
        self.writer_id = 0
        self._forked = False    # once True, commits publish writer.json

    # -- bootstrap (the offline batch build, now generation-shaped) ------------

    def add_partition(self, asset: str, docs: list[tuple[str, str]]) -> None:
        """Pack ``docs`` as partition ``len(self.parts)``'s base segment and
        publish generation 1. All partitions must be added before the first
        commit (they share one global generation number)."""
        self.gen = 1
        if self._stats_ref is None:       # once per generation, not per part
            self._stats_ref = self.catalog.publish_generation_state(
                self.stats_asset, self.gen, self.stats, self.vocab)
        i = len(self.parts)
        writer = IndexWriter(global_stats=self.stats, vocab=self.vocab,
                             structured=self.structured,
                             facet_fields=self.facet_fields)
        writer.add_many(docs)
        base_seg = f"g{self.gen:06d}-base"
        self.catalog.publish_segment(asset, base_seg,
                                     write_segment(writer.pack()))
        st = _PartitionState(asset=asset, seg_docs=list(docs),
                             tombstones=set(), base_seg=base_seg,
                             deltas=[], base_docs=len(docs), delta_docs=0)
        if self.embedder is not None:
            st.vec_base = f"g{self.gen:06d}-vecbase"
            self.catalog.publish_segment(
                asset, st.vec_base, write_vector_segment(self._pack_vecs(docs)))
        self.parts.append(st)
        self.catalog.publish_generation(asset, self._manifest(st))
        self.runtime.register(self._writer_fn(i),
                              self._make_indexer_handler(i))
        for pos, (ext, text) in enumerate(docs):
            self.doc_store.put(ext, {"id": ext, "contents": text})
            self._ext_index[ext] = (i, pos, text)

    def _manifest(self, st: _PartitionState) -> GenerationManifest:
        return GenerationManifest(
            gen=self.gen, base=st.base_seg, deltas=list(st.deltas),
            tombstones=sorted(st.tombstones), stats_ref=self._stats_ref,
            vec_base=st.vec_base, vec_deltas=list(st.vec_deltas))

    def _pack_vecs(self, docs: list):
        """Embed + pack one segment's docs as its dense twin (row r of the
        vector segment IS doc r of the sparse segment)."""
        if docs:
            # structured corpora carry Mapping texts; the embedder sees the
            # same flattened view the analyzer tokenizes
            vecs = np.stack([self.embedder(flatten_text(text))
                             for _, text in docs]).astype(np.float32)
        else:   # a merge can empty a partition; the tier stays well-formed
            vecs = np.zeros((0, self.vec_dim), dtype=np.float32)
        return pack_vectors(vecs, [ext for ext, _ in docs],
                            dtype=self.vec_dtype)

    # -- staging ---------------------------------------------------------------

    def stage_add(self, docs: Iterable[tuple[str, str]]) -> int:
        """Stage docs for the next commit. The whole batch is validated
        BEFORE anything mutates — a duplicate id rejects the batch without
        half-staging it. An id whose delete is already staged may be
        re-added (delete + add + commit = the update recipe, one commit)."""
        docs = [(ext, text) for ext, text in docs]
        seen: set[str] = set()
        for ext, _ in docs:
            live = ext in self._ext_index and ext not in self.pending_deletes
            if live or ext in self._pending_ids or ext in seen:
                raise ValueError(f"document {ext!r} already indexed "
                                 "(updates = delete + add + commit)")
            seen.add(ext)
        for ext, text in docs:
            self.pending_adds.append((ext, text))
            self._pending_ids.add(ext)
        return len(self.pending_adds)

    def stage_delete(self, ids: Iterable[str]) -> int:
        for ext in ids:
            if ext in self._pending_ids:    # never-committed doc: just unstage
                self.pending_adds = [d for d in self.pending_adds
                                     if d[0] != ext]
                self._pending_ids.discard(ext)
            elif ext in self._ext_index:
                self.pending_deletes.add(ext)
        return len(self.pending_deletes)

    # -- the writer Lambda body -------------------------------------------------

    def _writer_fn(self, i: int) -> str:
        """Handler name for partition ``i``'s writer Lambda. Forked writers
        own distinct pools — two writers racing a commit must not share
        warm instances (their staged inputs differ)."""
        if self.writer_id:
            return f"indexer-w{self.writer_id}-p{i}"
        return f"indexer-p{i}"

    def _seg_tag(self) -> str:
        """Segment-id tag keeping forked writers' same-generation uploads
        disjoint: the create-once segment publish would otherwise conflict
        on BYTES before the manifest CAS even picks a winner. Empty for the
        primary, so single-writer segment ids are bit-identical to the
        pre-fork layout."""
        return f"w{self.writer_id}-" if self.writer_id else ""

    def _make_indexer_handler(self, i: int):
        """Handler for ``indexer-p{i}``: pack this partition's staged docs
        as a delta (or re-pack its live docs as a fresh base, for a merge)
        and publish the segment. Stateless w.r.t. the instance cache; the
        staged inputs live at the coordinator, exactly like the query
        coordinator owns the scatter."""
        st_ref = self.parts

        def handler(cache, payload: dict) -> tuple[dict, float]:
            st = st_ref[i]
            op, gen = payload["op"], payload["gen"]
            t0 = time.perf_counter()
            self._seg_seq += 1
            tag = self._seg_tag()
            if op == "delta":
                docs = list(st.staged_docs)
                packed = IndexWriter.delta(docs, self.stats, vocab=self.vocab,
                                           structured=self.structured,
                                           facet_fields=self.facet_fields)
                seg = f"g{gen:06d}-delta-{tag}{self._seg_seq:04d}"
            elif op == "merge":
                docs = st.live_docs() + list(st.staged_docs)
                writer = IndexWriter(global_stats=self.stats,
                                     vocab=self.vocab,
                                     structured=self.structured,
                                     facet_fields=self.facet_fields)
                writer.add_many(docs)
                packed = writer.pack()
                seg = f"g{gen:06d}-base-{tag}{self._seg_seq:04d}"
            else:
                raise ValueError(f"unknown indexer op {op!r}")
            self.catalog.publish_segment(st.asset, seg, write_segment(packed))
            vec_seg = None
            if self.embedder is not None:
                # the dense twin packs in the SAME invocation over the SAME
                # doc list: rows stay doc-for-doc aligned with the sparse
                # segment, and both tiers flip together at publish
                kind = "vecbase" if op == "merge" else "vecdelta"
                vec_seg = f"g{gen:06d}-{kind}-{tag}{self._seg_seq:04d}"
                self.catalog.publish_segment(
                    st.asset, vec_seg,
                    write_vector_segment(self._pack_vecs(docs)))
            if self.sim_write_s is not None:
                exec_s = self.sim_write_s + self.sim_write_per_doc_s * len(docs)
            else:
                exec_s = time.perf_counter() - t0
            return {"op": op, "seg": seg, "gen": gen, "vec_seg": vec_seg,
                    "n_docs": packed.meta.n_docs}, exec_s

        return handler

    # -- commit: delta pack → CAS publish → prewarmed rollover -------------------

    def _checkpoint(self) -> dict:
        """Everything ``commit`` mutates, cheap-copied. A failed commit
        (handler error, PublishConflict from a racing writer) restores this
        so the staged work is NOT lost and the writer can rebase + retry —
        without it, a partial multi-partition publish would wedge every
        future commit and silently drop the pending batch."""
        return {
            "stats": _copy_stats(self.stats),
            "vocab": self.vocab,        # rebound by extend_vocab, never mutated
            "ext_index": dict(self._ext_index),
            "pending_adds": list(self.pending_adds),
            "pending_ids": set(self._pending_ids),
            "pending_deletes": set(self.pending_deletes),
            "rr": self._rr,
            "gen": self.gen,
            "stats_ref": self._stats_ref,
            "parts": [(list(st.seg_docs), set(st.tombstones), st.base_seg,
                       list(st.deltas), st.base_docs, st.delta_docs,
                       st.vec_base, list(st.vec_deltas))
                      for st in self.parts],
        }

    def _restore(self, cp: dict) -> None:
        # every restored container is a COPY: ``commit``'s conflict-retry
        # loop restores the same checkpoint repeatedly, and handing out
        # the checkpoint's own objects would let attempt N's mutations
        # corrupt what attempt N+1 restores
        self.stats = _copy_stats(cp["stats"])
        self.vocab = cp["vocab"]        # rebound by extend_vocab, never mutated
        self._ext_index = dict(cp["ext_index"])
        self.pending_adds = list(cp["pending_adds"])
        self._pending_ids = set(cp["pending_ids"])
        self.pending_deletes = set(cp["pending_deletes"])
        self._rr, self.gen = cp["rr"], cp["gen"]
        self._stats_ref = cp["stats_ref"]
        for st, (sd, tb, bs, dl, bd, dd, vb, vd) in zip(self.parts,
                                                        cp["parts"]):
            st.seg_docs, st.tombstones, st.base_seg = list(sd), set(tb), bs
            st.deltas, st.base_docs, st.delta_docs = list(dl), bd, dd
            st.vec_base, st.vec_deltas = vb, list(vd)
            st.staged_docs = []

    def _published_gen(self) -> int:
        """Highest generation any partition's manifest currently serves.
        A previous commit that failed AFTER flipping some partitions leaves
        them ahead of ``self.gen``; basing the next generation on the max
        (instead of blindly ``self.gen + 1``) lets the retry publish a
        strictly newer generation everywhere instead of wedging on the
        stale-base check forever."""
        gens = (parse_generation(self.catalog.current_version(st.asset))
                for st in self.parts)
        return max((g for g in gens if g is not None), default=0)

    def _foreign_gen(self) -> int | None:
        """The generation a COMPLETE foreign commit published, if EVERY
        partition has moved past this writer's view (a racing writer won
        the whole flip). ``None`` while any partition still serves
        ``self.gen`` or older — that is this writer's OWN partial flip,
        which ``commit``'s max()+1 leapfrog retry handles instead (a
        rebase there would adopt a half-published generation)."""
        gens = [parse_generation(self.catalog.current_version(st.asset))
                for st in self.parts]
        if gens and all(g is not None and g > self.gen for g in gens):
            return min(gens)
        return None

    def _rebase(self) -> int:
        """Adopt the state a racing writer published past this writer's
        view, keeping the staged batch pending on top of it.

        Without this, a stale writer's commit would CAS-publish a
        generation built WITHOUT the winner's documents — the stale-base
        check only orders generation numbers, it cannot see content, so
        the winner's docs would vanish silently (the classic lost update).

        Rebuilds every partition's tier view from the published manifests
        (segment doc ids re-read from the store, texts from the doc KV —
        tombstoned rows keep an empty placeholder, nothing reads them),
        adopts the winner's live stats/vocab AND its round-robin cursor
        (``writer.json``), so the rebased commit places documents exactly
        where a serialized pair of commits would have. The staged batch is
        revalidated against the new view: deletes of ids the winner
        already removed drop out (delete-of-unknown is a no-op, same as
        ``stage_delete``); an add whose id the winner also added is a
        conflict the caller must resolve — loud error, batch preserved."""
        gen = self._foreign_gen()
        if gen is None:
            return self.gen
        manifests = [self.catalog.read_generation(st.asset)
                     for st in self.parts]
        stats, vocab = self.catalog.resolve_generation_state(manifests[0])
        self.stats = _copy_stats(stats)
        self.vocab = dict(vocab)
        self._ext_index = {}
        for i, (st, m) in enumerate(zip(self.parts, manifests)):
            tombs = set(m.tombstones)
            seg_docs: list[tuple[str, str]] = []
            base_docs = 0
            for seg_i, seg in enumerate(m.segments):
                pack = read_segment(self.catalog.open_segment(st.asset, seg))
                if seg_i == 0:
                    base_docs = len(pack.meta.doc_ids)
                for ext in pack.meta.doc_ids:
                    pos = len(seg_docs)
                    if pos in tombs:
                        # tombstoned rows are never scored, merged, or
                        # looked up — and their doc may be gone from the KV
                        seg_docs.append((ext, ""))
                    else:
                        text = self.doc_store.get(ext)["contents"]
                        seg_docs.append((ext, text))
                        self._ext_index[ext] = (i, pos, text)
            st.seg_docs = seg_docs
            st.tombstones = tombs
            st.base_seg = m.base
            st.deltas = list(m.deltas)
            st.base_docs = base_docs
            st.delta_docs = len(seg_docs) - base_docs
            st.vec_base = m.vec_base
            st.vec_deltas = list(m.vec_deltas)
            st.staged_docs = []
        writer = self.catalog.resolve_generation_writer(manifests[0])
        self._rr = int(writer.get("rr", self._rr))
        ref = manifests[0].stats_ref
        self._stats_ref = list(ref) if ref is not None else None
        self.gen = gen
        # revalidate the still-pending batch against the adopted view
        self.pending_deletes &= set(self._ext_index)
        for ext, _ in self.pending_adds:
            if ext in self._ext_index and ext not in self.pending_deletes:
                raise ValueError(
                    f"rebase conflict: document {ext!r} was also added by "
                    "the racing writer (updates = delete + add + commit)")
        return gen

    def sync(self) -> bool:
        """Adopt a racing writer's published state outside of a commit.
        Returns True if the view moved. Same rollback discipline as
        ``commit``: a rebase conflict restores the pre-sync view."""
        if self._foreign_gen() is None:
            return False
        cp = self._checkpoint()
        try:
            self._rebase()
        except Exception:
            self._restore(cp)
            raise
        return True

    def fork(self, writer_id: int) -> "FleetIndexer":
        """A SECOND writer over the same catalog, doc store, and runtime —
        the multi-writer story. The clone shares the published index (it
        starts from this writer's current view) but stages and commits
        independently; whichever writer publishes a generation first wins
        the CAS, and the other rebases on it inside its own ``commit``.

        Distinct handler names (``indexer-w{id}-p{i}``) and segment-id
        tags keep the two writers' same-generation uploads from colliding
        before the manifest CAS picks a winner; a loser's uploads become
        unreferenced orphans the reference-based gc reclaims after it
        rebases and republishes."""
        if writer_id == self.writer_id:
            raise ValueError("forked writer needs a distinct writer_id")
        w = FleetIndexer(
            self.catalog, self.doc_store, self.runtime,
            stats=_copy_stats(self.stats),
            vocab=self.vocab, merge_policy=self.merge_policy,
            sim_write_s=self.sim_write_s,
            sim_write_per_doc_s=self.sim_write_per_doc_s,
            stats_asset=self.stats_asset, embedder=self.embedder,
            vec_dim=self.vec_dim, vec_dtype=self.vec_dtype,
            structured=self.structured, facet_fields=self.facet_fields)
        w.writer_id = writer_id
        w.gen = self.gen
        w._stats_ref = list(self._stats_ref) if self._stats_ref else None
        w._ext_index = dict(self._ext_index)
        w._rr = self._rr
        w._seg_seq = self._seg_seq
        w.parts = [_PartitionState(
            asset=st.asset, seg_docs=list(st.seg_docs),
            tombstones=set(st.tombstones), base_seg=st.base_seg,
            deltas=list(st.deltas), base_docs=st.base_docs,
            delta_docs=st.delta_docs, vec_base=st.vec_base,
            vec_deltas=list(st.vec_deltas)) for st in self.parts]
        # both writers now publish their round-robin cursor with each
        # generation, so whichever loses a race can adopt the winner's
        self._forked = w._forked = True
        for i in range(len(w.parts)):
            self.runtime.register(w._writer_fn(i),
                                  w._make_indexer_handler(i))
        return w

    def commit(self, fn_groups, *, t_arrival: float | None = None,
               ping_payload: dict | None = None,
               max_publish_retries: int = 3) -> tuple[dict, float]:
        """Make staged adds/deletes searchable, atomically, fleet-wide.

        Returns (result body, simulated latency). Latency = the writer
        fan-out (all touched partitions pack concurrently at one arrival
        instant, like a scatter) plus the rollover prewarm pings. The
        serving pointer (``self.gen``) flips together with the manifests;
        the prewarm pings then hydrate every pool on the new generation
        off the query path, and any query already dispatched keeps its own
        pinned generation (still readable), so nothing is dropped or torn.
        On ANY failure the writer state rolls back to the pre-commit
        checkpoint (already-uploaded segments remain as unreferenced
        orphans for gc) and the staged batch stays pending; queries keep
        pinning the old generation, which every partition still serves.

        CONCURRENT WRITERS (``fork``): if a racing writer published past
        this writer's view, the commit REBASES the staged batch on the
        winner's generation first (``_rebase``) — and when the race is
        lost mid-publish (:class:`PublishConflict` from the CAS or the
        create-once segment upload), it rolls back, rebases on the new
        winner, and retries, up to ``max_publish_retries`` extra attempts.
        Exhaustion re-raises the conflict with the checkpoint restored and
        the batch still staged."""
        t0 = self.runtime.clock if t_arrival is None else t_arrival
        if not self.pending_adds and not self.pending_deletes:
            return {"gen": self.gen, "committed": False}, 0.0
        cp = self._checkpoint()
        conflicts = rebased = 0
        while True:
            try:
                if self._foreign_gen() is not None:
                    self._rebase()
                    rebased += 1
                next_gen = max(self.gen, self._published_gen()) + 1
                result, write_lat = self._commit_locked(next_gen, t0)
                break
            except PublishConflict:
                self._restore(cp)
                conflicts += 1
                if conflicts > max_publish_retries:
                    raise
            except Exception:
                self._restore(cp)
                raise
        result["publish_conflicts"] = conflicts
        result["rebased"] = rebased
        # KV content changes land only AFTER the publishes succeeded — a
        # rolled-back commit must neither lose deleted docs' content nor
        # orphan never-published adds in the doc store. Deletes skip ext
        # ids this same commit re-added (the put below writes the new
        # content); adds become fetchable exactly when they become
        # searchable.
        for ext in result.pop("_deleted_ids"):
            if ext not in self._ext_index:
                self.doc_store.delete(ext)
        for ext, text in result.pop("_added_docs"):
            self.doc_store.put(ext, {"id": ext, "contents": text})

        # zero-downtime rollover: hydrate every pool on the new generation
        # OFF the query path, then gc superseded generations (the serving
        # and previous manifests — and every segment they pin — survive)
        pings = rollover_fleet(
            self.runtime, fn_groups, next_gen,
            ping_payload=ping_payload, t_arrival=t0 + write_lat)
        ping_lat = max((r.latency_s for r in pings), default=0.0)
        for st in self.parts:
            self.catalog.gc(st.asset, keep=2)
        self._gc_state_segments()
        result["pings"] = len(pings)
        self.commits.append(dict(result, t=t0))
        return result, write_lat + ping_lat

    def _gc_state_segments(self) -> None:
        """Reclaim shared stats/vocab segments that NO surviving partition
        manifest references — the same reference-based rule the catalog's
        own segment gc uses. An age cutoff would be wrong: after a partial
        publish failure the generation sequence can skip, leaving a kept
        rollback manifest pointing at a state segment older than the
        naive keep window. Also sweeps orphans failed commits left."""
        live: set[str] = set()
        for st in self.parts:
            for v in self.catalog.versions(st.asset):
                m = self.catalog.read_generation(st.asset, v)
                if m.stats_ref and m.stats_ref[0] == self.stats_asset:
                    live.add(m.stats_ref[1])
        self.catalog.sweep_unreferenced(self.stats_asset, live)

    def _commit_locked(self, next_gen: int, t0: float) -> tuple[dict, float]:
        """The state-mutating half of ``commit``: stats/vocab/tier updates,
        the billed writer fan-out, and the CAS manifest publishes. Runs
        under ``commit``'s checkpoint — any exception here rolls everything
        back."""
        # deletes first: tombstone the internal POSITION (a re-add of the
        # same ext id gets a fresh position the tombstone can't touch) and
        # fold the doc out of the global stats
        new_tombs: list[set] = [set() for _ in self.parts]
        n_del = 0
        deleted_ids = []
        for ext in sorted(self.pending_deletes):
            p, pos, text = self._ext_index.pop(ext)
            new_tombs[p].add(pos)
            update_stats(self.stats, text, sign=-1)
            deleted_ids.append(ext)
            n_del += 1
        # adds: round-robin over partitions, fold INTO the global stats
        # (each doc tokenized ONCE here, shared by stats + vocab growth)
        staged: list[list] = [[] for _ in self.parts]
        new_terms: set[str] = set()
        for ext, text in self.pending_adds:
            p = self._rr % len(self.parts)
            self._rr += 1
            pos = len(self.parts[p].seg_docs) + len(staged[p])
            staged[p].append((ext, text))
            self._ext_index[ext] = (p, pos, text)
            counts = token_counts(text)
            new_terms.update(counts)
            update_stats(self.stats, text, sign=1, counts=counts)
        self.vocab = extend_vocab(self.vocab, new_terms)
        n_add = len(self.pending_adds)
        self.pending_adds, self.pending_deletes = [], set()
        self._pending_ids = set()

        # writer fan-out: every touched partition packs at one arrival
        recs, plans = [], []
        for i, st in enumerate(self.parts):
            st.tombstones |= new_tombs[i]
            do_merge = self.merge_policy.should_merge(
                st.base_docs, st.delta_docs + len(staged[i]),
                len(st.deltas) + (1 if staged[i] else 0),
                len(st.tombstones))
            if not staged[i] and not do_merge:
                plans.append(None)
                continue
            st.staged_docs = staged[i]
            op = "merge" if do_merge else "delta"
            out, rec = self.runtime.invoke(
                self._writer_fn(i), {"op": op, "gen": next_gen},
                t_arrival=t0, write=True)
            recs.append(rec)
            plans.append(out)
        write_lat = max((r.latency_s for r in recs), default=0.0)

        # apply the writers' results, then CAS-publish EVERY partition's
        # manifest at next_gen (global stats moved, so every partition's
        # scoring state did too — untouched segment tiers just re-point)
        merged_parts = []
        for i, (st, out) in enumerate(zip(self.parts, plans)):
            if out is not None and out["op"] == "merge":
                st.seg_docs = st.live_docs() + st.staged_docs
                st.base_seg, st.deltas = out["seg"], []
                st.base_docs, st.delta_docs = len(st.seg_docs), 0
                st.tombstones = set()
                if out.get("vec_seg"):
                    st.vec_base, st.vec_deltas = out["vec_seg"], []
                # a merge renumbers the partition's internal positions
                for pos, (ext, text) in enumerate(st.seg_docs):
                    self._ext_index[ext] = (i, pos, text)
                merged_parts.append(i)
            elif out is not None:
                st.seg_docs = st.seg_docs + st.staged_docs
                st.deltas = st.deltas + [out["seg"]]
                st.delta_docs += len(st.staged_docs)
                if out.get("vec_seg"):
                    st.vec_deltas = st.vec_deltas + [out["vec_seg"]]
            st.staged_docs = []
        self.gen = next_gen
        # ONE shared stats/vocab segment per generation; every partition's
        # manifest references it instead of inlining O(vocab) bytes each
        self._stats_ref = self.catalog.publish_generation_state(
            self.stats_asset, next_gen, self.stats, self.vocab,
            writer={"rr": self._rr} if self._forked else None)
        for st in self.parts:
            self.catalog.publish_generation(st.asset, self._manifest(st))
        return {"gen": next_gen, "committed": True, "indexed": n_add,
                "deleted": n_del, "merged": merged_parts,
                "writers": len(recs), "_deleted_ids": deleted_ids,
                "_added_docs": [d for part in staged for d in part]}, write_lat

    # -- introspection (tests, benches, the oracle) -----------------------------

    def live_corpus(self) -> list[tuple[str, str]]:
        """The searchable corpus, in (partition, internal id) order — the
        exact order a from-scratch rebuild (or oracle) must index to share
        the fleet's tie-breaks."""
        out = []
        for st in self.parts:
            out.extend(st.live_docs())
        return out

    def part_doc_offsets(self) -> list[int]:
        """Global-id base per partition (internal spaces INCLUDE tombstoned
        docs until a merge purges them)."""
        offs, n = [], 0
        for st in self.parts:
            offs.append(n)
            n += len(st.seg_docs)
        return offs


# -- fleet-level partitioned app (paper §3's scale-out, assembled) -----------------


@dataclasses.dataclass
class PartitionedSearchApp:
    """N document partitions behind one gateway route.

    Global doc id = the partition's doc-offset + partition-local internal
    id. With the (always-attached) :class:`FleetIndexer`, offsets are the
    cumulative ACTUAL tier sizes (``part_doc_offsets()`` — tombstoned
    slots included until a merge purges them), so ids shift as commits
    land; clients should key on ``ext_ids``, which are stable. Only for a
    never-committed fleet does the offset reduce to the bootstrap-uniform
    ``partition * n_docs_local`` the mesh-level path shares.
    """

    store: ObjectStore
    catalog: AssetCatalog
    doc_store: KVStore
    runtime: FaaSRuntime
    gateway: Gateway
    scatter: ScatterGather
    assets: list[str]
    fn_names: list[str]      # primaries, one per partition
    n_parts: int
    n_docs_local: int
    search_k: int = 10       # per-partition compiled top-k (SearchConfig.k)
    fn_groups: list[list[str]] = dataclasses.field(default_factory=list)
    replicas: int = 1
    controller: FleetController | None = None
    indexer: FleetIndexer | None = None
    # text → (dim,) f32 query embedder; non-None iff the fleet serves a
    # dense-vector tier (FleetSpec.index.vector)
    embedder: "Callable | None" = None
    # format-v2 structured tier (IndexSpec.structured/facet_fields):
    # fielded scoring, phrases, facets, snippets via sq/sqs bodies
    structured: bool = False
    facet_fields: tuple = ()

    def query(self, q: "str | list[str] | None" = None, k: int = 10, *,
              t_arrival: float | None = None, fetch_docs: bool = True,
              mode: str = "sparse", vector=None, sq=None, facets=None,
              snippets: bool = False):
        """One query (str) or a micro-batch (list of str) through the
        gateway; batches evaluate as ONE invocation per partition.

        ``mode`` selects the tier(s): ``"sparse"`` (BM25), ``"dense"``
        (embedding inner product), or ``"hybrid"`` (both, fused with
        Reciprocal Rank Fusion). ``vector`` optionally supplies the query
        embedding(s) — one (dim,) sequence per query — otherwise the
        fleet's embedder derives them from the text; dense-mode callers
        may pass ``q=None`` with ``vector`` alone.

        ``sq`` is a STRUCTURED query in the v2 DSL (or a list of them —
        mutually exclusive with ``q``): terms, ``field:term`` scoping,
        quoted phrases, ``^boost``, AND/OR. Parsed ONCE here at admission
        (malformed queries 400 before anything dispatches); partitions
        evaluate the shipped AST. ``facets`` names declared facet fields
        to count over each query's FULL match set, merged at gather like
        top-k. ``snippets=True`` cuts highlighted fragments from the
        fetched docs. All three need a fleet built with
        ``IndexSpec(structured=True, ...)``.

        ``k`` is capped at the per-partition ``SearchConfig.k``: each
        partition's jitted fn returns its top ``search_k`` candidates, so
        merged ranks beyond that are not sound and are never returned."""
        return self.gateway.request(
            "GET", "/search",
            _search_body(q, k, fetch_docs, mode, vector, sq, facets,
                         snippets),
            t_arrival=t_arrival)

    def submit(self, q: "str | list[str] | None" = None, k: int = 10, *,
               t_arrival: float | None = None, fetch_docs: bool = True,
               mode: str = "sparse", vector=None, sq=None, facets=None,
               snippets: bool = False) -> PendingResponse:
        """Admit a query to the gateway's adaptive micro-batch window:
        concurrent arrivals inside one window coalesce into ONE
        ``ScatterGather.search_batch`` dispatch — one vmapped invocation
        per partition per window — and under sparse traffic the window is
        zero, so the returned handle resolves immediately with exactly the
        latency :meth:`query` would have charged. The serving generation is
        pinned per query AT ADMISSION: a commit landing while the window is
        open splits the flush into per-generation dispatches instead of
        moving an admitted query to an index it didn't arrive under.
        ``mode``/``vector``/``sq``/``facets``/``snippets`` as in
        :meth:`query`; a window groups dispatches by (generation, mode,
        structured), so mixed traffic coalesces per dispatch shape."""
        return self.gateway.submit(
            "GET", "/search",
            _search_body(q, k, fetch_docs, mode, vector, sq, facets,
                         snippets),
            t_arrival=t_arrival)

    def flush(self, now: float | None = None) -> int:
        """Close the search route's due admission window(s) — the window
        timer's analogue for virtual-clock drivers; call once at end of
        run (``now=None`` closes unconditionally)."""
        return self.gateway.flush(now)

    def warm(self, *, t_arrival: float | None = None) -> list[InvocationRecord]:
        """Touch EVERY function — primaries and replicas — once, hydrating
        each pool (replicas otherwise only see traffic when a hedge fires,
        so a backup leg would land as cold as the straggler it covers).
        The paper's "keep the fleet warm" pinger, fleet-wide. Pings are
        capacity maintenance, not queries: they bill to the ledger's idle
        line and stay out of latency percentiles and controller signals."""
        t0 = self.runtime.clock if t_arrival is None else t_arrival
        payload = {"q": "", "k": 1, "fetch_docs": False}
        if self.embedder is not None:
            # warm BOTH tiers on hybrid fleets: a dense leg landing on a
            # pool that only ever saw sparse pings would hydrate cold
            payload["mode"] = "hybrid"
            payload["qv"] = [float(x) for x in self.embedder("")]
        recs = []
        for group in self.fn_groups:
            for fn in group:
                _, rec = self.runtime.invoke(fn, dict(payload), t_arrival=t0,
                                             keepalive=True)
                recs.append(rec)
        return recs

    # -- the /index coordinator (NRT writes) --------------------------------------

    def add_documents(self, docs: Iterable[tuple[str, str]], *,
                      t_arrival: float | None = None):
        """Stage (ext_id, text) docs for the next commit."""
        return self.gateway.request(
            "POST", "/index", {"op": "add", "docs": [list(d) for d in docs]},
            t_arrival=t_arrival)

    def delete_documents(self, ids: Iterable[str], *,
                         t_arrival: float | None = None):
        """Stage deletes (tombstones) for the next commit."""
        return self.gateway.request(
            "POST", "/index", {"op": "delete", "ids": list(ids)},
            t_arrival=t_arrival)

    def commit(self, *, t_arrival: float | None = None):
        """Pack staged changes into delta segments, publish the next
        generation, and roll the fleet over to it — zero downtime."""
        return self.gateway.request(
            "POST", "/index", {"op": "commit"}, t_arrival=t_arrival)

    def _index_route(self, body: dict, t_arrival: float | None
                     ) -> tuple[dict, float, InvocationRecord | None]:
        ix = self.indexer
        if ix is None:
            raise ValueError("this app was built without an indexer")
        op = body.get("op")
        if op == "add":
            n = ix.stage_add([tuple(d) for d in body["docs"]])
            return {"staged": True, "pending_adds": n}, ENQUEUE_COST_S, None
        if op == "delete":
            n = ix.stage_delete(body["ids"])
            return {"staged": True, "pending_deletes": n}, ENQUEUE_COST_S, None
        if op == "commit":
            # rollover prewarm: partial, term-frequency-ranked — each ping
            # hydrates the new generation's superindex + the top-df terms'
            # blocks (and the dense tier's live rows, when one exists)
            # instead of backfilling the whole partition; the cold tail
            # still lazy-loads on demand. Eager fleets hydrate fully, as
            # before.
            ping = {"q": "", "k": 1, "fetch_docs": False,
                    "prewarm_terms": PREWARM_TOP_TERMS}
            if self.embedder is not None:
                ping["prewarm_dense"] = True
            result, lat = ix.commit(
                self.fn_groups, t_arrival=t_arrival, ping_payload=ping)
            return result, lat, None
        raise ValueError(f"unknown /index op {op!r}")

    # -- the /search coordinator (Gateway → ScatterGather → merge) ---------------

    def _global_id(self, hit: PartitionHit, offsets: list[int] | None) -> int:
        if offsets is not None:
            return offsets[hit.partition] + hit.doc_id
        return hit.partition * self.n_docs_local + hit.doc_id

    def _fetch_raw(self, merged: list[list[PartitionHit]],
                   fetch_docs: bool) -> tuple[dict, float]:
        """ONE batched KV fetch for the union of all merged hits — per-query
        (or per-partition) round trips would defeat the batching. Charged
        per BatchGetItem-sized chunk (the store's own accounting)."""
        ext = dict.fromkeys(
            h.ext_id for hits in merged for h in hits if h.ext_id is not None)
        if not fetch_docs:
            return {}, 0.0
        return self.doc_store.batch_get_billed(ext)

    def _materialize(self, hits: list[PartitionHit], raw: dict, *,
                     terms: "list[str] | None" = None,
                     snippets: bool = False) -> dict:
        offsets = (self.indexer.part_doc_offsets()
                   if self.indexer is not None else None)
        ext_ids = [h.ext_id for h in hits]
        docs = [raw.get(e) for e in ext_ids] if raw else []
        out = {
            "ids": [self._global_id(h, offsets) for h in hits],
            "scores": [h.score for h in hits],
            "ext_ids": ext_ids,
            "docs": docs,
        }
        if snippets:
            # cut from the SAME deduped KV fetch the merge already did —
            # snippets add zero extra round trips (they need fetch_docs)
            out["snippets"] = [
                make_snippet(d["contents"], terms or []) if d else None
                for d in docs]
        return out

    def _merged_facets(self, results: list, qi: int, batched: bool,
                       facet_fields) -> dict:
        """Gather-side facet merge for one query: each partition counted
        its FULL eligible match set per requested field; string-keyed
        summation joins them globally — facets merge at gather exactly
        like top-k, one more reduction over the same scatter results."""
        per_part = [(r["results"][qi] if batched else r) for r in results]
        return {f: merge_facet_counts(
                    [pp.get("facets", {}).get(f, {}) for pp in per_part])
                for f in facet_fields}

    def _field_avgdl(self) -> dict:
        """Live per-field average lengths from the writer's global stats —
        partition-invariant scoring inputs, shipped with every structured
        scatter (resolved at the same instant the generation is pinned,
        so legs never score a field under a different corpus state than
        the generation they serve)."""
        stats = self.indexer.stats
        return {f: field_avgdl(stats, f) for f in stats.get("fields", {})}

    def _structured_plan(self, body: dict, mode: str
                         ) -> tuple[str, bool, list, None, "list[Query]"]:
        """The structured (``sq``/``sqs``) half of :meth:`_query_plan`:
        parse the DSL ONCE here at admission — workers only ever see the
        shipped AST payloads — and reject everything the fleet cannot
        serve (no structured tier, undeclared facet field, malformed
        query) BEFORE anything dispatches."""
        if mode != "sparse":
            raise BadRequest("structured queries are sparse-tier only "
                             f"(got mode={mode!r})")
        if not self.structured:
            raise BadRequest(
                "this fleet serves no structured tier (build it with "
                "FleetSpec(index=IndexSpec(structured=True, ...)))")
        if "q" in body or "queries" in body:
            raise BadRequest("pass either q/queries or sq/sqs, not both")
        batched = "sqs" in body
        raw = list(body["sqs"]) if batched else [body["sq"]]
        if batched and not raw:
            raise BadRequest("sqs=[] — an empty micro-batch has nothing "
                             "to dispatch")
        try:
            asts = [parse_query(s) for s in raw]
        except QueryParseError as e:
            raise BadRequest(str(e)) from None
        for f in body.get("facets", ()):
            if f not in self.facet_fields:
                raise BadRequest(
                    f"facet field {f!r} not declared "
                    f"(declared: {list(self.facet_fields)})")
        return mode, batched, raw, None, asts

    def _query_plan(self, body: dict) -> tuple[str, bool, "list | None",
                                               "list | None",
                                               "list[Query] | None"]:
        """Validate a /search body and resolve its tiers' inputs:
        (mode, batched, texts, vectors, structured ASTs). Texts is None
        for a vector-only dense query; vectors is None for sparse; ASTs
        are non-None iff the body carries ``sq``/``sqs`` (texts then
        holds the raw DSL strings). Embeds text queries at the
        COORDINATOR when the client sent no vectors — every scatter
        leg (and the oracle) then scores identical floats. Raises
        :class:`BadRequest` for anything the fleet cannot serve."""
        mode = body.get("mode", "sparse")
        if mode not in SEARCH_MODES:
            raise BadRequest(f"mode must be one of {SEARCH_MODES}, "
                             f"got {mode!r}")
        if "sq" in body or "sqs" in body:
            return self._structured_plan(body, mode)
        batched = "queries" in body or "qvs" in body
        if "queries" in body:
            texts = list(body["queries"])
        elif "q" in body:
            texts = [body["q"]]
        else:
            texts = None
        if mode == "sparse":
            if texts is None:
                raise BadRequest("sparse search needs q/queries text")
            if batched and not texts:
                # reject BEFORE anything dispatches: an empty micro-batch
                # has nothing to scatter, and invoking the fleet for it
                # would bill every partition for zero queries (the gateway
                # maps this to a 400 — the client's error, not a 502)
                raise BadRequest("queries=[] — an empty micro-batch has "
                                 "nothing to dispatch")
            return mode, batched, texts, None, None
        if self.embedder is None:
            raise BadRequest("this fleet serves no dense-vector tier "
                             "(build it with FleetSpec(index=IndexSpec("
                             "vector=VectorSpec(...))))")
        if mode == "hybrid" and texts is None:
            raise BadRequest("hybrid search needs q/queries text for its "
                             "sparse tier")
        if "qvs" in body:
            vecs = [list(v) for v in body["qvs"]]
        elif "qv" in body:
            vecs = [list(body["qv"])]
        else:
            vecs = None
        if vecs is None:
            if texts is None:
                raise BadRequest(f"{mode} search needs text or qv/qvs "
                                 "query vectors")
            vecs = [[float(x) for x in self.embedder(q)] for q in texts]
        if texts is not None and len(vecs) != len(texts):
            raise BadRequest(f"{len(vecs)} query vectors for "
                             f"{len(texts)} text queries")
        if batched and not vecs:
            raise BadRequest("qvs=[] — an empty micro-batch has nothing "
                             "to dispatch")
        return mode, batched, texts, vecs, None

    def _merged_hitlists(self, results: list, n_q: int, batched: bool,
                         mode: str, k: int) -> list[list[PartitionHit]]:
        """Coordinator-side gather: per-query global top-k hit lists from
        the scatter's raw per-partition results.

        Sparse/dense merge exactly like the pre-hybrid path (the handler
        puts the selected tier's hits in the primary result fields).
        Hybrid fuses with Reciprocal Rank Fusion: each tier merges to the
        full per-partition depth (``search_k`` — the deepest sound
        ranking), then ``rrf_fuse`` combines the two rankings by rank
        alone, in fixed (sparse, dense) tier order — the same call the
        oracle fusion makes, so fused scores are bit-identical to it."""
        def tier(qi: int, sub: str | None) -> list[dict]:
            per_part = []
            for r in results:
                rr = r["results"][qi] if batched else r
                per_part.append(rr[sub] if sub else rr)
            return per_part

        if mode != "hybrid":
            return [_merge_hits(tier(qi, None), k) for qi in range(n_q)]
        out = []
        for qi in range(n_q):
            sparse = _merge_hits(tier(qi, None), self.search_k)
            dense = _merge_hits(tier(qi, "dense"), self.search_k)
            bykey = {(h.partition, h.doc_id): h for h in dense}
            bykey.update({(h.partition, h.doc_id): h for h in sparse})
            fused = rrf_fuse([[(h.partition, h.doc_id) for h in sparse],
                              [(h.partition, h.doc_id) for h in dense]], k)
            out.append([PartitionHit(key[1], score, key[0],
                                     bykey[key].ext_id)
                        for key, score in fused])
        return out

    def _search_route(self, body: dict, t_arrival: float | None
                      ) -> tuple[dict, float, InvocationRecord | None]:
        # a partition only surfaces its top search_k candidates — a merged
        # rank past that could silently miss docs, so clamp rather than lie
        k = min(int(body.get("k", self.search_k)), self.search_k)
        fetch_docs = body.get("fetch_docs", True)
        mode, batched, texts, vecs, asts = self._query_plan(body)
        n_q = len(asts) if asts is not None else \
            len(texts) if texts is not None else len(vecs)
        facet_req = list(body.get("facets", ())) if asts is not None else []
        snippets = bool(body.get("snippets")) and asts is not None
        # hybrid legs return their full search_k per tier — RRF ranks are
        # only sound at the deepest per-tier depth; the fused list then
        # truncates to the caller's k
        payload = {"k": self.search_k if mode == "hybrid" else k,
                   "fetch_docs": False}
        if mode != "sparse":
            payload["mode"] = mode
        if self.indexer is not None:
            # pin ONE generation for every leg of this query — primaries,
            # hedged backups, freshly-scaled replicas — so a commit's
            # rollover landing mid-scatter can never tear the merge across
            # generations (ScatterGather additionally asserts this, across
            # BOTH tiers of a hybrid result)
            payload["gen"] = self.indexer.gen
        if asts is not None:
            # ship the admission-parsed ASTs (workers never re-parse) with
            # the per-query facet requests and the live field avgdls —
            # resolved HERE, the same instant the generation was pinned
            if batched:
                payload["sqs"] = [a.to_payload() for a in asts]
            else:
                payload["sq"] = asts[0].to_payload()
            payload["facets"] = [facet_req] * n_q
            payload["favg"] = self._field_avgdl()
        elif batched:
            if texts is not None:
                payload["queries"] = texts
            if vecs is not None:
                payload["qvs"] = vecs
        else:
            if texts is not None:
                payload["q"] = texts[0]
            if vecs is not None:
                payload["qv"] = vecs[0]
        results, lat, records = self.scatter.scatter(
            payload, t_arrival=t_arrival)
        merged = self._merged_hitlists(results, n_q, batched, mode, k)
        raw, fetch_s = self._fetch_raw(merged, fetch_docs)

        def _mat(qi: int) -> dict:
            r = self._materialize(
                merged[qi], raw,
                terms=asts[qi].terms if asts is not None else None,
                snippets=snippets)
            if facet_req:
                r["facets"] = self._merged_facets(results, qi, batched,
                                                  facet_req)
            return r

        if batched:
            result: dict = {"results": [_mat(qi) for qi in range(n_q)]}
        else:
            result = _mat(0)
        result["partitions"] = [
            {"fn": r.fn, "cold": r.cold, "hydrate_s": r.hydrate_s,
             "backfill_s": r.backfill_s, "latency_s": r.latency_s,
             "hedged": r.hedged} for r in records]
        if "gen" in payload:
            result["generation"] = payload["gen"]
        slowest = max(records, key=lambda r: r.latency_s, default=None) \
            if records else None
        # the control loop rides the request path: the controller ticks at
        # the arrival instant AFTER dispatch — scale decisions see this
        # arrival in their window, and keep-alive pings can never race the
        # request itself for a pool's idle instance (the legs just
        # dispatched hold their instances busy at t0, so their pools are
        # skipped as traffic-warmed)
        if self.controller is not None:
            self.controller.maybe_tick(
                self.runtime.clock if t_arrival is None else t_arrival)
        return result, lat + fetch_s, slowest

    # -- the windowed /search coordinator (adaptive micro-batch dispatch) ---------

    def _admit_search(self, body: dict, t_arrival: float) -> dict:
        """Admission hook for the batched ``/search`` route: validate the
        body before it can occupy the window, and pin the serving
        generation AT ADMISSION — so a commit whose rollover lands while
        the window is still open can never retroactively move an admitted
        query onto an index it didn't arrive under (the flush then splits
        into one scatter per pinned generation; every one of them still
        merges hits from exactly one generation). Dense/hybrid bodies also
        resolve their query vectors here (embedding the text when the
        client sent none), so a flush never has to reject. Structured
        bodies parse their DSL here (malformed → 400 before the window)
        and pin the live field avgdls alongside the generation — the
        scoring state a commit inside the open window must not move."""
        mode, _, texts, vecs, asts = self._query_plan(body)
        body = dict(body)
        body["_texts"], body["_vecs"], body["_mode"] = texts, vecs, mode
        body["_asts"] = asts
        if asts is not None:
            body["_favg"] = self._field_avgdl()
        if self.indexer is not None:
            body["_gen"] = self.indexer.gen
        return body

    def _search_route_batch(self, bodies: list, t_arrivals: list,
                            t_dispatch: float) -> list:
        """Dispatch ONE admission window: every query of every admitted
        body rides a single ``search_batch`` scatter per pinned generation
        — one vmapped invocation per partition per window — and the merged
        per-query top-k is bit-identical to serial dispatch (per-query
        candidate sets never interact; a window's k is the per-partition
        ``search_k`` ceiling and each body's smaller ``k`` is a prefix of
        that merge). Duplicate query strings across (or within) bodies are
        NOT coalesced: every admitted query gets its own slot in the batch
        and its own full result."""
        # (batched, texts, vecs, mode, n_q, k, fetch_docs, gen, asts,
        #  facets, snippets, favg) per body — _admit_search already
        # validated and resolved _texts/_vecs/_mode/_asts/_favg
        per_body = []
        for body in bodies:
            texts, vecs = body["_texts"], body["_vecs"]
            mode = body["_mode"]
            asts = body.get("_asts")
            per_body.append((
                "queries" in body or "qvs" in body or "sqs" in body,
                texts, vecs, mode,
                len(asts) if asts is not None else
                len(texts) if texts is not None else len(vecs),
                min(int(body.get("k", self.search_k)), self.search_k),
                body.get("fetch_docs", True),
                body.get("_gen"),
                asts,
                list(body.get("facets", ())) if asts is not None else [],
                bool(body.get("snippets")) and asts is not None,
                body.get("_favg")))
        # one scatter per (pinned generation, mode, structured), in
        # admission order — normally exactly one; more when a commit
        # landed inside the open window or dispatch shapes mix (tiers
        # hydrate per leg and structured payloads ship ASTs, so shape is
        # part of the dispatch identity, not a per-query flag)
        group_order: list = []
        group_members: dict = {}
        for bi, pb in enumerate(per_body):
            gkey = (pb[7], pb[3], pb[8] is not None)
            if gkey not in group_members:
                group_order.append(gkey)
                group_members[gkey] = []
            group_members[gkey].append(bi)
        merged_by_body: dict[int, list] = {}
        facets_by_body: dict[int, list] = {}
        lat_by_body: dict[int, float] = {}
        recs_by_body: dict[int, list] = {}
        for gkey in group_order:
            gen, mode, structured = gkey
            idxs = group_members[gkey]
            payload: dict = {"k": self.search_k, "fetch_docs": False}
            if structured:
                # flat AST micro-batch + per-query facet requests; favg is
                # generation-pinned, so any member's pin serves the group
                payload["sqs"] = [a.to_payload() for bi in idxs
                                  for a in per_body[bi][8]]
                payload["facets"] = [per_body[bi][9] for bi in idxs
                                     for _ in per_body[bi][8]]
                payload["favg"] = per_body[idxs[0]][11] or {}
            else:
                if mode != "sparse":
                    payload["mode"] = mode
                    payload["qvs"] = [v for bi in idxs
                                      for v in per_body[bi][2]]
                if mode != "dense":
                    payload["queries"] = [q for bi in idxs
                                          for q in per_body[bi][1]]
                elif any(per_body[bi][1] is not None for bi in idxs):
                    # text-less dense bodies leave queries out entirely;
                    # mixed groups substitute "" so counts stay aligned
                    payload["queries"] = [q for bi in idxs for q in
                                          (per_body[bi][1] or
                                           [""] * per_body[bi][4])]
            if gen is not None:
                payload["gen"] = gen
            results, lat, records = self.scatter.scatter(
                payload, t_arrival=t_dispatch)
            n_flat = sum(per_body[bi][4] for bi in idxs)
            merged = self._merged_hitlists(results, n_flat, True, mode,
                                           self.search_k)
            at = 0
            for bi in idxs:
                n = per_body[bi][4]
                merged_by_body[bi] = merged[at: at + n]
                freq = per_body[bi][9]
                if freq:
                    facets_by_body[bi] = [
                        self._merged_facets(results, at + j, True, freq)
                        for j in range(n)]
                at += n
                lat_by_body[bi] = lat
                recs_by_body[bi] = records
        # ONE batched KV fetch for the union of every doc-requesting
        # body's hits — the same amortization the handler-side batch does
        need = [hits for bi, pb in enumerate(per_body)
                if pb[6] for hits in merged_by_body[bi]]
        raw, fetch_s = self._fetch_raw(need, True) if need else ({}, 0.0)
        out = []
        for bi, (batched, texts, vecs, mode, n_q, k, fetch_docs, gen,
                 asts, freq, snip, _favg) in enumerate(per_body):
            braw = raw if fetch_docs else {}
            hit_lists = [hits[:k] for hits in merged_by_body[bi]]

            def _mat(j: int) -> dict:
                r = self._materialize(
                    hit_lists[j], braw,
                    terms=asts[j].terms if asts is not None else None,
                    snippets=snip)
                if freq:
                    r["facets"] = facets_by_body[bi][j]
                return r

            if batched:
                result: dict = {"results": [_mat(j) for j in range(n_q)]}
            else:
                result = _mat(0)
            result["partitions"] = [
                {"fn": r.fn, "cold": r.cold, "hydrate_s": r.hydrate_s,
                 "backfill_s": r.backfill_s, "latency_s": r.latency_s,
                 "hedged": r.hedged}
                for r in recs_by_body[bi]]
            if gen is not None:
                result["generation"] = gen
            out.append((result,
                        lat_by_body[bi] + (fetch_s if fetch_docs else 0.0)))
        # same control-loop ride-along as the serial path: tick AFTER the
        # window dispatched, so keep-alive pings never race the batch for
        # a pool's idle instance
        if self.controller is not None:
            self.controller.maybe_tick(t_dispatch)
        return out


def build_partitioned_search_app(
    docs: Iterable[tuple[str, str]],
    spec: "FleetSpec | int | None" = None,
    *,
    n_parts: int | None = None,
    replicas: int | None = None,
    hedge: "HedgePolicy | float | None" = None,
    autoscale: "AutoscalePolicy | bool | None" = None,
    routing: str | None = None,
    window: WindowPolicy | None = None,
    partition_weights: "list[float] | None" = None,
    merge_policy: MergePolicy | None = None,
    runtime_config: RuntimeConfig | None = None,
    search_config: SearchConfig | None = None,
    backend: Backend | None = None,
    asset_prefix: str | None = None,
) -> PartitionedSearchApp:
    """Assemble the partitioned fleet: one segment per partition, ``replicas``
    Lambda functions serving it, global BM25 stats, scatter-gather behind
    ``/search``.

    The configuration surface is :class:`~repro.core.partition.FleetSpec`::

        app = build_partitioned_search_app(docs, FleetSpec(
            n_parts=4,
            replication=ReplicationSpec(replicas=2, hedge=0.05),
            index=IndexSpec(vector=VectorSpec(dim=16)),   # dense tier
        ))

    DEPRECATED: the pre-FleetSpec keyword sprawl (``n_parts=...,
    replicas=..., hedge=..., ...``) still assembles identically through a
    shim — each legacy kwarg maps onto the corresponding spec field, and a
    bare int second positional is ``n_parts`` — but new call sites should
    pass a ``FleetSpec``; mixing both surfaces in one call is an error.

    Every partition's segment is packed with ``compute_global_stats`` over
    the FULL corpus — the distributed-IR invariant that makes the merged
    ranking identical to a single-index build at any partition count.

    ``replicas=R`` publishes each segment ONCE (shared ``AssetCatalog``
    entry) but registers R functions per partition — separate instance
    pools over identical ``PackedIndex``es, so a backup leg returns
    bit-identical hits. ``hedge`` is a :class:`HedgePolicy` (or a float
    shorthand for a fixed ``after_s`` threshold) enabling projection-based
    backup legs; replicas without a policy are standby-only.

    ``autoscale`` (an :class:`AutoscalePolicy`, or ``True`` for defaults)
    attaches a :class:`FleetController`: ``replicas`` then only sets the
    STARTING group size, and the controller grows/shrinks each partition's
    pool count between ``min_replicas`` and ``max_replicas`` against the
    cost ledger, ticking on the request path. ``routing`` selects the
    scatter's primary-choice rule (``"static"`` or ``"aware"``); it
    defaults to ``"aware"`` whenever a controller is attached — a fleet
    whose pools come and go should not pin primaries to pool zero — and to
    the PR 2 ``"static"`` behaviour otherwise.

    The fleet is WRITABLE: segments publish as generation 1 through a
    :class:`FleetIndexer`, and ``POST /index`` (``add_documents`` /
    ``delete_documents`` / ``commit``) grows the index with delta segments
    + zero-downtime generation rollovers; ``merge_policy`` bounds the
    delta tier. Every query pins the serving generation across all its
    scatter legs, so rollovers can never tear a merged result.

    ``window`` (a :class:`~repro.core.gateway.WindowPolicy`; defaults
    apply when omitted) governs the gateway's adaptive micro-batch window
    behind :meth:`PartitionedSearchApp.submit`: concurrent arrivals
    coalesce into one vmapped invocation per partition per window, sized
    from the trailing arrival rate and zero under sparse traffic. The
    synchronous :meth:`~PartitionedSearchApp.query` path never waits on a
    window. ``partition_weights`` skews the document split (Zipf-shaped
    fleets: a hot head partition, a cold tail) — global BM25 stats keep
    the merged ranking exact regardless of the split.
    """
    # keyword sprawl = the flattened fleet shape that FleetSpec replaced.
    # runtime_config / search_config / backend are verbatim FleetSpec
    # fields, fine to pass alongside the bare-int n_parts shorthand.
    sprawl = {k: v for k, v in dict(
        n_parts=n_parts, replicas=replicas, hedge=hedge, autoscale=autoscale,
        routing=routing, window=window, partition_weights=partition_weights,
        merge_policy=merge_policy, asset_prefix=asset_prefix).items()
        if v is not None}
    legacy = dict(sprawl)
    for k, v in dict(runtime_config=runtime_config,
                     search_config=search_config, backend=backend).items():
        if v is not None:
            legacy[k] = v
    if isinstance(spec, FleetSpec):
        if legacy:
            raise TypeError(
                "pass configuration on the FleetSpec, not as legacy "
                f"kwargs: {sorted(legacy)}")
    else:
        if spec is not None:       # positional n_parts shorthand, not sprawl
            legacy.setdefault("n_parts", int(spec))
        if sprawl:
            warnings.warn(
                "build_partitioned_search_app's keyword sprawl is "
                "deprecated; pass a FleetSpec instead",
                DeprecationWarning, stacklevel=2)
        spec = FleetSpec(
            n_parts=legacy.get("n_parts", 4),
            replication=ReplicationSpec(
                replicas=legacy.get("replicas", 1),
                hedge=legacy.get("hedge"),
                autoscale=legacy.get("autoscale")),
            gateway=GatewaySpec(window=legacy.get("window"),
                                routing=legacy.get("routing")),
            index=IndexSpec(
                partition_weights=legacy.get("partition_weights"),
                merge_policy=legacy.get("merge_policy"),
                asset_prefix=legacy.get("asset_prefix", "index")),
            runtime_config=legacy.get("runtime_config"),
            search_config=legacy.get("search_config"),
            backend=legacy.get("backend"))

    rep, gw, ix = spec.replication, spec.gateway, spec.index
    autoscale_policy = rep.autoscale
    if autoscale_policy is True:
        autoscale_policy = AutoscalePolicy()
    resolved_routing = gw.routing or ("aware" if autoscale_policy
                                      else "static")
    embedder = None
    if ix.vector is not None:
        embedder = ix.vector.embedder or hash_embedder(ix.vector.dim)
    scfg = spec.search_config or SearchConfig()
    if scfg.lazy_hydration is None:
        # the fleet default since PR 8: cold legs answer from range reads
        # of the superindex + the queried terms' blocks (PR 7's layout),
        # backfilling off the critical path. Pass lazy_hydration=False to
        # pin the historical eager profile.
        scfg = dataclasses.replace(scfg, lazy_hydration=True)

    docs = list(docs)
    store = ObjectStore(spec.backend)
    doc_store = KVStore()
    catalog = AssetCatalog(store)
    runtime = FaaSRuntime(spec.runtime_config)
    # structured fleets carry per-field stats for BM25F avgdl; v1 fleets
    # must not grow the stats blob (its bytes feed hydration pricing)
    gstats = compute_global_stats(docs, fields=ix.structured)
    # every partition packs against the corpus-global vocab: queries then
    # encode (and idf-truncate, for > max_terms) identically per partition
    gvocab = global_vocab(gstats)
    parts, per = partition_corpus(docs, spec.n_parts,
                                  weights=ix.partition_weights)
    indexer = FleetIndexer(
        catalog, doc_store, runtime, stats=gstats, vocab=gvocab,
        merge_policy=ix.merge_policy, sim_write_s=scfg.sim_write_s,
        sim_write_per_doc_s=scfg.sim_write_per_doc_s,
        stats_asset=f"{ix.asset_prefix}-stats",
        embedder=embedder,
        vec_dim=ix.vector.dim if ix.vector else 16,
        vec_dtype=ix.vector.dtype if ix.vector else "float32",
        structured=ix.structured, facet_fields=ix.facet_fields)
    assets, fn_groups = [], []
    for p, pdocs in enumerate(parts):
        if not pdocs:        # corpus didn't fill the last partition(s)
            continue
        asset = f"{ix.asset_prefix}-p{p}"
        indexer.add_partition(asset, pdocs)
        group = []
        for r in range(rep.replicas):
            fn = f"search-p{p}" if r == 0 else f"search-p{p}r{r}"
            runtime.register(fn, make_search_handler(
                catalog, doc_store, asset, scfg))
            group.append(fn)
        assets.append(asset)
        fn_groups.append(group)
    scatter = ScatterGather(runtime, fn_groups, hedge=rep.hedge,
                            routing=resolved_routing,
                            degraded_ok=rep.degraded_ok)
    gateway = Gateway(runtime)
    controller = None
    if autoscale_policy:
        # one factory per partition: a scale-up registers a fresh handler
        # over the SAME published asset — no re-publish, no new segment
        factories = [
            (lambda a=asset_name: make_search_handler(
                catalog, doc_store, a, scfg))
            for asset_name in assets]
        controller = FleetController(
            runtime, scatter, factories, autoscale_policy,
            ping_payload={"q": "", "k": 1, "fetch_docs": False})
    app = PartitionedSearchApp(
        store=store, catalog=catalog, doc_store=doc_store, runtime=runtime,
        gateway=gateway, scatter=scatter, assets=assets,
        fn_names=scatter.fn_names, n_parts=spec.n_parts, n_docs_local=per,
        search_k=scfg.k,
        fn_groups=scatter.groups, replicas=rep.replicas,
        controller=controller, indexer=indexer, embedder=embedder,
        structured=ix.structured, facet_fields=tuple(ix.facet_fields))
    gateway.route("GET", "/search", app._search_route)
    # admission sheds feed the autoscaler: sustained backpressure is a
    # scale-up signal the latency/queue estimators can't see (shed
    # arrivals never reach a pool)
    gateway.route_batched("GET", "/search", app._search_route_batch,
                          policy=gw.window, admit=app._admit_search,
                          on_shed=controller.note_shed if controller
                          else None)
    gateway.route("POST", "/index", app._index_route)
    return app
