"""End-to-end Anlessini application assembly (Figure 1 of the paper).

``build_search_app`` wires corpus → index → object store → FaaS runtime →
gateway and returns the pieces; used by examples, benchmarks, and tests.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable

from repro.core.gateway import Gateway
from repro.core.kvstore import KVStore
from repro.core.object_store import Backend, ObjectStore
from repro.core.refresh import AssetCatalog
from repro.core.runtime import FaaSRuntime, RuntimeConfig
from repro.index.builder import IndexWriter, write_segment
from repro.search.searcher import SearchConfig, make_search_handler


@dataclasses.dataclass
class SearchApp:
    store: ObjectStore
    catalog: AssetCatalog
    doc_store: KVStore
    runtime: FaaSRuntime
    gateway: Gateway
    asset: str

    def query(self, q: str, k: int = 10, *, t_arrival: float | None = None):
        return self.gateway.request(
            "GET", "/search", {"q": q, "k": k}, t_arrival=t_arrival)


def index_corpus(docs: Iterable[tuple[str, str]], store: ObjectStore,
                 doc_store: KVStore, *, asset: str = "index",
                 version: str = "v1",
                 global_stats: dict | None = None) -> AssetCatalog:
    """The offline batch side: build, pack, publish (paper §3).

    Pass ``global_stats`` (index.builder.compute_global_stats over the FULL
    corpus) when these docs are one partition of a larger deployment."""
    writer = IndexWriter(global_stats=global_stats)
    for ext_id, text in docs:
        writer.add(ext_id, text)
        doc_store.put(ext_id, {"id": ext_id, "contents": text})
    packed = writer.pack()
    catalog = AssetCatalog(store)
    catalog.publish(asset, version, write_segment(packed))
    return catalog


def build_search_app(
    docs: Iterable[tuple[str, str]],
    *,
    runtime_config: RuntimeConfig | None = None,
    search_config: SearchConfig | None = None,
    backend: Backend | None = None,
    asset: str = "index",
) -> SearchApp:
    store = ObjectStore(backend)
    doc_store = KVStore()
    catalog = index_corpus(docs, store, doc_store, asset=asset)
    runtime = FaaSRuntime(runtime_config)
    runtime.register(
        "search", make_search_handler(catalog, doc_store, asset, search_config))
    gateway = Gateway(runtime)
    gateway.route("GET", "/search", "search")
    return SearchApp(store, catalog, doc_store, runtime, gateway, asset)
