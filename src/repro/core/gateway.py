"""API Gateway analogue: REST-ish routing in front of the FaaS runtime.

Paper §2: "all operations are proxied through REST endpoints provided by the
API Gateway. The final product is a full-featured search application
accessible to a search client."

The gateway owns route → function mapping, request/response envelopes, and
adds the gateway's own (small) proxy overhead so end-to-end latency matches
what the paper measures "from the browser".
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

from repro.core.runtime import (FaaSRuntime, InvocationRecord,
                                nearest_rank_percentiles)


GATEWAY_OVERHEAD_S = 0.010   # API-Gateway proxy+auth overhead (~10 ms)


class RouteError(Exception):
    pass


@dataclasses.dataclass(frozen=True)
class Response:
    status: int
    body: Any
    latency_s: float
    record: InvocationRecord | None = None

    @property
    def ok(self) -> bool:
        return 200 <= self.status < 300


# A coordinator route fans one request out to several functions (e.g.
# scatter-gather over partitions) and owns its own latency accounting:
# (body, t_arrival) -> (result, latency_s, representative record | None).
Coordinator = Callable[[Any, "float | None"],
                       "tuple[Any, float, InvocationRecord | None]"]


class Gateway:
    def __init__(self, runtime: FaaSRuntime) -> None:
        self.runtime = runtime
        self._routes: dict[tuple[str, str], "str | Coordinator"] = {}
        # end-to-end latency log per route (what "the browser" saw) — the
        # runtime's records are per-invocation, so a hedged or fanned-out
        # request has no single record to read percentiles from
        self.latencies: dict[tuple[str, str], list[float]] = {}

    def route(self, method: str, path: str, fn: "str | Coordinator") -> None:
        """Map method+path to a runtime function name, or to a coordinator
        callable that orchestrates several invocations (scatter-gather)."""
        self._routes[(method.upper(), path)] = fn

    def request(self, method: str, path: str, body: Any = None,
                *, t_arrival: float | None = None) -> Response:
        key = (method.upper(), path)
        fn = self._routes.get(key)
        if fn is None:
            return Response(404, {"error": f"no route {method} {path}"}, 0.0)
        try:
            if callable(fn):
                result, lat, rec = fn(body, t_arrival)
            else:
                result, rec = self.runtime.invoke(fn, body,
                                                  t_arrival=t_arrival)
                lat = rec.latency_s
        except Exception as e:  # Lambda error → 502 from the gateway
            return Response(502, {"error": str(e)}, GATEWAY_OVERHEAD_S)
        self.latencies.setdefault(key, []).append(lat + GATEWAY_OVERHEAD_S)
        return Response(200, result, lat + GATEWAY_OVERHEAD_S, rec)

    def latency_percentiles(self, method: str, path: str,
                            qs=(0.5, 0.9, 0.99)) -> dict[float, float]:
        """End-to-end latency quantiles for one route, over successful
        requests (the numbers the paper reports "from the browser")."""
        return nearest_rank_percentiles(
            self.latencies.get((method.upper(), path), []), qs)

    def routes(self) -> list[tuple[str, str, str]]:
        return [(m, p, f if isinstance(f, str)
                 else getattr(f, "__name__", "<coordinator>"))
                for (m, p), f in sorted(self._routes.items())]
