"""API Gateway analogue: REST-ish routing in front of the FaaS runtime.

Paper §2: "all operations are proxied through REST endpoints provided by the
API Gateway. The final product is a full-featured search application
accessible to a search client."

The gateway owns route → function mapping, request/response envelopes, and
adds the gateway's own (small) proxy overhead so end-to-end latency matches
what the paper measures "from the browser".

Batched routes additionally get an ADMISSION QUEUE with an adaptive
micro-batch window: concurrent arrivals inside one window coalesce into a
single coordinator dispatch (for ``/search``: one vmapped invocation per
partition per window), which is how the gateway serves "interactive search
at unusual operating points" — amortizing a device call over whatever
concurrency the arrival process actually offers. The window is sized from
the trailing arrival rate, clamped by a p99-latency budget, and collapses
to ZERO under sparse traffic so a lone query never waits on a window that
no second query will ever join.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

from repro.core.runtime import (FaaSRuntime, InvocationRecord,
                                RetriesExhausted, nearest_rank_percentiles)


GATEWAY_OVERHEAD_S = 0.010   # API-Gateway proxy+auth overhead (~10 ms)


@dataclasses.dataclass(frozen=True)
class BackpressurePolicy:
    """Admission backpressure for a batched route.

    A window that closes at ``max_batch`` (a HARD flush) means the arrival
    process outran the widest batch the route may dispatch. One hard flush
    is a burst; ``consecutive_hard_flushes`` of them in a row is overload,
    and from then on new arrivals are SHED: resolved immediately with a 429
    and a ``Retry-After`` derived from the trailing drain rate (the seconds
    the fleet needs to dispatch one more ``max_batch`` at its observed
    throughput). Shed requests never dispatch and bill nothing — they are
    counted on :class:`~repro.core.cost.CostLedger`'s ``shed_*`` line so an
    operator can see refused demand next to the spend it did not cause."""

    consecutive_hard_flushes: int = 3
    drain_window_s: float = 1.0        # trailing window for the drain rate
    min_retry_after_s: float = 0.050
    max_retry_after_s: float = 2.0

    def __post_init__(self) -> None:
        if self.consecutive_hard_flushes < 1:
            raise ValueError("consecutive_hard_flushes must be >= 1")
        if self.drain_window_s <= 0:
            raise ValueError("drain_window_s must be > 0")
        if not 0 <= self.min_retry_after_s <= self.max_retry_after_s:
            raise ValueError("need 0 <= min_retry_after_s <= max_retry_after_s")

    def retry_after_s(self, batch: int, drain_qps: float) -> float:
        """Seconds until the fleet should have drained one more ``batch``
        requests at the trailing rate — the honest Retry-After."""
        if drain_qps <= 0.0:
            return self.max_retry_after_s
        return min(self.max_retry_after_s,
                   max(self.min_retry_after_s, batch / drain_qps))


class RouteError(Exception):
    pass


class BadRequest(Exception):
    """A malformed request body (e.g. an empty micro-batch). Raised by a
    coordinator or an admission validator; the gateway maps it to a 400 —
    the client's error — instead of the 502 a Lambda failure earns, and a
    batched route rejects it AT ADMISSION, before anything dispatches."""


@dataclasses.dataclass(frozen=True)
class Response:
    status: int
    body: Any
    latency_s: float
    record: InvocationRecord | None = None

    @property
    def ok(self) -> bool:
        return 200 <= self.status < 300


class PendingResponse:
    """Handle for a request admitted to a batching window. The response
    materializes when the window flushes (immediately, when the adaptive
    window is zero); reading ``response`` before then raises — in a
    virtual-clock simulation that is always a driver bug, never a race."""

    __slots__ = ("t_arrival", "_response")

    def __init__(self, t_arrival: float) -> None:
        self.t_arrival = t_arrival
        self._response: Response | None = None

    def done(self) -> bool:
        return self._response is not None

    @property
    def response(self) -> Response:
        if self._response is None:
            raise RuntimeError("window still open — flush the gateway (or "
                               "submit a later arrival) before reading")
        return self._response

    def _resolve(self, response: Response) -> None:
        self._response = response


@dataclasses.dataclass
class WindowPolicy:
    """Sizing rule for the adaptive micro-batch window.

    On the FIRST arrival of a window the gateway picks how long to hold the
    admission queue open:

    * sparse traffic (trailing rate < ``sparse_qps``) → window 0: a lone
      query dispatches immediately and never pays for a batch that will not
      form;
    * otherwise ``target_batch / rate`` — just long enough for the arrival
      process to offer ~``target_batch`` coalescable queries — capped at
      ``max_window_s``;
    * clamped so the added wait cannot push the route past its latency
      budget: window ≤ ``p99_budget_s`` − the route's trailing p99 (over
      the ``p99_window`` most recent requests). A route already near
      budget stops batching before it starts breaching.
    """

    max_window_s: float = 0.050
    target_batch: int = 8
    rate_window_s: float = 1.0
    sparse_qps: float = 2.0            # below this, window -> 0
    p99_budget_s: float | None = 0.300
    p99_window: int = 64               # trailing requests for the budget clamp
    max_batch: int = 64                # hard flush at this many queued
    backpressure: BackpressurePolicy | None = None   # None -> never shed

    def window_s(self, rate_qps: float, route_p99_s: float) -> float:
        if rate_qps < self.sparse_qps:
            return 0.0
        w = min(self.max_window_s, self.target_batch / max(rate_qps, 1e-9))
        if self.p99_budget_s is not None and not math.isnan(route_p99_s):
            w = min(w, max(0.0, self.p99_budget_s - route_p99_s))
        return w


# A coordinator route fans one request out to several functions (e.g.
# scatter-gather over partitions) and owns its own latency accounting:
# (body, t_arrival) -> (result, latency_s, representative record | None).
Coordinator = Callable[[Any, "float | None"],
                       "tuple[Any, float, InvocationRecord | None]"]

# A batch coordinator dispatches one WINDOW of admitted requests at the
# window-close instant: (bodies, t_arrivals, t_dispatch) -> per-request
# (result, dispatch_latency_s) pairs, in admission order. The gateway adds
# each request's queue wait (t_dispatch - t_arrival) and proxy overhead.
BatchCoordinator = Callable[[list, list, float], "list[tuple[Any, float]]"]


class _AdmissionQueue:
    """One batched route's open window: admitted requests + close time."""

    def __init__(self, policy: WindowPolicy) -> None:
        self.policy = policy
        self.pending: list[tuple[Any, PendingResponse]] = []
        self.window_close = 0.0
        self.arrivals: list[float] = []     # trailing-rate history
        self.waits: list[float] = []        # per-request t_dispatch - t_arrival
        self.batch_sizes: list[int] = []    # per-flush, for introspection
        # backpressure state: consecutive max_batch flushes, the trailing
        # drain history (t_dispatch, batch size), shed arrivals, and the
        # horizon new arrivals are shed until once the threshold trips
        self.hard_flushes = 0
        self.flushes: list[tuple[float, int]] = []
        self.sheds: list[float] = []
        self.shed_until = 0.0

    def rate(self, now: float) -> float:
        cutoff = now - self.policy.rate_window_s
        self.arrivals = [t for t in self.arrivals if t > cutoff]
        return len(self.arrivals) / self.policy.rate_window_s

    def drain_qps(self, now: float, window_s: float) -> float:
        """Requests DISPATCHED per second over the trailing window — the
        throughput the fleet is actually sustaining, as opposed to the
        arrival rate the clients are offering."""
        cutoff = now - window_s
        self.flushes = [(t, n) for t, n in self.flushes if t > cutoff]
        return sum(n for _, n in self.flushes) / window_s


class Gateway:
    def __init__(self, runtime: FaaSRuntime) -> None:
        self.runtime = runtime
        self._routes: dict[tuple[str, str], "str | Coordinator"] = {}
        # batched routes: admission queue + window policy per route
        self._batched: dict[tuple[str, str],
                            tuple[BatchCoordinator, "Callable | None"]] = {}
        self._queues: dict[tuple[str, str], _AdmissionQueue] = {}
        # shed-notification hooks (e.g. the autoscaler counting refused
        # demand it would otherwise never see in the invocation records)
        self._on_shed: dict[tuple[str, str], Callable[[float], None]] = {}
        # end-to-end latency log per route (what "the browser" saw) — the
        # runtime's records are per-invocation, so a hedged or fanned-out
        # request has no single record to read percentiles from
        self.latencies: dict[tuple[str, str], list[float]] = {}

    def route(self, method: str, path: str, fn: "str | Coordinator") -> None:
        """Map method+path to a runtime function name, or to a coordinator
        callable that orchestrates several invocations (scatter-gather)."""
        self._routes[(method.upper(), path)] = fn

    def route_batched(self, method: str, path: str,
                      coordinator: BatchCoordinator, *,
                      policy: WindowPolicy | None = None,
                      admit: "Callable[[Any, float], Any] | None" = None,
                      on_shed: "Callable[[float], None] | None" = None
                      ) -> None:
        """Register a route whose :meth:`submit` arrivals coalesce through
        the adaptive micro-batch window into single batch dispatches.

        ``admit(body, t_arrival)`` runs at ADMISSION (not dispatch): it
        validates the body — raising :class:`BadRequest` rejects it with a
        400 before it can occupy the window — and may return an annotated
        replacement body (e.g. pinning the index generation the request
        must be served from, so a commit landing while the window is open
        can never retroactively move an already-admitted query)."""
        key = (method.upper(), path)
        self._batched[key] = (coordinator, admit)
        self._queues[key] = _AdmissionQueue(policy or WindowPolicy())
        if on_shed is not None:
            self._on_shed[key] = on_shed

    def request(self, method: str, path: str, body: Any = None,
                *, t_arrival: float | None = None) -> Response:
        key = (method.upper(), path)
        fn = self._routes.get(key)
        if fn is None:
            return Response(404, {"error": f"no route {method} {path}"}, 0.0)
        try:
            if callable(fn):
                result, lat, rec = fn(body, t_arrival)
            else:
                result, rec = self.runtime.invoke(fn, body,
                                                  t_arrival=t_arrival)
                lat = rec.latency_s
        except BadRequest as e:  # malformed body → 400, nothing dispatched
            return Response(400, {"error": str(e)}, GATEWAY_OVERHEAD_S)
        except RetriesExhausted as e:   # bounded retries ran out → typed 503
            return Response(503, {"error": str(e)}, GATEWAY_OVERHEAD_S)
        except Exception as e:  # Lambda error → 502 from the gateway
            return Response(502, {"error": str(e)}, GATEWAY_OVERHEAD_S)
        self.latencies.setdefault(key, []).append(lat + GATEWAY_OVERHEAD_S)
        return Response(200, result, lat + GATEWAY_OVERHEAD_S, rec)

    # -- the admission queue (batched routes) ---------------------------------

    def submit(self, method: str, path: str, body: Any = None,
               *, t_arrival: float | None = None) -> PendingResponse:
        """Admit a request to its route's micro-batch window.

        Arrivals must be submitted in nondecreasing ``t_arrival`` order (the
        virtual-clock discipline every driver already follows). A submission
        past the open window's close first flushes that window — so the
        caller of an EARLIER arrival can always read its response once any
        later arrival (or :meth:`flush`) has moved time past the close.
        Routes without a batch registration dispatch immediately through
        :meth:`request` and return an already-resolved handle."""
        key = (method.upper(), path)
        t0 = self.runtime.clock if t_arrival is None else t_arrival
        if key not in self._batched:
            handle = PendingResponse(t0)
            handle._resolve(self.request(method, path, body, t_arrival=t0))
            return handle
        q = self._queues[key]
        # a window whose close has passed flushes before the new arrival
        if q.pending and t0 >= q.window_close:
            self._flush_queue(key, q.window_close)

        coordinator, admit = self._batched[key]
        handle = PendingResponse(t0)
        # admission backpressure: past the consecutive-hard-flush threshold
        # the route sheds — a 429 the client can retry after the fleet has
        # had time to drain, billed to NOTHING (no dispatch, no charge; the
        # ledger's shed line is a count, not GB·s)
        if t0 < q.shed_until:
            retry_after = q.shed_until - t0
            self.runtime.ledger.record_shed()
            q.sheds.append(t0)
            hook = self._on_shed.get(key)
            if hook is not None:
                hook(t0)
            handle._resolve(Response(
                429, {"error": "admission backpressure: route overloaded",
                      "retry_after_s": retry_after}, GATEWAY_OVERHEAD_S))
            return handle
        if admit is not None:
            try:
                annotated = admit(body, t0)
            except BadRequest as e:
                handle._resolve(
                    Response(400, {"error": str(e)}, GATEWAY_OVERHEAD_S))
                return handle
            if annotated is not None:
                body = annotated

        q.arrivals.append(t0)
        if not q.pending:
            w = q.policy.window_s(q.rate(t0), self._route_p99(key, q))
            if w <= 0.0:                # sparse traffic: a lone query never
                q.pending.append((body, handle))   # waits on a window
                self._flush_queue(key, t0)
                return handle
            q.window_close = t0 + w
        q.pending.append((body, handle))
        if len(q.pending) >= q.policy.max_batch:
            self._flush_queue(key, t0, hard=True)  # hard cap: dispatch now
        return handle

    def flush(self, now: float | None = None) -> int:
        """Close due (or, with ``now=None``, ALL) open windows.

        Drivers call this when virtual time passes a window close with no
        further arrivals to trigger it — the analogue of the window timer
        firing — and once at end of run. Returns the number of windows
        flushed."""
        n = 0
        for key, q in self._queues.items():
            if not q.pending:
                continue
            if now is None or now >= q.window_close:
                self._flush_queue(key, q.window_close)
                n += 1
        return n

    def _route_p99(self, key: tuple[str, str], q: _AdmissionQueue) -> float:
        lats = self.latencies.get(key, [])
        return nearest_rank_percentiles(
            lats[-q.policy.p99_window:], qs=(0.99,))[0.99]

    def _flush_queue(self, key: tuple[str, str], t_dispatch: float,
                     *, hard: bool = False) -> None:
        q = self._queues[key]
        batch, q.pending = q.pending, []
        q.batch_sizes.append(len(batch))
        q.flushes.append((t_dispatch, len(batch)))
        if hard:
            # A max_batch flush dispatches the batch ONCE, right now. The
            # burst that filled it must not leak into the NEXT window's
            # sizing: those arrivals were already absorbed, and leaving them
            # in the trailing-rate history would make the reopened window
            # collapse toward zero (rate spike -> tiny window -> instant
            # re-flush), amplifying the very overload it should absorb.
            # Reseed with the dispatch instant rather than clearing outright:
            # an empty history would make the NEXT overload arrival read as
            # sparse traffic and dispatch solo — a soft flush that resets
            # the hard streak, so sustained overload would alternate
            # hard/solo forever and backpressure could never trip.
            q.arrivals[:] = [t_dispatch]
            q.hard_flushes += 1
            bp = q.policy.backpressure
            if bp is not None and q.hard_flushes >= bp.consecutive_hard_flushes:
                drain = q.drain_qps(t_dispatch, bp.drain_window_s)
                q.shed_until = max(
                    q.shed_until,
                    t_dispatch + bp.retry_after_s(len(batch), drain))
        else:
            q.hard_flushes = 0          # the arrival process fit its window
        coordinator, _ = self._batched[key]
        bodies = [b for b, _ in batch]
        arrivals = [h.t_arrival for _, h in batch]
        try:
            results = coordinator(bodies, arrivals, t_dispatch)
        except BadRequest as e:
            for _, handle in batch:
                handle._resolve(
                    Response(400, {"error": str(e)}, GATEWAY_OVERHEAD_S))
            return
        except RetriesExhausted as e:   # retries ran out → typed 503 each
            for _, handle in batch:
                handle._resolve(
                    Response(503, {"error": str(e)}, GATEWAY_OVERHEAD_S))
            return
        except Exception as e:          # whole-flight failure → 502 each
            for _, handle in batch:
                handle._resolve(
                    Response(502, {"error": str(e)}, GATEWAY_OVERHEAD_S))
            return
        for (_, handle), (result, disp_lat) in zip(batch, results):
            wait = t_dispatch - handle.t_arrival
            q.waits.append(wait)
            lat = wait + disp_lat + GATEWAY_OVERHEAD_S
            self.latencies.setdefault(key, []).append(lat)
            handle._resolve(Response(200, result, lat))

    def window_stats(self, method: str, path: str) -> dict:
        """Introspection for the route's admission queue: flush batch sizes
        and per-request added waits (a sparse-traffic run must show every
        wait at exactly zero — the window's no-added-latency contract)."""
        q = self._queues.get((method.upper(), path))
        if q is None:
            return {"batches": 0, "mean_batch": 0.0, "max_wait_s": 0.0,
                    "waits": [], "sheds": 0, "hard_flushes": 0}
        return {
            "batches": len(q.batch_sizes),
            "mean_batch": (sum(q.batch_sizes) / len(q.batch_sizes)
                           if q.batch_sizes else 0.0),
            "max_wait_s": max(q.waits, default=0.0),
            "waits": list(q.waits),
            "sheds": len(q.sheds),
            "hard_flushes": q.hard_flushes,
        }

    def latency_percentiles(self, method: str, path: str,
                            qs=(0.5, 0.9, 0.99)) -> dict[float, float]:
        """End-to-end latency quantiles for one route, over successful
        requests (the numbers the paper reports "from the browser")."""
        return nearest_rank_percentiles(
            self.latencies.get((method.upper(), path), []), qs)

    def routes(self) -> list[tuple[str, str, str]]:
        return [(m, p, f if isinstance(f, str)
                 else getattr(f, "__name__", "<coordinator>"))
                for (m, p), f in sorted(self._routes.items())]
