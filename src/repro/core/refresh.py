"""Versioned asset publishing + atomic switch-over (paper §3).

"Indexes can be built in batch offline, and then bulk loaded into a serving
framework. In such a scenario, new indexes can be placed alongside the old,
and then the Lambda instances can be refreshed to switch over to the new
indexes."

Layout in the object store:

    assets/<name>/versions/<version>/...files...
    assets/<name>/MANIFEST            <- tiny JSON pointer {"current": version}

Publishing writes the new version's files *alongside* the old, then swaps the
manifest with a conditional put (etag compare-and-set) so concurrent
publishers cannot interleave. Serving instances resolve the manifest on cold
start; ``refresh()`` invalidates hydration caches so the next invocation on
each instance re-resolves — exactly the paper's "Lambda instances can be
refreshed" story, with zero downtime (old version stays readable throughout).
"""

from __future__ import annotations

from repro.core import jsonutil as orjson   # orjson when installed

from repro.core.directory import Directory, StoreDirectory, copy_directory
from repro.core.object_store import NoSuchKey, ObjectStore, PreconditionFailed


class PublishConflict(Exception):
    pass


class AssetCatalog:
    def __init__(self, store: ObjectStore, root: str = "assets") -> None:
        self.store = store
        self.root = root.rstrip("/")

    # -- paths -----------------------------------------------------------------

    def _manifest_key(self, name: str) -> str:
        return f"{self.root}/{name}/MANIFEST"

    def version_prefix(self, name: str, version: str) -> str:
        return f"{self.root}/{name}/versions/{version}/"

    # -- publish (the offline batch-indexing side) --------------------------------

    def publish(self, name: str, version: str, files: Directory) -> str:
        """Upload `files` as a new version and atomically flip the manifest."""
        prefix = self.version_prefix(name, version)
        copy_directory(files, self.store, prefix)
        # compare-and-set the manifest
        try:
            cur = self.store.head(self._manifest_key(name))
            if_etag = cur.etag
        except NoSuchKey:
            if_etag = ""
        body = orjson.dumps({"current": version})
        try:
            self.store.put(self._manifest_key(name), body, if_etag=if_etag)
        except PreconditionFailed as e:
            raise PublishConflict(f"concurrent publish of {name!r}") from e
        return version

    def versions(self, name: str) -> list[str]:
        prefix = f"{self.root}/{name}/versions/"
        seen = []
        for meta in self.store.list(prefix):
            v = meta.key[len(prefix):].split("/", 1)[0]
            if v not in seen:
                seen.append(v)
        return seen

    def gc(self, name: str, keep: int = 2) -> list[str]:
        """Delete all but the newest `keep` versions (old one kept for
        rollback — the 'new indexes placed alongside the old' invariant)."""
        current = self.current_version(name)
        vs = self.versions(name)
        doomed = [v for v in vs if v != current][: max(0, len(vs) - keep)]
        for v in doomed:
            for meta in self.store.list(self.version_prefix(name, v)):
                self.store.delete(meta.key)
        return doomed

    # -- resolve (the serving side) ------------------------------------------------

    def current_version(self, name: str) -> str:
        data = self.store.get(self._manifest_key(name))
        return orjson.loads(data)["current"]

    def open(self, name: str, version: str | None = None, *,
             block_size: int = 1 << 20) -> tuple[str, StoreDirectory]:
        v = version if version is not None else self.current_version(name)
        return v, StoreDirectory(self.store, self.version_prefix(name, v),
                                 block_size=block_size)


def refresh_fleet(runtime, asset_name: str) -> int:
    """Invalidate `asset_name` in every instance's hydration cache. The next
    invocation per instance re-resolves the manifest and re-hydrates — a
    rolling, zero-downtime switch-over."""
    dropped = 0
    for inst in runtime._instances:
        dropped += inst.cache.invalidate(asset_name)
    return dropped
