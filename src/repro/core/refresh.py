"""Versioned asset publishing + atomic switch-over (paper §3).

"Indexes can be built in batch offline, and then bulk loaded into a serving
framework. In such a scenario, new indexes can be placed alongside the old,
and then the Lambda instances can be refreshed to switch over to the new
indexes."

Layout in the object store:

    assets/<name>/versions/<version>/...files...
    assets/<name>/segments/<seg>/...files...   <- immutable segment data (NRT)
    assets/<name>/MANIFEST            <- tiny JSON pointer {"current": version}

Publishing writes the new version's files *alongside* the old, then swaps the
manifest with a conditional put (etag compare-and-set) so concurrent
publishers cannot interleave. Serving instances resolve the manifest on cold
start; ``refresh()`` invalidates hydration caches so the next invocation on
each instance re-resolves — exactly the paper's "Lambda instances can be
refreshed" story, with zero downtime (old version stays readable throughout).

Near-real-time indexing rides the same seam as *generations*: a generation
is a tiny manifest version (``generation.json``) that REFERENCES immutable
segments published under ``segments/`` — one base segment plus an ordered
delta tier — with a tombstone set for deletes and the live corpus-wide BM25
stats/vocab. Committing a batch publishes only the new delta's bytes (the
Airphant-style small-immutable-increment story), then CAS-flips the
manifest; a torn publish between two concurrent writers surfaces as
:class:`PublishConflict` on the loser, never as a half-visible generation.
"""

from __future__ import annotations

import dataclasses

from repro.core import jsonutil as orjson   # orjson when installed

from repro.core.directory import (Directory, RamDirectory, StoreDirectory,
                                  copy_directory)
from repro.core.object_store import NoSuchKey, ObjectStore, PreconditionFailed


class PublishConflict(Exception):
    pass


GENERATION_FILE = "generation.json"


def generation_version(gen: int) -> str:
    """Canonical version string for generation ``gen``. Zero-padding makes
    typical listings read in order, but all ORDERING logic must go through
    :func:`parse_generation` — lexical comparison has a cliff at the first
    generation wider than the pad (gen-1000000 sorts before gen-999999)."""
    return f"gen-{gen:06d}"


def parse_generation(version: str) -> int | None:
    """Numeric generation of a ``gen-*`` version string, else None."""
    if version.startswith("gen-"):
        try:
            return int(version[4:])
        except ValueError:
            return None
    return None


@dataclasses.dataclass
class GenerationManifest:
    """One generation of a NRT-updated asset: base + ordered deltas +
    tombstones, plus the LIVE corpus-wide scoring state.

    The scoring state — ``stats`` (n_docs/avgdl/df over live documents)
    and ``vocab`` — is generation-level, not segment-level: segment blocks
    store only tf and doc lengths (stat-independent), and idf/avgdl are
    applied at QUERY time from this state — Lucene's move of computing idf
    from the live IndexReader. That is the invariant that keeps a
    delta-served index exactly rank-identical to a from-scratch rebuild of
    the final corpus; a frozen-idf delta would drift as the corpus grows.

    The state may be INLINE (``stats``/``vocab``) or SHARED
    (``stats_ref = [asset, segment]`` pointing at one stats segment in the
    catalog). Shared is what a partitioned fleet publishes: the global
    df/vocab are identical for every partition, so inlining them would
    store O(partitions × generations) copies of the whole vocabulary —
    the manifest would outweigh the delta it describes. Resolve with
    :meth:`AssetCatalog.resolve_generation_state`.
    """

    gen: int                       # monotonically increasing generation number
    base: str                      # base segment id (under segments/)
    deltas: list[str]              # ordered delta segment ids
    tombstones: list[int]          # deleted INTERNAL doc positions (stable:
    #                                base+delta order; a re-add gets a fresh
    #                                position, so old tombstones can't kill it)
    stats: dict | None = None      # inline live {"n_docs", "avgdl", "df"}
    vocab: dict | None = None      # inline frozen append-only term -> id map
    stats_ref: list | None = None  # OR shared: [asset, segment] in the catalog
    # dense-vector tier (hybrid retrieval): the SAME base+delta shape as the
    # BM25 tier, row positions aligned with it doc-for-doc, so ONE tombstone
    # list and ONE generation number govern both tiers. None = no dense tier
    # (pre-hybrid manifests parse unchanged).
    vec_base: str | None = None
    vec_deltas: list = dataclasses.field(default_factory=list)

    def to_json(self) -> bytes:
        return orjson.dumps(dataclasses.asdict(self))

    @classmethod
    def from_json(cls, data: bytes) -> "GenerationManifest":
        return cls(**orjson.loads(data))

    @property
    def segments(self) -> list[str]:
        return [self.base] + list(self.deltas)

    @property
    def vec_segments(self) -> list[str]:
        """Dense-tier segment ids, base first ([] when no dense tier)."""
        if self.vec_base is None:
            return []
        return [self.vec_base] + list(self.vec_deltas)


class AssetCatalog:
    def __init__(self, store: ObjectStore, root: str = "assets") -> None:
        self.store = store
        self.root = root.rstrip("/")

    # -- paths -----------------------------------------------------------------

    def _manifest_key(self, name: str) -> str:
        return f"{self.root}/{name}/MANIFEST"

    def version_prefix(self, name: str, version: str) -> str:
        return f"{self.root}/{name}/versions/{version}/"

    def segment_prefix(self, name: str, seg: str) -> str:
        return f"{self.root}/{name}/segments/{seg}/"

    # -- publish (the offline batch-indexing side) --------------------------------

    def publish(self, name: str, version: str, files: Directory) -> str:
        """Upload `files` as a new version and atomically flip the manifest."""
        prefix = self.version_prefix(name, version)
        copy_directory(files, self.store, prefix)
        # compare-and-set the manifest
        try:
            cur = self.store.head(self._manifest_key(name))
            if_etag = cur.etag
        except NoSuchKey:
            if_etag = ""
        body = orjson.dumps({"current": version})
        try:
            self.store.put(self._manifest_key(name), body, if_etag=if_etag)
        except PreconditionFailed as e:
            raise PublishConflict(f"concurrent publish of {name!r}") from e
        return version

    def versions(self, name: str) -> list[str]:
        prefix = f"{self.root}/{name}/versions/"
        seen = []
        for meta in self.store.list(prefix):
            v = meta.key[len(prefix):].split("/", 1)[0]
            if v not in seen:
                seen.append(v)
        return seen

    def gc(self, name: str, keep: int = 2) -> list[str]:
        """Delete all but the newest `keep` versions (old one kept for
        rollback — the 'new indexes placed alongside the old' invariant).
        The CURRENT (serving) version is never deleted, whatever ``keep``
        says. Generation manifests additionally pin their segments: after
        pruning versions, any segment no surviving generation references is
        reclaimed too (a merged-away delta tier stops costing storage)."""
        current = self.current_version(name)
        # oldest-first, numerically for generations (lexical order has a
        # cliff when the gen number outgrows its zero-pad)
        vs = sorted(self.versions(name),
                    key=lambda v: (0, parse_generation(v))
                    if parse_generation(v) is not None else (1, v))
        doomed = [v for v in vs if v != current][: max(0, len(vs) - keep)]
        for v in doomed:
            for meta in self.store.list(self.version_prefix(name, v)):
                self.store.delete(meta.key)
        self._gc_segments(name)
        return doomed

    def _gc_segments(self, name: str) -> list[str]:
        """Reclaim segments referenced by NO surviving generation manifest.
        No-op for plain-segment assets (no generation manifests)."""
        live: set[str] = set()
        saw_generation = False
        for v in self.versions(name):
            d = StoreDirectory(self.store, self.version_prefix(name, v))
            if GENERATION_FILE not in d.list():
                continue
            saw_generation = True
            m = self.read_generation(name, v)
            live.update(m.segments)
            live.update(m.vec_segments)
        if not saw_generation:
            return []
        return self.sweep_unreferenced(name, live)

    def sweep_unreferenced(self, name: str, live: "set[str]") -> list[str]:
        """Delete every segment of ``name`` whose id is not in ``live``.
        The one segment-sweeping rule — shared by the catalog's own gc and
        any coordinator-level sweep (e.g. the fleet writer's shared
        stats/vocab segments), so key-layout changes can't diverge."""
        doomed = []
        prefix = f"{self.root}/{name}/segments/"
        for meta in self.store.list(prefix):
            seg = meta.key[len(prefix):].split("/", 1)[0]
            if seg not in live:
                self.store.delete(meta.key)
                if seg not in doomed:
                    doomed.append(seg)
        return doomed

    # -- generations (the NRT incremental-indexing side) ---------------------------

    def publish_segment(self, name: str, seg: str, files: Directory) -> str:
        """Upload one immutable segment's files under ``segments/<seg>/``.
        No manifest flip: a segment is invisible until a generation
        manifest referencing it is published.

        Segments are IMMUTABLE — publishing an id that already exists is
        refused as a :class:`PublishConflict`. Without this, two writers
        racing the same generation number would silently overwrite each
        other's segment BYTES before the manifest CAS picks a winner, and
        the winner's manifest could end up serving the loser's documents."""
        prefix = self.segment_prefix(name, seg)
        if self.store.list(prefix):
            raise PublishConflict(
                f"{name!r}: segment {seg!r} already published — segments "
                "are immutable; a racing writer owns this id")
        copy_directory(files, self.store, prefix)
        return seg

    def open_segment(self, name: str, seg: str, *,
                     block_size: int = 1 << 20) -> StoreDirectory:
        return StoreDirectory(self.store, self.segment_prefix(name, seg),
                              block_size=block_size)

    def publish_generation(self, name: str,
                           manifest: GenerationManifest) -> str:
        """Publish ``manifest`` as version ``gen-<gen>`` and CAS-flip the
        asset manifest to it.

        Two conflict classes, both surfaced as :class:`PublishConflict`:

        * a STALE BASE — the asset already serves ``manifest.gen`` or newer,
          so this writer built its delta against a superseded generation
          (checked against the manifest read below, not at an earlier
          instant, so sequential lost-update races are caught too);
        * a TORN PUBLISH — the asset manifest changed between that read and
          our conditional put (two writers racing the same flip); the etag
          compare-and-set lets exactly one land.

        The loser's generation files are cleaned up (no phantom generation
        for gc to mistake for live state); it must re-read the current
        generation, rebase its delta, and retry."""
        version = generation_version(manifest.gen)
        key = self._manifest_key(name)
        try:
            if_etag = self.store.head(key).etag
            current = orjson.loads(self.store.get(key))["current"]
        except NoSuchKey:
            if_etag, current = "", None
        cur_gen = parse_generation(current) if current is not None else None
        if cur_gen is not None and cur_gen >= manifest.gen:
            raise PublishConflict(
                f"{name!r}: generation {version} is not newer than the "
                f"published {current} — rebase the delta and retry")
        # create-once: two writers racing the SAME generation number would
        # otherwise write the same key, and the CAS loser's cleanup would
        # delete the file the WINNER's flip now serves. The conditional
        # create makes the generation directory exclusively ours — losing
        # THIS race is a conflict before anything else is touched.
        gen_key = self.version_prefix(name, version) + GENERATION_FILE
        try:
            self.store.put(gen_key, manifest.to_json(), if_etag="")
        except PreconditionFailed as e:
            raise PublishConflict(
                f"{name!r}: generation {version} already published by a "
                "concurrent writer — rebase the delta and retry") from e
        try:
            self.store.put(key, orjson.dumps({"current": version}),
                           if_etag=if_etag)
        except PreconditionFailed as e:
            # we exclusively own gen_key (create-once above), so deleting
            # it cannot destroy another writer's published generation
            self.store.delete(gen_key)
            raise PublishConflict(
                f"concurrent publish of {name!r} (lost the {version} "
                "manifest race)") from e
        return version

    def read_generation(self, name: str,
                        version: str | None = None) -> GenerationManifest:
        """Load the generation manifest for ``version`` (default: current)."""
        v = version if version is not None else self.current_version(name)
        d = StoreDirectory(self.store, self.version_prefix(name, v))
        return GenerationManifest.from_json(
            d.open_input(GENERATION_FILE).read_all())

    def current_generation(self, name: str) -> GenerationManifest:
        return self.read_generation(name)

    def publish_generation_state(self, name: str, gen: int, stats: dict,
                                 vocab: dict,
                                 writer: dict | None = None) -> list:
        """Publish one generation's SHARED scoring state (live stats +
        vocab) as a segment; returns the ``stats_ref`` the partition
        manifests should carry. One copy per generation, however many
        partitions reference it.

        ``writer`` optionally rides along as ``writer.json`` — coordinator
        bookkeeping (e.g. the round-robin placement cursor) a SECOND writer
        must adopt when it rebases on this generation, so a raced commit
        converges on the same document placement a serialized pair of
        commits would have produced."""
        seg = f"g{gen:06d}-state"
        files = {"stats.json": orjson.dumps(stats),
                 "vocab.json": orjson.dumps(vocab)}
        if writer is not None:
            files["writer.json"] = orjson.dumps(writer)
        self.publish_segment(name, seg, RamDirectory(files))
        return [name, seg]

    def resolve_generation_writer(self, manifest: GenerationManifest) -> dict:
        """The coordinator bookkeeping published with a generation's shared
        state ({} for inline-state or pre-writer-state generations)."""
        if manifest.stats_ref is None:
            return {}
        asset, seg = manifest.stats_ref
        d = self.open_segment(asset, seg)
        if "writer.json" not in d.list():
            return {}
        return orjson.loads(d.open_input("writer.json").read_all())

    def resolve_generation_state(self,
                                 manifest: GenerationManifest) -> tuple[dict, dict]:
        """(stats, vocab) for a manifest — inline, or read through the
        shared ``stats_ref`` segment (a billed store read)."""
        if manifest.stats is not None and manifest.vocab is not None:
            return manifest.stats, manifest.vocab
        if manifest.stats_ref is None:
            raise ValueError(
                f"generation {manifest.gen} manifest carries neither inline "
                "stats/vocab nor a stats_ref")
        asset, seg = manifest.stats_ref
        d = self.open_segment(asset, seg)
        return (orjson.loads(d.open_input("stats.json").read_all()),
                orjson.loads(d.open_input("vocab.json").read_all()))

    # -- resolve (the serving side) ------------------------------------------------

    def current_version(self, name: str) -> str:
        data = self.store.get(self._manifest_key(name))
        return orjson.loads(data)["current"]

    def open(self, name: str, version: str | None = None, *,
             block_size: int = 1 << 20) -> tuple[str, StoreDirectory]:
        v = version if version is not None else self.current_version(name)
        return v, StoreDirectory(self.store, self.version_prefix(name, v),
                                 block_size=block_size)


def refresh_fleet(runtime, asset_name: str) -> int:
    """Invalidate `asset_name` in every instance's hydration cache. The next
    invocation per instance re-resolves the manifest and re-hydrates — a
    rolling, zero-downtime switch-over."""
    dropped = 0
    for inst in runtime._instances:
        dropped += inst.cache.invalidate(asset_name)
    return dropped


def rollover_fleet(runtime, fn_groups, gen: int, *,
                   ping_payload: dict | None = None,
                   t_arrival: float | None = None,
                   stagger: bool = True) -> list:
    """Swap every pool of every replica group to generation ``gen`` with
    zero downtime: ping each function ONCE with the new generation pinned
    in the payload (keepalive — billed to the idle line, excluded from
    latency percentiles and policy history), so every pool hydrates — and
    jit-specializes on — the new generation OFF the query path.

    Pools within one replica group roll over STAGGERED (``stagger=True``,
    the default): pool *r+1*'s pings dispatch at the instant pool *r*'s
    pings complete, so at most ONE of a group's pools is ever busy
    hydrating — a query landing mid-rollover always finds the group's
    other pools idle (already re-warmed, or still warm on the old
    generation, which stays readable until gc), instead of every pool
    going busy at the same instant and forcing the query to queue behind
    a hydration or cold-boot a fresh instance. Replica groups themselves
    roll in parallel at ``t_arrival`` — a query fans out to EVERY
    partition, so serializing across groups would stretch the rollover
    without sheltering anyone. A single-pool group (R=1) has nothing to
    stagger; its behaviour is bit-identical either way.

    In-flight queries are never dropped: a query dispatched before the
    swap carries its own pinned generation and any instance can still
    re-hydrate that older generation (old versions stay readable until
    gc), so the coordinator may flip its serving generation the moment
    these pings return. Retired/unregistered functions are skipped (a
    rollover racing a scale-down must not resurrect a draining pool).

    EVERY idle instance of a pool gets its own ping (concurrent pings at
    one arrival instant land on distinct instances): a pool grown to N by
    concurrent traffic would otherwise prewarm only its MRU instance and
    the other N-1 would hydrate the new generation IN-BAND on their next
    query — exactly the p99 spike the prewarm exists to prevent. Busy
    instances can't be prewarmed (FaaS can't interrupt a running
    invocation); they pay their re-hydration on first touch, like any
    cold start."""
    t0 = runtime.clock if t_arrival is None else t_arrival
    payload = dict(ping_payload or {})
    payload["gen"] = gen
    recs = []
    for group in fn_groups:
        t_pool = t0
        for fn in (group if isinstance(group, (list, tuple)) else [group]):
            if not runtime.registered(fn):
                continue
            idle = sum(1 for i in runtime._instances
                       if i.fn == fn and i.alive and i.busy_until <= t_pool)
            pool_recs = []
            for _ in range(max(1, idle)):
                _, rec = runtime.invoke(fn, dict(payload), t_arrival=t_pool,
                                        keepalive=True)
                pool_recs.append(rec)
            recs.extend(pool_recs)
            if stagger and pool_recs:
                t_pool = max(r.t_done for r in pool_recs)
    return recs
