"""Hydration cache: the 'warm instance' mechanism.

Paper §2: a cold Lambda instance pays a one-time cost to populate its
in-memory cache from S3; warm instances serve with zero store traffic —
"Lambda execution incurs no performance penalty in steady state."

``HydrationCache`` holds *hydrated assets* (packed index arrays, model
weights, embedding tables) keyed by (asset_name, version). Values are
arbitrary pytrees — on a real TPU these are device arrays in HBM; in this
container they are CPU-backed jax arrays. Eviction is LRU by accounted
bytes, which is how a 2GB-Lambda memory ceiling is modeled.
"""

from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Any, Callable

import jax


def pytree_nbytes(tree: Any) -> int:
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        if hasattr(leaf, "nbytes"):
            total += int(leaf.nbytes)
        elif isinstance(leaf, (bytes, bytearray)):
            total += len(leaf)
    return total


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    hydrate_seconds: float = 0.0   # simulated time spent hydrating (cold starts)
    backfill_seconds: float = 0.0  # partial → full upgrades, off the critical path

    @property
    def cold_fraction(self) -> float:
        n = self.hits + self.misses
        return self.misses / n if n else 0.0


class HydrationCache:
    """LRU cache of hydrated assets with a byte budget (the instance's RAM)."""

    def __init__(self, capacity_bytes: int) -> None:
        self.capacity_bytes = int(capacity_bytes)
        self._entries: "OrderedDict[tuple[str, str], tuple[Any, int]]" = OrderedDict()
        self._bytes = 0
        self._lock = threading.RLock()
        self.stats = CacheStats()

    def get_or_hydrate(
        self,
        name: str,
        version: str,
        hydrate: Callable[[], tuple[Any, float]],
    ) -> Any:
        """Return the cached asset, or call ``hydrate() -> (asset, sim_s)``.

        ``sim_s`` is the simulated hydration wall-time (store read cost +
        deserialize + host→device transfer estimate) accumulated into stats —
        this is the cold-start penalty of the paper.
        """
        key = (name, version)
        with self._lock:
            hit = self._entries.get(key)
            if hit is not None:
                self._entries.move_to_end(key)
                self.stats.hits += 1
                return hit[0]
        # hydrate outside the lock: concurrent cold starts may duplicate work,
        # which is exactly what concurrent cold Lambda containers do.
        asset, sim_s = hydrate()
        nbytes = pytree_nbytes(asset)
        with self._lock:
            self.stats.misses += 1
            self.stats.hydrate_seconds += float(sim_s)
            if key not in self._entries:
                self._entries[key] = (asset, nbytes)
                self._bytes += nbytes
                self._evict_to_fit()
            return self._entries.get(key, (asset, nbytes))[0]

    def note_hydration(self, sim_s: float) -> None:
        """Account extra on-critical-path hydration for an entry that was a
        HIT but needed more data (a partially-hydrated asset pulling a new
        query's term blocks)."""
        with self._lock:
            self.stats.hydrate_seconds += float(sim_s)

    def note_backfill(self, name: str, version: str,
                      sim_s: float, nbytes: int | None = None) -> None:
        """Account a partial → full upgrade: time goes to the separate
        ``backfill_seconds`` line (never hydrate_seconds — backfill is off
        the critical path by contract), and the entry's byte accounting is
        refreshed since the asset just grew."""
        with self._lock:
            self.stats.backfill_seconds += float(sim_s)
            key = (name, version)
            hit = self._entries.get(key)
            if hit is not None:
                asset, old_nb = hit
                new_nb = int(nbytes) if nbytes is not None else pytree_nbytes(asset)
                self._entries[key] = (asset, new_nb)
                self._bytes += new_nb - old_nb
                self._evict_to_fit()

    def _evict_to_fit(self) -> None:
        while self._bytes > self.capacity_bytes and len(self._entries) > 1:
            _, (old, nb) = self._entries.popitem(last=False)
            del old
            self._bytes -= nb
            self.stats.evictions += 1

    def invalidate(self, name: str, version: str | None = None) -> int:
        """Drop an asset (all versions if version is None). Paper §3 refresh."""
        dropped = 0
        with self._lock:
            for key in list(self._entries):
                if key[0] == name and (version is None or key[1] == version):
                    _, nb = self._entries.pop(key)
                    self._bytes -= nb
                    dropped += 1
        return dropped

    def __contains__(self, key: tuple[str, str]) -> bool:
        with self._lock:
            return key in self._entries

    @property
    def used_bytes(self) -> int:
        with self._lock:
            return self._bytes

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)
