"""FaaS runtime simulator — the Lambda execution substrate of the paper.

Models what AWS does "behind the scenes" (§2): provisioning containers,
scaling the fleet up/down with load, load-balancing, and the cold/warm
distinction. One request occupies one instance for its duration (Lambda's
concurrency = instance model); a request that finds no idle instance forces a
*cold start*: container provision + asset hydration, both charged to that
request's latency.

The simulator runs on a virtual clock (simulated seconds) so behaviour is
deterministic and fast; actual compute time for a request is supplied by the
handler (measured wall time of the jitted scoring fn, or a model).

Fault tolerance: instances can be killed (failure injection); in-flight
requests are retried on another instance. Straggler mitigation: requests
whose execution exceeds ``hedge_after_s`` are duplicated ("backup requests",
Dean's tail-at-scale trick) and the earlier completion wins.
"""

from __future__ import annotations

import dataclasses
import itertools
import random
from typing import Any, Callable

from repro.core.cache import HydrationCache
from repro.core.cost import CostLedger, Invocation


class RuntimeError_(Exception):
    pass


class RetriesExhausted(RuntimeError_):
    """A function's client-side retries ran out: every attempt landed on an
    instance that died before the handler ran. Subclasses ``RuntimeError_``
    so pre-existing broad handlers still catch it, but carries enough for a
    gateway to map it to a typed 503 instead of a generic 502."""

    def __init__(self, fn: str, attempts: int) -> None:
        super().__init__(f"{fn}: instance died {attempts} times")
        self.fn = fn
        self.attempts = attempts


# A handler receives (instance_cache, payload) and returns
# (result, exec_seconds). exec_seconds is the simulated compute time for the
# request *excluding* hydration (the cache accounts hydration separately).
Handler = Callable[[HydrationCache, Any], tuple[Any, float]]


def nearest_rank_percentiles(lats, qs=(0.5, 0.9, 0.99)) -> dict[float, float]:
    """Nearest-rank quantiles over an (unsorted) latency list; NaN when
    empty. The ONE quantile convention for the runtime, the gateway, and
    the benchmarks — so their p99s agree on the same run."""
    lats = sorted(lats)
    if not lats:
        return {q: float("nan") for q in qs}
    return {q: lats[min(len(lats) - 1, int(q * len(lats)))] for q in qs}


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded client-side retries for instance death.

    ``max_attempts`` counts TOTAL tries (first attempt included); backoff
    before retry *n* is ``base_backoff_s * multiplier**(n-1)`` capped at
    ``max_backoff_s``, stretched by up to ``jitter`` (a fraction, drawn from
    the runtime's seeded RNG so a retry schedule is reproducible per seed).
    The zero-backoff default reproduces the historical immediate-retry
    behaviour exactly — including the RNG draw sequence, since jitter only
    consumes a draw when both jitter and the backoff are nonzero."""

    max_attempts: int = 3
    base_backoff_s: float = 0.0
    multiplier: float = 2.0
    max_backoff_s: float = 2.0
    jitter: float = 0.0

    def __post_init__(self) -> None:
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if self.base_backoff_s < 0 or self.max_backoff_s < 0:
            raise ValueError("backoff seconds must be >= 0")
        if self.multiplier < 1.0:
            raise ValueError("multiplier must be >= 1")
        if not 0.0 <= self.jitter <= 1.0:
            raise ValueError("jitter must be in [0, 1]")

    def backoff_s(self, attempt: int, rng) -> float:
        """Virtual-clock delay before retry ``attempt`` (1-based)."""
        delay = min(self.base_backoff_s * self.multiplier ** (attempt - 1),
                    self.max_backoff_s)
        if delay > 0.0 and self.jitter > 0.0:
            delay *= 1.0 + self.jitter * rng.random()
        return delay


@dataclasses.dataclass
class RuntimeConfig:
    memory_bytes: int = 2 << 30          # the paper's "generous 2GB instance"
    provision_s: float = 0.150           # container cold-boot (JVM/runtime init)
    idle_timeout_s: float = 600.0        # AWS reaps idle containers ~5-15 min
    max_instances: int = 1000            # account concurrency limit
    hedge_after_s: float | None = None   # straggler mitigation threshold
    failure_rate: float = 0.0            # per-invocation instance-death prob
    max_retries: int = 2                 # legacy knob; ignored when retry set
    retry: RetryPolicy | None = None     # None -> immediate retries, bounded
                                         # by max_retries (legacy behaviour)
    seed: int = 0

    def retry_policy(self) -> RetryPolicy:
        if self.retry is not None:
            return self.retry
        return RetryPolicy(max_attempts=self.max_retries + 1)


@dataclasses.dataclass
class InvocationRecord:
    fn: str
    t_arrival: float
    t_done: float
    latency_s: float
    exec_s: float
    hydrate_s: float
    cold: bool
    # cold splits two ways: ``provisioned`` means a FRESH container booted
    # for this request (capacity shortfall — more standby pools would have
    # absorbed it), while a hydration-only cold (warm container, new index
    # generation) is content turnover that every pool pays exactly once per
    # generation — adding pools ADDS hydrations, so a scaling policy must
    # not read it as load pressure
    provisioned: bool
    instance_id: int
    retries: int = 0
    hedged: bool = False
    # cross-replica hedging (invoke_hedged): the losing leg's function and
    # the latency the caller would have eaten without the backup
    backup_fn: str | None = None
    loser_latency_s: float = 0.0
    # keep-alive ping (standby-capacity maintenance, not a query): excluded
    # from latency percentiles and hedge-policy history, billed as idle
    keepalive: bool = False
    # indexing work (delta pack / merge): billed to the ledger's write line
    write: bool = False
    # partial → full lazy-hydration upgrade run after the response was
    # computed: billed to the ledger's backfill line, EXCLUDED from
    # latency_s/hydrate_s (it extends instance busy time, not the caller's
    # wait) — hedging/autoscaling thus see the PARTIAL cold cost
    backfill_s: float = 0.0

    @property
    def overhead_s(self) -> float:
        return self.latency_s - self.exec_s


class Instance:
    _ids = itertools.count()

    def __init__(self, memory_bytes: int, now: float, fn: str = "") -> None:
        self.id = next(Instance._ids)
        self.fn = fn                  # Lambda pins environments per function
        self.cache = HydrationCache(memory_bytes)
        self.busy_until = now
        self.last_used = now
        self.born = now
        self.invocations = 0
        self.alive = True

    def is_warm_for(self, asset_key: tuple[str, str]) -> bool:
        return asset_key in self.cache


class FaaSRuntime:
    """The fleet. ``invoke`` is the Lambda entry point."""

    def __init__(self, config: RuntimeConfig | None = None) -> None:
        self.config = config if config is not None else RuntimeConfig()
        self._handlers: dict[str, Handler] = {}
        self._instances: list[Instance] = []
        self._rng = random.Random(self.config.seed)
        self.ledger = CostLedger()
        self.records: list[InvocationRecord] = []
        self.clock = 0.0
        self._retired: dict[str, float] = {}        # fn -> retirement time
        self.kill_log: list[tuple[float, str]] = []  # (time, fn) per kill

    # -- registration ---------------------------------------------------------

    def register(self, fn_name: str, handler: Handler) -> None:
        self._handlers[fn_name] = handler
        self._retired.pop(fn_name, None)   # re-registering reinstates

    def registered(self, fn_name: str) -> bool:
        return fn_name in self._handlers and fn_name not in self._retired

    def retire(self, fn_name: str, *, t: float | None = None) -> None:
        """Stop routing to ``fn_name`` and drain its pool.

        Retirement is the scale-down half of fleet control: no NEW
        invocation may land on a retired function (``invoke`` raises), its
        idle instances are reclaimed immediately, and busy ones finish
        their in-flight request — win or lose a hedge race, FaaS can't
        cancel — then evaporate on the next fleet sweep. The published
        segment is untouched: retiring ``search-p0r2`` removes one instance
        pool over the asset, never the asset itself."""
        if fn_name not in self._handlers:
            raise RuntimeError_(f"no function {fn_name!r} registered")
        now = self.clock if t is None else max(t, 0.0)
        self._retired[fn_name] = now
        self._instances = [
            i for i in self._instances
            if not (i.fn == fn_name and i.busy_until <= now)]

    # -- fleet management (what AWS does behind the scenes) --------------------

    def _reap_idle(self, now: float) -> None:
        cfg = self.config
        self._instances = [
            i for i in self._instances
            if i.alive and (now - i.last_used) <= cfg.idle_timeout_s
            and not (i.fn in self._retired and i.busy_until <= now)
        ]

    def _acquire(self, now: float, fn: str = "") -> tuple[Instance, bool]:
        """Find an idle warm instance FOR THIS FUNCTION, else provision.

        Lambda execution environments are per-function: an instance that
        booted for function A is never handed a request for function B (a
        partitioned fleet would otherwise thrash each other's hydration
        caches). Within a function's pool, prefer the most-recently-used
        idle instance (AWS's observed bin-packing; maximizes warmth)."""
        self._reap_idle(now)
        idle = [i for i in self._instances
                if i.busy_until <= now and i.fn == fn]
        if idle:
            inst = max(idle, key=lambda i: i.last_used)
            return inst, False
        if len(self._instances) >= self.config.max_instances:
            # throttled: wait for the earliest-free same-function instance
            # (429 + retry in real Lambda; modeled as queueing delay)
            pool = [i for i in self._instances if i.fn == fn]
            if pool:
                inst = min(pool, key=lambda i: i.busy_until)
                return inst, False
            # fleet is full of OTHER functions' environments: reclaim the
            # earliest-free one and boot a fresh environment for this fn in
            # its place (never hand fn a foreign instance's cache) — the
            # request queues until the victim frees, then pays a cold boot.
            victim = min(self._instances, key=lambda i: i.busy_until)
            self._instances.remove(victim)
            inst = Instance(self.config.memory_bytes, now, fn)
            inst.busy_until = max(now, victim.busy_until)
            self._instances.append(inst)
            return inst, True
        inst = Instance(self.config.memory_bytes, now, fn)
        self._instances.append(inst)
        return inst, True

    def kill_instance(self, instance_id: int | None = None, *,
                      fn: str | None = None) -> bool:
        """Failure injection: kill one instance (random if unspecified).

        ``fn`` restricts the pick to one function's pool — this is how a
        benchmark makes one partition's fleet deliberately cold while its
        replicas stay warm."""
        live = [i for i in self._instances
                if i.alive and (fn is None or i.fn == fn)]
        if not live:
            return False
        victim = None
        if instance_id is None:
            victim = self._rng.choice(live)
        else:
            for i in live:
                if i.id == instance_id:
                    victim = i
        if victim is None:
            return False
        victim.alive = False
        self._instances.remove(victim)
        # the kill log is what hedge-aware routing rotates primaries on:
        # a pool that just lost an instance is the one most likely to greet
        # the next request with a cold start
        self.kill_log.append((self.clock, victim.fn))
        return True

    def recent_kills(self, fn: str, *, now: float | None = None,
                     window_s: float = 30.0) -> int:
        """Kill events in ``fn``'s pool within the trailing window — the
        'recently struggling' signal for routing and scale-up decisions."""
        t = self.clock if now is None else now
        return sum(1 for (tk, f) in self.kill_log
                   if f == fn and 0.0 <= t - tk <= window_s)

    def pool_busy(self, fn: str, now: float | None = None) -> bool:
        """True if any of ``fn``'s instances has in-flight work at ``now``.
        A busy pool needs no keep-alive: serving traffic IS its keep-alive,
        and a ping racing a live request would steal the idle instance the
        request was about to reuse — forcing a pointless cold start."""
        t = self.clock if now is None else now
        return any(i.fn == fn and i.alive and i.busy_until > t
                   for i in self._instances)

    def pool_expiry_s(self, fn: str, now: float | None = None) -> float | None:
        """Seconds until the LAST of ``fn``'s instances would be reaped for
        idleness (None if the pool has no instances). A keep-alive manager
        pings a pool when this drops under its margin; a warm pool serving
        steady traffic never needs the ping.

        Boundary contract (``tests`` pin this): an instance idle EXACTLY
        ``idle_timeout_s`` is still alive — ``_reap_idle``, ``probe``, and
        ``_acquire`` all keep instances at ``now - last_used <=
        idle_timeout_s``, reaping strictly after — and this method reports
        ``0.0`` for it. Keep-alive margin math (``autoscale._keepalive``
        pings when ``expiry < margin``) therefore fires the ping while the
        instance is still warm: an expiry of 0 is a pingable pool, not a
        lost one, and a margin of 0 would (correctly) never ping."""
        t = self.clock if now is None else now
        expiries = [i.last_used + self.config.idle_timeout_s - t
                    for i in self._instances if i.fn == fn and i.alive]
        return max(expiries) if expiries else None

    # -- invocation -------------------------------------------------------------

    def probe(self, fn: str, t_arrival: float | None = None) -> tuple[float, float]:
        """Projected (queue_wait_s, cold_boot_s) for the NEXT invocation of
        ``fn``, without mutating the fleet.

        Mirrors ``_acquire``'s placement decision at ``t_arrival`` under the
        virtual clock: an idle warm instance → (0, 0); a throttled fleet →
        queueing delay; otherwise a fresh provision. Hydration is not
        projected (the runtime doesn't know the handler's assets), so this is
        a lower bound — which is all a hedging policy needs, since a cold
        boot alone already dwarfs any warm-latency quantile."""
        now = self.clock if t_arrival is None else max(t_arrival, 0.0)
        cfg = self.config
        live = [i for i in self._instances
                if i.alive and (now - i.last_used) <= cfg.idle_timeout_s]
        if any(i.busy_until <= now and i.fn == fn for i in live):
            return 0.0, 0.0
        if len(live) >= cfg.max_instances:
            pool = [i for i in live if i.fn == fn]
            if pool:
                inst = min(pool, key=lambda i: i.busy_until)
                return max(0.0, inst.busy_until - now), 0.0
            victim = min(live, key=lambda i: i.busy_until)
            return max(0.0, victim.busy_until - now), cfg.provision_s
        return 0.0, cfg.provision_s

    def invoke(self, fn: str, payload: Any, *, t_arrival: float | None = None,
               keepalive: bool = False,
               write: bool = False) -> tuple[Any, InvocationRecord]:
        if fn not in self._handlers:
            raise RuntimeError_(f"no function {fn!r} registered")
        if fn in self._retired:
            raise RuntimeError_(f"function {fn!r} is retired (draining)")
        now = self.clock if t_arrival is None else max(t_arrival, 0.0)
        self.clock = max(self.clock, now)
        return self._invoke_retrying(fn, payload, now, keepalive=keepalive,
                                     write=write)

    def invoke_hedged(self, fn: str, backup_fn: str, payload: Any, *,
                      t_arrival: float | None = None) -> tuple[Any, InvocationRecord]:
        """Fire ``fn`` AND ``backup_fn`` (a replica serving the same asset)
        at the same arrival instant; the first completion wins.

        This is the cross-replica half of tail hedging: the per-instance
        ``hedge_after_s`` backup fires mid-execution on the SAME pool, while
        this one is decided at dispatch (from ``probe``'s projection) and
        lands on a DIFFERENT pool, so it sidesteps a cold/throttled fleet
        entirely. FaaS offers no cancellation, so the losing leg runs to
        completion, keeps its instance busy, and is billed in full (the
        hedging tax, visible in ``CostLedger.hedge_gb_seconds``) — but only
        the winner's latency is what the caller waits for, and only one
        logical record is appended (latency = winner's)."""
        for name in (fn, backup_fn):
            if name not in self._handlers:
                raise RuntimeError_(f"no function {name!r} registered")
            if name in self._retired:
                raise RuntimeError_(f"function {name!r} is retired (draining)")
        now = self.clock if t_arrival is None else max(t_arrival, 0.0)
        self.clock = max(self.clock, now)
        # Each leg retries independently; a leg whose retries run out must
        # not sink the call when its sibling succeeded — that is the whole
        # point of sending two. Retried legs keep their attribution flag, so
        # a dying-then-retried backup still bills on the hedge line.
        legs: list[tuple[Any, InvocationRecord]] = []
        first_err: RetriesExhausted | None = None
        for name, is_hedge in ((fn, False), (backup_fn, True)):
            try:
                legs.append(self._invoke_retrying(name, payload, now,
                                                  record=False, hedge=is_hedge))
            except RetriesExhausted as e:
                first_err = first_err or e
        if not legs:
            raise first_err
        if len(legs) == 1:
            (res, win), = legs
            dead = backup_fn if win.fn == fn else fn
            rec = dataclasses.replace(
                win, hedged=True, backup_fn=dead,
                loser_latency_s=float("inf"))   # the dead leg never finished
            self.records.append(rec)
            return res, rec
        (res, win), (_, lose) = sorted(
            legs, key=lambda p: p[1].latency_s)
        rec = dataclasses.replace(
            win, hedged=True, backup_fn=lose.fn, loser_latency_s=lose.latency_s)
        self.records.append(rec)
        return res, rec

    def _invoke_retrying(self, fn: str, payload: Any, now: float, *,
                         record: bool = True, hedge: bool = False,
                         keepalive: bool = False, write: bool = False):
        policy = self.config.retry_policy()
        attempt = 0
        while True:
            try:
                return self._invoke_once(fn, payload, now, attempt,
                                         record=record, hedge=hedge,
                                         keepalive=keepalive, write=write)
            except _InstanceDied:
                attempt += 1
                if attempt >= policy.max_attempts:
                    raise RetriesExhausted(fn, attempt) from None
                # client-side retry on another instance, after an exponential
                # backoff on the virtual clock (0 under the legacy default).
                # A dead attempt billed nothing: failure injection fires
                # before the handler runs and before any ledger charge, so
                # the retry's invocation carries the SAME attribution flags
                # (a hedged leg's retry stays on the hedge line).
                now += policy.backoff_s(attempt, self._rng)
                self.clock = max(self.clock, now)

    def _invoke_once(self, fn: str, payload: Any, now: float, attempt: int, *,
                     record: bool = True, hedge: bool = False,
                     keepalive: bool = False, write: bool = False):
        cfg = self.config
        inst, fresh = self._acquire(now, fn)
        queue_wait = max(0.0, inst.busy_until - now)
        t_start = now + queue_wait
        cold_boot = cfg.provision_s if fresh else 0.0

        if cfg.failure_rate and self._rng.random() < cfg.failure_rate:
            inst.alive = False
            if inst in self._instances:
                self._instances.remove(inst)
            raise _InstanceDied()

        hyd_before = inst.cache.stats.hydrate_seconds
        bf_before = inst.cache.stats.backfill_seconds
        result, exec_s = self._handlers[fn](inst.cache, payload)
        hydrate_s = inst.cache.stats.hydrate_seconds - hyd_before
        backfill_s = inst.cache.stats.backfill_seconds - bf_before
        cold = fresh or hydrate_s > 0

        # backfill (partial → full upgrade after the response) is OFF the
        # critical path: the caller's duration excludes it, but the instance
        # stays busy while it streams — and it bills on its own ledger line.
        duration = cold_boot + hydrate_s + exec_s
        # the primary occupies its instance for its FULL execution, win or
        # lose the hedge race — mark it busy now so a backup request can
        # never be "concurrently" placed on this same instance.
        inst.busy_until = t_start + duration + backfill_s
        inst.last_used = inst.busy_until
        inst.invocations += 1

        # Straggler hedging: if this execution ran past the hedge threshold,
        # fire a backup request on a second instance and take the faster.
        hedged = False
        result_duration = duration         # what the CALLER waits for
        if cfg.hedge_after_s is not None and exec_s > cfg.hedge_after_s:
            t_hedge = t_start + cfg.hedge_after_s
            inst2, fresh2 = self._acquire(t_hedge, fn)
            # a capped 1-instance fleet hands back the busy primary — there
            # is no second instance to back up on, so don't pretend to hedge
            if inst2 is not inst:
                queue2 = max(0.0, inst2.busy_until - t_hedge)
                hyd2_before = inst2.cache.stats.hydrate_seconds
                bf2_before = inst2.cache.stats.backfill_seconds
                result2, exec2_s = self._handlers[fn](inst2.cache, payload)
                hyd2 = inst2.cache.stats.hydrate_seconds - hyd2_before
                bf2 = inst2.cache.stats.backfill_seconds - bf2_before
                dur2 = (cfg.hedge_after_s + queue2
                        + (cfg.provision_s if fresh2 else 0.0) + hyd2 + exec2_s)
                if dur2 < result_duration:
                    result, result_duration = result2, dur2
                inst2.busy_until = t_start + dur2 + bf2
                inst2.last_used = inst2.busy_until
                inst2.invocations += 1
                self.ledger.charge(
                    Invocation(cfg.memory_bytes, exec2_s + hyd2, fresh2,
                               hedge=True))
                if bf2 > 0:
                    self.ledger.charge(
                        Invocation(cfg.memory_bytes, bf2, False,
                                   hedge=True, backfill=True))
                hedged = True

        self.clock = max(self.clock, inst.busy_until)

        self.ledger.charge(Invocation(cfg.memory_bytes, exec_s + hydrate_s,
                                      cold, hedge=hedge, idle=keepalive,
                                      write=write))
        if backfill_s > 0:
            # the deferred bulk transfer bills as its own invocation-time
            # line — never folded into the serving charge above, never into
            # the caller-visible latency below
            self.ledger.charge(Invocation(cfg.memory_bytes, backfill_s, False,
                                          hedge=hedge, backfill=True))
        rec = InvocationRecord(
            fn=fn, t_arrival=now, t_done=t_start + result_duration,
            latency_s=queue_wait + result_duration, exec_s=exec_s,
            hydrate_s=hydrate_s, cold=cold, provisioned=fresh,
            instance_id=inst.id,
            retries=attempt, hedged=hedged, keepalive=keepalive, write=write,
            backfill_s=backfill_s,
        )
        if record:
            self.records.append(rec)
        return result, rec

    # -- introspection ------------------------------------------------------------

    @property
    def fleet_size(self) -> int:
        return len(self._instances)

    def recent_latencies(self, fn=None, *, warm_only: bool = False,
                         window: int | None = None) -> list[float]:
        """Matching latencies from the record log, NEWEST first. ``window``
        caps the scan at that many newest matches — one bounded reverse
        pass, so per-query policy work never grows with the run length.
        Keep-alive pings never match (capacity maintenance, not queries)."""
        if fn is None:
            match = lambda r: True
        elif isinstance(fn, str):
            match = lambda r: r.fn == fn
        else:
            names = set(fn)
            match = lambda r: r.fn in names
        out: list[float] = []
        for r in reversed(self.records):
            if match(r) and not r.keepalive and not (warm_only and r.cold):
                out.append(r.latency_s)
                if window is not None and len(out) >= window:
                    break
        return out

    def latency_percentiles(self, fn=None, qs=(0.5, 0.9, 0.99), *,
                            warm_only: bool = False,
                            window: int | None = None) -> dict[float, float]:
        """Latency quantiles over the record log. ``fn`` may be a single
        function name or a collection of names (e.g. one partition's replica
        group); ``warm_only`` drops cold-start records — the baseline a
        hedging policy compares projected completions against. Keep-alive
        pings are never counted: they are capacity maintenance, not queries,
        and their near-zero exec would drag every quantile down.

        ``window`` restricts the quantiles to the newest matching records —
        the SAME recency convention :class:`~repro.core.partition.
        HedgePolicy` scans with, so a long-running fleet's controller scales
        on the latency regime it is actually in, not on hours-stale history
        (unwindowed, a mid-run regime shift is invisible until the old
        records are outnumbered)."""
        return nearest_rank_percentiles(
            self.recent_latencies(fn, warm_only=warm_only, window=window), qs)

    def warm_fraction(self, fn: str | None = None) -> float:
        recs = [r for r in self.records if fn is None or r.fn == fn]
        if not recs:
            return 0.0
        return sum(not r.cold for r in recs) / len(recs)


class _InstanceDied(Exception):
    pass
