"""DynamoDB-analogue key-value store for raw documents.

Paper §2: "Raw documents are stored in DynamoDB (organized as a simple
key-value store) so that they can be accessed as part of the search results."

Also used by the Crane & Lin '17 baseline (repro.baselines), which stored
*postings lists* in DynamoDB — the design the paper improves on.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Iterable, Mapping

from repro.core import jsonutil as orjson   # orjson when installed


class KVError(Exception):
    pass


@dataclasses.dataclass
class KVModel:
    """DynamoDB-ish latency accounting (simulated, never sleeps)."""

    get_s: float = 0.004          # single GetItem ~4 ms
    batch_get_s: float = 0.010    # BatchGetItem round trip
    batch_max_items: int = 100    # DynamoDB BatchGetItem limit
    put_s: float = 0.006

    def batch_get_cost(self, n_keys: int) -> float:
        """Simulated seconds for a batch_get of n_keys — one round trip per
        batch_max_items chunk, matching KVStore.batch_get's own accounting.
        Callers that bill KV time into their latency use THIS, never a
        hand-rolled formula."""
        return -(-n_keys // self.batch_max_items) * self.batch_get_s


@dataclasses.dataclass
class KVStats:
    gets: int = 0
    puts: int = 0
    deletes: int = 0
    round_trips: int = 0
    sim_seconds: float = 0.0


class KVStore:
    """Thread-safe KV store with JSON item values and batch ops."""

    def __init__(self, model: KVModel | None = None) -> None:
        self._items: dict[str, bytes] = {}
        self._lock = threading.Lock()
        self.model = model if model is not None else KVModel()
        self.stats = KVStats()

    def put(self, key: str, item: Mapping) -> None:
        data = orjson.dumps(item)
        with self._lock:
            self._items[key] = data
        self.stats.puts += 1
        self.stats.round_trips += 1
        self.stats.sim_seconds += self.model.put_s

    def batch_put(self, items: Mapping[str, Mapping]) -> None:
        blobs = {k: orjson.dumps(v) for k, v in items.items()}
        with self._lock:
            self._items.update(blobs)
        self.stats.puts += len(items)
        self.stats.round_trips += 1
        self.stats.sim_seconds += self.model.put_s

    def delete(self, key: str) -> None:
        """DeleteItem semantics: idempotent, missing keys are a no-op.
        Without this, a search fleet's document deletes would be cosmetic —
        the index tombstones the doc but its full contents stay fetchable
        by ext id forever (the usual reason to delete IS data removal)."""
        with self._lock:
            self._items.pop(key, None)
        self.stats.deletes += 1
        self.stats.round_trips += 1
        self.stats.sim_seconds += self.model.put_s   # DeleteItem ≈ PutItem

    def get(self, key: str) -> dict:
        with self._lock:
            data = self._items.get(key)
        self.stats.gets += 1
        self.stats.round_trips += 1
        self.stats.sim_seconds += self.model.get_s
        if data is None:
            raise KVError(f"no item {key!r}")
        return orjson.loads(data)

    def batch_get_billed(self, keys: Iterable[str]) -> tuple[dict[str, dict], float]:
        """batch_get + the simulated seconds a caller bills into ITS latency.

        The single source of the 'one deduped fetch, charged per
        BatchGetItem chunk' rule used by the search handler and the
        partitioned-app coordinator. Cost is charged per key ATTEMPTED —
        missing keys still cost the round trip."""
        keys = list(keys)
        if not keys:
            return {}, 0.0
        return self.batch_get(keys), self.model.batch_get_cost(len(keys))

    def batch_get(self, keys: Iterable[str]) -> dict[str, dict]:
        """BatchGetItem semantics: missing keys silently absent; batches of
        ``batch_max_items`` each cost one round trip."""
        keys = list(keys)
        out: dict[str, dict] = {}
        bm = self.model.batch_max_items
        for i in range(0, len(keys), bm):
            chunk = keys[i : i + bm]
            with self._lock:
                for k in chunk:
                    data = self._items.get(k)
                    if data is not None:
                        out[k] = orjson.loads(data)
            self.stats.round_trips += 1
            self.stats.sim_seconds += self.model.batch_get_s
            self.stats.gets += len(chunk)
        return out

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._items

    def __len__(self) -> int:
        with self._lock:
            return len(self._items)
